//! Quickstart: the smallest end-to-end mixed-precision OTA-FL run,
//! through the `Experiment` builder API.
//!
//! 15 clients in three precision groups (16/8/4-bit), 5 communication
//! rounds over synthetic traffic signs, analog over-the-air aggregation at
//! 20 dB SNR.  Run with:
//!
//! ```sh
//! make artifacts            # once
//! cargo run --release --example quickstart
//! ```

use std::rc::Rc;

use mpota::config::RunConfig;
use mpota::coordinator::pretrain;
use mpota::fl::Scheme;
use mpota::runtime::Runtime;
use mpota::sim::{Experiment, ProgressPrinter};

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.rounds = 5;
    cfg.scheme = Scheme::parse("16,8,4")?;
    cfg.train_samples = 1920; // 128 per client
    cfg.test_samples = 384;
    cfg.local_steps = 2;
    cfg.lr = 0.08;
    cfg.channel.snr_db = 20.0;

    // one shared runtime: pretraining and the experiment reuse it
    let runtime = Rc::new(Runtime::load(&cfg.artifacts_dir)?);
    // start from the pretrained feature extractor (the paper's runs start
    // from ImageNet weights) — trains it on first use, ~3 min
    cfg.init_params = Some(pretrain::ensure_pretrained(
        &runtime,
        &pretrain::PretrainConfig::default(),
    )?);

    println!("mpota quickstart — scheme {} over {} rounds", cfg.scheme, cfg.rounds);
    let mut exp = Experiment::builder(cfg)
        .runtime(runtime)
        .observe(ProgressPrinter) // streams each round as it completes
        .build()?;
    let report = exp.run()?;

    println!("\nfinal server accuracy: {:.2}%", 100.0 * report.final_accuracy);
    for rq in &report.requant {
        println!(
            "  requantized to {:>2}-bit: {:.2}%",
            rq.precision.bits(),
            100.0 * rq.accuracy
        );
    }
    println!(
        "energy: {:.2} J (vs all-32bit {:.2} J → {:.1}% saved)",
        report.energy.actual_joules,
        report.energy.all32_joules,
        report.energy.saving_vs_32()
    );
    Ok(())
}
