//! Channel-realism demo: temporally correlated fading, path-loss
//! geometry, and feedback-driven precision policies — no PJRT artifacts
//! needed, everything runs on the channel subsystem directly.
//!
//! 1. Gauss-Markov AR(1) fading: how ρ turns independent per-round fades
//!    into persistent ones (empirical lag-1 autocorrelation and the
//!    probability that a silenced client stays silenced next round).
//! 2. Path-loss geometry: the per-client SNR asymmetry a disc placement
//!    with log-distance path loss + shadowing produces.
//! 3. Feedback policies: the precision ladders `LossPlateau` and
//!    `EnergyBudget` walk in response to a synthetic run history.
//!
//! ```sh
//! cargo run --release --example correlated_fading
//! ```

use mpota::channel::{ChannelConfig, RoundChannel};
use mpota::metrics::RoundRecord;
use mpota::quant::Precision;
use mpota::rng::Rng;
use mpota::sim::{
    ChannelModel, EnergyBudget, GaussMarkov, LossPlateau, PathLossGeometry,
    PolicyCtx, PrecisionPolicy,
};

const CLIENTS: usize = 15;
const ROUNDS: usize = 400;

fn main() -> anyhow::Result<()> {
    correlated_fading();
    path_loss_geometry();
    feedback_policies()?;
    Ok(())
}

/// Drive a model for `ROUNDS` rounds and report temporal statistics.
fn correlated_fading() {
    println!("== Gauss-Markov correlated fading ({CLIENTS} clients, {ROUNDS} rounds)\n");
    println!(
        "{:>6} {:>10} {:>14} {:>16}",
        "rho", "lag1-acf", "P(silenced)", "P(stay silenced)"
    );
    for rho in [0.0f32, 0.5, 0.9, 0.99] {
        let mut cfg = ChannelConfig::default();
        cfg.rho = rho;
        let mut model = GaussMarkov::new(cfg);
        // mpota-lint: allow(R4): example binary — its own entry point with a demo seed
        let mut rng = Rng::seed_from(7);
        let mut rc = RoundChannel::empty();
        let mut prev_h = vec![mpota::channel::C32::ZERO; CLIENTS];
        let mut prev_silenced = vec![false; CLIENTS];
        let (mut num, mut den) = (0.0f64, 0.0f64);
        let (mut silenced, mut stay, mut stay_base) = (0usize, 0usize, 0usize);
        for t in 0..ROUNDS {
            model.draw_into(CLIENTS, &mut rng, &mut rc);
            for (k, c) in rc.clients.iter().enumerate() {
                let now_silenced = c.effective_gain.is_none();
                if t > 0 {
                    num += (c.h.re * prev_h[k].re + c.h.im * prev_h[k].im) as f64;
                    den += prev_h[k].norm_sq() as f64;
                    if prev_silenced[k] {
                        stay_base += 1;
                        if now_silenced {
                            stay += 1;
                        }
                    }
                }
                silenced += now_silenced as usize;
                prev_h[k] = c.h;
                prev_silenced[k] = now_silenced;
            }
        }
        let p_sil = silenced as f64 / (ROUNDS * CLIENTS) as f64;
        let p_stay = if stay_base > 0 {
            stay as f64 / stay_base as f64
        } else {
            f64::NAN
        };
        println!(
            "{rho:>6.2} {:>10.3} {:>13.1}% {:>15.1}%",
            num / den,
            100.0 * p_sil,
            100.0 * p_stay
        );
    }
    println!(
        "\n(i.i.d. fading forgets a deep fade immediately; at high rho a\n\
         silenced client tends to STAY silenced — exactly the correlated\n\
         outage pattern the paper's i.i.d. assumption hides)\n"
    );
}

fn path_loss_geometry() {
    println!("== Path-loss geometry ({CLIENTS} clients on a 100 m disc)\n");
    let mut cfg = ChannelConfig::default();
    cfg.model = mpota::channel::FadingKind::PathLoss;
    let mut model = PathLossGeometry::new(cfg);
    // mpota-lint: allow(R4): example binary — its own entry point with a demo seed
    let mut rng = Rng::seed_from(11);
    let mut rc = RoundChannel::empty();
    let mut silenced = vec![0usize; CLIENTS];
    for _ in 0..ROUNDS {
        model.draw_into(CLIENTS, &mut rng, &mut rc);
        for (k, c) in rc.clients.iter().enumerate() {
            silenced[k] += c.effective_gain.is_none() as usize;
        }
    }
    println!(
        "{:>7} {:>10} {:>11} {:>11} {:>10}",
        "client", "dist (m)", "shadow dB", "gain dB", "silenced"
    );
    let mut order: Vec<usize> = (0..CLIENTS).collect();
    let sites = model.sites().to_vec();
    order.sort_by(|&a, &b| sites[a].distance.partial_cmp(&sites[b].distance).unwrap());
    for k in order {
        let s = &sites[k];
        println!(
            "{k:>7} {:>10.1} {:>11.1} {:>11.1} {:>9.1}%",
            s.distance,
            s.shadow_db,
            20.0 * (s.amp as f64).log10(),
            100.0 * silenced[k] as f64 / ROUNDS as f64
        );
    }
    println!(
        "\n(near/unshadowed clients transmit nearly every round; far or\n\
         shadowed ones fall below the truncation threshold persistently)\n"
    );
}

fn feedback_policies() -> anyhow::Result<()> {
    println!("== Feedback precision policies (synthetic 30-round history)\n");
    let mut plateau: Box<dyn PrecisionPolicy> =
        Box::new(LossPlateau::new().with_patience(4));
    let mut budget: Box<dyn PrecisionPolicy> = Box::new(EnergyBudget::new(1.0));
    let mut out: Vec<Precision> = Vec::new();
    let mut rec = RoundRecord::default();
    println!("{:>6} {:>12} {:>14} {:>16}", "round", "loss", "plateau bits", "budget bits");
    for t in 1..=30 {
        let prev = if t == 1 { None } else { Some(&rec) };
        let ctx = PolicyCtx { round: t, clients: CLIENTS, snr_db: 20.0, prev };
        plateau.assign_into(&ctx, &mut out)?;
        let p_bits = out[0].bits();
        budget.assign_into(&ctx, &mut out)?;
        let b_bits = out[0].bits();
        // synthetic run: loss improves early then plateaus; energy accrues
        // ~0.6 J per round against the 15 J fleet budget
        let loss = if t < 10 { 2.0 / t as f64 } else { 0.21 };
        if t % 5 == 0 || t == 1 {
            println!("{t:>6} {loss:>12.3} {p_bits:>14} {b_bits:>16}");
        }
        rec = RoundRecord {
            round: t,
            server_loss: loss,
            energy_joules: 0.6 * t as f64,
            evaluated: true,
            ..Default::default()
        };
    }
    println!(
        "\n(loss-plateau promotes precision once improvement stalls;\n\
         energy-budget demotes it as the fleet burns through its cap)"
    );
    Ok(())
}
