//! End-to-end driver (DESIGN.md "End-to-end validation"): a full
//! mixed-precision OTA-FL training run through all three layers —
//! rust coordinator → PJRT artifacts → Pallas quantization kernels —
//! with pretrained initialization, logging the accuracy curve and the
//! final requantization/energy report exactly as EXPERIMENTS.md records.
//! Built on the `Experiment` session API; `--policy snr-adaptive` swaps
//! the static scheme for the dynamic bit-selection policy.
//!
//! Defaults are sized for a single CPU core (~10 min); flags scale it up:
//!
//! ```sh
//! cargo run --release --example mixed_precision_train -- \
//!     --scheme 16,8,4 --rounds 30 --snr-db 20
//! ```

use std::rc::Rc;

use mpota::cli::Args;
use mpota::config::RunConfig;
use mpota::coordinator::pretrain;
use mpota::fl::Scheme;
use mpota::runtime::Runtime;
use mpota::sim::{Experiment, ProgressPrinter};

fn main() -> anyhow::Result<()> {
    // examples have no subcommand; feed a placeholder one
    let mut args =
        Args::parse(std::iter::once("run".to_string()).chain(std::env::args().skip(1)))?;
    let mut cfg = RunConfig::default();
    cfg.rounds = args.get_parse("rounds", 30usize)?;
    if let Some(s) = args.get("scheme") {
        cfg.scheme = Scheme::parse(s)?;
    } else {
        cfg.scheme = Scheme::parse("16,8,4")?;
    }
    if let Some(p) = args.get("policy") {
        cfg.policy = p.parse()?;
    }
    cfg.train_samples = args.get_parse("train-samples", 2880usize)?;
    cfg.test_samples = args.get_parse("test-samples", 576usize)?;
    cfg.local_steps = args.get_parse("local-steps", 2usize)?;
    cfg.lr = args.get_parse("lr", 0.02f32)?;
    cfg.channel.snr_db = args.get_parse("snr-db", 20.0f32)?;
    cfg.seed = args.get_parse("seed", 42u64)?;
    args.finish()?;

    // Pretrained initialization (the paper's ImageNet stand-in), sharing
    // one runtime with the experiment.
    let runtime = Rc::new(Runtime::load(&cfg.artifacts_dir)?);
    let pcfg = pretrain::PretrainConfig::default();
    cfg.init_params = Some(pretrain::ensure_pretrained(&runtime, &pcfg)?);

    println!(
        "mixed-precision OTA-FL: scheme {}, policy {}, {} rounds, SNR {} dB, pretrained init",
        cfg.scheme, cfg.policy, cfg.rounds, cfg.channel.snr_db
    );
    let out_dir = cfg.out_dir.clone();
    let mut exp = Experiment::builder(cfg)
        .runtime(runtime.clone())
        .observe(ProgressPrinter)
        .build()?;
    let report = exp.run()?;

    println!("\n—— final report ——");
    println!("{}", report.to_json().to_string_pretty());
    if let Some(r90) = report.rounds_to_90 {
        println!("reached 90% at round {r90}");
    }
    let stem = format!("e2e_{}", report.file_label());
    report.log.write_files(&out_dir, &stem)?;
    println!("curve written to {}/{stem}.csv", out_dir.display());

    let c = runtime.counters();
    println!(
        "runtime counters: {} train steps ({:.3}s avg), {} eval batches ({:.3}s avg), {} compiles",
        c.train_steps,
        c.train_secs / c.train_steps.max(1) as f64,
        c.eval_batches,
        c.eval_secs / c.eval_batches.max(1) as f64,
        c.compiles
    );
    Ok(())
}
