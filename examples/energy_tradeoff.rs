//! Energy/accuracy trade-off explorer (the Fig.-4 scenario as a library
//! consumer would script it): sweeps precision schemes, reports each
//! scheme's 4-bit-client accuracy against its energy saving vs the
//! homogeneous 32-bit and 16-bit fleets.
//!
//! Multi-run idiom: ONE `Rc<Runtime>` (artifacts compile once) and ONE
//! recycled `Arena` (server buffers allocate once) across all eight runs
//! — the same machinery `mpota sweep` uses.
//!
//! ```sh
//! cargo run --release --example energy_tradeoff -- --rounds 8
//! ```

use std::rc::Rc;

use mpota::cli::Args;
use mpota::config::RunConfig;
use mpota::coordinator::pretrain;
use mpota::fl::Scheme;
use mpota::quant::Precision;
use mpota::runtime::Runtime;
use mpota::sim::{Arena, Experiment};

fn main() -> anyhow::Result<()> {
    let mut args =
        Args::parse(std::iter::once("run".to_string()).chain(std::env::args().skip(1)))?;
    let rounds = args.get_parse("rounds", 8usize)?;
    let samples = args.get_parse("train-samples", 1920usize)?;
    args.finish()?;

    // schemes containing a 4-bit group (the paper's Fig.-4 focus) plus the
    // homogeneous baselines
    let schemes = [
        "32,32,32", "16,16,16", "8,8,8", "4,4,4", // homogeneous
        "32,16,4", "16,8,4", "12,4,4", "24,8,4", // mixed with 4-bit clients
    ];

    let runtime = Rc::new(Runtime::load(std::path::Path::new("artifacts"))?);
    let pretrained =
        pretrain::ensure_pretrained(&runtime, &pretrain::PretrainConfig::default())?;

    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>12}",
        "scheme", "acc@4bit", "energy (J)", "vs 32-bit", "vs 16-bit"
    );
    let mut arena = Arena::default();
    for s in schemes {
        let mut cfg = RunConfig::default();
        cfg.rounds = rounds;
        cfg.scheme = Scheme::parse(s)?;
        cfg.train_samples = samples;
        cfg.test_samples = 384;
        cfg.local_steps = 2;
        cfg.lr = 0.02;
        cfg.init_params = Some(pretrained.clone());
        let mut exp = Experiment::builder(cfg)
            .runtime(runtime.clone())
            .arena(arena)
            .build()?;
        let report = exp.run()?;

        // 4-bit client view: final global model requantized to 4 bits
        // (for schemes without 4-bit clients, evaluate it anyway — that is
        // exactly the paper's "re-quantized for 4-bit clients" comparison)
        let acc4 = match report
            .requant
            .iter()
            .find(|r| r.precision.bits() == 4)
        {
            Some(r) => r.accuracy,
            None => {
                let q = exp.requantize_global(Precision::of(4));
                exp.evaluate_model(&q)?.accuracy
            }
        };
        println!(
            "{:<10} {:>9.2}% {:>12.2} {:>11.1}% {:>11.1}%",
            s,
            100.0 * acc4,
            report.energy.actual_joules,
            report.energy.saving_vs_32(),
            report.energy.saving_vs_16()
        );
        arena = exp.into_arena();
    }
    Ok(())
}
