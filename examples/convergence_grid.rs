//! Convergence-science grid: feedback precision policies vs the static
//! ladder across Dirichlet(α) label skew × SNR × aggregator — the
//! evaluation the policy subsystem was built for, runnable anywhere.
//!
//! Every cell trains with the deterministic PJRT-free
//! [`mpota::testing::GradStatsBackend`]: each client's gradients pull the
//! model toward a synthetic optimum displaced along its own label
//! marginal, so non-IID partitions produce the real pathology (client
//! drift slows convergence; aggregation noise slows it further) at a few
//! milliseconds per round.  Because the backend is built per cell from a
//! factory, the fl-mode cells run CONCURRENTLY on the exec pool under
//! `workers > 1`, and the report is bit-identical to a serial run.
//!
//! The CLI equivalent is
//! `mpota sweep --mock-backend --partitions iid,dirichlet --alphas 0.1,1.0
//!  --snrs 0,20 --aggregations ota,ideal
//!  --policies static,snr-adaptive,loss-plateau,profiling --workers 4`.
//!
//! ```sh
//! cargo run --release --example convergence_grid
//! ```

use mpota::config::{Aggregation, PartitionKind, PolicyKind, RunConfig};
use mpota::fl::Scheme;
use mpota::sim::sweep::{run_fl_sweep, SweepSpec};

fn main() -> anyhow::Result<()> {
    let mut base = RunConfig::default();
    base.artifacts_dir = mpota::testing::mock_artifacts_dir("convergence-grid");
    base.variant = "mock".into();
    base.clients = 6;
    base.clients_per_round = 6;
    base.rounds = 12;
    base.train_samples = 96;
    base.test_samples = 32;
    base.scheme = Scheme::parse("16,8,4")?;
    base.seed = 7;
    base.workers = 4; // cell-level parallelism under the backend factory

    let mut spec = SweepSpec::new(base);
    spec.snrs_db = vec![0.0, 20.0];
    spec.aggregations = vec![Aggregation::OtaAnalog, Aggregation::Ideal];
    spec.policies = vec![
        PolicyKind::Static,
        PolicyKind::SnrAdaptive,
        PolicyKind::LossPlateau,
        PolicyKind::Profiling,
    ];
    // the IID column is the drift-free reference; under iid the alpha
    // coordinate is inert (identical cells, distinct grid labels)
    spec.partitions = vec![PartitionKind::Iid, PartitionKind::Dirichlet];
    spec.alphas = vec![0.1, 1.0];
    spec.backend_factory = Some(std::sync::Arc::new(|| {
        Box::new(mpota::testing::GradStatsBackend::for_mock())
            as Box<dyn mpota::exec::TrainBackend>
    }));

    println!(
        "convergence grid: {} cells ({} policies x {} SNRs x {} aggregators \
         x {} partitions x {} alphas)\n",
        spec.grid_size(),
        spec.policies.len(),
        spec.snrs_db.len(),
        spec.aggregations.len(),
        spec.partitions.len(),
        spec.alphas.len()
    );
    let report = run_fl_sweep(&spec)?;

    println!(
        "{:<10} {:>6} {:<13} {:>7} {:>8} {:>12} {:>10} {:>10}",
        "partition", "alpha", "policy", "snr dB", "agg", "final loss", "final acc", "energy J"
    );
    for c in report.json.req("cells")?.as_array()? {
        println!(
            "{:<10} {:>6} {:<13} {:>7.1} {:>8} {:>12.5} {:>10.4} {:>10.3}",
            c.req("partition")?.as_str()?,
            c.req("alpha")?.as_f64()?,
            c.req("policy")?.as_str()?,
            c.req("snr_db")?.as_f64()?,
            c.req("aggregation")?.as_str()?,
            c.req("final_loss")?.as_f64()?,
            c.req("final_accuracy")?.as_f64()?,
            c.req("energy_j")?.as_f64()?,
        );
    }

    let path = std::path::Path::new("runs/convergence_grid/SWEEP_report.json");
    report.write(path)?;
    println!("\nconsolidated report written to {}", path.display());
    Ok(())
}
