//! Config-grid sweep through the library API: schemes × SNRs ×
//! aggregators in one process, consolidated JSON report out.
//!
//! Uses the channel-only mode (synthetic payloads through policy +
//! channel model + aggregator — no PJRT artifacts needed), so this runs
//! anywhere; swap `run_channel_sweep` for `run_fl_sweep` to sweep full
//! federated runs once `make artifacts` has been run.  The CLI equivalent
//! is `mpota sweep --channel-only --schemes "16,8,4;8,8,8" --snrs 5,20`.
//!
//! ```sh
//! cargo run --release --example sweep_grid
//! ```

use mpota::config::{Aggregation, RunConfig};
use mpota::fl::Scheme;
use mpota::sim::sweep::{run_channel_sweep, SweepSpec};

fn main() -> anyhow::Result<()> {
    let mut base = RunConfig::default();
    base.rounds = 4;
    base.seed = 7;

    let mut spec = SweepSpec::new(base);
    spec.schemes = vec![
        Scheme::parse("16,8,4")?,
        Scheme::parse("8,8,8")?,
        Scheme::parse("4,4,4")?,
    ];
    spec.snrs_db = vec![5.0, 15.0, 25.0];
    spec.aggregations = vec![Aggregation::OtaAnalog, Aggregation::Ideal];
    spec.payload_len = 16_384;

    println!(
        "channel-only sweep: {} cells ({} schemes x {} SNRs x {} aggregators)\n",
        spec.grid_size(),
        spec.schemes.len(),
        spec.snrs_db.len(),
        spec.aggregations.len()
    );
    let report = run_channel_sweep(&spec)?;

    println!(
        "{:<10} {:>8} {:>8} {:>14} {:>14}",
        "scheme", "snr dB", "agg", "mse vs ideal", "participants"
    );
    for c in report.json.req("cells")?.as_array()? {
        println!(
            "{:<10} {:>8.1} {:>8} {:>14.3e} {:>14.1}",
            c.req("scheme")?.as_str()?,
            c.req("snr_db")?.as_f64()?,
            c.req("aggregation")?.as_str()?,
            c.req("mean_mse_vs_ideal")?.as_f64()?,
            c.req("mean_participants")?.as_f64()?,
        );
    }

    let path = std::path::Path::new("runs/sweep_grid/SWEEP_report.json");
    report.write(path)?;
    println!("\nconsolidated report written to {}", path.display());
    Ok(())
}
