//! Wireless-layer walkthrough: what the multi-precision modulation scheme
//! actually does, step by step, with numbers you can read — driven through
//! the composable `sim` traits (`ChannelModel` + `Aggregator` behind a
//! `Session`), with no ML in the loop.
//!
//! Demonstrates (1) why mixed-precision payloads superpose cleanly under
//! analog amplitude modulation, (2) the effect of SNR, channel-estimation
//! quality and the fading model on aggregation error, and (3) the
//! bandwidth cost of the digital-orthogonal baseline — the paper's
//! Eq. 2-8 pipeline end to end.
//!
//! ```sh
//! cargo run --release --example ota_channel_demo
//! ```

use mpota::channel::ChannelConfig;
use mpota::kernels::PayloadPlane;
use mpota::ota;
use mpota::quant::{fake_quant, Precision};
use mpota::rng::Rng;
use mpota::sim::{AnalogOta, Awgn, ChannelModel, RayleighPilot, Session};
use mpota::tensor;

fn main() -> anyhow::Result<()> {
    let k = 15;
    let n = 65_536;
    // mpota-lint: allow(R4): example binary — its own entry point with a demo seed
    let root = Rng::seed_from(2025);

    // --- 1. fifteen clients with mixed-precision payloads ---------------
    let mut data_rng = root.stream("payloads");
    let raw: Vec<Vec<f32>> = (0..k)
        .map(|_| {
            let mut v = vec![0.0f32; n];
            data_rng.fill_normal(&mut v, 0.0, 1.0);
            v
        })
        .collect();
    let precisions: Vec<Precision> = [32u8, 32, 32, 32, 32, 8, 8, 8, 8, 8, 4, 4, 4, 4, 4]
        .iter()
        .map(|&b| Precision::of(b))
        .collect();
    let payloads: Vec<Vec<f32>> = raw
        .iter()
        .zip(&precisions)
        .map(|(r, &p)| fake_quant(r, p))
        .collect();
    let plane = PayloadPlane::from_rows(&payloads);
    println!("clients: 5x32-bit, 5x8-bit, 5x4-bit; payload {n} params each\n");

    // the noise-free ideal the channel should reproduce
    let ideal = mpota::fl::mean(&payloads);

    // --- 2. analog OTA across channel models, SNR and CSI quality -------
    // each row is one pluggable ChannelModel behind the same Session API
    let rows: Vec<(&str, Box<dyn ChannelModel>)> = vec![
        (
            "rayleigh  5 dB, est. CSI",
            Box::new(RayleighPilot::new(ChannelConfig {
                snr_db: 5.0,
                ..Default::default()
            })),
        ),
        (
            "rayleigh 15 dB, est. CSI",
            Box::new(RayleighPilot::new(ChannelConfig {
                snr_db: 15.0,
                ..Default::default()
            })),
        ),
        (
            "rayleigh 30 dB, est. CSI",
            Box::new(RayleighPilot::new(ChannelConfig {
                snr_db: 30.0,
                ..Default::default()
            })),
        ),
        (
            "rayleigh 30 dB, perfect CSI",
            Box::new(RayleighPilot::new(ChannelConfig {
                snr_db: 30.0,
                perfect_csi: true,
                ..Default::default()
            })),
        ),
        ("awgn     30 dB (no fading)", Box::new(Awgn { snr_db: 30.0 })),
    ];
    println!("{:<28} {:>12} {:>14}", "channel model", "agg MSE", "participants");
    for (label, model) in rows {
        let mut session = Session::new(
            model,
            Box::new(AnalogOta),
            root.stream(label),
            root.stream("noise"),
            1,
        );
        let stats = session.aggregate(1, &plane, &precisions);
        let mse = tensor::mse(session.result(), &ideal);
        println!("{label:<28} {mse:>12.3e} {:>14}", stats.participants);
    }

    // --- 3. the digital-orthogonal baseline -----------------------------
    let (dig, dstats) = ota::digital::aggregate(&raw, &precisions);
    let dig_mse = tensor::mse(&dig, &ideal);
    println!("\ndigital orthogonal baseline:");
    println!("  aggregate MSE vs ideal: {dig_mse:.3e} (bit-exact transport)");
    println!(
        "  channel uses: {} (OTA uses {n} — a {}x bandwidth win for OTA)",
        dstats.channel_uses,
        dstats.channel_uses / n as u64
    );
    println!(
        "  bits on the wire: {} ({} bits/param avg across the mixed fleet)",
        dstats.bits_transmitted,
        dstats.bits_transmitted / (k as u64 * n as u64)
    );

    // --- 4. Eq. 3's obstruction, demonstrated ---------------------------
    // summing *integer codes* across precisions is meaningless: quantize
    // two payloads at different precisions and compare code-sum vs
    // decimal-sum.
    let a = &raw[0][..8];
    let b = &raw[10][..8];
    let (ca, pa) = mpota::quant::fixed::encode_tensor(a, 8);
    let (cb, pb) = mpota::quant::fixed::encode_tensor(b, 4);
    println!("\nEq. 3 demo (first 4 params):");
    println!("  8-bit codes {:?} (scale {:.4})", &ca[..4], pa.scale);
    println!("  4-bit codes {:?} (scale {:.4})", &cb[..4], pb.scale);
    let code_sum: Vec<u32> = ca.iter().zip(&cb).map(|(x, y)| x + y).collect();
    let decimal_sum: Vec<f32> = a.iter().zip(b).map(|(x, y)| x + y).collect();
    println!("  raw code sum      {:?}  <- no common scale: meaningless", &code_sum[..4]);
    println!(
        "  decimal (analog)  {:?}  <- what amplitude modulation sums",
        &decimal_sum[..4]
    );
    Ok(())
}
