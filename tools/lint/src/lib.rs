//! `mpota-lint` — invariant-enforcing static analysis for the mpota
//! OTA-FL reproduction.
//!
//! The repo's standing contracts (per-seed bit-identity across
//! `{pipeline_depth, shard_size, threads, workers}`, zero-alloc
//! steady-state rounds, one sanctioned thread spawner, one sanctioned
//! randomness root) are enforced dynamically by the test suites — but a
//! dynamic test only catches the schedule it happens to run.  This tool
//! is the static complement: a hand-rolled Rust lexer (no external
//! crates, matching the product crate's no-deps idiom) walks
//! `rust/src`, `rust/benches`, `rust/tests` and `examples/` and enforces
//! six repo-specific rules with `file:line` diagnostics:
//!
//! * **R1** — every `unsafe` block / fn / impl is immediately preceded
//!   by a `// SAFETY:` comment (a `# Safety` doc section counts for
//!   `unsafe fn` declarations).
//! * **R2** — no `std::thread::{spawn, scope, Builder}` outside
//!   `exec/pool.rs`: the parked pool is the only sanctioned spawner.
//! * **R3** — no `HashMap` / `HashSet` on result-feeding paths: their
//!   iteration order is nondeterministic and breaks the bit-identity
//!   contract.  (Test-only code is exempt.)
//! * **R4** — no RNG construction or seeding outside `rng.rs`: all
//!   randomness must derive from the run root via the named skip-ahead
//!   stream API (`stream` / `substream`).  (Tests and benches, which
//!   are their own entry points, are exempt.)
//! * **R5** — no allocating calls inside functions tagged
//!   `// mpota-lint: zero-alloc-hot` — the static complement to the
//!   counting-allocator audit in `rust/tests/alloc_counter.rs`.  In
//!   `rust/src/kernels/` the tag is itself mandatory for hot-path
//!   kernels (fn names containing `superpose`/`axpy`/`pack`): an
//!   untagged packed kernel is a lint failure.
//! * **R6** — unsafe-count ratchet: each file's `unsafe` site count
//!   must not exceed its committed baseline
//!   (`tools/lint/baseline.json`).
//!
//! Escapes: `// mpota-lint: allow(<rule>): <mandatory reason>` on the
//! violating line (trailing) or in the comment block immediately above
//! it.  An allow without a reason is itself a violation.  R6 has no
//! inline escape — raising a file's unsafe budget is a deliberate edit
//! to the committed baseline.
//!
//! Output: human diagnostics on stderr/stdout (via the callers) and a
//! machine-readable `LINT_report.json` at the repo root; nonzero exit
//! on any violation.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// The directories scanned, relative to the repo root.
pub const SCAN_DIRS: [&str; 4] = ["rust/src", "rust/benches", "rust/tests", "examples"];

/// Default location of the unsafe-ratchet baseline, relative to root.
pub const BASELINE_REL: &str = "tools/lint/baseline.json";

/// Default location of the machine-readable report, relative to root.
pub const REPORT_REL: &str = "LINT_report.json";

// ---------------------------------------------------------------------------
// Rules and diagnostics
// ---------------------------------------------------------------------------

/// A lint rule (R1–R6) or the escape-syntax meta rule.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Rule {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
    /// Malformed `mpota-lint:` directives (missing reason, unknown rule).
    Escape,
}

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
            Rule::R6 => "R6",
            Rule::Escape => "escape",
        }
    }

    fn from_id(s: &str) -> Option<Rule> {
        match s {
            "R1" => Some(Rule::R1),
            "R2" => Some(Rule::R2),
            "R3" => Some(Rule::R3),
            "R4" => Some(Rule::R4),
            "R5" => Some(Rule::R5),
            "R6" => Some(Rule::R6),
            _ => None,
        }
    }
}

/// One violation, anchored to a repo-relative `file:line`.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

/// One `mpota-lint: allow(...)` escape found in the tree.
#[derive(Clone, Debug)]
pub struct Allow {
    pub file: String,
    pub line: usize,
    pub rule: Rule,
    pub reason: String,
}

/// Scan result for a single source file.
#[derive(Clone, Debug, Default)]
pub struct FileScan {
    pub diagnostics: Vec<Diagnostic>,
    pub allows: Vec<Allow>,
    pub unsafe_count: usize,
}

// ---------------------------------------------------------------------------
// Lexer
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
enum TokData {
    Ident(String),
    Punct(char),
}

#[derive(Clone, Debug)]
struct Tok {
    line: usize,
    data: TokData,
}

impl Tok {
    fn is_ident(&self, s: &str) -> bool {
        matches!(&self.data, TokData::Ident(t) if t == s)
    }

    fn ident(&self) -> Option<&str> {
        match &self.data {
            TokData::Ident(t) => Some(t.as_str()),
            TokData::Punct(_) => None,
        }
    }

    fn is_punct(&self, c: char) -> bool {
        matches!(&self.data, TokData::Punct(p) if *p == c)
    }
}

/// Per-line facts the rule checks consume (1-indexed; entry 0 unused).
#[derive(Clone, Debug, Default)]
struct LineInfo {
    /// Concatenated comment text on this line (line + block comments).
    comment: String,
    has_comment: bool,
    /// Any non-comment token starts on this line.
    has_code: bool,
    /// An `unsafe` keyword token starts on this line.
    has_unsafe: bool,
    /// The raw line starts with an attribute (`#[` / `#![`).
    attr_only: bool,
}

impl LineInfo {
    fn comment_only(&self) -> bool {
        self.has_comment && !self.has_code
    }
}

struct Lexed {
    toks: Vec<Tok>,
    lines: Vec<LineInfo>,
}

/// Tokenize Rust source into idents and punctuation, stripping comments
/// (recorded per line), string/char literals and numbers.  This is not a
/// full Rust lexer — it only needs to be exact about what is and is not
/// code, so that keyword matches never fire inside comments or strings.
fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let nlines = src.lines().count();
    let mut lines = vec![LineInfo::default(); nlines + 2];
    let mut toks: Vec<Tok> = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;
    let n = chars.len();

    let record_comment = |lines: &mut [LineInfo], line: usize, text: &str| {
        let li = &mut lines[line];
        li.has_comment = true;
        if !li.comment.is_empty() {
            li.comment.push(' ');
        }
        li.comment.push_str(text);
    };

    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // line comment (also covers /// and //! doc comments)
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            record_comment(&mut lines, line, &text);
            continue;
        }
        // block comment, possibly nested / multi-line
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            let mut text = String::new();
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    record_comment(&mut lines, line, &text);
                    text.clear();
                    line += 1;
                    i += 1;
                } else if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    text.push(chars[i]);
                    i += 1;
                }
            }
            record_comment(&mut lines, line, &text);
            continue;
        }
        // raw strings and raw identifiers: r"..", r#".."#, br".."; r#ident
        if (c == 'r' || c == 'b') && i + 1 < n {
            let raw_at = if c == 'b' && chars[i + 1] == 'r' { i + 2 } else { i + 1 };
            let mut h = raw_at;
            while h < n && chars[h] == '#' {
                h += 1;
            }
            let hashes = h - raw_at;
            let is_raw_str = (c == 'r' || chars.get(i + 1) == Some(&'r'))
                && h < n
                && chars[h] == '"'
                && (c != 'b' || chars[i + 1] == 'r');
            if is_raw_str {
                // skip to the matching `"###` terminator
                i = h + 1;
                'raw: while i < n {
                    if chars[i] == '\n' {
                        line += 1;
                        i += 1;
                        continue;
                    }
                    if chars[i] == '"' {
                        let mut k = 0usize;
                        while k < hashes && i + 1 + k < n && chars[i + 1 + k] == '#' {
                            k += 1;
                        }
                        if k == hashes {
                            i += 1 + hashes;
                            break 'raw;
                        }
                    }
                    i += 1;
                }
                continue;
            }
            if c == 'r' && hashes == 1 && h < n && is_ident_start(chars[h]) {
                // raw identifier r#type: lex the ident, drop the prefix
                i = h;
                let start = i;
                while i < n && is_ident_continue(chars[i]) {
                    i += 1;
                }
                let text: String = chars[start..i].iter().collect();
                push_ident(&mut toks, &mut lines, line, text);
                continue;
            }
        }
        // byte string b"..." / byte char b'x'
        if c == 'b' && i + 1 < n && (chars[i + 1] == '"' || chars[i + 1] == '\'') {
            i += 1;
            // fall through to the string/char branches below on next loop
            let quote = chars[i];
            i = skip_quoted(&chars, i, quote, &mut line);
            continue;
        }
        // string literal
        if c == '"' {
            i = skip_quoted(&chars, i, '"', &mut line);
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && chars[i + 1] == '\\' {
                i = skip_quoted(&chars, i, '\'', &mut line);
                continue;
            }
            if i + 2 < n && is_ident_start(chars[i + 1]) && chars[i + 2] != '\'' {
                // lifetime: skip the tick and its ident
                i += 1;
                while i < n && is_ident_continue(chars[i]) {
                    i += 1;
                }
                continue;
            }
            i = skip_quoted(&chars, i, '\'', &mut line);
            continue;
        }
        // number literal (digits + alphanumeric suffix/radix chars)
        if c.is_ascii_digit() {
            while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            lines[line].has_code = true;
            continue;
        }
        // identifier / keyword
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_continue(chars[i]) {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            push_ident(&mut toks, &mut lines, line, text);
            continue;
        }
        // punctuation
        toks.push(Tok { line, data: TokData::Punct(c) });
        lines[line].has_code = true;
        i += 1;
    }

    // attribute lines, from the raw text
    for (idx, raw) in src.lines().enumerate() {
        let t = raw.trim_start();
        if t.starts_with("#[") || t.starts_with("#![") {
            lines[idx + 1].attr_only = true;
        }
    }

    Lexed { toks, lines }
}

fn push_ident(toks: &mut Vec<Tok>, lines: &mut [LineInfo], line: usize, text: String) {
    lines[line].has_code = true;
    if text == "unsafe" {
        lines[line].has_unsafe = true;
    }
    toks.push(Tok { line, data: TokData::Ident(text) });
}

fn is_ident_start(c: char) -> bool {
    c.is_alphabetic() || c == '_'
}

fn is_ident_continue(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Skip a quoted literal starting at the opening quote; returns the index
/// one past the closing quote, tracking newlines (multi-line strings).
fn skip_quoted(chars: &[char], open: usize, quote: char, line: &mut usize) -> usize {
    let n = chars.len();
    let mut i = open + 1;
    while i < n {
        let c = chars[i];
        if c == '\\' {
            i += 2;
            continue;
        }
        if c == '\n' {
            *line += 1;
            i += 1;
            continue;
        }
        if c == quote {
            return i + 1;
        }
        i += 1;
    }
    i
}

// ---------------------------------------------------------------------------
// Directives: allow(...) escapes and zero-alloc-hot markers
// ---------------------------------------------------------------------------

struct Directives {
    allows: Vec<Allow>,
    /// Lines carrying a `zero-alloc-hot` marker.
    hot_markers: Vec<usize>,
    /// Malformed-directive diagnostics.
    errors: Vec<Diagnostic>,
}

fn parse_directives(rel: &str, lines: &[LineInfo]) -> Directives {
    let mut out = Directives { allows: Vec::new(), hot_markers: Vec::new(), errors: Vec::new() };
    for (lno, li) in lines.iter().enumerate() {
        if !li.has_comment {
            continue;
        }
        let text = li.comment.as_str();
        let mut from = 0usize;
        while let Some(pos) = text[from..].find("mpota-lint:") {
            let at = from + pos + "mpota-lint:".len();
            let rest = text[at..].trim_start();
            from = at;
            if let Some(inner) = rest.strip_prefix("allow(") {
                let Some(close) = inner.find(')') else {
                    out.errors.push(Diagnostic {
                        file: rel.to_string(),
                        line: lno,
                        rule: Rule::Escape,
                        message: "unterminated `mpota-lint: allow(` directive".into(),
                    });
                    continue;
                };
                let rule_id = inner[..close].trim();
                let tail = inner[close + 1..].trim_start();
                let Some(rule) = Rule::from_id(rule_id) else {
                    out.errors.push(Diagnostic {
                        file: rel.to_string(),
                        line: lno,
                        rule: Rule::Escape,
                        message: format!("allow(...) names unknown rule '{rule_id}'"),
                    });
                    continue;
                };
                if rule == Rule::R6 {
                    out.errors.push(Diagnostic {
                        file: rel.to_string(),
                        line: lno,
                        rule: Rule::Escape,
                        message: "R6 (unsafe ratchet) has no inline escape — edit \
                                  tools/lint/baseline.json deliberately"
                            .into(),
                    });
                    continue;
                }
                let reason = tail.strip_prefix(':').map(str::trim).unwrap_or("");
                if reason.is_empty() {
                    out.errors.push(Diagnostic {
                        file: rel.to_string(),
                        line: lno,
                        rule: Rule::Escape,
                        message: format!(
                            "allow({rule_id}) without a reason — write \
                             `mpota-lint: allow({rule_id}): <why this is sound>`"
                        ),
                    });
                    continue;
                }
                out.allows.push(Allow {
                    file: rel.to_string(),
                    line: lno,
                    rule,
                    reason: reason.to_string(),
                });
            } else if rest.starts_with("zero-alloc-hot") {
                out.hot_markers.push(lno);
            } else {
                let word: String =
                    rest.chars().take_while(|c| !c.is_whitespace()).collect();
                out.errors.push(Diagnostic {
                    file: rel.to_string(),
                    line: lno,
                    rule: Rule::Escape,
                    message: format!("unknown mpota-lint directive '{word}'"),
                });
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Shared walk: does an annotation in the comment block above cover `line`?
// ---------------------------------------------------------------------------

/// Walk upward from `line` through attribute lines and lines that are
/// themselves part of the same `unsafe` group, into the contiguous
/// comment block immediately above; `pred` is evaluated on every comment
/// line (and on `line` itself, covering trailing comments).
fn comment_scope_satisfies<F>(lines: &[LineInfo], line: usize, pred: F) -> bool
where
    F: Fn(usize) -> bool,
{
    if pred(line) {
        return true;
    }
    let mut i = line.saturating_sub(1);
    while i >= 1 {
        let li = &lines[i];
        if li.comment_only() {
            // scan the whole contiguous comment block
            let mut j = i;
            while j >= 1 && lines[j].comment_only() {
                if pred(j) {
                    return true;
                }
                j -= 1;
            }
            return false;
        }
        if li.attr_only || li.has_unsafe {
            i -= 1;
            continue;
        }
        return false;
    }
    false
}

// ---------------------------------------------------------------------------
// Per-file scan
// ---------------------------------------------------------------------------

/// Which rules apply to a file, derived from its repo-relative path.
struct Scope {
    r2: bool,
    r3: bool,
    r4: bool,
}

fn scope_for(rel: &str) -> Scope {
    let tests = rel.starts_with("rust/tests/");
    let benches = rel.starts_with("rust/benches/");
    Scope {
        // exec/pool.rs is the one sanctioned spawner
        r2: !rel.ends_with("exec/pool.rs"),
        // test binaries never feed round results
        r3: !tests,
        // rng.rs owns construction; tests and benches are their own
        // seeded entry points
        r4: !rel.ends_with("src/rng.rs") && !tests && !benches,
    }
}

const R5_PATH_TYPES: [&str; 10] = [
    "Vec", "Box", "String", "VecDeque", "BTreeMap", "BTreeSet", "HashMap",
    "HashSet", "Rc", "Arc",
];
const R5_PATH_FNS: [&str; 5] = ["new", "with_capacity", "from", "from_iter", "pin"];
const R5_METHODS: [&str; 4] = ["to_vec", "to_owned", "to_string", "collect"];
const R5_MACROS: [&str; 2] = ["vec", "format"];
/// Hot-path kernel name fragments: a non-test `fn` in `rust/src/kernels/`
/// whose name contains one of these IS superposition hot path and must
/// carry the `// mpota-lint: zero-alloc-hot` tag (R5 coverage check).
const R5_KERNEL_NAMES: [&str; 3] = ["superpose", "axpy", "pack"];
const R4_IDENTS: [&str; 5] =
    ["seed_from", "thread_rng", "from_entropy", "StdRng", "SmallRng"];

/// Scan one file's source.  `baseline_unsafe` is the committed R6 budget
/// for this file (0 when absent from the baseline).
pub fn scan_source(rel: &str, src: &str, baseline_unsafe: usize) -> FileScan {
    let lexed = lex(src);
    let toks = &lexed.toks;
    let lines = &lexed.lines;
    let scope = scope_for(rel);
    let directives = parse_directives(rel, lines);
    let test_spans = test_token_spans(toks);
    let in_test = |ti: usize| test_spans.iter().any(|&(lo, hi)| ti >= lo && ti < hi);

    let mut raw: Vec<Diagnostic> = Vec::new();
    let mut unsafe_count = 0usize;

    // --- token-stream rules -------------------------------------------
    for ti in 0..toks.len() {
        let tok = &toks[ti];
        let Some(id) = tok.ident() else { continue };
        match id {
            "unsafe" => {
                unsafe_count += 1;
                let (kind, fn_like) = match toks.get(ti + 1) {
                    Some(t) if t.is_ident("fn") => ("fn", true),
                    Some(t) if t.is_ident("impl") => ("impl", false),
                    Some(t) if t.is_ident("trait") => ("trait", false),
                    Some(t) if t.is_ident("extern") => ("extern block", true),
                    _ => ("block", false),
                };
                let covered = comment_scope_satisfies(lines, tok.line, |l| {
                    let li = &lines[l];
                    li.has_comment
                        && (li.comment.contains("SAFETY:")
                            || (fn_like && li.comment.contains("# Safety")))
                });
                if !covered {
                    raw.push(Diagnostic {
                        file: rel.to_string(),
                        line: tok.line,
                        rule: Rule::R1,
                        message: format!(
                            "`unsafe` {kind} without an immediately preceding \
                             `// SAFETY:` comment stating the aliasing/lifetime \
                             argument"
                        ),
                    });
                }
            }
            "thread" if scope.r2 => {
                if let Some(m) = path_call(toks, ti, &["spawn", "scope", "Builder"]) {
                    raw.push(Diagnostic {
                        file: rel.to_string(),
                        line: tok.line,
                        rule: Rule::R2,
                        message: format!(
                            "`std::thread::{m}` outside exec/pool.rs — the parked \
                             `exec::pool()` is the only sanctioned spawner \
                             (dispatch with broadcast/host_broadcast)"
                        ),
                    });
                }
            }
            "HashMap" | "HashSet" if scope.r3 && !in_test(ti) => {
                raw.push(Diagnostic {
                    file: rel.to_string(),
                    line: tok.line,
                    rule: Rule::R3,
                    message: format!(
                        "`{id}` on a result-feeding path — its iteration order is \
                         nondeterministic and breaks the per-seed bit-identity \
                         contract; use BTreeMap/BTreeSet/Vec"
                    ),
                });
            }
            _ if scope.r4 && R4_IDENTS.contains(&id) && !in_test(ti) => {
                raw.push(Diagnostic {
                    file: rel.to_string(),
                    line: tok.line,
                    rule: Rule::R4,
                    message: format!(
                        "RNG construction/seeding (`{id}`) outside rng.rs — all \
                         randomness must derive from the run root via the named \
                         stream API (`stream`/`substream`)"
                    ),
                });
            }
            _ => {}
        }
    }

    // --- R5: allocating calls inside zero-alloc-hot functions ----------
    for &marker_line in &directives.hot_markers {
        match hot_fn_body(toks, marker_line) {
            Some((body_lo, body_hi)) => {
                scan_hot_body(rel, toks, body_lo, body_hi, &mut raw);
            }
            None => raw.push(Diagnostic {
                file: rel.to_string(),
                line: marker_line,
                rule: Rule::Escape,
                message: "`zero-alloc-hot` marker is not followed by a fn with a body"
                    .into(),
            }),
        }
    }

    // --- R5 coverage: kernel hot paths must carry the tag ---------------
    // Packed/superpose/axpy kernels in rust/src/kernels/ run inside the
    // zero-alloc streaming window; an untagged one silently escapes both
    // the static R5 body scan and reviewer attention, so the tag itself
    // is mandatory there.
    if rel.starts_with("rust/src/kernels/") {
        for ti in 0..toks.len() {
            if !toks[ti].is_ident("fn") || in_test(ti) {
                continue;
            }
            let Some(name) = toks.get(ti + 1).and_then(|t| t.ident()) else {
                continue;
            };
            if !R5_KERNEL_NAMES.iter().any(|m| name.contains(m)) {
                continue;
            }
            let line = toks[ti].line;
            let tagged = comment_scope_satisfies(lines, line, |l| {
                directives.hot_markers.contains(&l)
            });
            if !tagged {
                raw.push(Diagnostic {
                    file: rel.to_string(),
                    line,
                    rule: Rule::R5,
                    message: format!(
                        "kernel `{name}` is on the packed/superposition hot \
                         path but is not tagged `// mpota-lint: \
                         zero-alloc-hot` — tag it so the static allocation \
                         scan covers its body"
                    ),
                });
            }
        }
    }

    // --- R6: unsafe-count ratchet --------------------------------------
    if unsafe_count > baseline_unsafe {
        let first_line =
            toks.iter().find(|t| t.is_ident("unsafe")).map(|t| t.line).unwrap_or(1);
        raw.push(Diagnostic {
            file: rel.to_string(),
            line: first_line,
            rule: Rule::R6,
            message: format!(
                "unsafe-count ratchet: {unsafe_count} unsafe sites exceed the \
                 committed baseline of {baseline_unsafe} \
                 (tools/lint/baseline.json) — shrink the unsafe surface or raise \
                 the baseline deliberately"
            ),
        });
    }

    // --- apply allow escapes -------------------------------------------
    let mut diagnostics: Vec<Diagnostic> = directives.errors;
    for d in raw {
        let suppressed = d.rule != Rule::R6
            && comment_scope_satisfies(lines, d.line, |l| {
                directives.allows.iter().any(|a| a.rule == d.rule && a.line == l)
            });
        if !suppressed {
            diagnostics.push(d);
        }
    }
    diagnostics.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));

    FileScan { diagnostics, allows: directives.allows, unsafe_count }
}

/// If `toks[ti]` starts a `<ident>::<one of tails>` path, return the tail.
fn path_call<'a>(toks: &[Tok], ti: usize, tails: &[&'a str]) -> Option<&'a str> {
    if !(toks.get(ti + 1)?.is_punct(':') && toks.get(ti + 2)?.is_punct(':')) {
        return None;
    }
    let m = toks.get(ti + 3)?.ident()?;
    tails.iter().find(|t| **t == m).copied()
}

/// Token spans (half-open index ranges) of `#[cfg(test)]` items.
fn test_token_spans(toks: &[Tok]) -> Vec<(usize, usize)> {
    let mut spans = Vec::new();
    let mut ti = 0usize;
    while ti < toks.len() {
        // match `# [ cfg ( ... test ... ) ]`
        if toks[ti].is_punct('#')
            && toks.get(ti + 1).map(|t| t.is_punct('[')).unwrap_or(false)
            && toks.get(ti + 2).map(|t| t.is_ident("cfg")).unwrap_or(false)
            && toks.get(ti + 3).map(|t| t.is_punct('(')).unwrap_or(false)
        {
            let mut j = ti + 4;
            let mut depth = 1usize;
            // `cfg(not(test))` must NOT count as a test region
            let negated = toks.get(j).map(|t| t.is_ident("not")).unwrap_or(false);
            let mut saw_test = false;
            while j < toks.len() && depth > 0 {
                if toks[j].is_punct('(') {
                    depth += 1;
                } else if toks[j].is_punct(')') {
                    depth -= 1;
                } else if toks[j].is_ident("test") {
                    saw_test = true;
                }
                j += 1;
            }
            let saw_test = saw_test && !negated;
            // expect the closing `]`
            if saw_test && toks.get(j).map(|t| t.is_punct(']')).unwrap_or(false) {
                if let Some(span) = item_body_span(toks, j + 1) {
                    spans.push(span);
                    ti = span.1;
                    continue;
                }
            }
        }
        ti += 1;
    }
    spans
}

/// From the first token after an attribute, find the annotated item's
/// body span: the half-open token range covering `{ ... }`.  Returns
/// `None` when a `;` terminates the item first (no body).
fn item_body_span(toks: &[Tok], mut ti: usize) -> Option<(usize, usize)> {
    let start = ti;
    // skip any further attributes
    while toks.get(ti)?.is_punct('#') {
        if !toks.get(ti + 1)?.is_punct('[') {
            break;
        }
        let mut depth = 1usize;
        ti += 2;
        while depth > 0 {
            let t = toks.get(ti)?;
            if t.is_punct('[') {
                depth += 1;
            } else if t.is_punct(']') {
                depth -= 1;
            }
            ti += 1;
        }
    }
    loop {
        let t = toks.get(ti)?;
        if t.is_punct(';') {
            return None;
        }
        if t.is_punct('{') {
            break;
        }
        ti += 1;
    }
    let body_lo = ti;
    let mut depth = 0usize;
    while let Some(t) = toks.get(ti) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some((start, ti + 1));
            }
        }
        ti += 1;
    }
    Some((body_lo, toks.len()))
}

/// Body token range of the fn a `zero-alloc-hot` marker (at `marker_line`)
/// tags: the next `fn` token after the marker, then its `{ ... }`.
fn hot_fn_body(toks: &[Tok], marker_line: usize) -> Option<(usize, usize)> {
    let fn_ti = toks
        .iter()
        .position(|t| t.line > marker_line && t.is_ident("fn"))?;
    let mut ti = fn_ti;
    loop {
        let t = toks.get(ti)?;
        if t.is_punct(';') {
            return None;
        }
        if t.is_punct('{') {
            break;
        }
        ti += 1;
    }
    let lo = ti;
    let mut depth = 0usize;
    while let Some(t) = toks.get(ti) {
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth -= 1;
            if depth == 0 {
                return Some((lo, ti + 1));
            }
        }
        ti += 1;
    }
    Some((lo, toks.len()))
}

fn scan_hot_body(
    rel: &str,
    toks: &[Tok],
    lo: usize,
    hi: usize,
    out: &mut Vec<Diagnostic>,
) {
    let mut push = |line: usize, what: String| {
        out.push(Diagnostic {
            file: rel.to_string(),
            line,
            rule: Rule::R5,
            message: format!(
                "allocating call `{what}` inside a `zero-alloc-hot` function — \
                 the steady-state round path must not touch the heap \
                 (rust/tests/alloc_counter.rs pins this dynamically)"
            ),
        });
    };
    for ti in lo..hi.min(toks.len()) {
        let tok = &toks[ti];
        if let Some(id) = tok.ident() {
            if R5_PATH_TYPES.contains(&id) {
                if let Some(m) = path_call(toks, ti, &R5_PATH_FNS) {
                    push(tok.line, format!("{id}::{m}"));
                    continue;
                }
            }
            if R5_MACROS.contains(&id)
                && toks.get(ti + 1).map(|t| t.is_punct('!')).unwrap_or(false)
            {
                push(tok.line, format!("{id}!"));
                continue;
            }
        }
        if tok.is_punct('.') {
            if let Some(m) = toks.get(ti + 1).and_then(|t| t.ident()) {
                if R5_METHODS.contains(&m) {
                    push(toks[ti + 1].line, format!(".{m}()"));
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Whole-tree run
// ---------------------------------------------------------------------------

/// Options for a whole-repo lint run.
pub struct Options {
    /// Repo root (the directory holding `rust/` and `tools/`).
    pub root: PathBuf,
    /// Where to write the machine-readable report; `None` means the
    /// default `<root>/LINT_report.json`.
    pub report: Option<PathBuf>,
    /// Unsafe-ratchet baseline; defaults to `tools/lint/baseline.json`.
    pub baseline: Option<PathBuf>,
    /// Rewrite the baseline from the current counts instead of checking.
    pub update_baseline: bool,
}

impl Options {
    pub fn at_root(root: PathBuf) -> Options {
        Options { root, report: None, baseline: None, update_baseline: false }
    }
}

/// Result of a whole-repo run (the report JSON is also returned so
/// callers can print or re-route it).
pub struct Outcome {
    pub files_scanned: usize,
    pub diagnostics: Vec<Diagnostic>,
    pub allows: Vec<Allow>,
    pub unsafe_counts: BTreeMap<String, usize>,
    pub baseline: BTreeMap<String, usize>,
    pub report_json: String,
}

impl Outcome {
    pub fn clean(&self) -> bool {
        self.diagnostics.is_empty()
    }
}

/// Lint the repo at `opts.root`: scan every `.rs` file under
/// [`SCAN_DIRS`], check R1–R6, write the report, and return the outcome.
pub fn run(opts: &Options) -> Result<Outcome, String> {
    let baseline_path = opts
        .baseline
        .clone()
        .unwrap_or_else(|| opts.root.join(BASELINE_REL));
    let baseline = if baseline_path.exists() {
        parse_baseline(
            &fs::read_to_string(&baseline_path)
                .map_err(|e| format!("reading {}: {e}", baseline_path.display()))?,
        )
        .map_err(|e| format!("parsing {}: {e}", baseline_path.display()))?
    } else {
        BTreeMap::new()
    };

    let mut files: Vec<PathBuf> = Vec::new();
    for dir in SCAN_DIRS {
        let d = opts.root.join(dir);
        if d.is_dir() {
            collect_rs(&d, &mut files)?;
        }
    }
    files.sort();

    let mut diagnostics: Vec<Diagnostic> = Vec::new();
    let mut allows: Vec<Allow> = Vec::new();
    let mut unsafe_counts: BTreeMap<String, usize> = BTreeMap::new();
    for path in &files {
        let rel = rel_path(&opts.root, path);
        let src = fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let base = if opts.update_baseline {
            usize::MAX // ratchet off while re-baselining
        } else {
            baseline.get(&rel).copied().unwrap_or(0)
        };
        let scan = scan_source(&rel, &src, base);
        diagnostics.extend(scan.diagnostics);
        allows.extend(scan.allows);
        if scan.unsafe_count > 0 {
            unsafe_counts.insert(rel, scan.unsafe_count);
        }
    }

    if opts.update_baseline {
        fs::write(&baseline_path, baseline_json(&unsafe_counts))
            .map_err(|e| format!("writing {}: {e}", baseline_path.display()))?;
    }

    let report_json = report_json(files.len(), &diagnostics, &allows, &unsafe_counts, {
        if opts.update_baseline { &unsafe_counts } else { &baseline }
    });
    if let Some(report_path) =
        opts.report.clone().or_else(|| Some(opts.root.join(REPORT_REL)))
    {
        fs::write(&report_path, &report_json)
            .map_err(|e| format!("writing {}: {e}", report_path.display()))?;
    }

    Ok(Outcome {
        files_scanned: files.len(),
        diagnostics,
        allows,
        unsafe_counts,
        baseline: if opts.update_baseline { BTreeMap::new() } else { baseline },
        report_json,
    })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    let entries =
        fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
    for entry in entries {
        let entry = entry.map_err(|e| format!("reading {}: {e}", dir.display()))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    let mut s = String::new();
    for comp in rel.components() {
        if !s.is_empty() {
            s.push('/');
        }
        s.push_str(&comp.as_os_str().to_string_lossy());
    }
    s
}

/// Locate the repo root by walking up from `start` until a directory
/// holding both `rust/src/lib.rs` and `tools/lint` is found.
pub fn discover_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.to_path_buf();
    loop {
        if dir.join("rust/src/lib.rs").is_file() && dir.join("tools/lint").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

// ---------------------------------------------------------------------------
// Hand-rolled JSON (emission + the flat string->number baseline parser)
// ---------------------------------------------------------------------------

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn baseline_json(counts: &BTreeMap<String, usize>) -> String {
    let mut s = String::from("{\n");
    let mut first = true;
    for (k, v) in counts {
        if !first {
            s.push_str(",\n");
        }
        first = false;
        s.push_str(&format!("  \"{}\": {v}", json_escape(k)));
    }
    s.push_str("\n}\n");
    s
}

/// Parse a flat `{ "path": count, ... }` object.
fn parse_baseline(src: &str) -> Result<BTreeMap<String, usize>, String> {
    let mut out = BTreeMap::new();
    let chars: Vec<char> = src.chars().collect();
    let mut i = 0usize;
    let n = chars.len();
    let skip_ws = |i: &mut usize| {
        while *i < n && chars[*i].is_whitespace() {
            *i += 1;
        }
    };
    skip_ws(&mut i);
    if i >= n || chars[i] != '{' {
        return Err("expected '{'".into());
    }
    i += 1;
    loop {
        skip_ws(&mut i);
        if i < n && chars[i] == '}' {
            return Ok(out);
        }
        if i >= n || chars[i] != '"' {
            return Err("expected '\"' starting a key".into());
        }
        i += 1;
        let mut key = String::new();
        while i < n && chars[i] != '"' {
            if chars[i] == '\\' && i + 1 < n {
                i += 1;
            }
            key.push(chars[i]);
            i += 1;
        }
        i += 1; // closing quote
        skip_ws(&mut i);
        if i >= n || chars[i] != ':' {
            return Err(format!("expected ':' after key '{key}'"));
        }
        i += 1;
        skip_ws(&mut i);
        let mut num = String::new();
        while i < n && chars[i].is_ascii_digit() {
            num.push(chars[i]);
            i += 1;
        }
        let v: usize =
            num.parse().map_err(|_| format!("bad count for key '{key}'"))?;
        out.insert(key, v);
        skip_ws(&mut i);
        if i < n && chars[i] == ',' {
            i += 1;
            continue;
        }
        skip_ws(&mut i);
        if i < n && chars[i] == '}' {
            return Ok(out);
        }
        return Err("expected ',' or '}'".into());
    }
}

fn report_json(
    files_scanned: usize,
    diagnostics: &[Diagnostic],
    allows: &[Allow],
    unsafe_counts: &BTreeMap<String, usize>,
    baseline: &BTreeMap<String, usize>,
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"tool\": \"mpota-lint\",\n");
    s.push_str(&format!("  \"files_scanned\": {files_scanned},\n"));
    s.push_str(&format!("  \"clean\": {},\n", diagnostics.is_empty()));

    // per-rule violation counts
    s.push_str("  \"rule_counts\": {");
    let all_rules =
        [Rule::R1, Rule::R2, Rule::R3, Rule::R4, Rule::R5, Rule::R6, Rule::Escape];
    for (i, r) in all_rules.iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        let c = diagnostics.iter().filter(|d| d.rule == *r).count();
        s.push_str(&format!("\"{}\": {c}", r.id()));
    }
    s.push_str("},\n");

    s.push_str("  \"violations\": [");
    for (i, d) in diagnostics.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \
             \"message\": \"{}\"}}",
            json_escape(&d.file),
            d.line,
            d.rule.id(),
            json_escape(&d.message)
        ));
    }
    if diagnostics.is_empty() {
        s.push_str("],\n");
    } else {
        s.push_str("\n  ],\n");
    }

    s.push_str("  \"allows\": [");
    for (i, a) in allows.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \"{}\", \
             \"reason\": \"{}\"}}",
            json_escape(&a.file),
            a.line,
            a.rule.id(),
            json_escape(&a.reason)
        ));
    }
    if allows.is_empty() {
        s.push_str("],\n");
    } else {
        s.push_str("\n  ],\n");
    }

    // unsafe ratchet state: current count vs committed baseline, per file
    s.push_str("  \"unsafe\": {\n");
    s.push_str(&format!(
        "    \"total\": {},\n",
        unsafe_counts.values().sum::<usize>()
    ));
    s.push_str("    \"files\": {");
    let mut first = true;
    for (k, v) in unsafe_counts {
        if !first {
            s.push(',');
        }
        first = false;
        let base = baseline.get(k).copied().unwrap_or(0);
        s.push_str(&format!(
            "\n      \"{}\": {{\"count\": {v}, \"baseline\": {base}}}",
            json_escape(k)
        ));
    }
    if unsafe_counts.is_empty() {
        s.push_str("}\n");
    } else {
        s.push_str("\n    }\n");
    }
    s.push_str("  }\n");
    s.push_str("}\n");
    s
}
