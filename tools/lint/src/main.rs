//! `mpota-lint` CLI: lint the repo, print `file:line` diagnostics, write
//! `LINT_report.json` at the repo root, exit nonzero on violations.
//!
//!     cargo run -p mpota-lint [-- --root <dir>] [--report <path>]
//!                             [--baseline <path>] [--update-baseline]

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut report: Option<PathBuf> = None;
    let mut baseline: Option<PathBuf> = None;
    let mut update_baseline = false;

    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => root = args.next().map(PathBuf::from),
            "--report" => report = args.next().map(PathBuf::from),
            "--baseline" => baseline = args.next().map(PathBuf::from),
            "--update-baseline" => update_baseline = true,
            "--help" | "-h" => {
                println!(
                    "mpota-lint: repo-invariant static analysis (rules R1-R6)\n\
                     \n\
                     USAGE: mpota-lint [--root <dir>] [--report <path>]\n\
                            [--baseline <path>] [--update-baseline]\n\
                     \n\
                     Walks rust/src, rust/benches, rust/tests, examples/ and\n\
                     writes LINT_report.json at the repo root.  Exits 1 on\n\
                     violations.  Escape hatch:\n\
                     // mpota-lint: allow(<rule>): <mandatory reason>"
                );
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("mpota-lint: unknown option '{other}' (see --help)");
                return ExitCode::from(2);
            }
        }
    }

    let root = match root.or_else(|| {
        std::env::current_dir()
            .ok()
            .and_then(|d| mpota_lint::discover_root(&d))
            .or_else(|| {
                // fall back to the manifest location (tools/lint -> repo root)
                let mf = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
                mf.parent().and_then(|p| p.parent()).map(|p| p.to_path_buf())
            })
    }) {
        Some(r) => r,
        None => {
            eprintln!("mpota-lint: could not locate the repo root (use --root)");
            return ExitCode::from(2);
        }
    };

    let opts = mpota_lint::Options { root, report, baseline, update_baseline };
    match mpota_lint::run(&opts) {
        Ok(outcome) => {
            for d in &outcome.diagnostics {
                println!("{}:{}: [{}] {}", d.file, d.line, d.rule.id(), d.message);
            }
            let unsafe_total: usize = outcome.unsafe_counts.values().sum();
            eprintln!(
                "mpota-lint: {} files, {} violation(s), {} allow(s), \
                 {} unsafe site(s) across {} file(s)",
                outcome.files_scanned,
                outcome.diagnostics.len(),
                outcome.allows.len(),
                unsafe_total,
                outcome.unsafe_counts.len(),
            );
            if outcome.clean() {
                ExitCode::SUCCESS
            } else {
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("mpota-lint: error: {e}");
            ExitCode::from(2)
        }
    }
}
