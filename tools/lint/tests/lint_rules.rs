//! Fixture corpus for the lint rules: each known-bad snippet triggers
//! exactly the one rule it targets, and each `allow(...)` escape
//! suppresses it.  Fixtures are data (read, lexed, scanned) — they are
//! never compiled, so they can reference types that don't exist.

use mpota_lint::{scan_source, Rule};
use std::path::Path;

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name);
    std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading fixture {}: {e}", p.display()))
}

/// Scan a fixture as if it lived on a rule-bearing path (`rust/src/...`),
/// returning just the fired rules in order.
fn rules_of(name: &str, baseline_unsafe: usize) -> Vec<Rule> {
    let rel = format!("rust/src/fixtures/{name}");
    let scan = scan_source(&rel, &fixture(name), baseline_unsafe);
    scan.diagnostics.iter().map(|d| d.rule).collect()
}

#[test]
fn r1_unsafe_without_safety_comment_fires_once() {
    assert_eq!(rules_of("r1_unsafe_no_comment.rs", 1), vec![Rule::R1]);
}

#[test]
fn r1_safety_comment_satisfies() {
    assert_eq!(rules_of("r1_safety_ok.rs", 1), Vec::<Rule>::new());
}

#[test]
fn r1_allow_escape_suppresses() {
    assert_eq!(rules_of("r1_allowed.rs", 1), Vec::<Rule>::new());
}

#[test]
fn r2_thread_scope_fires_once() {
    assert_eq!(rules_of("r2_thread_scope.rs", 0), vec![Rule::R2]);
}

#[test]
fn r2_allow_escape_suppresses() {
    assert_eq!(rules_of("r2_allowed.rs", 0), Vec::<Rule>::new());
}

#[test]
fn r2_is_exempt_inside_exec_pool() {
    // the same source scanned at the sanctioned spawner's path is clean
    let src = fixture("r2_thread_scope.rs");
    let scan = scan_source("rust/src/exec/pool.rs", &src, 0);
    assert!(scan.diagnostics.is_empty(), "{:?}", scan.diagnostics);
}

#[test]
fn r3_hashmap_fires_once() {
    assert_eq!(rules_of("r3_hashmap.rs", 0), vec![Rule::R3]);
}

#[test]
fn r3_trailing_allow_escape_suppresses() {
    assert_eq!(rules_of("r3_allowed.rs", 0), Vec::<Rule>::new());
}

#[test]
fn r3_cfg_test_mod_is_exempt() {
    assert_eq!(rules_of("r3_test_exempt.rs", 0), Vec::<Rule>::new());
}

#[test]
fn r4_seeding_fires_once() {
    assert_eq!(rules_of("r4_seed.rs", 0), vec![Rule::R4]);
}

#[test]
fn r4_allow_escape_suppresses() {
    assert_eq!(rules_of("r4_allowed.rs", 0), Vec::<Rule>::new());
}

#[test]
fn r4_is_exempt_in_rng_rs_tests_and_benches() {
    let src = fixture("r4_seed.rs");
    for rel in ["rust/src/rng.rs", "rust/tests/foo.rs", "rust/benches/foo.rs"] {
        let scan = scan_source(rel, &src, 0);
        assert!(scan.diagnostics.is_empty(), "{rel}: {:?}", scan.diagnostics);
    }
}

#[test]
fn r5_alloc_in_hot_fn_fires_once() {
    assert_eq!(rules_of("r5_alloc_in_hot.rs", 0), vec![Rule::R5]);
}

#[test]
fn r5_allow_escape_suppresses() {
    assert_eq!(rules_of("r5_allowed.rs", 0), Vec::<Rule>::new());
}

#[test]
fn r5_untagged_packed_kernel_fires_in_kernels_dir() {
    // a superpose/axpy/pack-named fn under rust/src/kernels/ must carry
    // the zero-alloc-hot tag
    let src = fixture("r5_untagged_kernel.rs");
    let scan = scan_source("rust/src/kernels/fixture.rs", &src, 0);
    let rules: Vec<Rule> = scan.diagnostics.iter().map(|d| d.rule).collect();
    assert_eq!(rules, vec![Rule::R5], "{:?}", scan.diagnostics);
}

#[test]
fn r5_tagged_packed_kernel_is_clean() {
    let src = fixture("r5_tagged_kernel.rs");
    let scan = scan_source("rust/src/kernels/fixture.rs", &src, 0);
    assert!(scan.diagnostics.is_empty(), "{:?}", scan.diagnostics);
}

#[test]
fn r5_kernel_tag_requirement_is_scoped_to_the_kernels_dir() {
    // the same untagged source elsewhere (and in test mods) is clean
    let src = fixture("r5_untagged_kernel.rs");
    let scan = scan_source("rust/src/fixtures/r5_untagged_kernel.rs", &src, 0);
    assert!(scan.diagnostics.is_empty(), "{:?}", scan.diagnostics);
    let in_test = format!("#[cfg(test)]\nmod tests {{\n{src}\n}}\n");
    let scan = scan_source("rust/src/kernels/fixture.rs", &in_test, 0);
    assert!(scan.diagnostics.is_empty(), "{:?}", scan.diagnostics);
}

#[test]
fn r6_ratchet_fires_when_count_exceeds_baseline() {
    assert_eq!(rules_of("r6_ratchet.rs", 1), vec![Rule::R6]);
    assert_eq!(rules_of("r6_ratchet.rs", 2), Vec::<Rule>::new());
}

#[test]
fn r6_has_no_inline_escape() {
    // an allow(R6) is rejected as a malformed escape, and the ratchet
    // still fires
    let src = "// mpota-lint: allow(R6): trying to dodge the ratchet\n\
               pub fn f(v: &[u8]) -> u8 {\n\
                   let p = v.as_ptr();\n\
                   // SAFETY: fixture; callers check !v.is_empty().\n\
                   unsafe { *p }\n\
               }\n";
    let scan = scan_source("rust/src/fixtures/r6_allow.rs", src, 0);
    let rules: Vec<Rule> = scan.diagnostics.iter().map(|d| d.rule).collect();
    assert!(rules.contains(&Rule::Escape), "{rules:?}");
    assert!(rules.contains(&Rule::R6), "{rules:?}");
}

#[test]
fn allow_without_reason_is_a_violation_and_does_not_suppress() {
    let rules = rules_of("allow_missing_reason.rs", 0);
    assert!(rules.contains(&Rule::Escape), "{rules:?}");
    assert!(rules.contains(&Rule::R2), "{rules:?}");
    assert_eq!(rules.len(), 2, "{rules:?}");
}

#[test]
fn allow_unknown_rule_is_a_violation_and_does_not_suppress() {
    let rules = rules_of("allow_unknown_rule.rs", 0);
    assert!(rules.contains(&Rule::Escape), "{rules:?}");
    assert!(rules.contains(&Rule::R2), "{rules:?}");
    assert_eq!(rules.len(), 2, "{rules:?}");
}

#[test]
fn keywords_inside_strings_and_comments_do_not_fire() {
    let src = r#"
pub fn doc() -> &'static str {
    // std::thread::spawn in a comment is not code
    "std::thread::spawn(HashMap::new(), Rng::seed_from(0), unsafe)"
}
"#;
    let scan = scan_source("rust/src/fixtures/strings.rs", src, 0);
    assert!(scan.diagnostics.is_empty(), "{:?}", scan.diagnostics);
    assert_eq!(scan.unsafe_count, 0);
}

#[test]
fn diagnostics_carry_file_and_line() {
    let scan =
        scan_source("rust/src/fixtures/r4_seed.rs", &fixture("r4_seed.rs"), 0);
    assert_eq!(scan.diagnostics.len(), 1);
    let d = &scan.diagnostics[0];
    assert_eq!(d.file, "rust/src/fixtures/r4_seed.rs");
    assert_eq!(d.line, 5, "seed_from sits on line 5 of the fixture");
}
