// Fixture: a raw scoped spawn outside exec/pool.rs.
// Expected: exactly one R2 diagnostic (`s.spawn` is a method call on the
// scope handle, not `std::thread::spawn`, so only `thread::scope` fires).

pub fn fan_out(xs: &mut [u32]) {
    std::thread::scope(|s| {
        for x in xs.iter_mut() {
            s.spawn(move || *x += 1);
        }
    });
}
