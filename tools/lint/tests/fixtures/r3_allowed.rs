// Fixture: the same HashMap, escaped with a reasoned allow (trailing
// comment form). Expected: clean.

pub fn tally(keys: &[u32]) -> usize {
    let mut m = std::collections::HashMap::new(); // mpota-lint: allow(R3): fixture; len() only, never iterated
    for k in keys {
        *m.entry(*k).or_insert(0usize) += 1;
    }
    m.len()
}
