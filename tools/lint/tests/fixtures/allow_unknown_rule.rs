// Fixture: allow(...) naming a rule that does not exist. Expected: one
// `escape` diagnostic plus the original R2 (nothing was suppressed).

pub fn fan_out() {
    // mpota-lint: allow(R9): there is no rule nine
    std::thread::scope(|_s| {});
}
