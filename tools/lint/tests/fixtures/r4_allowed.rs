// Fixture: the same seeding, escaped with a reasoned allow.
// Expected: clean.

pub fn fresh() -> Rng {
    // mpota-lint: allow(R4): fixture; the one sanctioned root seed in this snippet
    Rng::seed_from(0xC0FFEE)
}
