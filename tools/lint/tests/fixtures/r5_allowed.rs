// Fixture: the same allocating call, escaped with a reasoned allow.
// Expected: clean.

// mpota-lint: zero-alloc-hot
pub fn axpy(dst: &mut [f32], src: &[f32]) {
    // mpota-lint: allow(R5): fixture; scratch copy happens once at warmup, not per round
    let tmp = src.to_vec();
    for (d, s) in dst.iter_mut().zip(tmp.iter()) {
        *d += *s;
    }
}
