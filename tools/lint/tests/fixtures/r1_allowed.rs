// Fixture: the allow(...) escape suppresses R1. Expected: clean.

pub fn read_first(v: &[u8]) -> u8 {
    let p = v.as_ptr();
    // mpota-lint: allow(R1): fixture exercising the escape hatch syntax
    unsafe { *p }
}
