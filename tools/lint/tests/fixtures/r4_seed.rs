// Fixture: RNG seeding outside rng.rs.
// Expected: exactly one R4 diagnostic.

pub fn fresh() -> Rng {
    Rng::seed_from(0xC0FFEE)
}
