// Fixture: an `unsafe` block with no SAFETY comment anywhere near it.
// Expected: exactly one R1 diagnostic (with baseline_unsafe = 1).

pub fn read_first(v: &[u8]) -> u8 {
    let p = v.as_ptr();
    unsafe { *p }
}
