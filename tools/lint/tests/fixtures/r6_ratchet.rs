// Fixture: two properly-annotated unsafe sites. With baseline_unsafe = 1
// the ratchet (R6) fires once; with baseline_unsafe = 2 the file is clean.

pub fn first(v: &[u8]) -> u8 {
    let p = v.as_ptr();
    // SAFETY: callers check `!v.is_empty()`; `p` targets the live v[0].
    unsafe { *p }
}

pub fn second(v: &[u8]) -> u8 {
    let p = v.as_ptr();
    // SAFETY: callers check `v.len() > 1`; `p.add(1)` targets the live v[1].
    unsafe { *p.add(1) }
}
