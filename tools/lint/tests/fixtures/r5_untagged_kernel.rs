//! Fixture: a packed-superposition kernel WITHOUT the zero-alloc-hot tag.
//! Scanned at a `rust/src/kernels/` path this must fire R5 (coverage);
//! scanned anywhere else it is clean — the tag requirement is scoped to
//! the kernel directory.

/// Decode-and-accumulate over a packed row (fixture body; never compiled).
pub fn superpose_packed(plane: &PackedPlane, y: &mut [f32]) {
    for (i, d) in y.iter_mut().enumerate() {
        *d += plane.get(i);
    }
}
