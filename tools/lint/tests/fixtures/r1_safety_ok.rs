// Fixture: the same unsafe block, properly annotated. Expected: clean.

pub fn read_first(v: &[u8]) -> u8 {
    let p = v.as_ptr();
    // SAFETY: every caller checks `!v.is_empty()`, so `p` points at the
    // live first element of `v` for the duration of the read.
    unsafe { *p }
}
