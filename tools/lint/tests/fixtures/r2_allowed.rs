// Fixture: the same scoped spawn, escaped with a reasoned allow.
// Expected: clean.

pub fn fan_out(xs: &mut [u32]) {
    // mpota-lint: allow(R2): fixture; baseline comparison against raw scoped spawn
    std::thread::scope(|s| {
        for x in xs.iter_mut() {
            s.spawn(move || *x += 1);
        }
    });
}
