// Fixture: a HashMap on a non-test path.
// Expected: exactly one R3 diagnostic (one `HashMap` ident).

pub fn tally(keys: &[u32]) -> usize {
    let mut m = std::collections::HashMap::new();
    for k in keys {
        *m.entry(*k).or_insert(0usize) += 1;
    }
    m.len()
}
