// Fixture: an allocating call inside a zero-alloc-hot function.
// Expected: exactly one R5 diagnostic (the `.to_vec()`).

// mpota-lint: zero-alloc-hot
pub fn axpy(dst: &mut [f32], src: &[f32]) {
    let tmp = src.to_vec();
    for (d, s) in dst.iter_mut().zip(tmp.iter()) {
        *d += *s;
    }
}
