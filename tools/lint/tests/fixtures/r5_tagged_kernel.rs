//! Fixture: the same packed kernel WITH the zero-alloc-hot tag — clean at
//! any path, and its body is covered by the R5 allocation scan.

/// Decode-and-accumulate over a packed row (fixture body; never compiled).
// mpota-lint: zero-alloc-hot
pub fn superpose_packed(plane: &PackedPlane, y: &mut [f32]) {
    for (i, d) in y.iter_mut().enumerate() {
        *d += plane.get(i);
    }
}
