// Fixture: an allow(...) with no reason is itself a violation AND does
// not suppress the rule it names. Expected: one `escape` diagnostic plus
// the original R2.

pub fn fan_out() {
    // mpota-lint: allow(R2)
    std::thread::scope(|_s| {});
}
