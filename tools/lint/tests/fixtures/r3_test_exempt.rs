// Fixture: HashMap inside a #[cfg(test)] mod is exempt from R3.
// Expected: clean.

pub fn noop() {}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn scratch_map_is_fine_here() {
        let mut m: HashMap<u32, u32> = HashMap::new();
        m.insert(1, 2);
        assert_eq!(m.len(), 1);
    }
}
