//! Integration test: the real source tree lints clean.  This is the
//! in-`cargo test` mirror of the CI `cargo run -p mpota-lint` gate, so a
//! violation fails the suite with the exact `file:line` diagnostics.

use std::path::Path;

#[test]
fn repo_lints_clean_against_committed_baseline() {
    let root = mpota_lint::discover_root(Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("repo root (rust/src/lib.rs + tools/lint) not found");
    // write the report to a scratch path: the committed LINT_report.json
    // is refreshed by the CI lint step, not by test runs
    let report = std::env::temp_dir().join("mpota_lint_repo_clean_report.json");
    let opts = mpota_lint::Options {
        root,
        report: Some(report),
        baseline: None,
        update_baseline: false,
    };
    let outcome = mpota_lint::run(&opts).expect("lint run failed");
    assert!(
        outcome.files_scanned >= 30,
        "suspiciously few files scanned: {}",
        outcome.files_scanned
    );
    if !outcome.clean() {
        let mut msg = String::new();
        for d in &outcome.diagnostics {
            msg.push_str(&format!(
                "{}:{}: [{}] {}\n",
                d.file,
                d.line,
                d.rule.id(),
                d.message
            ));
        }
        panic!("repo is not lint-clean:\n{msg}");
    }
    // every allow escape in the tree carries a reason (the parser rejects
    // reasonless allows, but pin it explicitly as an acceptance criterion)
    for a in &outcome.allows {
        assert!(
            !a.reason.trim().is_empty(),
            "{}:{} allow({}) without a reason",
            a.file,
            a.line,
            a.rule.id()
        );
    }
}
