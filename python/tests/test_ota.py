"""Pallas OTA superposition kernel vs the jnp oracle + linearity laws."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.ota import ota_superpose_pallas


def _inputs(k, n, seed, noise=True):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    hre = jnp.asarray((1.0 + 0.05 * rng.standard_normal(k)).astype(np.float32))
    him = jnp.asarray((0.05 * rng.standard_normal(k)).astype(np.float32))
    scale = 0.1 if noise else 0.0
    nre = jnp.asarray((scale * rng.standard_normal(n)).astype(np.float32))
    nim = jnp.asarray((scale * rng.standard_normal(n)).astype(np.float32))
    return x, hre, him, nre, nim


@pytest.mark.parametrize("n", [128, 4096, 5000, 16384])
def test_matches_oracle(n):
    args = _inputs(15, n, seed=n)
    got_re, got_im = ota_superpose_pallas(*args)
    want_re, want_im = ref.ota_superpose(*args)
    np.testing.assert_allclose(np.asarray(got_re), np.asarray(want_re), atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_im), np.asarray(want_im), atol=1e-4)


def test_perfect_csi_no_noise_is_plain_sum():
    k, n = 15, 1000
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    ones = jnp.ones(k, jnp.float32)
    zeros_k = jnp.zeros(k, jnp.float32)
    zeros_n = jnp.zeros(n, jnp.float32)
    re, im = ota_superpose_pallas(x, ones, zeros_k, zeros_n, zeros_n)
    np.testing.assert_allclose(np.asarray(re), np.asarray(x.sum(0)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(im), 0.0, atol=1e-6)


def test_linearity_in_payloads():
    # superpose(x + y) == superpose(x) + superpose(y) - noise (noise counted
    # once); verify with zero noise.
    k, n = 7, 513
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    hre = jnp.asarray(rng.standard_normal(k).astype(np.float32))
    him = jnp.asarray(rng.standard_normal(k).astype(np.float32))
    z = jnp.zeros(n, jnp.float32)
    rx, ix = ota_superpose_pallas(x, hre, him, z, z)
    ry, iy = ota_superpose_pallas(y, hre, him, z, z)
    rxy, ixy = ota_superpose_pallas(x + y, hre, him, z, z)
    np.testing.assert_allclose(np.asarray(rxy), np.asarray(rx + ry), atol=1e-3)
    np.testing.assert_allclose(np.asarray(ixy), np.asarray(ix + iy), atol=1e-3)


def test_silenced_clients_zero_gain_contribute_nothing():
    k, n = 4, 256
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    hre = jnp.asarray([1.0, 0.0, 1.0, 0.0], jnp.float32)  # clients 1,3 silent
    him = jnp.zeros(k, jnp.float32)
    z = jnp.zeros(n, jnp.float32)
    re, _ = ota_superpose_pallas(x, hre, him, z, z)
    want = np.asarray(x[0] + x[2])
    np.testing.assert_allclose(np.asarray(re), want, atol=1e-4)


@given(
    k=st.integers(min_value=1, max_value=20),
    n=st.integers(min_value=1, max_value=3000),
)
def test_shapes_hypothesis(k, n):
    args = _inputs(k, n, seed=k * 7919 + n)
    got_re, got_im = ota_superpose_pallas(*args)
    assert got_re.shape == (n,)
    assert got_im.shape == (n,)
    want_re, want_im = ref.ota_superpose(*args)
    np.testing.assert_allclose(np.asarray(got_re), np.asarray(want_re), atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_im), np.asarray(want_im), atol=2e-4)
