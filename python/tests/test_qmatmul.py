"""Pallas tiled quantized matmul vs its oracles.

Comparison notes: under jit, XLA's fusion (reciprocal multiplies, FMA) can
flip `floor` on values that land within an ulp of a level boundary, so
fixed-point comparisons use a tolerance scaled to the quantization step
times the contraction depth; float-truncation and identity paths are exact
up to accumulation order.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.qmatmul import qmatmul_pallas

RNG = np.random.default_rng(7)


def _mats(m, k, n, scale=1.0, seed=None):
    rng = np.random.default_rng(seed if seed is not None else RNG.integers(1 << 31))
    a = (rng.standard_normal((m, k)) * scale).astype(np.float32)
    b = (rng.standard_normal((k, n)) * scale).astype(np.float32)
    return jnp.asarray(a), jnp.asarray(b)


def _tol(a, b, bits, bm=32, bk=128, bn=128):
    """Error bound: a few boundary flips x step x |counterpart| x depth."""
    if bits >= 32:
        return 1e-4 * max(1.0, float(jnp.abs(a).max() * jnp.abs(b).max()))
    if bits in ref.FLOAT_TRUNC_LEVELS:
        rel = 2.0 ** -(bits - 9)
        k = a.shape[1]
        return 4.0 * rel * float(jnp.abs(a).max() * jnp.abs(b).max()) * k**0.5 + 1e-4
    step_a = float((a.max() - a.min())) / (2**bits - 1)
    step_b = float((b.max() - b.min())) / (2**bits - 1)
    # a handful of one-level flips along the contraction
    return 8.0 * (
        step_a * float(jnp.abs(b).max()) + step_b * float(jnp.abs(a).max())
    ) + 1e-4


@pytest.mark.parametrize("bits", [32, 16, 8, 4])
def test_matches_tiled_oracle_aligned(bits):
    a, b = _mats(32, 128, 128, seed=1)
    got = np.asarray(qmatmul_pallas(a, b, bits))
    want = np.asarray(ref.qmatmul_tiled(a, b, bits, 32, 128, 128))
    assert np.abs(got - want).max() < _tol(a, b, bits)


@pytest.mark.parametrize("bits", [32, 16, 8, 4])
@pytest.mark.parametrize("shape", [(5, 7, 3), (33, 130, 65), (1, 1, 1), (64, 256, 64)])
def test_unaligned_shapes(bits, shape):
    m, k, n = shape
    a, b = _mats(m, k, n, seed=m * 1000 + k + n)
    got = np.asarray(qmatmul_pallas(a, b, bits))
    assert got.shape == (m, n)
    if bits == 32:
        want = np.asarray(jnp.matmul(a, b))
        assert np.abs(got - want).max() < _tol(a, b, 32)
    else:
        # padded-tile-exact oracle: pad like the kernel, compare, crop
        bm_, bk_, bn_ = min(32, m), min(128, k), min(128, n)
        mp = -(-m // bm_) * bm_
        kp = -(-k // bk_) * bk_
        np_ = -(-n // bn_) * bn_
        ap = jnp.pad(a, ((0, mp - m), (0, kp - k)))
        bp = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
        want = np.asarray(ref.qmatmul_tiled(ap, bp, bits, bm_, bk_, bn_))[:m, :n]
        assert np.abs(got - want).max() < _tol(a, b, bits)


def test_q32_equals_plain_matmul():
    a, b = _mats(32, 128, 64, seed=3)
    got = np.asarray(qmatmul_pallas(a, b, 32))
    want = np.asarray(jnp.matmul(a, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_zero_inputs_give_zero():
    a = jnp.zeros((32, 128), jnp.float32)
    b = jnp.zeros((128, 128), jnp.float32)
    for bits in [32, 8, 4]:
        assert np.all(np.asarray(qmatmul_pallas(a, b, bits)) == 0.0)


def test_contraction_mismatch_raises():
    a = jnp.zeros((4, 5), jnp.float32)
    b = jnp.zeros((6, 7), jnp.float32)
    with pytest.raises(ValueError):
        qmatmul_pallas(a, b, 8)


def test_quantization_error_shrinks_with_bits():
    a, b = _mats(32, 128, 64, scale=1.0, seed=9)
    exact = np.asarray(jnp.matmul(a, b))
    errs = []
    for bits in [4, 8, 16]:
        got = np.asarray(qmatmul_pallas(a, b, bits))
        errs.append(np.abs(got - exact).mean())
    assert errs[0] > errs[1] > errs[2], errs


@given(
    m=st.integers(min_value=1, max_value=40),
    k=st.integers(min_value=1, max_value=150),
    n=st.integers(min_value=1, max_value=150),
    bits=st.sampled_from([32, 16, 8, 4]),
)
def test_shapes_hypothesis(m, k, n, bits):
    a, b = _mats(m, k, n, seed=m * 10007 + k * 101 + n)
    got = np.asarray(qmatmul_pallas(a, b, bits))
    assert got.shape == (m, n)
    assert np.all(np.isfinite(got))
    # loose correctness: quantized result tracks the exact product
    exact = np.asarray(jnp.matmul(a, b))
    assert np.abs(got - exact).max() <= _tol(a, b, bits) + np.abs(exact).max() * 0.6
