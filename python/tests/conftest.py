"""Shared pytest configuration for the compile-path test suite."""

import os
import sys

# Allow `pytest python/tests` from the repo root as well as `cd python &&
# pytest tests/`: make the `compile` package importable either way.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PYROOT = os.path.dirname(_HERE)
if _PYROOT not in sys.path:
    sys.path.insert(0, _PYROOT)

from hypothesis import settings

# Interpret-mode Pallas on one CPU core is slow; never let hypothesis's
# default 200ms deadline flake a shrink run.
settings.register_profile("mpota", deadline=None, max_examples=25)
settings.load_profile("mpota")
