"""Pallas quantization kernels vs pure-jnp oracles (Algorithm 2).

The fixed-point kernel must match `ref.fixed_point_fake_quant` EXACTLY
(atol=0): both compute scale/zero-point with the same jnp reductions and
the kernel body replays the same floor/clip ops.  The float-truncation
kernel is pure bit masking, so it is exact by construction.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.quantize import (
    LANES,
    fake_quant_pallas,
    fixed_point_fake_quant_pallas,
    float_truncate_pallas,
)

RNG = np.random.default_rng(2024)


def _rand(shape, scale=1.0):
    return (RNG.standard_normal(shape) * scale).astype(np.float32)


# ----------------------------------------------------------------- oracles


@pytest.mark.parametrize("bits", ref.FIXED_POINT_LEVELS)
def test_fixed_point_levels_on_grid(bits):
    """De-quantized outputs sit on exactly <= 2^b distinct levels."""
    w = jnp.asarray(_rand(257))
    out = np.asarray(ref.fixed_point_fake_quant(w, bits))
    assert len(np.unique(out)) <= 2**bits


@pytest.mark.parametrize("bits", ref.FIXED_POINT_LEVELS)
def test_fixed_point_range_preserved(bits):
    """Outputs stay within [w_min - scale, w_max + scale]."""
    w = _rand(513, scale=3.0)
    out = np.asarray(ref.fixed_point_fake_quant(jnp.asarray(w), bits))
    scale = (w.max() - w.min()) / (2**bits - 1)
    assert out.min() >= w.min() - scale - 1e-6
    assert out.max() <= w.max() + scale + 1e-6


@pytest.mark.parametrize("bits", ref.FIXED_POINT_LEVELS)
def test_fixed_point_error_bounded_by_step(bits):
    w = _rand(1024)
    out = np.asarray(ref.fixed_point_fake_quant(jnp.asarray(w), bits))
    scale = (w.max() - w.min()) / (2**bits - 1)
    # floor-quantization error is < 1 step (plus float slack)
    assert np.abs(out - w).max() <= scale * (1 + 1e-3)


def test_fixed_point_constant_tensor_survives():
    """w_max == w_min must not divide by zero; values stay near constant."""
    w = jnp.full((64,), 0.7311, jnp.float32)
    out = np.asarray(ref.fixed_point_fake_quant(w, 8))
    assert np.all(np.isfinite(out))
    assert np.abs(out - 0.7311).max() < 1e-3


def test_fixed_point_zeros():
    out = np.asarray(ref.fixed_point_fake_quant(jnp.zeros(32), 4))
    assert np.all(out == 0.0)


@pytest.mark.parametrize("bits", ref.FLOAT_TRUNC_LEVELS)
def test_float_truncate_magnitude_never_grows(bits):
    """Mantissa truncation moves values toward zero, never away."""
    w = _rand(512, scale=100.0)
    out = np.asarray(ref.float_truncate(jnp.asarray(w), bits))
    assert np.all(np.abs(out) <= np.abs(w))
    assert np.all((np.sign(out) == np.sign(w)) | (out == 0))


@pytest.mark.parametrize("bits", ref.FLOAT_TRUNC_LEVELS)
def test_float_truncate_relative_error(bits):
    """Relative error < 2^-(mantissa bits kept)."""
    w = _rand(512, scale=5.0)
    w = np.where(np.abs(w) < 1e-3, 1.0, w).astype(np.float32)
    out = np.asarray(ref.float_truncate(jnp.asarray(w), bits))
    rel = np.abs(out - w) / np.abs(w)
    assert rel.max() < 2.0 ** -(bits - 9)


def test_float_truncate_idempotent():
    w = jnp.asarray(_rand(256))
    once = ref.float_truncate(w, 16)
    twice = ref.float_truncate(once, 16)
    assert np.array_equal(np.asarray(once), np.asarray(twice))


def test_fixed_point_monotone():
    """Quantization preserves order (non-strict)."""
    w = np.sort(_rand(512, scale=2.0))
    out = np.asarray(ref.fixed_point_fake_quant(jnp.asarray(w), 6))
    assert np.all(np.diff(out) >= 0)


def test_q32_identity():
    w = jnp.asarray(_rand(100))
    assert np.array_equal(np.asarray(ref.fake_quant(w, 32)), np.asarray(w))
    assert np.array_equal(np.asarray(fake_quant_pallas(w, 32)), np.asarray(w))


def test_unsupported_level_raises():
    with pytest.raises(ValueError):
        ref.fake_quant(jnp.zeros(4), 5)
    with pytest.raises(ValueError):
        fake_quant_pallas(jnp.zeros(4), 7)
    with pytest.raises(ValueError):
        ref.float_truncate(jnp.zeros(4), 8)


# ------------------------------------------------- pallas kernel vs oracle


@pytest.mark.parametrize("bits", ref.FIXED_POINT_LEVELS)
@pytest.mark.parametrize(
    "shape", [(7,), (128,), (129,), (4, 33), (3, 3, 3, 16), (2000,)]
)
def test_pallas_fixed_matches_ref(bits, shape):
    w = jnp.asarray(_rand(shape, scale=2.0))
    got = np.asarray(fixed_point_fake_quant_pallas(w, bits))
    want = np.asarray(ref.fixed_point_fake_quant(w, bits))
    assert got.shape == want.shape
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("bits", ref.FLOAT_TRUNC_LEVELS)
@pytest.mark.parametrize("shape", [(5,), (200,), (16, 128), (1, 1, 130)])
def test_pallas_trunc_matches_ref(bits, shape):
    w = jnp.asarray(_rand(shape, scale=50.0))
    got = np.asarray(float_truncate_pallas(w, bits))
    want = np.asarray(ref.float_truncate(w, bits))
    np.testing.assert_array_equal(got, want)


@given(
    n=st.integers(min_value=1, max_value=5000),
    bits=st.sampled_from(ref.SUPPORTED_LEVELS),
    scale=st.floats(min_value=1e-3, max_value=1e3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pallas_matches_ref_hypothesis(n, bits, scale, seed):
    """Hypothesis sweep over length / precision / magnitude / seed."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.standard_normal(n) * scale).astype(np.float32))
    got = np.asarray(fake_quant_pallas(w, bits))
    want = np.asarray(ref.fake_quant(w, bits))
    np.testing.assert_array_equal(got, want)


@given(
    rows=st.integers(min_value=1, max_value=40),
    cols=st.integers(min_value=1, max_value=300),
    bits=st.sampled_from(ref.FIXED_POINT_LEVELS),
)
def test_pallas_2d_shapes_hypothesis(rows, cols, bits):
    rng = np.random.default_rng(rows * 1000 + cols)
    w = jnp.asarray(rng.standard_normal((rows, cols)).astype(np.float32))
    got = np.asarray(fake_quant_pallas(w, bits))
    want = np.asarray(ref.fake_quant(w, bits))
    assert got.shape == (rows, cols)
    np.testing.assert_array_equal(got, want)


def test_pallas_padding_does_not_leak():
    """Values past the tensor end (lane padding) must never affect output."""
    w = _rand(LANES + 1, scale=2.0)
    full = np.asarray(fake_quant_pallas(jnp.asarray(w), 6))
    # same data with a different total length => same prefix result
    w2 = np.concatenate([w, np.full(37, 77.7, np.float32)])
    out2 = np.asarray(fake_quant_pallas(jnp.asarray(w2[: LANES + 1]), 6))
    np.testing.assert_array_equal(full, out2)
