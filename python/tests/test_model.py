"""L2 model tests: parameter bookkeeping, forward shapes, QAT training
dynamics, and the artifact entry-point contracts the rust runtime relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref


def _batch(n=M.TRAIN_BATCH, seed=0):
    rng = np.random.default_rng(seed)
    imgs = jnp.asarray(rng.uniform(0, 1, (n, *M.IMAGE_SHAPE)).astype(np.float32))
    labels = jnp.asarray(rng.integers(0, M.NUM_CLASSES, n).astype(np.int32))
    return imgs, labels


# ------------------------------------------------------------ bookkeeping


@pytest.mark.parametrize("name", list(M.VARIANTS))
def test_param_spec_matches_count(name):
    cfg = M.VARIANTS[name]
    spec = M.param_spec(cfg)
    total = sum(int(np.prod(s)) for _, s in spec)
    assert total == M.param_count(cfg)
    theta = M.init_flat_params(cfg)
    assert theta.shape == (total,)


def test_unflatten_flatten_roundtrip():
    cfg = M.VARIANTS["tiny"]
    theta = M.init_flat_params(cfg, seed=3)
    params = M._unflatten(cfg, theta)
    back = M._flatten(cfg, params)
    assert np.array_equal(np.asarray(theta), np.asarray(back))


def test_classifier_head_zero_init():
    cfg = M.VARIANTS["tiny"]
    theta = M.init_flat_params(cfg)
    params = M._unflatten(cfg, theta)
    assert np.all(np.asarray(params["d1_w"]) == 0.0)
    assert np.all(np.asarray(params["d1_b"]) == 0.0)


def test_variants_are_ordered_by_size():
    sizes = {n: M.param_count(c) for n, c in M.VARIANTS.items()}
    assert sizes["tiny"] < sizes["small"] < sizes["base"] < sizes["wide"]


# ----------------------------------------------------------------- forward


def test_forward_shapes_and_mask():
    cfg = M.VARIANTS["tiny"]
    theta = M.init_flat_params(cfg)
    imgs, _ = _batch(8)
    logits = M.forward(cfg, 32, theta, imgs)
    assert logits.shape == (8, M.PADDED_CLASSES)
    # padding classes are masked to huge negatives
    pad = np.asarray(logits[:, M.NUM_CLASSES:])
    assert np.all(pad < -1e8)


def test_initial_loss_is_uniform_over_real_classes():
    cfg = M.VARIANTS["tiny"]
    theta = M.init_flat_params(cfg)
    imgs, labels = _batch()
    loss, _ = M._loss_and_metrics(cfg, 32, theta, imgs, labels)
    assert abs(float(loss) - np.log(M.NUM_CLASSES)) < 1e-3


@pytest.mark.parametrize("bits", [32, 16, 8, 4])
def test_forward_finite_at_all_precisions(bits):
    cfg = M.VARIANTS["tiny"]
    theta = M.init_flat_params(cfg, seed=1)
    imgs, _ = _batch(4, seed=2)
    logits = M.forward(cfg, bits, theta, imgs[:4])
    assert np.all(np.isfinite(np.asarray(logits[:, : M.NUM_CLASSES])))


# ---------------------------------------------------------------- training


def test_train_step_contract_and_learning():
    cfg = M.VARIANTS["tiny"]
    step = jax.jit(M.make_train_step(cfg, 32))
    theta = M.init_flat_params(cfg)
    imgs, labels = _batch(seed=5)
    lr = jnp.asarray([0.2], jnp.float32)
    losses = []
    for _ in range(12):
        theta, metrics = step(theta, imgs, labels, lr)
        losses.append(float(metrics[0]))
    assert metrics.shape == (2,)
    # overfits a single batch: loss must drop monotonically-ish and clearly
    assert losses[-1] < losses[0] - 0.5, losses
    # correct-count within range
    assert 0.0 <= float(metrics[1]) <= M.TRAIN_BATCH


def test_train_step_q8_keeps_params_on_grid():
    cfg = M.VARIANTS["tiny"]
    step = jax.jit(M.make_train_step(cfg, 8))
    theta = M.init_flat_params(cfg, seed=4)
    imgs, labels = _batch(seed=6)
    new_theta, _ = step(theta, imgs, labels, jnp.asarray([0.05], jnp.float32))
    # The returned params are on an 8-bit grid.  Re-quantization re-derives
    # scale/zero-point from the (already clipped) tensor, so it is not a
    # bitwise no-op — but it can move each value by at most one step of the
    # new grid.
    again = np.asarray(ref.fake_quant(new_theta, 8))
    new_theta = np.asarray(new_theta)
    step_size = (new_theta.max() - new_theta.min()) / 255.0
    assert np.abs(again - new_theta).max() <= step_size * 1.01
    # and the tensor really is coarse: at most 256 distinct values
    assert len(np.unique(new_theta)) <= 256


def test_low_precision_trains_slower():
    """The paper's core observation: 4-bit training stalls vs f32."""
    cfg = M.VARIANTS["tiny"]
    imgs, labels = _batch(seed=7)
    lr = jnp.asarray([0.05], jnp.float32)

    def run(bits, steps=6):
        step = jax.jit(M.make_train_step(cfg, bits))
        theta = M.init_flat_params(cfg)
        first = last = None
        for _ in range(steps):
            theta, m = step(theta, imgs, labels, lr)
            if first is None:
                first = float(m[0])
            last = float(m[0])
        return first - last  # loss improvement

    assert run(32) > run(4) - 1e-3


def test_eval_step_weight_mask():
    cfg = M.VARIANTS["tiny"]
    ev = jax.jit(M.make_eval_step(cfg))
    theta = M.init_flat_params(cfg, seed=8)
    imgs, labels = _batch(M.EVAL_BATCH, seed=9)
    w_full = jnp.ones(M.EVAL_BATCH, jnp.float32)
    w_half = jnp.asarray(
        [1.0] * (M.EVAL_BATCH // 2) + [0.0] * (M.EVAL_BATCH // 2), jnp.float32
    )
    full = np.asarray(ev(theta, imgs, labels, w_full))
    half = np.asarray(ev(theta, imgs, labels, w_half))
    assert half[0] < full[0]  # masked loss sum is smaller
    assert half[1] <= full[1]
    # zero weights => zero metrics
    zero = np.asarray(ev(theta, imgs, labels, jnp.zeros(M.EVAL_BATCH)))
    assert zero[0] == 0.0 and zero[1] == 0.0


def test_gradient_quantization_via_custom_vjp():
    """Cotangents through _fq are quantized: at 4 bits the gradient of a
    fine-grained function must lie on a coarse grid."""
    x = jnp.asarray(np.linspace(-1, 1, 64, dtype=np.float32))
    c = jnp.sin(x * 3.7)  # fine-grained CONSTANT cotangent source
    g = jax.grad(lambda t: jnp.sum(M._fq(t, 4) * c))(x)
    distinct = np.unique(np.round(np.asarray(g), 5))
    # the raw cotangent c has 64 distinct values; after the quantized-STE
    # backward pass it must collapse onto a <= 2^4-level grid
    assert len(distinct) <= 16, len(distinct)


def test_macs_per_sample_positive_and_ordered():
    macs = {n: M.macs_per_sample(c) for n, c in M.VARIANTS.items()}
    assert all(v > 0 for v in macs.values())
    assert macs["tiny"] < macs["base"] < macs["wide"]
