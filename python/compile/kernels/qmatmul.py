"""L1 Pallas kernel: tiled quantized matmul (the model's dense-layer hot spot).

The paper's clients compute end-to-end at their designated precision; the
FPGA analogue packs more MACs per DSP slice at lower bit-widths.  The TPU
analogue implemented here (DESIGN.md §5): each (bm x bk) tile of A and
(bk x bn) tile of B is *fake-quantized in VMEM* (per-tile min/max affine or
mantissa truncation, per the precision->format map), then fed to an
MXU-shaped f32 `jnp.dot`.  Accumulation is f32 across the K grid axis —
matching low-precision-multiply / wide-accumulate AxC hardware.

Per-TILE (not per-tensor) quantization is deliberate: it is what a blocked
accelerator implementation can actually compute without a global reduction,
and it is *more* faithful to blocked FPGA dataflows.  The tile-exact oracle
is `ref.qmatmul_tiled`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = ["qmatmul_pallas", "TILE_M", "TILE_K", "TILE_N"]

# MXU-shaped tiles: 128x128 systolic array; bm follows the training batch.
TILE_M = 32
TILE_K = 128
TILE_N = 128

_SCALE_EPS = 1e-12


def _tile_fake_quant(x: jax.Array, bits: int) -> jax.Array:
    """Quantize one VMEM tile in-register.  Mirrors ref.fake_quant math,
    but with tile-local (not tensor-global) min/max for the fixed branch."""
    if bits >= 32:
        return x
    if bits in ref.FLOAT_TRUNC_LEVELS:
        drop = 23 - (bits - 9)
        mask = 0xFFFF_FFFF << drop & 0xFFFF_FFFF
        u = jax.lax.bitcast_convert_type(x, jnp.uint32)
        return jax.lax.bitcast_convert_type(u & jnp.uint32(mask), jnp.float32)
    if bits in ref.FIXED_POINT_LEVELS:
        levels = jnp.float32(2**bits - 1)
        w_min = jnp.min(x)
        w_max = jnp.max(x)
        scale = jnp.maximum((w_max - w_min) / levels, _SCALE_EPS)
        zp = -w_min / scale
        # nearest rounding: this quantizer sits inside the TRAINING graphs
        # (see ref.fixed_point_fake_quant's rounding note / Gupta et al. 16)
        q = jnp.clip(jnp.round(x / scale + zp), 0.0, levels)
        return (q - zp) * scale
    raise ValueError(f"unsupported precision level: {bits}")


def _qmm_kernel(bits: int, nk: int, a_ref, b_ref, o_ref):
    """Grid (i, j, k); o[i,j] accumulates quant(a[i,k]) @ quant(b[k,j])."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    at = _tile_fake_quant(a_ref[...], bits)
    bt = _tile_fake_quant(b_ref[...], bits)
    o_ref[...] += jnp.dot(at, bt, preferred_element_type=jnp.float32)


def _pad_to(x: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


def qmatmul_pallas(
    a: jax.Array,
    b: jax.Array,
    bits: int,
    bm: int = TILE_M,
    bk: int = TILE_K,
    bn: int = TILE_N,
) -> jax.Array:
    """(M,K) @ (K,N) with per-tile fake-quant of both operands.

    Arbitrary shapes: operands are zero-padded up to tile multiples (an
    all-zero pad tile quantizes to zeros and contributes nothing), output
    is cropped back to (M, N).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {a.shape} @ {b.shape}")
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    bm_, bk_, bn_ = min(bm, m), min(bk, k), min(bn, n)
    mp = -(-m // bm_) * bm_
    kp = -(-k // bk_) * bk_
    np_ = -(-n // bn_) * bn_
    ap = _pad_to(a, mp, kp)
    bp = _pad_to(b, kp, np_)
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        functools.partial(_qmm_kernel, bits, grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(ap, bp)
    return out[:m, :n]
