"""L1 Pallas kernels: Algorithm 2 quantization as fake-quant.

Two kernels, both elementwise over VMEM-shaped (rows, 128) tiles:

  * `fixed_point_fake_quant_pallas`  — the "fixed" branch of Algorithm 2
    (per-tensor affine: scale / zero-point computed on the host side of the
    graph with jnp.min/max, broadcast into the kernel as (1, 1) operands).
  * `float_truncate_pallas`          — the "floating-point" branch
    (IEEE-754 mantissa truncation via bit masking; bit-width is static).

TPU adaptation (DESIGN.md §5): tiles are (block_rows, 128) — the 128-lane
vector register shape — and block_rows is sized so a block is ≈256 KiB,
comfortably inside VMEM with double-buffering headroom.  `interpret=True`
everywhere: the CPU PJRT plugin cannot execute Mosaic custom-calls, so the
kernels lower to plain HLO (the structure — BlockSpec tiling, lane shape —
is what carries to real TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

__all__ = [
    "fixed_point_fake_quant_pallas",
    "float_truncate_pallas",
    "fake_quant_pallas",
    "LANES",
    "BLOCK_ROWS",
]

LANES = 128
# 2048 rows x 128 lanes x 4 B = 1 MiB per block: still double-bufferable in
# a 16 MiB VMEM, and 4x fewer interpret-mode grid iterations per call than
# the original 512-row blocks (§Perf iteration 3: train_q4 -19% step time).
BLOCK_ROWS = 2048


def _pad_rows(flat: jax.Array, pad_value: float) -> tuple[jax.Array, int]:
    """Pad a 1-D array to a (rows, LANES) grid with rows % block == 0."""
    n = flat.shape[0]
    rows = -(-n // LANES)  # ceil div
    block_rows = min(BLOCK_ROWS, max(8, rows))
    rows_padded = -(-rows // block_rows) * block_rows
    total = rows_padded * LANES
    padded = jnp.full((total,), pad_value, flat.dtype).at[:n].set(flat)
    return padded.reshape(rows_padded, LANES), block_rows


def _fixed_kernel(bits: int, nearest: bool, x_ref, scale_ref, zp_ref, o_ref):
    """q = clip(round(x/scale + zp), 0, 2^b-1); out = (q - zp) * scale."""
    scale = scale_ref[0, 0]
    zp = zp_ref[0, 0]
    levels = jnp.float32(2**bits - 1)
    pre = x_ref[...] / scale + zp
    q = jnp.round(pre) if nearest else jnp.floor(pre)
    q = jnp.clip(q, 0.0, levels)
    o_ref[...] = (q - zp) * scale


def fixed_point_fake_quant_pallas(
    x: jax.Array, bits: int, rounding: str = "floor"
) -> jax.Array:
    """Per-tensor affine fake-quant of an arbitrary-shape f32 tensor.

    Matches `ref.fixed_point_fake_quant` exactly (same round/clip math;
    scale and zero-point are computed with the same jnp reductions).
    """
    orig_shape = x.shape
    x = x.astype(jnp.float32)
    flat = x.reshape(-1)
    scale, zp = ref.fixed_point_params(flat, bits)
    # Pad with w_min (quantizes to level 0) so padding cannot overflow the
    # clip range; padded lanes are cropped before returning.
    w_min = jnp.min(flat)
    tiles, block_rows = _pad_rows(flat, 0.0)
    tiles = jnp.where(
        jnp.arange(tiles.size).reshape(tiles.shape) < flat.shape[0], tiles, w_min
    )
    rows = tiles.shape[0]
    grid = (rows // block_rows,)
    out = pl.pallas_call(
        functools.partial(_fixed_kernel, bits, rounding == "nearest"),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(tiles, scale.reshape(1, 1), zp.reshape(1, 1))
    return out.reshape(-1)[: flat.shape[0]].reshape(orig_shape)


def _trunc_kernel(mask: int, x_ref, o_ref):
    """Mask off dropped mantissa bits on the u32 view of the f32 tile."""
    u = jax.lax.bitcast_convert_type(x_ref[...], jnp.uint32)
    o_ref[...] = jax.lax.bitcast_convert_type(u & jnp.uint32(mask), jnp.float32)


def float_truncate_pallas(x: jax.Array, bits: int) -> jax.Array:
    """Mantissa-truncation fake-quant (Algorithm 2 "floating-point")."""
    if bits >= 32:
        return x.astype(jnp.float32)
    if bits < 10:
        raise ValueError(f"float truncation needs >= 10 bits, got {bits}")
    mant_keep = bits - 9
    drop = 23 - mant_keep
    mask = 0xFFFF_FFFF << drop & 0xFFFF_FFFF
    orig_shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    tiles, block_rows = _pad_rows(flat, 0.0)
    rows = tiles.shape[0]
    out = pl.pallas_call(
        functools.partial(_trunc_kernel, mask),
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, LANES), jnp.float32),
        interpret=True,
    )(tiles)
    return out.reshape(-1)[: flat.shape[0]].reshape(orig_shape)


def fake_quant_pallas(x: jax.Array, bits: int, rounding: str = "floor") -> jax.Array:
    """Dispatch mirroring `ref.fake_quant` (DESIGN.md §3 mapping)."""
    if bits >= 32:
        return x.astype(jnp.float32)
    if bits in ref.FLOAT_TRUNC_LEVELS:
        return float_truncate_pallas(x, bits)
    if bits in ref.FIXED_POINT_LEVELS:
        return fixed_point_fake_quant_pallas(x, bits, rounding)
    raise ValueError(f"unsupported precision level: {bits}")
