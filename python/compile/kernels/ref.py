"""Pure-jnp reference oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle here to float tolerance under pytest (see
python/tests/).  They implement, in plain jax.numpy:

  * Algorithm 2 of the paper (fixed-point affine quantization and
    floating-point truncation), as *fake-quantization*: the returned tensor
    holds the de-quantized decimal values, i.e. exactly the values the
    paper's multi-precision amplitude modulation transmits ("Convert model
    update to decimal", Alg. 1 step 3).
  * The quantized matmul used by the model's dense layers.
  * The K-client over-the-air superposition (Eq. 2 / Alg. 1 step 4) with
    residual channel-compensation error and additive receiver noise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "fixed_point_params",
    "fixed_point_fake_quant",
    "float_truncate",
    "fake_quant",
    "qmatmul",
    "qmatmul_tiled",
    "ota_superpose",
    "FIXED_POINT_LEVELS",
    "FLOAT_TRUNC_LEVELS",
    "SUPPORTED_LEVELS",
]

# Paper §III-B: fixed-point is preferred below 8-bit ("due to the limited
# dynamic range of floating-point formats under 8-bit representation");
# float formats are supported at >= 8-bit.  We follow the mapping recorded
# in DESIGN.md §3: {8, 6, 4, 3, 2} -> fixed point, {24, 16, 12} -> float
# truncation, 32 -> identity.
FIXED_POINT_LEVELS = (8, 6, 4, 3, 2)
FLOAT_TRUNC_LEVELS = (24, 16, 12)
SUPPORTED_LEVELS = (32,) + FLOAT_TRUNC_LEVELS + FIXED_POINT_LEVELS

# Guard for degenerate all-constant tensors (w_max == w_min) where the
# affine scale collapses to zero.
_SCALE_EPS = 1e-12


def fixed_point_params(w: jax.Array, bits: int):
    """Per-tensor scale / zero-point of Algorithm 2 ("fixed" branch).

    scale       = (w_max - w_min) / (2^b - 1)
    zero_point  = -w_min / scale
    """
    w_min = jnp.min(w)
    w_max = jnp.max(w)
    levels = jnp.float32(2**bits - 1)
    scale = (w_max - w_min) / levels
    scale = jnp.maximum(scale, _SCALE_EPS)
    zero_point = -w_min / scale
    return scale.astype(jnp.float32), zero_point.astype(jnp.float32)


def fixed_point_fake_quant(
    w: jax.Array, bits: int, rounding: str = "floor"
) -> jax.Array:
    """Algorithm 2 "fixed" branch followed by de-quantization.

    q_ij = max(0, min(2^b - 1, round(w_ij / scale + zero_point)))
    out  = (q_ij - zero_point) * scale

    rounding="floor"   — Algorithm 2 verbatim (transmission payloads, PTQ,
                         the rust goldens contract).
    rounding="nearest" — round-half-even, used for the TRAINING-state
                         quantizer inside the QAT graphs: with floor, any
                         negative perturbation of an on-grid weight drops a
                         full level, so SGD performs a destructive downward
                         random walk.  The paper's low-precision-training
                         citation [16] (Gupta et al. 2015) establishes that
                         nearest/stochastic rounding is required for
                         convergent low-precision training.
    """
    scale, zero_point = fixed_point_params(w, bits)
    levels = jnp.float32(2**bits - 1)
    pre = w / scale + zero_point
    q = jnp.floor(pre) if rounding == "floor" else jnp.round(pre)
    q = jnp.clip(q, 0.0, levels)
    return ((q - zero_point) * scale).astype(jnp.float32)


def float_truncate(w: jax.Array, bits: int) -> jax.Array:
    """Algorithm 2 "floating-point" branch: truncate mantissa to fit b bits.

    Layout kept: 1 sign bit + 8 exponent bits + (bits - 9) mantissa bits.
    Truncation (not rounding) of the IEEE-754 mantissa, exactly as
    "Truncate mantissa and exponent to fit b bits".  bits == 32 is the
    identity.  Requires bits >= 10 (at least one mantissa bit).
    """
    if bits >= 32:
        return w.astype(jnp.float32)
    if bits < 10:
        raise ValueError(f"float truncation needs >= 10 bits, got {bits}")
    mant_keep = bits - 9
    drop = 23 - mant_keep
    mask = jnp.uint32(0xFFFF_FFFF << drop & 0xFFFF_FFFF)
    u = jax.lax.bitcast_convert_type(w.astype(jnp.float32), jnp.uint32)
    return jax.lax.bitcast_convert_type(u & mask, jnp.float32)


def fake_quant(w: jax.Array, bits: int, rounding: str = "floor") -> jax.Array:
    """Dispatch per DESIGN.md §3 precision->format mapping."""
    if bits >= 32:
        return w.astype(jnp.float32)
    if bits in FLOAT_TRUNC_LEVELS:
        return float_truncate(w, bits)
    if bits in FIXED_POINT_LEVELS:
        return fixed_point_fake_quant(w, bits, rounding)
    raise ValueError(f"unsupported precision level: {bits}")


def qmatmul(a: jax.Array, b: jax.Array, bits: int) -> jax.Array:
    """Quantized matmul oracle: fake-quant both operands, then f32 matmul.

    Per-TENSOR quantization (the Pallas kernel quantizes per-tile; the
    pytest suite compares against `qmatmul_tiled` below for the tiled
    semantics and against this for the bits==32 path).  Nearest rounding —
    this is the training-graph quantizer.
    """
    return jnp.matmul(
        fake_quant(a, bits, "nearest"), fake_quant(b, bits, "nearest")
    )


def qmatmul_tiled(
    a: jax.Array, b: jax.Array, bits: int, bm: int, bk: int, bn: int
) -> jax.Array:
    """Tile-exact oracle of the Pallas qmatmul kernel.

    The kernel quantizes each (bm x bk) tile of `a` and (bk x bn) tile of
    `b` independently (per-tile min/max), then accumulates f32 partial
    products.  This mirrors that loop in plain jnp so tests can assert
    exact agreement.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    out = jnp.zeros((m, n), jnp.float32)
    for i0 in range(0, m, bm):
        for j0 in range(0, n, bn):
            acc = jnp.zeros((min(bm, m - i0), min(bn, n - j0)), jnp.float32)
            for k0 in range(0, k, bk):
                at = fake_quant(a[i0 : i0 + bm, k0 : k0 + bk], bits, "nearest")
                bt = fake_quant(b[k0 : k0 + bk, j0 : j0 + bn], bits, "nearest")
                acc = acc + jnp.matmul(at, bt)
            out = out.at[i0 : i0 + bm, j0 : j0 + bn].set(acc)
    return out


def ota_superpose(
    x: jax.Array,
    heff_re: jax.Array,
    heff_im: jax.Array,
    noise_re: jax.Array,
    noise_im: jax.Array,
):
    """K-client over-the-air superposition (Eq. 2 with Eq. 6 precoding).

    x        : (K, N) real amplitude-modulated decimal payloads
    heff_*   : (K,)  effective complex gain h_k * ĥ_k^{-1} per client
               (== 1 + estimation error; exactly 1 under perfect CSI)
    noise_*  : (N,)  receiver AWGN
    returns  : (re, im) of  Σ_k heff_k · x_k  +  n
    """
    re = jnp.einsum("k,kn->n", heff_re, x) + noise_re
    im = jnp.einsum("k,kn->n", heff_im, x) + noise_im
    return re.astype(jnp.float32), im.astype(jnp.float32)
