"""L1 Pallas kernel: K-client over-the-air superposition (Eq. 2 + Eq. 6).

The electromagnetic superposition itself is free in the real channel; what
the server-side emulation must compute per element n is

    y[n] = Σ_k  (h_k · ĥ_k^{-1}) · x_k[n]  +  noise[n]

where `h_k · ĥ_k^{-1}` is the residual effective gain after the client's
channel-inversion precoding (exactly 1+0j under perfect CSI; close to it
under pilot-based LS estimation, Eq. 5).  x is REAL — the paper's whole
point is that the mixed-precision payloads are converted to their decimal
values and amplitude-modulated, so superposition is plain linear addition
regardless of each client's bit-width (this is what breaks for digital QAM,
paper Eq. 3).

The kernel reduces over the K axis in VMEM: each grid step loads a
(K, block_n) slab of payloads plus the (K, 1) effective gains and produces
one (1, block_n) strip of the received complex baseband.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ota_superpose_pallas", "OTA_BLOCK_N"]

# 15 clients x 4096 lanes x 4 B = 240 KiB of payload per grid step.
OTA_BLOCK_N = 4096


def _ota_kernel(x_ref, hre_ref, him_ref, nre_ref, nim_ref, ore_ref, oim_ref):
    x = x_ref[...]          # (K, bn) real payload slab
    hre = hre_ref[...]      # (K, 1) effective gain, real part
    him = him_ref[...]      # (K, 1) effective gain, imag part
    ore_ref[...] = jnp.sum(hre * x, axis=0, keepdims=True) + nre_ref[...]
    oim_ref[...] = jnp.sum(him * x, axis=0, keepdims=True) + nim_ref[...]


def ota_superpose_pallas(
    x: jax.Array,
    heff_re: jax.Array,
    heff_im: jax.Array,
    noise_re: jax.Array,
    noise_im: jax.Array,
    block_n: int = OTA_BLOCK_N,
):
    """Superpose K client payloads; matches `ref.ota_superpose`.

    x: (K, N) f32, heff_*: (K,) f32, noise_*: (N,) f32.  N is padded to a
    block multiple internally and cropped on return.
    """
    k, n = x.shape
    bn = min(block_n, max(128, n))
    np_ = -(-n // bn) * bn
    if np_ != n:
        x = jnp.pad(x, ((0, 0), (0, np_ - n)))
        noise_re = jnp.pad(noise_re, (0, np_ - n))
        noise_im = jnp.pad(noise_im, (0, np_ - n))
    grid = (np_ // bn,)
    re, im = pl.pallas_call(
        _ota_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, bn), lambda i: (0, i)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bn), lambda i: (0, i)),
            pl.BlockSpec((1, bn), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
        ],
        interpret=True,
    )(
        x.astype(jnp.float32),
        heff_re.reshape(k, 1).astype(jnp.float32),
        heff_im.reshape(k, 1).astype(jnp.float32),
        noise_re.reshape(1, np_).astype(jnp.float32),
        noise_im.reshape(1, np_).astype(jnp.float32),
    )
    return re.reshape(-1)[:n], im.reshape(-1)[:n]
