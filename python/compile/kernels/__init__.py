"""L1: Pallas kernels for the paper's compute hot-spots.

  quantize.py — Algorithm 2 fake-quant (fixed-point affine + float trunc)
  qmatmul.py  — tiled quantized matmul (dense-layer hot spot)
  ota.py      — K-client over-the-air superposition
  ref.py      — pure-jnp oracles (the pytest correctness signal)
"""

from . import ota, qmatmul, quantize, ref  # noqa: F401
