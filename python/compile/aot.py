"""AOT driver: lower every (variant, precision) graph to HLO TEXT artifacts.

Run exactly once by `make artifacts`; the rust binary is self-contained
afterwards.  Python never appears on the request path.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs under --out (default ../artifacts):
  manifest.json            — everything rust needs: shapes, flat param
                             layout, artifact filenames, MAC counts
  <variant>_train_q<b>.hlo.txt
  <variant>_eval.hlo.txt
  ota_k15.hlo.txt
  <variant>_init.f32.bin   — He-init flat params (little-endian f32)
  goldens.json             — quantization test vectors for bit-exact parity
                             tests of the rust quant mirror
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ref
from .kernels.ota import ota_superpose_pallas

# Precision levels lowered as train-step artifacts for the flagship variant
# (paper §IV-A2: schemes draw from [32, 24, 16, 12, 8, 6, 4]).
TRAIN_LEVELS = (32, 24, 16, 12, 8, 6, 4)
# Variants besides the flagship get f32 training + eval only (Table I uses
# post-training quantization, done by the rust quant mirror).
FLAGSHIP = "base"
OTA_CLIENTS = 15
OTA_CHUNK = 16384


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> None:
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) / 1024:.0f} KiB)")


def lower_train(cfg: M.VariantConfig, bits: int) -> str:
    p = M.param_count(cfg)
    step = M.make_train_step(cfg, bits)
    lowered = jax.jit(step).lower(
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((M.TRAIN_BATCH, *M.IMAGE_SHAPE), jnp.float32),
        jax.ShapeDtypeStruct((M.TRAIN_BATCH,), jnp.int32),
        jax.ShapeDtypeStruct((1,), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_eval(cfg: M.VariantConfig) -> str:
    p = M.param_count(cfg)
    step = M.make_eval_step(cfg)
    lowered = jax.jit(step).lower(
        jax.ShapeDtypeStruct((p,), jnp.float32),
        jax.ShapeDtypeStruct((M.EVAL_BATCH, *M.IMAGE_SHAPE), jnp.float32),
        jax.ShapeDtypeStruct((M.EVAL_BATCH,), jnp.int32),
        jax.ShapeDtypeStruct((M.EVAL_BATCH,), jnp.float32),
    )
    return to_hlo_text(lowered)


def lower_ota() -> str:
    lowered = jax.jit(
        lambda x, hre, him, nre, nim: ota_superpose_pallas(x, hre, him, nre, nim)
    ).lower(
        jax.ShapeDtypeStruct((OTA_CLIENTS, OTA_CHUNK), jnp.float32),
        jax.ShapeDtypeStruct((OTA_CLIENTS,), jnp.float32),
        jax.ShapeDtypeStruct((OTA_CLIENTS,), jnp.float32),
        jax.ShapeDtypeStruct((OTA_CHUNK,), jnp.float32),
        jax.ShapeDtypeStruct((OTA_CHUNK,), jnp.float32),
    )
    return to_hlo_text(lowered)


def emit_goldens(path: str) -> None:
    """Deterministic quantization vectors: rust/src/quant must match these
    bit-for-bit (same floor/clip math, same scale/zero-point formulas)."""
    rng = np.random.default_rng(12345)
    cases = []
    inputs = {
        "normal": rng.standard_normal(64).astype(np.float32),
        "uniform_pos": rng.uniform(0.0, 7.5, 64).astype(np.float32),
        "mixed_scale": (
            rng.standard_normal(64) * 10.0 ** rng.integers(-3, 4, 64).astype(np.float64)
        ).astype(np.float32),
        "constant": np.full(16, 0.7311, np.float32),
        "zeros": np.zeros(8, np.float32),
        "with_negatives": np.linspace(-5.0, 5.0, 33).astype(np.float32),
    }
    for name, arr in inputs.items():
        for bits in ref.SUPPORTED_LEVELS:
            for rounding in ("floor", "nearest"):
                out = np.asarray(ref.fake_quant(jnp.asarray(arr), bits, rounding))
                cases.append(
                    {
                        "name": f"{name}_q{bits}_{rounding}",
                        "bits": int(bits),
                        "rounding": rounding,
                        "input": [float(v) for v in arr],
                        "expect": [float(v) for v in out],
                    }
                )
    with open(path, "w") as f:
        json.dump({"cases": cases}, f)
    print(f"  wrote {path} ({len(cases)} cases)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--variants",
        default=",".join(M.VARIANTS),
        help="comma-separated variant subset (flagship always included)",
    )
    ap.add_argument(
        "--levels",
        default=",".join(str(b) for b in TRAIN_LEVELS),
        help="train-step precision levels for the flagship variant",
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    t0 = time.time()

    variants = [v.strip() for v in args.variants.split(",") if v.strip()]
    if FLAGSHIP not in variants:
        variants.insert(0, FLAGSHIP)
    levels = [int(b) for b in args.levels.split(",") if b.strip()]
    for b in levels:
        assert b in ref.SUPPORTED_LEVELS, f"unsupported level {b}"

    manifest = {
        "version": 1,
        "train_batch": M.TRAIN_BATCH,
        "eval_batch": M.EVAL_BATCH,
        "image": list(M.IMAGE_SHAPE),
        "classes": M.NUM_CLASSES,
        "padded_classes": M.PADDED_CLASSES,
        "flagship": FLAGSHIP,
        "train_levels": levels,
        "ota": {
            "artifact": "ota_k15.hlo.txt",
            "clients": OTA_CLIENTS,
            "chunk": OTA_CHUNK,
        },
        "goldens": "goldens.json",
        "variants": {},
    }

    for vname in variants:
        cfg = M.VARIANTS[vname]
        print(f"[{vname}] param_count={M.param_count(cfg)}")
        train_levels = levels if vname == FLAGSHIP else [32]
        artifacts = {}
        for bits in train_levels:
            fname = f"{vname}_train_q{bits}.hlo.txt"
            _write(os.path.join(args.out, fname), lower_train(cfg, bits))
            artifacts[f"train_q{bits}"] = fname
        fname = f"{vname}_eval.hlo.txt"
        _write(os.path.join(args.out, fname), lower_eval(cfg))
        artifacts["eval"] = fname

        init = np.asarray(M.init_flat_params(cfg, seed=0), dtype="<f4")
        init_name = f"{vname}_init.f32.bin"
        init.tofile(os.path.join(args.out, init_name))
        print(f"  wrote {init_name} ({init.nbytes / 1024:.0f} KiB)")

        manifest["variants"][vname] = {
            "param_count": int(M.param_count(cfg)),
            "params": [[n, list(s)] for n, s in M.param_spec(cfg)],
            "artifacts": artifacts,
            "init": init_name,
            "macs_per_sample": int(M.macs_per_sample(cfg)),
        }

    _write(os.path.join(args.out, "ota_k15.hlo.txt"), lower_ota())
    emit_goldens(os.path.join(args.out, "goldens.json"))

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest.json written; total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
