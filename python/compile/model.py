"""L2: the jax model — "SignNet" CNN family with quantization-aware training.

The paper trains ResNet-50 on GTSRB at each client's designated precision
("the quantization function is systematically applied to every layer of the
CNN model ... and is integrated into both the forward and backward passes").
Our substitute (DESIGN.md §2) is a compact CNN family sized for interpret-
mode Pallas on CPU; the quantization semantics are identical:

  * every weight tensor is fake-quantized (L1 Pallas kernel) before use;
  * every activation is fake-quantized after its non-linearity;
  * every cotangent flowing back through a quantizer is itself quantized
    (straight-through-estimator with a quantized gradient) — this is what
    reproduces the paper's observation that ultra-low precision limits
    gradient dynamic range and makes 4-bit convergence slow and erratic;
  * dense layers run through the tiled quantized-matmul Pallas kernel in
    both the forward and backward passes;
  * the SGD parameter update is re-quantized so parameters live on the
    client's precision grid end-to-end.

Everything here is traced by `jax.jit(...).lower(...)` in aot.py — exactly
once per (variant, precision) — and never imported at runtime.

Parameter convention: a single FLAT f32 vector.  The rust coordinator keeps
model state as one flat vector (that is what gets amplitude-modulated for
OTA aggregation), so every artifact takes/returns flat params; slicing into
layer shapes happens inside the graph.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.qmatmul import qmatmul_pallas
from .kernels.quantize import fake_quant_pallas

__all__ = [
    "VariantConfig",
    "VARIANTS",
    "NUM_CLASSES",
    "PADDED_CLASSES",
    "IMAGE_SHAPE",
    "TRAIN_BATCH",
    "EVAL_BATCH",
    "param_spec",
    "param_count",
    "init_flat_params",
    "make_train_step",
    "make_eval_step",
    "macs_per_sample",
]

NUM_CLASSES = 43       # GTSRB-like: 43 traffic-sign classes
PADDED_CLASSES = 64    # logits padded to a lane-friendly width; extras masked
IMAGE_SHAPE = (32, 32, 3)
TRAIN_BATCH = 32
EVAL_BATCH = 64

_MASK_NEG = -1e9       # additive logit mask for the padding classes
GRAD_CLIP_NORM = 10.0  # global-norm gradient clip (see make_train_step)


@dataclass(frozen=True)
class VariantConfig:
    """One SignNet family member.

    channels    : output channels of the three conv stages
    convs_per_stage : conv layers per stage (depth knob)
    dense       : width of the hidden dense layer
    """

    name: str
    channels: tuple = (32, 64, 128)
    convs_per_stage: int = 1
    dense: int = 256


# Five variants standing in for the paper's Table-I model zoo (DESIGN.md §2).
VARIANTS = {
    "tiny": VariantConfig("tiny", channels=(8, 16, 32), dense=64),
    "small": VariantConfig("small", channels=(16, 32, 64), dense=128),
    "base": VariantConfig("base", channels=(32, 64, 128), dense=256),
    "wide": VariantConfig("wide", channels=(48, 96, 192), dense=256),
    "deep": VariantConfig("deep", channels=(24, 48, 96), convs_per_stage=2, dense=128),
}


# --------------------------------------------------------------------------
# Parameter bookkeeping: ordered spec <-> flat vector
# --------------------------------------------------------------------------

def param_spec(cfg: VariantConfig):
    """Ordered (name, shape) list — the SINGLE source of truth for the flat
    layout, mirrored verbatim into artifacts/manifest.json for rust."""
    spec = []
    cin = IMAGE_SHAPE[2]
    for stage, cout in enumerate(cfg.channels):
        for rep in range(cfg.convs_per_stage):
            spec.append((f"s{stage}c{rep}_w", (3, 3, cin, cout)))
            spec.append((f"s{stage}c{rep}_b", (cout,)))
            cin = cout
    spec.append(("d0_w", (cfg.channels[-1], cfg.dense)))
    spec.append(("d0_b", (cfg.dense,)))
    spec.append(("d1_w", (cfg.dense, PADDED_CLASSES)))
    spec.append(("d1_b", (PADDED_CLASSES,)))
    return spec


def param_count(cfg: VariantConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def _unflatten(cfg: VariantConfig, theta: jax.Array) -> dict:
    params, off = {}, 0
    for name, shape in param_spec(cfg):
        size = 1
        for d in shape:
            size *= d
        params[name] = theta[off : off + size].reshape(shape)
        off += size
    return params


def _flatten(cfg: VariantConfig, params: dict) -> jax.Array:
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in param_spec(cfg)]
    )


def init_flat_params(cfg: VariantConfig, seed: int = 0) -> jax.Array:
    """He-normal conv/dense init, zero biases — the 'random start'.

    The 'pretrained' initialisation the paper gets from ImageNet is produced
    by the rust pipeline itself (`mpota pretrain`, central f32 SGD on a
    held-out synthetic shard) and saved next to this blob.
    """
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_b") or name == "d1_w":
            # Biases and the classifier head start at zero: logits begin
            # uniform (loss = ln(NUM_CLASSES)) which keeps the first rounds
            # of low-precision training inside the quantizer dynamic range.
            chunks.append(jnp.zeros(shape, jnp.float32).reshape(-1))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = (2.0 / fan_in) ** 0.5
            chunks.append(
                (jax.random.normal(sub, shape, jnp.float32) * std).reshape(-1)
            )
    return jnp.concatenate(chunks)


# --------------------------------------------------------------------------
# Quantizers with quantized-cotangent STE
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _fq(x, bits):
    # nearest rounding throughout the training graphs: Algorithm 2's floor
    # is kept for transmission/PTQ, but floor applied to the SGD weight
    # state makes every negatively-perturbed on-grid weight drop a full
    # level per step (a destructive downward random walk).  Nearest is the
    # convergent choice per the paper's citation [16] (Gupta et al. 2015).
    return fake_quant_pallas(x, bits, rounding="nearest")


def _fq_fwd(x, bits):
    return fake_quant_pallas(x, bits, rounding="nearest"), None


def _fq_bwd(bits, _res, g):
    # STE, but the cotangent itself is pushed onto the precision grid:
    # the client's backward pass also runs at q_k bits (paper §III-B).
    return (fake_quant_pallas(g, bits, rounding="nearest"),)


_fq.defvjp(_fq_fwd, _fq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _qmm(a, b, bits):
    return qmatmul_pallas(a, b, bits)


def _qmm_fwd(a, b, bits):
    return qmatmul_pallas(a, b, bits), (a, b)


def _qmm_bwd(bits, res, g):
    # Both backward matmuls also run through the quantized kernel: the AxC
    # hardware has no full-precision multiplier to fall back to.
    a, b = res
    da = qmatmul_pallas(g, b.T, bits)
    db = qmatmul_pallas(a.T, g, bits)
    return (da, db)


_qmm.defvjp(_qmm_fwd, _qmm_bwd)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

_DIMS = jax.lax.conv_dimension_numbers(
    (1, *IMAGE_SHAPE), (3, 3, 1, 1), ("NHWC", "HWIO", "NHWC")
)


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME", dimension_numbers=_DIMS
    )
    return y + b


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def forward(cfg: VariantConfig, bits: int, theta: jax.Array, images: jax.Array):
    """images (B,32,32,3) -> masked logits (B, PADDED_CLASSES)."""
    p = _unflatten(cfg, theta)
    x = images
    for stage in range(len(cfg.channels)):
        for rep in range(cfg.convs_per_stage):
            w = _fq(p[f"s{stage}c{rep}_w"], bits)
            b = _fq(p[f"s{stage}c{rep}_b"], bits)
            x = jax.nn.relu(_conv(x, w, b))
            x = _fq(x, bits)
        x = _maxpool2(x)
    x = jnp.mean(x, axis=(1, 2))  # global average pool -> (B, C3)
    x = jax.nn.relu(_qmm(x, p["d0_w"], bits) + _fq(p["d0_b"], bits))
    x = _fq(x, bits)
    logits = _qmm(x, p["d1_w"], bits) + _fq(p["d1_b"], bits)
    mask = jnp.where(jnp.arange(PADDED_CLASSES) < NUM_CLASSES, 0.0, _MASK_NEG)
    return logits + mask


def _loss_and_metrics(cfg, bits, theta, images, labels):
    logits = forward(cfg, bits, theta, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, PADDED_CLASSES, dtype=jnp.float32)
    per_example = -jnp.sum(onehot * logp, axis=-1)
    loss = jnp.mean(per_example)
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
    )
    return loss, correct


# --------------------------------------------------------------------------
# Artifact entry points
# --------------------------------------------------------------------------

def make_train_step(cfg: VariantConfig, bits: int):
    """One minibatch SGD step at precision `bits`.

    (theta f32[P], images f32[B,32,32,3], labels i32[B], lr f32[1])
      -> (new_theta f32[P], metrics f32[2] = [mean_loss, correct_count])

    The updated parameters are re-quantized so they stay on the client's
    precision grid (Alg. 1 step 2: the client operates end-to-end at q_k).
    """

    def train_step(theta, images, labels, lr):
        (loss, correct), grad = jax.value_and_grad(
            lambda t: _loss_and_metrics(cfg, bits, t, images, labels),
            has_aux=True,
        )(theta)
        # Global-norm gradient clipping: low-precision forward passes emit
        # occasional huge cross-entropy gradients (coarse logits), and an
        # unclipped 4-bit run diverges within a few rounds.  Clipping keeps
        # ultra-low-precision training in the paper's "slow and erratic
        # but bounded" regime (cf. its citation [16] on the narrow dynamic
        # range of low-precision gradients).
        grad_norm = jnp.sqrt(jnp.sum(grad * grad) + 1e-12)
        clip = jnp.minimum(1.0, GRAD_CLIP_NORM / grad_norm)
        new_theta = _fq(theta - lr[0] * clip * grad, bits)
        return new_theta, jnp.stack([loss, correct])

    return train_step


def make_eval_step(cfg: VariantConfig):
    """f32 evaluation with a per-example weight mask for ragged last batches.

    (theta f32[P], images f32[B,32,32,3], labels i32[B], weights f32[B])
      -> metrics f32[2] = [Σ w·loss_i, Σ w·correct_i]
    """

    def eval_step(theta, images, labels, weights):
        logits = forward(cfg, 32, theta, images)
        logp = jax.nn.log_softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(labels, PADDED_CLASSES, dtype=jnp.float32)
        per_example = -jnp.sum(onehot * logp, axis=-1)
        correct = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
        return jnp.stack(
            [jnp.sum(per_example * weights), jnp.sum(correct * weights)]
        )

    return eval_step


# --------------------------------------------------------------------------
# Energy-model inputs
# --------------------------------------------------------------------------

def macs_per_sample(cfg: VariantConfig) -> int:
    """Forward-pass multiply-accumulates for one sample (energy model D_ML).

    Conv: H·W·K_h·K_w·C_in·C_out at each layer's output resolution;
    dense: C_in·C_out.  Pooling/activations are ignored (MAC-free).
    """
    h, w, cin = IMAGE_SHAPE
    total = 0
    for stage, cout in enumerate(VARIANTS[cfg.name].channels):
        for _ in range(cfg.convs_per_stage):
            total += h * w * 3 * 3 * cin * cout
            cin = cout
        h, w = h // 2, w // 2
    total += cfg.channels[-1] * cfg.dense
    total += cfg.dense * PADDED_CLASSES
    return total
