//! First-order Gauss-Markov (AR(1)) evolution for temporally correlated
//! block fading.
//!
//! The paper (and the seed reproduction) draw an independent Rayleigh
//! coefficient per (client, round).  Real deployments are not i.i.d.: a
//! client in a deep fade this round tends to still be in one next round.
//! The standard discrete-time model for that memory is the first-order
//! Gauss-Markov process over the complex coefficient,
//!
//! ```text
//! h(t) = ρ · h(t-1) + sqrt(1 - ρ²) · w(t),      w(t) ~ CN(0, 1)
//! ```
//!
//! which keeps the marginal distribution CN(0, 1) (unit-power Rayleigh
//! magnitude, exactly as [`crate::channel::fading`]) while giving the
//! sequence lag-1 autocorrelation `E[h(t)·h*(t-1)] = ρ`.  Physically ρ
//! relates to the Doppler spread through Jakes' model, `ρ = J₀(2π f_d T)`:
//! ρ = 0 recovers the i.i.d. per-round draw, ρ → 1 a quasi-static channel
//! that barely moves between rounds.

use crate::channel::complex::C32;

/// One AR(1) step: `ρ·prev + sqrt(1-ρ²)·innovation`.
///
/// `rho == 0` is special-cased to return the innovation *bit-exactly*
/// (no `0·prev + 1·w` float round trip), which is what pins the
/// [`crate::sim::GaussMarkov`] channel model at ρ = 0 to the i.i.d.
/// Rayleigh path bit-for-bit per seed.
#[inline]
pub fn ar1_step(prev: C32, rho: f32, innovation: C32) -> C32 {
    if rho == 0.0 {
        return innovation;
    }
    prev.scale(rho) + innovation.scale((1.0 - rho * rho).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::fading::rayleigh_coeff;
    use crate::rng::Rng;

    #[test]
    fn rho_zero_returns_innovation_bit_exactly() {
        let mut rng = Rng::seed_from(5);
        for _ in 0..100 {
            let prev = rayleigh_coeff(&mut rng);
            let w = rayleigh_coeff(&mut rng);
            let h = ar1_step(prev, 0.0, w);
            assert_eq!(h.re.to_bits(), w.re.to_bits());
            assert_eq!(h.im.to_bits(), w.im.to_bits());
        }
    }

    #[test]
    fn process_stays_unit_power() {
        // the sqrt(1-rho^2) innovation scaling keeps the marginal CN(0,1)
        for rho in [0.3f32, 0.7, 0.95] {
            let mut rng = Rng::seed_from(6);
            let mut h = rayleigh_coeff(&mut rng); // stationary init
            let n = 100_000;
            let mut pow = 0.0f64;
            for _ in 0..n {
                h = ar1_step(h, rho, rayleigh_coeff(&mut rng));
                pow += h.norm_sq() as f64;
            }
            pow /= n as f64;
            // high rho => strongly correlated samples => wider CI
            assert!((pow - 1.0).abs() < 0.1, "rho={rho}: E|h|^2 = {pow}");
        }
    }

    #[test]
    fn lag1_autocorrelation_tracks_rho() {
        let rho = 0.8f32;
        let mut rng = Rng::seed_from(7);
        let mut h = rayleigh_coeff(&mut rng);
        let (mut num, mut den) = (0.0f64, 0.0f64);
        for _ in 0..200_000 {
            let prev = h;
            h = ar1_step(h, rho, rayleigh_coeff(&mut rng));
            num += (h.re * prev.re + h.im * prev.im) as f64; // Re(h·prev*)
            den += prev.norm_sq() as f64;
        }
        let acf = num / den;
        assert!((acf - rho as f64).abs() < 0.01, "acf {acf} vs rho {rho}");
    }
}
