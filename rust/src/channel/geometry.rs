//! Per-client path-loss geometry: clients placed on a disc around the
//! server, log-distance path loss plus log-normal shadowing — PERSISTENT
//! per-client SNR asymmetry instead of the seed's symmetric fleet.
//!
//! A client at distance `d` from the server has large-scale power gain
//!
//! ```text
//! G(d) [dB] = -10 · α · log10(d / d₀) + X,      X ~ N(0, σ_sh²)  [dB]
//! ```
//!
//! with path-loss exponent `α` and shadowing standard deviation `σ_sh`.
//! Distances are drawn area-uniformly over the annulus
//! `[REF_DISTANCE, radius]` (uniform client density on the disc), ONCE per
//! run — near/far and lucky/shadowed clients keep their advantage every
//! round, which is exactly the heterogeneity i.i.d. fading averages away.
//!
//! The fleet is normalized to mean unit power gain so the server-side SNR
//! knob keeps its calibrated meaning; what changes is the *spread* across
//! clients.  The composite per-round channel is `h_k(t) = a_k · g_k(t)`
//! with the fixed amplitude scale `a_k = sqrt(G_k)` from here and the
//! unit-power small-scale Rayleigh draw `g_k(t)` from
//! [`crate::channel::fading`].
//!
//! Fleet scaling: sites are placed LAZILY, one per CLIENT IDENTITY, the
//! first round that client is selected — a million-client run with
//! `clients_per_round = 64` places exactly the clients that ever
//! participate, and [`crate::sim::PathLossGeometry`] caps the resident
//! set with a bounded id-keyed LRU so memory stays O(K) even when
//! selection churns through the fleet.  The persistent asymmetry
//! attaches to the client, not the participant slot: a far client drawn
//! via [`place_one_raw`] keeps its distance and shadowing realisation
//! every time it reappears, whichever slot it lands in (the
//! [`crate::sim::ChannelModel`] fleet-scaling contract).

use crate::rng::Rng;

/// Reference distance d₀ in meters: the closest a client can sit, and the
/// distance at which the un-normalized path gain is 0 dB.
pub const REF_DISTANCE: f32 = 10.0;

/// One client's placement and fixed large-scale channel state.
#[derive(Clone, Copy, Debug)]
pub struct Site {
    /// Distance from the server in meters.
    pub distance: f32,
    /// This client's log-normal shadowing realisation in dB.
    pub shadow_db: f32,
    /// Amplitude scale `a_k = sqrt(normalized power gain)` applied to the
    /// small-scale fading draw each round.
    pub amp: f32,
}

/// Log-distance path gain in dB at distance `d` (no shadowing):
/// `-10·α·log10(d/d₀)`.
pub fn path_gain_db(distance: f32, alpha: f32) -> f32 {
    -10.0 * alpha * (distance / REF_DISTANCE).log10()
}

/// Place ONE client area-uniformly on the annulus `[REF_DISTANCE,
/// radius]` and compute its shadowed path gain.  Consumes exactly one
/// uniform and one normal draw — deterministic per RNG state.  The
/// returned [`Site::amp`] holds the RAW linear POWER gain, not the
/// amplitude scale; callers normalize against a fleet mean and take the
/// square root ([`place_clients`] does both, [`crate::sim::PathLossGeometry`]
/// normalizes incrementally as ids first appear).
pub fn place_one_raw(radius: f32, alpha: f32, shadowing_db: f32, rng: &mut Rng) -> Site {
    let r0_sq = REF_DISTANCE * REF_DISTANCE;
    let r_sq = radius * radius;
    // area-uniform over the annulus: d = sqrt(u·(R² - d₀²) + d₀²)
    let u = rng.uniform() as f32;
    let distance = (u * (r_sq - r0_sq) + r0_sq).sqrt();
    let shadow_db = rng.normal_f32(0.0, shadowing_db);
    let gain_db = path_gain_db(distance, alpha) + shadow_db;
    let gain = 10f32.powf(gain_db / 10.0);
    Site { distance, shadow_db, amp: gain }
}

/// Place `n` clients area-uniformly on the annulus `[REF_DISTANCE,
/// radius]` and compute their shadowed, fleet-normalized amplitude
/// scales.  Consumes exactly one uniform and one normal draw per client —
/// deterministic per RNG state.
pub fn place_clients(
    n: usize,
    radius: f32,
    alpha: f32,
    shadowing_db: f32,
    rng: &mut Rng,
) -> Vec<Site> {
    assert!(n > 0, "need at least one client");
    assert!(
        radius > REF_DISTANCE,
        "cell radius {radius} must exceed the reference distance {REF_DISTANCE}"
    );
    let mut sites = Vec::with_capacity(n);
    let mut mean_gain = 0.0f64;
    for _ in 0..n {
        // amp temporarily holds the raw linear POWER gain; the
        // normalization pass below converts it to the amplitude scale
        let site = place_one_raw(radius, alpha, shadowing_db, rng);
        mean_gain += site.amp as f64;
        sites.push(site);
    }
    mean_gain /= n as f64;
    for s in &mut sites {
        s.amp = ((s.amp as f64 / mean_gain).sqrt()) as f32;
    }
    sites
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_respects_the_annulus_and_normalization() {
        let mut rng = Rng::seed_from(31);
        let sites = place_clients(200, 100.0, 3.0, 6.0, &mut rng);
        assert_eq!(sites.len(), 200);
        let mut mean_pow = 0.0f64;
        for s in &sites {
            assert!(
                (REF_DISTANCE..=100.0).contains(&s.distance),
                "distance {} outside annulus",
                s.distance
            );
            assert!(s.amp > 0.0);
            mean_pow += (s.amp as f64) * (s.amp as f64);
        }
        mean_pow /= sites.len() as f64;
        assert!((mean_pow - 1.0).abs() < 1e-3, "mean power gain {mean_pow}");
    }

    #[test]
    fn without_shadowing_gain_is_monotone_in_distance() {
        let mut rng = Rng::seed_from(32);
        let mut sites = place_clients(50, 300.0, 2.8, 0.0, &mut rng);
        sites.sort_by(|a, b| a.distance.partial_cmp(&b.distance).unwrap());
        for w in sites.windows(2) {
            assert!(
                w[0].amp > w[1].amp,
                "closer client must have the larger gain: {:?} vs {:?}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn farther_cells_spread_the_gains_wider() {
        let spread = |radius: f32| {
            let mut rng = Rng::seed_from(33);
            let sites = place_clients(100, radius, 3.0, 0.0, &mut rng);
            let dbs: Vec<f64> =
                sites.iter().map(|s| 20.0 * (s.amp as f64).log10()).collect();
            let lo = dbs.iter().cloned().fold(f64::INFINITY, f64::min);
            let hi = dbs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            hi - lo
        };
        assert!(spread(500.0) > spread(50.0) + 10.0);
    }

    #[test]
    fn deterministic_per_rng_state() {
        let a = place_clients(20, 120.0, 3.0, 4.0, &mut Rng::seed_from(34));
        let b = place_clients(20, 120.0, 3.0, 4.0, &mut Rng::seed_from(34));
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.distance.to_bits(), y.distance.to_bits());
            assert_eq!(x.amp.to_bits(), y.amp.to_bits());
        }
    }

    #[test]
    fn path_gain_reference_point() {
        assert_eq!(path_gain_db(REF_DISTANCE, 3.0), 0.0);
        // one decade out at alpha=3: -30 dB
        assert!((path_gain_db(REF_DISTANCE * 10.0, 3.0) + 30.0).abs() < 1e-4);
    }
}
