//! Minimal complex-f32 arithmetic for the baseband channel simulation.
//!
//! (The vendored dependency set has no `num-complex`; the handful of ops
//! the PHY needs are trivial to supply and keep fully inlinable.)

use std::ops::{Add, Div, Mul, Neg, Sub};

/// Complex number, f32 components (baseband samples, channel gains).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct C32 {
    pub re: f32,
    pub im: f32,
}

impl C32 {
    pub const ZERO: C32 = C32 { re: 0.0, im: 0.0 };
    pub const ONE: C32 = C32 { re: 1.0, im: 0.0 };

    #[inline]
    pub fn new(re: f32, im: f32) -> Self {
        C32 { re, im }
    }

    /// From polar form (magnitude, phase-radians).
    pub fn from_polar(r: f32, theta: f32) -> Self {
        C32::new(r * theta.cos(), r * theta.sin())
    }

    #[inline]
    pub fn conj(self) -> Self {
        C32::new(self.re, -self.im)
    }

    #[inline]
    pub fn norm_sq(self) -> f32 {
        self.re * self.re + self.im * self.im
    }

    #[inline]
    pub fn abs(self) -> f32 {
        self.norm_sq().sqrt()
    }

    pub fn arg(self) -> f32 {
        self.im.atan2(self.re)
    }

    /// Multiplicative inverse; returns None for (near-)zero magnitude.
    pub fn inv(self) -> Option<Self> {
        let n = self.norm_sq();
        if n < 1e-30 {
            None
        } else {
            Some(C32::new(self.re / n, -self.im / n))
        }
    }

    /// Scale by a real factor.
    #[inline]
    pub fn scale(self, a: f32) -> Self {
        C32::new(self.re * a, self.im * a)
    }
}

impl Add for C32 {
    type Output = C32;
    #[inline]
    fn add(self, o: C32) -> C32 {
        C32::new(self.re + o.re, self.im + o.im)
    }
}

impl Sub for C32 {
    type Output = C32;
    #[inline]
    fn sub(self, o: C32) -> C32 {
        C32::new(self.re - o.re, self.im - o.im)
    }
}

impl Mul for C32 {
    type Output = C32;
    #[inline]
    fn mul(self, o: C32) -> C32 {
        C32::new(
            self.re * o.re - self.im * o.im,
            self.re * o.im + self.im * o.re,
        )
    }
}

impl Div for C32 {
    type Output = C32;
    #[inline]
    fn div(self, o: C32) -> C32 {
        let n = o.norm_sq();
        C32::new(
            (self.re * o.re + self.im * o.im) / n,
            (self.im * o.re - self.re * o.im) / n,
        )
    }
}

impl Neg for C32 {
    type Output = C32;
    #[inline]
    fn neg(self) -> C32 {
        C32::new(-self.re, -self.im)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: C32, b: C32, tol: f32) -> bool {
        (a - b).abs() < tol
    }

    #[test]
    fn basic_arithmetic() {
        let a = C32::new(1.0, 2.0);
        let b = C32::new(3.0, -1.0);
        assert_eq!(a + b, C32::new(4.0, 1.0));
        assert_eq!(a - b, C32::new(-2.0, 3.0));
        // (1+2i)(3-i) = 3 - i + 6i - 2i^2 = 5 + 5i
        assert_eq!(a * b, C32::new(5.0, 5.0));
    }

    #[test]
    fn division_inverts_multiplication() {
        let a = C32::new(0.3, -0.7);
        let b = C32::new(-1.2, 0.4);
        assert!(close((a * b) / b, a, 1e-6));
    }

    #[test]
    fn inv_and_conj() {
        let a = C32::new(2.0, -3.0);
        let inv = a.inv().unwrap();
        assert!(close(a * inv, C32::ONE, 1e-6));
        assert_eq!(a.conj(), C32::new(2.0, 3.0));
        assert!(C32::ZERO.inv().is_none());
    }

    #[test]
    fn polar_roundtrip() {
        let c = C32::from_polar(2.0, 0.7);
        assert!((c.abs() - 2.0).abs() < 1e-6);
        assert!((c.arg() - 0.7).abs() < 1e-6);
    }

    #[test]
    fn norms() {
        assert_eq!(C32::new(3.0, 4.0).abs(), 5.0);
        assert_eq!(C32::new(3.0, 4.0).norm_sq(), 25.0);
    }
}
