//! Truncated channel-inversion precoding (paper Eq. 6).
//!
//! Each client pre-multiplies its payload by ĥ⁻¹ so the server receives
//! `h·ĥ⁻¹·x ≈ x` and the electromagnetic superposition performs the sum.
//! Plain inversion has unbounded transmit power for deeply-faded channels;
//! like the OTA-FL literature the paper cites ([3], [5]) we truncate: a
//! client whose |ĥ| falls below a threshold is *silenced* for the round
//! (its payload is dropped from the superposition and the server's scaling
//! is adjusted by the participating count).

use crate::channel::complex::C32;

/// Outcome of precoding for one client-round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Precode {
    /// Client transmits with this precoding coefficient (= ĥ⁻¹).
    Transmit(C32),
    /// Channel too deeply faded (|ĥ| < threshold): client stays silent.
    Silenced,
}

/// Default truncation threshold on |ĥ|.  With h ~ CN(0,1) this silences
/// P[|h| < 0.1] ≈ 1% of client-rounds while bounding the transmit power
/// amplification at 1/0.1² = 100x (20 dB).
pub const DEFAULT_TRUNCATION: f32 = 0.1;

/// Compute the truncated-inversion precoder for an estimated channel.
/// Inlined: called once per (client, round) inside the zero-alloc
/// `draw_into` loop.
#[inline]
pub fn channel_inversion(h_est: C32, truncation: f32) -> Precode {
    if h_est.abs() < truncation {
        return Precode::Silenced;
    }
    match h_est.inv() {
        Some(inv) => Precode::Transmit(inv),
        None => Precode::Silenced,
    }
}

/// Effective end-to-end gain for a transmitting client: `h_true · ĥ⁻¹`.
/// Under perfect CSI this is exactly 1+0j; the deviation is the residual
/// misalignment the OTA aggregation inherits.
#[inline]
pub fn effective_gain(h_true: C32, precode: &Precode) -> Option<C32> {
    match precode {
        Precode::Transmit(inv) => Some(h_true * *inv),
        Precode::Silenced => None,
    }
}

/// Transmit-power amplification factor |ĥ⁻¹|² of a precoder.
pub fn power_amplification(precode: &Precode) -> f32 {
    match precode {
        Precode::Transmit(inv) => inv.norm_sq(),
        Precode::Silenced => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::fading::rayleigh_coeff;
    use crate::rng::Rng;

    #[test]
    fn perfect_csi_gain_is_one() {
        let h = C32::new(0.6, -0.8);
        let p = channel_inversion(h, DEFAULT_TRUNCATION);
        let g = effective_gain(h, &p).unwrap();
        assert!((g - C32::ONE).abs() < 1e-6, "{g:?}");
    }

    #[test]
    fn deep_fade_is_silenced() {
        let h = C32::new(0.01, 0.02);
        assert_eq!(channel_inversion(h, 0.1), Precode::Silenced);
        assert_eq!(effective_gain(h, &Precode::Silenced), None);
    }

    #[test]
    fn zero_channel_is_silenced_even_with_zero_truncation() {
        assert_eq!(channel_inversion(C32::ZERO, 0.0), Precode::Silenced);
    }

    #[test]
    fn power_amplification_bounded_by_truncation() {
        let mut rng = Rng::seed_from(11);
        let trunc = 0.2f32;
        let bound = 1.0 / (trunc * trunc) * 1.001;
        for _ in 0..10_000 {
            let h = rayleigh_coeff(&mut rng);
            let p = channel_inversion(h, trunc);
            assert!(power_amplification(&p) <= bound);
        }
    }

    #[test]
    fn silencing_rate_near_theory() {
        // P[|h| < t] = 1 - exp(-t^2) for unit-power Rayleigh
        let mut rng = Rng::seed_from(12);
        let trunc = 0.3f32;
        let n = 100_000;
        let silenced = (0..n)
            .filter(|_| {
                matches!(
                    channel_inversion(rayleigh_coeff(&mut rng), trunc),
                    Precode::Silenced
                )
            })
            .count();
        let rate = silenced as f64 / n as f64;
        let theory = 1.0 - (-(trunc as f64).powi(2)).exp();
        assert!((rate - theory).abs() < 0.005, "rate {rate} theory {theory}");
    }

    #[test]
    fn imperfect_csi_gain_near_one() {
        let mut rng = Rng::seed_from(13);
        let h = C32::new(0.9, 0.5);
        // small estimation error
        let h_est = h + C32::new(0.01, -0.02);
        let p = channel_inversion(h_est, DEFAULT_TRUNCATION);
        let g = effective_gain(h, &p).unwrap();
        assert!((g - C32::ONE).abs() < 0.05, "{g:?}");
        let _ = rng.next_u64();
    }
}
