//! Wireless physical-layer substrate: everything between "client has a
//! decimal payload" and "server has a noisy superposition".
//!
//! Composition per communication round (paper §III-A):
//!
//! 1. [`fading`] draws each client's Rayleigh coefficient h_k (block fading);
//! 2. [`pilot`] simulates the downlink pilot broadcast and LS estimation
//!    ĥ_k at each client (Eq. 5);
//! 3. [`precode`] computes the truncated channel inversion ĥ_k⁻¹ (Eq. 6);
//! 4. [`RoundChannel`] packages the resulting effective gains h_k·ĥ_k⁻¹
//!    and the server AWGN level for the OTA superposition (`crate::ota`).

pub mod complex;
pub mod correlated;
pub mod fading;
pub mod geometry;
pub mod pilot;
pub mod precode;

pub use complex::C32;
pub use precode::Precode;

use anyhow::{bail, Result};

use crate::rng::Rng;

/// Which physical-layer model the run simulates.  The full simulation
/// pipeline lives behind the [`crate::sim::ChannelModel`] trait; this enum
/// is the config-file-friendly name for the built-in models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FadingKind {
    /// Rayleigh block fading + pilot LS estimation + truncated channel
    /// inversion (the paper's §III-A pipeline — the default).
    Rayleigh,
    /// No fading: every client arrives with unit gain, only the server
    /// AWGN remains (a perfectly-aligned OTA uplink; consumes no
    /// channel-RNG draws).
    Awgn,
    /// Temporally correlated block fading: each client's coefficient
    /// evolves as a first-order Gauss-Markov (AR(1)) process with
    /// coefficient [`ChannelConfig::rho`] (see [`correlated`]); ρ = 0 is
    /// bit-identical to `Rayleigh`.  Pilot estimation and precoding are
    /// unchanged.
    GaussMarkov,
    /// Spatial asymmetry: clients placed on a disc with log-distance path
    /// loss + log-normal shadowing (see [`geometry`]), so per-client mean
    /// SNR differs persistently across the run; small-scale fading stays
    /// Rayleigh.
    PathLoss,
}

impl std::str::FromStr for FadingKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "rayleigh" => Ok(FadingKind::Rayleigh),
            "awgn" | "none" => Ok(FadingKind::Awgn),
            "gauss_markov" | "gauss-markov" | "ar1" => Ok(FadingKind::GaussMarkov),
            "path_loss" | "path-loss" | "geometry" => Ok(FadingKind::PathLoss),
            other => bail!(
                "unknown channel model '{other}' \
                 (rayleigh|awgn|gauss_markov|path_loss)"
            ),
        }
    }
}

impl std::fmt::Display for FadingKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}",
            match self {
                FadingKind::Rayleigh => "rayleigh",
                FadingKind::Awgn => "awgn",
                FadingKind::GaussMarkov => "gauss_markov",
                FadingKind::PathLoss => "path_loss",
            }
        )
    }
}

/// Channel-simulation configuration (one per run).
#[derive(Clone, Debug, PartialEq)]
pub struct ChannelConfig {
    /// Server receiver SNR in dB (paper: 5-30 dB of emulated noise).
    pub snr_db: f32,
    /// Pilot sequence length for LS channel estimation.
    pub pilot_len: usize,
    /// Per-sample noise variance during pilot reception at the clients.
    pub pilot_noise_var: f32,
    /// Truncation threshold on |ĥ| for channel-inversion precoding.
    pub truncation: f32,
    /// Perfect-CSI switch (ablation: zero estimation error).
    pub perfect_csi: bool,
    /// Which built-in physical-layer model to simulate.
    pub model: FadingKind,
    /// AR(1) temporal-correlation coefficient ρ ∈ [0, 1) for the
    /// `gauss_markov` model (0 = i.i.d. per round, identical to
    /// `rayleigh`; unused by the other models).
    pub rho: f32,
    /// Path-loss exponent α for the `path_loss` model.
    pub path_loss_exp: f32,
    /// Log-normal shadowing standard deviation (dB) for `path_loss`.
    pub shadowing_db: f32,
    /// Cell radius in meters for `path_loss`: clients are placed
    /// area-uniformly between [`geometry::REF_DISTANCE`] and this.
    pub cell_radius: f32,
}

impl Default for ChannelConfig {
    fn default() -> Self {
        ChannelConfig {
            snr_db: 20.0,
            pilot_len: 16,
            pilot_noise_var: 0.01,
            truncation: precode::DEFAULT_TRUNCATION,
            perfect_csi: false,
            model: FadingKind::Rayleigh,
            rho: 0.0,
            path_loss_exp: 3.0,
            shadowing_db: 6.0,
            cell_radius: 100.0,
        }
    }
}

impl ChannelConfig {
    /// Validate the channel knobs (called from `RunConfig::validate`, and
    /// per sweep cell so `channel_model`-axis overrides are checked too
    /// instead of panicking inside a model constructor mid-sweep).
    pub fn validate(&self) -> Result<()> {
        if !self.snr_db.is_finite() {
            bail!("snr_db must be finite");
        }
        if !(0.0..1.0).contains(&self.rho) {
            bail!("rho {} must be in [0, 1)", self.rho);
        }
        if !(self.path_loss_exp > 0.0 && self.path_loss_exp.is_finite()) {
            bail!("path_loss_exp must be positive and finite");
        }
        if !(self.shadowing_db >= 0.0 && self.shadowing_db.is_finite()) {
            bail!("shadowing_db must be non-negative and finite");
        }
        if self.model == FadingKind::PathLoss
            && !(self.cell_radius > geometry::REF_DISTANCE
                && self.cell_radius.is_finite())
        {
            bail!(
                "cell_radius {} must be finite and exceed the reference \
                 distance {}",
                self.cell_radius,
                geometry::REF_DISTANCE
            );
        }
        Ok(())
    }
}

/// One client's channel state for one round.
#[derive(Clone, Copy, Debug)]
pub struct ClientChannel {
    /// True channel h_k.
    pub h: C32,
    /// Client's estimate ĥ_k (== h under perfect CSI).
    pub h_est: C32,
    /// Truncated inversion precoder.
    pub precode: Precode,
    /// h_k · ĥ_k⁻¹ if transmitting.
    pub effective_gain: Option<C32>,
}

/// All clients' channel state for one round plus the server noise level.
#[derive(Clone, Debug)]
pub struct RoundChannel {
    pub clients: Vec<ClientChannel>,
    pub snr_db: f32,
}

impl Default for RoundChannel {
    fn default() -> Self {
        RoundChannel::empty()
    }
}

impl RoundChannel {
    /// Empty channel state, ready to be filled by [`draw_into`].
    ///
    /// [`draw_into`]: RoundChannel::draw_into
    pub fn empty() -> Self {
        RoundChannel { clients: Vec::new(), snr_db: 0.0 }
    }

    /// Draw a full round of channels: fading, pilot estimation, precoding.
    pub fn draw(cfg: &ChannelConfig, num_clients: usize, rng: &mut Rng) -> Self {
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        let mut rc = RoundChannel::empty();
        rc.draw_into(cfg, num_clients, rng, &pilot);
        rc
    }

    /// Draw a round of channels into this (reused) value — the zero-alloc
    /// round-loop form.  `pilot` is the broadcast pilot sequence, computed
    /// once per run ([`pilot::pilot_sequence`]); RNG consumption is
    /// identical to [`RoundChannel::draw`].
    pub fn draw_into(
        &mut self,
        cfg: &ChannelConfig,
        num_clients: usize,
        rng: &mut Rng,
        pilot: &[C32],
    ) {
        self.snr_db = cfg.snr_db;
        self.clients.clear();
        for _ in 0..num_clients {
            let h = fading::rayleigh_coeff(rng);
            self.push_from_h(cfg, h, rng, pilot);
        }
    }

    /// Run the estimation + precoding tail of the §III-A pipeline for one
    /// client whose true channel this round is `h`, and append its state.
    /// RNG consumption (pilot reception noise) is identical for every
    /// fading model that feeds this, which is what keeps alternate models
    /// (e.g. AR(1) with ρ = 0) bit-compatible with the i.i.d. path when
    /// their fading draws coincide.
    pub fn push_from_h(
        &mut self,
        cfg: &ChannelConfig,
        h: C32,
        rng: &mut Rng,
        pilot: &[C32],
    ) {
        let h_est = if cfg.perfect_csi {
            h
        } else {
            pilot::estimate(h, pilot, cfg.pilot_noise_var, rng)
        };
        let pc = precode::channel_inversion(h_est, cfg.truncation);
        let effective_gain = precode::effective_gain(h, &pc);
        self.clients.push(ClientChannel { h, h_est, precode: pc, effective_gain });
    }

    /// Indices of clients actually transmitting this round.
    pub fn active(&self) -> Vec<usize> {
        self.clients
            .iter()
            .enumerate()
            .filter(|(_, c)| c.effective_gain.is_some())
            .map(|(i, _)| i)
            .collect()
    }

    /// Server noise variance for a superposed signal of mean power
    /// `signal_power`: var = P / 10^(SNR/10).
    pub fn noise_var(&self, signal_power: f32) -> f32 {
        signal_power / 10f32.powf(self.snr_db / 10.0)
    }
}

/// Convert an SNR in dB to linear.
pub fn db_to_linear(db: f32) -> f32 {
    10f32.powf(db / 10.0)
}

/// Convert a linear power ratio to dB.
pub fn linear_to_db(lin: f32) -> f32 {
    10.0 * lin.log10()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_channel_shapes() {
        let mut rng = Rng::seed_from(21);
        let cfg = ChannelConfig::default();
        let rc = RoundChannel::draw(&cfg, 15, &mut rng);
        assert_eq!(rc.clients.len(), 15);
        for c in &rc.clients {
            match c.precode {
                Precode::Transmit(_) => assert!(c.effective_gain.is_some()),
                Precode::Silenced => assert!(c.effective_gain.is_none()),
            }
        }
    }

    #[test]
    fn perfect_csi_gains_are_one() {
        let mut rng = Rng::seed_from(22);
        let cfg = ChannelConfig { perfect_csi: true, ..Default::default() };
        let rc = RoundChannel::draw(&cfg, 30, &mut rng);
        for c in &rc.clients {
            if let Some(g) = c.effective_gain {
                assert!((g - C32::ONE).abs() < 1e-5, "{g:?}");
            }
        }
    }

    #[test]
    fn imperfect_csi_gains_near_one() {
        let mut rng = Rng::seed_from(23);
        let cfg = ChannelConfig::default();
        let rc = RoundChannel::draw(&cfg, 200, &mut rng);
        let gains: Vec<_> = rc.clients.iter().filter_map(|c| c.effective_gain).collect();
        assert!(!gains.is_empty());
        let mean_err: f32 =
            gains.iter().map(|g| (*g - C32::ONE).abs()).sum::<f32>() / gains.len() as f32;
        assert!(mean_err < 0.2, "mean misalignment {mean_err}");
    }

    #[test]
    fn noise_var_follows_snr() {
        let rc = RoundChannel { clients: vec![], snr_db: 10.0 };
        assert!((rc.noise_var(1.0) - 0.1).abs() < 1e-6);
        let rc = RoundChannel { clients: vec![], snr_db: 30.0 };
        assert!((rc.noise_var(2.0) - 0.002).abs() < 1e-6);
    }

    #[test]
    fn db_conversions_roundtrip() {
        for db in [-10.0f32, 0.0, 5.0, 17.3, 30.0] {
            let lin = db_to_linear(db);
            assert!((linear_to_db(lin) - db).abs() < 1e-4);
        }
    }

    #[test]
    fn determinism_per_seed() {
        let cfg = ChannelConfig::default();
        let mut r1 = Rng::seed_from(7);
        let mut r2 = Rng::seed_from(7);
        let a = RoundChannel::draw(&cfg, 15, &mut r1);
        let b = RoundChannel::draw(&cfg, 15, &mut r2);
        for (x, y) in a.clients.iter().zip(b.clients.iter()) {
            assert_eq!(x.h, y.h);
            assert_eq!(x.h_est, y.h_est);
        }
    }

    #[test]
    fn draw_into_matches_draw_and_reuses_capacity() {
        let cfg = ChannelConfig::default();
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        let mut r1 = Rng::seed_from(31);
        let mut r2 = Rng::seed_from(31);
        let mut reused = RoundChannel::empty();
        for _ in 0..3 {
            let fresh = RoundChannel::draw(&cfg, 15, &mut r1);
            reused.draw_into(&cfg, 15, &mut r2, &pilot);
            assert_eq!(reused.clients.len(), 15);
            for (x, y) in fresh.clients.iter().zip(reused.clients.iter()) {
                assert_eq!(x.h, y.h);
                assert_eq!(x.h_est, y.h_est);
                assert_eq!(x.effective_gain, y.effective_gain);
            }
        }
        // same RNG state afterwards: the two paths consumed identically
        assert_eq!(r1.next_u64(), r2.next_u64());
    }
}
