//! Rayleigh block-fading channel draws.
//!
//! Paper §II-B: "a Single-Input Single-Output (SISO) fading channel between
//! the server and an edge device k, characterized by a Rayleigh distributed
//! random variable h_{s,k} ∈ ℂ".  We model h ~ CN(0, 1): real and imaginary
//! parts i.i.d. N(0, 1/2), so |h| is Rayleigh(σ=1/√2) with E[|h|²] = 1.
//! Block fading: one draw per (client, round), constant across the round's
//! payload — the standard model in the OTA-FL line the paper builds on [3],
//! [5].

use crate::channel::complex::C32;
use crate::rng::Rng;

/// Unit-average-power Rayleigh coefficient.
pub fn rayleigh_coeff(rng: &mut Rng) -> C32 {
    let s = std::f32::consts::FRAC_1_SQRT_2;
    C32::new(rng.normal_f32(0.0, s), rng.normal_f32(0.0, s))
}

/// Per-round channel realisations for all clients.
pub fn draw_round(rng: &mut Rng, clients: usize) -> Vec<C32> {
    (0..clients).map(|_| rayleigh_coeff(rng)).collect()
}

/// Circularly-symmetric complex Gaussian sample with total variance `var`
/// (each component gets var/2) — receiver noise, estimation error.
pub fn cn_sample(rng: &mut Rng, var: f32) -> C32 {
    let s = (var * 0.5).sqrt();
    C32::new(rng.normal_f32(0.0, s), rng.normal_f32(0.0, s))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_average_power() {
        let mut rng = Rng::seed_from(100);
        let n = 200_000;
        let mean_pow: f64 = (0..n)
            .map(|_| rayleigh_coeff(&mut rng).norm_sq() as f64)
            .sum::<f64>()
            / n as f64;
        assert!((mean_pow - 1.0).abs() < 0.01, "E|h|^2 = {mean_pow}");
    }

    #[test]
    fn magnitude_is_rayleigh() {
        // E[|h|] for Rayleigh(1/sqrt(2)) = sqrt(pi)/2 ≈ 0.8862
        let mut rng = Rng::seed_from(101);
        let n = 200_000;
        let mean_mag: f64 = (0..n)
            .map(|_| rayleigh_coeff(&mut rng).abs() as f64)
            .sum::<f64>()
            / n as f64;
        let expect = (std::f64::consts::PI).sqrt() / 2.0;
        assert!((mean_mag - expect).abs() < 0.005, "E|h| = {mean_mag}");
    }

    #[test]
    fn phase_uniform() {
        // quadrant counts should be ~equal
        let mut rng = Rng::seed_from(102);
        let mut quad = [0usize; 4];
        let n = 40_000;
        for _ in 0..n {
            let h = rayleigh_coeff(&mut rng);
            let q = match (h.re >= 0.0, h.im >= 0.0) {
                (true, true) => 0,
                (false, true) => 1,
                (false, false) => 2,
                (true, false) => 3,
            };
            quad[q] += 1;
        }
        for q in quad {
            let frac = q as f64 / n as f64;
            assert!((frac - 0.25).abs() < 0.01, "{quad:?}");
        }
    }

    #[test]
    fn cn_sample_variance() {
        let mut rng = Rng::seed_from(103);
        let var = 0.37f32;
        let n = 100_000;
        let mean_pow: f64 = (0..n)
            .map(|_| cn_sample(&mut rng, var).norm_sq() as f64)
            .sum::<f64>()
            / n as f64;
        assert!((mean_pow - var as f64).abs() < 0.01, "{mean_pow}");
    }

    #[test]
    fn draw_round_shape_and_determinism() {
        let mut a = Rng::seed_from(9);
        let mut b = Rng::seed_from(9);
        let ha = draw_round(&mut a, 15);
        let hb = draw_round(&mut b, 15);
        assert_eq!(ha.len(), 15);
        assert_eq!(ha, hb);
    }
}
