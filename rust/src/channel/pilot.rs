//! Pilot-based least-squares channel estimation (paper Eq. 5).
//!
//! The server broadcasts a predefined pilot sequence `u`; client k receives
//! `y = h_{s,k} · u + n` and estimates
//!
//! ```text
//! ĥ_{s,k} = ⟨y, u⟩ / |u|²  =  h + ⟨n, u⟩ / |u|²
//! ```
//!
//! so the estimation error is CN(0, σ_n² / (L · P_u)) — longer pilots or
//! higher pilot power give better CSI, which directly controls the residual
//! misalignment `h·ĥ⁻¹ - 1` that pollutes OTA aggregation.

use crate::channel::complex::C32;
use crate::channel::fading::cn_sample;
use crate::rng::Rng;

/// A deterministic unit-power Zadoff-Chu-style pilot sequence of length L.
/// (Constant modulus, good autocorrelation; the exact family is irrelevant
/// for LS estimation quality — only length x power matters.)
pub fn pilot_sequence(len: usize) -> Vec<C32> {
    assert!(len > 0, "pilot length must be positive");
    // ZC root 1 over length L (use odd virtual length to avoid degeneracy)
    let l = if len % 2 == 0 { len + 1 } else { len };
    (0..len)
        .map(|n| {
            let phase = -std::f32::consts::PI * (n * (n + 1)) as f32 / l as f32;
            C32::from_polar(1.0, phase)
        })
        .collect()
}

/// Simulate reception of the pilot through channel `h` with per-sample
/// noise variance `noise_var`, and LS-estimate the channel (Eq. 5).
pub fn estimate(h: C32, pilot: &[C32], noise_var: f32, rng: &mut Rng) -> C32 {
    let mut num = C32::ZERO; // ⟨y, u⟩ = Σ y_i · u_i*
    let mut den = 0.0f32; // |u|²
    for &u in pilot {
        let y = h * u + cn_sample(rng, noise_var);
        num = num + y * u.conj();
        den += u.norm_sq();
    }
    num.scale(1.0 / den)
}

/// Theoretical variance of the LS estimation error for a given pilot.
pub fn estimation_error_var(pilot: &[C32], noise_var: f32) -> f32 {
    let energy: f32 = pilot.iter().map(|u| u.norm_sq()).sum();
    noise_var / energy
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pilot_is_unit_modulus() {
        for len in [1usize, 8, 16, 63, 64] {
            let p = pilot_sequence(len);
            assert_eq!(p.len(), len);
            for u in p {
                assert!((u.abs() - 1.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn noiseless_estimation_is_exact() {
        let mut rng = Rng::seed_from(1);
        let h = C32::new(0.8, -0.6);
        let pilot = pilot_sequence(16);
        let est = estimate(h, &pilot, 0.0, &mut rng);
        assert!((est - h).abs() < 1e-5, "{est:?}");
    }

    #[test]
    fn error_variance_matches_theory() {
        let mut rng = Rng::seed_from(2);
        let h = C32::new(0.3, 1.1);
        let pilot = pilot_sequence(8);
        let noise_var = 0.25f32;
        let n = 20_000;
        let mean_err: f64 = (0..n)
            .map(|_| (estimate(h, &pilot, noise_var, &mut rng) - h).norm_sq() as f64)
            .sum::<f64>()
            / n as f64;
        let theory = estimation_error_var(&pilot, noise_var) as f64;
        assert!(
            (mean_err - theory).abs() / theory < 0.05,
            "measured {mean_err}, theory {theory}"
        );
    }

    #[test]
    fn longer_pilot_better_estimate() {
        let mut rng = Rng::seed_from(3);
        let h = C32::new(-0.5, 0.9);
        let noise_var = 0.5f32;
        let mut errs = Vec::new();
        for len in [2usize, 16, 128] {
            let pilot = pilot_sequence(len);
            let n = 5000;
            let e: f64 = (0..n)
                .map(|_| (estimate(h, &pilot, noise_var, &mut rng) - h).norm_sq() as f64)
                .sum::<f64>()
                / n as f64;
            errs.push(e);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "{errs:?}");
    }

    #[test]
    fn estimator_is_unbiased() {
        let mut rng = Rng::seed_from(4);
        let h = C32::new(1.0, -2.0);
        let pilot = pilot_sequence(4);
        let n = 50_000;
        let mut acc = C32::ZERO;
        for _ in 0..n {
            acc = acc + estimate(h, &pilot, 0.3, &mut rng);
        }
        let mean = acc.scale(1.0 / n as f32);
        assert!((mean - h).abs() < 0.02, "{mean:?}");
    }
}
