//! Build-anywhere stand-in for the vendored `xla` crate (PJRT bindings).
//!
//! The full rust_pallas image vendors `xla` (PJRT CPU client + HLO text
//! parser); plain checkouts do not have it, and the crate must still pass
//! `cargo build --release && cargo test -q` there.  This module mirrors the
//! exact API surface `runtime` consumes so the code type-checks unchanged,
//! and every entry point returns a descriptive error at runtime.  All
//! artifact-dependent tests/benches skip before touching PJRT, so the stub
//! is never exercised in CI beyond type-checking.
//!
//! Enabling the real bindings takes two steps, both inside the vendored
//! image: add `xla = { path = ... }` to `[dependencies]` in Cargo.toml
//! (the crate is not on crates.io, so it cannot ship as an optional
//! dependency without breaking offline builds) and build with
//! `--features pjrt`.

use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT execution unavailable: mpota was built without the `pjrt` feature. \
     Inside the rust_pallas image: add the vendored `xla` path dependency to \
     rust/Cargo.toml, run `make artifacts`, and build with `--features pjrt`";

/// PJRT CPU client stand-in.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        // Creating the client succeeds so `Runtime::load` can still parse
        // manifests; execution paths fail with a clear message instead.
        Ok(PjRtClient)
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        bail!(UNAVAILABLE)
    }
}

/// Parsed HLO module stand-in.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        bail!(UNAVAILABLE)
    }
}

/// Computation handle stand-in.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Loaded-executable stand-in.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<ExecBuffer>>> {
        bail!(UNAVAILABLE)
    }
}

/// Device-buffer stand-in returned by `execute`.
pub struct ExecBuffer;

impl ExecBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }
}

/// Host literal stand-in.
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_xs: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        bail!(UNAVAILABLE)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        bail!(UNAVAILABLE)
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        bail!(UNAVAILABLE)
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        bail!(UNAVAILABLE)
    }
}
