//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! PJRT client (the `xla` crate) — the only place rust touches XLA.
//!
//! Design notes:
//! * HLO **text** is the interchange format (jax ≥ 0.5 emits 64-bit
//!   instruction ids in serialized protos which xla_extension 0.5.1
//!   rejects; the text parser reassigns ids).
//! * Executables are compiled once per artifact and cached; compilation is
//!   the expensive step (~1 s per train graph), execution is the hot path.
//! * `PjRtClient` is `Rc`-based (not `Send`), so all PJRT work stays on
//!   the coordinator thread — on this 1-core testbed that is also the
//!   throughput-optimal layout.

pub mod manifest;

// PJRT bindings: the real vendored `xla` crate with `--features pjrt`, an
// API-compatible in-tree stub otherwise (see xla_stub.rs) so the crate
// builds and tests in checkouts without the vendored toolchain.  Public
// because `Runtime::executable` exposes `xla::PjRtLoadedExecutable`.
#[cfg(not(feature = "pjrt"))]
#[path = "xla_stub.rs"]
pub mod xla;

pub use manifest::{Manifest, OtaInfo, VariantInfo};

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::quant::Precision;
use crate::tensor;

/// Result of one train step.
#[derive(Clone, Debug)]
pub struct TrainOutput {
    pub new_theta: Vec<f32>,
    pub loss: f32,
    /// correct predictions within the minibatch
    pub correct: f32,
}

/// Aggregated evaluation over a full dataset.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub samples: usize,
}

/// Cumulative dispatch counters (perf accounting — EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct Counters {
    pub compiles: u64,
    pub compile_secs: f64,
    pub train_steps: u64,
    pub train_secs: f64,
    pub eval_batches: u64,
    pub eval_secs: f64,
}

/// The PJRT-backed executor for all AOT artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    exes: RefCell<BTreeMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    counters: RefCell<Counters>,
}

impl Runtime {
    /// Create a CPU PJRT client and parse the artifact manifest.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)
            .with_context(|| format!("loading manifest from {}", artifacts_dir.display()))?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            exes: RefCell::new(BTreeMap::new()),
            counters: RefCell::new(Counters::default()),
        })
    }

    pub fn counters(&self) -> Counters {
        *self.counters.borrow()
    }

    /// Compile (or fetch cached) the executable for an artifact filename.
    pub fn executable(&self, filename: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(filename) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path_of(filename);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        {
            let mut c = self.counters.borrow_mut();
            c.compiles += 1;
            c.compile_secs += t0.elapsed().as_secs_f64();
        }
        self.exes.borrow_mut().insert(filename.to_string(), exe.clone());
        Ok(exe)
    }

    /// Eagerly compile every artifact a run will need (so the first round
    /// is not polluted by compile latency).
    pub fn warmup(&self, variant: &str, levels: &[Precision]) -> Result<()> {
        let v = self.manifest.variant(variant)?;
        for p in levels {
            let key = format!("train_q{}", p.bits());
            let f = v
                .artifacts
                .get(&key)
                .with_context(|| format!("variant {variant} lacks {key}"))?;
            self.executable(f)?;
        }
        let eval = v.artifacts.get("eval").context("missing eval artifact")?;
        self.executable(eval)?;
        Ok(())
    }

    /// Initial (He-init) flat params shipped with the artifacts.
    pub fn init_params(&self, variant: &str) -> Result<Vec<f32>> {
        let v = self.manifest.variant(variant)?;
        let params = tensor::read_f32_file(&self.manifest.path_of(&v.init))?;
        if params.len() != v.param_count {
            bail!(
                "init blob has {} params, manifest says {}",
                params.len(),
                v.param_count
            );
        }
        Ok(params)
    }

    // ------------------------------------------------------------ training

    /// One SGD minibatch step at `precision` on `variant`.
    ///
    /// `images`: train_batch × H×W×C floats; `labels`: train_batch i32.
    pub fn train_step(
        &self,
        variant: &str,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        let v = self.manifest.variant(variant)?;
        let b = self.manifest.train_batch;
        let (h, w, c) = (
            self.manifest.image[0] as i64,
            self.manifest.image[1] as i64,
            self.manifest.image[2] as i64,
        );
        if theta.len() != v.param_count {
            bail!("theta len {} != param_count {}", theta.len(), v.param_count);
        }
        if images.len() != b * self.manifest.sample_len() || labels.len() != b {
            bail!("batch shape mismatch");
        }
        let key = format!("train_q{}", precision.bits());
        let file = v
            .artifacts
            .get(&key)
            .with_context(|| format!("no train artifact at {precision} for {variant}"))?;
        let exe = self.executable(file)?;

        let t0 = Instant::now();
        let theta_l = xla::Literal::vec1(theta);
        let images_l = xla::Literal::vec1(images).reshape(&[b as i64, h, w, c])?;
        let labels_l = xla::Literal::vec1(labels);
        let lr_l = xla::Literal::vec1(&[lr]);
        let result = exe.execute::<xla::Literal>(&[theta_l, images_l, labels_l, lr_l])?
            [0][0]
            .to_literal_sync()?;
        let (new_theta_l, metrics_l) = result.to_tuple2()?;
        let new_theta = new_theta_l.to_vec::<f32>()?;
        let metrics = metrics_l.to_vec::<f32>()?;
        {
            let mut cnt = self.counters.borrow_mut();
            cnt.train_steps += 1;
            cnt.train_secs += t0.elapsed().as_secs_f64();
        }
        Ok(TrainOutput { new_theta, loss: metrics[0], correct: metrics[1] })
    }

    // ---------------------------------------------------------- evaluation

    /// Evaluate `theta` over a labelled set, handling ragged final batches
    /// with the artifact's per-example weight mask.
    pub fn evaluate(
        &self,
        variant: &str,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
    ) -> Result<EvalResult> {
        let v = self.manifest.variant(variant)?;
        if theta.len() != v.param_count {
            bail!("theta len {} != param_count {}", theta.len(), v.param_count);
        }
        let sample_len = self.manifest.sample_len();
        let n = labels.len();
        if images.len() != n * sample_len {
            bail!("images/labels length mismatch");
        }
        let eb = self.manifest.eval_batch;
        let file = v.artifacts.get("eval").context("missing eval artifact")?;
        let exe = self.executable(file)?;
        let (h, w, c) = (
            self.manifest.image[0] as i64,
            self.manifest.image[1] as i64,
            self.manifest.image[2] as i64,
        );

        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut batch_images = vec![0.0f32; eb * sample_len];
        let mut batch_labels = vec![0i32; eb];
        let mut weights = vec![0.0f32; eb];
        let mut off = 0usize;
        while off < n {
            let take = (n - off).min(eb);
            batch_images[..take * sample_len]
                .copy_from_slice(&images[off * sample_len..(off + take) * sample_len]);
            batch_labels[..take].copy_from_slice(&labels[off..off + take]);
            for i in 0..eb {
                weights[i] = if i < take { 1.0 } else { 0.0 };
                if i >= take {
                    batch_labels[i] = 0;
                }
            }
            if take < eb {
                batch_images[take * sample_len..].fill(0.0);
            }
            let t0 = Instant::now();
            let theta_l = xla::Literal::vec1(theta);
            let images_l =
                xla::Literal::vec1(&batch_images).reshape(&[eb as i64, h, w, c])?;
            let labels_l = xla::Literal::vec1(&batch_labels);
            let weights_l = xla::Literal::vec1(&weights);
            let result = exe
                .execute::<xla::Literal>(&[theta_l, images_l, labels_l, weights_l])?
                [0][0]
                .to_literal_sync()?;
            let metrics = result.to_tuple1()?.to_vec::<f32>()?;
            loss_sum += metrics[0] as f64;
            correct += metrics[1] as f64;
            {
                let mut cnt = self.counters.borrow_mut();
                cnt.eval_batches += 1;
                cnt.eval_secs += t0.elapsed().as_secs_f64();
            }
            off += take;
        }
        Ok(EvalResult {
            loss: loss_sum / n as f64,
            accuracy: correct / n as f64,
            samples: n,
        })
    }

    /// Per-layer fake-quantization of a variant's flat model (paper
    /// §III-B; used for re-quantization of the broadcast/global model and
    /// Table-I PTQ).
    pub fn quantize_model(
        &self,
        variant: &str,
        theta: &[f32],
        p: crate::quant::Precision,
        r: crate::quant::Rounding,
    ) -> Result<Vec<f32>> {
        self.quantize_model_par(variant, theta, p, r, 1)
    }

    /// Chunk-parallel form of [`quantize_model`] using the fused
    /// quantize-into kernels; bit-identical for any `threads` (kernels
    /// determinism contract).
    pub fn quantize_model_par(
        &self,
        variant: &str,
        theta: &[f32],
        p: crate::quant::Precision,
        r: crate::quant::Rounding,
        threads: usize,
    ) -> Result<Vec<f32>> {
        let v = self.manifest.variant(variant)?;
        if theta.len() != v.param_count {
            bail!("theta len {} != param_count {}", theta.len(), v.param_count);
        }
        let mut out = vec![0.0f32; theta.len()];
        crate::quant::fake_quant_layout_into(&mut out, theta, &v.layout, p, r, threads);
        Ok(out)
    }

    // ---------------------------------------------------------------- OTA

    /// Execute the L1 OTA-superposition artifact on one chunk.
    /// `x` is K×chunk payload rows; returns (re, im) of the superposition.
    /// Used to cross-validate the rust `ota::analog` hot path against the
    /// Pallas kernel lowered into HLO.
    pub fn ota_chunk(
        &self,
        x: &[f32],
        gains_re: &[f32],
        gains_im: &[f32],
        noise_re: &[f32],
        noise_im: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let k = self.manifest.ota.clients;
        let chunk = self.manifest.ota.chunk;
        if x.len() != k * chunk
            || gains_re.len() != k
            || gains_im.len() != k
            || noise_re.len() != chunk
            || noise_im.len() != chunk
        {
            bail!("ota chunk shape mismatch");
        }
        let exe = self.executable(&self.manifest.ota.artifact.clone())?;
        let x_l = xla::Literal::vec1(x).reshape(&[k as i64, chunk as i64])?;
        let result = exe.execute::<xla::Literal>(&[
            x_l,
            xla::Literal::vec1(gains_re),
            xla::Literal::vec1(gains_im),
            xla::Literal::vec1(noise_re),
            xla::Literal::vec1(noise_im),
        ])?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != 2 {
            bail!("ota artifact returned {} outputs, expected 2", parts.len());
        }
        Ok((parts[0].to_vec::<f32>()?, parts[1].to_vec::<f32>()?))
    }
}
