//! `artifacts/manifest.json` — the contract between the python compile
//! path and the rust runtime.
//!
//! Written once by `python/compile/aot.py`; rust never re-derives any
//! shape or layout, it only reads them from here.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::json;
use crate::tensor::ParamLayout;

/// One model variant's artifact set.
#[derive(Clone, Debug)]
pub struct VariantInfo {
    pub name: String,
    pub param_count: usize,
    pub layout: ParamLayout,
    /// artifact key ("train_q8", "eval") -> filename
    pub artifacts: BTreeMap<String, String>,
    /// He-init flat params blob filename.
    pub init: String,
    /// Forward-pass MACs per sample (energy model input).
    pub macs_per_sample: u64,
}

impl VariantInfo {
    /// Precision levels this variant has train artifacts for.
    pub fn train_levels(&self) -> Vec<u8> {
        let mut levels: Vec<u8> = self
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("train_q"))
            .filter_map(|b| b.parse().ok())
            .collect();
        levels.sort_by(|a, b| b.cmp(a));
        levels
    }
}

/// OTA artifact description.
#[derive(Clone, Debug)]
pub struct OtaInfo {
    pub artifact: String,
    pub clients: usize,
    pub chunk: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub image: Vec<usize>,
    pub classes: usize,
    pub padded_classes: usize,
    pub flagship: String,
    pub train_levels: Vec<u8>,
    pub variants: BTreeMap<String, VariantInfo>,
    pub ota: OtaInfo,
    pub goldens: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let v = json::parse_file(&dir.join("manifest.json"))?;
        let version = v.req("version")?.as_usize()?;
        if version != 1 {
            bail!("manifest version {version} unsupported (expected 1)");
        }
        let mut variants = BTreeMap::new();
        for (name, info) in v.req("variants")?.as_object()? {
            let layout = ParamLayout::from_manifest(info.req("params")?)
                .with_context(|| format!("variant {name} params"))?;
            let param_count = info.req("param_count")?.as_usize()?;
            if layout.total != param_count {
                bail!(
                    "variant {name}: layout total {} != param_count {param_count}",
                    layout.total
                );
            }
            let mut artifacts = BTreeMap::new();
            for (k, f) in info.req("artifacts")?.as_object()? {
                artifacts.insert(k.clone(), f.as_str()?.to_string());
            }
            variants.insert(
                name.clone(),
                VariantInfo {
                    name: name.clone(),
                    param_count,
                    layout,
                    artifacts,
                    init: info.req("init")?.as_str()?.to_string(),
                    macs_per_sample: info.req("macs_per_sample")?.as_usize()? as u64,
                },
            );
        }
        let ota_v = v.req("ota")?;
        Ok(Manifest {
            dir: dir.to_path_buf(),
            train_batch: v.req("train_batch")?.as_usize()?,
            eval_batch: v.req("eval_batch")?.as_usize()?,
            image: v.req("image")?.as_usize_vec()?,
            classes: v.req("classes")?.as_usize()?,
            padded_classes: v.req("padded_classes")?.as_usize()?,
            flagship: v.req("flagship")?.as_str()?.to_string(),
            train_levels: v
                .req("train_levels")?
                .as_usize_vec()?
                .into_iter()
                .map(|b| b as u8)
                .collect(),
            variants,
            ota: OtaInfo {
                artifact: ota_v.req("artifact")?.as_str()?.to_string(),
                clients: ota_v.req("clients")?.as_usize()?,
                chunk: ota_v.req("chunk")?.as_usize()?,
            },
            goldens: v.req("goldens")?.as_str()?.to_string(),
        })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantInfo> {
        self.variants
            .get(name)
            .with_context(|| format!("variant '{name}' not in manifest"))
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, filename: &str) -> PathBuf {
        self.dir.join(filename)
    }

    /// Image elements per sample.
    pub fn sample_len(&self) -> usize {
        self.image.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture_dir() -> PathBuf {
        let dir = std::env::temp_dir().join("mpota_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "version": 1, "train_batch": 32, "eval_batch": 64,
              "image": [32, 32, 3], "classes": 43, "padded_classes": 64,
              "flagship": "base", "train_levels": [32, 8],
              "ota": {"artifact": "ota.hlo.txt", "clients": 15, "chunk": 1024},
              "goldens": "goldens.json",
              "variants": {
                "base": {
                  "param_count": 10,
                  "params": [["w", [2, 3]], ["b", [4]]],
                  "artifacts": {"train_q32": "t32.hlo", "train_q8": "t8.hlo",
                                "eval": "e.hlo"},
                  "init": "base_init.f32.bin",
                  "macs_per_sample": 1000
                }
              }
            }"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn loads_and_validates() {
        let m = Manifest::load(&fixture_dir()).unwrap();
        assert_eq!(m.train_batch, 32);
        assert_eq!(m.sample_len(), 3072);
        let v = m.variant("base").unwrap();
        assert_eq!(v.param_count, 10);
        assert_eq!(v.train_levels(), vec![32, 8]);
        assert_eq!(v.layout.entry("b").unwrap().offset, 6);
        assert!(m.variant("nope").is_err());
        assert!(m.path_of("x.hlo").ends_with("mpota_manifest_test/x.hlo"));
    }

    #[test]
    fn rejects_param_count_mismatch() {
        let dir = std::env::temp_dir().join("mpota_manifest_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "train_batch": 1, "eval_batch": 1,
                "image": [2], "classes": 1, "padded_classes": 1,
                "flagship": "x", "train_levels": [],
                "ota": {"artifact": "o", "clients": 1, "chunk": 1},
                "goldens": "g",
                "variants": {"x": {"param_count": 99,
                  "params": [["w", [2]]], "artifacts": {}, "init": "i",
                  "macs_per_sample": 1}}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let dir = std::env::temp_dir().join("mpota_manifest_ver");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 2}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
