//! Digital orthogonal-uplink baseline (conventional FL aggregation).
//!
//! Each client transmits its quantized update bit-exactly in its own
//! orthogonal slot (TDMA; error-free link-layer assumed, as is standard
//! when comparing aggregation *architectures*).  The server must then
//! perform per-client PRECISION CONVERSION — decode each client's format
//! (affine codes at its scale/zero-point, or truncated floats) back to f32
//! — before it can average.  This conversion step, and the K× channel
//! uses, are exactly the overheads the paper's analog scheme eliminates.

use crate::kernels::packed::RowKind;
use crate::kernels::{par, PackedPlane, PayloadPlane};
use crate::ota::AggregateStats;
use crate::quant::{fixed, float, Format, Precision};
use crate::tensor;

/// What one client puts on the air in the digital baseline.
#[derive(Clone, Debug)]
pub enum DigitalFrame {
    /// Affine integer codes + the (scale, zero-point) header.
    Fixed {
        codes: Vec<u32>,
        params: fixed::AffineParams,
        bits: u8,
    },
    /// Truncated floats transmitted as raw 32-bit words with the dropped
    /// mantissa bits elided: b bits on the wire per value.
    Float { words: Vec<u32>, bits: u8 },
}

impl DigitalFrame {
    /// Encode a payload at the client's precision.
    pub fn encode(payload: &[f32], p: Precision) -> Self {
        match p.format() {
            Format::FixedPoint => {
                let (codes, params) = fixed::encode_tensor(payload, p.bits());
                DigitalFrame::Fixed { codes, params, bits: p.bits() }
            }
            Format::FloatTrunc | Format::Identity => {
                let mask = float::mask(p.bits()).expect("validated level");
                DigitalFrame::Float {
                    words: payload.iter().map(|v| v.to_bits() & mask).collect(),
                    bits: p.bits(),
                }
            }
        }
    }

    /// Server-side decode back to decimal values (precision conversion).
    pub fn decode(&self) -> Vec<f32> {
        match self {
            DigitalFrame::Fixed { codes, params, .. } => {
                fixed::decode_tensor(codes, *params)
            }
            DigitalFrame::Float { words, .. } => {
                words.iter().map(|&w| f32::from_bits(w)).collect()
            }
        }
    }

    /// Payload bits on the wire (header ignored: 64 bits amortised away).
    pub fn bits_on_wire(&self) -> u64 {
        match self {
            DigitalFrame::Fixed { codes, bits, .. } => {
                codes.len() as u64 * *bits as u64
            }
            DigitalFrame::Float { words, bits } => words.len() as u64 * *bits as u64,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            DigitalFrame::Fixed { codes, .. } => codes.len(),
            DigitalFrame::Float { words, .. } => words.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Full digital-baseline aggregation: encode at each client's precision,
/// transmit orthogonally, decode and average at the server.
///
/// `payloads[k]` are the RAW (pre-quantization) client updates; encoding
/// performs the client-side quantization, so the decoded values match what
/// the analog path would transmit as decimals.
pub fn aggregate(
    payloads: &[Vec<f32>],
    precisions: &[Precision],
) -> (Vec<f32>, AggregateStats) {
    assert_eq!(payloads.len(), precisions.len());
    let n = payloads.first().map(|p| p.len()).unwrap_or(0);
    let k = payloads.len();
    let mut acc = vec![0.0f32; n];
    let mut stats = AggregateStats::default();
    for (payload, &p) in payloads.iter().zip(precisions.iter()) {
        assert_eq!(payload.len(), n, "payload length mismatch");
        let frame = DigitalFrame::encode(payload, p);
        stats.bits_transmitted += frame.bits_on_wire();
        // Orthogonal slots: every client costs its own n channel uses.
        stats.channel_uses += n as u64;
        let decoded = frame.decode();
        tensor::axpy(&mut acc, 1.0, &decoded);
    }
    if k > 0 {
        tensor::scale(&mut acc, 1.0 / k as f32);
    }
    stats.participants = k;
    (acc, stats)
}

/// Round-loop form of the digital baseline: encode→decode is fused per
/// element straight out of the payload plane into `out` (no materialised
/// code or decode vectors — zero heap allocation once `out` is warm), the
/// element axis chunk-parallel per client sweep.
///
/// Bit-identical to [`aggregate`] on the same payloads for any `threads`:
/// `decode(encode(v))` is exactly the fake-quant value the frame
/// round-trip produces, and the accumulation order over clients is the
/// same ascending sweep.
pub fn aggregate_plane_into(
    plane: &PayloadPlane,
    precisions: &[Precision],
    out: &mut Vec<f32>,
    threads: usize,
) -> AggregateStats {
    let n = plane.n();
    let k = plane.k();
    out.resize(n, 0.0);
    out.fill(0.0);
    let mut stats = AggregateStats::default();
    accumulate_plane_into(plane, precisions, out.as_mut_slice(), threads, &mut stats);
    if k > 0 {
        tensor::scale_par(out, 1.0 / k as f32, threads);
    }
    stats.participants = k;
    stats
}

/// Accumulate ONE SHARD of the digital baseline into `out` — NO reset, NO
/// final scale: per row, fused encode→decode at the row's precision
/// (element-parallel) added onto the partial sum, plus wire-stats accrual
/// (channel uses, bits on wire) into `stats`.
///
/// The streaming form of [`aggregate_plane_into`]: shards accumulated in
/// slot order over a pre-zeroed `out`, followed by one `1/K_total` scale,
/// reproduce the one-shot path bit-for-bit for every shard partition (per
/// element, the same decoded contributions arrive in the same ascending
/// client order).
// mpota-lint: zero-alloc-hot
pub fn accumulate_plane_into(
    plane: &PayloadPlane,
    precisions: &[Precision],
    out: &mut [f32],
    threads: usize,
    stats: &mut AggregateStats,
) {
    accumulate_plane_masked_into(plane, precisions, None, out, threads, stats);
}

/// Masked form of [`accumulate_plane_into`] for partial-participation
/// (straggler/dropout) rounds: rows with `included[r] == false` are
/// skipped entirely — never read or decoded, and they accrue NO channel
/// uses and NO bits (an excluded client transmits nothing in its
/// orthogonal slot).  `None` is the everyone-transmits path, identical to
/// the unmasked entry instruction for instruction.
// mpota-lint: zero-alloc-hot
pub fn accumulate_plane_masked_into(
    plane: &PayloadPlane,
    precisions: &[Precision],
    included: Option<&[bool]>,
    out: &mut [f32],
    threads: usize,
    stats: &mut AggregateStats,
) {
    assert_eq!(plane.k(), precisions.len());
    if let Some(mask) = included {
        assert_eq!(mask.len(), plane.k(), "participation mask length mismatch");
    }
    let n = plane.n();
    assert_eq!(out.len(), n, "accumulator length mismatch");
    for (row_i, &p) in precisions.iter().enumerate() {
        if included.map_or(false, |mask| !mask[row_i]) {
            continue;
        }
        let row = plane.row(row_i);
        stats.channel_uses += n as u64;
        stats.bits_transmitted += n as u64 * p.bits() as u64;
        match p.format() {
            Format::FixedPoint => {
                let ap = fixed::params(row, p.bits());
                let max_code = p.max_code();
                par::par_chunks_mut(threads, out, |off, chunk| {
                    let r = &row[off..off + chunk.len()];
                    for (o, &v) in chunk.iter_mut().zip(r.iter()) {
                        *o += fixed::decode(fixed::encode(v, ap, max_code), ap);
                    }
                });
            }
            Format::FloatTrunc | Format::Identity => {
                let mask = float::mask(p.bits()).expect("validated level");
                par::par_chunks_mut(threads, out, |off, chunk| {
                    let r = &row[off..off + chunk.len()];
                    for (o, &v) in chunk.iter_mut().zip(r.iter()) {
                        *o += f32::from_bits(v.to_bits() & mask);
                    }
                });
            }
        }
    }
}

/// [`accumulate_plane_masked_into`] over a bit-packed shard.  The packed
/// rows hold the TRANSMITTED codes; the server-side precision conversion
/// runs on the decoded decimals exactly as the f32 path runs on a
/// fake-quantized row: fixed-point rows re-derive an affine header from
/// the decoded values' min/max (the same double-quantization the f32
/// streaming path performs on its staged rows — so `packed_planes` on and
/// off stay bit-identical), float rows re-mask (idempotent on the stored
/// truncated bits).  No intermediate f32 row is materialized.
// mpota-lint: zero-alloc-hot
pub fn accumulate_packed_masked_into(
    packed: &PackedPlane,
    precisions: &[Precision],
    included: Option<&[bool]>,
    out: &mut [f32],
    threads: usize,
    stats: &mut AggregateStats,
) {
    assert_eq!(packed.k(), precisions.len());
    if let Some(mask) = included {
        assert_eq!(mask.len(), packed.k(), "participation mask length mismatch");
    }
    let n = packed.n();
    assert_eq!(out.len(), n, "accumulator length mismatch");
    for (row_i, &p) in precisions.iter().enumerate() {
        if included.map_or(false, |mask| !mask[row_i]) {
            continue;
        }
        let row = packed.row(row_i);
        stats.channel_uses += n as u64;
        stats.bits_transmitted += n as u64 * p.bits() as u64;
        match p.format() {
            Format::FixedPoint => {
                // exact min/max over the decoded decimals, in the same
                // ascending element order as `fixed::params` on a slice
                let mut lo = f32::INFINITY;
                let mut hi = f32::NEG_INFINITY;
                for i in 0..n {
                    let v = row.get(i);
                    lo = lo.min(v);
                    hi = hi.max(v);
                }
                if n == 0 {
                    lo = 0.0;
                    hi = 0.0;
                }
                let ap = fixed::params_from_range(lo, hi, p.bits());
                let max_code = p.max_code();
                par::par_chunks_mut(threads, out, |off, chunk| {
                    for (j, o) in chunk.iter_mut().enumerate() {
                        let v = row.get(off + j);
                        *o += fixed::decode(fixed::encode(v, ap, max_code), ap);
                    }
                });
            }
            Format::FloatTrunc | Format::Identity => {
                debug_assert!(matches!(row.kind, RowKind::Trunc16 | RowKind::Words));
                let mask = float::mask(p.bits()).expect("validated level");
                par::par_chunks_mut(threads, out, |off, chunk| {
                    for (j, o) in chunk.iter_mut().enumerate() {
                        let v = row.get(off + j);
                        *o += f32::from_bits(v.to_bits() & mask);
                    }
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::fake_quant;
    use crate::rng::Rng;

    fn payload(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect()
    }

    #[test]
    fn frame_roundtrip_equals_fake_quant() {
        for bits in [32u8, 24, 16, 12, 8, 6, 4, 3, 2] {
            let p = Precision::of(bits);
            let w = payload(333, bits as u64);
            let frame = DigitalFrame::encode(&w, p);
            let decoded = frame.decode();
            let expect = fake_quant(&w, p);
            assert_eq!(decoded, expect, "bits={bits}");
        }
    }

    #[test]
    fn bits_on_wire_scale_with_precision() {
        let w = payload(1000, 1);
        let f32b = DigitalFrame::encode(&w, Precision::of(32)).bits_on_wire();
        let f4b = DigitalFrame::encode(&w, Precision::of(4)).bits_on_wire();
        assert_eq!(f32b, 32_000);
        assert_eq!(f4b, 4_000);
    }

    #[test]
    fn aggregate_is_mean_of_quantized() {
        let raw: Vec<Vec<f32>> = (0..3).map(|i| payload(200, 40 + i)).collect();
        let ps = vec![Precision::of(8), Precision::of(4), Precision::of(32)];
        let (agg, stats) = aggregate(&raw, &ps);
        let mut want = vec![0.0f32; 200];
        for (w, &p) in raw.iter().zip(ps.iter()) {
            let q = fake_quant(w, p);
            tensor::axpy(&mut want, 1.0 / 3.0, &q);
        }
        assert!(tensor::max_abs_diff(&agg, &want) < 1e-6);
        assert_eq!(stats.participants, 3);
        // K x n channel uses (vs n for OTA)
        assert_eq!(stats.channel_uses, 600);
        assert_eq!(stats.bits_transmitted, (8 + 4 + 32) * 200);
    }

    #[test]
    fn empty_inputs() {
        let (agg, stats) = aggregate(&[], &[]);
        assert!(agg.is_empty());
        assert_eq!(stats.participants, 0);
    }

    #[test]
    fn sharded_accumulation_matches_one_shot_bitwise() {
        let raw: Vec<Vec<f32>> = (0..6).map(|i| payload(20_000, 80 + i)).collect();
        let ps: Vec<Precision> =
            [32u8, 24, 16, 12, 8, 4].iter().map(|&b| Precision::of(b)).collect();
        let plane = PayloadPlane::from_rows(&raw);
        for threads in [1usize, 4] {
            let mut want = Vec::new();
            let want_stats = aggregate_plane_into(&plane, &ps, &mut want, threads);
            for shard in [1usize, 2, 4, 6] {
                let mut acc = vec![0.0f32; 20_000];
                let mut stats = AggregateStats::default();
                let mut lo = 0usize;
                while lo < 6 {
                    let hi = (lo + shard).min(6);
                    let sp = PayloadPlane::from_rows(&raw[lo..hi]);
                    accumulate_plane_into(&sp, &ps[lo..hi], &mut acc, threads, &mut stats);
                    lo = hi;
                }
                tensor::scale_par(&mut acc, 1.0 / 6.0f32, threads);
                stats.participants = 6;
                assert_eq!(acc, want, "shard={shard} threads={threads}");
                assert_eq!(stats.channel_uses, want_stats.channel_uses);
                assert_eq!(stats.bits_transmitted, want_stats.bits_transmitted);
            }
        }
    }

    #[test]
    fn masked_accumulation_skips_rows_and_their_wire_stats() {
        let raw: Vec<Vec<f32>> = (0..5).map(|i| payload(400, 30 + i)).collect();
        let ps: Vec<Precision> =
            [32u8, 16, 8, 8, 4].iter().map(|&b| Precision::of(b)).collect();
        let mask = [true, false, true, true, false];
        let plane = PayloadPlane::from_rows(&raw);
        let mut acc = vec![0.0f32; 400];
        let mut stats = AggregateStats::default();
        accumulate_plane_masked_into(&plane, &ps, Some(&mask), &mut acc, 1, &mut stats);

        // reference: only the included rows, as their own plane
        let sub: Vec<Vec<f32>> = raw
            .iter()
            .zip(mask.iter())
            .filter(|(_, &m)| m)
            .map(|(r, _)| r.clone())
            .collect();
        let sub_ps: Vec<Precision> = ps
            .iter()
            .zip(mask.iter())
            .filter(|(_, &m)| m)
            .map(|(&p, _)| p)
            .collect();
        let mut want = vec![0.0f32; 400];
        let mut want_stats = AggregateStats::default();
        accumulate_plane_into(
            &PayloadPlane::from_rows(&sub),
            &sub_ps,
            &mut want,
            1,
            &mut want_stats,
        );
        assert_eq!(acc, want);
        assert_eq!(stats.channel_uses, want_stats.channel_uses);
        assert_eq!(stats.channel_uses, 3 * 400);
        assert_eq!(stats.bits_transmitted, (32 + 8 + 8) * 400);
    }

    #[test]
    fn packed_accumulation_matches_staged_f32_accumulation_bitwise() {
        // the packed-planes parity contract: a shard packed from RAW rows
        // must accumulate exactly what the f32 streaming path accumulates
        // from the same rows staged through fake_quant (both re-derive
        // the server-side affine header from the received decimals)
        let raw: Vec<Vec<f32>> = (0..9).map(|i| payload(5_000, 60 + i)).collect();
        let ps: Vec<Precision> = [32u8, 24, 16, 12, 8, 6, 4, 3, 2]
            .iter()
            .map(|&b| Precision::of(b))
            .collect();
        let mut packed = PackedPlane::new();
        packed.reset(&ps, 5_000);
        let mut staged = PayloadPlane::zeros(9, 5_000);
        for (r, (w, &p)) in raw.iter().zip(ps.iter()).enumerate() {
            packed.pack_row(r, w);
            staged.row_mut(r).copy_from_slice(&fake_quant(w, p));
        }
        let mask = [true, true, false, true, true, true, false, true, true];
        for threads in [1usize, 4] {
            let mut want = vec![0.0f32; 5_000];
            let mut want_stats = AggregateStats::default();
            accumulate_plane_masked_into(
                &staged, &ps, Some(&mask), &mut want, threads, &mut want_stats,
            );
            let mut got = vec![0.0f32; 5_000];
            let mut stats = AggregateStats::default();
            accumulate_packed_masked_into(
                &packed, &ps, Some(&mask), &mut got, threads, &mut stats,
            );
            let same =
                got.iter().zip(want.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(same, "packed digital diverged threads={threads}");
            assert_eq!(stats.channel_uses, want_stats.channel_uses);
            assert_eq!(stats.bits_transmitted, want_stats.bits_transmitted);
        }
    }

    #[test]
    fn plane_path_matches_frame_path_bitwise() {
        let raw: Vec<Vec<f32>> = (0..6).map(|i| payload(20_000, 70 + i)).collect();
        let ps: Vec<Precision> =
            [32u8, 24, 16, 12, 8, 4].iter().map(|&b| Precision::of(b)).collect();
        let (want, want_stats) = aggregate(&raw, &ps);
        let plane = PayloadPlane::from_rows(&raw);
        let mut out = Vec::new();
        for threads in [1usize, 4] {
            let stats = aggregate_plane_into(&plane, &ps, &mut out, threads);
            assert_eq!(out, want, "threads={threads}");
            assert_eq!(stats.participants, want_stats.participants);
            assert_eq!(stats.channel_uses, want_stats.channel_uses);
            assert_eq!(stats.bits_transmitted, want_stats.bits_transmitted);
        }
    }
}
