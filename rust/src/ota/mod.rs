//! Over-the-air aggregation — the paper's core mechanism — plus the
//! conventional digital baseline it is compared against.
//!
//! * [`analog`] — multi-precision amplitude-modulated superposition
//!   (paper Fig. 2b / Alg. 1 steps 3-4): every client's quantized update is
//!   converted to its decimal values (fake-quant output), precoded with
//!   ĥ⁻¹, and summed *in the channel* with AWGN at the configured SNR.
//!   One channel use per parameter regardless of K — the bandwidth win —
//!   and precision-heterogeneity is free because superposition happens on
//!   real amplitudes, not on digital constellations (Eq. 3's obstruction).
//! * [`digital`] — orthogonal conventional uplink: each client transmits
//!   its integer quantization codes bit-exactly in its own slot; the server
//!   de-quantizes to f32 and averages.  K× the channel uses, plus explicit
//!   per-client precision conversion at the server (the overhead the paper
//!   eliminates).
//!
//! Both paths expose two entries: a convenience form over `&[Vec<f32>]`
//! (tests/examples) and the round-loop `*_plane_into` form over a
//! contiguous [`crate::kernels::PayloadPlane`] with caller-owned scratch —
//! fused, chunk-parallel, allocation-free once warm, and bit-identical to
//! the convenience form for any thread count (kernels-layer determinism
//! contract).

pub mod analog;
pub mod digital;

/// Diagnostics shared by both aggregation paths.
#[derive(Clone, Debug, Default)]
pub struct AggregateStats {
    /// Clients that actually contributed this round.
    pub participants: usize,
    /// Mean squared error of the aggregate vs the noise-free ideal mean of
    /// the *same participants'* payloads (0 for digital).
    pub mse_vs_ideal: f64,
    /// Mean received-signal power before noise injection.
    pub signal_power: f64,
    /// Injected noise variance (analog only).
    pub noise_var: f64,
    /// Channel uses consumed (symbols on the uplink).
    pub channel_uses: u64,
    /// Payload bits moved (digital only; analog is analog).
    pub bits_transmitted: u64,
}

#[cfg(test)]
mod tests {
    use super::analog;
    use super::digital;
    use crate::channel::{ChannelConfig, RoundChannel};
    use crate::quant::Precision;
    use crate::rng::Rng;

    /// Cross-check: at very high SNR with perfect CSI, analog OTA and the
    /// digital baseline agree to within the quantization step.
    #[test]
    fn analog_and_digital_agree_at_high_snr() {
        let mut rng = Rng::seed_from(99);
        let n = 512;
        let payloads: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let precisions = vec![Precision::of(8); 4];
        let quantized: Vec<Vec<f32>> = payloads
            .iter()
            .zip(&precisions)
            .map(|(p, q)| crate::quant::fake_quant(p, *q))
            .collect();

        let cfg = ChannelConfig { snr_db: 80.0, perfect_csi: true, ..Default::default() };
        let rc = RoundChannel::draw(&cfg, 4, &mut rng);
        let (a, _) = analog::aggregate(&quantized, &rc, &mut rng);
        let (d, _) = digital::aggregate(&payloads, &precisions);

        let max_diff = crate::tensor::max_abs_diff(&a, &d);
        assert!(max_diff < 1e-3, "analog vs digital max diff {max_diff}");
    }
}
