//! Analog amplitude-modulated OTA superposition (paper Alg. 1 steps 3-4).
//!
//! Client k's transmitted baseband is `ĥ_k⁻¹ · x_k` (Eq. 6) where `x_k` is
//! the DECIMAL value vector of its quantized update — the multi-precision
//! modulation trick: a 4-bit client and a 32-bit client both put plain real
//! amplitudes on the carrier (Eq. 4), so the channel's superposition *is*
//! the sum, with no common digital constellation needed (Eq. 3).
//!
//! The server receives `Σ_k h_k ĥ_k⁻¹ x_k + n` (Eq. 2), takes the real
//! part (the payload is real; the imaginary part carries only misalignment
//! and noise) and scales by 1/K_active to obtain the model average
//! (Alg. 1 step 4, adjusted for truncation-silenced clients).
//!
//! This mirrors the L1 Pallas kernel `kernels/ota.py`; the rust path is the
//! request-path implementation, the artifact is used by `runtime` tests to
//! cross-validate the two.

use crate::channel::{RoundChannel, C32};
use crate::ota::AggregateStats;
use crate::rng::Rng;
use crate::tensor;

/// Superpose client payloads through the round's channel realisation.
///
/// `payloads[k]` is client k's decimal payload (all equal length N).
/// Returns the aggregated MEAN vector (length N) and diagnostics.
///
/// Silenced clients (truncated inversion) contribute nothing; the mean is
/// over actual participants.  If every client is silenced the aggregate is
/// all-zeros with `participants == 0` — the caller (coordinator) treats
/// that as "round lost" and re-broadcasts the previous global model.
pub fn aggregate(
    payloads: &[Vec<f32>],
    round: &RoundChannel,
    rng: &mut Rng,
) -> (Vec<f32>, AggregateStats) {
    assert_eq!(
        payloads.len(),
        round.clients.len(),
        "one payload per client required"
    );
    let n = payloads.first().map(|p| p.len()).unwrap_or(0);
    for (k, p) in payloads.iter().enumerate() {
        assert_eq!(p.len(), n, "payload {k} length mismatch");
    }

    // --- superposition: y = Σ_k g_k · x_k  (complex accumulate) ---------
    let mut y_re = vec![0.0f32; n];
    let mut y_im = vec![0.0f32; n];
    let mut participants = 0usize;
    let mut ideal = vec![0.0f32; n]; // noise-free, misalignment-free mean
    for (k, payload) in payloads.iter().enumerate() {
        if let Some(g) = round.clients[k].effective_gain {
            tensor::axpy(&mut y_re, g.re, payload);
            tensor::axpy(&mut y_im, g.im, payload);
            tensor::axpy(&mut ideal, 1.0, payload);
            participants += 1;
        }
    }

    let mut stats = AggregateStats {
        participants,
        channel_uses: n as u64,
        ..Default::default()
    };
    if participants == 0 {
        return (vec![0.0f32; n], stats);
    }

    // --- receiver noise calibrated to received signal power -------------
    let signal_power = (tensor::sq_norm(&y_re) + tensor::sq_norm(&y_im)) / n as f64;
    let noise_var = round.noise_var(signal_power as f32);
    stats.signal_power = signal_power;
    stats.noise_var = noise_var as f64;
    if noise_var > 0.0 {
        // CN(0, var): var/2 per component.  Noise is generated into a
        // reused buffer with the pairwise Box-Muller fill (§Perf: 26%
        // faster than per-element draws on this path).
        let std = (noise_var * 0.5).sqrt();
        rng.add_normal(&mut y_re, std);
        rng.add_normal(&mut y_im, std);
    }

    // --- demodulate: real part, scale to the mean ------------------------
    let scale = 1.0 / participants as f32;
    tensor::scale(&mut y_re, scale);
    tensor::scale(&mut ideal, scale);
    stats.mse_vs_ideal = tensor::mse(&y_re, &ideal);
    (y_re, stats)
}

/// Effective-gain view for the OTA artifact (`ota_k15.hlo.txt`): the PJRT
/// path takes (gains_re, gains_im) vectors with zeros for silenced clients.
pub fn gain_vectors(round: &RoundChannel) -> (Vec<f32>, Vec<f32>) {
    let mut re = Vec::with_capacity(round.clients.len());
    let mut im = Vec::with_capacity(round.clients.len());
    for c in &round.clients {
        let g = c.effective_gain.unwrap_or(C32::ZERO);
        re.push(g.re);
        im.push(g.im);
    }
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::ChannelConfig;

    fn payloads(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from(seed);
        (0..k)
            .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect()
    }

    fn perfect_round(k: usize, snr_db: f32) -> RoundChannel {
        let mut rng = Rng::seed_from(1);
        let cfg = ChannelConfig { snr_db, perfect_csi: true, ..Default::default() };
        RoundChannel::draw(&cfg, k, &mut rng)
    }

    #[test]
    fn noiseless_perfect_csi_recovers_exact_mean() {
        let ps = payloads(5, 300, 2);
        let rc = perfect_round(5, 200.0); // effectively noise-free
        let mut rng = Rng::seed_from(3);
        let (agg, stats) = aggregate(&ps, &rc, &mut rng);
        assert_eq!(stats.participants, 5);
        let mut want = vec![0.0f32; 300];
        for p in &ps {
            tensor::axpy(&mut want, 0.2, p);
        }
        assert!(tensor::max_abs_diff(&agg, &want) < 1e-4);
        assert!(stats.mse_vs_ideal < 1e-10);
    }

    #[test]
    fn mse_tracks_snr() {
        let ps = payloads(10, 2000, 4);
        let mut mses = Vec::new();
        for snr in [5.0f32, 15.0, 25.0] {
            let rc = perfect_round(10, snr);
            let mut rng = Rng::seed_from(5);
            let (_, stats) = aggregate(&ps, &rc, &mut rng);
            mses.push(stats.mse_vs_ideal);
        }
        assert!(mses[0] > mses[1] && mses[1] > mses[2], "{mses:?}");
        // each 10 dB step should cut MSE by roughly 10x
        assert!(mses[0] / mses[2] > 30.0, "{mses:?}");
    }

    #[test]
    fn mixed_precision_payloads_superpose_linearly() {
        // the paper's core claim: heterogeneous-precision payloads need no
        // common format — aggregate(quant_4bit, quant_16bit, f32) is just
        // the mean of the decimal values.
        use crate::quant::{fake_quant, Precision};
        let raw = payloads(3, 400, 6);
        let q: Vec<Vec<f32>> = vec![
            fake_quant(&raw[0], Precision::of(4)),
            fake_quant(&raw[1], Precision::of(16)),
            raw[2].clone(),
        ];
        let rc = perfect_round(3, 300.0);
        let mut rng = Rng::seed_from(7);
        let (agg, _) = aggregate(&q, &rc, &mut rng);
        let mut want = vec![0.0f32; 400];
        for p in &q {
            tensor::axpy(&mut want, 1.0 / 3.0, p);
        }
        assert!(tensor::max_abs_diff(&agg, &want) < 1e-4);
    }

    #[test]
    fn all_silenced_round_is_lost() {
        let ps = payloads(2, 50, 8);
        let mut rc = perfect_round(2, 20.0);
        for c in rc.clients.iter_mut() {
            c.precode = crate::channel::Precode::Silenced;
            c.effective_gain = None;
        }
        let mut rng = Rng::seed_from(9);
        let (agg, stats) = aggregate(&ps, &rc, &mut rng);
        assert_eq!(stats.participants, 0);
        assert!(agg.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn channel_uses_are_payload_length_not_k_times() {
        let ps = payloads(15, 123, 10);
        let rc = perfect_round(15, 20.0);
        let mut rng = Rng::seed_from(11);
        let (_, stats) = aggregate(&ps, &rc, &mut rng);
        assert_eq!(stats.channel_uses, 123); // OTA: one use per element
    }

    #[test]
    fn determinism() {
        let ps = payloads(5, 100, 12);
        let rc = perfect_round(5, 15.0);
        let mut r1 = Rng::seed_from(13);
        let mut r2 = Rng::seed_from(13);
        let (a, _) = aggregate(&ps, &rc, &mut r1);
        let (b, _) = aggregate(&ps, &rc, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_payload_lengths_panic() {
        let rc = perfect_round(2, 20.0);
        let mut rng = Rng::seed_from(14);
        let _ = aggregate(&[vec![0.0; 3], vec![0.0; 4]], &rc, &mut rng);
    }
}
