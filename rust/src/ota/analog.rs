//! Analog amplitude-modulated OTA superposition (paper Alg. 1 steps 3-4).
//!
//! Client k's transmitted baseband is `ĥ_k⁻¹ · x_k` (Eq. 6) where `x_k` is
//! the DECIMAL value vector of its quantized update — the multi-precision
//! modulation trick: a 4-bit client and a 32-bit client both put plain real
//! amplitudes on the carrier (Eq. 4), so the channel's superposition *is*
//! the sum, with no common digital constellation needed (Eq. 3).
//!
//! The server receives `Σ_k h_k ĥ_k⁻¹ x_k + n` (Eq. 2), takes the real
//! part (the payload is real; the imaginary part carries only misalignment
//! and noise) and scales by 1/K_active to obtain the model average
//! (Alg. 1 step 4, adjusted for truncation-silenced clients).
//!
//! This mirrors the L1 Pallas kernel `kernels/ota.py`; the rust path is the
//! request-path implementation, the artifact is used by `runtime` tests to
//! cross-validate the two.

use crate::channel::{RoundChannel, C32};
use crate::kernels::{fused, PackedPlane, PayloadPlane};
use crate::ota::AggregateStats;
use crate::rng::Rng;
use crate::tensor;

/// Reusable server-side buffers for the analog aggregation (one per run,
/// owned by the coordinator's round scratch arena): the complex receive
/// accumulators, the noise-free ideal, and the active-client gain list.
/// After [`aggregate_plane_into`] (or [`finalize_plane_into`]) returns,
/// `y_re` holds the aggregated MEAN vector.
///
/// The accumulators are N-sized (one air channel), NOT K-sized: a round
/// streamed through [`begin_plane_into`] → [`accumulate_plane_into`] →
/// [`finalize_plane_into`] only ever materializes one shard of payloads
/// next to them, which is what makes O(shard·N) round memory possible for
/// massive fleets.
#[derive(Clone, Debug, Default)]
pub struct OtaScratch {
    pub y_re: Vec<f32>,
    pub y_im: Vec<f32>,
    pub ideal: Vec<f32>,
    /// The CURRENT shard's active (row, gain) list — shard-local row
    /// indices, rebuilt per [`accumulate_plane_into`] call.
    pub active: Vec<(usize, C32)>,
    /// Participants accumulated across shards since [`begin_plane_into`].
    pub active_total: usize,
}

impl OtaScratch {
    pub fn new() -> Self {
        OtaScratch::default()
    }

    /// Resize (allocation-free once warm) and zero the accumulators.
    fn reset(&mut self, n: usize) {
        self.y_re.resize(n, 0.0);
        self.y_im.resize(n, 0.0);
        self.ideal.resize(n, 0.0);
        self.y_re.fill(0.0);
        self.y_im.fill(0.0);
        self.ideal.fill(0.0);
    }
}

/// Superpose client payloads through the round's channel realisation.
///
/// `payloads[k]` is client k's decimal payload (all equal length N).
/// Returns the aggregated MEAN vector (length N) and diagnostics.
///
/// Convenience wrapper over [`aggregate_plane_into`] (sequential, fresh
/// buffers) — tests, examples and one-shot callers.  The coordinator's
/// round loop uses the plane/scratch form directly.
///
/// Silenced clients (truncated inversion) contribute nothing; the mean is
/// over actual participants.  If every client is silenced the aggregate is
/// all-zeros with `participants == 0` — the caller (coordinator) treats
/// that as "round lost" and re-broadcasts the previous global model.
pub fn aggregate(
    payloads: &[Vec<f32>],
    round: &RoundChannel,
    rng: &mut Rng,
) -> (Vec<f32>, AggregateStats) {
    let plane = PayloadPlane::from_rows(payloads);
    let mut scratch = OtaScratch::new();
    let stats = aggregate_plane_into(&plane, round, rng, &mut scratch, 1);
    (std::mem::take(&mut scratch.y_re), stats)
}

/// The round-loop form of the analog OTA aggregation: payloads live in a
/// contiguous [`PayloadPlane`], all server buffers come from `scratch`
/// (zero heap allocation once warm), and the element axis is
/// chunk-parallel for `threads > 1`.
///
/// On return `scratch.y_re` holds the aggregated mean.  For a fixed seed
/// the result is bit-identical to the sequential scalar path at every
/// thread count (see the `kernels` module determinism contract; enforced
/// by `rust/tests/kernels.rs`).
pub fn aggregate_plane_into(
    plane: &PayloadPlane,
    round: &RoundChannel,
    rng: &mut Rng,
    scratch: &mut OtaScratch,
    threads: usize,
) -> AggregateStats {
    assert_eq!(
        plane.k(),
        round.clients.len(),
        "one payload per client required"
    );
    begin_plane_into(plane.n(), scratch);
    accumulate_plane_into(plane, 0, round, scratch, threads);
    finalize_plane_into(round, rng, scratch, threads)
}

/// Start a STREAMED (sharded) analog aggregation round with N-element
/// payloads: zero the air accumulators and the participant count.  Follow
/// with any number of [`accumulate_plane_into`] calls over consecutive
/// slot ranges and one [`finalize_plane_into`].  A single-shard stream is
/// exactly [`aggregate_plane_into`] — the one-shot entry is implemented
/// on these three functions, so the two paths share every instruction.
// mpota-lint: zero-alloc-hot
pub fn begin_plane_into(n: usize, scratch: &mut OtaScratch) {
    scratch.reset(n);
    scratch.active_total = 0;
}

/// Superpose ONE SHARD of payload rows through the channel gains of slots
/// `slot0 .. slot0 + plane.k()` of the round realisation, adding onto the
/// accumulated partial sums.
///
/// Bit-exactness across shard partitions: per element, every accumulator
/// receives the f32 contributions in ascending global slot order no
/// matter how the slots are cut into shards (the fused kernel sweeps the
/// shard's rows in order, and shards arrive in order), so any
/// `shard_size` reproduces the unsharded superposition bit-for-bit.
// mpota-lint: zero-alloc-hot
pub fn accumulate_plane_into(
    plane: &PayloadPlane,
    slot0: usize,
    round: &RoundChannel,
    scratch: &mut OtaScratch,
    threads: usize,
) {
    accumulate_plane_masked_into(plane, slot0, round, None, scratch, threads);
}

/// Masked form of [`accumulate_plane_into`] for straggler/dropout rounds:
/// rows with `included[r] == false` (shard-aligned mask) never join the
/// active list — their plane rows are not read, they add no signal, and
/// `active_total` (the 1/K_active divisor [`finalize_plane_into`] scales
/// by) self-adjusts.  `None` is the everyone-transmits path, identical to
/// the unmasked entry instruction for instruction.
// mpota-lint: zero-alloc-hot
pub fn accumulate_plane_masked_into(
    plane: &PayloadPlane,
    slot0: usize,
    round: &RoundChannel,
    included: Option<&[bool]>,
    scratch: &mut OtaScratch,
    threads: usize,
) {
    assert!(
        slot0 + plane.k() <= round.clients.len(),
        "shard slots {}..{} exceed the round's {} channel draws",
        slot0,
        slot0 + plane.k(),
        round.clients.len()
    );
    if let Some(mask) = included {
        assert_eq!(mask.len(), plane.k(), "participation mask length mismatch");
    }
    scratch.active.clear();
    for r in 0..plane.k() {
        if included.map_or(false, |mask| !mask[r]) {
            continue; // excluded client: slot stays silent
        }
        if let Some(g) = round.clients[slot0 + r].effective_gain {
            scratch.active.push((r, g));
        }
    }
    scratch.active_total += scratch.active.len();
    if scratch.active.is_empty() {
        return;
    }
    // --- superposition: y += Σ_k g_k · x_k (fused complex accumulate) ---
    fused::superpose(
        plane,
        &scratch.active,
        &mut scratch.y_re,
        &mut scratch.y_im,
        &mut scratch.ideal,
        threads,
    );
}

/// [`accumulate_plane_masked_into`] over a bit-packed shard: the rows of
/// `packed` hold TRANSMISSION-QUANTIZED codes at each slot's assigned
/// precision, and the fused kernel decodes + superposes them in one sweep
/// (no intermediate f32 row).  Because `decode(pack(x)) == fake_quant(x)`
/// bit-for-bit, this accumulates exactly what the f32 path accumulates
/// from a fake-quantized plane — the active-list build, `active_total`
/// accounting and chunk grid are shared instruction for instruction.
// mpota-lint: zero-alloc-hot
pub fn accumulate_packed_masked_into(
    packed: &PackedPlane,
    slot0: usize,
    round: &RoundChannel,
    included: Option<&[bool]>,
    scratch: &mut OtaScratch,
    threads: usize,
) {
    assert!(
        slot0 + packed.k() <= round.clients.len(),
        "shard slots {}..{} exceed the round's {} channel draws",
        slot0,
        slot0 + packed.k(),
        round.clients.len()
    );
    if let Some(mask) = included {
        assert_eq!(mask.len(), packed.k(), "participation mask length mismatch");
    }
    scratch.active.clear();
    for r in 0..packed.k() {
        if included.map_or(false, |mask| !mask[r]) {
            continue; // excluded client: slot stays silent
        }
        if let Some(g) = round.clients[slot0 + r].effective_gain {
            scratch.active.push((r, g));
        }
    }
    scratch.active_total += scratch.active.len();
    if scratch.active.is_empty() {
        return;
    }
    // --- superposition: y += Σ_k g_k · decode(codes_k), fused ------------
    fused::superpose_packed(
        packed,
        &scratch.active,
        &mut scratch.y_re,
        &mut scratch.y_im,
        &mut scratch.ideal,
        threads,
    );
}

/// Finish a streamed analog aggregation: inject receiver noise calibrated
/// to the ACCUMULATED signal power, demodulate, and scale to the
/// participant mean.  On return `scratch.y_re` holds the aggregated MEAN
/// vector (all-zeros with `participants == 0` when every slot was
/// truncation-silenced — the "round lost" case).
// mpota-lint: zero-alloc-hot
pub fn finalize_plane_into(
    round: &RoundChannel,
    rng: &mut Rng,
    scratch: &mut OtaScratch,
    threads: usize,
) -> AggregateStats {
    let n = scratch.y_re.len();
    let participants = scratch.active_total;
    let mut stats = AggregateStats {
        participants,
        channel_uses: n as u64,
        ..Default::default()
    };
    if participants == 0 {
        return stats;
    }

    // --- receiver noise calibrated to received signal power -------------
    // (f64 reduction stays sequential: its summation order is part of the
    // bit-exact contract and it is cheap relative to the sweeps above.)
    let signal_power =
        (tensor::sq_norm(&scratch.y_re) + tensor::sq_norm(&scratch.y_im)) / n as f64;
    let noise_var = round.noise_var(signal_power as f32);
    stats.signal_power = signal_power;
    stats.noise_var = noise_var as f64;
    if noise_var > 0.0 {
        // CN(0, var): var/2 per component, both components in one
        // skip-ahead-parallel pairwise Box-Muller sweep (§Perf; draws
        // exactly match the sequential re-then-im fill).
        let std = (noise_var * 0.5).sqrt();
        rng.add_normal2(&mut scratch.y_re, &mut scratch.y_im, std, threads);
    }

    // --- demodulate: real part, scale to the mean ------------------------
    let scale = 1.0 / participants as f32;
    tensor::scale_par(&mut scratch.y_re, scale, threads);
    tensor::scale_par(&mut scratch.ideal, scale, threads);
    stats.mse_vs_ideal = tensor::mse(&scratch.y_re, &scratch.ideal);
    stats
}

/// Effective-gain view for the OTA artifact (`ota_k15.hlo.txt`): the PJRT
/// path takes (gains_re, gains_im) vectors with zeros for silenced clients.
pub fn gain_vectors(round: &RoundChannel) -> (Vec<f32>, Vec<f32>) {
    let mut re = Vec::with_capacity(round.clients.len());
    let mut im = Vec::with_capacity(round.clients.len());
    for c in &round.clients {
        let g = c.effective_gain.unwrap_or(C32::ZERO);
        re.push(g.re);
        im.push(g.im);
    }
    (re, im)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::ChannelConfig;

    fn payloads(k: usize, n: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Rng::seed_from(seed);
        (0..k)
            .map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect()
    }

    fn perfect_round(k: usize, snr_db: f32) -> RoundChannel {
        let mut rng = Rng::seed_from(1);
        let cfg = ChannelConfig { snr_db, perfect_csi: true, ..Default::default() };
        RoundChannel::draw(&cfg, k, &mut rng)
    }

    #[test]
    fn noiseless_perfect_csi_recovers_exact_mean() {
        let ps = payloads(5, 300, 2);
        let rc = perfect_round(5, 200.0); // effectively noise-free
        let mut rng = Rng::seed_from(3);
        let (agg, stats) = aggregate(&ps, &rc, &mut rng);
        assert_eq!(stats.participants, 5);
        let mut want = vec![0.0f32; 300];
        for p in &ps {
            tensor::axpy(&mut want, 0.2, p);
        }
        assert!(tensor::max_abs_diff(&agg, &want) < 1e-4);
        assert!(stats.mse_vs_ideal < 1e-10);
    }

    #[test]
    fn mse_tracks_snr() {
        let ps = payloads(10, 2000, 4);
        let mut mses = Vec::new();
        for snr in [5.0f32, 15.0, 25.0] {
            let rc = perfect_round(10, snr);
            let mut rng = Rng::seed_from(5);
            let (_, stats) = aggregate(&ps, &rc, &mut rng);
            mses.push(stats.mse_vs_ideal);
        }
        assert!(mses[0] > mses[1] && mses[1] > mses[2], "{mses:?}");
        // each 10 dB step should cut MSE by roughly 10x
        assert!(mses[0] / mses[2] > 30.0, "{mses:?}");
    }

    #[test]
    fn mixed_precision_payloads_superpose_linearly() {
        // the paper's core claim: heterogeneous-precision payloads need no
        // common format — aggregate(quant_4bit, quant_16bit, f32) is just
        // the mean of the decimal values.
        use crate::quant::{fake_quant, Precision};
        let raw = payloads(3, 400, 6);
        let q: Vec<Vec<f32>> = vec![
            fake_quant(&raw[0], Precision::of(4)),
            fake_quant(&raw[1], Precision::of(16)),
            raw[2].clone(),
        ];
        let rc = perfect_round(3, 300.0);
        let mut rng = Rng::seed_from(7);
        let (agg, _) = aggregate(&q, &rc, &mut rng);
        let mut want = vec![0.0f32; 400];
        for p in &q {
            tensor::axpy(&mut want, 1.0 / 3.0, p);
        }
        assert!(tensor::max_abs_diff(&agg, &want) < 1e-4);
    }

    #[test]
    fn all_silenced_round_is_lost() {
        let ps = payloads(2, 50, 8);
        let mut rc = perfect_round(2, 20.0);
        for c in rc.clients.iter_mut() {
            c.precode = crate::channel::Precode::Silenced;
            c.effective_gain = None;
        }
        let mut rng = Rng::seed_from(9);
        let (agg, stats) = aggregate(&ps, &rc, &mut rng);
        assert_eq!(stats.participants, 0);
        assert!(agg.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn channel_uses_are_payload_length_not_k_times() {
        let ps = payloads(15, 123, 10);
        let rc = perfect_round(15, 20.0);
        let mut rng = Rng::seed_from(11);
        let (_, stats) = aggregate(&ps, &rc, &mut rng);
        assert_eq!(stats.channel_uses, 123); // OTA: one use per element
    }

    #[test]
    fn determinism() {
        let ps = payloads(5, 100, 12);
        let rc = perfect_round(5, 15.0);
        let mut r1 = Rng::seed_from(13);
        let mut r2 = Rng::seed_from(13);
        let (a, _) = aggregate(&ps, &rc, &mut r1);
        let (b, _) = aggregate(&ps, &rc, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn mismatched_payload_lengths_panic() {
        let rc = perfect_round(2, 20.0);
        let mut rng = Rng::seed_from(14);
        let _ = aggregate(&[vec![0.0; 3], vec![0.0; 4]], &rc, &mut rng);
    }

    #[test]
    fn sharded_stream_matches_one_shot_bitwise() {
        // the shard-invariance kernel contract: any shard partition of
        // the round's slots, streamed through begin/accumulate/finalize,
        // reproduces the one-shot aggregation bit-for-bit — including
        // noise draws, participants and MSE — at every thread count
        let ps = payloads(15, 20_000, 91);
        let rc = perfect_round(15, 20.0); // noise_var > 0: real noise path
        let plane = crate::kernels::PayloadPlane::from_rows(&ps);
        let mut want_scratch = OtaScratch::new();
        let mut r0 = Rng::seed_from(17);
        let want_stats =
            aggregate_plane_into(&plane, &rc, &mut r0, &mut want_scratch, 1);
        for threads in [1usize, 4] {
            for shard in [1usize, 4, 7, 15] {
                let mut rng = Rng::seed_from(17);
                let mut scratch = OtaScratch::new();
                begin_plane_into(20_000, &mut scratch);
                let mut lo = 0usize;
                while lo < 15 {
                    let hi = (lo + shard).min(15);
                    let shard_plane =
                        crate::kernels::PayloadPlane::from_rows(&ps[lo..hi]);
                    accumulate_plane_into(&shard_plane, lo, &rc, &mut scratch, threads);
                    lo = hi;
                }
                let stats = finalize_plane_into(&rc, &mut rng, &mut scratch, threads);
                assert_eq!(
                    scratch.y_re, want_scratch.y_re,
                    "shard={shard} threads={threads}"
                );
                assert_eq!(stats.participants, want_stats.participants);
                assert_eq!(
                    stats.mse_vs_ideal.to_bits(),
                    want_stats.mse_vs_ideal.to_bits(),
                    "shard={shard} threads={threads}"
                );
                assert_eq!(
                    stats.noise_var.to_bits(),
                    want_stats.noise_var.to_bits(),
                    "shard={shard} threads={threads}"
                );
            }
        }
    }

    #[test]
    fn masked_accumulate_matches_subset_superposition_and_adjusts_divisor() {
        // excluding rows via the participation mask must be bit-identical
        // to superposing only the included rows through their own slots'
        // gains, with the 1/K_active divisor following the active count
        let ps = payloads(6, 512, 21);
        let rc = perfect_round(6, 20.0);
        let mask = [true, false, true, true, false, true];

        let plane = crate::kernels::PayloadPlane::from_rows(&ps);
        let mut masked = OtaScratch::new();
        begin_plane_into(512, &mut masked);
        accumulate_plane_masked_into(&plane, 0, &rc, Some(&mask), &mut masked, 1);
        let mut rng = Rng::seed_from(23);
        let got = finalize_plane_into(&rc, &mut rng, &mut masked, 1);

        // reference: the included subset as its own (sub-)round
        let sub_ps: Vec<Vec<f32>> = ps
            .iter()
            .zip(mask.iter())
            .filter(|(_, &m)| m)
            .map(|(p, _)| p.clone())
            .collect();
        let mut sub_rc = rc.clone();
        let mut keep = mask.iter();
        sub_rc.clients.retain(|_| *keep.next().unwrap());
        let sub_plane = crate::kernels::PayloadPlane::from_rows(&sub_ps);
        let mut want_scratch = OtaScratch::new();
        let mut r0 = Rng::seed_from(23);
        let want =
            aggregate_plane_into(&sub_plane, &sub_rc, &mut r0, &mut want_scratch, 1);

        assert_eq!(got.participants, 4, "divisor must track the active count");
        assert_eq!(want.participants, 4);
        assert_eq!(masked.y_re, want_scratch.y_re);
        assert_eq!(got.mse_vs_ideal.to_bits(), want.mse_vs_ideal.to_bits());

        // an all-true mask is the unmasked path, bit for bit
        let mut all = OtaScratch::new();
        begin_plane_into(512, &mut all);
        accumulate_plane_masked_into(&plane, 0, &rc, Some(&[true; 6]), &mut all, 1);
        let mut none = OtaScratch::new();
        begin_plane_into(512, &mut none);
        accumulate_plane_into(&plane, 0, &rc, &mut none, 1);
        assert_eq!(all.y_re, none.y_re);
        assert_eq!(all.active_total, none.active_total);
    }

    #[test]
    fn plane_path_matches_wrapper_for_any_thread_count() {
        // large even N: exercises the chunk-parallel superposition AND the
        // skip-ahead parallel noise fill (20 dB SNR => noise_var > 0)
        let ps = payloads(15, 20_000, 77);
        let rc = perfect_round(15, 20.0);
        let mut r0 = Rng::seed_from(5);
        let (want, want_stats) = aggregate(&ps, &rc, &mut r0);
        let plane = crate::kernels::PayloadPlane::from_rows(&ps);
        let mut scratch = OtaScratch::new();
        for threads in [1usize, 2, 4] {
            let mut rng = Rng::seed_from(5);
            let stats = aggregate_plane_into(&plane, &rc, &mut rng, &mut scratch, threads);
            assert_eq!(scratch.y_re, want, "threads={threads}");
            assert_eq!(stats.participants, want_stats.participants);
            assert_eq!(
                stats.mse_vs_ideal.to_bits(),
                want_stats.mse_vs_ideal.to_bits(),
                "threads={threads}"
            );
        }
    }
}
