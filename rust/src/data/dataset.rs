//! Dataset assembly + batch iteration over the synthetic sign corpus.

use crate::data::signs::{self, NUM_CLASSES, SAMPLE_LEN};
use crate::rng::Rng;

/// An in-memory labelled image set (HWC f32 images, i32 labels).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: Vec<f32>, // n * SAMPLE_LEN, sample-major
    pub labels: Vec<i32>,
    pub n: usize,
}

impl Dataset {
    /// Generate `n` samples, class-balanced (round-robin over the 43
    /// classes then shuffled), deterministically from `rng`.
    pub fn generate(n: usize, rng: &mut Rng) -> Self {
        let mut order: Vec<usize> = (0..n).map(|i| i % NUM_CLASSES).collect();
        rng.shuffle(&mut order);
        let mut images = vec![0.0f32; n * SAMPLE_LEN];
        let mut labels = Vec::with_capacity(n);
        for (i, &class) in order.iter().enumerate() {
            signs::render_into(
                class,
                rng,
                &mut images[i * SAMPLE_LEN..(i + 1) * SAMPLE_LEN],
            );
            labels.push(class as i32);
        }
        Dataset { images, labels, n }
    }

    /// Borrow sample `i`'s pixels.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.images[i * SAMPLE_LEN..(i + 1) * SAMPLE_LEN]
    }

    /// Copy a batch given sample indices; `images_out` must hold
    /// `idx.len() * SAMPLE_LEN`, `labels_out` `idx.len()`.
    pub fn gather(&self, idx: &[usize], images_out: &mut [f32], labels_out: &mut [i32]) {
        assert_eq!(images_out.len(), idx.len() * SAMPLE_LEN);
        assert_eq!(labels_out.len(), idx.len());
        for (j, &i) in idx.iter().enumerate() {
            images_out[j * SAMPLE_LEN..(j + 1) * SAMPLE_LEN]
                .copy_from_slice(self.image(i));
            labels_out[j] = self.labels[i];
        }
    }

    /// Split into (first, rest) at `at` samples.
    pub fn split(mut self, at: usize) -> (Dataset, Dataset) {
        assert!(at <= self.n);
        let tail_images = self.images.split_off(at * SAMPLE_LEN);
        let tail_labels = self.labels.split_off(at);
        let tail_n = tail_labels.len();
        let head = Dataset { images: self.images, labels: self.labels, n: at };
        let tail = Dataset { images: tail_images, labels: tail_labels, n: tail_n };
        (head, tail)
    }

    /// Class histogram (for balance checks).
    pub fn class_counts(&self) -> [usize; NUM_CLASSES] {
        let mut counts = [0usize; NUM_CLASSES];
        for &l in &self.labels {
            counts[l as usize] += 1;
        }
        counts
    }
}

/// Epoch-shuffling minibatch index iterator (drops the ragged tail batch —
/// training artifacts have a fixed batch dimension).
pub struct BatchIter {
    order: Vec<usize>,
    batch: usize,
    cursor: usize,
}

impl BatchIter {
    pub fn new(n: usize, batch: usize, rng: &mut Rng) -> Self {
        assert!(batch > 0);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        BatchIter { order, batch, cursor: 0 }
    }

    /// Whether another full minibatch remains in this epoch (lets the
    /// zero-alloc client loop reset BEFORE borrowing the batch slice).
    pub fn has_next(&self) -> bool {
        self.cursor + self.batch <= self.order.len()
    }

    /// Next minibatch of indices, or None at epoch end.
    pub fn next_batch(&mut self) -> Option<&[usize]> {
        if !self.has_next() {
            return None;
        }
        let s = &self.order[self.cursor..self.cursor + self.batch];
        self.cursor += self.batch;
        Some(s)
    }

    /// Reshuffle and restart for the next epoch.
    pub fn reset(&mut self, rng: &mut Rng) {
        rng.shuffle(&mut self.order);
        self.cursor = 0;
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_balanced_and_deterministic() {
        let mut r1 = Rng::seed_from(42);
        let mut r2 = Rng::seed_from(42);
        let d1 = Dataset::generate(430, &mut r1);
        let d2 = Dataset::generate(430, &mut r2);
        assert_eq!(d1.images, d2.images);
        assert_eq!(d1.labels, d2.labels);
        let counts = d1.class_counts();
        assert!(counts.iter().all(|&c| c == 10), "{counts:?}");
    }

    #[test]
    fn gather_batches() {
        let mut rng = Rng::seed_from(1);
        let d = Dataset::generate(50, &mut rng);
        let idx = [3usize, 17, 49];
        let mut imgs = vec![0.0f32; 3 * SAMPLE_LEN];
        let mut labels = vec![0i32; 3];
        d.gather(&idx, &mut imgs, &mut labels);
        assert_eq!(labels[1], d.labels[17]);
        assert_eq!(&imgs[SAMPLE_LEN..2 * SAMPLE_LEN], d.image(17));
    }

    #[test]
    fn split_partitions() {
        let mut rng = Rng::seed_from(2);
        let d = Dataset::generate(100, &mut rng);
        let all_labels = d.labels.clone();
        let (a, b) = d.split(60);
        assert_eq!(a.n, 60);
        assert_eq!(b.n, 40);
        assert_eq!(
            a.labels.iter().chain(b.labels.iter()).copied().collect::<Vec<_>>(),
            all_labels
        );
    }

    #[test]
    fn batch_iter_covers_epoch_without_repeats() {
        let mut rng = Rng::seed_from(3);
        let mut it = BatchIter::new(100, 32, &mut rng);
        assert_eq!(it.batches_per_epoch(), 3);
        let mut seen = Vec::new();
        while let Some(b) = it.next_batch() {
            assert_eq!(b.len(), 32);
            seen.extend_from_slice(b);
        }
        assert_eq!(seen.len(), 96);
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 96, "repeated index within epoch");
        // reset starts a new epoch with a different order
        it.reset(&mut rng);
        let mut second = Vec::new();
        while let Some(b) = it.next_batch() {
            second.extend_from_slice(b);
        }
        assert_eq!(second.len(), 96);
        assert_ne!(seen, second);
    }

    #[test]
    fn batch_iter_small_n() {
        let mut rng = Rng::seed_from(4);
        let mut it = BatchIter::new(10, 32, &mut rng);
        assert!(it.next_batch().is_none());
    }
}
