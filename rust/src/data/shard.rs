//! Client data sharding (paper §IV-A1: "each client is assigned an equal
//! subset of the data") and the non-IID convergence-science partitions
//! (Dirichlet(α) label skew, power-law sample-count skew).

use anyhow::{bail, Result};

use crate::data::dataset::Dataset;
use crate::rng::Rng;

/// A client's view into the global training corpus: owned sample indices.
#[derive(Clone, Debug)]
pub struct Shard {
    pub client: usize,
    pub indices: Vec<usize>,
}

/// Partition `n` samples into `k` equal IID shards (shuffled assignment;
/// remainder samples are dropped so shards stay exactly equal, matching
/// the paper's equal-subset setup).
pub fn equal_shards(n: usize, k: usize, rng: &mut Rng) -> Vec<Shard> {
    assert!(k > 0, "need at least one client");
    let per = n / k;
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    (0..k)
        .map(|c| Shard {
            client: c,
            indices: order[c * per..(c + 1) * per].to_vec(),
        })
        .collect()
}

/// One exact Gamma(shape, 1) draw — Marsaglia–Tsang squeeze, with the
/// `shape < 1` boost `Gamma(shape) = Gamma(shape + 1) · U^{1/shape}`.
/// Consumes a data-dependent number of draws from `rng`, which is fine for
/// partition construction (a one-shot setup step on one stream, never on
/// the per-round path).
fn gamma(shape: f64, rng: &mut Rng) -> f64 {
    debug_assert!(shape > 0.0 && shape.is_finite());
    if shape < 1.0 {
        let g = gamma(shape + 1.0, rng);
        let u = rng.uniform().max(1e-300);
        return g * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let t = 1.0 + c * x;
        if t <= 0.0 {
            continue;
        }
        let v = t * t * t;
        let u = rng.uniform().max(1e-300);
        if u < 1.0 - 0.0331 * x * x * x * x {
            return d * v;
        }
        if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
            return d * v;
        }
    }
}

/// A full client partition in CSR form: client `i` owns the corpus
/// indices `order[offsets[i]..offsets[i+1]]`.  Variable-length shards —
/// the Dirichlet/Zipf counterpart of the IID fleet's positional
/// `order[i·per..(i+1)·per]` recipe.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PartitionRecipe {
    /// Corpus sample indices, grouped by owning client.
    pub order: Vec<usize>,
    /// `clients + 1` monotone offsets into `order`.
    pub offsets: Vec<usize>,
}

impl PartitionRecipe {
    pub fn clients(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Client `id`'s sample indices.
    pub fn shard_of(&self, id: usize) -> &[usize] {
        &self.order[self.offsets[id]..self.offsets[id + 1]]
    }
}

/// Dirichlet(α) label-skewed partition with optional power-law
/// sample-count skew — the convergence-science non-IID generator.
///
/// For every class, per-client proportions are drawn as normalized
/// `w_i · Gamma(α)` where `w_i = (i+1)^{-skew_zipf}` (Hsu et al.-style
/// per-class Dirichlet over clients, size-biased by the Zipf weight), and
/// the class's shuffled samples are apportioned to those proportions by
/// largest remainder — every sample is assigned exactly once.  Small α
/// concentrates each class on few clients (heavy per-client label skew);
/// large α recovers near-uniform marginals.  A deterministic repair pass
/// then moves samples from the largest shards until every client owns at
/// least `min_per` samples (one train batch, so `BatchIter` always has a
/// full batch).
///
/// Deterministic: the output is a pure function of `(labels, clients,
/// alpha, skew_zipf, min_per)` and the state of `rng`.
pub fn dirichlet_recipe(
    labels: &[i32],
    clients: usize,
    alpha: f64,
    skew_zipf: f64,
    min_per: usize,
    rng: &mut Rng,
) -> Result<PartitionRecipe> {
    let n = labels.len();
    if clients == 0 {
        bail!("need at least one client");
    }
    if !(alpha > 0.0 && alpha.is_finite()) {
        bail!("alpha {alpha} must be positive and finite");
    }
    if !(skew_zipf >= 0.0 && skew_zipf.is_finite()) {
        bail!("skew_zipf {skew_zipf} must be >= 0 and finite");
    }
    if clients * min_per > n {
        bail!(
            "dirichlet partition cannot give {clients} clients at least \
             {min_per} samples each from a {n}-sample corpus"
        );
    }

    // Per-class sample buckets, shuffled so the concrete indices a client
    // receives are seed-random (not corpus-order).
    let mut per_class: Vec<Vec<usize>> =
        vec![Vec::new(); crate::data::signs::NUM_CLASSES];
    for (i, &l) in labels.iter().enumerate() {
        per_class[l as usize].push(i);
    }
    for bucket in per_class.iter_mut() {
        rng.shuffle(bucket);
    }

    // Zipf size weights: client i's expected share of EVERY class is
    // proportional to (i+1)^-skew_zipf, so expected shard sizes follow
    // the power law while alpha independently controls label skew.
    let zipf: Vec<f64> = (0..clients)
        .map(|i| ((i + 1) as f64).powf(-skew_zipf))
        .collect();

    let mut shards: Vec<Vec<usize>> = vec![Vec::new(); clients];
    let mut props = vec![0.0f64; clients];
    let mut counts = vec![0usize; clients];
    let mut frac_order: Vec<usize> = Vec::with_capacity(clients);
    for bucket in per_class.iter() {
        if bucket.is_empty() {
            continue;
        }
        // Size-biased Dirichlet proportions over clients for this class.
        let mut total = 0.0f64;
        for (i, p) in props.iter_mut().enumerate() {
            *p = zipf[i] * gamma(alpha, rng);
            total += *p;
        }
        if !(total > 0.0) {
            // all-zero underflow (absurdly small alpha): fall back to the
            // size weights alone
            props.copy_from_slice(&zipf);
            total = props.iter().sum();
        }
        // Largest-remainder apportionment of the bucket: exact, integral,
        // deterministic (ties broken by client index).
        let m = bucket.len();
        let mut assigned = 0usize;
        for i in 0..clients {
            let quota = m as f64 * (props[i] / total);
            counts[i] = quota.floor() as usize;
            props[i] = quota - counts[i] as f64; // keep the fractional part
            assigned += counts[i];
        }
        frac_order.clear();
        frac_order.extend(0..clients);
        frac_order.sort_by(|&a, &b| {
            props[b].partial_cmp(&props[a]).unwrap().then(a.cmp(&b))
        });
        for &i in frac_order.iter().take(m - assigned) {
            counts[i] += 1;
        }
        let mut start = 0usize;
        for (i, &c) in counts.iter().enumerate() {
            shards[i].extend_from_slice(&bucket[start..start + c]);
            start += c;
        }
        debug_assert_eq!(start, m, "class bucket fully apportioned");
    }

    // Floor repair: move samples from the currently-largest shard to any
    // client below `min_per` until everyone holds a full train batch.
    // Deterministic (first-max donor, first-min recipient) and rarely
    // triggered outside tiny corpora or extreme alpha.
    loop {
        let (mut lo, mut hi) = (0usize, 0usize);
        for i in 1..clients {
            if shards[i].len() < shards[lo].len() {
                lo = i;
            }
            if shards[i].len() > shards[hi].len() {
                hi = i;
            }
        }
        if shards[lo].len() >= min_per {
            break;
        }
        let moved = shards[hi].pop().expect("donor shard non-empty");
        shards[lo].push(moved);
    }

    let mut order = Vec::with_capacity(n);
    let mut offsets = Vec::with_capacity(clients + 1);
    offsets.push(0);
    for s in &shards {
        order.extend_from_slice(s);
        offsets.push(order.len());
    }
    Ok(PartitionRecipe { order, offsets })
}

/// Non-IID label-skewed shards: each class's samples are split across
/// clients by exact Dirichlet(alpha) proportions (see
/// [`dirichlet_recipe`]).  Lower alpha = more skew.
pub fn dirichlet_shards(
    data: &Dataset,
    k: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Shard> {
    let recipe = dirichlet_recipe(&data.labels, k, alpha, 0.0, 1, rng)
        .expect("dirichlet shard parameters");
    (0..k)
        .map(|c| Shard { client: c, indices: recipe.shard_of(c).to_vec() })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;

    #[test]
    fn equal_shards_partition_equally() {
        let mut rng = Rng::seed_from(1);
        let shards = equal_shards(1000, 15, &mut rng);
        assert_eq!(shards.len(), 15);
        for s in &shards {
            assert_eq!(s.indices.len(), 66);
        }
        // disjoint
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        all.sort_unstable();
        let len = all.len();
        all.dedup();
        assert_eq!(all.len(), len);
    }

    #[test]
    fn equal_shards_deterministic() {
        let mut r1 = Rng::seed_from(2);
        let mut r2 = Rng::seed_from(2);
        let a = equal_shards(100, 5, &mut r1);
        let b = equal_shards(100, 5, &mut r2);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    fn dirichlet_shards_cover_all_samples() {
        let mut rng = Rng::seed_from(3);
        let data = Dataset::generate(430, &mut rng);
        let shards = dirichlet_shards(&data, 10, 0.5, &mut rng);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..430).collect::<Vec<_>>());
    }

    #[test]
    fn recipe_is_exact_deterministic_and_floored() {
        // synthetic labels matching Dataset::generate's class-balanced
        // round-robin construction, without rendering any images
        let n = 860usize;
        let labels: Vec<i32> = (0..n)
            .map(|i| (i % crate::data::signs::NUM_CLASSES) as i32)
            .collect();
        let mut r1 = Rng::seed_from(7).stream("shard");
        let mut r2 = Rng::seed_from(7).stream("shard");
        let a = dirichlet_recipe(&labels, 6, 0.1, 0.0, 8, &mut r1).unwrap();
        let b = dirichlet_recipe(&labels, 6, 0.1, 0.0, 8, &mut r2).unwrap();
        assert_eq!(a, b, "same seed, same recipe");
        // exact partition: every sample exactly once
        let mut all = a.order.clone();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
        // min_per floor honored even at heavy skew
        for c in 0..a.clients() {
            assert!(a.shard_of(c).len() >= 8, "client {c} under the floor");
        }
        // infeasible floor is a config error, not a panic
        assert!(dirichlet_recipe(&labels, 200, 1.0, 0.0, 8, &mut r1).is_err());
        assert!(dirichlet_recipe(&labels, 6, 0.0, 0.0, 8, &mut r1).is_err());
        assert!(dirichlet_recipe(&labels, 6, 1.0, -1.0, 8, &mut r1).is_err());
    }

    #[test]
    fn zipf_skew_orders_expected_shard_sizes() {
        let n = 4300usize;
        let labels: Vec<i32> = (0..n)
            .map(|i| (i % crate::data::signs::NUM_CLASSES) as i32)
            .collect();
        // large alpha isolates the size skew from the label skew
        let mut rng = Rng::seed_from(11).stream("shard");
        let r = dirichlet_recipe(&labels, 8, 50.0, 1.2, 8, &mut rng).unwrap();
        let sizes: Vec<usize> = (0..8).map(|c| r.shard_of(c).len()).collect();
        assert!(
            sizes[0] > 2 * sizes[7],
            "zipf head {} should dwarf the tail {}",
            sizes[0],
            sizes[7]
        );
        // head-heavy overall: earlier clients hold more than later ones
        assert!(sizes[0] > sizes[3] && sizes[3] > sizes[7], "{sizes:?}");
    }

    #[test]
    fn low_alpha_skews_more() {
        let mut rng = Rng::seed_from(4);
        let data = Dataset::generate(860, &mut rng);
        let skewed = dirichlet_shards(&data, 5, 0.2, &mut rng);
        let uniform = dirichlet_shards(&data, 5, 100.0, &mut rng);
        let spread = |shards: &[Shard]| {
            let sizes: Vec<f64> = shards.iter().map(|s| s.indices.len() as f64).collect();
            let m = sizes.iter().sum::<f64>() / sizes.len() as f64;
            sizes.iter().map(|s| (s - m).abs()).sum::<f64>()
        };
        assert!(spread(&skewed) >= spread(&uniform),
            "skewed {} uniform {}", spread(&skewed), spread(&uniform));
    }
}
