//! Client data sharding (paper §IV-A1: "each client is assigned an equal
//! subset of the data").

use crate::data::dataset::Dataset;
use crate::rng::Rng;

/// A client's view into the global training corpus: owned sample indices.
#[derive(Clone, Debug)]
pub struct Shard {
    pub client: usize,
    pub indices: Vec<usize>,
}

/// Partition `n` samples into `k` equal IID shards (shuffled assignment;
/// remainder samples are dropped so shards stay exactly equal, matching
/// the paper's equal-subset setup).
pub fn equal_shards(n: usize, k: usize, rng: &mut Rng) -> Vec<Shard> {
    assert!(k > 0, "need at least one client");
    let per = n / k;
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    (0..k)
        .map(|c| Shard {
            client: c,
            indices: order[c * per..(c + 1) * per].to_vec(),
        })
        .collect()
}

/// Non-IID label-skewed shards (extension knob, not used by the paper's
/// headline experiments): each client draws a Dirichlet(alpha) mixture
/// over classes.  Lower alpha = more skew.
pub fn dirichlet_shards(
    data: &Dataset,
    k: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Shard> {
    assert!(k > 0 && alpha > 0.0);
    // Bucket samples per class.
    let mut per_class: Vec<Vec<usize>> =
        vec![Vec::new(); crate::data::signs::NUM_CLASSES];
    for (i, &l) in data.labels.iter().enumerate() {
        per_class[l as usize].push(i);
    }
    let mut shards: Vec<Shard> = (0..k)
        .map(|c| Shard { client: c, indices: Vec::new() })
        .collect();
    for bucket in per_class.iter_mut() {
        rng.shuffle(bucket);
        // Dirichlet via normalized Gamma(alpha, 1) draws (Marsaglia-Tsang
        // would be overkill; alpha is O(1), use the sum-of-exponentials
        // approximation for alpha>=1 and Johnk-style fallback otherwise —
        // here we use the simple normalized power of uniforms which is
        // adequate for shard skew).
        let weights: Vec<f64> = (0..k)
            .map(|_| {
                // Gamma(alpha) approximated by Weibull-ish transform: for
                // shard assignment purposes only the relative skew matters.
                let u: f64 = rng.uniform().max(1e-12);
                (-u.ln()).powf(1.0 / alpha)
            })
            .collect();
        let total: f64 = weights.iter().sum();
        let mut start = 0usize;
        for (c, w) in weights.iter().enumerate() {
            let take = if c + 1 == k {
                bucket.len() - start
            } else {
                ((w / total) * bucket.len() as f64).round() as usize
            };
            let end = (start + take).min(bucket.len());
            shards[c].indices.extend_from_slice(&bucket[start..end]);
            start = end;
        }
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;

    #[test]
    fn equal_shards_partition_equally() {
        let mut rng = Rng::seed_from(1);
        let shards = equal_shards(1000, 15, &mut rng);
        assert_eq!(shards.len(), 15);
        for s in &shards {
            assert_eq!(s.indices.len(), 66);
        }
        // disjoint
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        all.sort_unstable();
        let len = all.len();
        all.dedup();
        assert_eq!(all.len(), len);
    }

    #[test]
    fn equal_shards_deterministic() {
        let mut r1 = Rng::seed_from(2);
        let mut r2 = Rng::seed_from(2);
        let a = equal_shards(100, 5, &mut r1);
        let b = equal_shards(100, 5, &mut r2);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    fn dirichlet_shards_cover_all_samples() {
        let mut rng = Rng::seed_from(3);
        let data = Dataset::generate(430, &mut rng);
        let shards = dirichlet_shards(&data, 10, 0.5, &mut rng);
        let mut all: Vec<usize> =
            shards.iter().flat_map(|s| s.indices.iter().copied()).collect();
        all.sort_unstable();
        assert_eq!(all, (0..430).collect::<Vec<_>>());
    }

    #[test]
    fn low_alpha_skews_more() {
        let mut rng = Rng::seed_from(4);
        let data = Dataset::generate(860, &mut rng);
        let skewed = dirichlet_shards(&data, 5, 0.2, &mut rng);
        let uniform = dirichlet_shards(&data, 5, 100.0, &mut rng);
        let spread = |shards: &[Shard]| {
            let sizes: Vec<f64> = shards.iter().map(|s| s.indices.len() as f64).collect();
            let m = sizes.iter().sum::<f64>() / sizes.len() as f64;
            sizes.iter().map(|s| (s - m).abs()).sum::<f64>()
        };
        assert!(spread(&skewed) >= spread(&uniform),
            "skewed {} uniform {}", spread(&skewed), spread(&uniform));
    }
}
