//! Synthetic traffic-sign data substrate — the GTSRB stand-in.
//!
//! [`signs`] renders 43-class procedural sign images (deterministic per
//! seed), [`dataset`] assembles labelled corpora with batch iteration,
//! [`shard`] partitions them across FL clients.

pub mod dataset;
pub mod shard;
pub mod signs;

pub use dataset::{BatchIter, Dataset};
pub use shard::{dirichlet_recipe, dirichlet_shards, equal_shards, PartitionRecipe, Shard};
pub use signs::{NUM_CLASSES, SAMPLE_LEN};
