//! Procedural traffic-sign renderer — the GTSRB stand-in (DESIGN.md §2).
//!
//! 43 classes, each a distinct (plate shape, rim colour, inner glyph)
//! combination, rendered at 32×32 RGB with the nuisance variability that
//! makes GTSRB non-trivial: random background, sign position/scale/rotation
//! jitter, brightness/contrast (lighting), and sensor noise.  Every image
//! is a pure function of (class, per-sample RNG), so datasets are
//! deterministic per seed.
//!
//! The renderer evaluates signed-distance functions per pixel — no image
//! library needed, and it is fast enough to synthesise tens of thousands
//! of samples per second in release builds.

use crate::rng::Rng;

pub const IMG: usize = 32;
pub const CHANNELS: usize = 3;
pub const NUM_CLASSES: usize = 43;
/// Floats per sample.
pub const SAMPLE_LEN: usize = IMG * IMG * CHANNELS;

/// Plate silhouettes (matching the real-world sign families).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Shape {
    Circle,
    Triangle,
    TriangleDown,
    Diamond,
    Octagon,
    Square,
}

const SHAPES: [Shape; 6] = [
    Shape::Circle,
    Shape::Triangle,
    Shape::TriangleDown,
    Shape::Diamond,
    Shape::Octagon,
    Shape::Square,
];

/// Inner glyphs: coarse geometric marks a 32×32 CNN can discriminate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Glyph {
    HBar,
    VBar,
    Cross,
    Dot,
    TwoDots,
    ArrowUp,
    ArrowLeft,
    Ring,
}

const GLYPHS: [Glyph; 8] = [
    Glyph::HBar,
    Glyph::VBar,
    Glyph::Cross,
    Glyph::Dot,
    Glyph::TwoDots,
    Glyph::ArrowUp,
    Glyph::ArrowLeft,
    Glyph::Ring,
];

/// RGB triple in [0,1].
type Rgb = [f32; 3];

const RIM_COLOURS: [Rgb; 4] = [
    [0.80, 0.10, 0.12], // red
    [0.10, 0.25, 0.75], // blue
    [0.85, 0.70, 0.10], // amber
    [0.15, 0.15, 0.15], // black
];

/// Visual identity of one class.
#[derive(Clone, Copy, Debug)]
pub struct ClassSpec {
    pub shape: Shape,
    pub glyph: Glyph,
    pub rim: Rgb,
    pub face: Rgb,
}

/// Deterministic class table: 43 distinct (shape, glyph, rim) combos.
pub fn class_spec(class: usize) -> ClassSpec {
    assert!(class < NUM_CLASSES, "class {class} out of range");
    let shape = SHAPES[class % SHAPES.len()];
    let glyph = GLYPHS[(class / SHAPES.len() + class) % GLYPHS.len()];
    let rim = RIM_COLOURS[(class / 11) % RIM_COLOURS.len()];
    // plate face: white-ish for most, amber plates for diamonds
    let face = if shape == Shape::Diamond {
        [0.92, 0.78, 0.25]
    } else {
        [0.93, 0.93, 0.90]
    };
    ClassSpec { shape, glyph, rim, face }
}

/// Signed distance (negative = inside) of the unit-sized plate silhouette;
/// coordinates are in plate-local units where the plate spans ~[-1, 1].
fn shape_sdf(s: Shape, x: f32, y: f32) -> f32 {
    match s {
        Shape::Circle => (x * x + y * y).sqrt() - 1.0,
        Shape::Square => x.abs().max(y.abs()) - 0.9,
        Shape::Diamond => (x.abs() + y.abs()) - 1.15,
        Shape::Octagon => {
            let a = x.abs().max(y.abs());
            let b = (x.abs() + y.abs()) * std::f32::consts::FRAC_1_SQRT_2;
            a.max(b) - 0.95
        }
        Shape::Triangle => {
            // upward triangle: three half-plane constraints
            let d1 = -y - 0.75; // bottom edge y > -0.75 inside
            let d2 = 0.866 * x + 0.5 * y - 0.55;
            let d3 = -0.866 * x + 0.5 * y - 0.55;
            d1.max(d2).max(d3)
        }
        Shape::TriangleDown => {
            let d1 = y - 0.75;
            let d2 = 0.866 * x - 0.5 * y - 0.55;
            let d3 = -0.866 * x - 0.5 * y - 0.55;
            d1.max(d2).max(d3)
        }
    }
}

/// Glyph mask (true = glyph pixel) in plate-local coordinates.
fn glyph_hit(g: Glyph, x: f32, y: f32) -> bool {
    match g {
        Glyph::HBar => x.abs() < 0.55 && y.abs() < 0.16,
        Glyph::VBar => x.abs() < 0.16 && y.abs() < 0.55,
        Glyph::Cross => {
            (x.abs() < 0.14 && y.abs() < 0.5) || (y.abs() < 0.14 && x.abs() < 0.5)
        }
        Glyph::Dot => x * x + y * y < 0.20 * 0.20 * 4.0,
        Glyph::TwoDots => {
            let d1 = (x + 0.3) * (x + 0.3) + y * y;
            let d2 = (x - 0.3) * (x - 0.3) + y * y;
            d1 < 0.05 || d2 < 0.05
        }
        Glyph::ArrowUp => {
            let head = y > 0.05 && y < 0.55 && x.abs() < (0.55 - y) * 0.8;
            let stem = y <= 0.05 && y > -0.5 && x.abs() < 0.12;
            head || stem
        }
        Glyph::ArrowLeft => {
            let head = x < -0.05 && x > -0.55 && y.abs() < (0.55 + x) * 0.8;
            let stem = x >= -0.05 && x < 0.5 && y.abs() < 0.12;
            head || stem
        }
        Glyph::Ring => {
            let r = (x * x + y * y).sqrt();
            (0.30..0.52).contains(&r)
        }
    }
}

/// Per-sample nuisance parameters (the "real-world variability").
#[derive(Clone, Copy, Debug)]
struct Jitter {
    cx: f32,
    cy: f32,
    radius: f32,
    rot_sin: f32,
    rot_cos: f32,
    brightness: f32,
    contrast: f32,
    bg: Rgb,
    bg_grad: [f32; 2],
    noise_std: f32,
}

impl Jitter {
    fn draw(rng: &mut Rng) -> Self {
        let ang = rng.uniform_in(-0.30, 0.30); // ±17°
        Jitter {
            cx: 16.0 + rng.uniform_in(-2.5, 2.5),
            cy: 16.0 + rng.uniform_in(-2.5, 2.5),
            radius: rng.uniform_in(9.0, 13.0),
            rot_sin: ang.sin(),
            rot_cos: ang.cos(),
            brightness: rng.uniform_in(-0.12, 0.12),
            contrast: rng.uniform_in(0.75, 1.20),
            bg: [
                rng.uniform_in(0.15, 0.65),
                rng.uniform_in(0.20, 0.70),
                rng.uniform_in(0.15, 0.60),
            ],
            bg_grad: [rng.uniform_in(-0.004, 0.004), rng.uniform_in(-0.006, 0.002)],
            noise_std: rng.uniform_in(0.01, 0.06),
        }
    }
}

/// Render one sample into `out` (length SAMPLE_LEN, HWC layout, values
/// roughly in [0,1] before noise).
pub fn render_into(class: usize, rng: &mut Rng, out: &mut [f32]) {
    assert_eq!(out.len(), SAMPLE_LEN);
    let spec = class_spec(class);
    let j = Jitter::draw(rng);
    let inv_r = 1.0 / j.radius;
    for py in 0..IMG {
        for px in 0..IMG {
            // plate-local coordinates (rotate + scale + translate inverse)
            let dx = (px as f32 - j.cx) * inv_r;
            let dy = (py as f32 - j.cy) * inv_r;
            let x = j.rot_cos * dx + j.rot_sin * dy;
            let y = -j.rot_sin * dx + j.rot_cos * dy;

            let sdf = shape_sdf(spec.shape, x, y);
            let mut rgb = if sdf > 0.0 {
                // background with a soft vertical/horizontal gradient
                [
                    j.bg[0] + j.bg_grad[0] * px as f32 + j.bg_grad[1] * py as f32,
                    j.bg[1] + j.bg_grad[0] * px as f32 + j.bg_grad[1] * py as f32,
                    j.bg[2] + j.bg_grad[0] * px as f32 - j.bg_grad[1] * py as f32,
                ]
            } else if sdf > -0.22 {
                spec.rim
            } else if glyph_hit(spec.glyph, x * 1.4, y * 1.4) {
                [0.08, 0.08, 0.08]
            } else {
                spec.face
            };
            // lighting + sensor noise
            for c in 0..CHANNELS {
                let v = (rgb[c] - 0.5) * j.contrast + 0.5 + j.brightness;
                rgb[c] = (v + rng.normal_f32(0.0, j.noise_std)).clamp(0.0, 1.0);
            }
            let base = (py * IMG + px) * CHANNELS;
            out[base] = rgb[0];
            out[base + 1] = rgb[1];
            out[base + 2] = rgb[2];
        }
    }
}

/// Convenience allocation wrapper around [`render_into`].
pub fn render(class: usize, rng: &mut Rng) -> Vec<f32> {
    let mut out = vec![0.0f32; SAMPLE_LEN];
    render_into(class, rng, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_are_distinct() {
        // every class must differ from every other in at least one of
        // (shape, glyph, rim)
        for a in 0..NUM_CLASSES {
            for b in (a + 1)..NUM_CLASSES {
                let sa = class_spec(a);
                let sb = class_spec(b);
                let same = sa.shape == sb.shape
                    && sa.glyph == sb.glyph
                    && sa.rim == sb.rim;
                assert!(!same, "classes {a} and {b} visually identical");
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn class_out_of_range_panics() {
        let _ = class_spec(43);
    }

    #[test]
    fn render_is_deterministic_per_seed() {
        let mut r1 = Rng::seed_from(5).substream(3);
        let mut r2 = Rng::seed_from(5).substream(3);
        assert_eq!(render(7, &mut r1), render(7, &mut r2));
    }

    #[test]
    fn render_values_in_unit_range() {
        let mut rng = Rng::seed_from(6);
        for class in [0usize, 11, 42] {
            let img = render(class, &mut rng);
            assert_eq!(img.len(), SAMPLE_LEN);
            assert!(img.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn sign_pixels_differ_from_background() {
        // centre pixel should usually be plate face / glyph, not background:
        // render many and check the centre differs from a corner on average
        let mut rng = Rng::seed_from(7);
        let mut centre_diff = 0.0f32;
        let n = 50;
        for class in 0..n {
            let img = render(class % NUM_CLASSES, &mut rng);
            let c = (16 * IMG + 16) * CHANNELS;
            let corner = 0;
            centre_diff +=
                (img[c] - img[corner]).abs() + (img[c + 1] - img[corner + 1]).abs();
        }
        assert!(centre_diff / n as f32 > 0.05, "signs invisible?");
    }

    #[test]
    fn same_class_varies_across_samples() {
        let mut rng = Rng::seed_from(8);
        let a = render(3, &mut rng);
        let b = render(3, &mut rng);
        assert_ne!(a, b, "augmentation missing");
    }

    #[test]
    fn sdf_shapes_inside_outside() {
        for s in SHAPES {
            assert!(shape_sdf(s, 0.0, 0.0) < 0.0, "{s:?} centre must be inside");
            assert!(shape_sdf(s, 3.0, 3.0) > 0.0, "{s:?} far point must be outside");
        }
    }
}
