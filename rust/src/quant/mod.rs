//! Algorithm 2 of the paper, mirrored in rust.
//!
//! This is the SAME math as the L1 Pallas kernels
//! (`python/compile/kernels/quantize.py`) and their jnp oracles
//! (`kernels/ref.py`), re-implemented for the coordinator's runtime needs:
//!
//! * re-quantizing the broadcast global model to each client's precision
//!   (Fig. 2c of the paper, Alg. 1 step 2) without a PJRT round-trip;
//! * post-training quantization for the Table-I study;
//! * the digital-orthogonal baseline, which transmits actual integer codes
//!   and therefore needs `quantize` / `dequantize` (not just fake-quant).
//!
//! Bit-exactness contract: for every test vector in `artifacts/goldens.json`
//! (emitted by aot.py from the jnp oracle) the rust output must be
//! IDENTICAL at the bit level — both sides run plain IEEE-754 f32 ops in
//! the same order.  `rust/tests/goldens.rs` enforces this.

pub mod fixed;
pub mod float;

use anyhow::{bail, Result};

/// Precision levels usable by clients (paper §IV-A2 draws schemes from
/// [32, 24, 16, 12, 8, 6, 4]; Table I additionally probes 3 and 2).
pub const SUPPORTED_LEVELS: [u8; 9] = [32, 24, 16, 12, 8, 6, 4, 3, 2];

/// Number format backing a precision level (DESIGN.md §3 mapping).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// 32-bit IEEE-754: the identity.
    Identity,
    /// Mantissa truncation keeping 1 sign + 8 exponent + (b-9) mantissa
    /// bits (paper: float formats supported at >= 8 bits; we use it for
    /// 24/16/12 where the exponent still fits).
    FloatTrunc,
    /// Per-tensor affine fixed point (paper: preferred below 8 bits due to
    /// float's limited sub-8-bit dynamic range).
    FixedPoint,
}

/// A validated precision level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Precision {
    bits: u8,
}

impl Precision {
    pub fn new(bits: u8) -> Result<Self> {
        if !SUPPORTED_LEVELS.contains(&bits) {
            bail!(
                "unsupported precision {bits}; supported: {:?}",
                SUPPORTED_LEVELS
            );
        }
        Ok(Precision { bits })
    }

    /// Panicking constructor for statically-known levels (tests, tables).
    pub fn of(bits: u8) -> Self {
        Precision::new(bits).expect("static precision level")
    }

    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn format(&self) -> Format {
        match self.bits {
            32 => Format::Identity,
            24 | 16 | 12 => Format::FloatTrunc,
            _ => Format::FixedPoint,
        }
    }

    /// Quantization levels for the fixed-point branch (2^b - 1 is the max
    /// code, matching Algorithm 2's clip range).
    pub fn max_code(&self) -> u32 {
        (1u32 << self.bits) - 1
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-bit", self.bits)
    }
}

impl std::str::FromStr for Precision {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Precision::new(s.trim().parse::<u8>()?)
    }
}

/// Rounding rule for the fixed-point branch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rounding {
    /// Algorithm 2 verbatim — transmission payloads, PTQ, digital frames.
    Floor,
    /// Round-half-even — the training-state grid (matches the L2 QAT
    /// quantizer bit-for-bit; see quant::fixed docs).
    Nearest,
}

/// Fake-quantize out-of-place: returns the de-quantized decimal values —
/// exactly what the paper's analog amplitude modulation transmits.
pub fn fake_quant(w: &[f32], p: Precision) -> Vec<f32> {
    let mut out = w.to_vec();
    fake_quant_inplace(&mut out, p);
    out
}

/// Fake-quantize in place (the hot-path form: no allocation).
pub fn fake_quant_inplace(w: &mut [f32], p: Precision) {
    fake_quant_inplace_mode(w, p, Rounding::Floor);
}

/// Fake-quantize with an explicit rounding rule (fixed-point branch only;
/// float truncation has no rounding choice).
pub fn fake_quant_inplace_mode(w: &mut [f32], p: Precision, r: Rounding) {
    match p.format() {
        Format::Identity => {}
        Format::FloatTrunc => float::truncate_inplace(w, p.bits()),
        Format::FixedPoint => {
            fixed::fake_quant_inplace_mode(w, p.bits(), r == Rounding::Nearest)
        }
    }
}

/// Out-of-place form of [`fake_quant_inplace_mode`].
pub fn fake_quant_mode(w: &[f32], p: Precision, r: Rounding) -> Vec<f32> {
    let mut out = w.to_vec();
    fake_quant_inplace_mode(&mut out, p, r);
    out
}

/// Per-LAYER quantization of a flat model vector (paper §III-B: "the
/// quantization function is systematically applied to every layer") —
/// each named tensor in the layout gets its own scale/zero-point, exactly
/// like the in-graph L2 quantizer.  Quantizing the whole flat vector with
/// one scale would let the largest layer's range destroy the small ones.
pub fn fake_quant_layout_inplace(
    w: &mut [f32],
    layout: &crate::tensor::ParamLayout,
    p: Precision,
    r: Rounding,
) {
    assert_eq!(w.len(), layout.total, "flat vector / layout mismatch");
    for e in &layout.entries {
        fake_quant_inplace_mode(&mut w[e.offset..e.offset + e.size], p, r);
    }
}

/// Out-of-place form of [`fake_quant_layout_inplace`].
pub fn fake_quant_layout(
    w: &[f32],
    layout: &crate::tensor::ParamLayout,
    p: Precision,
    r: Rounding,
) -> Vec<f32> {
    let mut out = w.to_vec();
    fake_quant_layout_inplace(&mut out, layout, p, r);
    out
}

/// Fused quantize+modulate: fake-quantize `src` directly into `dst` (no
/// copy pass, no allocation) — the hot-path form that writes a client's
/// decimal payload straight into its payload-plane row.  Bit-identical to
/// `fake_quant_mode(src, p, r)` for any `threads` (see the kernels-layer
/// determinism contract).
pub fn fake_quant_into(dst: &mut [f32], src: &[f32], p: Precision, r: Rounding, threads: usize) {
    assert_eq!(dst.len(), src.len());
    match p.format() {
        Format::Identity => dst.copy_from_slice(src),
        Format::FloatTrunc => float::truncate_into(dst, src, p.bits(), threads),
        Format::FixedPoint => {
            fixed::fake_quant_into_mode(dst, src, p.bits(), r == Rounding::Nearest, threads)
        }
    }
}

/// Per-layer fused form of [`fake_quant_into`]: every named tensor of the
/// layout gets its own scale/zero-point, written straight from `src` into
/// `dst`.  Bit-identical to [`fake_quant_layout`] for any `threads`.
pub fn fake_quant_layout_into(
    dst: &mut [f32],
    src: &[f32],
    layout: &crate::tensor::ParamLayout,
    p: Precision,
    r: Rounding,
    threads: usize,
) {
    assert_eq!(src.len(), layout.total, "flat vector / layout mismatch");
    assert_eq!(dst.len(), layout.total, "flat vector / layout mismatch");
    for e in &layout.entries {
        let range = e.offset..e.offset + e.size;
        fake_quant_into(&mut dst[range.clone()], &src[range], p, r, threads);
    }
}

/// Worst-case quantization step for a tensor at precision `p` — used for
/// error budgeting in tests and the OTA MSE diagnostics.
pub fn quant_step(w: &[f32], p: Precision) -> f32 {
    match p.format() {
        Format::Identity => 0.0,
        Format::FloatTrunc => {
            // relative step 2^-(mantissa kept) of the largest magnitude
            let max = w.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            max * (2.0f32).powi(-((p.bits() as i32) - 9))
        }
        Format::FixedPoint => {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for &v in w {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            if !lo.is_finite() || !hi.is_finite() {
                return 0.0;
            }
            ((hi - lo) / p.max_code() as f32).max(1e-12)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_validation() {
        assert!(Precision::new(32).is_ok());
        assert!(Precision::new(4).is_ok());
        assert!(Precision::new(5).is_err());
        assert!(Precision::new(0).is_err());
        assert!(Precision::new(64).is_err());
    }

    #[test]
    fn format_mapping_matches_design() {
        assert_eq!(Precision::of(32).format(), Format::Identity);
        for b in [24u8, 16, 12] {
            assert_eq!(Precision::of(b).format(), Format::FloatTrunc, "{b}");
        }
        for b in [8u8, 6, 4, 3, 2] {
            assert_eq!(Precision::of(b).format(), Format::FixedPoint, "{b}");
        }
    }

    #[test]
    fn parse_and_display() {
        let p: Precision = "16".parse().unwrap();
        assert_eq!(p.bits(), 16);
        assert_eq!(p.to_string(), "16-bit");
        assert!("5".parse::<Precision>().is_err());
        assert!("x".parse::<Precision>().is_err());
    }

    #[test]
    fn identity_is_exact() {
        let w = [1.0f32, -2.5, 3.7e-9, 1e30];
        assert_eq!(fake_quant(&w, Precision::of(32)), w.to_vec());
    }

    #[test]
    fn fake_quant_error_bounded_by_step() {
        let mut rng = crate::rng::Rng::seed_from(1);
        let w: Vec<f32> = (0..1000).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        for bits in [24u8, 16, 12, 8, 6, 4, 3, 2] {
            let p = Precision::of(bits);
            let q = fake_quant(&w, p);
            let step = quant_step(&w, p);
            let max_err = w
                .iter()
                .zip(q.iter())
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err <= step * 1.001 + 1e-6,
                "bits={bits} err={max_err} step={step}"
            );
        }
    }

    #[test]
    fn max_code() {
        assert_eq!(Precision::of(8).max_code(), 255);
        assert_eq!(Precision::of(4).max_code(), 15);
        assert_eq!(Precision::of(2).max_code(), 3);
    }

    #[test]
    fn fused_into_bit_identical_to_copy_then_inplace() {
        let mut rng = crate::rng::Rng::seed_from(23);
        let mut w = vec![0.0f32; 20_000];
        rng.fill_normal(&mut w, 0.0, 2.0);
        for bits in SUPPORTED_LEVELS {
            let p = Precision::of(bits);
            for r in [Rounding::Floor, Rounding::Nearest] {
                let want = fake_quant_mode(&w, p, r);
                for threads in [1usize, 4] {
                    let mut dst = vec![f32::NAN; w.len()];
                    fake_quant_into(&mut dst, &w, p, r, threads);
                    let same = dst
                        .iter()
                        .zip(want.iter())
                        .all(|(a, b)| a.to_bits() == b.to_bits());
                    assert!(same, "bits={bits} rounding={r:?} threads={threads}");
                }
            }
        }
    }

    #[test]
    fn fused_layout_into_bit_identical() {
        let layout = crate::tensor::ParamLayout::from_manifest(
            &crate::json::parse(r#"[["w", [100, 70]], ["b", [70]], ["head", [5000]]]"#)
                .unwrap(),
        )
        .unwrap();
        let mut rng = crate::rng::Rng::seed_from(24);
        let mut w = vec![0.0f32; layout.total];
        rng.fill_normal(&mut w, 0.0, 1.0);
        for bits in [16u8, 8, 4] {
            let p = Precision::of(bits);
            let want = fake_quant_layout(&w, &layout, p, Rounding::Nearest);
            for threads in [1usize, 4] {
                let mut dst = vec![f32::NAN; w.len()];
                fake_quant_layout_into(&mut dst, &w, &layout, p, Rounding::Nearest, threads);
                let same = dst
                    .iter()
                    .zip(want.iter())
                    .all(|(a, b)| a.to_bits() == b.to_bits());
                assert!(same, "bits={bits} threads={threads}");
            }
        }
    }
}
