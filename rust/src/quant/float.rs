//! Floating-point truncation — Algorithm 2, "floating-point" branch.
//!
//! Keeps 1 sign bit, the full 8-bit exponent and the top (b - 9) mantissa
//! bits of the IEEE-754 single; the dropped mantissa bits are zeroed
//! (truncation toward zero in magnitude, exactly like the jnp oracle's
//! `u & (0xFFFFFFFF << drop)`).

use anyhow::{bail, Result};

/// Bit mask keeping sign+exponent+(bits-9) mantissa bits.
pub fn mask(bits: u8) -> Result<u32> {
    if bits >= 32 {
        return Ok(0xFFFF_FFFF);
    }
    if bits < 10 {
        bail!("float truncation needs >= 10 bits, got {bits}");
    }
    let mant_keep = (bits - 9) as u32;
    let drop = 23 - mant_keep;
    Ok(0xFFFF_FFFFu32 << drop)
}

/// Truncate one value.
#[inline]
pub fn truncate(v: f32, mask: u32) -> f32 {
    f32::from_bits(v.to_bits() & mask)
}

/// Truncate a slice in place.
pub fn truncate_inplace(w: &mut [f32], bits: u8) {
    let m = mask(bits).expect("validated precision level");
    for v in w.iter_mut() {
        *v = truncate(*v, m);
    }
}

/// Fused out-of-place truncation: reads `src`, writes truncated values
/// into `dst` (no copy pass).  Elementwise, so bit-identical to
/// [`truncate_inplace`] on a copy for any `threads`.
pub fn truncate_into(dst: &mut [f32], src: &[f32], bits: u8, threads: usize) {
    assert_eq!(dst.len(), src.len());
    let m = mask(bits).expect("validated precision level");
    crate::kernels::par::par_chunks_mut(threads, dst, |off, chunk| {
        let s = &src[off..off + chunk.len()];
        for (d, &v) in chunk.iter_mut().zip(s.iter()) {
            *d = truncate(v, m);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn mask_values() {
        assert_eq!(mask(32).unwrap(), 0xFFFF_FFFF);
        // 16-bit: 1+8+7 -> drop 16 mantissa bits
        assert_eq!(mask(16).unwrap(), 0xFFFF_0000);
        // 12-bit: 1+8+3 -> drop 20
        assert_eq!(mask(12).unwrap(), 0xFFF0_0000);
        // 24-bit: 1+8+15 -> drop 8
        assert_eq!(mask(24).unwrap(), 0xFFFF_FF00);
        assert!(mask(9).is_err());
    }

    #[test]
    fn magnitude_never_grows_sign_preserved() {
        let mut rng = Rng::seed_from(3);
        for bits in [24u8, 16, 12] {
            let m = mask(bits).unwrap();
            for _ in 0..2000 {
                let v = rng.normal_f32(0.0, 100.0);
                let t = truncate(v, m);
                assert!(t.abs() <= v.abs());
                assert!(t == 0.0 || t.signum() == v.signum());
            }
        }
    }

    #[test]
    fn relative_error_bounded() {
        let mut rng = Rng::seed_from(4);
        for bits in [24u8, 16, 12] {
            let m = mask(bits).unwrap();
            let bound = (2.0f32).powi(-((bits as i32) - 9));
            for _ in 0..2000 {
                let v = rng.normal_f32(0.0, 10.0);
                if v.abs() < 1e-30 {
                    continue;
                }
                let t = truncate(v, m);
                let rel = ((v - t) / v).abs();
                assert!(rel < bound, "bits={bits} v={v} t={t} rel={rel}");
            }
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::seed_from(5);
        for bits in [24u8, 16, 12] {
            let mut w: Vec<f32> = (0..256).map(|_| rng.normal_f32(0.0, 7.0)).collect();
            truncate_inplace(&mut w, bits);
            let once = w.clone();
            truncate_inplace(&mut w, bits);
            assert_eq!(w, once, "bits={bits}");
        }
    }

    #[test]
    fn special_values() {
        let m = mask(16).unwrap();
        assert_eq!(truncate(0.0, m), 0.0);
        assert_eq!(truncate(-0.0, m), -0.0);
        assert!(truncate(f32::INFINITY, m).is_infinite());
        assert!(truncate(f32::NAN, m).is_nan());
        // powers of two are exactly representable at any mantissa width
        for e in -10..10 {
            let v = (2.0f32).powi(e);
            assert_eq!(truncate(v, m), v);
        }
    }

    #[test]
    fn coarser_precision_is_coarser() {
        // every 12-bit representable value is also 16-bit representable
        let mut rng = Rng::seed_from(6);
        let m12 = mask(12).unwrap();
        let m16 = mask(16).unwrap();
        for _ in 0..500 {
            let v = rng.normal_f32(0.0, 5.0);
            let t12 = truncate(v, m12);
            assert_eq!(truncate(t12, m16), t12);
        }
    }
}
