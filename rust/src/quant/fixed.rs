//! Fixed-point affine quantization — Algorithm 2, "fixed" branch.
//!
//! All arithmetic is f32 in the exact op order of the jnp oracle
//! (`kernels/ref.py::fixed_point_fake_quant`) so the two implementations
//! agree bit-for-bit (enforced against `artifacts/goldens.json`):
//!
//! ```text
//! scale = max((w_max - w_min) / (2^b - 1), 1e-12)
//! zp    = -w_min / scale
//! q     = clip(floor(w/scale + zp), 0, 2^b - 1)
//! out   = (q - zp) * scale
//! ```

/// Must match `_SCALE_EPS` in kernels/ref.py.
pub const SCALE_EPS: f32 = 1e-12;

/// Per-tensor affine parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AffineParams {
    pub scale: f32,
    pub zero_point: f32,
}

/// Compute scale / zero-point from the tensor's min/max (Algorithm 2 l.4-5).
pub fn params(w: &[f32], bits: u8) -> AffineParams {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in w {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if w.is_empty() {
        lo = 0.0;
        hi = 0.0;
    }
    params_from_range(lo, hi, bits)
}

/// Scale / zero-point from a precomputed `[lo, hi]` tensor range — the
/// [`params`] tail, split out so callers that never materialize an f32
/// slice (the packed digital path sweeps decoded codes) derive params
/// through the exact same arithmetic.
pub fn params_from_range(lo: f32, hi: f32, bits: u8) -> AffineParams {
    if hi == lo {
        // Degenerate all-equal tensor: the span is zero, so any scale
        // represents it.  Scale 1 makes code 0 decode to exactly `lo`
        // (q = floor(v - lo) = 0 → (0 + lo) · 1 = lo, both roundings),
        // where the span/levels formula would clamp the scale to 1e-12
        // and blow the zero-point up to -lo/1e-12, recovering the
        // constant only to float luck.
        return AffineParams { scale: 1.0, zero_point: -lo };
    }
    let levels = ((1u64 << bits) - 1) as f32;
    let scale = ((hi - lo) / levels).max(SCALE_EPS);
    AffineParams { scale, zero_point: -lo / scale }
}

/// Quantize one value to its integer code (Algorithm 2 l.7).
#[inline]
pub fn encode(v: f32, p: AffineParams, max_code: u32) -> u32 {
    let q = (v / p.scale + p.zero_point).floor();
    let q = q.clamp(0.0, max_code as f32);
    q as u32
}

/// De-quantize an integer code back to its decimal value.
#[inline]
pub fn decode(code: u32, p: AffineParams) -> f32 {
    (code as f32 - p.zero_point) * p.scale
}

/// Fake-quantize in place (encode+decode without materialising codes),
/// Algorithm-2 floor rounding.
pub fn fake_quant_inplace(w: &mut [f32], bits: u8) {
    fake_quant_inplace_mode(w, bits, false);
}

/// One element of Algorithm-2 fake-quantization, in the exact oracle op
/// order: div, add, round, clip, sub, mul.  The SINGLE source of truth for
/// the bit-exactness contract — both the in-place and the fused
/// quantize-into sweeps call this.
#[inline]
fn fake_quant_element(v: f32, p: AffineParams, levels: f32, nearest: bool) -> f32 {
    let pre = v / p.scale + p.zero_point;
    let q = if nearest { pre.round_ties_even() } else { pre.floor() };
    (q.clamp(0.0, levels) - p.zero_point) * p.scale
}

/// Fake-quantize in place with selectable rounding.
///
/// `nearest = false` — Algorithm 2 verbatim (floor): transmission payloads,
/// PTQ, digital baseline.
/// `nearest = true` — round-half-even (matches jnp.round bit-for-bit via
/// `round_ties_even`): the TRAINING-state grid, mirroring the L2 QAT
/// quantizer (see kernels/ref.py rounding note; Gupta et al. [16]).
pub fn fake_quant_inplace_mode(w: &mut [f32], bits: u8, nearest: bool) {
    let p = params(w, bits);
    let levels = ((1u64 << bits) - 1) as f32;
    for v in w.iter_mut() {
        *v = fake_quant_element(*v, p, levels, nearest);
    }
}

/// Fused out-of-place fake-quantization: reads `src`, writes the
/// de-quantized decimals straight into `dst` (e.g. a payload-plane row),
/// skipping the copy pass of the copy-then-inplace idiom.  Bit-identical
/// to [`fake_quant_inplace_mode`] on a copy of `src`, for any `threads`:
/// the affine parameters come from an exact min/max reduction and the map
/// itself is elementwise.
pub fn fake_quant_into_mode(
    dst: &mut [f32],
    src: &[f32],
    bits: u8,
    nearest: bool,
    threads: usize,
) {
    assert_eq!(dst.len(), src.len());
    let p = params(src, bits);
    let levels = ((1u64 << bits) - 1) as f32;
    crate::kernels::par::par_chunks_mut(threads, dst, |off, chunk| {
        let s = &src[off..off + chunk.len()];
        for (d, &v) in chunk.iter_mut().zip(s.iter()) {
            *d = fake_quant_element(v, p, levels, nearest);
        }
    });
}

/// Quantize a full tensor to integer codes + params (digital baseline path:
/// these codes are what a conventional FL uplink would actually transmit).
pub fn encode_tensor(w: &[f32], bits: u8) -> (Vec<u32>, AffineParams) {
    let p = params(w, bits);
    let max_code = ((1u64 << bits) - 1) as u32;
    (w.iter().map(|&v| encode(v, p, max_code)).collect(), p)
}

/// Inverse of [`encode_tensor`].
pub fn decode_tensor(codes: &[u32], p: AffineParams) -> Vec<f32> {
    codes.iter().map(|&c| decode(c, p)).collect()
}

/// u32 words needed to hold `n` codes of `bits` each, LSB-first.
///
/// [`encode_tensor`] spends a full u32 per code at any width; the packed
/// stream spends exactly `bits` bits per code, so a 4-bit row costs n/8
/// words instead of n.
pub const fn packed_words(n: usize, bits: u8) -> usize {
    (n * bits as usize).div_ceil(32)
}

/// Encode `w` into an LSB-first bit-packed code stream at `bits` per
/// value — the storage form behind [`crate::kernels::PackedPlane`].
/// `out` must be exactly `packed_words(w.len(), bits)` long and is fully
/// overwritten.  Returns the affine params the codes decode with; the
/// round trip `decode(unpack_code(..)) == fake_quant(w)` is bit-exact
/// because pack/unpack move the integer codes losslessly and
/// encode→decode already IS the fake-quant op sequence.
// mpota-lint: zero-alloc-hot
pub fn encode_packed(w: &[f32], bits: u8, out: &mut [u32]) -> AffineParams {
    let p = params(w, bits);
    let max_code = ((1u64 << bits) - 1) as u32;
    assert_eq!(out.len(), packed_words(w.len(), bits), "packed row width");
    out.fill(0);
    let b = bits as usize;
    for (i, &v) in w.iter().enumerate() {
        let code = encode(v, p, max_code);
        let off = i * b;
        let word = off / 32;
        let shift = off % 32;
        out[word] |= code << shift;
        if shift + b > 32 {
            // 3/6-bit codes can straddle a word boundary: the high bits
            // spill into the next word's low end
            out[word + 1] |= code >> (32 - shift);
        }
    }
    p
}

/// Extract code `idx` from an LSB-first bit-packed stream (inverse of
/// [`encode_packed`]'s placement; straddling codes reassemble through a
/// two-word u64 window).
#[inline]
pub fn unpack_code(words: &[u32], idx: usize, bits: u8) -> u32 {
    let b = bits as usize;
    let mask = ((1u64 << bits) - 1) as u32;
    let off = idx * b;
    let word = off / 32;
    let shift = off % 32;
    if shift + b <= 32 {
        (words[word] >> shift) & mask
    } else {
        let window = words[word] as u64 | ((words[word + 1] as u64) << 32);
        ((window >> shift) as u32) & mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn params_of_known_range() {
        let w = [0.0f32, 1.0];
        let p = params(&w, 8);
        assert!((p.scale - 1.0 / 255.0).abs() < 1e-9);
        assert_eq!(p.zero_point, 0.0);
    }

    #[test]
    fn constant_tensor_does_not_blow_up() {
        let mut w = vec![0.7311f32; 33];
        fake_quant_inplace(&mut w, 8);
        assert!(w.iter().all(|v| v.is_finite()));
        assert!(w.iter().all(|v| (v - 0.7311).abs() < 1e-3));
    }

    #[test]
    fn zeros_stay_zero() {
        let mut w = vec![0.0f32; 8];
        fake_quant_inplace(&mut w, 4);
        assert_eq!(w, vec![0.0f32; 8]);
    }

    #[test]
    fn encode_decode_roundtrip_is_fake_quant() {
        let mut rng = Rng::seed_from(5);
        let w: Vec<f32> = (0..512).map(|_| rng.normal_f32(0.0, 3.0)).collect();
        for bits in [8u8, 6, 4, 3, 2] {
            let (codes, p) = encode_tensor(&w, bits);
            let decoded = decode_tensor(&codes, p);
            let mut fq = w.clone();
            fake_quant_inplace(&mut fq, bits);
            assert_eq!(decoded, fq, "bits={bits}");
        }
    }

    #[test]
    fn codes_within_range() {
        let mut rng = Rng::seed_from(6);
        let w: Vec<f32> = (0..256).map(|_| rng.normal_f32(1.0, 10.0)).collect();
        for bits in [8u8, 4, 2] {
            let (codes, _) = encode_tensor(&w, bits);
            let max = ((1u64 << bits) - 1) as u32;
            assert!(codes.iter().all(|&c| c <= max));
            // extremes are hit: min maps to 0, max maps to max_code
            assert!(codes.contains(&0));
            assert!(codes.contains(&max));
        }
    }

    #[test]
    fn output_on_uniform_grid() {
        let mut rng = Rng::seed_from(7);
        let mut w: Vec<f32> = (0..400).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let p = params(&w, 4);
        fake_quant_inplace(&mut w, 4);
        let mut distinct: Vec<f32> = w.clone();
        distinct.sort_by(f32::total_cmp);
        distinct.dedup();
        assert!(distinct.len() <= 16, "levels {}", distinct.len());
        // consecutive distinct levels differ by ~scale
        for pair in distinct.windows(2) {
            let gap = pair[1] - pair[0];
            let ratio = gap / p.scale;
            assert!((ratio - ratio.round()).abs() < 1e-3, "gap {gap}");
        }
    }

    #[test]
    fn monotone_nondecreasing() {
        let mut rng = Rng::seed_from(8);
        let mut w: Vec<f32> = (0..300).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        w.sort_by(f32::total_cmp);
        let mut q = w.clone();
        fake_quant_inplace(&mut q, 6);
        for pair in q.windows(2) {
            assert!(pair[1] >= pair[0]);
        }
    }

    #[test]
    fn empty_tensor_ok() {
        let mut w: Vec<f32> = vec![];
        fake_quant_inplace(&mut w, 8);
        assert!(w.is_empty());
    }

    #[test]
    fn constant_rows_roundtrip_exactly_at_every_width() {
        // the degenerate all-equal case: scale 1 / zero-point -c makes
        // code 0 decode to exactly c — bit-for-bit, both roundings, at
        // every supported fixed-point width
        for bits in [2u8, 3, 4, 6, 8, 16] {
            for &c in &[0.7311f32, -42.0, 3.25e-8, -1.5e9, 1.0, -0.125] {
                let w = vec![c; 17];
                let p = params(&w, bits);
                assert_eq!(p.scale, 1.0, "bits={bits} c={c}");
                for nearest in [false, true] {
                    let mut fq = w.clone();
                    fake_quant_inplace_mode(&mut fq, bits, nearest);
                    for v in &fq {
                        assert_eq!(
                            v.to_bits(),
                            c.to_bits(),
                            "bits={bits} c={c} nearest={nearest}"
                        );
                    }
                }
                let (codes, cp) = encode_tensor(&w, bits);
                assert!(codes.iter().all(|&code| code == 0), "bits={bits} c={c}");
                for d in decode_tensor(&codes, cp) {
                    assert_eq!(d.to_bits(), c.to_bits(), "bits={bits} c={c}");
                }
            }
        }
    }

    #[test]
    fn constant_zero_rows_stay_zero_at_every_width() {
        // ±0.0 collapses to +0.0 through the affine round trip (the
        // zero-point negation normalises the sign), which is exact
        for bits in [2u8, 3, 4, 6, 8, 16] {
            for &c in &[0.0f32, -0.0] {
                let mut w = vec![c; 9];
                fake_quant_inplace(&mut w, bits);
                assert!(w.iter().all(|&v| v == 0.0), "bits={bits} c={c}");
            }
        }
    }

    #[test]
    fn packed_codes_roundtrip_encode_tensor_at_every_width() {
        let mut rng = Rng::seed_from(31);
        let w: Vec<f32> = (0..517).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        for bits in [2u8, 3, 4, 6, 8, 16] {
            let (codes, p) = encode_tensor(&w, bits);
            let mut packed = vec![0u32; packed_words(w.len(), bits)];
            let pp = encode_packed(&w, bits, &mut packed);
            assert_eq!(pp, p, "bits={bits}");
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(unpack_code(&packed, i, bits), c, "bits={bits} [{i}]");
            }
        }
    }

    #[test]
    fn packed_words_is_tight() {
        assert_eq!(packed_words(0, 4), 0);
        assert_eq!(packed_words(8, 4), 1);
        assert_eq!(packed_words(9, 4), 2);
        assert_eq!(packed_words(32, 2), 2);
        assert_eq!(packed_words(11, 3), 2); // 33 bits
        assert_eq!(packed_words(10, 16), 5);
        assert_eq!(packed_words(5, 6), 1); // 30 bits
    }
}
