//! Tiny property-testing harness (the vendored dependency set has no
//! proptest/quickcheck; this supplies the subset we need).
//!
//! A property runs against `CASES` randomly-generated inputs from a
//! deterministic seed.  On failure the harness performs greedy shrinking
//! on `Vec<f32>` inputs (halving length, zeroing elements) and reports the
//! smallest failing case — enough to make coordinator-invariant tests
//! (routing, batching, aggregation state) debuggable.

use crate::rng::Rng;

/// Default number of cases per property.
pub const CASES: usize = 64;

/// The PRE-KERNEL-LAYER analog OTA aggregation, replicated verbatim:
/// per-client axpy sweeps, sequential f64 power reduction, sequential
/// re-then-im pairwise Box-Muller noise, sequential scaling.  This is the
/// single source of truth for "the historical scalar path" — the golden
/// tests pin the fused kernels against it bit-for-bit and the `hotpaths`
/// bench measures speedups relative to it, so both always reference the
/// same baseline.  Returns (mean vector, participants, mse_vs_ideal).
pub fn reference_ota_aggregate(
    payloads: &[Vec<f32>],
    round: &crate::channel::RoundChannel,
    rng: &mut Rng,
) -> (Vec<f32>, usize, f64) {
    use crate::tensor;
    let n = payloads.first().map(|p| p.len()).unwrap_or(0);
    let mut y_re = vec![0.0f32; n];
    let mut y_im = vec![0.0f32; n];
    let mut ideal = vec![0.0f32; n];
    let mut participants = 0usize;
    for (k, payload) in payloads.iter().enumerate() {
        if let Some(g) = round.clients[k].effective_gain {
            tensor::axpy(&mut y_re, g.re, payload);
            tensor::axpy(&mut y_im, g.im, payload);
            tensor::axpy(&mut ideal, 1.0, payload);
            participants += 1;
        }
    }
    if participants == 0 {
        return (y_re, 0, 0.0);
    }
    let signal_power = (tensor::sq_norm(&y_re) + tensor::sq_norm(&y_im)) / n as f64;
    let noise_var = round.noise_var(signal_power as f32);
    if noise_var > 0.0 {
        let std = (noise_var * 0.5).sqrt();
        rng.add_normal(&mut y_re, std);
        rng.add_normal(&mut y_im, std);
    }
    let scale = 1.0 / participants as f32;
    tensor::scale(&mut y_re, scale);
    tensor::scale(&mut ideal, scale);
    let mse = tensor::mse(&y_re, &ideal);
    (y_re, participants, mse)
}

/// Run `prop` on `cases` random inputs produced by `gen`.
/// Panics with the (shrunk-by-regeneration) failing case index on failure.
pub fn check<T: std::fmt::Debug, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    // mpota-lint: allow(R4): property-test harness derives its root from the property name
    let root = Rng::seed_from(0x5EED_0000 ^ fnv(name));
    for case in 0..cases {
        let mut rng = root.substream(case as u64);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!("property '{name}' failed on case {case}: {input:?}");
        }
    }
}

/// Random f32 vector generator with varied length/scale per case.
pub fn gen_vec(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let len = 1 + rng.below(max_len);
    let scale = 10f32.powf(rng.uniform_in(-3.0, 3.0));
    let mut v = vec![0.0f32; len];
    rng.fill_normal(&mut v, 0.0, scale);
    // occasionally inject degenerate structure
    match rng.below(8) {
        0 => v.iter_mut().for_each(|x| *x = 0.0),
        1 => {
            let c = v[0];
            v.iter_mut().for_each(|x| *x = c);
        }
        2 => v.iter_mut().for_each(|x| *x = x.abs()),
        _ => {}
    }
    v
}

/// Shrinking check specialised to Vec<f32> inputs: on failure, repeatedly
/// tries halving the vector and zeroing prefixes to find a smaller witness.
pub fn check_vec<P>(name: &str, cases: usize, max_len: usize, mut prop: P)
where
    P: FnMut(&[f32]) -> bool,
{
    // mpota-lint: allow(R4): property-test harness derives its root from the property name
    let root = Rng::seed_from(0x5EED_0001 ^ fnv(name));
    for case in 0..cases {
        let mut rng = root.substream(case as u64);
        let input = gen_vec(&mut rng, max_len);
        if !prop(&input) {
            let witness = shrink_vec(&input, &mut prop);
            panic!(
                "property '{name}' failed on case {case}; shrunk witness \
                 (len {}): {:?}",
                witness.len(),
                &witness[..witness.len().min(16)]
            );
        }
    }
}

fn shrink_vec<P>(failing: &[f32], prop: &mut P) -> Vec<f32>
where
    P: FnMut(&[f32]) -> bool,
{
    let mut cur = failing.to_vec();
    loop {
        let mut improved = false;
        // try halves (must be strictly smaller, or we would loop forever)
        let mid = cur.len() / 2;
        for range in [0..mid, mid..cur.len()] {
            let half = cur[range].to_vec();
            if !half.is_empty() && half.len() < cur.len() && !prop(&half) {
                cur = half;
                improved = true;
                break;
            }
        }
        if improved {
            continue;
        }
        // try zeroing single elements
        for i in 0..cur.len() {
            if cur[i] != 0.0 {
                let mut cand = cur.clone();
                cand[i] = 0.0;
                if !prop(&cand) {
                    cur = cand;
                    improved = true;
                    break;
                }
            }
        }
        if !improved {
            return cur;
        }
    }
}

fn fnv(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------
// Deterministic mock execution backend (shared by the integration test
// binaries: `tests/sim.rs` full-run pins, `tests/shard_invariance.rs`).
// ---------------------------------------------------------------------

/// Model size of the mock variant: large enough that `threads = 4`
/// actually chunks the kernels (and even, per the noise determinism
/// contract).
pub const MOCK_PARAMS: usize = 20_480;

/// Write a minimal artifacts dir (manifest + init blob) so
/// `Runtime::load` succeeds without PJRT; all execution then goes through
/// [`MockTrainer`].  `tag` keeps concurrent test binaries/dirs apart.
pub fn mock_artifacts_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("mpota_sim_fixture_{tag}"));
    std::fs::create_dir_all(&dir).unwrap();
    let manifest = format!(
        r#"{{
          "version": 1, "train_batch": 8, "eval_batch": 16,
          "image": [32, 32, 3], "classes": 43, "padded_classes": 64,
          "flagship": "mock", "train_levels": [32, 16, 8, 4],
          "ota": {{"artifact": "ota.hlo.txt", "clients": 15, "chunk": 1024}},
          "goldens": "goldens.json",
          "variants": {{
            "mock": {{
              "param_count": {MOCK_PARAMS},
              "params": [["w", [160, 128]]],
              "artifacts": {{}},
              "init": "mock_init.f32.bin",
              "macs_per_sample": 1000
            }}
          }}
        }}"#
    );
    std::fs::write(dir.join("manifest.json"), manifest).unwrap();
    let mut init = vec![0.0f32; MOCK_PARAMS];
    // mpota-lint: allow(R4): fixed seed for the mock-artifact fixture init weights
    Rng::seed_from(7).stream("mock-init").fill_normal(&mut init, 0.0, 0.1);
    crate::tensor::write_f32_file(&dir.join("mock_init.f32.bin"), &init).unwrap();
    dir
}

fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Deterministic, `Sync`, pure-function trainer: the "SGD step" is an
/// integer-mixed pseudo-gradient of (precision, labels, image statistic),
/// so outputs depend only on the call's inputs — never on which thread or
/// in which order clients execute.  That makes it the reference backend
/// for the workers/shard bit-identity contracts.
#[derive(Clone)]
pub struct MockTrainer;

impl crate::exec::TrainBackend for MockTrainer {
    fn train_step(
        &self,
        p: crate::quant::Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> anyhow::Result<crate::runtime::TrainOutput> {
        let mut h = 0xABCD_EF01_2345_6789u64 ^ (p.bits() as u64);
        for &l in labels {
            h = mix(h ^ l as u64);
        }
        let mut s = 0.0f64;
        let mut i = 0usize;
        while i < images.len() {
            s += images[i] as f64;
            i += 257;
        }
        h = mix(h ^ s.to_bits());
        let mut new_theta = theta.to_vec();
        for (i, t) in new_theta.iter_mut().enumerate() {
            let g = (mix(h ^ i as u64) >> 40) as f32 / (1u64 << 24) as f32 - 0.5;
            *t -= lr * (0.1 * g + 0.05 * *t);
        }
        Ok(crate::runtime::TrainOutput {
            new_theta,
            loss: (mix(h ^ 1) % 1000) as f32 / 1000.0,
            correct: (mix(h ^ 2) % (labels.len() as u64 + 1)) as f32,
        })
    }

    fn evaluate(
        &self,
        theta: &[f32],
        _images: &[f32],
        labels: &[i32],
    ) -> anyhow::Result<crate::runtime::EvalResult> {
        let mut h = 0u64;
        for &t in theta.iter().step_by(97) {
            h = mix(h ^ t.to_bits() as u64);
        }
        Ok(crate::runtime::EvalResult {
            loss: (h % 100_000) as f64 / 100_000.0,
            accuracy: (mix(h) % 1000) as f64 / 1000.0,
            samples: labels.len(),
        })
    }
}

// ---------------------------------------------------------------------
// Convergence-science mock backend
// ---------------------------------------------------------------------

/// Deterministic, PJRT-free [`crate::exec::TrainBackend`] whose gradient
/// statistics depend on each batch's LABEL MARGINAL, so non-IID
/// convergence effects are testable without hardware.
///
/// The model: a shared synthetic optimum `opt` plus one unit direction
/// per class.  A batch with label histogram `w` pulls the model toward
/// the pseudo-optimum
///
/// ```text
/// θ*(w) = opt + δ · (Σ_c w_c · dir_c − mean_c dir_c)
/// ```
///
/// via one explicit SGD step on the quadratic ½‖θ − θ*(w)‖², plus a small
/// deterministic zero-mean perturbation (hash-derived, a pure function of
/// the call inputs — never of thread or execution order).  For IID shards
/// the batch marginal is a noisy draw around uniform, so displacements
/// cancel across clients and rounds and the fleet contracts to `opt`; a
/// Dirichlet(α) shard concentrates `w` on few classes, giving each client
/// a persistently displaced optimum whose unweighted fleet mean no longer
/// cancels — the classic FedAvg heterogeneity penalty, here measurable as
/// a higher final [`evaluate`](crate::exec::TrainBackend::evaluate) loss
/// against the shared `opt`.  Aggregation noise (AnalogOta at low SNR)
/// perturbs the global model directly and slows every partition alike.
///
/// Implements the allocation-free
/// [`train_step_into`](crate::exec::TrainStep::train_step_into) seam, so
/// warm full-FL rounds through this backend stay heap-silent.
pub struct GradStatsBackend {
    dim: usize,
    /// Shared optimum (the evaluation target).
    opt: Vec<f32>,
    /// Per-class unit directions, row-major `[NUM_CLASSES][dim]`.
    dirs: Vec<f32>,
    /// Mean over classes of `dirs` (the uniform-marginal displacement).
    dir_mean: Vec<f32>,
    /// Displacement strength δ.
    delta: f32,
    /// Zero-mean per-step perturbation scale σ.
    sigma: f32,
}

impl GradStatsBackend {
    pub fn new(dim: usize) -> Self {
        use crate::data::NUM_CLASSES;
        // mpota-lint: allow(R4): fixed seed for the synthetic-optimum fixture
        let root = Rng::seed_from(0x6EAD_57A7);
        let mut opt = vec![0.0f32; dim];
        root.stream("opt").fill_normal(&mut opt, 0.0, 0.3);
        let mut dirs = vec![0.0f32; NUM_CLASSES * dim];
        let mut dir_rng = root.stream("dirs");
        let mut dir_mean = vec![0.0f32; dim];
        for c in 0..NUM_CLASSES {
            let row = &mut dirs[c * dim..(c + 1) * dim];
            dir_rng.fill_normal(row, 0.0, 1.0);
            let norm = row.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            let inv = (1.0 / norm.max(1e-12)) as f32;
            for (m, r) in dir_mean.iter_mut().zip(row.iter_mut()) {
                *r *= inv;
                *m += *r / NUM_CLASSES as f32;
            }
        }
        GradStatsBackend { dim, opt, dirs, dir_mean, delta: 2.0, sigma: 0.02 }
    }

    /// The backend sized for the mock artifacts fixture.
    pub fn for_mock() -> Self {
        GradStatsBackend::new(MOCK_PARAMS)
    }
}

impl crate::exec::TrainBackend for GradStatsBackend {
    fn train_step(
        &self,
        p: crate::quant::Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> anyhow::Result<crate::runtime::TrainOutput> {
        let mut new_theta = vec![0.0f32; theta.len()];
        let m = crate::exec::TrainBackend::train_step_into(
            self, p, theta, images, labels, lr, &mut new_theta,
        )?;
        Ok(crate::runtime::TrainOutput {
            new_theta,
            loss: m.loss,
            correct: m.correct,
        })
    }

    fn train_step_into(
        &self,
        _p: crate::quant::Precision,
        theta: &[f32],
        _images: &[f32],
        labels: &[i32],
        lr: f32,
        new_theta_out: &mut [f32],
    ) -> anyhow::Result<crate::exec::StepMetrics> {
        use crate::data::NUM_CLASSES;
        assert_eq!(theta.len(), self.dim, "model size != backend dim");
        // batch label histogram -> the (class, weight) pairs present
        let mut counts = [0u32; NUM_CLASSES];
        let mut h = 0x6A09_E667_F3BC_C908u64;
        for &l in labels {
            counts[l as usize] += 1;
            h = mix(h ^ l as u64);
        }
        // fold a strided model checksum in so the perturbation decorrelates
        // across rounds even for a frozen batch order
        for &t in theta.iter().step_by(997) {
            h = mix(h ^ t.to_bits() as u64);
        }
        let inv_b = 1.0f32 / labels.len() as f32;
        let mut cls = [0usize; NUM_CLASSES];
        let mut wgt = [0f32; NUM_CLASSES];
        let mut present = 0usize;
        for (c, &n) in counts.iter().enumerate() {
            if n > 0 {
                cls[present] = c;
                wgt[present] = n as f32 * inv_b;
                present += 1;
            }
        }
        let mut sumsq = 0.0f64;
        for j in 0..self.dim {
            let mut s = 0.0f32;
            for i in 0..present {
                s += wgt[i] * self.dirs[cls[i] * self.dim + j];
            }
            let target = self.opt[j] + self.delta * (s - self.dir_mean[j]);
            let noise = (mix(h ^ j as u64) >> 40) as f32 / (1u64 << 24) as f32 - 0.5;
            let resid = theta[j] - target;
            sumsq += (resid as f64) * (resid as f64);
            new_theta_out[j] = theta[j] - lr * (resid + self.sigma * noise);
        }
        let loss = (0.5 * sumsq / self.dim as f64) as f32;
        Ok(crate::exec::StepMetrics {
            loss,
            correct: labels.len() as f32 / (1.0 + 50.0 * loss),
        })
    }

    fn evaluate(
        &self,
        theta: &[f32],
        _images: &[f32],
        labels: &[i32],
    ) -> anyhow::Result<crate::runtime::EvalResult> {
        assert_eq!(theta.len(), self.dim, "model size != backend dim");
        let mut sumsq = 0.0f64;
        for (t, o) in theta.iter().zip(self.opt.iter()) {
            let d = (t - o) as f64;
            sumsq += d * d;
        }
        let loss = 0.5 * sumsq / self.dim as f64;
        Ok(crate::runtime::EvalResult {
            loss,
            accuracy: 1.0 / (1.0 + 50.0 * loss),
            samples: labels.len(),
        })
    }
}

/// Relative-or-absolute closeness for float comparisons in tests.
pub fn close(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum-commutes", 32, |r| (r.uniform(), r.uniform()), |&(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics() {
        check("always-false", 4, |r| r.next_u64(), |_| false);
    }

    #[test]
    fn vec_generator_hits_degenerate_cases() {
        let mut zeros = false;
        let mut constant = false;
        let root = Rng::seed_from(1);
        for i in 0..200 {
            let mut rng = root.substream(i);
            let v = gen_vec(&mut rng, 64);
            if v.iter().all(|&x| x == 0.0) {
                zeros = true;
            } else if v.len() > 1 && v.windows(2).all(|w| w[0] == w[1]) {
                constant = true;
            }
        }
        assert!(zeros && constant, "zeros={zeros} constant={constant}");
    }

    #[test]
    #[should_panic(expected = "shrunk witness")]
    fn shrinker_reports_small_witness() {
        // property "vector is empty" always fails (gen emits len >= 1) and
        // shrinks to a length-1 witness
        check_vec("bounded", 4, 256, |v| v.is_empty());
    }

    #[test]
    fn close_helper() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0));
        assert!(close(0.0, 1e-12, 0.0, 1e-9));
        assert!(!close(1.0, 2.0, 1e-3, 1e-3));
    }
}
