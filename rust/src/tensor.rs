//! Flat-parameter bookkeeping and the small vector-math kernel set used on
//! the L3 hot path.
//!
//! Model state lives as ONE flat `Vec<f32>` everywhere in the coordinator —
//! that is the representation that gets amplitude-modulated for OTA
//! aggregation — and the layout (which slice is which layer) comes verbatim
//! from `artifacts/manifest.json`, written by the same python that lowered
//! the graphs.  Rust never re-derives shapes.

use anyhow::{bail, Context, Result};

use crate::json::Value;

/// One named parameter tensor inside the flat vector.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Ordered layout of a variant's flat parameter vector.
#[derive(Clone, Debug, Default)]
pub struct ParamLayout {
    pub entries: Vec<ParamEntry>,
    pub total: usize,
}

impl ParamLayout {
    /// Build from the manifest's `"params": [[name, [shape...]], ...]`.
    pub fn from_manifest(params: &Value) -> Result<Self> {
        let mut entries = Vec::new();
        let mut offset = 0usize;
        for pair in params.as_array()? {
            let pair = pair.as_array()?;
            if pair.len() != 2 {
                bail!("param spec entry must be [name, shape]");
            }
            let name = pair[0].as_str()?.to_string();
            let shape = pair[1].as_usize_vec()?;
            let size = shape.iter().product::<usize>().max(1);
            entries.push(ParamEntry { name, shape, offset, size });
            offset += size;
        }
        Ok(ParamLayout { entries, total: offset })
    }

    pub fn entry(&self, name: &str) -> Option<&ParamEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Slice a named tensor out of a flat vector.
    pub fn view<'a>(&self, flat: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let e = self
            .entry(name)
            .with_context(|| format!("unknown param '{name}'"))?;
        Ok(&flat[e.offset..e.offset + e.size])
    }
}

// ---------------------------------------------------------------- file I/O

/// Read a little-endian f32 blob (e.g. `<variant>_init.f32.bin`).
pub fn read_f32_file(path: &std::path::Path) -> Result<Vec<f32>> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{} length {} not a multiple of 4", path.display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write a little-endian f32 blob (checkpoints, pretrained params).
pub fn write_f32_file(path: &std::path::Path, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
}

// ---------------------------------------------------------- vector kernels
//
// The aggregation hot loop works over ~1e5..1e8-element f32 slices.  These
// are written as straightforward indexable loops that LLVM auto-vectorizes;
// `hotpaths` benches track their throughput (EXPERIMENTS.md §Perf).

/// y += alpha * x
pub fn axpy(y: &mut [f32], alpha: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * *xi;
    }
}

/// y += alpha * x, chunk-parallel (bit-identical to [`axpy`] for any
/// thread count: elementwise work, deterministic chunk grid).
pub fn axpy_par(y: &mut [f32], alpha: f32, x: &[f32], threads: usize) {
    assert_eq!(y.len(), x.len());
    crate::kernels::par::par_chunks_mut(threads, y, |off, chunk| {
        axpy(chunk, alpha, &x[off..off + chunk.len()]);
    });
}

/// y = x (copy)
pub fn assign(y: &mut [f32], x: &[f32]) {
    assert_eq!(y.len(), x.len());
    y.copy_from_slice(x);
}

/// dst = a - b, elementwise (the update-payload build Δθ = θ_k - θ_start,
/// written straight into a payload-plane row — no intermediate vector).
pub fn diff_into(dst: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(dst.len(), a.len());
    assert_eq!(dst.len(), b.len());
    for i in 0..dst.len() {
        dst[i] = a[i] - b[i];
    }
}

/// x *= alpha
pub fn scale(x: &mut [f32], alpha: f32) {
    for xi in x.iter_mut() {
        *xi *= alpha;
    }
}

/// x *= alpha, chunk-parallel (bit-identical to [`scale`]).
pub fn scale_par(x: &mut [f32], alpha: f32, threads: usize) {
    crate::kernels::par::par_chunks_mut(threads, x, |_, chunk| {
        scale(chunk, alpha);
    });
}

/// sum of squares
pub fn sq_norm(x: &[f32]) -> f64 {
    x.iter().map(|&v| (v as f64) * (v as f64)).sum()
}

/// mean squared error between two slices
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// max |a - b|
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json;

    fn layout_fixture() -> ParamLayout {
        let v = json::parse(r#"[["w", [2, 3]], ["b", [3]], ["s", []]]"#).unwrap();
        ParamLayout::from_manifest(&v).unwrap()
    }

    #[test]
    fn layout_offsets_and_total() {
        let l = layout_fixture();
        assert_eq!(l.total, 6 + 3 + 1);
        assert_eq!(l.entry("w").unwrap().offset, 0);
        assert_eq!(l.entry("b").unwrap().offset, 6);
        assert_eq!(l.entry("s").unwrap().offset, 9);
        assert_eq!(l.entry("s").unwrap().size, 1);
        assert!(l.entry("nope").is_none());
    }

    #[test]
    fn layout_view_slices() {
        let l = layout_fixture();
        let flat: Vec<f32> = (0..10).map(|i| i as f32).collect();
        assert_eq!(l.view(&flat, "b").unwrap(), &[6.0, 7.0, 8.0]);
        assert!(l.view(&flat, "zzz").is_err());
    }

    #[test]
    fn layout_rejects_malformed() {
        let v = json::parse(r#"[["w"]]"#).unwrap();
        assert!(ParamLayout::from_manifest(&v).is_err());
    }

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("mpota_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.f32.bin");
        let data = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE, 1e30];
        write_f32_file(&path, &data).unwrap();
        let back = read_f32_file(&path).unwrap();
        assert_eq!(back, data);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn read_rejects_bad_length() {
        let dir = std::env::temp_dir().join("mpota_tensor_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8; 5]).unwrap();
        assert!(read_f32_file(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn vector_kernels() {
        let mut y = vec![1.0f32, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        scale(&mut y, 0.5);
        assert_eq!(y, vec![1.5, 2.0, 2.5]);
        assert!((sq_norm(&y) - (1.5 * 1.5 + 4.0 + 6.25) as f64).abs() < 1e-9);
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[2.0, 3.0]), 2.0);
        assert!((mse(&[1.0, 3.0], &[2.0, 5.0]) - 2.5).abs() < 1e-12);
        let mut d = vec![0.0f32; 2];
        diff_into(&mut d, &[3.0, 1.0], &[1.0, 4.0]);
        assert_eq!(d, vec![2.0, -3.0]);
    }

    #[test]
    fn par_kernels_match_sequential_bitwise() {
        let mut rng = crate::rng::Rng::seed_from(31);
        let n = 20_000;
        let mut x = vec![0.0f32; n];
        rng.fill_normal(&mut x, 0.0, 3.0);
        let mut base = vec![0.0f32; n];
        rng.fill_normal(&mut base, 0.0, 1.0);
        let mut want = base.clone();
        axpy(&mut want, 0.37, &x);
        scale(&mut want, 1.0 / 7.0);
        for threads in [1usize, 4] {
            let mut got = base.clone();
            axpy_par(&mut got, 0.37, &x, threads);
            scale_par(&mut got, 1.0 / 7.0, threads);
            assert_eq!(got, want, "threads={threads}");
        }
    }
}
