//! Minimal JSON parser/serializer (no serde in the vendored dependency set).
//!
//! Used for the build-time interchange files (`artifacts/manifest.json`,
//! `artifacts/goldens.json`), run configuration files, and structured run
//! logs.  Supports the full JSON grammar except exotic number forms beyond
//! f64; object key order is preserved (insertion order) so emitted logs are
//! stable and diffable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    /// Non-negative integer token too large to represent exactly in f64
    /// (> 2^53).  Kept exact so 64-bit seeds survive a JSON roundtrip;
    /// integers that DO fit in f64 parse as [`Value::Num`] as before.
    BigInt(u64),
    Str(String),
    Array(Vec<Value>),
    /// Insertion-ordered object (key, value) pairs.
    Object(Vec<(String, Value)>),
}

/// Largest integer magnitude f64 represents exactly (2^53).
const F64_EXACT_INT_MAX: u64 = 1 << 53;

impl Value {
    // ---------------------------------------------------------- accessors

    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Object(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key).ok_or_else(|| anyhow!("missing JSON key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            // lossy above 2^53, like every f64 consumer of JSON
            Value::BigInt(u) => Ok(*u as f64),
            other => bail!("expected number, got {other:?}"),
        }
    }

    /// Exact unsigned 64-bit integer: rejects fractional values and floats
    /// that cannot round-trip (> 2^53) instead of silently truncating.
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Value::BigInt(u) => Ok(*u),
            Value::Num(n)
                if n.fract() == 0.0 && *n >= 0.0 && *n <= F64_EXACT_INT_MAX as f64 =>
            {
                Ok(*n as u64)
            }
            other => bail!("expected unsigned integer, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        if let Value::BigInt(u) = self {
            return usize::try_from(*u)
                .map_err(|_| anyhow!("integer {u} exceeds usize"));
        }
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_u32(&self) -> Result<u32> {
        Ok(self.as_usize()? as u32)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_array(&self) -> Result<&[Value]> {
        match self {
            Value::Array(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_object(&self) -> Result<&[(String, Value)]> {
        match self {
            Value::Object(o) => Ok(o),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Convenience: array of f32.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_array()?
            .iter()
            .map(|v| v.as_f64().map(|f| f as f32))
            .collect()
    }

    /// Convenience: array of usize.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_array()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Object as a string->Value map (loses order; for lookup-heavy use).
    pub fn to_map(&self) -> Result<BTreeMap<String, Value>> {
        Ok(self
            .as_object()?
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect())
    }

    // -------------------------------------------------------- constructors

    pub fn object() -> Value {
        Value::Object(Vec::new())
    }

    /// Insert (or replace) a key in an object value; panics on non-objects
    /// (programming error, not data error).
    pub fn set(&mut self, key: &str, val: Value) -> &mut Value {
        match self {
            Value::Object(pairs) => {
                if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = val;
                } else {
                    pairs.push((key.to_string(), val));
                }
                self
            }
            _ => panic!("Value::set on non-object"),
        }
    }

    /// Exact u64 constructor: `Num` when f64 can hold the value exactly
    /// (keeps emitted JSON identical for everyday integers), `BigInt`
    /// above 2^53.
    pub fn from_u64(u: u64) -> Value {
        if u <= F64_EXACT_INT_MAX {
            Value::Num(u as f64)
        } else {
            Value::BigInt(u)
        }
    }

    pub fn from_f32s(xs: &[f32]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    pub fn from_strs(xs: &[&str]) -> Value {
        Value::Array(xs.iter().map(|s| Value::Str(s.to_string())).collect())
    }

    // --------------------------------------------------------- serializer

    /// Compact single-line serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Pretty serialization with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(true) => out.push_str("true"),
            Value::Bool(false) => out.push_str("false"),
            Value::Num(n) => write_num(out, *n),
            Value::BigInt(u) => {
                let _ = write!(out, "{u}");
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Array(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Object(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Value::Array(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Value::Object(o) if !o.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn push_indent(out: &mut String, levels: usize) {
    for _ in 0..levels {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if !n.is_finite() {
        // JSON has no inf/nan; emit null (matches python json.dumps default
        // behaviour closely enough for logs — we never rely on these).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------------ parser

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

/// Parse the JSON file at `path`.
pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse(&text).with_context(|| format!("parsing {}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, value: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(pairs));
                }
                other => bail!("expected ',' or '}}', got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(items));
                }
                other => bail!("expected ',' or ']', got {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).context("bad \\u escape")?,
                                16,
                            )?;
                            self.pos += 4;
                            // Surrogate pairs: handle the high half if a low
                            // half follows; lone surrogates become U+FFFD.
                            if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos + 1) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 2) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 3..self.pos + 7)
                                        .ok_or_else(|| anyhow!("truncated surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)?,
                                        16,
                                    )?;
                                    if (0xDC00..0xE000).contains(&low) {
                                        self.pos += 6;
                                        let c = 0x10000
                                            + ((code - 0xD800) << 10)
                                            + (low - 0xDC00);
                                        out.push(
                                            char::from_u32(c).unwrap_or('\u{FFFD}'),
                                        );
                                    } else {
                                        out.push('\u{FFFD}');
                                    }
                                } else {
                                    out.push('\u{FFFD}');
                                }
                            } else {
                                out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            }
                        }
                        other => bail!("bad escape \\{:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let len = utf8_len(self.bytes[start]);
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated UTF-8"))?;
                    out.push_str(std::str::from_utf8(chunk).context("invalid UTF-8")?);
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        // Integer tokens beyond f64's exact range keep full precision.
        if !text.contains(['.', 'e', 'E', '-']) {
            if let Ok(u) = text.parse::<u64>() {
                if u > F64_EXACT_INT_MAX {
                    return Ok(Value::BigInt(u));
                }
            }
        }
        Ok(Value::Num(text.parse::<f64>().context("bad number")?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        b if b < 0x80 => 1,
        b if b & 0xE0 == 0xC0 => 2,
        b if b & 0xF0 == 0xE0 => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("false").unwrap(), Value::Bool(false));
        assert_eq!(parse("3").unwrap(), Value::Num(3.0));
        assert_eq!(parse("-2.5e2").unwrap(), Value::Num(-250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Value::Null);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"s",true,null],"o":{"n":-7}}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string()).unwrap(), v);
        assert_eq!(parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            parse(r#""é😀""#).unwrap(),
            Value::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("{'a':1}").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn object_helpers() {
        let mut o = Value::object();
        o.set("x", Value::Num(1.0));
        o.set("x", Value::Num(2.0)); // replace
        o.set("y", Value::from_f32s(&[1.5, 2.5]));
        assert_eq!(o.get("x").unwrap().as_f64().unwrap(), 2.0);
        assert_eq!(o.get("y").unwrap().as_f32_vec().unwrap(), vec![1.5, 2.5]);
        assert!(o.req("z").is_err());
    }

    #[test]
    fn numbers_preserved() {
        for n in ["0", "123456789", "-1", "0.125", "1e-3", "9007199254740991"] {
            let v = parse(n).unwrap();
            let round = parse(&v.to_string()).unwrap();
            assert_eq!(v, round, "{n}");
        }
    }

    #[test]
    fn usize_conversions() {
        assert_eq!(parse("42").unwrap().as_usize().unwrap(), 42);
        assert!(parse("-1").unwrap().as_usize().is_err());
        assert!(parse("1.5").unwrap().as_usize().is_err());
    }

    #[test]
    fn big_integers_roundtrip_exactly() {
        // above 2^53: f64 would corrupt the low bits
        for u in [(1u64 << 53) + 1, u64::MAX, 0xDEAD_BEEF_CAFE_F00D] {
            let v = parse(&u.to_string()).unwrap();
            assert_eq!(v, Value::BigInt(u));
            assert_eq!(v.as_u64().unwrap(), u);
            assert_eq!(parse(&v.to_string()).unwrap(), v);
            assert_eq!(Value::from_u64(u), v);
        }
        // at or below 2^53: still a plain Num, still exact via as_u64
        for u in [0u64, 42, 1 << 53] {
            let v = parse(&u.to_string()).unwrap();
            assert_eq!(v, Value::Num(u as f64));
            assert_eq!(v.as_u64().unwrap(), u);
            assert_eq!(Value::from_u64(u), v);
        }
    }

    #[test]
    fn as_u64_rejects_non_integers() {
        assert!(parse("1.5").unwrap().as_u64().is_err());
        assert!(parse("-3").unwrap().as_u64().is_err());
        assert!(parse("1e300").unwrap().as_u64().is_err());
        assert!(parse("\"7\"").unwrap().as_u64().is_err());
    }
}
