//! Run metrics: accuracy/loss tracking, convergence detection, and
//! structured (JSONL + CSV) run logs.
//!
//! The paper's server-side metrics (§IV-A3): convergence speed (rounds to
//! a target accuracy) and final aggregated-model performance; client-side:
//! post-requantization accuracy.  [`RoundRecord`] captures one
//! communication round; [`RunLog`] accumulates them and renders the
//! artefacts the benches print.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

use crate::json::Value;

/// Everything measured in one communication round.
#[derive(Clone, Debug, Default)]
pub struct RoundRecord {
    pub round: usize,
    /// Server (global model) top-1 accuracy on the held-out test set.
    pub server_accuracy: f64,
    /// Server test loss.
    pub server_loss: f64,
    /// Mean client training loss this round (across participants).
    pub train_loss: f64,
    /// Mean client training accuracy this round.
    pub train_accuracy: f64,
    /// Clients that actually transmitted (not silenced).
    pub participants: usize,
    /// OTA aggregation MSE vs the noise-free ideal.
    pub ota_mse: f64,
    /// Cumulative client energy so far (J).
    pub energy_joules: f64,
    /// Wall-clock seconds spent in this round.
    pub wall_secs: f64,
    /// Whether `server_accuracy`/`server_loss` come from a FRESH
    /// evaluation this round (false on non-eval rounds, where they are
    /// carried forward from the last evaluation).  Feedback policies
    /// that react to the loss must ignore carried-forward rounds.
    pub evaluated: bool,
}

impl RoundRecord {
    /// The canonical JSONL-line object for one round; `label` tags the
    /// originating run (scheme/policy/cell coordinates).  Shared by the
    /// post-hoc [`RunLog::to_jsonl`] export and the streaming
    /// [`crate::sim::JsonlStreamer`], so both emit identical lines.
    pub fn to_json(&self, label: &str) -> Value {
        let mut o = Value::object();
        o.set("label", Value::Str(label.to_string()));
        o.set("round", Value::Num(self.round as f64));
        o.set("server_acc", Value::Num(self.server_accuracy));
        o.set("server_loss", Value::Num(self.server_loss));
        o.set("train_loss", Value::Num(self.train_loss));
        o.set("train_acc", Value::Num(self.train_accuracy));
        o.set("participants", Value::Num(self.participants as f64));
        o.set("ota_mse", Value::Num(self.ota_mse));
        o.set("energy_j", Value::Num(self.energy_joules));
        o.set("wall_s", Value::Num(self.wall_secs));
        o.set("evaluated", Value::Bool(self.evaluated));
        o
    }
}

/// Accumulated log for a full run.
#[derive(Clone, Debug, Default)]
pub struct RunLog {
    pub rounds: Vec<RoundRecord>,
    /// Label for reports (e.g. the scheme string "16,8,4").
    pub label: String,
}

impl RunLog {
    pub fn new(label: impl Into<String>) -> Self {
        RunLog { rounds: Vec::new(), label: label.into() }
    }

    pub fn push(&mut self, r: RoundRecord) {
        self.rounds.push(r);
    }

    pub fn final_accuracy(&self) -> f64 {
        self.rounds.last().map(|r| r.server_accuracy).unwrap_or(0.0)
    }

    /// Best accuracy seen at any round.
    pub fn best_accuracy(&self) -> f64 {
        self.rounds.iter().map(|r| r.server_accuracy).fold(0.0, f64::max)
    }

    /// First round whose accuracy reaches `threshold` (convergence speed,
    /// paper §IV-A3). None if never reached.
    pub fn rounds_to_accuracy(&self, threshold: f64) -> Option<usize> {
        self.rounds
            .iter()
            .find(|r| r.server_accuracy >= threshold)
            .map(|r| r.round)
    }

    /// Convergence-stability proxy: standard deviation of round-over-round
    /// accuracy deltas in the first `k` rounds ("erratic" = large).
    pub fn early_instability(&self, k: usize) -> f64 {
        let accs: Vec<f64> = self
            .rounds
            .iter()
            .take(k)
            .map(|r| r.server_accuracy)
            .collect();
        if accs.len() < 3 {
            return 0.0;
        }
        let deltas: Vec<f64> = accs.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = deltas.iter().sum::<f64>() / deltas.len() as f64;
        (deltas.iter().map(|d| (d - mean) * (d - mean)).sum::<f64>()
            / deltas.len() as f64)
            .sqrt()
    }

    /// Total energy at end of run.
    pub fn total_energy(&self) -> f64 {
        self.rounds.last().map(|r| r.energy_joules).unwrap_or(0.0)
    }

    // ------------------------------------------------------------- export

    /// One JSON object per round (JSONL) — machine-readable run record.
    /// (For long runs, prefer streaming the same lines live with
    /// `--stream` / [`crate::sim::JsonlStreamer`].)
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for r in &self.rounds {
            out.push_str(&r.to_json(&self.label).to_string());
            out.push('\n');
        }
        out
    }

    /// CSV (header + one row per round) — for quick plotting.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,server_acc,server_loss,train_loss,train_acc,participants,ota_mse,energy_j,wall_s\n",
        );
        for r in &self.rounds {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6},{},{:.3e},{:.4},{:.3}\n",
                r.round,
                r.server_accuracy,
                r.server_loss,
                r.train_loss,
                r.train_accuracy,
                r.participants,
                r.ota_mse,
                r.energy_joules,
                r.wall_secs
            ));
        }
        out
    }

    pub fn write_files(&self, dir: &Path, stem: &str) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating {}", dir.display()))?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.jsonl")))?;
        f.write_all(self.to_jsonl().as_bytes())?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.csv")))?;
        f.write_all(self.to_csv().as_bytes())?;
        Ok(())
    }
}

/// Online mean/variance (Welford) for streaming diagnostics.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log_with_accs(accs: &[f64]) -> RunLog {
        let mut log = RunLog::new("test");
        for (i, &a) in accs.iter().enumerate() {
            log.push(RoundRecord {
                round: i + 1,
                server_accuracy: a,
                ..Default::default()
            });
        }
        log
    }

    #[test]
    fn convergence_detection() {
        let log = log_with_accs(&[0.1, 0.5, 0.85, 0.92, 0.91]);
        assert_eq!(log.rounds_to_accuracy(0.9), Some(4));
        assert_eq!(log.rounds_to_accuracy(0.99), None);
        assert_eq!(log.final_accuracy(), 0.91);
        assert_eq!(log.best_accuracy(), 0.92);
    }

    #[test]
    fn instability_orders_smooth_vs_erratic() {
        let smooth = log_with_accs(&[0.1, 0.3, 0.5, 0.7, 0.8, 0.85]);
        let erratic = log_with_accs(&[0.1, 0.4, 0.2, 0.6, 0.3, 0.7]);
        assert!(erratic.early_instability(6) > smooth.early_instability(6));
    }

    #[test]
    fn jsonl_parses_back() {
        let log = log_with_accs(&[0.25, 0.5]);
        for line in log.to_jsonl().lines() {
            let v = crate::json::parse(line).unwrap();
            assert_eq!(v.get("label").unwrap().as_str().unwrap(), "test");
            assert!(v.get("server_acc").unwrap().as_f64().unwrap() <= 0.5);
        }
    }

    #[test]
    fn csv_has_header_and_rows() {
        let log = log_with_accs(&[0.2]);
        let csv = log.to_csv();
        let lines: Vec<_> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[1].starts_with("1,"));
    }

    #[test]
    fn welford_matches_closed_form() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // sample variance of this classic set is 32/7
        assert!((w.var() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.count(), 8);
    }

    #[test]
    fn empty_log_defaults() {
        let log = RunLog::new("empty");
        assert_eq!(log.final_accuracy(), 0.0);
        assert_eq!(log.rounds_to_accuracy(0.5), None);
        assert_eq!(log.early_instability(10), 0.0);
    }

    #[test]
    fn write_files_creates_both() {
        let dir = std::env::temp_dir().join("mpota_metrics_test");
        let log = log_with_accs(&[0.3, 0.6]);
        log.write_files(&dir, "run1").unwrap();
        assert!(dir.join("run1.jsonl").exists());
        assert!(dir.join("run1.csv").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
