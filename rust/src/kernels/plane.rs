//! Contiguous K×N payload plane — the aggregation-path replacement for
//! `&[Vec<f32>]`.
//!
//! One flat row-major buffer holds every client's decimal payload for the
//! round.  Row k is `data[k*n .. (k+1)*n]`, so the superposition kernels
//! stream each payload with unit stride, and the buffer is allocated once
//! per run and reused every round (`reset` only grows capacity).

/// K client payload rows of N parameters each, contiguous row-major.
#[derive(Clone, Debug, Default)]
pub struct PayloadPlane {
    data: Vec<f32>,
    k: usize,
    n: usize,
}

impl PayloadPlane {
    /// Empty plane (shape 0×0); call [`reset`](Self::reset) before use.
    pub fn new() -> Self {
        PayloadPlane::default()
    }

    /// Zero-filled plane of shape k×n.
    pub fn zeros(k: usize, n: usize) -> Self {
        PayloadPlane { data: vec![0.0; k * n], k, n }
    }

    /// Copy a ragged payload list into a fresh plane.
    ///
    /// Panics with "payload {k} length mismatch" if rows differ in length
    /// (same contract as the historical slice-of-vecs aggregation entry).
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        let n = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut plane = PayloadPlane::zeros(rows.len(), n);
        for (k, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), n, "payload {k} length mismatch");
            plane.row_mut(k).copy_from_slice(r);
        }
        plane
    }

    /// Reshape to k×n, reusing the existing allocation when possible.
    /// Contents are unspecified afterwards (rows are meant to be
    /// overwritten); no allocation happens once capacity has grown.
    pub fn reset(&mut self, k: usize, n: usize) {
        self.data.resize(k * n, 0.0);
        self.k = k;
        self.n = n;
    }

    /// Number of rows (clients).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Row length (parameters per payload).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Client k's payload row.
    pub fn row(&self, k: usize) -> &[f32] {
        &self.data[k * self.n..(k + 1) * self.n]
    }

    /// Client k's payload row, mutable.
    pub fn row_mut(&mut self, k: usize) -> &mut [f32] {
        let n = self.n;
        &mut self.data[k * n..(k + 1) * n]
    }

    /// Iterate rows in client order.
    pub fn rows(&self) -> impl Iterator<Item = &[f32]> {
        (0..self.k).map(move |k| self.row(k))
    }

    /// The whole K×N buffer, row-major.
    pub fn as_slice(&self) -> &[f32] {
        &self.data
    }

    /// The whole K×N buffer, row-major, mutable — the entry point for
    /// row-partitioned parallel writers (each worker owns a contiguous
    /// row range, so rows stay disjoint; see
    /// [`crate::kernels::par::par_row_partition_mut`]).
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_disjoint_views() {
        let mut p = PayloadPlane::zeros(3, 4);
        p.row_mut(1).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.row(0), &[0.0; 4]);
        assert_eq!(p.row(1), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(p.row(2), &[0.0; 4]);
        assert_eq!(p.as_slice().len(), 12);
    }

    #[test]
    fn from_rows_round_trips() {
        let rows = vec![vec![1.0f32, -2.0], vec![3.0, 4.0], vec![0.5, 0.25]];
        let p = PayloadPlane::from_rows(&rows);
        assert_eq!(p.k(), 3);
        assert_eq!(p.n(), 2);
        for (k, r) in p.rows().enumerate() {
            assert_eq!(r, rows[k].as_slice());
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn ragged_rows_panic() {
        let _ = PayloadPlane::from_rows(&[vec![0.0; 3], vec![0.0; 4]]);
    }

    #[test]
    fn reset_reuses_capacity() {
        let mut p = PayloadPlane::zeros(4, 100);
        let cap = p.data.capacity();
        p.reset(2, 100);
        p.reset(4, 100);
        assert_eq!(p.data.capacity(), cap, "reset must not reallocate");
        assert_eq!((p.k(), p.n()), (4, 100));
    }

    #[test]
    fn empty_plane_is_fine() {
        let p = PayloadPlane::from_rows(&[]);
        assert_eq!((p.k(), p.n()), (0, 0));
        assert_eq!(p.rows().count(), 0);
    }
}
