//! Scoped-thread chunk-parallelism helpers (no external crates).
//!
//! Work is split into contiguous chunks whose boundaries depend only on
//! the element count and chunk count — never on scheduling — so parallel
//! results are reproducible.  Below [`MIN_CHUNK_LEN`] elements per chunk
//! the spawn overhead dominates and the helpers fall back to the inline
//! sequential path (which also keeps the `threads = 1` round loop free of
//! heap allocation; spawning scoped threads allocates their stacks).

/// Smallest worthwhile per-chunk element count for f32 sweeps.
pub const MIN_CHUNK_LEN: usize = 4096;

/// Hardware parallelism (1 if it cannot be determined).
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Thread count from the `MPOTA_THREADS` environment variable (default 1
/// — the exact sequential path).  Used by the benches; results are
/// bit-identical per seed at any value, so it only trades wall-clock.
pub fn env_threads() -> usize {
    std::env::var("MPOTA_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&t| t > 0)
        .unwrap_or(1)
}

/// Number of chunks actually worth using for `n` elements at `threads`.
pub fn effective_chunks(threads: usize, n: usize) -> usize {
    threads.min(n / MIN_CHUNK_LEN).max(1)
}

/// Length of chunk `i` of `chunks` over `n` elements (balanced split:
/// the first `n % chunks` chunks get one extra element).
pub fn chunk_len(n: usize, chunks: usize, i: usize) -> usize {
    n / chunks + usize::from(i < n % chunks)
}

/// Start offset of chunk `i` of `chunks` over `n` elements.
pub fn chunk_start(n: usize, chunks: usize, i: usize) -> usize {
    let base = n / chunks;
    let rem = n % chunks;
    i * base + i.min(rem)
}

/// Run `f(offset, chunk)` over disjoint contiguous chunks of `buf`,
/// in parallel when `threads > 1` and the buffer is large enough.
///
/// `f` must be oblivious to chunking (pure elementwise work): the chunk
/// grid is deterministic, so results are identical for any thread count.
pub fn par_chunks_mut<T, F>(threads: usize, buf: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = buf.len();
    let chunks = effective_chunks(threads, n);
    if chunks <= 1 {
        f(0, buf);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = buf;
        let mut off = 0usize;
        for c in 0..chunks {
            let len = chunk_len(n, chunks, c);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(len);
            rest = tail;
            let o = off;
            off += len;
            s.spawn(move || f(o, head));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_grid_is_a_partition() {
        for n in [0usize, 1, 5, 4096, 10_000, 142_720] {
            for chunks in 1..6 {
                let mut total = 0usize;
                for i in 0..chunks {
                    assert_eq!(chunk_start(n, chunks, i), total);
                    total += chunk_len(n, chunks, i);
                }
                assert_eq!(total, n, "n={n} chunks={chunks}");
            }
        }
    }

    #[test]
    fn small_buffers_stay_sequential() {
        assert_eq!(effective_chunks(8, 100), 1);
        assert_eq!(effective_chunks(8, MIN_CHUNK_LEN * 3), 3);
        assert_eq!(effective_chunks(2, MIN_CHUNK_LEN * 100), 2);
        assert_eq!(effective_chunks(1, 1_000_000), 1);
    }

    #[test]
    fn par_chunks_mut_matches_sequential() {
        let n = MIN_CHUNK_LEN * 4 + 7;
        let mut seq: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut par = seq.clone();
        let work = |off: usize, chunk: &mut [f32]| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = *v * 0.5 + (off + j) as f32;
            }
        };
        par_chunks_mut(1, &mut seq, work);
        par_chunks_mut(4, &mut par, work);
        assert_eq!(seq, par);
    }
}
