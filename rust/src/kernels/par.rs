//! Chunk-parallelism helpers over the persistent [`crate::exec`] pool
//! (no external crates).
//!
//! Work is split into contiguous chunks whose boundaries depend only on
//! the element count and chunk count — never on scheduling — so parallel
//! results are reproducible.  Below [`MIN_CHUNK_LEN`] elements per chunk
//! the dispatch overhead dominates and the helpers fall back to the inline
//! sequential path.  Dispatch runs on the parked worker pool
//! ([`crate::exec::pool`]): no threads are spawned per call and the
//! `threads > 1` path performs no heap allocation in steady state
//! (`rust/tests/alloc_counter.rs`), which scoped spawning could not offer
//! (it allocates a stack per chunk per call).

/// Smallest worthwhile per-chunk element count for f32 sweeps.
pub const MIN_CHUNK_LEN: usize = 4096;

/// Upper bound on chunks per dispatch.  Lets hot paths precompute
/// per-chunk state (e.g. skip-ahead RNG clones) in fixed-size stack
/// tables; results are bit-identical at ANY chunk count, so the clamp
/// only bounds how wide a single dispatch goes.
pub const MAX_CHUNKS: usize = 16;

/// Hardware parallelism (1 if it cannot be determined).
pub fn auto_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Thread count from the `MPOTA_THREADS` environment variable (default 1
/// — the exact sequential path).  Used by the benches; results are
/// bit-identical per seed at any value, so it only trades wall-clock.
pub fn env_threads() -> usize {
    std::env::var("MPOTA_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&t| t > 0)
        .unwrap_or(1)
}

/// Number of chunks actually worth using for `n` elements at `threads`.
pub fn effective_chunks(threads: usize, n: usize) -> usize {
    threads.min(MAX_CHUNKS).min(n / MIN_CHUNK_LEN).max(1)
}

/// Length of chunk `i` of `chunks` over `n` elements (balanced split:
/// the first `n % chunks` chunks get one extra element).
pub fn chunk_len(n: usize, chunks: usize, i: usize) -> usize {
    n / chunks + usize::from(i < n % chunks)
}

/// Start offset of chunk `i` of `chunks` over `n` elements.
pub fn chunk_start(n: usize, chunks: usize, i: usize) -> usize {
    let base = n / chunks;
    let rem = n % chunks;
    i * base + i.min(rem)
}

/// Run `f(offset, chunk)` over disjoint contiguous chunks of `buf`,
/// in parallel on the exec pool when `threads > 1` and the buffer is
/// large enough.
///
/// `f` must be oblivious to chunking (pure elementwise work): the chunk
/// grid is deterministic, so results are identical for any thread count.
// mpota-lint: zero-alloc-hot
pub fn par_chunks_mut<T, F>(threads: usize, buf: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = buf.len();
    let chunks = effective_chunks(threads, n);
    if chunks <= 1 {
        f(0, buf);
        return;
    }
    let base = crate::exec::SendPtr::from_mut(buf);
    let task = move |c: usize| {
        let start = chunk_start(n, chunks, c);
        let len = chunk_len(n, chunks, c);
        // SAFETY: the deterministic chunk grid partitions [0, n) into
        // disjoint ranges and the pool runs each task index exactly once,
        // so no two live chunk borrows overlap; `buf` outlives the
        // dispatch because `broadcast` blocks until every task finishes.
        let chunk = unsafe { base.slice_at(start, len) };
        f(start, chunk);
    };
    crate::exec::pool().broadcast(chunks, &task);
}

/// Partition `buf` — a row-major `rows × (buf.len() / rows)` matrix —
/// into up to `parts` contiguous ROW ranges (balanced grid) and run
/// `f(first_row, rows_chunk)` for each range on the exec pool.
///
/// This is the inter-client / inter-cell partitioning primitive: unlike
/// [`par_chunks_mut`] there is no minimum-size fallback (the unit of work
/// is a whole row — a client payload — not an element), and `parts = 1`
/// is the exact sequential path.
// mpota-lint: zero-alloc-hot
pub fn par_row_partition_mut<T, F>(parts: usize, rows: usize, buf: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if rows == 0 {
        return;
    }
    assert_eq!(buf.len() % rows, 0, "buf must be rows x row_len");
    let row_len = buf.len() / rows;
    let parts = parts.min(rows).max(1);
    if parts <= 1 {
        f(0, buf);
        return;
    }
    let base = crate::exec::SendPtr::from_mut(buf);
    let task = move |p: usize| {
        let r0 = chunk_start(rows, parts, p);
        let nrows = chunk_len(rows, parts, p);
        // SAFETY: disjoint row ranges from the deterministic grid; one
        // task per index; `buf` outlives the blocking dispatch.
        let chunk = unsafe { base.slice_at(r0 * row_len, nrows * row_len) };
        f(r0, chunk);
    };
    crate::exec::pool().broadcast(parts, &task);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_grid_is_a_partition() {
        for n in [0usize, 1, 5, 4096, 10_000, 142_720] {
            for chunks in 1..6 {
                let mut total = 0usize;
                for i in 0..chunks {
                    assert_eq!(chunk_start(n, chunks, i), total);
                    total += chunk_len(n, chunks, i);
                }
                assert_eq!(total, n, "n={n} chunks={chunks}");
            }
        }
    }

    #[test]
    fn small_buffers_stay_sequential() {
        assert_eq!(effective_chunks(8, 100), 1);
        assert_eq!(effective_chunks(8, MIN_CHUNK_LEN * 3), 3);
        assert_eq!(effective_chunks(2, MIN_CHUNK_LEN * 100), 2);
        assert_eq!(effective_chunks(1, 1_000_000), 1);
        // the fixed-table clamp
        assert_eq!(effective_chunks(64, MIN_CHUNK_LEN * 100), MAX_CHUNKS);
    }

    #[test]
    fn par_chunks_mut_matches_sequential() {
        let n = MIN_CHUNK_LEN * 4 + 7;
        let mut seq: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let mut par = seq.clone();
        let work = |off: usize, chunk: &mut [f32]| {
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = *v * 0.5 + (off + j) as f32;
            }
        };
        par_chunks_mut(1, &mut seq, work);
        par_chunks_mut(4, &mut par, work);
        assert_eq!(seq, par);
    }

    #[test]
    fn par_row_partition_matches_sequential() {
        let (rows, row_len) = (10usize, 37usize);
        let mut seq: Vec<f32> = (0..rows * row_len).map(|i| i as f32).collect();
        let mut par = seq.clone();
        let work = |r0: usize, chunk: &mut [f32]| {
            for (i, row) in chunk.chunks_mut(37).enumerate() {
                let scale = (r0 + i + 1) as f32;
                for v in row.iter_mut() {
                    *v *= scale;
                }
            }
        };
        par_row_partition_mut(1, rows, &mut seq, work);
        par_row_partition_mut(4, rows, &mut par, work);
        assert_eq!(seq, par);
        // more parts than rows clamps; zero rows is a no-op
        let mut tiny = vec![1.0f32; 3];
        par_row_partition_mut(8, 3, &mut tiny, |_, c| {
            for v in c.iter_mut() {
                *v += 1.0;
            }
        });
        assert_eq!(tiny, vec![2.0; 3]);
        let mut empty: Vec<f32> = Vec::new();
        par_row_partition_mut(4, 0, &mut empty, |_, _| unreachable!());
    }
}
