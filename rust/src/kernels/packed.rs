//! Bit-packed K×N payload plane — rows stored at their ASSIGNED precision.
//!
//! [`super::PayloadPlane`] spends a full f32 per value regardless of the
//! row's precision, so a 4-bit client wastes 8× memory bandwidth in the
//! streaming superposition hot path.  `PackedPlane` stores each row in the
//! tightest lossless form its precision admits:
//!
//! | precision        | storage ([`RowKind`])          | bytes/value |
//! |------------------|--------------------------------|-------------|
//! | 2/3/4/6/8 (fixed)| LSB-first affine codes         | bits/8      |
//! | 12/16 (f-trunc)  | top-16 IEEE-754 bits, 2/word   | 2           |
//! | 24 (f-trunc)     | masked 32-bit words            | 4           |
//! | 32 (identity)    | raw 32-bit words               | 4           |
//!
//! Fixed-point rows carry a per-row [`AffineParams`] sidecar (scale /
//! zero-point) set when the row is packed.  Packing IS the transmission
//! quantization: `decode(pack(x))` equals `fake_quant(x)` bit-for-bit
//! (floor rounding) because the codes move losslessly and encode→decode
//! is exactly the fake-quant op sequence (`rust/tests/packed_plane.rs`
//! pins this against `mpota::testing`).  The fused kernels in
//! [`super::fused`] decode codes and accumulate `g·x` in one sweep — no
//! intermediate f32 row is ever materialized.
//!
//! Like the f32 plane, the buffer is allocated once per run and recycled
//! every shard ([`reset`](PackedPlane::reset) only grows capacity).

use crate::quant::fixed::{self, AffineParams};
use crate::quant::{float, Format, Precision};

/// Storage form of one packed row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RowKind {
    /// LSB-first affine integer codes, `bits` per value (fixed-point
    /// levels 2/3/4/6/8); decodes through the row's [`AffineParams`].
    Fixed,
    /// Top-16 IEEE-754 bits per value, two per word (float-truncation
    /// levels 12/16 — the 12-bit mask zeroes bits the top half keeps).
    Trunc16,
    /// One full 32-bit word per value: 24-bit rows store mask-truncated
    /// floats, 32-bit rows the raw bits (both decode by `from_bits`).
    Words,
}

#[derive(Clone, Copy, Debug)]
struct RowMeta {
    kind: RowKind,
    bits: u8,
    /// Truncation mask applied at pack time (FloatTrunc/Identity rows).
    mask: u32,
    /// First word of the row in the shared word store.
    offset: usize,
    /// Words the row occupies.
    len: usize,
    /// Affine sidecar (Fixed rows; identity scale otherwise).
    params: AffineParams,
}

/// Borrowed view of one packed row — what the fused kernels consume.
#[derive(Clone, Copy, Debug)]
pub struct PackedRow<'a> {
    pub kind: RowKind,
    pub bits: u8,
    pub words: &'a [u32],
    pub params: AffineParams,
}

impl PackedRow<'_> {
    /// Decode element `i` — the scalar-reference path (golden tests, the
    /// generic kernel tails).  The vectorized kernels inline the same
    /// arithmetic over word-aligned lanes.
    #[inline]
    pub fn get(&self, i: usize) -> f32 {
        match self.kind {
            RowKind::Fixed => {
                fixed::decode(fixed::unpack_code(self.words, i, self.bits), self.params)
            }
            RowKind::Trunc16 => {
                let w = self.words[i / 2];
                f32::from_bits(((w >> (16 * (i & 1))) & 0xFFFF) << 16)
            }
            RowKind::Words => f32::from_bits(self.words[i]),
        }
    }
}

/// K packed payload rows of N values each, contiguous in one word store.
#[derive(Clone, Debug, Default)]
pub struct PackedPlane {
    words: Vec<u32>,
    meta: Vec<RowMeta>,
    n: usize,
}

fn row_kind(p: Precision) -> (RowKind, u32) {
    match p.format() {
        Format::FixedPoint => (RowKind::Fixed, 0),
        Format::FloatTrunc if p.bits() <= 16 => {
            (RowKind::Trunc16, float::mask(p.bits()).expect("validated level"))
        }
        Format::FloatTrunc | Format::Identity => {
            (RowKind::Words, float::mask(p.bits()).expect("validated level"))
        }
    }
}

fn row_words(kind: RowKind, bits: u8, n: usize) -> usize {
    match kind {
        RowKind::Fixed => fixed::packed_words(n, bits),
        RowKind::Trunc16 => n.div_ceil(2),
        RowKind::Words => n,
    }
}

impl PackedPlane {
    /// Empty plane; call [`reset`](Self::reset) before use.
    pub fn new() -> Self {
        PackedPlane::default()
    }

    /// Reshape to one row per precision, each sized for its storage form.
    /// Contents are unspecified afterwards (rows are meant to be packed);
    /// no allocation happens once capacity has grown.
    pub fn reset(&mut self, precisions: &[Precision], n: usize) {
        self.meta.clear();
        self.n = n;
        let mut offset = 0usize;
        for &p in precisions {
            let (kind, mask) = row_kind(p);
            let len = row_words(kind, p.bits(), n);
            self.meta.push(RowMeta {
                kind,
                bits: p.bits(),
                mask,
                offset,
                len,
                params: AffineParams { scale: 1.0, zero_point: 0.0 },
            });
            offset += len;
        }
        self.words.resize(offset, 0);
    }

    /// Number of rows (clients).
    pub fn k(&self) -> usize {
        self.meta.len()
    }

    /// Row length (values per payload).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Storage bytes row `r` occupies in the word store.
    pub fn row_bytes(&self, r: usize) -> usize {
        self.meta[r].len * 4
    }

    /// Pack `src` into row `r` at the row's assigned precision — the
    /// transmission-quantization step: the stored form decodes to exactly
    /// `fake_quant(src, precision)` (floor rounding), bit-for-bit.
    // mpota-lint: zero-alloc-hot
    pub fn pack_row(&mut self, r: usize, src: &[f32]) {
        assert_eq!(src.len(), self.n, "packed row length mismatch");
        let m = self.meta[r];
        let words = &mut self.words[m.offset..m.offset + m.len];
        match m.kind {
            RowKind::Fixed => {
                self.meta[r].params = fixed::encode_packed(src, m.bits, words);
            }
            RowKind::Trunc16 => {
                let mut it = src.chunks_exact(2);
                for (w, pair) in words.iter_mut().zip(&mut it) {
                    let a = (pair[0].to_bits() & m.mask) >> 16;
                    let b = (pair[1].to_bits() & m.mask) >> 16;
                    *w = a | (b << 16);
                }
                if let [last] = it.remainder() {
                    words[m.len - 1] = (last.to_bits() & m.mask) >> 16;
                }
            }
            RowKind::Words => {
                for (w, &v) in words.iter_mut().zip(src.iter()) {
                    *w = v.to_bits() & m.mask;
                }
            }
        }
    }

    /// Borrow row `r` for decoding.
    #[inline]
    pub fn row(&self, r: usize) -> PackedRow<'_> {
        let m = self.meta[r];
        PackedRow {
            kind: m.kind,
            bits: m.bits,
            words: &self.words[m.offset..m.offset + m.len],
            params: m.params,
        }
    }

    /// Scalar-reference unpack of row `r` into `dst` — the golden-test
    /// decode (the fused kernels never materialize this row).
    // mpota-lint: zero-alloc-hot
    pub fn unpack_row_into(&self, r: usize, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.n, "unpacked row length mismatch");
        let row = self.row(r);
        for (i, d) in dst.iter_mut().enumerate() {
            *d = row.get(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant;
    use crate::rng::Rng;

    fn precisions() -> Vec<Precision> {
        quant::SUPPORTED_LEVELS.iter().map(|&b| Precision::of(b)).collect()
    }

    #[test]
    fn pack_unpack_is_fake_quant_at_every_level() {
        let ps = precisions();
        let n = 301usize;
        let mut rng = Rng::seed_from(41);
        let mut plane = PackedPlane::new();
        plane.reset(&ps, n);
        let mut rows = Vec::new();
        for r in 0..ps.len() {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 2.0);
            plane.pack_row(r, &v);
            rows.push(v);
        }
        let mut dst = vec![0.0f32; n];
        for (r, &p) in ps.iter().enumerate() {
            plane.unpack_row_into(r, &mut dst);
            let want = quant::fake_quant(&rows[r], p);
            for (i, (a, b)) in dst.iter().zip(want.iter()).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{p} row {r} diverges at [{i}]: packed {a} vs fake-quant {b}"
                );
            }
        }
    }

    #[test]
    fn storage_is_tight_per_kind() {
        let ps = precisions(); // [32, 24, 16, 12, 8, 6, 4, 3, 2]
        let n = 64usize;
        let mut plane = PackedPlane::new();
        plane.reset(&ps, n);
        let bytes: Vec<usize> = (0..ps.len()).map(|r| plane.row_bytes(r)).collect();
        assert_eq!(bytes, vec![256, 256, 128, 128, 64, 48, 32, 24, 16]);
    }

    #[test]
    fn reset_reuses_capacity() {
        let ps = precisions();
        let mut plane = PackedPlane::new();
        plane.reset(&ps, 1000);
        let cap_w = plane.words.capacity();
        let cap_m = plane.meta.capacity();
        plane.reset(&ps[..3], 500);
        plane.reset(&ps, 1000);
        assert_eq!(plane.words.capacity(), cap_w, "reset must not reallocate");
        assert_eq!(plane.meta.capacity(), cap_m, "reset must not reallocate");
        assert_eq!((plane.k(), plane.n()), (ps.len(), 1000));
    }

    #[test]
    fn odd_length_trunc16_rows_roundtrip() {
        let ps = vec![Precision::of(16), Precision::of(12)];
        let mut rng = Rng::seed_from(43);
        for n in [1usize, 7, 33] {
            let mut plane = PackedPlane::new();
            plane.reset(&ps, n);
            for (r, &p) in ps.iter().enumerate() {
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, 0.0, 3.0);
                plane.pack_row(r, &v);
                let mut dst = vec![0.0f32; n];
                plane.unpack_row_into(r, &mut dst);
                let want = quant::fake_quant(&v, p);
                assert_eq!(dst, want, "{p} n={n}");
            }
        }
    }

    #[test]
    fn constant_and_zero_rows_roundtrip_exactly() {
        // the degenerate-params contract carried through packing
        let ps = vec![
            Precision::of(2),
            Precision::of(3),
            Precision::of(4),
            Precision::of(6),
            Precision::of(8),
        ];
        for c in [0.0f32, 0.7311, -42.0] {
            let n = 19usize;
            let mut plane = PackedPlane::new();
            plane.reset(&ps, n);
            let v = vec![c; n];
            let mut dst = vec![0.0f32; n];
            for (r, &p) in ps.iter().enumerate() {
                plane.pack_row(r, &v);
                plane.unpack_row_into(r, &mut dst);
                assert!(dst.iter().all(|&d| d == c), "{p} c={c}: {dst:?}");
            }
        }
    }

    #[test]
    fn empty_plane_is_fine() {
        let mut plane = PackedPlane::new();
        plane.reset(&[], 0);
        assert_eq!((plane.k(), plane.n()), (0, 0));
    }
}
