//! Fused single-pass kernels for the OTA superposition hot path.
//!
//! The scalar path accumulated `y_re`, `y_im` and the noise-free `ideal`
//! with three separate `tensor::axpy` sweeps per client — reading every
//! payload three times.  The fused kernels read each payload row once and
//! update all accumulators in the same pass, which roughly triples the
//! arithmetic per byte moved on this memory-bound loop.
//!
//! Bit-exactness: per element, each accumulator receives exactly the same
//! f32 additions in the same (ascending client) order as the scalar
//! sweeps — accumulators are independent, so fusing them changes nothing.
//! Chunk-parallel execution only partitions the element axis (disjoint
//! output chunks, deterministic grid), so it is bit-identical too; chunks
//! dispatch onto the persistent [`crate::exec`] pool (no per-call thread
//! spawning, no steady-state allocation).

use crate::channel::C32;
use crate::kernels::{par, PayloadPlane};

/// Fused complex axpy: `y_re += g.re * x` and `y_im += g.im * x` in one
/// pass over `x`.
// mpota-lint: zero-alloc-hot
pub fn axpy2(y_re: &mut [f32], y_im: &mut [f32], g: C32, x: &[f32]) {
    assert_eq!(y_re.len(), x.len());
    assert_eq!(y_im.len(), x.len());
    for i in 0..x.len() {
        let v = x[i];
        y_re[i] += g.re * v;
        y_im[i] += g.im * v;
    }
}

/// Fused complex axpy plus ideal accumulation: one pass updating
/// `y_re += g.re * x`, `y_im += g.im * x`, `ideal += x`.
// mpota-lint: zero-alloc-hot
pub fn axpy3(y_re: &mut [f32], y_im: &mut [f32], ideal: &mut [f32], g: C32, x: &[f32]) {
    assert_eq!(y_re.len(), x.len());
    assert_eq!(y_im.len(), x.len());
    assert_eq!(ideal.len(), x.len());
    for i in 0..x.len() {
        let v = x[i];
        y_re[i] += g.re * v;
        y_im[i] += g.im * v;
        ideal[i] += v;
    }
}

/// Superpose the active payload rows through their effective gains:
/// for each `(row, g)` in `active` (ascending row order),
/// `y_re += g.re * plane[row]`, `y_im += g.im * plane[row]`,
/// `ideal += plane[row]`.
///
/// Accumulators must be pre-zeroed (or hold a prior partial sum) — the
/// kernel only adds.  With `threads > 1` the element axis is chunked; the
/// per-element result is bit-identical for any thread count.
// mpota-lint: zero-alloc-hot
pub fn superpose(
    plane: &PayloadPlane,
    active: &[(usize, C32)],
    y_re: &mut [f32],
    y_im: &mut [f32],
    ideal: &mut [f32],
    threads: usize,
) {
    let n = plane.n();
    assert_eq!(y_re.len(), n);
    assert_eq!(y_im.len(), n);
    assert_eq!(ideal.len(), n);

    let work = |off: usize, yr: &mut [f32], yi: &mut [f32], id: &mut [f32]| {
        let len = yr.len();
        for &(k, g) in active {
            let row = &plane.row(k)[off..off + len];
            axpy3(yr, yi, id, g, row);
        }
    };

    let chunks = par::effective_chunks(threads, n);
    if chunks <= 1 {
        work(0, y_re, y_im, ideal);
        return;
    }
    let yr_base = crate::exec::SendPtr::from_mut(y_re);
    let yi_base = crate::exec::SendPtr::from_mut(y_im);
    let id_base = crate::exec::SendPtr::from_mut(ideal);
    let task = move |c: usize| {
        let start = par::chunk_start(n, chunks, c);
        let len = par::chunk_len(n, chunks, c);
        // SAFETY: the deterministic chunk grid yields disjoint ranges of
        // the three equal-length accumulators; each task index runs
        // exactly once and the dispatch blocks until all tasks finish.
        let (yr, yi, id) = unsafe {
            (
                yr_base.slice_at(start, len),
                yi_base.slice_at(start, len),
                id_base.slice_at(start, len),
            )
        };
        work(start, yr, yi, id);
    };
    crate::exec::pool().broadcast(chunks, &task);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor;

    fn plane_and_gains(k: usize, n: usize, seed: u64) -> (PayloadPlane, Vec<(usize, C32)>) {
        let mut rng = Rng::seed_from(seed);
        let mut plane = PayloadPlane::zeros(k, n);
        for i in 0..k {
            rng.fill_normal(plane.row_mut(i), 0.0, 1.0);
        }
        // every other client active, with non-trivial gains
        let active: Vec<(usize, C32)> = (0..k)
            .filter(|i| i % 2 == 0)
            .map(|i| {
                (i, C32::new(rng.normal_f32(1.0, 0.1), rng.normal_f32(0.0, 0.1)))
            })
            .collect();
        (plane, active)
    }

    /// Naive three-sweep reference (the pre-kernel-layer scalar path).
    fn reference(
        plane: &PayloadPlane,
        active: &[(usize, C32)],
        n: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut y_re = vec![0.0f32; n];
        let mut y_im = vec![0.0f32; n];
        let mut ideal = vec![0.0f32; n];
        for &(k, g) in active {
            tensor::axpy(&mut y_re, g.re, plane.row(k));
            tensor::axpy(&mut y_im, g.im, plane.row(k));
            tensor::axpy(&mut ideal, 1.0, plane.row(k));
        }
        (y_re, y_im, ideal)
    }

    #[test]
    fn fused_matches_three_sweeps_bitwise() {
        // the middle case shrinks under Miri but stays odd and multi-chunk
        let big = if cfg!(miri) { (5usize, 8_193usize, 2u64) } else { (15, 20_001, 2) };
        for (k, n, seed) in [(4usize, 257usize, 1u64), big, (1, 64, 3)] {
            let (plane, active) = plane_and_gains(k, n, seed);
            let (want_re, want_im, want_id) = reference(&plane, &active, n);
            for threads in [1usize, 4] {
                let mut y_re = vec![0.0f32; n];
                let mut y_im = vec![0.0f32; n];
                let mut ideal = vec![0.0f32; n];
                superpose(&plane, &active, &mut y_re, &mut y_im, &mut ideal, threads);
                assert_eq!(y_re, want_re, "k={k} n={n} threads={threads}");
                assert_eq!(y_im, want_im, "k={k} n={n} threads={threads}");
                assert_eq!(ideal, want_id, "k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn axpy2_is_two_axpys() {
        let mut rng = Rng::seed_from(9);
        let mut x = vec![0.0f32; 333];
        rng.fill_normal(&mut x, 0.0, 2.0);
        let g = C32::new(0.7, -1.3);
        let mut y_re = vec![0.1f32; 333];
        let mut y_im = vec![-0.2f32; 333];
        let mut want_re = y_re.clone();
        let mut want_im = y_im.clone();
        tensor::axpy(&mut want_re, g.re, &x);
        tensor::axpy(&mut want_im, g.im, &x);
        axpy2(&mut y_re, &mut y_im, g, &x);
        assert_eq!(y_re, want_re);
        assert_eq!(y_im, want_im);
    }

    #[test]
    fn no_active_clients_is_identity() {
        let plane = PayloadPlane::zeros(3, 100);
        let mut y_re = vec![1.0f32; 100];
        let mut y_im = vec![2.0f32; 100];
        let mut ideal = vec![3.0f32; 100];
        superpose(&plane, &[], &mut y_re, &mut y_im, &mut ideal, 4);
        assert!(y_re.iter().all(|&v| v == 1.0));
        assert!(y_im.iter().all(|&v| v == 2.0));
        assert!(ideal.iter().all(|&v| v == 3.0));
    }
}
