//! Fused single-pass kernels for the OTA superposition hot path.
//!
//! The scalar path accumulated `y_re`, `y_im` and the noise-free `ideal`
//! with three separate `tensor::axpy` sweeps per client — reading every
//! payload three times.  The fused kernels read each payload row once and
//! update all accumulators in the same pass, which roughly triples the
//! arithmetic per byte moved on this memory-bound loop.
//!
//! Two storage forms feed the superposition:
//!
//! * [`PayloadPlane`] — unit-stride f32 rows, swept 8 lanes at a time
//!   through the portable [`F32x8`] chunks ([`superpose`]);
//! * [`PackedPlane`] — rows bit-packed at their assigned precision,
//!   decoded and accumulated in ONE sweep ([`superpose_packed`]): codes
//!   stream out of the packed words, de-quantize in-register and fold
//!   straight into the accumulators — no intermediate f32 row is ever
//!   materialized, so a 4-bit row moves 1/8th of the bytes.
//!
//! Bit-exactness: per element, each accumulator receives exactly the same
//! f32 additions in the same (ascending client) order as the scalar
//! sweeps — accumulators are independent, lanes are independent (rustc
//! performs no FMA contraction), and the packed decode is the exact
//! fake-quant op sequence — so fusing, vectorizing and packing change
//! nothing.  The scalar-reference fallbacks ([`axpy3_scalar`], the packed
//! rows' [`PackedRow::get`]) stay as the golden anchors.  Chunk-parallel
//! execution only partitions the element axis (disjoint output chunks,
//! deterministic grid), so it is bit-identical too; chunks dispatch onto
//! the persistent [`crate::exec`] pool (no per-call thread spawning, no
//! steady-state allocation).

use crate::channel::C32;
use crate::kernels::packed::{PackedRow, RowKind};
use crate::kernels::{par, PackedPlane, PayloadPlane};

/// Portable 8-lane f32 vector: a plain `[f32; 8]` whose per-lane ops the
/// optimizer lowers to one AVX/NEON register operation each.  Lanes are
/// independent and every op is the scalar op applied per lane — rustc
/// never contracts separate mul/add into an FMA — so lane-parallel sweeps
/// are bit-identical to the scalar reference.
#[derive(Clone, Copy)]
struct F32x8([f32; 8]);

impl F32x8 {
    const LANES: usize = 8;

    #[inline(always)]
    fn splat(v: f32) -> Self {
        F32x8([v; 8])
    }

    #[inline(always)]
    fn load(s: &[f32]) -> Self {
        let mut v = [0.0f32; 8];
        v.copy_from_slice(&s[..8]);
        F32x8(v)
    }

    #[inline(always)]
    fn store(self, d: &mut [f32]) {
        d[..8].copy_from_slice(&self.0);
    }

    #[inline(always)]
    fn add(self, o: Self) -> Self {
        let mut r = [0.0f32; 8];
        for l in 0..8 {
            r[l] = self.0[l] + o.0[l];
        }
        F32x8(r)
    }

    #[inline(always)]
    fn sub(self, o: Self) -> Self {
        let mut r = [0.0f32; 8];
        for l in 0..8 {
            r[l] = self.0[l] - o.0[l];
        }
        F32x8(r)
    }

    #[inline(always)]
    fn mul(self, o: Self) -> Self {
        let mut r = [0.0f32; 8];
        for l in 0..8 {
            r[l] = self.0[l] * o.0[l];
        }
        F32x8(r)
    }
}

/// Fused complex axpy: `y_re += g.re * x` and `y_im += g.im * x` in one
/// pass over `x` — 8-lane main loop, scalar tail.
// mpota-lint: zero-alloc-hot
pub fn axpy2(y_re: &mut [f32], y_im: &mut [f32], g: C32, x: &[f32]) {
    assert_eq!(y_re.len(), x.len());
    assert_eq!(y_im.len(), x.len());
    let n = x.len();
    let gre = F32x8::splat(g.re);
    let gim = F32x8::splat(g.im);
    let mut i = 0;
    while i + F32x8::LANES <= n {
        let xv = F32x8::load(&x[i..]);
        F32x8::load(&y_re[i..]).add(gre.mul(xv)).store(&mut y_re[i..]);
        F32x8::load(&y_im[i..]).add(gim.mul(xv)).store(&mut y_im[i..]);
        i += F32x8::LANES;
    }
    while i < n {
        let v = x[i];
        y_re[i] += g.re * v;
        y_im[i] += g.im * v;
        i += 1;
    }
}

/// Fused complex axpy plus ideal accumulation: one pass updating
/// `y_re += g.re * x`, `y_im += g.im * x`, `ideal += x` — 8-lane main
/// loop, scalar tail.  [`axpy3_scalar`] is the bit-identical reference.
// mpota-lint: zero-alloc-hot
pub fn axpy3(y_re: &mut [f32], y_im: &mut [f32], ideal: &mut [f32], g: C32, x: &[f32]) {
    assert_eq!(y_re.len(), x.len());
    assert_eq!(y_im.len(), x.len());
    assert_eq!(ideal.len(), x.len());
    let n = x.len();
    let gre = F32x8::splat(g.re);
    let gim = F32x8::splat(g.im);
    let mut i = 0;
    while i + F32x8::LANES <= n {
        let xv = F32x8::load(&x[i..]);
        F32x8::load(&y_re[i..]).add(gre.mul(xv)).store(&mut y_re[i..]);
        F32x8::load(&y_im[i..]).add(gim.mul(xv)).store(&mut y_im[i..]);
        F32x8::load(&ideal[i..]).add(xv).store(&mut ideal[i..]);
        i += F32x8::LANES;
    }
    while i < n {
        let v = x[i];
        y_re[i] += g.re * v;
        y_im[i] += g.im * v;
        ideal[i] += v;
        i += 1;
    }
}

/// Scalar reference for [`axpy3`] — the pre-SIMD sweep, kept verbatim as
/// the golden anchor the vectorized path is pinned bit-identical to.
// mpota-lint: zero-alloc-hot
pub fn axpy3_scalar(
    y_re: &mut [f32],
    y_im: &mut [f32],
    ideal: &mut [f32],
    g: C32,
    x: &[f32],
) {
    assert_eq!(y_re.len(), x.len());
    assert_eq!(y_im.len(), x.len());
    assert_eq!(ideal.len(), x.len());
    for i in 0..x.len() {
        let v = x[i];
        y_re[i] += g.re * v;
        y_im[i] += g.im * v;
        ideal[i] += v;
    }
}

/// Superpose the active payload rows through their effective gains:
/// for each `(row, g)` in `active` (ascending row order),
/// `y_re += g.re * plane[row]`, `y_im += g.im * plane[row]`,
/// `ideal += plane[row]`.
///
/// Accumulators must be pre-zeroed (or hold a prior partial sum) — the
/// kernel only adds.  With `threads > 1` the element axis is chunked; the
/// per-element result is bit-identical for any thread count.
// mpota-lint: zero-alloc-hot
pub fn superpose(
    plane: &PayloadPlane,
    active: &[(usize, C32)],
    y_re: &mut [f32],
    y_im: &mut [f32],
    ideal: &mut [f32],
    threads: usize,
) {
    let n = plane.n();
    assert_eq!(y_re.len(), n);
    assert_eq!(y_im.len(), n);
    assert_eq!(ideal.len(), n);

    let work = |off: usize, yr: &mut [f32], yi: &mut [f32], id: &mut [f32]| {
        let len = yr.len();
        for &(k, g) in active {
            let row = &plane.row(k)[off..off + len];
            axpy3(yr, yi, id, g, row);
        }
    };

    let chunks = par::effective_chunks(threads, n);
    if chunks <= 1 {
        work(0, y_re, y_im, ideal);
        return;
    }
    let yr_base = crate::exec::SendPtr::from_mut(y_re);
    let yi_base = crate::exec::SendPtr::from_mut(y_im);
    let id_base = crate::exec::SendPtr::from_mut(ideal);
    let task = move |c: usize| {
        let start = par::chunk_start(n, chunks, c);
        let len = par::chunk_len(n, chunks, c);
        // SAFETY: the deterministic chunk grid yields disjoint ranges of
        // the three equal-length accumulators; each task index runs
        // exactly once and the dispatch blocks until all tasks finish.
        let (yr, yi, id) = unsafe {
            (
                yr_base.slice_at(start, len),
                yi_base.slice_at(start, len),
                id_base.slice_at(start, len),
            )
        };
        work(start, yr, yi, id);
    };
    crate::exec::pool().broadcast(chunks, &task);
}

/// One packed row's fused decode-and-accumulate over the element window
/// `[off, off + yr.len())`: `y += g · decode(row)`, `ideal += decode(row)`
/// without materializing the decoded row.  Scalar heads align the global
/// element index to an 8-lane boundary so the vector groups never
/// straddle a code mid-word; scalar tails finish the remainder through
/// the same [`PackedRow::get`] reference decode.
// mpota-lint: zero-alloc-hot
#[inline]
fn accum_packed_row(
    row: PackedRow<'_>,
    g: C32,
    off: usize,
    y_re: &mut [f32],
    y_im: &mut [f32],
    ideal: &mut [f32],
) {
    let len = y_re.len();
    let gre = F32x8::splat(g.re);
    let gim = F32x8::splat(g.im);

    // the shared scalar step (head / tail / non-pow2 widths)
    macro_rules! scalar_at {
        ($i:expr) => {{
            let v = row.get(off + $i);
            y_re[$i] += g.re * v;
            y_im[$i] += g.im * v;
            ideal[$i] += v;
        }};
    }
    // fold one decoded 8-lane group into the accumulators at `i`
    macro_rules! lanes_at {
        ($i:expr, $v:expr) => {{
            let v: F32x8 = $v;
            F32x8::load(&y_re[$i..]).add(gre.mul(v)).store(&mut y_re[$i..]);
            F32x8::load(&y_im[$i..]).add(gim.mul(v)).store(&mut y_im[$i..]);
            F32x8::load(&ideal[$i..]).add(v).store(&mut ideal[$i..]);
        }};
    }

    let mut i = 0usize;
    match row.kind {
        RowKind::Fixed if row.bits.is_power_of_two() => {
            // 2/4/8-bit codes: at a global index divisible by 8 a group
            // of 8 codes spans whole half-words/words, so per-lane
            // extraction never crosses a word boundary mid-code
            while i < len && (off + i) % F32x8::LANES != 0 {
                scalar_at!(i);
                i += 1;
            }
            let b = row.bits as usize;
            let mask = ((1u64 << row.bits) - 1) as u32;
            let scale = F32x8::splat(row.params.scale);
            let zp = F32x8::splat(row.params.zero_point);
            while i + F32x8::LANES <= len {
                let e = off + i;
                let mut lane = [0.0f32; 8];
                for l in 0..8 {
                    let bit = (e + l) * b;
                    lane[l] = ((row.words[bit / 32] >> (bit % 32)) & mask) as f32;
                }
                // decode: (code - zp) * scale — the exact scalar op order
                lanes_at!(i, F32x8(lane).sub(zp).mul(scale));
                i += F32x8::LANES;
            }
        }
        RowKind::Fixed => {
            // 3/6-bit codes straddle word boundaries: the u64-window
            // scalar decode is the whole path
        }
        RowKind::Trunc16 => {
            while i < len && (off + i) % F32x8::LANES != 0 {
                scalar_at!(i);
                i += 1;
            }
            while i + F32x8::LANES <= len {
                let w0 = (off + i) / 2; // even global index: half 0 first
                let mut lane = [0.0f32; 8];
                for l in 0..8 {
                    let w = row.words[w0 + l / 2];
                    lane[l] = f32::from_bits(((w >> (16 * (l & 1))) & 0xFFFF) << 16);
                }
                lanes_at!(i, F32x8(lane));
                i += F32x8::LANES;
            }
        }
        RowKind::Words => {
            while i + F32x8::LANES <= len {
                let w = &row.words[off + i..off + i + 8];
                let mut lane = [0.0f32; 8];
                for (d, &wv) in lane.iter_mut().zip(w) {
                    *d = f32::from_bits(wv);
                }
                lanes_at!(i, F32x8(lane));
                i += F32x8::LANES;
            }
        }
    }
    while i < len {
        scalar_at!(i);
        i += 1;
    }
}

/// Packed-plane form of [`superpose`]: for each `(row, g)` in `active`
/// (ascending row order), decode row `row` of the packed plane AND
/// accumulate `y_re += g.re · x`, `y_im += g.im · x`, `ideal += x` in the
/// same sweep — the unpack-fuse-superpose path.  Bit-identical to
/// [`superpose`] over the fake-quantized f32 rows the packed rows decode
/// to, at every thread count (disjoint element chunks, deterministic
/// grid, lane-independent decode).
// mpota-lint: zero-alloc-hot
pub fn superpose_packed(
    plane: &PackedPlane,
    active: &[(usize, C32)],
    y_re: &mut [f32],
    y_im: &mut [f32],
    ideal: &mut [f32],
    threads: usize,
) {
    let n = plane.n();
    assert_eq!(y_re.len(), n);
    assert_eq!(y_im.len(), n);
    assert_eq!(ideal.len(), n);

    let work = |off: usize, yr: &mut [f32], yi: &mut [f32], id: &mut [f32]| {
        for &(k, g) in active {
            accum_packed_row(plane.row(k), g, off, yr, yi, id);
        }
    };

    let chunks = par::effective_chunks(threads, n);
    if chunks <= 1 {
        work(0, y_re, y_im, ideal);
        return;
    }
    let yr_base = crate::exec::SendPtr::from_mut(y_re);
    let yi_base = crate::exec::SendPtr::from_mut(y_im);
    let id_base = crate::exec::SendPtr::from_mut(ideal);
    let task = move |c: usize| {
        let start = par::chunk_start(n, chunks, c);
        let len = par::chunk_len(n, chunks, c);
        // SAFETY: the deterministic chunk grid yields disjoint ranges of
        // the three equal-length accumulators; each task index runs
        // exactly once and the dispatch blocks until all tasks finish.
        let (yr, yi, id) = unsafe {
            (
                yr_base.slice_at(start, len),
                yi_base.slice_at(start, len),
                id_base.slice_at(start, len),
            )
        };
        work(start, yr, yi, id);
    };
    crate::exec::pool().broadcast(chunks, &task);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, Precision};
    use crate::rng::Rng;
    use crate::tensor;

    fn plane_and_gains(k: usize, n: usize, seed: u64) -> (PayloadPlane, Vec<(usize, C32)>) {
        let mut rng = Rng::seed_from(seed);
        let mut plane = PayloadPlane::zeros(k, n);
        for i in 0..k {
            rng.fill_normal(plane.row_mut(i), 0.0, 1.0);
        }
        // every other client active, with non-trivial gains
        let active: Vec<(usize, C32)> = (0..k)
            .filter(|i| i % 2 == 0)
            .map(|i| {
                (i, C32::new(rng.normal_f32(1.0, 0.1), rng.normal_f32(0.0, 0.1)))
            })
            .collect();
        (plane, active)
    }

    /// Naive three-sweep reference (the pre-kernel-layer scalar path).
    fn reference(
        plane: &PayloadPlane,
        active: &[(usize, C32)],
        n: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let mut y_re = vec![0.0f32; n];
        let mut y_im = vec![0.0f32; n];
        let mut ideal = vec![0.0f32; n];
        for &(k, g) in active {
            tensor::axpy(&mut y_re, g.re, plane.row(k));
            tensor::axpy(&mut y_im, g.im, plane.row(k));
            tensor::axpy(&mut ideal, 1.0, plane.row(k));
        }
        (y_re, y_im, ideal)
    }

    #[test]
    fn fused_matches_three_sweeps_bitwise() {
        // the middle case shrinks under Miri but stays odd and multi-chunk
        let big = if cfg!(miri) { (5usize, 8_193usize, 2u64) } else { (15, 20_001, 2) };
        for (k, n, seed) in [(4usize, 257usize, 1u64), big, (1, 64, 3)] {
            let (plane, active) = plane_and_gains(k, n, seed);
            let (want_re, want_im, want_id) = reference(&plane, &active, n);
            for threads in [1usize, 4] {
                let mut y_re = vec![0.0f32; n];
                let mut y_im = vec![0.0f32; n];
                let mut ideal = vec![0.0f32; n];
                superpose(&plane, &active, &mut y_re, &mut y_im, &mut ideal, threads);
                assert_eq!(y_re, want_re, "k={k} n={n} threads={threads}");
                assert_eq!(y_im, want_im, "k={k} n={n} threads={threads}");
                assert_eq!(ideal, want_id, "k={k} n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn vector_axpy3_matches_scalar_reference_bitwise() {
        let mut rng = Rng::seed_from(19);
        for n in [1usize, 7, 8, 9, 64, 333] {
            let mut x = vec![0.0f32; n];
            rng.fill_normal(&mut x, 0.0, 2.0);
            let g = C32::new(rng.normal_f32(1.0, 0.3), rng.normal_f32(0.0, 0.3));
            let mut yr = vec![0.25f32; n];
            let mut yi = vec![-0.75f32; n];
            let mut id = vec![0.5f32; n];
            let mut wr = yr.clone();
            let mut wi = yi.clone();
            let mut wid = id.clone();
            axpy3(&mut yr, &mut yi, &mut id, g, &x);
            axpy3_scalar(&mut wr, &mut wi, &mut wid, g, &x);
            assert_eq!(yr, wr, "n={n}");
            assert_eq!(yi, wi, "n={n}");
            assert_eq!(id, wid, "n={n}");
        }
    }

    #[test]
    fn axpy2_is_two_axpys() {
        let mut rng = Rng::seed_from(9);
        let mut x = vec![0.0f32; 333];
        rng.fill_normal(&mut x, 0.0, 2.0);
        let g = C32::new(0.7, -1.3);
        let mut y_re = vec![0.1f32; 333];
        let mut y_im = vec![-0.2f32; 333];
        let mut want_re = y_re.clone();
        let mut want_im = y_im.clone();
        tensor::axpy(&mut want_re, g.re, &x);
        tensor::axpy(&mut want_im, g.im, &x);
        axpy2(&mut y_re, &mut y_im, g, &x);
        assert_eq!(y_re, want_re);
        assert_eq!(y_im, want_im);
    }

    #[test]
    fn no_active_clients_is_identity() {
        let plane = PayloadPlane::zeros(3, 100);
        let mut y_re = vec![1.0f32; 100];
        let mut y_im = vec![2.0f32; 100];
        let mut ideal = vec![3.0f32; 100];
        superpose(&plane, &[], &mut y_re, &mut y_im, &mut ideal, 4);
        assert!(y_re.iter().all(|&v| v == 1.0));
        assert!(y_im.iter().all(|&v| v == 2.0));
        assert!(ideal.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn packed_superpose_matches_f32_superpose_bitwise() {
        // mixed-width plane: pack RAW rows; the f32 reference superposes
        // the fake-quantized rows the packed codes decode to — the two
        // paths must agree bit-for-bit at every thread count
        let levels: Vec<Precision> = crate::quant::SUPPORTED_LEVELS
            .iter()
            .map(|&b| Precision::of(b))
            .collect();
        let sizes: &[usize] =
            if cfg!(miri) { &[1, 9, 257] } else { &[1, 9, 257, 20_001] };
        for &n in sizes {
            let k = levels.len();
            let mut rng = Rng::seed_from(100 + n as u64);
            let mut packed = PackedPlane::new();
            packed.reset(&levels, n);
            let mut fq = PayloadPlane::zeros(k, n);
            let mut raw = vec![0.0f32; n];
            for (r, &p) in levels.iter().enumerate() {
                rng.fill_normal(&mut raw, 0.0, 1.5);
                packed.pack_row(r, &raw);
                let q = quant::fake_quant(&raw, p);
                fq.row_mut(r).copy_from_slice(&q);
            }
            let active: Vec<(usize, C32)> = (0..k)
                .map(|i| {
                    (i, C32::new(rng.normal_f32(1.0, 0.2), rng.normal_f32(0.0, 0.2)))
                })
                .collect();
            let mut want_re = vec![0.0f32; n];
            let mut want_im = vec![0.0f32; n];
            let mut want_id = vec![0.0f32; n];
            superpose(&fq, &active, &mut want_re, &mut want_im, &mut want_id, 1);
            for threads in [1usize, 4] {
                let mut y_re = vec![0.0f32; n];
                let mut y_im = vec![0.0f32; n];
                let mut ideal = vec![0.0f32; n];
                superpose_packed(
                    &packed, &active, &mut y_re, &mut y_im, &mut ideal, threads,
                );
                let same = y_re.iter().zip(want_re.iter()).all(|(a, b)| {
                    a.to_bits() == b.to_bits()
                });
                assert!(same, "y_re diverged n={n} threads={threads}");
                let same = y_im.iter().zip(want_im.iter()).all(|(a, b)| {
                    a.to_bits() == b.to_bits()
                });
                assert!(same, "y_im diverged n={n} threads={threads}");
                let same = ideal.iter().zip(want_id.iter()).all(|(a, b)| {
                    a.to_bits() == b.to_bits()
                });
                assert!(same, "ideal diverged n={n} threads={threads}");
            }
        }
    }
}
