//! Fused, chunk-parallel kernels for the OTA round hot path (§Perf).
//!
//! The server-side cost of one communication round is pure vector math
//! over K client payloads of N parameters: quantize + modulate each
//! payload, superpose them through the channel gains, inject calibrated
//! AWGN, and average.  This module supplies the substrate that makes that
//! path fast without giving up reproducibility:
//!
//! * [`plane`] — [`PayloadPlane`], a contiguous K×N row-major buffer that
//!   replaces `&[Vec<f32>]` on the aggregation path: one allocation per
//!   run, cache-friendly row strides, stable row addresses for chunked
//!   column sweeps.
//! * [`packed`] — [`PackedPlane`], the bit-packed sibling: each row is
//!   stored at its ASSIGNED precision (affine codes for fixed-point
//!   levels, top-16-bit halves for 12/16-bit float truncation, whole
//!   words otherwise) with a per-row `AffineParams` sidecar, so a 4-bit
//!   row moves 1/8th of the bytes through the memory-bound superposition.
//! * [`fused`] — single-pass kernels: the complex [`fused::superpose`]
//!   accumulates `y_re`, `y_im` and the noise-free `ideal` in ONE sweep
//!   over each payload row (the scalar path reads every payload three
//!   times) through portable 8-lane SIMD chunks, [`fused::axpy2`] is the
//!   per-row building block, and [`fused::superpose_packed`] decodes
//!   packed codes and accumulates `g·x` in the same sweep — no
//!   intermediate f32 row is ever materialized.
//! * [`par`] — chunk-parallelism over the persistent [`crate::exec`]
//!   worker pool (no external deps, no per-call thread spawning): N is
//!   split into contiguous column chunks, each pool task owns a disjoint
//!   output chunk, and chunk boundaries depend only on N and the chunk
//!   count — never on scheduling.  [`par::par_row_partition_mut`] is the
//!   row-aligned variant used to partition clients / sweep cells.
//!
//! # Determinism-under-parallelism contract
//!
//! Every kernel here is **bit-identical to the sequential reference for
//! any thread count**:
//!
//! * Elementwise maps (scale, axpy, quantize) and per-element reductions
//!   over clients are computed in the same per-element operation order
//!   regardless of chunking, so the f32 results match bit-for-bit.
//! * min/max reductions (fixed-point quantization parameters) are exact
//!   under any association, so chunked reduction changes nothing.
//! * Order-sensitive f64 reductions (signal power, MSE diagnostics) stay
//!   sequential — they are O(N) and cheap.
//! * Receiver-noise generation keeps ONE logical RNG stream: a cursor
//!   sweep precomputes the generator state at every chunk's draw offset
//!   (`Rng::clone_skip`), exploiting the fixed two-draws-per-pair shape of
//!   the pairwise Box-Muller fill (see `Rng::add_normal2`).  The draws a
//!   chunk consumes are exactly the draws the sequential pass would have
//!   used at those positions.
//!
//! `threads = 1` executes the plain sequential loops — byte-for-byte the
//! pre-kernel-layer behaviour — and `threads > 1` reproduces it exactly.
//! `rust/tests/kernels.rs` enforces both against naive references.

pub mod fused;
pub mod packed;
pub mod par;
pub mod plane;

pub use packed::PackedPlane;
pub use plane::PayloadPlane;
