//! Client precision schemes (paper §IV-A2).
//!
//! "We assign quantization levels to the 15 clients by a group of 5.  Each
//! scheme consists of 3 precision levels, and each precision level is
//! assigned to 5 clients.  Quantization levels are chosen from
//! [32, 24, 16, 12, 8, 6, 4]."
//!
//! A [`Scheme`] is the ordered list of group levels (e.g. `[16, 8, 4]`);
//! [`Scheme::client_precisions`] expands it to the per-client assignment.

use anyhow::{bail, Result};

use crate::quant::Precision;

/// Levels a *scheme* may draw from (Table I's 3/2-bit probing levels are
/// not valid client operating points — no train artifacts exist for them).
pub const SCHEME_LEVELS: [u8; 7] = [32, 24, 16, 12, 8, 6, 4];

/// An ordered assignment of precision levels to client groups.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Scheme {
    groups: Vec<Precision>,
}

impl Scheme {
    /// Build from group levels, highest first by convention.
    pub fn new(levels: &[u8]) -> Result<Self> {
        if levels.is_empty() {
            bail!("scheme needs at least one precision group");
        }
        let mut groups = Vec::with_capacity(levels.len());
        for &b in levels {
            if !SCHEME_LEVELS.contains(&b) {
                bail!("scheme level {b} not in {SCHEME_LEVELS:?}");
            }
            groups.push(Precision::of(b));
        }
        Ok(Scheme { groups })
    }

    /// Parse "16,8,4".
    pub fn parse(s: &str) -> Result<Self> {
        let levels: Result<Vec<u8>> = s
            .split(',')
            .map(|t| Ok(t.trim().parse::<u8>()?))
            .collect();
        Scheme::new(&levels?)
    }

    /// The paper's eight Fig.-3 schemes.
    pub fn paper_schemes() -> Vec<Scheme> {
        [
            "32,32,32",
            "32,16,8",
            "24,12,6",
            "16,16,16",
            "16,8,4",
            "12,4,4",
            "8,8,8",
            "4,4,4",
        ]
        .iter()
        .map(|s| Scheme::parse(s).expect("static scheme"))
        .collect()
    }

    pub fn groups(&self) -> &[Precision] {
        &self.groups
    }

    /// Is every group at the same level?
    pub fn is_homogeneous(&self) -> bool {
        self.groups.windows(2).all(|w| w[0] == w[1])
    }

    /// O(1) check that `clients` divides evenly into the groups — the
    /// single source of the divisibility error every expansion (and
    /// `RunConfig::validate`) reports, so a 10M-client config validates
    /// without materializing a fleet-sized assignment.
    pub fn check_divides(&self, clients: usize) -> Result<()> {
        let g = self.groups.len();
        if clients % g != 0 {
            bail!("{clients} clients do not divide into {g} equal groups");
        }
        Ok(())
    }

    /// Expand to per-client precisions: `clients` must divide evenly into
    /// the groups (paper: 15 clients / 3 groups = 5 each).
    pub fn client_precisions(&self, clients: usize) -> Result<Vec<Precision>> {
        let mut out = Vec::with_capacity(clients);
        self.client_precisions_into(clients, &mut out)?;
        Ok(out)
    }

    /// Expand into a reused buffer — the zero-alloc per-round form used by
    /// the static precision policy (`sim::StaticScheme`).  Identical
    /// output to [`client_precisions`](Self::client_precisions).
    pub fn client_precisions_into(
        &self,
        clients: usize,
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        self.check_divides(clients)?;
        let per = clients / self.groups.len();
        out.clear();
        for &p in &self.groups {
            for _ in 0..per {
                out.push(p);
            }
        }
        Ok(())
    }

    /// Expand the assignment at the SELECTED client indices only — the
    /// O(K) massive-fleet form: client `k`'s group is `k / (clients /
    /// groups)`, identical to the full [`client_precisions`] expansion at
    /// index `k`, without materializing the fleet.
    ///
    /// [`client_precisions`]: Self::client_precisions
    pub fn selected_precisions_into(
        &self,
        clients: usize,
        selected: &[usize],
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        self.check_divides(clients)?;
        let per = clients / self.groups.len();
        out.clear();
        for &k in selected {
            debug_assert!(k < clients, "client index {k} out of the {clients}-fleet");
            out.push(self.groups[k / per]);
        }
        Ok(())
    }

    /// Distinct levels, high to low.
    pub fn distinct_levels(&self) -> Vec<Precision> {
        let mut ls = self.groups.clone();
        ls.sort_by(|a, b| b.bits().cmp(&a.bits()));
        ls.dedup();
        ls
    }

    /// Lowest precision present (the paper's client-performance focus).
    pub fn lowest(&self) -> Precision {
        *self
            .groups
            .iter()
            .min_by_key(|p| p.bits())
            .expect("non-empty scheme")
    }
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let parts: Vec<String> =
            self.groups.iter().map(|p| p.bits().to_string()).collect();
        write!(f, "{}", parts.join(","))
    }
}

impl std::str::FromStr for Scheme {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Scheme::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_display_roundtrip() {
        let s = Scheme::parse("16,8,4").unwrap();
        assert_eq!(s.to_string(), "16,8,4");
        assert_eq!(s.groups().len(), 3);
        assert!(!s.is_homogeneous());
    }

    #[test]
    fn rejects_bad_levels() {
        assert!(Scheme::parse("16,8,5").is_err());
        assert!(Scheme::parse("3,3,3").is_err()); // 3-bit: probe-only level
        assert!(Scheme::parse("").is_err());
    }

    #[test]
    fn paper_schemes_all_valid_for_15_clients() {
        let schemes = Scheme::paper_schemes();
        assert_eq!(schemes.len(), 8);
        for s in &schemes {
            let ps = s.client_precisions(15).unwrap();
            assert_eq!(ps.len(), 15);
            // groups of five (paper §IV-A2)
            for g in 0..3 {
                let group = &ps[g * 5..(g + 1) * 5];
                assert!(group.windows(2).all(|w| w[0] == w[1]));
            }
        }
    }

    #[test]
    fn homogeneous_detection() {
        assert!(Scheme::parse("8,8,8").unwrap().is_homogeneous());
        assert!(!Scheme::parse("12,4,4").unwrap().is_homogeneous());
    }

    #[test]
    fn client_expansion_requires_divisibility() {
        let s = Scheme::parse("16,8,4").unwrap();
        assert!(s.client_precisions(16).is_err());
        assert!(s.client_precisions(3).is_ok());
    }

    #[test]
    fn selected_expansion_matches_full_expansion() {
        let s = Scheme::parse("16,8,4").unwrap();
        let full = s.client_precisions(15).unwrap();
        let mut out = Vec::new();
        // every client, a sparse subset, and an unsorted subset
        let all: Vec<usize> = (0..15).collect();
        s.selected_precisions_into(15, &all, &mut out).unwrap();
        assert_eq!(out, full);
        let subset = [0usize, 4, 5, 9, 10, 14];
        s.selected_precisions_into(15, &subset, &mut out).unwrap();
        let want: Vec<_> = subset.iter().map(|&k| full[k]).collect();
        assert_eq!(out, want);
        // divisibility is still enforced
        assert!(s.selected_precisions_into(16, &subset, &mut out).is_err());
    }

    #[test]
    fn distinct_and_lowest() {
        let s = Scheme::parse("12,4,4").unwrap();
        assert_eq!(
            s.distinct_levels().iter().map(|p| p.bits()).collect::<Vec<_>>(),
            vec![12, 4]
        );
        assert_eq!(s.lowest().bits(), 4);
    }
}
