//! Per-round client selection (paper §II-A: "N clients, at each
//! communication round, K of them are selected").

use crate::rng::Rng;

/// Strategy for picking the K participants each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selection {
    /// All N clients every round (the paper's evaluation setting).
    All,
    /// Uniformly random K without replacement.
    UniformK(usize),
    /// Deterministic rotation: rounds cycle through client blocks.
    RoundRobinK(usize),
}

impl Selection {
    /// Client indices participating in `round` (1-based round index).
    pub fn select(&self, clients: usize, round: usize, rng: &mut Rng) -> Vec<usize> {
        let mut out = Vec::new();
        self.select_into(clients, round, rng, &mut out);
        out
    }

    /// Fill `out` with the round's participant indices, reusing its
    /// capacity (the zero-alloc round-loop form).  RNG consumption and
    /// results are identical to [`select`](Selection::select).
    pub fn select_into(
        &self,
        clients: usize,
        round: usize,
        rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        match *self {
            Selection::All => out.extend(0..clients),
            Selection::UniformK(k) => {
                let k = k.min(clients);
                // partial Fisher-Yates, draw-for-draw the same as
                // Rng::choose_k, over the reused buffer
                out.extend(0..clients);
                for i in 0..k {
                    let j = i + rng.below(clients - i);
                    out.swap(i, j);
                }
                out.truncate(k);
                out.sort_unstable();
            }
            Selection::RoundRobinK(k) => {
                let k = k.min(clients);
                let start = ((round.saturating_sub(1)) * k) % clients;
                out.extend((0..k).map(|i| (start + i) % clients));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_selects_everyone() {
        let mut rng = Rng::seed_from(1);
        assert_eq!(Selection::All.select(5, 3, &mut rng), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn uniform_k_distinct_and_sized() {
        let mut rng = Rng::seed_from(2);
        for round in 1..50 {
            let sel = Selection::UniformK(6).select(15, round, &mut rng);
            assert_eq!(sel.len(), 6);
            let mut d = sel.clone();
            d.dedup();
            assert_eq!(d.len(), 6);
            assert!(sel.windows(2).all(|w| w[0] < w[1]), "sorted");
        }
    }

    #[test]
    fn uniform_k_covers_all_clients_eventually() {
        let mut rng = Rng::seed_from(3);
        let mut seen = vec![false; 15];
        for round in 1..200 {
            for i in Selection::UniformK(5).select(15, round, &mut rng) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn round_robin_rotates() {
        let mut rng = Rng::seed_from(4);
        let s = Selection::RoundRobinK(5);
        assert_eq!(s.select(15, 1, &mut rng), vec![0, 1, 2, 3, 4]);
        assert_eq!(s.select(15, 2, &mut rng), vec![5, 6, 7, 8, 9]);
        assert_eq!(s.select(15, 3, &mut rng), vec![10, 11, 12, 13, 14]);
        assert_eq!(s.select(15, 4, &mut rng), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn k_clamped_to_n() {
        let mut rng = Rng::seed_from(5);
        assert_eq!(Selection::UniformK(99).select(4, 1, &mut rng).len(), 4);
    }

    #[test]
    fn select_into_matches_legacy_choose_k_draws() {
        // the reusable-buffer path must consume the RNG exactly like the
        // historical choose_k-based implementation
        let mut legacy_rng = Rng::seed_from(6);
        let mut new_rng = Rng::seed_from(6);
        let mut out = Vec::new();
        for round in 1..20 {
            let mut legacy = legacy_rng.choose_k(15, 6);
            legacy.sort_unstable();
            Selection::UniformK(6).select_into(15, round, &mut new_rng, &mut out);
            assert_eq!(out, legacy, "round {round}");
        }
        assert_eq!(legacy_rng.next_u64(), new_rng.next_u64());
    }
}
