//! Per-round client selection (paper §II-A: "N clients, at each
//! communication round, K of them are selected").
//!
//! Massive-fleet contract: every variant selects K from N using O(K)
//! scratch — `UniformK` runs a SPARSE partial Fisher-Yates (identical RNG
//! draws and output to the historical dense permutation, so existing
//! per-seed pins hold), and `SampledK` uses Floyd's sampling algorithm
//! (K draws, K state) so selecting 64 participants from a 10M-client
//! fleet touches 10M-independent memory.

use crate::rng::Rng;

/// Strategy for picking the K participants each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selection {
    /// All N clients every round (the paper's evaluation setting).
    All,
    /// Uniformly random K without replacement (partial Fisher-Yates; the
    /// historical draw order, kept RNG-compatible for existing pins).
    UniformK(usize),
    /// Uniformly random K without replacement via Floyd's sampling —
    /// O(K) memory AND O(K) RNG draws, the massive-fleet selector.  The
    /// draw sequence differs from [`UniformK`](Selection::UniformK) (both
    /// are uniform; trajectories are pinned per selector).
    SampledK(usize),
    /// Deterministic rotation: rounds cycle through client blocks.
    RoundRobinK(usize),
}

impl Selection {
    /// The selector a [`crate::config::RunConfig`] names: `Auto`
    /// reproduces the historical coordinator behavior (everyone when
    /// `K == N`, else `UniformK`); the explicit kinds map literally.
    pub fn from_config(
        kind: crate::config::SelectionKind,
        clients: usize,
        k: usize,
    ) -> Selection {
        use crate::config::SelectionKind as SK;
        let k = k.min(clients);
        match kind {
            SK::Auto => {
                if k == clients {
                    Selection::All
                } else {
                    Selection::UniformK(k)
                }
            }
            SK::Uniform => Selection::UniformK(k),
            SK::Sampled => Selection::SampledK(k),
            SK::RoundRobin => Selection::RoundRobinK(k),
        }
    }

    /// Client indices participating in `round` (1-based round index).
    pub fn select(&self, clients: usize, round: usize, rng: &mut Rng) -> Vec<usize> {
        let mut out = Vec::new();
        self.select_into(clients, round, rng, &mut out);
        out
    }

    /// Fill `out` with the round's participant indices, reusing its
    /// capacity (the zero-alloc round-loop form).  RNG consumption and
    /// results are identical to [`select`](Selection::select).
    ///
    /// Scratch bound: `All` grows `out` to N; every K-selector touches
    /// only O(K) entries of `out` (capacity included), so fleet size
    /// never enters the round's memory footprint.
    pub fn select_into(
        &self,
        clients: usize,
        round: usize,
        rng: &mut Rng,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        match *self {
            Selection::All => out.extend(0..clients),
            Selection::UniformK(k) => {
                let k = k.min(clients);
                // SPARSE partial Fisher-Yates: draw-for-draw and
                // output-identical to the historical dense
                // `extend(0..N); swap(i, j)` implementation (pinned by
                // `select_into_matches_legacy_choose_k_draws`), but
                // tracking only the O(k) touched positions.  Positions
                // < k live in `out[..k]`; a displaced value at a
                // position >= k is kept as a (position, value) pair
                // appended after index k in the same buffer, so the
                // buffer never grows past 3k entries even for
                // multi-million-client fleets.
                out.extend(0..k);
                for i in 0..k {
                    let j = i + rng.below(clients - i);
                    if j < k {
                        out.swap(i, j);
                    } else {
                        // locate the displaced-pair entry for position j
                        let mut pair = None;
                        let mut idx = k;
                        while idx < out.len() {
                            if out[idx] == j {
                                pair = Some(idx);
                                break;
                            }
                            idx += 2;
                        }
                        match pair {
                            Some(idx) => {
                                let vj = out[idx + 1];
                                out[idx + 1] = out[i];
                                out[i] = vj;
                            }
                            None => {
                                // position j still holds its identity
                                let vi = out[i];
                                out[i] = j;
                                out.push(j);
                                out.push(vi);
                            }
                        }
                    }
                }
                out.truncate(k);
                out.sort_unstable();
            }
            Selection::SampledK(k) => {
                let k = k.min(clients);
                // Floyd's sampling: for j in N-k..N draw t in [0, j];
                // insert t unless already chosen, else insert j.  Each
                // k-subset has probability 1/C(N, k); exactly k RNG
                // draws and k entries of state.
                for j in (clients - k)..clients {
                    let t = rng.below(j + 1);
                    if out.contains(&t) {
                        out.push(j);
                    } else {
                        out.push(t);
                    }
                }
                out.sort_unstable();
            }
            Selection::RoundRobinK(k) => {
                let k = k.min(clients);
                let start = ((round.saturating_sub(1)) * k) % clients;
                out.extend((0..k).map(|i| (start + i) % clients));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn all_selects_everyone() {
        let mut rng = Rng::seed_from(1);
        assert_eq!(Selection::All.select(5, 3, &mut rng), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn uniform_k_distinct_and_sized() {
        let mut rng = Rng::seed_from(2);
        for round in 1..50 {
            let sel = Selection::UniformK(6).select(15, round, &mut rng);
            assert_eq!(sel.len(), 6);
            let mut d = sel.clone();
            d.dedup();
            assert_eq!(d.len(), 6);
            assert!(sel.windows(2).all(|w| w[0] < w[1]), "sorted");
        }
    }

    #[test]
    fn uniform_k_covers_all_clients_eventually() {
        let mut rng = Rng::seed_from(3);
        let mut seen = vec![false; 15];
        for round in 1..200 {
            for i in Selection::UniformK(5).select(15, round, &mut rng) {
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn round_robin_rotates() {
        let mut rng = Rng::seed_from(4);
        let s = Selection::RoundRobinK(5);
        assert_eq!(s.select(15, 1, &mut rng), vec![0, 1, 2, 3, 4]);
        assert_eq!(s.select(15, 2, &mut rng), vec![5, 6, 7, 8, 9]);
        assert_eq!(s.select(15, 3, &mut rng), vec![10, 11, 12, 13, 14]);
        assert_eq!(s.select(15, 4, &mut rng), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn k_clamped_to_n() {
        let mut rng = Rng::seed_from(5);
        assert_eq!(Selection::UniformK(99).select(4, 1, &mut rng).len(), 4);
        assert_eq!(Selection::SampledK(99).select(4, 1, &mut rng).len(), 4);
    }

    #[test]
    fn select_into_matches_legacy_choose_k_draws() {
        // the reusable-buffer path must consume the RNG exactly like the
        // historical choose_k-based implementation
        let mut legacy_rng = Rng::seed_from(6);
        let mut new_rng = Rng::seed_from(6);
        let mut out = Vec::new();
        for round in 1..20 {
            let mut legacy = legacy_rng.choose_k(15, 6);
            legacy.sort_unstable();
            Selection::UniformK(6).select_into(15, round, &mut new_rng, &mut out);
            assert_eq!(out, legacy, "round {round}");
        }
        assert_eq!(legacy_rng.next_u64(), new_rng.next_u64());
    }

    #[test]
    fn sparse_uniform_k_matches_dense_at_every_shape() {
        // the sparse Fisher-Yates must equal the dense reference for any
        // (n, k), including k == n and repeated collisions
        for (n, k, seed) in
            [(15usize, 6usize, 7u64), (8, 8, 8), (100, 1, 9), (50, 49, 10), (2, 1, 11)]
        {
            let mut dense_rng = Rng::seed_from(seed);
            let mut sparse_rng = Rng::seed_from(seed);
            let mut out = Vec::new();
            for round in 1..30 {
                let mut dense = dense_rng.choose_k(n, k);
                dense.sort_unstable();
                Selection::UniformK(k).select_into(n, round, &mut sparse_rng, &mut out);
                assert_eq!(out, dense, "n={n} k={k} round={round}");
            }
            assert_eq!(dense_rng.next_u64(), sparse_rng.next_u64());
        }
    }

    #[test]
    fn uniform_k_scratch_stays_o_k_for_huge_fleets() {
        // the dense implementation grew `out` to N; the sparse one must
        // stay within 3k entries of capacity even at N = 10^7
        let mut rng = Rng::seed_from(12);
        let mut out = Vec::new();
        for round in 1..5 {
            Selection::UniformK(64).select_into(10_000_000, round, &mut rng, &mut out);
            assert_eq!(out.len(), 64);
            // the buffer holds at most 3k entries; amortized doubling
            // growth can at most round that up to 4k — either way it is
            // O(K), ten-thousand-fold below the dense O(N)
            assert!(
                out.capacity() <= 4 * 64 + 16,
                "capacity {} exceeds the O(K) bound",
                out.capacity()
            );
            assert!(out.windows(2).all(|w| w[0] < w[1]), "sorted, distinct");
            assert!(out.iter().all(|&c| c < 10_000_000), "in range");
        }
    }

    #[test]
    fn property_sampled_k_without_replacement_and_in_range() {
        // satellite pin: SampledK draws are distinct and in-range for N
        // up to 10^7, across many (n, k, seed) shapes
        testing::check(
            "sampled-k-valid",
            48,
            |rng| {
                let n = match rng.below(3) {
                    0 => 1 + rng.below(100),
                    1 => 1 + rng.below(100_000),
                    _ => 10_000_000,
                };
                let k = 1 + rng.below(64.min(n));
                let seed = rng.next_u64();
                (n, k, seed)
            },
            |&(n, k, seed)| {
                let mut rng = Rng::seed_from(seed);
                let mut out = Vec::new();
                for round in 1..4 {
                    Selection::SampledK(k).select_into(n, round, &mut rng, &mut out);
                    if out.len() != k {
                        return false;
                    }
                    // sorted output: distinctness is adjacency
                    if !out.windows(2).all(|w| w[0] < w[1]) {
                        return false;
                    }
                    if !out.iter().all(|&c| c < n) {
                        return false;
                    }
                    if out.capacity() > 4 * k + 16 {
                        return false; // O(K) scratch contract
                    }
                }
                true
            },
        );
    }

    #[test]
    fn sampled_k_is_deterministic_and_seed_sensitive() {
        let a = Selection::SampledK(5).select(1000, 1, &mut Rng::seed_from(77));
        let b = Selection::SampledK(5).select(1000, 1, &mut Rng::seed_from(77));
        let c = Selection::SampledK(5).select(1000, 1, &mut Rng::seed_from(78));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn from_config_maps_kinds() {
        use crate::config::SelectionKind as SK;
        assert_eq!(Selection::from_config(SK::Auto, 10, 10), Selection::All);
        assert_eq!(Selection::from_config(SK::Auto, 10, 4), Selection::UniformK(4));
        assert_eq!(Selection::from_config(SK::Uniform, 10, 4), Selection::UniformK(4));
        assert_eq!(Selection::from_config(SK::Sampled, 10, 4), Selection::SampledK(4));
        assert_eq!(
            Selection::from_config(SK::RoundRobin, 10, 4),
            Selection::RoundRobinK(4)
        );
        // K clamps to the fleet
        assert_eq!(Selection::from_config(SK::Sampled, 3, 9), Selection::SampledK(3));
    }
}
