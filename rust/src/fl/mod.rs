//! Federated-learning core: schemes, client selection, aggregation oracle.
//!
//! The round state machine itself lives in [`crate::coordinator`]; this
//! module holds the pure-math pieces it composes.

pub mod fedavg;
pub mod scheme;
pub mod selection;

pub use fedavg::{fedavg, mean};
pub use scheme::Scheme;
pub use selection::Selection;
