//! Federated-learning core: schemes, client selection, aggregation oracle.
//!
//! The round state machine itself lives in [`crate::coordinator`]; this
//! module holds the pure-math pieces it composes.

pub mod fedavg;
pub mod id_lru;
pub mod scheme;
pub mod selection;

pub use fedavg::{
    fedavg, fedavg_plane_into, mean, mean_packed_masked_accumulate,
    mean_plane_accumulate, mean_plane_into, mean_plane_masked_accumulate,
};
pub use id_lru::IdLru;
pub use scheme::Scheme;
pub use selection::Selection;
