//! FedAvg aggregation math (paper Eq. 1) — the noise-free oracle both
//! wireless paths are measured against.

use crate::tensor;

/// Weighted FedAvg: θ = Σ w_k θ_k / Σ w_k.
/// `weights` are typically dataset sizes (paper: equal shards → equal w).
pub fn fedavg(updates: &[Vec<f32>], weights: &[f32]) -> Vec<f32> {
    assert_eq!(updates.len(), weights.len());
    let n = updates.first().map(|u| u.len()).unwrap_or(0);
    let mut acc = vec![0.0f32; n];
    let total: f32 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum positive");
    for (u, &w) in updates.iter().zip(weights.iter()) {
        assert_eq!(u.len(), n, "update length mismatch");
        tensor::axpy(&mut acc, w / total, u);
    }
    acc
}

/// Unweighted mean (the paper's Alg. 1 step 4: r/K).
pub fn mean(updates: &[Vec<f32>]) -> Vec<f32> {
    let w = vec![1.0f32; updates.len()];
    fedavg(updates, &w)
}

/// Weighted FedAvg over a payload plane, written into a reused output
/// buffer (zero allocation once warm), chunk-parallel.  Bit-identical to
/// [`fedavg`] on the same rows for any `threads`: per element, the
/// weighted contributions accumulate in the same ascending client order.
pub fn fedavg_plane_into(
    plane: &crate::kernels::PayloadPlane,
    weights: &[f32],
    out: &mut Vec<f32>,
    threads: usize,
) {
    assert_eq!(plane.k(), weights.len());
    let total: f32 = weights.iter().sum();
    assert!(total > 0.0, "weights must sum positive");
    out.resize(plane.n(), 0.0);
    out.fill(0.0);
    crate::kernels::par::par_chunks_mut(threads, out.as_mut_slice(), |off, chunk| {
        for (k, &w) in weights.iter().enumerate() {
            let row = &plane.row(k)[off..off + chunk.len()];
            let f = w / total;
            for (o, &x) in chunk.iter_mut().zip(row.iter()) {
                *o += f * x;
            }
        }
    });
}

/// Unweighted mean over a payload plane into a reused buffer —
/// bit-identical to [`mean`] on the same rows for any `threads` (the
/// all-ones weight total `1+1+…+1` is exact in f32 for any realistic K).
pub fn mean_plane_into(
    plane: &crate::kernels::PayloadPlane,
    out: &mut Vec<f32>,
    threads: usize,
) {
    let k = plane.k();
    out.resize(plane.n(), 0.0);
    out.fill(0.0);
    if k == 0 {
        return;
    }
    let f = 1.0f32 / k as f32;
    mean_plane_accumulate(plane, f, out.as_mut_slice(), threads);
}

/// Add `f · row` for every row of `plane` into `out` — NO reset, NO final
/// scale.  This is the streaming-shard kernel behind [`mean_plane_into`]:
/// accumulating a round's shards in slot order with `f = 1/K_total` over a
/// pre-zeroed `out` reproduces the one-shot mean bit-for-bit for every
/// shard partition, because per element the same f32 contributions arrive
/// in the same ascending client order and the chunk grid depends only on
/// `out.len()` and `threads`.
// mpota-lint: zero-alloc-hot
pub fn mean_plane_accumulate(
    plane: &crate::kernels::PayloadPlane,
    f: f32,
    out: &mut [f32],
    threads: usize,
) {
    let k = plane.k();
    if k == 0 {
        return;
    }
    assert_eq!(plane.n(), out.len(), "accumulator length mismatch");
    crate::kernels::par::par_chunks_mut(threads, out, |off, chunk| {
        for ki in 0..k {
            let row = &plane.row(ki)[off..off + chunk.len()];
            for (o, &x) in chunk.iter_mut().zip(row.iter()) {
                *o += f * x;
            }
        }
    });
}

/// Masked form of [`mean_plane_accumulate`] for partial-participation
/// (straggler/dropout) rounds: rows with `included[r] == false` are
/// skipped entirely — never read (the plane holds stale data for clients
/// the round excluded).  `None` delegates to the unmasked kernel, so the
/// everyone-transmits path stays instruction-identical.
// mpota-lint: zero-alloc-hot
pub fn mean_plane_masked_accumulate(
    plane: &crate::kernels::PayloadPlane,
    f: f32,
    included: Option<&[bool]>,
    out: &mut [f32],
    threads: usize,
) {
    let mask = match included {
        None => return mean_plane_accumulate(plane, f, out, threads),
        Some(m) => m,
    };
    let k = plane.k();
    if k == 0 {
        return;
    }
    assert_eq!(mask.len(), k, "participation mask length mismatch");
    assert_eq!(plane.n(), out.len(), "accumulator length mismatch");
    crate::kernels::par::par_chunks_mut(threads, out, |off, chunk| {
        for ki in 0..k {
            if !mask[ki] {
                continue;
            }
            let row = &plane.row(ki)[off..off + chunk.len()];
            for (o, &x) in chunk.iter_mut().zip(row.iter()) {
                *o += f * x;
            }
        }
    });
}

/// [`mean_plane_masked_accumulate`] over a bit-packed shard: decodes each
/// included row's codes inline and adds `f · decode(row)` onto `out` — no
/// intermediate f32 row.  Bit-identical to the f32 kernel over the
/// fake-quantized rows the packed rows decode to (same ascending client
/// order, same per-element op order, same chunk grid).
// mpota-lint: zero-alloc-hot
pub fn mean_packed_masked_accumulate(
    packed: &crate::kernels::PackedPlane,
    f: f32,
    included: Option<&[bool]>,
    out: &mut [f32],
    threads: usize,
) {
    let k = packed.k();
    if k == 0 {
        return;
    }
    if let Some(mask) = included {
        assert_eq!(mask.len(), k, "participation mask length mismatch");
    }
    assert_eq!(packed.n(), out.len(), "accumulator length mismatch");
    crate::kernels::par::par_chunks_mut(threads, out, |off, chunk| {
        for ki in 0..k {
            if included.map_or(false, |mask| !mask[ki]) {
                continue;
            }
            let row = packed.row(ki);
            for (j, o) in chunk.iter_mut().enumerate() {
                *o += f * row.get(off + j);
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing;

    #[test]
    fn mean_of_identical_is_identity() {
        let u = vec![vec![1.0f32, -2.0, 3.0]; 5];
        assert_eq!(mean(&u), vec![1.0, -2.0, 3.0]);
    }

    #[test]
    fn weighted_average() {
        let updates = vec![vec![0.0f32, 0.0], vec![10.0f32, 20.0]];
        let out = fedavg(&updates, &[3.0, 1.0]);
        assert_eq!(out, vec![2.5, 5.0]);
    }

    #[test]
    fn property_mean_within_bounds() {
        // every coordinate of the mean lies within [min, max] of inputs
        testing::check(
            "fedavg-bounds",
            testing::CASES,
            |rng| {
                let k = 1 + rng.below(6);
                let n = 1 + rng.below(50);
                let us: Vec<Vec<f32>> = (0..k)
                    .map(|_| {
                        let mut v = vec![0.0f32; n];
                        rng.fill_normal(&mut v, 0.0, 5.0);
                        v
                    })
                    .collect();
                us
            },
            |us| {
                let m = mean(us);
                (0..m.len()).all(|i| {
                    let lo = us.iter().map(|u| u[i]).fold(f32::INFINITY, f32::min);
                    let hi = us.iter().map(|u| u[i]).fold(f32::NEG_INFINITY, f32::max);
                    m[i] >= lo - 1e-4 && m[i] <= hi + 1e-4
                })
            },
        );
    }

    #[test]
    fn property_linearity() {
        // fedavg(a+b) == fedavg(a) + fedavg(b) elementwise
        testing::check(
            "fedavg-linearity",
            32,
            |rng| {
                let n = 1 + rng.below(32);
                let mk = |rng: &mut crate::rng::Rng| {
                    (0..3)
                        .map(|_| {
                            let mut v = vec![0.0f32; n];
                            rng.fill_normal(&mut v, 0.0, 1.0);
                            v
                        })
                        .collect::<Vec<_>>()
                };
                (mk(rng), mk(rng))
            },
            |(a, b)| {
                let sum: Vec<Vec<f32>> = a
                    .iter()
                    .zip(b.iter())
                    .map(|(x, y)| x.iter().zip(y.iter()).map(|(p, q)| p + q).collect())
                    .collect();
                let lhs = mean(&sum);
                let ra = mean(a);
                let rb = mean(b);
                lhs.iter()
                    .zip(ra.iter().zip(rb.iter()))
                    .all(|(l, (x, y))| (l - (x + y)).abs() < 1e-4)
            },
        );
    }

    #[test]
    #[should_panic(expected = "weights must sum positive")]
    fn zero_weights_panic() {
        let _ = fedavg(&[vec![1.0]], &[0.0]);
    }

    #[test]
    fn sharded_mean_accumulation_matches_one_shot_bitwise() {
        // splitting the rows into arbitrary shard partitions and
        // accumulating in slot order must reproduce the one-shot mean
        // bit-for-bit (the streaming round's ideal-reference contract)
        let mut rng = crate::rng::Rng::seed_from(61);
        let k = 9usize;
        let n = 20_000usize;
        let rows: Vec<Vec<f32>> = (0..k)
            .map(|_| {
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, 0.0, 1.5);
                v
            })
            .collect();
        let plane = crate::kernels::PayloadPlane::from_rows(&rows);
        for threads in [1usize, 4] {
            let mut want = Vec::new();
            mean_plane_into(&plane, &mut want, threads);
            for shard in [1usize, 2, 4, 9] {
                let f = 1.0f32 / k as f32;
                let mut acc = vec![0.0f32; n];
                let mut lo = 0usize;
                while lo < k {
                    let hi = (lo + shard).min(k);
                    let shard_plane =
                        crate::kernels::PayloadPlane::from_rows(&rows[lo..hi]);
                    mean_plane_accumulate(&shard_plane, f, &mut acc, threads);
                    lo = hi;
                }
                assert_eq!(acc, want, "shard={shard} threads={threads}");
            }
        }
    }

    #[test]
    fn plane_mean_and_fedavg_match_bitwise() {
        let mut rng = crate::rng::Rng::seed_from(51);
        let updates: Vec<Vec<f32>> = (0..5)
            .map(|_| {
                let mut v = vec![0.0f32; 20_000];
                rng.fill_normal(&mut v, 0.0, 2.0);
                v
            })
            .collect();
        let weights = [3.0f32, 1.0, 2.0, 0.5, 4.0];
        let want_mean = mean(&updates);
        let want_avg = fedavg(&updates, &weights);
        let plane = crate::kernels::PayloadPlane::from_rows(&updates);
        let mut out = Vec::new();
        for threads in [1usize, 4] {
            mean_plane_into(&plane, &mut out, threads);
            assert_eq!(out, want_mean, "mean threads={threads}");
            fedavg_plane_into(&plane, &weights, &mut out, threads);
            assert_eq!(out, want_avg, "fedavg threads={threads}");
        }
    }
}
