//! `IdLru` — a client-**identity**-keyed bounded LRU slab.
//!
//! The fleet-scaling substrate for every piece of persistent per-client
//! state (GaussMarkov fading memory, path-loss sites, `ClientState`,
//! profiling history): state is keyed by CLIENT ID, never by the
//! participant slot a client happens to occupy this round, and total
//! memory is bounded by the configured capacity — O(K), never O(fleet).
//!
//! Design constraints, in order:
//!
//! 1. **Determinism** — no hash collections anywhere (lint rule R3):
//!    the id index is a sorted `Vec<(id, slot)>` probed by binary
//!    search, so every iteration order is a pure function of the ids.
//! 2. **Zero-alloc warm rounds** — all three backing vectors reserve
//!    capacity up front (`reserve`); inserts within capacity use
//!    `Vec::push`/`Vec::insert` below capacity and evictions recycle
//!    the LRU slot in place, so a round over resident-or-evictable ids
//!    touches the heap only while capacity is still growing.
//! 3. **Stable slots** — a resident value never moves: `slot_of` /
//!    `value_mut` indices stay valid across touches and unrelated
//!    evictions, which lets callers hold `u32` slots for a whole round
//!    (the coordinator's slab-indexed client phase relies on this).
//!
//! Capacity protocol: callers `reserve(2 * K)` at the top of each round
//! (monotone — capacity never shrinks).  With capacity ≥ 2K, one round's
//! K participants can never evict each other: eviction only fires when
//! the LRU is full of OLDER entries, and at 2K at least K of them are
//! from previous rounds.
//!
//! Recency: `get_or_insert_with` is the only *touching* accessor (it
//! front-moves the entry); `get`/`slot_of` deliberately do not touch, so
//! read-only probes (diagnostics, tests) cannot perturb eviction order.

/// Sentinel link: "no slot".
const NIL: u32 = u32::MAX;

#[derive(Clone, Debug)]
struct Meta {
    /// The client id owning this slot.
    id: usize,
    /// More-recently-used neighbour (toward `head`).
    prev: u32,
    /// Less-recently-used neighbour (toward `tail`).
    next: u32,
}

/// Bounded, id-keyed LRU slab. See the module docs for the contract.
#[derive(Clone, Debug, Default)]
pub struct IdLru<T> {
    /// Slot-indexed values (parallel to `meta`).
    values: Vec<T>,
    /// Slot-indexed ids + intrusive recency links.
    meta: Vec<Meta>,
    /// `(id, slot)` pairs sorted by id — the deterministic index.
    index: Vec<(usize, u32)>,
    /// Most-recently-used slot (NIL when empty).
    head: u32,
    /// Least-recently-used slot (NIL when empty) — the eviction victim.
    tail: u32,
    /// Maximum resident entries; 0 until the first `reserve`.
    cap: usize,
}

impl<T> IdLru<T> {
    /// An empty LRU with zero capacity — `reserve` before inserting.
    pub fn new() -> Self {
        IdLru {
            values: Vec::new(),
            meta: Vec::new(),
            index: Vec::new(),
            head: NIL,
            tail: NIL,
            cap: 0,
        }
    }

    /// Grow (never shrink) the capacity to at least `cap` entries and
    /// pre-reserve the backing vectors, so inserts up to `cap` are
    /// allocation-free.  Warm-round no-op once sized.
    pub fn reserve(&mut self, cap: usize) {
        if cap <= self.cap {
            return;
        }
        self.cap = cap;
        self.values.reserve(cap - self.values.len());
        self.meta.reserve(cap - self.meta.len());
        self.index.reserve(cap - self.index.len());
    }

    /// Resident entry count.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Current capacity bound.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Is `id` resident?  Does not touch recency.
    pub fn contains(&self, id: usize) -> bool {
        self.index.binary_search_by_key(&id, |e| e.0).is_ok()
    }

    /// Resident slot of `id`, if any.  Does not touch recency.
    pub fn slot_of(&self, id: usize) -> Option<u32> {
        self.index
            .binary_search_by_key(&id, |e| e.0)
            .ok()
            .map(|i| self.index[i].1)
    }

    /// Resident value of `id`, if any.  Does not touch recency.
    pub fn get(&self, id: usize) -> Option<&T> {
        self.slot_of(id).map(|s| &self.values[s as usize])
    }

    /// Value at a slot previously returned by `get_or_insert_with` /
    /// `slot_of`.
    pub fn value(&self, slot: u32) -> &T {
        &self.values[slot as usize]
    }

    /// Mutable value at a slot.
    pub fn value_mut(&mut self, slot: u32) -> &mut T {
        &mut self.values[slot as usize]
    }

    /// All resident values in slot order (slot order is insertion order
    /// of the slots, NOT recency and NOT id order).
    pub fn values(&self) -> &[T] {
        &self.values
    }

    /// All resident values, mutably, in slot order.
    pub fn values_mut(&mut self) -> &mut [T] {
        &mut self.values
    }

    /// The `(id, slot)` index, sorted by id — the deterministic
    /// iteration order for reductions over residents.
    pub fn entries(&self) -> &[(usize, u32)] {
        &self.index
    }

    /// Look up `id`, inserting `make()` if absent (evicting the
    /// least-recently-used entry when full).  Returns
    /// `(slot, fresh, evicted)`: `fresh` is true when `make` ran, and
    /// `evicted` carries the displaced value (its id left the index).
    /// This is the one *touching* accessor — the entry becomes MRU.
    ///
    /// Panics if called with zero capacity (`reserve` first).
    pub fn get_or_insert_with<F: FnOnce() -> T>(
        &mut self,
        id: usize,
        make: F,
    ) -> (u32, bool, Option<T>) {
        match self.index.binary_search_by_key(&id, |e| e.0) {
            Ok(i) => {
                let slot = self.index[i].1;
                self.touch(slot);
                (slot, false, None)
            }
            Err(i) => {
                assert!(self.cap > 0, "IdLru: reserve a capacity before inserting");
                if self.values.len() < self.cap {
                    // room: append a new slot
                    let slot = self.values.len() as u32;
                    self.values.push(make());
                    self.meta.push(Meta { id, prev: NIL, next: NIL });
                    self.link_front(slot);
                    self.index.insert(i, (id, slot));
                    (slot, true, None)
                } else {
                    // full: recycle the least-recently-used slot
                    let slot = self.tail;
                    let old_id = self.meta[slot as usize].id;
                    let old = std::mem::replace(&mut self.values[slot as usize], make());
                    let old_i = self
                        .index
                        .binary_search_by_key(&old_id, |e| e.0)
                        .expect("IdLru: tail id missing from index");
                    self.index.remove(old_i);
                    // re-probe: removing old_id may shift the target
                    let new_i = self
                        .index
                        .binary_search_by_key(&id, |e| e.0)
                        .expect_err("IdLru: inserting an id that is already resident");
                    self.index.insert(new_i, (id, slot));
                    self.meta[slot as usize].id = id;
                    self.touch(slot);
                    (slot, true, Some(old))
                }
            }
        }
    }

    /// Detach `slot` from the recency list.
    fn unlink(&mut self, slot: u32) {
        let Meta { prev, next, .. } = self.meta[slot as usize];
        if prev != NIL {
            self.meta[prev as usize].next = next;
        } else {
            self.head = next;
        }
        if next != NIL {
            self.meta[next as usize].prev = prev;
        } else {
            self.tail = prev;
        }
    }

    /// Attach `slot` at the head (MRU position).
    fn link_front(&mut self, slot: u32) {
        self.meta[slot as usize].prev = NIL;
        self.meta[slot as usize].next = self.head;
        if self.head != NIL {
            self.meta[self.head as usize].prev = slot;
        }
        self.head = slot;
        if self.tail == NIL {
            self.tail = slot;
        }
    }

    /// Move `slot` to the MRU position.
    fn touch(&mut self, slot: u32) {
        if self.head == slot {
            return;
        }
        self.unlink(slot);
        self.link_front(slot);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(lru: &IdLru<u64>) -> Vec<usize> {
        lru.entries().iter().map(|&(id, _)| id).collect()
    }

    #[test]
    fn inserts_and_looks_up_by_id() {
        let mut lru: IdLru<u64> = IdLru::new();
        lru.reserve(4);
        let (s7, fresh, ev) = lru.get_or_insert_with(7, || 70);
        assert!(fresh && ev.is_none());
        let (s3, fresh, _) = lru.get_or_insert_with(3, || 30);
        assert!(fresh);
        assert_ne!(s7, s3);
        // resident lookup: same slot, not fresh, no make() call
        let (again, fresh, ev) = lru.get_or_insert_with(7, || unreachable!());
        assert_eq!(again, s7);
        assert!(!fresh && ev.is_none());
        assert_eq!(lru.get(3), Some(&30));
        assert_eq!(lru.get(99), None);
        assert_eq!(ids(&lru), vec![3, 7], "index iterates in id order");
    }

    #[test]
    fn evicts_least_recently_used_and_recycles_the_slot() {
        let mut lru: IdLru<u64> = IdLru::new();
        lru.reserve(2);
        lru.get_or_insert_with(1, || 10);
        lru.get_or_insert_with(2, || 20);
        // touch 1 so 2 becomes LRU
        lru.get_or_insert_with(1, || unreachable!());
        let (slot, fresh, evicted) = lru.get_or_insert_with(3, || 33);
        assert!(fresh);
        assert_eq!(evicted, Some(20), "id 2 was LRU");
        assert!(!lru.contains(2));
        assert!(lru.contains(1) && lru.contains(3));
        assert_eq!(lru.len(), 2);
        // the evictee's slot was recycled in place
        assert_eq!(lru.slot_of(3), Some(slot));
        assert_eq!(lru.value(slot), &33);
    }

    #[test]
    fn resident_slots_are_stable_across_touches_and_evictions() {
        let mut lru: IdLru<u64> = IdLru::new();
        lru.reserve(3);
        lru.get_or_insert_with(10, || 1);
        let (s20, _, _) = lru.get_or_insert_with(20, || 2);
        lru.get_or_insert_with(30, || 3);
        // touch 20, then force an eviction (victim: 10)
        lru.get_or_insert_with(20, || unreachable!());
        let (_, _, evicted) = lru.get_or_insert_with(40, || 4);
        assert_eq!(evicted, Some(1));
        assert_eq!(lru.slot_of(20), Some(s20), "resident slot moved");
        assert_eq!(lru.value(s20), &2);
    }

    #[test]
    fn reserve_is_monotone_and_grows_capacity() {
        let mut lru: IdLru<u64> = IdLru::new();
        lru.reserve(4);
        assert_eq!(lru.capacity(), 4);
        lru.reserve(2); // shrink request: ignored
        assert_eq!(lru.capacity(), 4);
        lru.reserve(8);
        assert_eq!(lru.capacity(), 8);
        for id in 0..8 {
            lru.get_or_insert_with(id, || id as u64);
        }
        assert_eq!(lru.len(), 8);
        assert_eq!(ids(&lru), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn cap_2k_never_evicts_the_current_round() {
        // the capacity protocol: with cap = 2K, inserting K fresh ids
        // can only evict ids from PREVIOUS rounds
        let k = 8usize;
        let mut lru: IdLru<usize> = IdLru::new();
        lru.reserve(2 * k);
        for round in 0..50 {
            let base = round * 1000;
            for j in 0..k {
                let id = base + j;
                let (_, _, evicted) = lru.get_or_insert_with(id, || id);
                if let Some(old) = evicted {
                    assert!(old < base, "evicted a current-round participant");
                }
            }
            for j in 0..k {
                assert!(lru.contains(base + j), "round member evicted mid-round");
            }
        }
        assert_eq!(lru.len(), 2 * k);
    }

    #[test]
    fn eviction_keeps_the_index_sorted() {
        let mut lru: IdLru<u64> = IdLru::new();
        lru.reserve(3);
        for id in [5usize, 1, 9, 4, 7, 2, 8] {
            lru.get_or_insert_with(id, || id as u64);
            let got = ids(&lru);
            let mut want = got.clone();
            want.sort_unstable();
            assert_eq!(got, want);
            for &(id, slot) in lru.entries() {
                assert_eq!(lru.value(slot), &(id as u64));
            }
        }
    }
}
