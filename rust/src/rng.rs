//! Deterministic, splittable PRNG for fully reproducible experiments.
//!
//! All stochastic behaviour in the system — dataset generation, client
//! selection, Rayleigh fading draws, pilot noise, receiver AWGN — flows
//! from one root seed through *named streams*, so a run is reproducible
//! bit-for-bit regardless of thread scheduling: every client worker and
//! every substrate derives its own independent stream instead of sharing a
//! mutable global generator.
//!
//! Generator: xoshiro256++ (Blackman & Vigna), seeded via splitmix64.
//! No external crates (the image only vendors the `xla` closure).

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller output
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 (expanded with splitmix64 per Vigna's
    /// recommendation so low-entropy seeds still fill all 256 bits).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // xoshiro must not start at the all-zero state
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Rng { s, spare_normal: None }
    }

    /// Derive an independent named stream: hash the label into the seed
    /// space and mix with this generator's state *without* consuming from
    /// it.  Streams with different labels are statistically independent.
    pub fn stream(&self, label: &str) -> Rng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a offset basis
        for b in label.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        let mixed = self.s[0] ^ h.rotate_left(17) ^ self.s[2].rotate_left(29);
        Rng::seed_from(mixed ^ h)
    }

    /// Derive an independent stream indexed by an integer (e.g. client id).
    pub fn substream(&self, index: u64) -> Rng {
        let mixed = self.s[1]
            ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(23)
            ^ self.s[3];
        Rng::seed_from(mixed)
    }

    /// Next raw u64.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift (unbiased for
    /// our n << 2^64 use-cases up to negligible 2^-64 bias).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box-Muller (caches the second draw).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0)
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean / std, as f32.
    #[inline]
    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Rayleigh-distributed magnitude with scale sigma:
    /// if X,Y ~ N(0, sigma^2) then |X + iY| ~ Rayleigh(sigma).
    pub fn rayleigh(&mut self, sigma: f64) -> f64 {
        let u = 1.0 - self.uniform();
        sigma * (-2.0 * u.ln()).sqrt()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot choose {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Fill a slice with standard normals (f32).
    ///
    /// Hot-path form: consumes Box-Muller PAIRS directly (no spare-cache
    /// branch per element), which measures ~25% faster than per-element
    /// `normal_f32` on the OTA noise-injection path (EXPERIMENTS.md §Perf).
    // mpota-lint: zero-alloc-hot
    pub fn fill_normal(&mut self, out: &mut [f32], mean: f32, std: f32) {
        let mut i = 0usize;
        while i + 1 < out.len() {
            let u1 = 1.0 - self.uniform();
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            let (s, c) = theta.sin_cos();
            out[i] = mean + std * (r * c) as f32;
            out[i + 1] = mean + std * (r * s) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] = self.normal_f32(mean, std);
        }
    }

    /// Add N(0, std²) noise to a slice in place (single pass, no scratch
    /// buffer — the OTA AWGN hot path).
    // mpota-lint: zero-alloc-hot
    pub fn add_normal(&mut self, out: &mut [f32], std: f32) {
        let mut i = 0usize;
        while i + 1 < out.len() {
            let u1 = 1.0 - self.uniform();
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            let (s, c) = theta.sin_cos();
            out[i] += std * (r * c) as f32;
            out[i + 1] += std * (r * s) as f32;
            i += 2;
        }
        if i < out.len() {
            out[i] += self.normal_f32(0.0, std);
        }
    }

    /// Advance the generator by `draws` raw u64 outputs (the spare-normal
    /// cache is untouched).  Used to fast-forward worker clones to a known
    /// position in the stream.
    pub fn skip(&mut self, draws: u64) {
        for _ in 0..draws {
            self.next_u64();
        }
    }

    /// Clone the generator fast-forwarded by `draws` raw outputs, with the
    /// spare-normal cache cleared (worker clones only ever execute the
    /// pairwise Box-Muller loop, which never consults the cache).
    pub fn clone_skip(&self, draws: u64) -> Rng {
        let mut r = Rng { s: self.s, spare_normal: None };
        r.skip(draws);
        r
    }

    /// Add N(0, std²) noise to `re` then `im` — bit-identical to
    /// `self.add_normal(re, std); self.add_normal(im, std);` for EVERY
    /// thread count, parallel (on the [`crate::exec`] pool) when
    /// profitable.
    ///
    /// Exactness argument: for even lengths the sequential pass consumes
    /// exactly one u64 draw per element (two per Box-Muller pair: u1, u2)
    /// and never touches the spare-normal cache, so the draw position of
    /// every element is known in advance — element `i` of `re` starts at
    /// draw `i`, element `i` of `im` at draw `n + i`.  A single cursor
    /// sweep clones the generator state at each pair-aligned chunk
    /// boundary (in draw order) into a fixed stack table
    /// ([`crate::kernels::par::MAX_CHUNKS`] bounds the grid), pool tasks
    /// fill their disjoint chunks with exactly the draws the sequential
    /// pass would have used there, and the owning generator lands past
    /// all `2n` draws.  Odd lengths interact with the spare cache and
    /// fall back to the sequential pass (the OTA payload length is the
    /// model parameter count — even for every shipped variant).
    // mpota-lint: zero-alloc-hot
    pub fn add_normal2(&mut self, re: &mut [f32], im: &mut [f32], std: f32, threads: usize) {
        use crate::kernels::par;
        assert_eq!(re.len(), im.len(), "noise component length mismatch");
        let n = re.len();
        let total = 2 * n;
        let chunks = par::effective_chunks(threads, total);
        if chunks <= 1 || n % 2 != 0 {
            self.add_normal(re, std);
            self.add_normal(im, std);
            return;
        }
        let pairs = total / 2;
        // One cursor sweeps the stream ONCE on this thread, cloning the
        // generator state at each segment boundary (boundaries are visited
        // in increasing draw order), so pool tasks start with zero
        // skipping and the total fast-forward work is O(2n) instead of
        // O(threads·n).  The table lives on the stack: the parallel noise
        // path stays allocation-free.
        let mut cursor = self.clone_skip(0);
        let mut pos = 0u64;
        let mut table: [(Option<Rng>, Option<Rng>); par::MAX_CHUNKS] =
            std::array::from_fn(|_| (None, None));
        for c in 0..chunks {
            let (re_lo, re_hi, im_lo, im_hi) = noise_chunk_ranges(n, pairs, chunks, c);
            if re_hi > re_lo {
                cursor.skip(re_lo as u64 - pos);
                pos = re_lo as u64;
                table[c].0 = Some(cursor.clone());
            }
            if im_hi > im_lo {
                cursor.skip((n + im_lo) as u64 - pos);
                pos = (n + im_lo) as u64;
                table[c].1 = Some(cursor.clone());
            }
        }
        let re_base = crate::exec::SendPtr::from_mut(re);
        let im_base = crate::exec::SendPtr::from_mut(im);
        let table_ref = &table;
        let task = move |c: usize| {
            let (re_lo, re_hi, im_lo, im_hi) = noise_chunk_ranges(n, pairs, chunks, c);
            if re_hi > re_lo {
                // SAFETY: chunk ranges are disjoint across task indices
                // and each index runs exactly once; the buffers outlive
                // the blocking dispatch.
                let part = unsafe { re_base.slice_at(re_lo, re_hi - re_lo) };
                let mut r = table_ref[c].0.clone().expect("re state precomputed");
                r.add_normal(part, std);
            }
            if im_hi > im_lo {
                // SAFETY: as above, over the `im` buffer.
                let part = unsafe { im_base.slice_at(im_lo, im_hi - im_lo) };
                let mut r = table_ref[c].1.clone().expect("im state precomputed");
                r.add_normal(part, std);
            }
        };
        crate::exec::pool().broadcast(chunks, &task);
        // land the owning generator exactly where the sequential pass would
        cursor.skip(total as u64 - pos);
        self.s = cursor.s;
    }
}

/// Element ranges of chunk `c` over the virtual `[re || im]` draw stream,
/// aligned to Box-Muller pairs: returns `(re_lo, re_hi, im_lo, im_hi)`
/// with the `im` range already translated to `im`-local indices.
fn noise_chunk_ranges(
    n: usize,
    pairs: usize,
    chunks: usize,
    c: usize,
) -> (usize, usize, usize, usize) {
    use crate::kernels::par;
    let p0 = par::chunk_start(pairs, chunks, c);
    let p1 = p0 + par::chunk_len(pairs, chunks, c);
    let (g0, g1) = (2 * p0, 2 * p1);
    (g0.min(n), g1.min(n), g0.max(n) - n, g1.max(n) - n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn streams_are_independent_and_stable() {
        let root = Rng::seed_from(7);
        let mut s1 = root.stream("channel");
        let mut s2 = root.stream("data");
        let mut s1b = root.stream("channel");
        assert_eq!(s1.next_u64(), s1b.next_u64());
        assert_ne!(s1.next_u64(), s2.next_u64());
    }

    #[test]
    fn substreams_differ_per_index() {
        let root = Rng::seed_from(7);
        let mut c0 = root.substream(0);
        let mut c1 = root.substream(1);
        assert_ne!(c0.next_u64(), c1.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::seed_from(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    // statistical moment checks draw 50k–100k samples — prohibitively
    // slow under the Miri interpreter and not what Miri is for (they
    // carry no unsafe); the CI Miri job skips them
    #[test]
    #[cfg_attr(miri, ignore)]
    fn uniform_mean_near_half() {
        let mut r = Rng::seed_from(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn normal_moments() {
        let mut r = Rng::seed_from(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn rayleigh_mean_matches_theory() {
        // E[Rayleigh(sigma)] = sigma * sqrt(pi/2)
        let mut r = Rng::seed_from(9);
        let sigma = 0.5f64;
        let n = 100_000;
        let mean = (0..n).map(|_| r.rayleigh(sigma)).sum::<f64>() / n as f64;
        let expect = sigma * (std::f64::consts::PI / 2.0).sqrt();
        assert!((mean - expect).abs() < 0.01, "mean {mean} expect {expect}");
    }

    #[test]
    #[cfg_attr(miri, ignore)]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::seed_from(13);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::seed_from(17);
        for _ in 0..100 {
            let ks = r.choose_k(15, 5);
            assert_eq!(ks.len(), 5);
            let mut sorted = ks.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 5, "duplicates in {ks:?}");
            assert!(ks.iter().all(|&i| i < 15));
        }
    }

    #[test]
    fn clone_skip_matches_manual_advance() {
        let base = Rng::seed_from(77);
        let mut skipped = base.clone_skip(1000);
        let mut manual = base.clone();
        for _ in 0..1000 {
            manual.next_u64();
        }
        for _ in 0..16 {
            assert_eq!(skipped.next_u64(), manual.next_u64());
        }
    }

    #[test]
    fn add_normal2_bit_identical_any_thread_count() {
        // large enough to cross the parallel threshold, even length
        // (shrunk under Miri — still multi-chunk, interpreter-affordable)
        let sizes: [usize; 2] = if cfg!(miri) { [8_192, 4_096] } else { [20_000, 16_384] };
        for n in sizes {
            let mut want_re = vec![0.25f32; n];
            let mut want_im = vec![-0.5f32; n];
            let mut seq = Rng::seed_from(4242);
            seq.add_normal(&mut want_re, 0.7);
            seq.add_normal(&mut want_im, 0.7);
            for threads in [1usize, 2, 4, 7] {
                let mut re = vec![0.25f32; n];
                let mut im = vec![-0.5f32; n];
                let mut rng = Rng::seed_from(4242);
                rng.add_normal2(&mut re, &mut im, 0.7, threads);
                assert_eq!(re, want_re, "n={n} threads={threads}");
                assert_eq!(im, want_im, "n={n} threads={threads}");
                // generator state must also end up identical
                assert_eq!(rng.next_u64(), seq.clone().next_u64());
            }
        }
    }

    #[test]
    fn add_normal2_odd_length_falls_back_exactly() {
        // odd: exercises the spare-normal tail path
        let n = if cfg!(miri) { 4_097usize } else { 12_345 };
        let mut want_re = vec![0.0f32; n];
        let mut want_im = vec![0.0f32; n];
        let mut seq = Rng::seed_from(99);
        seq.add_normal(&mut want_re, 1.3);
        seq.add_normal(&mut want_im, 1.3);
        let mut re = vec![0.0f32; n];
        let mut im = vec![0.0f32; n];
        let mut rng = Rng::seed_from(99);
        rng.add_normal2(&mut re, &mut im, 1.3, 4);
        assert_eq!(re, want_re);
        assert_eq!(im, want_im);
        assert_eq!(rng.next_u64(), seq.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed_from(19);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
