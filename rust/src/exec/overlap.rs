//! Debug-build overlap registry: the executable form of the `SendPtr` /
//! `SendMutPtr` / `DisjointMut` SAFETY arguments.
//!
//! Every mutable range those wrappers hand to a pool task is *claimed*
//! here (absolute byte addresses), checked against all live claims, and
//! released when the owning dispatch retires.  The rules mirror the
//! documented contracts exactly:
//!
//! * two claims from DIFFERENT tasks (or different concurrent dispatches)
//!   must be disjoint — an overlap panics before the aliasing reference
//!   is ever created, so the violation aborts instead of racing;
//! * claims from the SAME task are always fine (a task reborrowing inside
//!   its own region is the nested-kernel case);
//! * at shard/round boundaries ([`assert_quiescent`], called by the
//!   coordinator and sweep engines) no claim from a dispatch this thread
//!   initiated may still be live.
//!
//! Only the OUTERMOST dispatch level registers claims (nested inline
//! dispatches — a client task's chunk-parallel kernels — run under the
//! owning task's identity, where aliasing is the task's own business and
//! checking would be quadratic in kernel calls).  The registry reuses one
//! global `Vec`'s capacity forever, so steady-state rounds stay
//! allocation-free and `rust/tests/alloc_counter.rs` keeps passing in
//! debug builds.  The whole module is compiled only under
//! `debug_assertions`; release builds carry zero cost and byte-identical
//! behaviour.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};

/// Per-thread dispatch context: which (dispatch, task) the code currently
/// runs as, who initiated the dispatch, and how deeply dispatches nest.
#[derive(Clone, Copy)]
struct Ctx {
    /// 0 = not inside any dispatch scope (claims are skipped).
    dispatch: u64,
    task: u32,
    /// Numeric id of the thread that initiated the dispatch.
    initiator: u64,
    /// 1 = direct task of the outermost dispatch (claims register);
    /// deeper levels skip.
    depth: u32,
}

const UNSCOPED: Ctx = Ctx { dispatch: 0, task: 0, initiator: 0, depth: 0 };

thread_local! {
    static CTX: Cell<Ctx> = const { Cell::new(UNSCOPED) };
    /// Lazily-assigned small numeric thread id (no allocation, unlike
    /// `std::thread::current()`).
    static THREAD_ID: Cell<u64> = const { Cell::new(0) };
}

static NEXT_DISPATCH: AtomicU64 = AtomicU64::new(1);
static NEXT_THREAD: AtomicU64 = AtomicU64::new(1);

#[derive(Clone, Copy)]
struct Claim {
    lo: usize,
    hi: usize,
    dispatch: u64,
    task: u32,
    initiator: u64,
}

static REGISTRY: Mutex<Vec<Claim>> = Mutex::new(Vec::new());

/// Cheap numeric id for the current thread.
pub(crate) fn thread_id() -> u64 {
    THREAD_ID.with(|c| {
        let v = c.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_THREAD.fetch_add(1, Ordering::Relaxed);
        c.set(v);
        v
    })
}

fn lock_registry() -> MutexGuard<'static, Vec<Claim>> {
    // a deliberate-overlap panic (tests) poisons the mutex; the claim
    // list itself is always consistent, so keep going
    REGISTRY.lock().unwrap_or_else(|p| p.into_inner())
}

/// Record a mutable byte-range claim `[lo, hi)` for the current task and
/// panic if it overlaps a live claim from any OTHER task.  No-op outside
/// a depth-1 dispatch scope (see the module docs).
pub(crate) fn claim(lo: usize, hi: usize) {
    let ctx = CTX.with(|c| c.get());
    if ctx.dispatch == 0 || ctx.depth != 1 {
        return;
    }
    let mut reg = lock_registry();
    for c in reg.iter() {
        let same_task = c.dispatch == ctx.dispatch && c.task == ctx.task;
        if !same_task && c.lo < hi && lo < c.hi {
            let (clo, chi, cd, ct) = (c.lo, c.hi, c.dispatch, c.task);
            drop(reg);
            panic!(
                "exec overlap registry: overlapping mutable ranges handed to \
                 concurrent tasks: [{lo:#x}, {hi:#x}) (dispatch {}, task {}) \
                 vs live [{clo:#x}, {chi:#x}) (dispatch {cd}, task {ct})",
                ctx.dispatch, ctx.task
            );
        }
    }
    reg.push(Claim {
        lo,
        hi,
        dispatch: ctx.dispatch,
        task: ctx.task,
        initiator: ctx.initiator,
    });
}

/// Assert that no claim from a dispatch initiated by THIS thread is still
/// live — the shard/round-boundary quiescence contract.  Claims from
/// other threads' concurrent dispatches (parallel tests) are ignored.
pub(crate) fn assert_quiescent() {
    let me = thread_id();
    let reg = lock_registry();
    for c in reg.iter() {
        assert!(
            c.initiator != me,
            "exec overlap registry: claim [{:#x}, {:#x}) (dispatch {}, task {}) \
             is still live at a shard/round boundary",
            c.lo,
            c.hi,
            c.dispatch,
            c.task
        );
    }
}

/// Initiator-side handle for one pooled dispatch: allocates the dispatch
/// id and, on drop (normal retire or unwind), releases every claim made
/// under it.  `retain` compacts in place — capacity is never given back.
pub(crate) struct DispatchClaims {
    pub(crate) id: u64,
    pub(crate) initiator: u64,
}

impl DispatchClaims {
    pub(crate) fn begin() -> DispatchClaims {
        DispatchClaims {
            id: NEXT_DISPATCH.fetch_add(1, Ordering::Relaxed),
            initiator: thread_id(),
        }
    }
}

impl Drop for DispatchClaims {
    fn drop(&mut self) {
        let mut reg = lock_registry();
        reg.retain(|c| c.dispatch != self.id);
    }
}

/// Worker/caller-side scope for running ONE task of a pooled dispatch:
/// installs the task identity (depth +1) for the duration of the closure.
pub(crate) struct TaskScope {
    saved: Ctx,
}

impl TaskScope {
    pub(crate) fn enter(dispatch: u64, task: u32, initiator: u64) -> TaskScope {
        let saved = CTX.with(|c| c.get());
        CTX.with(|c| {
            c.set(Ctx { dispatch, task, initiator, depth: saved.depth + 1 })
        });
        TaskScope { saved }
    }
}

impl Drop for TaskScope {
    fn drop(&mut self) {
        CTX.with(|c| c.set(self.saved));
    }
}

/// Scope for the inline dispatch path.  At the outermost level it acts as
/// a full dispatch (fresh id, per-iteration task identities, claims
/// released on drop) so the disjointness contract is checked even when
/// tasks run sequentially — the contract is about the ranges handed out,
/// not the schedule.  Nested inside a pool task it only bumps the depth,
/// keeping the owning task's identity.
pub(crate) struct InlineScope {
    saved: Ctx,
    own: Option<DispatchClaims>,
}

impl InlineScope {
    pub(crate) fn begin() -> InlineScope {
        let saved = CTX.with(|c| c.get());
        if saved.depth == 0 {
            let d = DispatchClaims::begin();
            CTX.with(|c| {
                c.set(Ctx {
                    dispatch: d.id,
                    task: 0,
                    initiator: d.initiator,
                    depth: 1,
                })
            });
            InlineScope { saved, own: Some(d) }
        } else {
            let mut ctx = saved;
            ctx.depth += 1;
            CTX.with(|c| c.set(ctx));
            InlineScope { saved, own: None }
        }
    }

    pub(crate) fn enter_task(&self, i: usize) {
        if self.own.is_some() {
            CTX.with(|c| {
                let mut ctx = c.get();
                ctx.task = i as u32;
                c.set(ctx);
            });
        }
    }
}

impl Drop for InlineScope {
    fn drop(&mut self) {
        // restore the context first; the owned DispatchClaims (field drop
        // order) then releases this scope's claims
        CTX.with(|c| c.set(self.saved));
    }
}
