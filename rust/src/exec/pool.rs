//! [`ExecPool`] — the persistent, parked worker pool every parallel
//! dispatch in the crate runs on.
//!
//! The PR-1 kernels spawned `std::thread::scope` workers per call, which
//! allocates a stack per chunk per dispatch and pays thread-creation
//! latency on every parallel kernel.  The pool spawns its workers ONCE
//! (lazily, up to a cap) and parks them on a condvar between jobs; a
//! dispatch installs a lifetime-erased job descriptor, wakes the workers,
//! and blocks until every task has run — no heap allocation anywhere on
//! the dispatch path, so the `threads > 1` round loop is zero-alloc in
//! steady state just like the sequential one (`tests/alloc_counter.rs`).
//!
//! # Dispatch model
//!
//! A job is `tasks` indexed closures `f(0..tasks)`.  Workers (and, for
//! [`broadcast`](ExecPool::broadcast), the calling thread) claim task
//! indices from a shared atomic counter until none remain.  Which thread
//! runs which index is scheduling-dependent — callers must make tasks
//! independent and deterministic by INDEX (disjoint output regions,
//! per-index RNG state), which is exactly the kernels-layer chunk-grid
//! contract, so results are bit-identical no matter how tasks land on
//! threads.
//!
//! # Nesting
//!
//! Dispatching from inside a pool task (or while the current thread is
//! already mid-dispatch) runs the inner job inline on the current thread:
//! inner parallelism would otherwise deadlock waiting for workers the
//! outer job occupies.  This keeps layered parallelism safe by
//! construction — e.g. client-partitioned training whose per-client
//! kernels are themselves chunk-parallel.
//!
//! # Safety
//!
//! The job descriptor stores raw pointers to the caller's closure and
//! counters (all on the caller's stack).  The dispatch cannot return
//! until every worker that copied the descriptor has dropped it
//! (`refs == 0`) and every task has finished (`done == tasks`), and the
//! descriptor is cleared under the same lock, so no worker can observe a
//! dangling job.  Task panics are caught, forwarded, and re-raised on the
//! calling thread after the job is fully retired.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// Set for the lifetime of a pool worker thread.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
    /// Set on any thread for the duration of a pool dispatch it initiated.
    static IN_DISPATCH: Cell<bool> = const { Cell::new(false) };
}

/// True on threads that must run nested dispatches inline (pool workers,
/// and any thread currently driving a dispatch of its own).
pub fn must_inline() -> bool {
    IN_POOL_WORKER.with(|c| c.get()) || IN_DISPATCH.with(|c| c.get())
}

/// First panic payload captured from a task (re-raised by the caller).
type PanicSlot = Mutex<Option<Box<dyn std::any::Any + Send>>>;

/// Lifetime-erased job descriptor; every pointer targets the dispatching
/// caller's stack frame, which outlives the job (see module Safety notes).
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    next: *const AtomicUsize,
    done: *const AtomicUsize,
    slots: *const AtomicUsize,
    panic: *const PanicSlot,
    tasks: usize,
    /// Identity for the debug overlap registry (see `exec::overlap`):
    /// claims made inside tasks are tagged with the dispatch they belong
    /// to and released when it retires.
    #[cfg(debug_assertions)]
    dispatch: u64,
    #[cfg(debug_assertions)]
    initiator: u64,
}

// SAFETY: the raw pointers are only dereferenced while the dispatching
// caller is blocked inside `dispatch` (it waits for `refs == 0` before
// returning), so the pointees are always live.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per installed job so sleeping workers can tell a new
    /// job from a spurious wakeup.
    epoch: u64,
    job: Option<Job>,
    /// Workers currently holding a copy of `job`.
    refs: usize,
    spawned: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between jobs.
    work_cv: Condvar,
    /// Dispatchers park here while their job drains (and while waiting
    /// for a previous dispatcher's job to clear).
    done_cv: Condvar,
}

/// Persistent parked worker pool; see the module docs.
pub struct ExecPool {
    shared: Arc<Shared>,
    cap: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ExecPool {
    /// Pool that will spawn at most `cap` worker threads (lazily, on the
    /// first dispatch that needs them).  `cap = 0` disables the pool:
    /// every dispatch runs inline on the caller.
    pub fn new(cap: usize) -> ExecPool {
        ExecPool {
            shared: Arc::new(Shared {
                state: Mutex::new(State {
                    epoch: 0,
                    job: None,
                    refs: 0,
                    spawned: 0,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
            }),
            cap,
            handles: Mutex::new(Vec::new()),
        }
    }

    /// Maximum worker threads this pool may spawn.
    pub fn max_workers(&self) -> usize {
        self.cap
    }

    /// Run `f(0)…f(tasks-1)`, the calling thread participating alongside
    /// the pool workers; returns when every task has finished.  Runs
    /// inline (sequentially) when the pool is disabled, the job is
    /// trivial, or the current thread is already inside a dispatch.
    pub fn broadcast(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        self.dispatch(tasks, tasks, f, None);
    }

    /// [`broadcast`](Self::broadcast) with at most `concurrency` threads
    /// (caller included) executing tasks at any moment — bounds peak
    /// memory when tasks own large scratch (e.g. parallel sweep cells).
    pub fn broadcast_limit(
        &self,
        tasks: usize,
        concurrency: usize,
        f: &(dyn Fn(usize) + Sync),
    ) {
        self.dispatch(tasks, concurrency, f, None);
    }

    /// Run every task on pool workers ONLY, while the calling thread runs
    /// `host()` — a serve loop for requests the tasks funnel back (see
    /// [`crate::exec::TrainService`]).  `host` must return once all tasks
    /// have signalled it (the pool then waits for the stragglers).
    ///
    /// Requires an enabled pool and a caller that is not itself a pool
    /// worker; the coordinator guards both before choosing this path.
    pub fn host_broadcast(
        &self,
        tasks: usize,
        f: &(dyn Fn(usize) + Sync),
        host: &mut dyn FnMut(),
    ) {
        self.dispatch(tasks, tasks, f, Some(host));
    }

    fn ensure_workers(&self, want: usize) {
        let want = want.min(self.cap);
        let mut st = self.shared.state.lock().unwrap();
        if st.spawned >= want {
            return;
        }
        let mut handles = self.handles.lock().unwrap();
        while st.spawned < want {
            let shared = Arc::clone(&self.shared);
            let h = std::thread::Builder::new()
                .name("mpota-exec".into())
                .spawn(move || worker_loop(shared))
                .expect("spawning exec pool worker");
            handles.push(h);
            st.spawned += 1;
        }
    }

    fn dispatch(
        &self,
        tasks: usize,
        concurrency: usize,
        f: &(dyn Fn(usize) + Sync),
        host: Option<&mut dyn FnMut()>,
    ) {
        if tasks == 0 {
            return;
        }
        let caller_runs = host.is_none();
        if caller_runs
            && (tasks == 1 || concurrency <= 1 || self.cap == 0 || must_inline())
        {
            // the overlap registry treats an outermost inline dispatch
            // exactly like a pooled one (fresh dispatch id, per-iteration
            // task identity): the disjointness contract is about the
            // ranges handed out, not the schedule they happen to run on
            #[cfg(debug_assertions)]
            let scope = crate::exec::overlap::InlineScope::begin();
            for i in 0..tasks {
                #[cfg(debug_assertions)]
                scope.enter_task(i);
                f(i);
            }
            return;
        }
        assert!(
            self.cap > 0 && !must_inline(),
            "host dispatch needs pool workers and a top-level caller"
        );

        // Concurrency slots available to WORKERS (the caller, when it
        // participates, is the extra executor on top of these).
        let worker_slots = concurrency
            .saturating_sub(usize::from(caller_runs))
            .min(tasks)
            .max(1);
        self.ensure_workers(worker_slots);

        let next = AtomicUsize::new(0);
        let done = AtomicUsize::new(0);
        let slots = AtomicUsize::new(worker_slots);
        let panic_slot: PanicSlot = Mutex::new(None);
        // releases this dispatch's overlap claims on drop — normal retire
        // AND the resume_unwind path below, so no stale claim survives a
        // panicked job
        #[cfg(debug_assertions)]
        let claims = crate::exec::overlap::DispatchClaims::begin();
        let job = Job {
            f: f as *const (dyn Fn(usize) + Sync),
            next: &next,
            done: &done,
            slots: &slots,
            panic: &panic_slot,
            tasks,
            #[cfg(debug_assertions)]
            dispatch: claims.id,
            #[cfg(debug_assertions)]
            initiator: claims.initiator,
        };

        {
            let mut st = self.shared.state.lock().unwrap();
            // serialize with dispatches from other threads
            while st.job.is_some() {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = Some(job);
            st.epoch = st.epoch.wrapping_add(1);
            drop(st);
            self.shared.work_cv.notify_all();
        }

        IN_DISPATCH.with(|c| c.set(true));
        struct DispatchGuard;
        impl Drop for DispatchGuard {
            fn drop(&mut self) {
                IN_DISPATCH.with(|c| c.set(false));
            }
        }
        let _guard = DispatchGuard;

        // The host runs under catch_unwind: letting a panic unwind this
        // frame while workers hold the Job (raw pointers into this stack)
        // would be a use-after-free, and st.job would never clear.  The
        // panic is re-raised only after the job is fully retired — hosts
        // must therefore make sure the worker tasks can still complete
        // when the host fails early (the TrainService host drains its
        // queue with errors before returning).
        let mut host_panic = None;
        if caller_runs {
            run_tasks(&job);
        } else if let Some(h) = host {
            host_panic = catch_unwind(AssertUnwindSafe(|| h())).err();
        }

        {
            let mut st = self.shared.state.lock().unwrap();
            while st.refs > 0 || done.load(Ordering::Acquire) < tasks {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
            drop(st);
            // wake any dispatcher queued behind this job
            self.shared.done_cv.notify_all();
        }

        let p = panic_slot.lock().unwrap().take();
        if let Some(p) = p {
            resume_unwind(p);
        }
        if let Some(p) = host_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        let handles = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Claim-and-run loop shared by workers and participating callers.
fn run_tasks(job: &Job) {
    // SAFETY: `dispatch` keeps every pointee alive until the job retires.
    let f = unsafe { &*job.f };
    let next = unsafe { &*job.next };
    let done = unsafe { &*job.done };
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= job.tasks {
            break;
        }
        let r = catch_unwind(AssertUnwindSafe(|| {
            // install the (dispatch, task) identity for the overlap
            // registry while the closure runs
            #[cfg(debug_assertions)]
            let _task = crate::exec::overlap::TaskScope::enter(
                job.dispatch,
                i as u32,
                job.initiator,
            );
            f(i)
        }));
        done.fetch_add(1, Ordering::Release);
        if let Err(p) = r {
            // SAFETY: `job.panic` targets the PanicSlot on the dispatching
            // caller's stack; the caller is parked in `dispatch` until
            // `done == tasks`, and this increment-to-done happens only
            // after the slot write completes under its mutex.
            let slot = unsafe { &*job.panic };
            let mut g = slot.lock().unwrap();
            if g.is_none() {
                *g = Some(p);
            }
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    IN_POOL_WORKER.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(job) = st.job {
                        // SAFETY: join only while the job has a concurrency
                        // slot; `job.slots` targets the dispatching caller's
                        // stack, and the claim happens under the state lock,
                        // so the caller cannot retire the job (and pop its
                        // frame) concurrently.
                        let claimed = unsafe { &*job.slots }
                            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |s| {
                                s.checked_sub(1)
                            })
                            .is_ok();
                        if claimed {
                            st.refs += 1;
                            break job;
                        }
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        run_tasks(&job);
        {
            let mut st = shared.state.lock().unwrap();
            st.refs -= 1;
        }
        shared.done_cv.notify_all();
    }
}

static GLOBAL_POOL: OnceLock<ExecPool> = OnceLock::new();

/// The process-wide pool every parallel kernel, client partition and
/// sweep cell dispatches on (created on first use; workers spawn lazily
/// as dispatches need them).
///
/// Sizing: `MPOTA_POOL_SIZE` when set (`0` disables the pool entirely —
/// every dispatch then runs inline, which is the bit-identical sequential
/// path); otherwise `max(available_parallelism - 1, 7)` so the
/// determinism contract's `threads = 4`-class test dispatches exercise
/// real cross-thread execution even on small CI boxes.
pub fn pool() -> &'static ExecPool {
    GLOBAL_POOL.get_or_init(|| ExecPool::new(default_cap()))
}

fn default_cap() -> usize {
    if let Ok(v) = std::env::var("MPOTA_POOL_SIZE") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n;
        }
    }
    crate::kernels::par::auto_threads().saturating_sub(1).max(7)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_runs_each_task_exactly_once() {
        let pool = ExecPool::new(3);
        for tasks in [1usize, 2, 5, 16, 33] {
            let counts: Vec<AtomicUsize> =
                (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            let f = |i: usize| {
                counts[i].fetch_add(1, Ordering::Relaxed);
            };
            pool.broadcast(tasks, &f);
            for (i, c) in counts.iter().enumerate() {
                assert_eq!(c.load(Ordering::Relaxed), 1, "task {i} of {tasks}");
            }
        }
    }

    #[test]
    fn disabled_pool_runs_inline() {
        let pool = ExecPool::new(0);
        let hits = AtomicUsize::new(0);
        let f = |_: usize| {
            assert!(!IN_POOL_WORKER.with(|c| c.get()));
            hits.fetch_add(1, Ordering::Relaxed);
        };
        pool.broadcast(6, &f);
        assert_eq!(hits.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn nested_dispatch_runs_inline_without_deadlock() {
        let pool = ExecPool::new(2);
        let total = AtomicUsize::new(0);
        let f = |_: usize| {
            let inner = |_: usize| {
                total.fetch_add(1, Ordering::Relaxed);
            };
            // whether this task landed on a worker or on the dispatching
            // caller, the nested dispatch must run inline
            pool.broadcast(4, &inner);
        };
        pool.broadcast(3, &f);
        assert_eq!(total.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn sequential_jobs_reuse_the_same_workers() {
        let pool = ExecPool::new(2);
        let sum = AtomicUsize::new(0);
        for round in 0..50 {
            let f = |i: usize| {
                sum.fetch_add(i + round, Ordering::Relaxed);
            };
            pool.broadcast(4, &f);
        }
        // Σ_round Σ_i (i + round) = 50·6 + 4·Σ(0..50)
        assert_eq!(sum.load(Ordering::Relaxed), 50 * 6 + 4 * 1225);
        assert!(pool.shared.state.lock().unwrap().spawned <= 2);
    }

    #[test]
    fn concurrency_limit_bounds_parallelism() {
        let pool = ExecPool::new(4);
        let active = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        let f = |_: usize| {
            let a = active.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(a, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(2));
            active.fetch_sub(1, Ordering::SeqCst);
        };
        pool.broadcast_limit(12, 2, &f);
        let p = peak.load(Ordering::SeqCst);
        assert!(p <= 2, "peak concurrency {p} exceeded the limit");
    }

    #[test]
    fn host_broadcast_runs_tasks_on_workers_only() {
        let pool = ExecPool::new(2);
        let sum = AtomicUsize::new(0);
        let f = |i: usize| {
            assert!(IN_POOL_WORKER.with(|c| c.get()), "task ran on the host");
            sum.fetch_add(i + 1, Ordering::Relaxed);
        };
        let mut host_ran = false;
        pool.host_broadcast(4, &f, &mut || {
            host_ran = true;
        });
        assert!(host_ran);
        assert_eq!(sum.load(Ordering::Relaxed), 1 + 2 + 3 + 4);
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn task_panics_propagate_to_the_caller() {
        let pool = ExecPool::new(2);
        let f = |i: usize| {
            if i == 3 {
                panic!("boom");
            }
        };
        pool.broadcast(8, &f);
    }

    /// The debug overlap registry (`exec::overlap`): claims made through
    /// `SendPtr` must be pairwise disjoint across tasks, released at
    /// dispatch retire, and quiescent at boundaries.
    #[cfg(debug_assertions)]
    mod overlap_registry {
        use super::*;

        #[test]
        fn claims_release_at_dispatch_retire_and_quiesce() {
            let pool = ExecPool::new(2);
            let mut buf = vec![0f32; 64];
            let p = crate::exec::SendPtr::from_mut(&mut buf[..]);
            let f = |i: usize| {
                // SAFETY: tasks 0 and 1 reborrow disjoint halves of `buf`,
                // which outlives the blocking dispatch.
                let s = unsafe { p.slice_at(i * 32, 32) };
                s[0] += 1.0;
            };
            pool.broadcast(2, &f);
            crate::exec::assert_quiescent();
            // the SAME ranges are claimable again by the next dispatch —
            // the previous dispatch's claims were released at retire
            pool.broadcast(2, &f);
            crate::exec::assert_quiescent();
            assert_eq!(buf[0], 2.0);
            assert_eq!(buf[32], 2.0);
        }

        #[test]
        fn same_task_reborrows_are_not_conflicts() {
            let pool = ExecPool::new(2);
            let mut buf = vec![0f32; 64];
            let p = crate::exec::SendPtr::from_mut(&mut buf[..]);
            let f = |i: usize| {
                // SAFETY: each task stays inside its own half, and the
                // second (overlapping) reborrow happens after the first
                // reference is dead — the nested-kernel pattern the
                // same-task rule exists for.
                unsafe { p.slice_at(i * 32, 32) }[0] += 1.0;
                unsafe { p.slice_at(i * 32 + 4, 8) }[0] += 1.0;
            };
            pool.broadcast(2, &f);
            crate::exec::assert_quiescent();
            assert_eq!(buf[0], 1.0);
            assert_eq!(buf[4], 1.0);
        }

        #[test]
        #[should_panic(expected = "overlapping")]
        fn deliberate_overlap_is_caught_on_a_pooled_dispatch() {
            let pool = ExecPool::new(2);
            let mut buf = vec![0f32; 64];
            let p = crate::exec::SendPtr::from_mut(&mut buf[..]);
            let f = |_i: usize| {
                // SAFETY: deliberately NOT upheld — both tasks claim rows
                // [0, 32).  The registry panics on the second claim BEFORE
                // the aliasing &mut is created, so the losing task never
                // touches the buffer.
                let s = unsafe { p.slice_at(0, 32) };
                s[0] += 1.0;
            };
            pool.broadcast(2, &f);
        }

        #[test]
        #[should_panic(expected = "overlapping")]
        fn deliberate_overlap_is_caught_on_the_inline_path_too() {
            // cap = 0: the dispatch runs inline, yet the handed-out ranges
            // must still be pairwise disjoint — the contract is about the
            // ranges handed out, not the schedule they happen to run on
            let pool = ExecPool::new(0);
            let mut buf = vec![0f32; 64];
            let p = crate::exec::SendPtr::from_mut(&mut buf[..]);
            let f = |_i: usize| {
                // SAFETY: deliberately NOT upheld, as above.
                let s = unsafe { p.slice_at(8, 16) };
                s[0] += 1.0;
            };
            pool.broadcast(2, &f);
        }
    }

    #[test]
    fn pool_survives_a_panicked_job() {
        let pool = ExecPool::new(2);
        let f = |_: usize| panic!("transient");
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| pool.broadcast(4, &f)));
        assert!(r.is_err());
        // the pool must still dispatch correctly afterwards
        let hits = AtomicUsize::new(0);
        let g = |_: usize| {
            hits.fetch_add(1, Ordering::Relaxed);
        };
        pool.broadcast(5, &g);
        assert_eq!(hits.load(Ordering::Relaxed), 5);
    }
}
