//! [`TrainService`] — the cross-thread funnel that lets client-partitioned
//! training run on pool workers while every PJRT dispatch stays on the
//! thread that owns the (single-threaded, `Rc`-based) runtime.
//!
//! Shape: the coordinator arms the service for `tasks` worker tasks,
//! dispatches them with [`crate::exec::ExecPool::host_broadcast`], and —
//! as the host — sits in [`TrainService::serve`] executing queued
//! train-step requests against the runtime.  Each worker task drives its
//! clients' round loop through a [`GatewayStep`]: a train step enqueues a
//! request (raw views of the worker's buffers plus a stack reply slot)
//! and blocks until the host writes the result back.  When a task
//! finishes its clients it [`detach`](TrainService::detach)es; once every
//! task has detached and the queue is drained, `serve` returns and the
//! pool retires the job.
//!
//! Concurrency win: the non-PJRT majority of client work (re-quantizing
//! the broadcast model, batch gathers, payload diffs, per-client RNG) runs
//! concurrently across workers; only the PJRT executions serialize, as
//! they must.  Requests carry pointers, not copies — the payload buffers
//! never cross the channel.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use anyhow::Result;

use crate::quant::Precision;
use crate::runtime::TrainOutput;

use super::train::TrainStep;

/// Borrowed view of one train-step request, as handed to the serve
/// closure on the runtime-owning thread.
pub struct TrainCall<'a> {
    pub precision: Precision,
    pub lr: f32,
    pub theta: &'a [f32],
    pub images: &'a [f32],
    pub labels: &'a [i32],
}

/// Raw (ptr, len) view of a caller-owned slice.
struct RawSlice<T> {
    ptr: *const T,
    len: usize,
}

impl<T> RawSlice<T> {
    fn of(s: &[T]) -> Self {
        RawSlice { ptr: s.as_ptr(), len: s.len() }
    }

    /// # Safety
    /// The source slice must outlive every use (the submitting worker
    /// blocks on its reply, keeping its buffers alive).
    unsafe fn as_slice<'a>(&self) -> &'a [T] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

struct TrainReq {
    precision: Precision,
    lr: f32,
    theta: RawSlice<f32>,
    images: RawSlice<f32>,
    labels: RawSlice<i32>,
    reply: *const ReplySlot,
}

// SAFETY: every pointer targets the submitting worker's stack/buffers,
// which stay alive because the worker blocks on `reply` until the host
// has consumed the request and written the result.
unsafe impl Send for TrainReq {}

#[derive(Default)]
struct ReplySlot {
    m: Mutex<Option<Result<TrainOutput>>>,
    cv: Condvar,
}

struct ServiceState {
    queue: VecDeque<TrainReq>,
    /// Worker tasks still attached to the current dispatch.
    attached: usize,
}

/// The request funnel; one lives in the coordinator and is re-armed per
/// round (buffers recycle — the queue never exceeds the worker count).
pub struct TrainService {
    state: Mutex<ServiceState>,
    cv: Condvar,
}

impl Default for TrainService {
    fn default() -> Self {
        TrainService::new()
    }
}

impl TrainService {
    pub fn new() -> Self {
        TrainService {
            state: Mutex::new(ServiceState { queue: VecDeque::new(), attached: 0 }),
            cv: Condvar::new(),
        }
    }

    /// Arm the service for a dispatch of `tasks` worker tasks; each task
    /// must call [`detach`](Self::detach) exactly once when done.
    pub fn reset(&self, tasks: usize) {
        let mut st = self.state.lock().unwrap();
        st.attached = tasks;
        st.queue.clear();
    }

    /// A worker task has finished its training work.
    pub fn detach(&self) {
        {
            let mut st = self.state.lock().unwrap();
            st.attached = st.attached.saturating_sub(1);
        }
        self.cv.notify_all();
    }

    fn submit(&self, req: TrainReq) {
        {
            let mut st = self.state.lock().unwrap();
            st.queue.push_back(req);
        }
        self.cv.notify_all();
    }

    /// Execute queued requests with `exec` (on the thread that owns the
    /// single-threaded runtime) until every attached task has detached
    /// and the queue is drained.
    pub fn serve(&self, mut exec: impl FnMut(TrainCall<'_>) -> Result<TrainOutput>) {
        loop {
            let req = {
                let mut st = self.state.lock().unwrap();
                loop {
                    if let Some(r) = st.queue.pop_front() {
                        break Some(r);
                    }
                    if st.attached == 0 {
                        break None;
                    }
                    st = self.cv.wait(st).unwrap();
                }
            };
            let Some(req) = req else { return };
            // SAFETY: the submitting worker blocks on `reply` until we
            // store the result, so the raw slice views and the reply slot
            // all target live stack/buffer memory (see TrainReq).
            let (call, reply) = unsafe {
                (
                    TrainCall {
                        precision: req.precision,
                        lr: req.lr,
                        theta: req.theta.as_slice(),
                        images: req.images.as_slice(),
                        labels: req.labels.as_slice(),
                    },
                    &*req.reply,
                )
            };
            let out = exec(call);
            {
                let mut g = reply.m.lock().unwrap();
                *g = Some(out);
            }
            reply.cv.notify_one();
        }
    }
}

/// Worker-side [`TrainStep`] that funnels every call through the service.
pub struct GatewayStep<'a> {
    svc: &'a TrainService,
}

impl<'a> GatewayStep<'a> {
    pub fn new(svc: &'a TrainService) -> Self {
        GatewayStep { svc }
    }
}

impl TrainStep for GatewayStep<'_> {
    fn train_step(
        &self,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        let reply = ReplySlot::default();
        self.svc.submit(TrainReq {
            precision,
            lr,
            theta: RawSlice::of(theta),
            images: RawSlice::of(images),
            labels: RawSlice::of(labels),
            reply: &reply,
        });
        let mut g = reply.m.lock().unwrap();
        loop {
            if let Some(out) = g.take() {
                return out;
            }
            g = reply.cv.wait(g).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn service_funnels_worker_calls_to_a_single_threaded_executor() {
        let svc = TrainService::new();
        svc.reset(3);
        // deliberately !Sync executor state: the whole point of the funnel
        let served = std::cell::Cell::new(0u32);
        // worker tasks run on an ExecPool via host_broadcast — the exact
        // dispatch shape the coordinator uses in production (the PR-4
        // version spawned ad-hoc std::thread::scope threads here, which
        // bypassed the pool this service is designed around)
        let pool = crate::exec::ExecPool::new(3);
        let task = |w: usize| {
            let step = GatewayStep::new(&svc);
            for i in 0..5u32 {
                let theta = vec![w as f32, i as f32];
                let out = step
                    .train_step(Precision::of(8), &theta, &[1.0], &[2], 0.1)
                    .unwrap();
                assert_eq!(out.new_theta, vec![w as f32 + 1.0, i as f32 + 1.0]);
                assert_eq!(out.loss, 0.5);
                assert_eq!(out.correct, 1.0);
            }
            svc.detach();
        };
        pool.host_broadcast(3, &task, &mut || {
            svc.serve(|call| {
                served.set(served.get() + 1);
                assert_eq!(call.images, &[1.0]);
                assert_eq!(call.labels, &[2]);
                Ok(TrainOutput {
                    new_theta: call.theta.iter().map(|v| v + 1.0).collect(),
                    loss: 0.5,
                    correct: call.labels.len() as f32,
                })
            });
        });
        assert_eq!(served.get(), 15);
    }

    #[test]
    fn errors_flow_back_to_the_submitting_worker() {
        let svc = TrainService::new();
        svc.reset(1);
        let pool = crate::exec::ExecPool::new(1);
        let task = |_w: usize| {
            let step = GatewayStep::new(&svc);
            let err = step
                .train_step(Precision::of(4), &[0.0], &[0.0], &[0], 0.1)
                .unwrap_err();
            assert!(err.to_string().contains("no device"), "{err}");
            svc.detach();
        };
        pool.host_broadcast(1, &task, &mut || {
            svc.serve(|_| anyhow::bail!("no device"));
        });
    }

    #[test]
    fn serve_returns_immediately_when_nothing_attached() {
        let svc = TrainService::new();
        svc.reset(0);
        let mut calls = 0;
        svc.serve(|_| {
            calls += 1;
            anyhow::bail!("unreachable")
        });
        assert_eq!(calls, 0);
    }
}
