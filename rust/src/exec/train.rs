//! The training seams the client round loop executes against.
//!
//! [`TrainStep`] is the per-call interface [`crate::coordinator::client`]
//! uses for one SGD minibatch step; [`TrainBackend`] is the injectable
//! whole-backend seam (train + evaluate + warmup) for runs that do not go
//! through PJRT — it is `Send + Sync`, so client-partitioned training
//! calls it from pool workers directly.  The PJRT runtime itself is
//! single-threaded (`Rc`-based client); it participates either as
//! [`RuntimeStep`] on the coordinator thread (`workers = 1`) or behind
//! the [`crate::exec::TrainService`] funnel (`workers > 1`).

use anyhow::Result;

use crate::quant::Precision;
use crate::runtime::{EvalResult, Runtime, TrainOutput};

/// Scalar step statistics returned by the allocation-free
/// [`TrainStep::train_step_into`] entry point.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub correct: f32,
}

/// One SGD minibatch step at a given precision — the client state
/// machine's only dependency on the execution backend.
pub trait TrainStep {
    fn train_step(
        &self,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<TrainOutput>;

    /// Allocation-free variant: write the updated model into
    /// `new_theta_out` instead of returning a fresh `Vec`.  The default
    /// delegates to [`TrainStep::train_step`] (the PJRT path keeps its
    /// historical allocation behaviour bit-for-bit); pure-rust backends
    /// override it to run the steady-state round loop without heap
    /// traffic.
    fn train_step_into(
        &self,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        new_theta_out: &mut [f32],
    ) -> Result<StepMetrics> {
        let out = self.train_step(precision, theta, images, labels, lr)?;
        new_theta_out.copy_from_slice(&out.new_theta);
        Ok(StepMetrics { loss: out.loss, correct: out.correct })
    }
}

/// A full training/evaluation backend that can replace PJRT for a run
/// (injected through `sim::ExperimentBuilder::backend`).  Must be `Sync`:
/// with `RunConfig.workers > 1` the client partition calls `train_step`
/// concurrently from pool workers.
pub trait TrainBackend: Send + Sync {
    fn train_step(
        &self,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<TrainOutput>;

    /// Allocation-free step (see [`TrainStep::train_step_into`]).  The
    /// default preserves the allocating behaviour; deterministic mock
    /// backends override it for the zero-alloc round contract.
    fn train_step_into(
        &self,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        new_theta_out: &mut [f32],
    ) -> Result<StepMetrics> {
        let out = TrainBackend::train_step(self, precision, theta, images, labels, lr)?;
        new_theta_out.copy_from_slice(&out.new_theta);
        Ok(StepMetrics { loss: out.loss, correct: out.correct })
    }

    /// Evaluate a flat model over a labelled set.
    fn evaluate(&self, theta: &[f32], images: &[f32], labels: &[i32])
        -> Result<EvalResult>;

    /// Pre-run warmup for the levels a policy may assign (PJRT compiles
    /// artifacts here; pure-rust backends usually need nothing).
    fn warmup(&self, levels: &[Precision]) -> Result<()> {
        let _ = levels;
        Ok(())
    }
}

/// An injected backend object is usable wherever a [`TrainStep`] is
/// expected (the coordinator hands `&dyn TrainBackend` to the client
/// round loop directly — on the coordinator thread or on pool workers).
/// A concrete impl on the trait object (rather than a blanket impl) keeps
/// coherence with the other `TrainStep` implementors.
impl TrainStep for dyn TrainBackend {
    fn train_step(
        &self,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        TrainBackend::train_step(self, precision, theta, images, labels, lr)
    }

    fn train_step_into(
        &self,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
        new_theta_out: &mut [f32],
    ) -> Result<StepMetrics> {
        TrainBackend::train_step_into(
            self, precision, theta, images, labels, lr, new_theta_out,
        )
    }
}

/// Direct PJRT dispatch on the thread that owns the runtime — the
/// `workers = 1` path, byte-for-byte the historical call.
pub struct RuntimeStep<'a> {
    pub runtime: &'a Runtime,
    pub variant: &'a str,
}

impl TrainStep for RuntimeStep<'_> {
    fn train_step(
        &self,
        precision: Precision,
        theta: &[f32],
        images: &[f32],
        labels: &[i32],
        lr: f32,
    ) -> Result<TrainOutput> {
        self.runtime
            .train_step(self.variant, precision, theta, images, labels, lr)
    }
}
