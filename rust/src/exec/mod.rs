//! The execution runtime: one persistent worker pool behind every layer
//! of parallelism in the system.
//!
//! Three layers dispatch onto the same [`ExecPool`]:
//!
//! * **intra-kernel** ([`crate::kernels::par`], [`crate::kernels::fused`],
//!   [`crate::rng::Rng::add_normal2`]) — the element axis of one vector
//!   op, gated by `RunConfig.threads`;
//! * **inter-client** ([`crate::coordinator::Coordinator`]) — local
//!   training + quantize/modulate partitioned across clients, gated by
//!   `RunConfig.workers`, with the PJRT dispatch funnelled back to the
//!   runtime-owning thread through [`TrainService`] (the PJRT client is
//!   `Rc`-based and must not migrate threads);
//! * **inter-cell** ([`crate::sim::sweep`]) — independent sweep cells,
//!   bounded by `RunConfig.workers`.
//!
//! Nested dispatches run inline automatically (a client task's kernels do
//! not re-enter the pool), so the layers compose without deadlock and the
//! chunk-grid determinism contract holds end to end: results are
//! bit-identical per seed for every `{threads, workers}` combination.
//!
//! [`TrainStep`] / [`TrainBackend`] are the training seams the client
//! round loop runs against: the PJRT [`Runtime`](crate::runtime::Runtime)
//! (directly on the coordinator thread, or through the [`TrainService`]
//! funnel when clients train on pool workers), or an injected pure-rust
//! backend (tests, alternative trainers) that is `Sync` and therefore
//! runs on the workers directly.

#[cfg(debug_assertions)]
pub(crate) mod overlap;
pub mod pool;
pub mod service;
pub mod train;

pub use pool::{must_inline, pool, ExecPool};
pub use service::{GatewayStep, TrainCall, TrainService};
pub use train::{RuntimeStep, StepMetrics, TrainBackend, TrainStep};

/// Debug-build assertion that every mutable range handed out through
/// [`SendPtr`]/[`SendMutPtr`]/[`DisjointMut`] by a dispatch THIS thread
/// initiated has been released — called by the round/sweep engines at
/// shard and round boundaries.  Compiles to nothing in release builds.
#[inline]
pub(crate) fn assert_quiescent() {
    #[cfg(debug_assertions)]
    overlap::assert_quiescent();
}

/// Lifetime-erased base pointer for handing DISJOINT regions of one
/// buffer to pool tasks (each task reconstructs its own chunk slice, so a
/// single `Fn`-shared closure can write a partitioned buffer).
pub(crate) struct SendPtr<T>(*mut T);

// manual impls: a derive would add spurious `T: Clone/Copy` bounds (the
// pointer itself is always Copy, e.g. for `SendPtr<Option<anyhow::Error>>`)
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

// SAFETY: SendPtr is only used to hand non-overlapping regions of one
// live buffer to pool tasks; callers uphold disjointness (documented at
// every `slice_at`/`at` call site).
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub(crate) fn from_mut(s: &mut [T]) -> Self {
        SendPtr(s.as_mut_ptr())
    }

    /// Reborrow `[off, off + len)` of the underlying buffer.
    ///
    /// # Safety
    /// The range must be in bounds of the original buffer, the buffer must
    /// outlive the returned slice, and no two live borrows may overlap.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn slice_at<'a>(self, off: usize, len: usize) -> &'a mut [T] {
        #[cfg(debug_assertions)]
        {
            // registered BEFORE the reference exists: an overlap aborts
            // instead of materialising the aliasing &mut
            let lo = self.0 as usize + off * std::mem::size_of::<T>();
            overlap::claim(lo, lo + len * std::mem::size_of::<T>());
        }
        std::slice::from_raw_parts_mut(self.0.add(off), len)
    }

    /// Reborrow element `i` of the underlying buffer.
    ///
    /// # Safety
    /// Same aliasing/lifetime rules as [`slice_at`](Self::slice_at).
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn at<'a>(self, i: usize) -> &'a mut T {
        #[cfg(debug_assertions)]
        {
            let lo = self.0 as usize + i * std::mem::size_of::<T>();
            overlap::claim(lo, lo + std::mem::size_of::<T>());
        }
        &mut *self.0.add(i)
    }
}

/// Lifetime-erased exclusive pointer to ONE value, for handing a `&mut T`
/// to exactly one task of a pool dispatch (the pipelined round engine's
/// superposition task is the round's sole `Session` toucher while the
/// other tasks train the next super-shard).
///
/// Unlike [`SendPtr`] this wrapper is deliberately NOT `Clone`/`Copy` and
/// carries no region arithmetic: it represents the whole value, moved
/// into one closure.
pub(crate) struct SendMutPtr<T>(*mut T);

// SAFETY: constructed from a live `&mut T` and dereferenced by exactly
// one pool task per dispatch (callers uphold single-toucher use; the
// coordinator gates the pipelined path to the built-in Send-safe session
// parts).  The borrow the pointer was made from outlives the blocking
// dispatch.
unsafe impl<T> Send for SendMutPtr<T> {}
unsafe impl<T> Sync for SendMutPtr<T> {}

impl<T> SendMutPtr<T> {
    pub(crate) fn from_mut(v: &mut T) -> Self {
        SendMutPtr(v as *mut T)
    }

    /// Reborrow the underlying value.
    ///
    /// # Safety
    /// At most one live reborrow at a time, only while the original
    /// borrow is still in scope (i.e. inside the blocking dispatch the
    /// pointer was created for).
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn get<'a>(&self) -> &'a mut T {
        #[cfg(debug_assertions)]
        {
            let lo = self.0 as usize;
            overlap::claim(lo, lo + std::mem::size_of::<T>());
        }
        &mut *self.0
    }
}

/// Shared handle over one `&mut [T]` that hands out `&mut` elements at
/// pairwise-DISTINCT indices to concurrent pool tasks (the client
/// partition indexes clients through the round's `selected` list, whose
/// entries are distinct by construction).
pub(crate) struct DisjointMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

// SAFETY: see `get` — callers never alias an index.
unsafe impl<T: Send> Send for DisjointMut<'_, T> {}
unsafe impl<T: Send> Sync for DisjointMut<'_, T> {}

impl<'a, T> DisjointMut<'a, T> {
    pub(crate) fn new(s: &'a mut [T]) -> Self {
        DisjointMut {
            ptr: s.as_mut_ptr(),
            len: s.len(),
            _marker: std::marker::PhantomData,
        }
    }

    /// Mutable access to element `i`.
    ///
    /// # Safety
    /// No two concurrently-live references may target the same index.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn get(&self, i: usize) -> &mut T {
        debug_assert!(i < self.len, "index {i} out of bounds ({})", self.len);
        #[cfg(debug_assertions)]
        {
            let lo = self.ptr as usize + i * std::mem::size_of::<T>();
            overlap::claim(lo, lo + std::mem::size_of::<T>());
        }
        &mut *self.ptr.add(i)
    }
}
