//! Minimal command-line argument parser (no `clap` in the vendored set).
//!
//! Grammar: `mpota <subcommand> [--key value | --flag] ...`
//! Unknown keys are rejected up-front so typos fail fast instead of
//! silently running a default experiment.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line: a subcommand plus `--key value` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Keys the caller actually read — for strict unknown-option checking.
    allowed: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let subcommand = it.next().unwrap_or_default();
        if subcommand.starts_with('-') {
            bail!("expected a subcommand before options, got '{subcommand}'");
        }
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                bail!("expected '--option', got '{arg}'");
            };
            if key.is_empty() {
                bail!("empty option name");
            }
            // --key=value or --key value or bare flag
            if let Some((k, v)) = key.split_once('=') {
                opts.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                opts.insert(key.to_string(), it.next().unwrap());
            } else {
                flags.push(key.to_string());
            }
        }
        Ok(Args { subcommand, opts, flags, allowed: Vec::new() })
    }

    /// From the process environment.
    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&mut self, key: &str) -> Option<&str> {
        self.allowed.push(key.to_string());
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&mut self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(&mut self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(default),
            Some(raw) => raw
                .parse::<T>()
                .map_err(|e| anyhow::anyhow!("--{key} '{raw}': {e}")),
        }
    }

    pub fn flag(&mut self, key: &str) -> bool {
        self.allowed.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Call after reading all options: errors on anything unrecognised.
    pub fn finish(&self) -> Result<()> {
        for k in self.opts.keys() {
            if !self.allowed.contains(k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !self.allowed.contains(f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let mut a = args(&["train", "--rounds", "10", "--scheme=16,8,4", "--verbose"]);
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.get("rounds"), Some("10"));
        assert_eq!(a.get("scheme"), Some("16,8,4"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn typed_parse_with_default() {
        let mut a = args(&["train", "--lr", "0.05"]);
        assert_eq!(a.get_parse("lr", 0.01f64).unwrap(), 0.05);
        assert_eq!(a.get_parse("rounds", 7usize).unwrap(), 7);
        assert!(a.get_parse("lr", 0i32).is_err()); // 0.05 not an i32
    }

    #[test]
    fn unknown_options_rejected() {
        let mut a = args(&["train", "--bogus", "1"]);
        let _ = a.get("rounds");
        assert!(a.finish().is_err());
    }

    #[test]
    fn malformed_inputs_rejected() {
        assert!(Args::parse(["--rounds".to_string()]).is_err());
        assert!(Args::parse(["train".to_string(), "positional".to_string()]).is_err());
        assert!(Args::parse(["train".to_string(), "--".to_string()]).is_err());
    }

    #[test]
    fn empty_argv_gives_empty_subcommand() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.subcommand, "");
    }
}
