//! End-of-run report: everything Figs. 3/4 and the tables need.

use crate::json::Value;
use crate::metrics::RunLog;
use crate::quant::Precision;

/// Post-run evaluation of the final global model re-quantized to one
/// precision level (paper Fig. 2c / Fig. 4: "client performance after
/// aggregation and re-quantization").
#[derive(Clone, Copy, Debug)]
pub struct RequantEval {
    pub precision: Precision,
    pub accuracy: f64,
    pub loss: f64,
}

/// Energy summary across the run.
#[derive(Clone, Debug, Default)]
pub struct EnergyReport {
    /// Actual joules spent by the mixed-precision client fleet.
    pub actual_joules: f64,
    /// Counterfactual joules had all clients run at 32-bit (same MACs).
    pub all32_joules: f64,
    /// Counterfactual at 16-bit.
    pub all16_joules: f64,
    /// Counterfactual at 8-bit.
    pub all8_joules: f64,
    /// Counterfactual at 4-bit.
    pub all4_joules: f64,
}

impl EnergyReport {
    pub fn saving_vs_32(&self) -> f64 {
        (1.0 - self.actual_joules / self.all32_joules) * 100.0
    }
    pub fn saving_vs_16(&self) -> f64 {
        (1.0 - self.actual_joules / self.all16_joules) * 100.0
    }
}

/// Full run outcome.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub label: String,
    pub log: RunLog,
    pub final_accuracy: f64,
    pub final_loss: f64,
    /// Final model re-quantized + evaluated at each scheme level.
    pub requant: Vec<RequantEval>,
    pub energy: EnergyReport,
    /// Rounds to reach 90% test accuracy (convergence speed).
    pub rounds_to_90: Option<usize>,
    /// Total wall-clock seconds.
    pub wall_secs: f64,
}

impl RunReport {
    /// The report label sanitized for use as a file stem (the label's
    /// separator characters `,` `@` `/` become `_`).
    pub fn file_label(&self) -> String {
        self.label.replace([',', '@', '/'], "_")
    }

    /// Accuracy of the final model at the scheme's lowest precision
    /// (the paper's headline client-side metric).
    pub fn lowest_precision_accuracy(&self) -> Option<f64> {
        self.requant
            .iter()
            .min_by_key(|r| r.precision.bits())
            .map(|r| r.accuracy)
    }

    pub fn to_json(&self) -> Value {
        let mut o = Value::object();
        o.set("label", Value::Str(self.label.clone()));
        o.set("final_accuracy", Value::Num(self.final_accuracy));
        o.set("final_loss", Value::Num(self.final_loss));
        o.set(
            "rounds_to_90",
            match self.rounds_to_90 {
                Some(r) => Value::Num(r as f64),
                None => Value::Null,
            },
        );
        let mut rq = Vec::new();
        for r in &self.requant {
            let mut e = Value::object();
            e.set("bits", Value::Num(r.precision.bits() as f64));
            e.set("accuracy", Value::Num(r.accuracy));
            e.set("loss", Value::Num(r.loss));
            rq.push(e);
        }
        o.set("requant", Value::Array(rq));
        let mut en = Value::object();
        en.set("actual_j", Value::Num(self.energy.actual_joules));
        en.set("all32_j", Value::Num(self.energy.all32_joules));
        en.set("all16_j", Value::Num(self.energy.all16_joules));
        en.set("all8_j", Value::Num(self.energy.all8_joules));
        en.set("all4_j", Value::Num(self.energy.all4_joules));
        en.set("saving_vs_32_pct", Value::Num(self.energy.saving_vs_32()));
        en.set("saving_vs_16_pct", Value::Num(self.energy.saving_vs_16()));
        o.set("energy", en);
        o.set("wall_secs", Value::Num(self.wall_secs));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_savings() {
        let e = EnergyReport {
            actual_joules: 30.0,
            all32_joules: 100.0,
            all16_joules: 50.0,
            all8_joules: 10.0,
            all4_joules: 2.0,
        };
        assert!((e.saving_vs_32() - 70.0).abs() < 1e-9);
        assert!((e.saving_vs_16() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn lowest_precision_pick() {
        let report = RunReport {
            label: "t".into(),
            log: RunLog::new("t"),
            final_accuracy: 0.9,
            final_loss: 0.3,
            requant: vec![
                RequantEval { precision: Precision::of(16), accuracy: 0.9, loss: 0.3 },
                RequantEval { precision: Precision::of(4), accuracy: 0.7, loss: 0.9 },
            ],
            energy: EnergyReport::default(),
            rounds_to_90: Some(12),
            wall_secs: 1.0,
        };
        assert_eq!(report.lowest_precision_accuracy(), Some(0.7));
        let j = report.to_json();
        assert_eq!(j.get("rounds_to_90").unwrap().as_f64().unwrap(), 12.0);
        assert_eq!(j.get("requant").unwrap().as_array().unwrap().len(), 2);
    }
}
