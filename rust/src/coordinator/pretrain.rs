//! Centralized f32 pre-training — the stand-in for the paper's
//! "ImageNet pre-trained weights initialization" (DESIGN.md §2).
//!
//! Trains a variant centrally (no FL, no channel) on a held-out synthetic
//! corpus and writes the resulting flat params next to the artifacts, so
//! federated runs can start from a sane feature extractor exactly like the
//! paper's runs start from ImageNet weights.  Also used by the Table-I
//! bench to produce the f32 models that are then post-training-quantized.

use std::path::Path;

use anyhow::Result;

use crate::data::{BatchIter, Dataset, SAMPLE_LEN};
use crate::quant::Precision;
use crate::rng::Rng;
use crate::runtime::Runtime;

/// Pre-training configuration.
#[derive(Clone, Debug)]
pub struct PretrainConfig {
    pub variant: String,
    pub samples: usize,
    pub epochs: usize,
    pub lr: f32,
    pub seed: u64,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig {
            variant: "base".into(),
            samples: 4096,
            epochs: 6,
            lr: 0.08,
            seed: 7,
        }
    }
}

/// Progress record per epoch.
#[derive(Clone, Copy, Debug)]
pub struct EpochStats {
    pub epoch: usize,
    pub mean_loss: f64,
    pub mean_acc: f64,
}

/// Run central SGD at f32; returns (params, per-epoch stats).
pub fn pretrain(
    runtime: &Runtime,
    cfg: &PretrainConfig,
) -> Result<(Vec<f32>, Vec<EpochStats>)> {
    // mpota-lint: allow(R4): pretraining is its own entry point with its own root seed
    let root = Rng::seed_from(cfg.seed);
    // A separate corpus from FL runs (stream "pretrain" vs "data"): the
    // pretrained features must not have seen the federated test set.
    let mut data_rng = root.stream("pretrain");
    let data = Dataset::generate(cfg.samples, &mut data_rng);

    let mut theta = runtime.init_params(&cfg.variant)?;
    let batch = runtime.manifest.train_batch;
    let mut it_rng = root.stream("batches");
    let mut batches = BatchIter::new(data.n, batch, &mut it_rng);
    let mut img_buf = vec![0.0f32; batch * SAMPLE_LEN];
    let mut label_buf = vec![0i32; batch];

    let mut stats = Vec::new();
    for epoch in 1..=cfg.epochs {
        let mut loss = 0.0f64;
        let mut acc = 0.0f64;
        let mut steps = 0usize;
        // simple 1/sqrt(epoch) decay keeps late epochs stable
        let lr = cfg.lr / (epoch as f32).sqrt();
        batches.reset(&mut it_rng);
        while let Some(idx) = batches.next_batch() {
            let idx = idx.to_vec();
            data.gather(&idx, &mut img_buf, &mut label_buf);
            let out = runtime.train_step(
                &cfg.variant,
                Precision::of(32),
                &theta,
                &img_buf,
                &label_buf,
                lr,
            )?;
            theta = out.new_theta;
            loss += out.loss as f64;
            acc += out.correct as f64 / batch as f64;
            steps += 1;
        }
        stats.push(EpochStats {
            epoch,
            mean_loss: loss / steps.max(1) as f64,
            mean_acc: acc / steps.max(1) as f64,
        });
    }
    Ok((theta, stats))
}

/// Standard location of a variant's pretrained blob.
pub fn pretrained_path(artifacts_dir: &Path, variant: &str) -> std::path::PathBuf {
    artifacts_dir.join(format!("{variant}_pretrained.f32.bin"))
}

/// Pretrain-if-missing: returns the blob path, training + writing it if it
/// does not exist yet (used by examples/benches so they are self-contained).
pub fn ensure_pretrained(
    runtime: &Runtime,
    cfg: &PretrainConfig,
) -> Result<std::path::PathBuf> {
    let path = pretrained_path(&runtime.manifest.dir, &cfg.variant);
    if !path.exists() {
        let (theta, stats) = pretrain(runtime, cfg)?;
        if let Some(last) = stats.last() {
            eprintln!(
                "[pretrain {}] epoch {} loss {:.3} acc {:.3}",
                cfg.variant, last.epoch, last.mean_loss, last.mean_acc
            );
        }
        crate::tensor::write_f32_file(&path, &theta)?;
    }
    Ok(path)
}
