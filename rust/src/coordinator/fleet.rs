//! Lazy, identity-keyed client fleet.
//!
//! The pre-fix coordinator materialized every [`ClientState`] eagerly at
//! build time — a `Vec` of N clients, each owning its shard indices and
//! training buffers.  At paper scale (N = 15) that is free; at massive
//! fleets (N = 1M, K = 64 selected per round) it is O(N) memory and
//! build latency for clients that are never selected.
//!
//! [`ClientFleet`] replaces the eager `Vec` with a bounded
//! [`IdLru`]`<ClientState>` keyed by CLIENT IDENTITY and capped at 2·K
//! (a round can never evict its own participants — see the LRU's
//! capacity protocol), so coordinator-side client memory is O(K), not
//! O(fleet).  Two invariants make the lazy fleet bit-identical to the
//! eager one wherever the eager one could run:
//!
//! * **Shard assignment is positional, not sequential.**  The fleet
//!   performs the exact `equal_shards` shuffle once at build time
//!   (consuming the same `"shard"` RNG stream draw-for-draw) and keeps
//!   the shuffled sample order; client `id`'s shard is the slice
//!   `order[id·per .. (id+1)·per]`, recovered at materialization time —
//!   identical indices regardless of WHEN (or whether) the client
//!   materializes.
//! * **Client RNG is a pure function of identity.**  [`ClientState::new`]
//!   derives `root.stream("client").substream(id)` — stream derivation
//!   consumes nothing — so a client first selected in round 900 starts
//!   the same batch sequence it would have started in round 1.
//!
//! Eviction (a client unselected long enough to fall off the 2·K window)
//! folds its cumulative energy/MACs into fleet-level scalars before the
//! state drops, so end-of-run energy accounting stays exact; a re-selected
//! client rematerializes with fresh training state (batch iterator
//! restarts), which is the documented trade of the bounded window and
//! only arises under random selection at K ≪ N — where no eager-fleet
//! baseline exists to diverge from.

use crate::data::PartitionRecipe;
use crate::fl::IdLru;
use crate::quant::Precision;
use crate::rng::Rng;

use super::client::ClientState;

/// Bounded, identity-keyed collection of materialized clients plus the
/// recipe (sample order + root RNG) to materialize any of the N fleet
/// members on demand.
pub struct ClientFleet {
    /// Materialized clients, keyed by client id, capacity 2·K.
    lru: IdLru<ClientState>,
    /// The shuffled (iid) or Dirichlet-assigned sample order over the
    /// training corpus; client `id` owns `order[id·per .. (id+1)·per]`
    /// positionally, or `order[offsets[id] .. offsets[id+1]]` when a
    /// non-uniform recipe supplies CSR `offsets`.
    order: Vec<usize>,
    /// CSR row offsets for unequal shards (empty for the positional
    /// `equal_shards` path — kept empty there so the iid fleet stays
    /// byte-identical to the historical constructor).
    offsets: Vec<usize>,
    /// Samples per client (`train_n / clients`), positional path only.
    per: usize,
    train_batch: usize,
    /// The run's root RNG — clients derive their private streams from it
    /// by id (derivation consumes nothing).
    root: Rng,
    /// Energy folded in from evicted clients (exact total accounting).
    evicted_energy_j: f64,
    /// MACs folded in from evicted clients (counterfactual reports).
    evicted_macs: f64,
}

impl ClientFleet {
    /// Build the fleet recipe: performs the `equal_shards` shuffle on
    /// `shard_rng` (identical RNG consumption to the eager constructor)
    /// but materializes NO clients.
    pub fn new(
        train_n: usize,
        clients: usize,
        train_batch: usize,
        root: Rng,
        shard_rng: &mut Rng,
    ) -> Self {
        let per = train_n / clients;
        let mut order: Vec<usize> = (0..train_n).collect();
        shard_rng.shuffle(&mut order);
        ClientFleet {
            lru: IdLru::new(),
            order,
            offsets: Vec::new(),
            per,
            train_batch,
            root,
            evicted_energy_j: 0.0,
            evicted_macs: 0.0,
        }
    }

    /// Build the fleet from a precomputed non-uniform [`PartitionRecipe`]
    /// (Dirichlet label partition, possibly size-skewed): client `id`'s
    /// shard is the CSR row `order[offsets[id] .. offsets[id+1]]` — like
    /// the positional path, identical indices regardless of WHEN the
    /// client materializes.
    pub fn with_recipe(recipe: PartitionRecipe, train_batch: usize, root: Rng) -> Self {
        let PartitionRecipe { order, offsets } = recipe;
        ClientFleet {
            lru: IdLru::new(),
            order,
            offsets,
            per: 0,
            train_batch,
            root,
            evicted_energy_j: 0.0,
            evicted_macs: 0.0,
        }
    }

    /// Grow the LRU window to hold a round of `kk` participants without
    /// evicting any of them (capacity 2·kk, monotone — see
    /// [`IdLru::reserve`]).
    pub fn reserve_round(&mut self, kk: usize) {
        self.lru.reserve(2 * kk.max(1));
    }

    /// Materialize (or touch) client `id` at this round's `precision`;
    /// returns its LRU slot, stable for the whole round (the capacity
    /// protocol guarantees no same-round eviction).  A first-time or
    /// re-entering client is built from the positional shard recipe; a
    /// resident one just gets its precision updated.  An eviction folds
    /// the departing client's energy/MACs into the fleet scalars.
    pub fn materialize(&mut self, id: usize, precision: Precision) -> u32 {
        let ClientFleet {
            lru,
            order,
            offsets,
            per,
            train_batch,
            root,
            evicted_energy_j,
            evicted_macs,
        } = self;
        let (slot, fresh, evicted) = lru.get_or_insert_with(id, || {
            let shard = if offsets.is_empty() {
                order[id * *per..(id + 1) * *per].to_vec()
            } else {
                order[offsets[id]..offsets[id + 1]].to_vec()
            };
            ClientState::new(id, precision, shard, *train_batch, root)
        });
        if let Some(old) = evicted {
            *evicted_energy_j += old.energy_joules;
            *evicted_macs += old.macs_spent;
        }
        if !fresh {
            lru.value_mut(slot).precision = precision;
        }
        slot
    }

    /// Materialized-client count (≤ 2·K, never O(fleet)).
    pub fn resident(&self) -> usize {
        self.lru.len()
    }

    /// The materialized client at LRU `slot` (from [`materialize`]).
    ///
    /// [`materialize`]: Self::materialize
    pub fn value(&self, slot: u32) -> &ClientState {
        self.lru.value(slot)
    }

    /// Mutable access by LRU slot.
    pub fn value_mut(&mut self, slot: u32) -> &mut ClientState {
        self.lru.value_mut(slot)
    }

    /// The materialized client with identity `id`, if resident.
    pub fn get(&self, id: usize) -> Option<&ClientState> {
        self.lru.get(id)
    }

    /// All materialized clients as one slice (LRU slot order) — the
    /// client phase builds its [`crate::exec::DisjointMut`] view over
    /// this; round slots index into it via the materialized slot slab.
    pub fn values_mut(&mut self) -> &mut [ClientState] {
        self.lru.values_mut()
    }

    /// Cumulative fleet energy: residents (summed in ascending-id order,
    /// matching the eager fleet's id-order sum when nothing has evicted)
    /// plus the energy folded in from evicted clients.
    pub fn actual_energy_joules(&self) -> f64 {
        let mut total = self.evicted_energy_j;
        for &(_, slot) in self.lru.entries() {
            total += self.lru.value(slot).energy_joules;
        }
        total
    }

    /// Per-client MACs for the counterfactual energy report: residents in
    /// ascending-id order, plus (when any client evicted) one pooled
    /// entry for the departed — counterfactual joules are linear in MACs,
    /// so pooling preserves the totals.
    pub fn macs_spent(&self) -> Vec<f64> {
        let mut macs: Vec<f64> = self
            .lru
            .entries()
            .iter()
            .map(|&(_, slot)| self.lru.value(slot).macs_spent)
            .collect();
        if self.evicted_macs > 0.0 {
            macs.push(self.evicted_macs);
        }
        macs
    }
}
