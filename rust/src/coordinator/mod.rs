//! The L3 coordinator: the paper's Algorithm 1 as a round-driven state
//! machine over the substrate modules.
//!
//! Per communication round t (Alg. 1):
//!   1. broadcast θ^(t-1) to the selected clients;
//!   2. each client re-quantizes to its precision q_k and trains locally
//!      (PJRT execution of the `train_q{b}` artifact — [`client`]);
//!   3. clients amplitude-modulate their decimal-valued models and the
//!      channel superposes them (`ota::analog` with `channel` simulation),
//!      or the digital / ideal baselines take over per config;
//!   4. the server scales by 1/K and the result becomes θ^(t).
//!
//! Scheduling note: the PJRT client is `Rc`-based (not `Send`) and this
//! testbed has one core, so client work is interleaved on the coordinator
//! thread; the per-client state machines in [`client`] keep the design
//! ready for a multi-queue runtime.

pub mod client;
pub mod pretrain;
pub mod report;

pub use client::ClientState;
pub use report::{EnergyReport, RequantEval, RunReport};

use std::time::Instant;

use anyhow::{Context, Result};

use crate::channel::{pilot, RoundChannel, C32};
use crate::config::{Aggregation, RunConfig};
use crate::data::{equal_shards, Dataset};
use crate::energy;
use crate::fl::{self, Selection};
use crate::kernels::PayloadPlane;
use crate::metrics::{RoundRecord, RunLog};
use crate::ota;
use crate::quant::{self, Precision};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor;

/// Round scratch arena: every server-side buffer a round needs, allocated
/// once and reused, so steady-state [`Coordinator::round`] performs no
/// heap allocation outside the PJRT training dispatch
/// (`rust/tests/alloc_counter.rs` pins this on the aggregation path).
#[derive(Default)]
struct RoundScratch {
    /// Participant indices for the round.
    selected: Vec<usize>,
    /// K×N decimal payload rows (the superposition input).
    plane: PayloadPlane,
    /// Per-participant precision levels (digital baseline).
    precisions: Vec<Precision>,
    /// Channel realisation (clients vec reused).
    round_channel: RoundChannel,
    /// Broadcast pilot sequence (depends only on cfg.pilot_len).
    pilot: Vec<C32>,
    /// Analog-aggregation accumulators + active-gain list.
    ota: ota::analog::OtaScratch,
    /// Digital/ideal aggregation output.
    agg: Vec<f32>,
}

/// Which scratch buffer holds the round's aggregate.
enum AggSlot {
    OtaReal,
    Agg,
}

/// Orchestrates one full federated run.
pub struct Coordinator {
    pub cfg: RunConfig,
    pub runtime: Runtime,
    clients: Vec<ClientState>,
    train_data: Dataset,
    test_data: Dataset,
    /// Global model (flat decimal values).
    theta: Vec<f32>,
    selection: Selection,
    select_rng: Rng,
    channel_rng: Rng,
    noise_rng: Rng,
    log: RunLog,
    macs_per_sample: u64,
    layout: crate::tensor::ParamLayout,
    scratch: RoundScratch,
}

impl Coordinator {
    /// Build everything: runtime, data, shards, clients, initial model.
    pub fn new(cfg: RunConfig) -> Result<Self> {
        cfg.validate()?;
        let runtime = Runtime::load(&cfg.artifacts_dir)?;
        let variant = runtime.manifest.variant(&cfg.variant)?.clone();

        let root = Rng::seed_from(cfg.seed);
        let mut data_rng = root.stream("data");
        let train_data = Dataset::generate(cfg.train_samples, &mut data_rng);
        let test_data = Dataset::generate(cfg.test_samples, &mut data_rng);

        let mut shard_rng = root.stream("shard");
        let shards = equal_shards(train_data.n, cfg.clients, &mut shard_rng);
        let precisions = cfg.scheme.client_precisions(cfg.clients)?;
        let clients: Vec<ClientState> = shards
            .into_iter()
            .zip(precisions.iter())
            .map(|(s, &p)| {
                ClientState::new(s.client, p, s.indices, runtime.manifest.train_batch, &root)
            })
            .collect();

        let theta = match &cfg.init_params {
            Some(path) => {
                let p = tensor::read_f32_file(path)?;
                anyhow::ensure!(
                    p.len() == variant.param_count,
                    "init params {} != param_count {}",
                    p.len(),
                    variant.param_count
                );
                p
            }
            None => runtime.init_params(&cfg.variant)?,
        };

        let selection = if cfg.clients_per_round == cfg.clients {
            Selection::All
        } else {
            Selection::UniformK(cfg.clients_per_round)
        };

        let label = format!("{}@{}", cfg.scheme, cfg.aggregation);
        let scratch = RoundScratch {
            pilot: pilot::pilot_sequence(cfg.channel.pilot_len),
            ..Default::default()
        };
        Ok(Coordinator {
            select_rng: root.stream("select"),
            channel_rng: root.stream("channel"),
            noise_rng: root.stream("noise"),
            log: RunLog::new(label),
            macs_per_sample: variant.macs_per_sample,
            layout: variant.layout.clone(),
            cfg,
            runtime,
            clients,
            train_data,
            test_data,
            theta,
            selection,
            scratch,
        })
    }

    /// Current global model (flat).
    pub fn global_model(&self) -> &[f32] {
        &self.theta
    }

    /// Replace the global model (e.g. with pretrained weights).
    pub fn set_global_model(&mut self, theta: Vec<f32>) -> Result<()> {
        anyhow::ensure!(theta.len() == self.theta.len(), "model size mismatch");
        self.theta = theta;
        Ok(())
    }

    /// Execute one communication round; returns its record.
    ///
    /// Steady-state contract: every server-side buffer comes from the
    /// reused [`RoundScratch`] arena — after the first round this method
    /// performs no heap allocation outside the PJRT training dispatch.
    /// With `cfg.threads == 1` it reproduces the historical sequential
    /// path bit-for-bit; any other thread count yields identical results
    /// (kernels-layer determinism contract).
    pub fn round(&mut self, t: usize) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let threads = self.cfg.threads;
        self.selection.select_into(
            self.cfg.clients,
            t,
            &mut self.select_rng,
            &mut self.scratch.selected,
        );
        let kk = self.scratch.selected.len();

        // Steps 1-2: broadcast + local training per selected client, each
        // payload fused-quantized straight into its payload-plane row.
        self.scratch.plane.reset(kk, self.theta.len());
        self.scratch.precisions.clear();
        let mut train_loss = 0.0f64;
        let mut train_acc = 0.0f64;
        let transmit_weights =
            matches!(self.cfg.transmit, crate::config::Transmit::Weights);
        for slot in 0..kk {
            let k = self.scratch.selected[slot];
            let c = &mut self.clients[k];
            let stats = c.local_round_into(
                &self.runtime,
                &self.cfg.variant,
                &self.train_data,
                &self.theta,
                self.cfg.lr,
                self.cfg.local_steps,
                self.macs_per_sample,
                transmit_weights,
                &self.layout,
                threads,
                self.scratch.plane.row_mut(slot),
            )?;
            self.scratch.precisions.push(c.precision);
            train_loss += stats.mean_loss;
            train_acc += stats.mean_acc;
        }
        train_loss /= kk as f64;
        train_acc /= kk as f64;

        // Steps 3-4: aggregation over the payload plane.
        let scratch = &mut self.scratch;
        let (slot, participants, ota_mse) = match self.cfg.aggregation {
            Aggregation::OtaAnalog => {
                scratch.round_channel.draw_into(
                    &self.cfg.channel,
                    kk,
                    &mut self.channel_rng,
                    &scratch.pilot,
                );
                let stats = ota::analog::aggregate_plane_into(
                    &scratch.plane,
                    &scratch.round_channel,
                    &mut self.noise_rng,
                    &mut scratch.ota,
                    threads,
                );
                (AggSlot::OtaReal, stats.participants, stats.mse_vs_ideal)
            }
            Aggregation::Digital => {
                let stats = ota::digital::aggregate_plane_into(
                    &scratch.plane,
                    &scratch.precisions,
                    &mut scratch.agg,
                    threads,
                );
                (AggSlot::Agg, stats.participants, 0.0)
            }
            Aggregation::Ideal => {
                fl::mean_plane_into(&scratch.plane, &mut scratch.agg, threads);
                (AggSlot::Agg, kk, 0.0)
            }
        };
        if participants > 0 {
            let agg: &[f32] = match slot {
                AggSlot::OtaReal => &self.scratch.ota.y_re,
                AggSlot::Agg => &self.scratch.agg,
            };
            match self.cfg.transmit {
                // θ^(t) = θ^(t-1) + mean(Δ_k)   (Alg. 1 steps 10/14)
                crate::config::Transmit::Updates => {
                    tensor::axpy_par(&mut self.theta, 1.0, agg, threads)
                }
                // θ^(t) = mean(θ_k)             (Alg. 1 step 18, ablation)
                crate::config::Transmit::Weights => self.theta.copy_from_slice(agg),
            }
        } // else: round lost to deep fades; keep θ^(t-1)

        // Evaluation + energy accounting.
        let mut rec = RoundRecord {
            round: t,
            train_loss,
            train_accuracy: train_acc,
            participants,
            ota_mse,
            energy_joules: self.actual_energy_joules(),
            wall_secs: 0.0,
            ..Default::default()
        };
        if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
            let eval = self.runtime.evaluate(
                &self.cfg.variant,
                &self.theta,
                &self.test_data.images,
                &self.test_data.labels,
            )?;
            rec.server_accuracy = eval.accuracy;
            rec.server_loss = eval.loss;
        } else if let Some(prev) = self.log.rounds.last() {
            rec.server_accuracy = prev.server_accuracy;
            rec.server_loss = prev.server_loss;
        }
        rec.wall_secs = t0.elapsed().as_secs_f64();
        Ok(rec)
    }

    /// Run all configured rounds and produce the final report.
    pub fn run(&mut self) -> Result<RunReport> {
        let t0 = Instant::now();
        self.runtime
            .warmup(&self.cfg.variant, &self.cfg.scheme.distinct_levels())
            .context("artifact warmup")?;
        for t in 1..=self.cfg.rounds {
            let rec = self.round(t)?;
            self.log.push(rec);
        }
        self.report(t0.elapsed().as_secs_f64())
    }

    /// Post-run report: requantized client evals + energy summary.
    pub fn report(&mut self, wall_secs: f64) -> Result<RunReport> {
        let mut requant = Vec::new();
        for p in self.cfg.scheme.distinct_levels() {
            let q = self.requantize_global(p);
            let eval = self.runtime.evaluate(
                &self.cfg.variant,
                &q,
                &self.test_data.images,
                &self.test_data.labels,
            )?;
            requant.push(RequantEval {
                precision: p,
                accuracy: eval.accuracy,
                loss: eval.loss,
            });
        }
        let final_eval = self.runtime.evaluate(
            &self.cfg.variant,
            &self.theta,
            &self.test_data.images,
            &self.test_data.labels,
        )?;
        Ok(RunReport {
            label: self.log.label.clone(),
            final_accuracy: final_eval.accuracy,
            final_loss: final_eval.loss,
            requant,
            energy: self.energy_report(),
            rounds_to_90: self.log.rounds_to_accuracy(0.90),
            wall_secs,
            log: self.log.clone(),
        })
    }

    /// Cumulative fleet energy so far (the per-round record field) —
    /// allocation-free, unlike the full counterfactual report.
    pub fn actual_energy_joules(&self) -> f64 {
        self.clients
            .iter()
            .map(|c| energy::mean_energy_joules(c.precision, c.macs_spent))
            .sum()
    }

    /// Energy actuals + homogeneous counterfactuals over the same MACs.
    pub fn energy_report(&self) -> EnergyReport {
        let macs: Vec<f64> = self.clients.iter().map(|c| c.macs_spent).collect();
        EnergyReport {
            actual_joules: self.actual_energy_joules(),
            all32_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(32)),
            all16_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(16)),
            all8_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(8)),
            all4_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(4)),
        }
    }

    /// Access the accumulated run log.
    pub fn log(&self) -> &RunLog {
        &self.log
    }

    /// Per-layer re-quantization of the current global model to precision
    /// `p` (Fig. 2c — the deployment view of a precision-p client).
    pub fn requantize_global(&self, p: Precision) -> Vec<f32> {
        quant::fake_quant_layout(&self.theta, &self.layout, p, quant::Rounding::Nearest)
    }

    /// Evaluate an arbitrary flat model on the held-out test set.
    pub fn evaluate_model(&self, theta: &[f32]) -> Result<crate::runtime::EvalResult> {
        self.runtime.evaluate(
            &self.cfg.variant,
            theta,
            &self.test_data.images,
            &self.test_data.labels,
        )
    }
}
