//! The L3 coordinator: the paper's Algorithm 1 as a round-driven state
//! machine over the substrate modules.
//!
//! Per communication round t (Alg. 1):
//!   1. the coordinator selects the round's K participants, the precision
//!      policy assigns each SELECTED client's level (O(K) — never a
//!      fleet-sized vector), and θ^(t-1) is broadcast to them;
//!   2. each client re-quantizes to its precision q_k and trains locally
//!      (PJRT execution of the `train_q{b}` artifact — [`client`]);
//!   3. the [`crate::sim::Session`] draws the round's channel through the
//!      pluggable [`crate::sim::ChannelModel`] and aggregates the payload
//!      plane through the pluggable [`crate::sim::Aggregator`] (analog
//!      OTA, digital orthogonal, or ideal FedAvg by default);
//!   4. the server applies the aggregate and the result becomes θ^(t).
//!
//! The pluggable parts arrive via [`crate::sim::SimParts`] (usually built
//! through [`crate::sim::Experiment`]); `Coordinator::new` wires the
//! config-selected defaults, which reproduce the pre-redesign enum
//! dispatch bit-for-bit per seed (`rust/tests/sim.rs`).
//!
//! Scheduling: the round streams its K selected clients through
//! fixed-size SHARDS (`RunConfig::shard_size`; `0` = one whole-round
//! shard, the historical path): each shard fills a small reusable
//! [`PayloadPlane`] and is immediately fused-superposed into the
//! session's persistent air accumulator before the next shard reuses the
//! buffers — round memory is O(shard_size·N + K) instead of O(K·N), and
//! the trajectory is bit-identical per seed for EVERY `{shard_size,
//! threads, workers}` combination (`rust/tests/shard_invariance.rs`).
//! With `RunConfig::workers > 1` each shard's client phase (step 2 —
//! re-quantize, local SGD orchestration, payload diff into the plane
//! row) is partitioned across the persistent [`crate::exec`] pool, each
//! worker owning a contiguous slot range and its disjoint plane rows.
//! The PJRT client is `Rc`-based (not `Send`), so its dispatches funnel
//! back to the coordinator thread through [`crate::exec::TrainService`];
//! an injected `Sync` [`crate::exec::TrainBackend`] runs on the workers
//! directly.  Per-client RNG/state makes the trajectory bit-identical at
//! every worker count (`rust/tests/sim.rs`).
//!
//! **Pipelining** (`RunConfig::pipeline_depth > 0`): the streamed round
//! overlaps the CLIENT phase of the next super-shard (`pipeline_depth ·
//! shard_len` slots) with the SUPERPOSITION of the previous one.  Each
//! step is ONE pool dispatch of `workers + 1` tasks: task 0 — the
//! dispatch's sole [`sim::Session`] toucher — accumulates the previous
//! super-shard out of one payload plane while tasks `1..=workers` train
//! the current super-shard into the other (double-buffered) plane.  The
//! accumulator remains the only synchronisation point, shards still
//! arrive in ascending slot order, and nested kernels run inline on the
//! superposing worker — so the trajectory stays bit-identical for every
//! `pipeline_depth` (`rust/tests/shard_invariance.rs`); `0` is the
//! serial PR-5 path.
//!
//! **Stragglers & dropouts**: when a [`sim::DeadlinePolicy`] is active
//! (injected, or derived from the `deadline_s`/`dropout_p` config keys),
//! the coordinator decides each round's exclusions up front — serially,
//! in slot order, from the dedicated `"straggler"` RNG stream — BEFORE
//! any training runs.  Excluded clients skip local training entirely (no
//! energy accrued, default stats) and their plane rows are never read:
//! the masked aggregation kernels skip them and the effective divisor
//! follows the clients that actually transmit (ideal/digital divide by
//! `active_k`; analog OTA's `active_total` self-adjusts).  With no
//! policy the stream is never consumed and the round is byte-identical
//! to the deadline-free engine.
//!
//! **Fleet scaling**: the coordinator holds NO fleet-sized client state.
//! Selection runs FIRST; the round's K selected identities are assigned
//! precisions through [`sim::PrecisionPolicy::assign_selected_into`]
//! (O(K)) and materialized on demand in the identity-keyed bounded
//! [`fleet::ClientFleet`] window (capacity 2·K — a round never evicts
//! its own participants), so a 1M-client run's coordinator memory stays
//! O(K + shard·N).  After aggregation the round's per-participant
//! measurements (|h|, this-round energy, local loss) are fed back to the
//! policy as a [`sim::RoundFeedback`] keyed by client identity — the
//! [`sim::ProfilingPlanner`] builds its per-client precision plan from
//! exactly this stream.

pub mod client;
pub mod fleet;
pub mod pretrain;
pub mod report;

pub use client::ClientState;
pub use fleet::ClientFleet;
pub use report::{EnergyReport, RequantEval, RunReport};

use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{PartitionKind, RunConfig};
use crate::data::Dataset;
use crate::energy;
use crate::exec;
use crate::fl::Selection;
use crate::kernels::{par, PackedPlane, PayloadPlane};
use crate::metrics::{RoundRecord, RunLog};
use crate::quant::{self, Precision};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sim;
use crate::tensor;

use client::LocalStats;

/// Round scratch arena for the coordinator-side buffers (participant
/// list, payload plane, per-round precision assignments), allocated once
/// and reused so steady-state [`Coordinator::round`] performs no heap
/// allocation outside the PJRT training dispatch
/// (`rust/tests/alloc_counter.rs` pins this on the aggregation path).
/// The aggregation-side buffers live in the [`sim::Session`]'s
/// [`sim::AggScratch`]; both recycle across runs through [`sim::Arena`].
#[derive(Default)]
pub struct RoundScratch {
    /// Participant indices for the round.
    pub(crate) selected: Vec<usize>,
    /// shard×N decimal payload rows (the superposition input).  With
    /// `RunConfig::shard_size == 0` this is the whole round's K×N plane;
    /// otherwise it holds one shard at a time and is recycled shard to
    /// shard — the O(shard·N) round-memory contract.
    pub(crate) plane: PayloadPlane,
    /// Second payload plane for the pipelined round engine: while one
    /// plane's super-shard superposes (task 0 of the combined dispatch),
    /// the next super-shard trains into this one.  Unused (never grown)
    /// when `pipeline_depth == 0`.
    pub(crate) plane2: PayloadPlane,
    /// Bit-packed transport staging buffer (`RunConfig::packed_planes`):
    /// each trained shard's included rows are packed here at their
    /// assigned precision immediately before accumulation, so the
    /// aggregators fold codes instead of f32 rows.  ONE buffer suffices
    /// even in the pipelined engine — staging and superposition both
    /// happen inside the dispatch's single session-touching task.  Never
    /// grown when packed transport is off.
    pub(crate) packed: PackedPlane,
    /// Round-slot participation mask (aligned with `precisions`): `true`
    /// = the client makes the deadline and transmits.  All-true when no
    /// deadline/dropout policy is active; excluded slots skip training
    /// and their (stale) plane rows are never read.
    pub(crate) included: Vec<bool>,
    /// Per-participant precision levels (aligned with ROUND slots, all K
    /// of them — shards index it at `lo..hi`).
    pub(crate) precisions: Vec<Precision>,
    /// LRU slots of the round's materialized participants (aligned with
    /// `selected`): the client phase reaches its [`ClientState`]s through
    /// this slab, never by fleet index.
    pub(crate) slab: Vec<u32>,
    /// Per-participant cumulative energy BEFORE the round — the feedback
    /// baseline (this round's spend = after − before).
    pub(crate) fb_energy0: Vec<f64>,
    /// Per-participant channel amplitude |h| (policy feedback).
    pub(crate) gains: Vec<f32>,
    /// Per-participant this-round energy in joules (policy feedback).
    pub(crate) fb_energy: Vec<f64>,
    /// Per-participant local training loss (policy feedback).
    pub(crate) fb_loss: Vec<f64>,
    /// Per-slot client training stats (parallel workers write disjoint
    /// entries; the coordinator sums them in slot order afterwards, so
    /// the reduction is bit-identical at every worker count).
    pub(crate) stats: Vec<LocalStats>,
    /// Per-worker first-error slots for the partitioned client phase.
    pub(crate) errors: Vec<Option<anyhow::Error>>,
}

/// Read-only context shared by every client-phase pool task.
struct ClientPhaseEnv<'a> {
    workers: usize,
    kk: usize,
    n: usize,
    /// Shard-local fleet-LRU slots (the round slab at `lo..hi`): entry
    /// `r` is where slot `lo + r`'s materialized client lives.
    slots: &'a [u32],
    data: &'a Dataset,
    theta: &'a [f32],
    lr: f32,
    local_steps: usize,
    macs_per_sample: u64,
    transmit_weights: bool,
    layout: &'a crate::tensor::ParamLayout,
    threads: usize,
    /// Shard-local participation mask; `false` slots never train.
    included: &'a [bool],
}

/// One worker's share of the client phase: slots
/// `[chunk_start(kk, workers, w), +chunk_len)` — contiguous, so the plane
/// rows and stats entries it writes are disjoint from every other
/// worker's; client LRU slots come from the round slab (`env.slots`),
/// whose entries are pairwise distinct (the round's identities are
/// pairwise distinct, the id-keyed LRU maps distinct resident ids to
/// distinct slots, and the 2·K capacity protocol rules out mid-round
/// eviction).
fn run_client_slots<S: exec::TrainStep + ?Sized>(
    env: &ClientPhaseEnv<'_>,
    clients: &exec::DisjointMut<'_, ClientState>,
    plane: exec::SendPtr<f32>,
    stats: exec::SendPtr<LocalStats>,
    errors: exec::SendPtr<Option<anyhow::Error>>,
    w: usize,
    step: &S,
) {
    let lo = par::chunk_start(env.kk, env.workers, w);
    let hi = lo + par::chunk_len(env.kk, env.workers, w);
    for slot in lo..hi {
        if !env.included[slot] {
            continue; // excluded by the deadline/dropout policy: no
                      // training, no energy, stats stay default
        }
        let s = env.slots[slot] as usize;
        // SAFETY: slab entries are pairwise distinct (distinct round
        // identities map to distinct LRU slots; the 2·K capacity protocol
        // rules out mid-round eviction) and each slot belongs to exactly
        // one worker range, so no client, plane row or stats entry is
        // aliased; the buffers outlive the blocking pool dispatch.
        let c = unsafe { clients.get(s) };
        let row = unsafe { plane.slice_at(slot * env.n, env.n) };
        let res = c.local_round_into(
            step,
            env.data,
            env.theta,
            env.lr,
            env.local_steps,
            env.macs_per_sample,
            env.transmit_weights,
            env.layout,
            env.threads,
            row,
        );
        match res {
            // SAFETY: `slot` belongs to exactly one worker's range, so
            // `stats[slot]` is unaliased; `stats` outlives the dispatch.
            Ok(s) => unsafe { *stats.at(slot) = s },
            Err(e) => {
                // first error wins for this worker; stop its share so a
                // broken backend fails fast instead of spinning.
                // SAFETY: `errors[w]` is this worker's private slot (one
                // entry per worker index) and outlives the dispatch.
                unsafe { *errors.at(w) = Some(e) };
                return;
            }
        }
    }
}

/// Transmission staging, f32 form: fake-quantize each included row of a
/// trained shard to its assigned precision in place — what the client
/// radio actually puts on the air.  Excluded rows hold stale data and are
/// never read downstream, so they are skipped here too.
fn stage_quant_shard(
    plane: &mut PayloadPlane,
    precisions: &[Precision],
    included: Option<&[bool]>,
) {
    debug_assert_eq!(plane.k(), precisions.len());
    for r in 0..plane.k() {
        if included.map_or(false, |m| !m[r]) {
            continue;
        }
        quant::fake_quant_inplace(plane.row_mut(r), precisions[r]);
    }
}

/// Transmission staging, packed form: pack each included row's RAW values
/// into the bit-packed plane at its assigned precision.  The stored codes
/// decode to exactly `fake_quant(row)` bit-for-bit, so the two staging
/// forms feed the aggregators identical per-element contributions —
/// `packed_planes` on/off is a pure storage choice
/// (`rust/tests/shard_invariance.rs` pins the trajectories against each
/// other).
fn stage_pack_shard(
    packed: &mut PackedPlane,
    plane: &PayloadPlane,
    precisions: &[Precision],
    included: Option<&[bool]>,
) {
    debug_assert_eq!(plane.k(), precisions.len());
    packed.reset(precisions, plane.n());
    for r in 0..plane.k() {
        if included.map_or(false, |m| !m[r]) {
            continue; // stale words: the masked kernels never decode them
        }
        packed.pack_row(r, plane.row(r));
    }
}

/// Orchestrates one full federated run.
pub struct Coordinator {
    pub cfg: RunConfig,
    pub runtime: Rc<Runtime>,
    /// Identity-keyed lazy client window: O(K) materialized clients, the
    /// rest of the fleet exists only as the shard/RNG recipe.
    fleet: ClientFleet,
    train_data: Dataset,
    test_data: Dataset,
    /// Global model (flat decimal values).
    theta: Vec<f32>,
    selection: Selection,
    select_rng: Rng,
    log: RunLog,
    macs_per_sample: u64,
    layout: crate::tensor::ParamLayout,
    scratch: RoundScratch,
    session: sim::Session,
    policy: Box<dyn sim::PrecisionPolicy>,
    /// Straggler/dropout policy; `None` = every selected client makes
    /// the deadline (the byte-identical deadline-free engine).
    deadline: Option<Box<dyn sim::DeadlinePolicy>>,
    /// Dedicated RNG stream for the deadline policy — derived for every
    /// run (stream derivation consumes nothing from the root) but
    /// consumed ONLY when a policy is active.
    straggler_rng: Rng,
    /// True when the aggregator is the config-selected built-in (not an
    /// injected trait object): the pipelined engine's superposition task
    /// runs on a pool worker and is gated to the built-ins, whose session
    /// state is known Send-safe.
    streaming_builtin: bool,
    /// Injected training/eval backend; `None` = the PJRT runtime.
    backend: Option<Box<dyn exec::TrainBackend>>,
    /// PJRT request funnel for the `workers > 1` client phase.
    train_svc: exec::TrainService,
}

impl Coordinator {
    /// Build everything with the config-selected default parts: runtime,
    /// data, the lazy client fleet, initial model, static-scheme policy,
    /// the configured channel model and aggregator.
    pub fn new(cfg: RunConfig) -> Result<Self> {
        Coordinator::from_parts(cfg, sim::SimParts::default())
    }

    /// Build with injected parts; `None` fields fall back to the
    /// config-selected defaults.  This is the [`sim::Experiment`]
    /// builder's entry point.
    pub fn from_parts(cfg: RunConfig, parts: sim::SimParts) -> Result<Self> {
        cfg.validate()?;
        let runtime = match parts.runtime {
            Some(rt) => rt,
            None => Rc::new(Runtime::load(&cfg.artifacts_dir)?),
        };
        let variant = runtime.manifest.variant(&cfg.variant)?.clone();

        // mpota-lint: allow(R4): the run's single root RNG — every other stream derives from it
        let root = Rng::seed_from(cfg.seed);
        let mut data_rng = root.stream("data");
        let train_data = Dataset::generate(cfg.train_samples, &mut data_rng);
        let test_data = Dataset::generate(cfg.test_samples, &mut data_rng);

        let mut policy = parts
            .policy
            .unwrap_or_else(|| sim::policy::from_config(cfg.policy, &cfg));

        let sim::Arena { round: mut scratch, agg, channel } =
            parts.arena.unwrap_or_default();

        // Construction-time policy validation: an empty-selection round-1
        // assignment surfaces config errors (e.g. scheme divisibility)
        // before any round runs, without materializing a fleet-sized
        // vector.  Policies observe the same round-1/prev-None call the
        // eager constructor made, so feedback-policy state is unchanged.
        policy.assign_selected_into(
            &sim::PolicyCtx {
                round: 1,
                clients: cfg.clients,
                snr_db: cfg.channel.snr_db,
                prev: None,
            },
            &[],
            &mut scratch.precisions,
        )?;

        // The fleet recipe performs the partition on the "shard" stream
        // (iid: the exact `equal_shards` shuffle, draw-for-draw identical
        // to the historical constructor; dirichlet: the per-class
        // size-biased recipe) but materializes no clients — they are
        // built on first selection, keyed by identity.
        let mut shard_rng = root.stream("shard");
        let fleet = match cfg.partition {
            PartitionKind::Iid => ClientFleet::new(
                train_data.n,
                cfg.clients,
                runtime.manifest.train_batch,
                root.clone(),
                &mut shard_rng,
            ),
            PartitionKind::Dirichlet => {
                let recipe = crate::data::dirichlet_recipe(
                    &train_data.labels,
                    cfg.clients,
                    cfg.alpha,
                    cfg.skew_zipf,
                    runtime.manifest.train_batch,
                    &mut shard_rng,
                )?;
                ClientFleet::with_recipe(recipe, runtime.manifest.train_batch, root.clone())
            }
        };

        let theta = match &cfg.init_params {
            Some(path) => {
                let p = tensor::read_f32_file(path)?;
                anyhow::ensure!(
                    p.len() == variant.param_count,
                    "init params {} != param_count {}",
                    p.len(),
                    variant.param_count
                );
                p
            }
            None => runtime.init_params(&cfg.variant)?,
        };

        // `Auto` reproduces the historical mapping (everyone at K == N,
        // else uniform Fisher-Yates); `Sampled` is the O(K) massive-fleet
        // selector (Floyd's algorithm).
        let selection =
            Selection::from_config(cfg.selection, cfg.clients, cfg.clients_per_round);

        let streaming_builtin = parts.aggregator.is_none();
        let aggregator = parts
            .aggregator
            .unwrap_or_else(|| sim::aggregator::from_config(cfg.aggregation));
        let channel_model = parts
            .channel_model
            .unwrap_or_else(|| sim::channel_model::from_config(&cfg.channel));

        // Straggler/dropout policy: injected wins; else derived from the
        // config knobs (None when both are off).  A disabled injected
        // policy is dropped so `deadline.is_some()` == "exclusions can
        // happen this run".
        let deadline = match parts.deadline {
            Some(d) if d.enabled() => Some(d),
            Some(_) => None,
            None => sim::deadline::from_config(&cfg),
        };

        // Shard streaming and deadline handling both need the shard
        // protocol — surface incompatible part/config combinations here,
        // at build time, instead of failing (or silently mis-aggregating)
        // rounds in.
        if !aggregator.supports_streaming() {
            let kk = cfg.clients_per_round;
            anyhow::ensure!(
                cfg.shard_len(kk) >= kk,
                "aggregator '{}' does not support streaming rounds: \
                 shard_size {} < clients_per_round {}; remove shard_size \
                 or use a streaming aggregator",
                aggregator.name(),
                cfg.shard_size,
                kk
            );
            if let Some(d) = &deadline {
                anyhow::bail!(
                    "aggregator '{}' does not support streaming rounds, \
                     which straggler handling requires: disable the '{}' \
                     deadline/dropout policy or use a streaming aggregator",
                    aggregator.name(),
                    d.name()
                );
            }
        }

        let mut label = format!("{}@{}", policy.label(), aggregator.name());
        if cfg.partition != PartitionKind::Iid {
            // non-IID runs tag their partition so convergence grids and
            // streamed JSONL rows stay distinguishable per alpha; IID
            // labels keep the historical shape byte for byte
            label.push_str(&format!("@{}(a{})", cfg.partition, cfg.alpha));
        }
        let mut session = sim::Session::with_state(
            channel_model,
            aggregator,
            root.stream("channel"),
            root.stream("noise"),
            cfg.threads,
            agg,
            channel,
        );
        for obs in parts.observers {
            session.add_observer(obs);
        }

        Ok(Coordinator {
            select_rng: root.stream("select"),
            straggler_rng: root.stream("straggler"),
            log: RunLog::new(label),
            macs_per_sample: variant.macs_per_sample,
            layout: variant.layout.clone(),
            cfg,
            runtime,
            fleet,
            train_data,
            test_data,
            theta,
            selection,
            scratch,
            session,
            policy,
            deadline,
            streaming_builtin,
            backend: parts.backend,
            train_svc: exec::TrainService::new(),
        })
    }

    /// Current global model (flat).
    pub fn global_model(&self) -> &[f32] {
        &self.theta
    }

    /// Replace the global model (e.g. with pretrained weights).
    pub fn set_global_model(&mut self, theta: Vec<f32>) -> Result<()> {
        anyhow::ensure!(theta.len() == self.theta.len(), "model size mismatch");
        self.theta = theta;
        Ok(())
    }

    /// Execute one communication round; returns its record.
    ///
    /// Steady-state contract: every server-side buffer comes from the
    /// reused scratch arenas ([`RoundScratch`] here, [`sim::AggScratch`]
    /// in the session) — after the first round this method performs no
    /// heap allocation outside the PJRT training dispatch, including
    /// through the trait-object seams.  With `cfg.threads == 1` the
    /// default parts reproduce the historical sequential path
    /// bit-for-bit; any other thread count yields identical results
    /// (kernels-layer determinism contract).
    pub fn round(&mut self, t: usize) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let threads = self.cfg.threads;
        self.session.begin_round(t);

        // Step 0a: participant selection — FIRST, so the policy assigns
        // (and the fleet materializes) only the K selected identities.
        // The policy consumes no selection RNG and selection consumes no
        // policy state, so hoisting selection leaves every stream's draw
        // sequence untouched.
        self.selection.select_into(
            self.cfg.clients,
            t,
            &mut self.select_rng,
            &mut self.scratch.selected,
        );
        let kk = self.scratch.selected.len();
        let n = self.theta.len();

        // Step 0b: per-round precision assignment at the selected
        // identities only (O(K); equals gathering the fleet-wide
        // assignment at `selected` — the PrecisionPolicy contract).
        {
            let RoundScratch { selected, precisions, .. } = &mut self.scratch;
            self.policy.assign_selected_into(
                &sim::PolicyCtx {
                    round: t,
                    clients: self.cfg.clients,
                    snr_db: self.cfg.channel.snr_db,
                    prev: self.log.rounds.last(),
                },
                selected,
                precisions,
            )?;
        }

        // Step 0c: materialize the round's clients in the identity-keyed
        // fleet window (capacity 2·K — no same-round eviction) and record
        // each one's LRU slot plus pre-round energy (feedback baseline).
        self.fleet.reserve_round(kk);
        self.scratch.slab.clear();
        self.scratch.fb_energy0.clear();
        for slot in 0..kk {
            let id = self.scratch.selected[slot];
            let s = self.fleet.materialize(id, self.scratch.precisions[slot]);
            self.scratch.slab.push(s);
            self.scratch.fb_energy0.push(self.fleet.value(s).energy_joules);
        }

        self.scratch.stats.clear();
        self.scratch.stats.resize(kk, LocalStats::default());

        // Deadline/dropout exclusion, decided up front — serially, in
        // slot order, from the dedicated "straggler" stream (consumed
        // only here, only when a policy is active, so the disabled path
        // is byte-identical to the deadline-free engine).
        self.scratch.included.clear();
        self.scratch.included.resize(kk, true);
        let mut active_k = kk;
        if let Some(policy) = &mut self.deadline {
            let RoundScratch { selected, precisions, included, .. } =
                &mut self.scratch;
            // the policy marks EXCLUDED slots true; invert to the
            // inclusion mask the client phase and aggregators consume
            included.fill(false);
            policy.exclude_into(
                &sim::DeadlineCtx {
                    round: t,
                    selected: selected.as_slice(),
                    precisions: precisions.as_slice(),
                },
                &mut self.straggler_rng,
                included.as_mut_slice(),
            );
            for v in included.iter_mut() {
                *v = !*v;
            }
            active_k = included.iter().filter(|&&v| v).count();
        }
        let straggler_on = self.deadline.is_some();

        // Transmission staging for built-in streaming rounds: each
        // trained shard is quantized to its assigned precisions before it
        // hits the air.  `packed_on` stages rows as bit-packed codes and
        // folds them through the packed kernel protocol; otherwise the
        // rows are fake-quantized in place.  The two are bit-identical
        // (`decode(pack(x)) == fake_quant(x)` exactly —
        // `rust/tests/shard_invariance.rs` pins the trajectories against
        // each other).  Injected aggregators keep the historical raw-row
        // plane.
        let packed_on = self.cfg.packed_planes
            && self.streaming_builtin
            && self.session.supports_packed();
        let stage_fq = self.streaming_builtin && !packed_on;

        // Steps 1-4, streamed in shards: each shard of selected clients
        // trains (partitioned across the exec pool when `cfg.workers >
        // 1`) into a small reusable payload plane which is immediately
        // fused-superposed into the session's persistent air accumulator
        // — round memory is O(shard_size·N + K), not O(K·N), and the
        // trajectory is bit-identical for EVERY shard size
        // (`rust/tests/shard_invariance.rs`).  `shard_size == 0` runs one
        // whole-round shard (the historical path).
        let shard_len = self.cfg.shard_len(kk);
        let stats = if self.session.supports_streaming() {
            // channel draw happens up front (same per-stream RNG
            // consumption as the post-training draw: the streams are
            // independent), FOR the round's selected identities — so
            // stateful channel models follow the client, not the slot —
            // and every shard superposes through its slots' gains as soon
            // as its clients finish
            self.session.begin_aggregate_partial_for(
                t,
                &self.scratch.selected,
                active_k,
                n,
            );
            let pool = exec::pool();
            // Pipelined engine: overlap the next super-shard's client
            // phase with the previous one's superposition.  Gated to the
            // built-in aggregators (the superposition task touches the
            // session from a pool worker) and to runs where the pool can
            // actually overlap work; the serial branch is bit-identical
            // by the shard-invariance contract.
            let pipelined = self.cfg.pipeline_depth > 0
                && self.streaming_builtin
                && pool.max_workers() > 0
                && !exec::must_inline();
            if pipelined {
                self.pipelined_shards(kk, shard_len, threads, packed_on, stage_fq)?;
            } else {
                let mut lo = 0usize;
                while lo < kk {
                    let hi = (lo + shard_len).min(kk);
                    self.client_phase(lo, hi, threads)?;
                    // transmission staging: quantize or bit-pack the
                    // trained rows at their assigned precisions
                    {
                        let RoundScratch {
                            plane, packed, precisions, included, ..
                        } = &mut self.scratch;
                        let prec = &precisions[lo..hi];
                        let mask = if straggler_on {
                            Some(&included[lo..hi])
                        } else {
                            None
                        };
                        if packed_on {
                            stage_pack_shard(packed, plane, prec, mask);
                        } else if stage_fq {
                            stage_quant_shard(plane, prec, mask);
                        }
                    }
                    if packed_on {
                        self.session.accumulate_packed_shard_masked(
                            &self.scratch.packed,
                            lo,
                            &self.scratch.precisions[lo..hi],
                            if straggler_on {
                                Some(&self.scratch.included[lo..hi])
                            } else {
                                None
                            },
                        );
                    } else {
                        self.session.accumulate_shard_masked(
                            &self.scratch.plane,
                            lo,
                            &self.scratch.precisions[lo..hi],
                            if straggler_on {
                                Some(&self.scratch.included[lo..hi])
                            } else {
                                None
                            },
                        );
                    }
                    // shard boundary: every range handed to the client
                    // phase's workers must have been released
                    exec::assert_quiescent();
                    lo = hi;
                }
            }
            self.session.finalize_aggregate(t, &self.scratch.precisions)
        } else {
            // custom aggregator without the streaming protocol: the
            // historical whole-plane round (`from_parts` already rejected
            // shard_size/deadline configs that need streaming)
            debug_assert!(shard_len >= kk && !straggler_on);
            self.client_phase(0, kk, threads)?;
            self.session.aggregate_for(
                t,
                &self.scratch.selected,
                &self.scratch.plane,
                &self.scratch.precisions,
            )
        };
        // round boundary: no live overlap-registry claim from this round's
        // dispatches may survive aggregation (debug builds only)
        exec::assert_quiescent();

        let mut train_loss = 0.0f64;
        let mut train_acc = 0.0f64;
        for s in &self.scratch.stats {
            train_loss += s.mean_loss;
            train_acc += s.mean_acc;
        }
        // mean over the clients that actually trained (excluded slots
        // contribute default-zero stats); a fully-excluded round keeps
        // the zero sums
        if active_k > 0 {
            train_loss /= active_k as f64;
            train_acc /= active_k as f64;
        }
        let participants = stats.participants;
        if participants > 0 {
            let agg = self.session.result();
            match self.cfg.transmit {
                // θ^(t) = θ^(t-1) + mean(Δ_k)   (Alg. 1 steps 10/14)
                crate::config::Transmit::Updates => {
                    tensor::axpy_par(&mut self.theta, 1.0, agg, threads)
                }
                // θ^(t) = mean(θ_k)             (Alg. 1 step 18, ablation)
                crate::config::Transmit::Weights => self.theta.copy_from_slice(agg),
            }
        } // else: round lost to deep fades; keep θ^(t-1)

        // Post-round policy feedback: per-participant |h|, this-round
        // energy and local loss, keyed by the round's identities.  The
        // default policies ignore it (no-op default); the profiling
        // planner folds it into its bounded per-client history.  All
        // buffers come from the scratch arena — zero-alloc once warm.
        {
            let ch = self.session.channel();
            let have_ch =
                self.session.needs_channel() && ch.clients.len() == kk;
            let RoundScratch {
                selected,
                slab,
                fb_energy0,
                gains,
                fb_energy,
                fb_loss,
                stats: local_stats,
                ..
            } = &mut self.scratch;
            gains.clear();
            fb_energy.clear();
            fb_loss.clear();
            for slot in 0..kk {
                gains.push(if have_ch { ch.clients[slot].h.abs() } else { 1.0 });
                let after = self.fleet.value(slab[slot]).energy_joules;
                fb_energy.push(after - fb_energy0[slot]);
                fb_loss.push(local_stats[slot].mean_loss);
            }
            self.policy.observe_feedback(&sim::RoundFeedback {
                round: t,
                ids: selected.as_slice(),
                gains: gains.as_slice(),
                energy_j: fb_energy.as_slice(),
                losses: fb_loss.as_slice(),
            });
        }

        // Evaluation + energy accounting.
        let mut rec = RoundRecord {
            round: t,
            train_loss,
            train_accuracy: train_acc,
            participants,
            ota_mse: stats.mse_vs_ideal,
            energy_joules: self.actual_energy_joules(),
            wall_secs: 0.0,
            ..Default::default()
        };
        if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
            let eval = self.evaluate_theta(&self.theta)?;
            rec.server_accuracy = eval.accuracy;
            rec.server_loss = eval.loss;
            rec.evaluated = true;
        } else if let Some(prev) = self.log.rounds.last() {
            rec.server_accuracy = prev.server_accuracy;
            rec.server_loss = prev.server_loss;
        }
        rec.wall_secs = t0.elapsed().as_secs_f64();
        self.session.end_round(&rec);
        Ok(rec)
    }

    /// Alg. 1 steps 1-2 for ONE SHARD of selected clients — round slots
    /// `lo..hi`: re-quantize the broadcast model, run local SGD, write
    /// each payload into its shard-local plane row (`slot - lo`), and
    /// record per-slot [`LocalStats`] at the GLOBAL slot index.  The
    /// plane is reset to the shard's shape (capacity reused, so a
    /// steady-state round stays allocation-free at any shard size).
    ///
    /// With `cfg.workers > 1` (and an enabled exec pool) the shard's
    /// slots are partitioned into contiguous ranges across pool workers;
    /// each worker mutates only its own clients, its disjoint plane rows
    /// and its per-slot stats entries.  Per-client RNG streams and
    /// client-owned scratch make the result bit-identical to the
    /// sequential pass for every worker count AND every shard partition.
    /// The PJRT runtime is not `Send`, so its train steps funnel back to
    /// this thread through [`exec::TrainService`]; an injected `Sync`
    /// backend is called from the workers directly.
    fn client_phase(&mut self, lo: usize, hi: usize, threads: usize) -> Result<()> {
        let n = self.theta.len();
        let count = hi - lo;
        self.scratch.plane.reset(count, n);
        let transmit_weights =
            matches!(self.cfg.transmit, crate::config::Transmit::Weights);

        let pool = exec::pool();
        let workers = if pool.max_workers() == 0 || exec::must_inline() {
            1 // pool disabled (or we are already on a pool thread): serial
        } else {
            self.cfg.workers.min(count).max(1)
        };

        if workers <= 1 {
            for r in 0..count {
                let slot = lo + r;
                if !self.scratch.included[slot] {
                    continue; // excluded: no training, stats stay default
                }
                let c = self.fleet.value_mut(self.scratch.slab[slot]);
                let stats = match &self.backend {
                    Some(b) => c.local_round_into(
                        b.as_ref(),
                        &self.train_data,
                        &self.theta,
                        self.cfg.lr,
                        self.cfg.local_steps,
                        self.macs_per_sample,
                        transmit_weights,
                        &self.layout,
                        threads,
                        self.scratch.plane.row_mut(r),
                    )?,
                    None => c.local_round_into(
                        &exec::RuntimeStep {
                            runtime: &self.runtime,
                            variant: &self.cfg.variant,
                        },
                        &self.train_data,
                        &self.theta,
                        self.cfg.lr,
                        self.cfg.local_steps,
                        self.macs_per_sample,
                        transmit_weights,
                        &self.layout,
                        threads,
                        self.scratch.plane.row_mut(r),
                    )?,
                };
                self.scratch.stats[slot] = stats;
            }
            return Ok(());
        }

        let RoundScratch { slab, plane, stats, errors, included, .. } =
            &mut self.scratch;
        // shard-local views: worker slot indices run 0..count over these
        let slots: &[u32] = &slab[lo..hi];
        let included: &[bool] = &included[lo..hi];
        let stats: &mut [LocalStats] = &mut stats[lo..hi];
        errors.clear();
        errors.resize_with(workers, || None);
        let plane_ptr = exec::SendPtr::from_mut(plane.as_mut_slice());
        let stats_ptr = exec::SendPtr::from_mut(stats);
        let errs_ptr = exec::SendPtr::from_mut(&mut errors[..]);
        let clients = exec::DisjointMut::new(self.fleet.values_mut());
        let env = ClientPhaseEnv {
            workers,
            kk: count,
            n,
            slots,
            data: &self.train_data,
            theta: &self.theta,
            lr: self.cfg.lr,
            local_steps: self.cfg.local_steps,
            macs_per_sample: self.macs_per_sample,
            transmit_weights,
            layout: &self.layout,
            threads,
            included,
        };

        match &self.backend {
            Some(b) => {
                // Sync backend: workers train their clients directly.
                let backend: &dyn exec::TrainBackend = b.as_ref();
                let task = |w: usize| {
                    run_client_slots(
                        &env, &clients, plane_ptr, stats_ptr, errs_ptr, w, backend,
                    );
                };
                pool.broadcast(workers, &task);
            }
            None => {
                // PJRT: workers drive the round loop, every train step
                // funnels back to this thread, which sits in `serve`.
                let svc = &self.train_svc;
                svc.reset(workers);
                let runtime = &self.runtime;
                let variant = self.cfg.variant.as_str();
                let task = |w: usize| {
                    // detach on EVERY exit — a panicking task must still
                    // release the serve loop or it would wait forever
                    struct DetachGuard<'a>(&'a exec::TrainService);
                    impl Drop for DetachGuard<'_> {
                        fn drop(&mut self) {
                            self.0.detach();
                        }
                    }
                    let _guard = DetachGuard(svc);
                    let step = exec::GatewayStep::new(svc);
                    run_client_slots(
                        &env, &clients, plane_ptr, stats_ptr, errs_ptr, w, &step,
                    );
                };
                // If the runtime panics mid-serve, fail the remaining
                // requests so every worker task can finish and detach
                // (keeping the dispatch deadlock-free), then re-raise.
                let mut serve_panic: Option<Box<dyn std::any::Any + Send>> = None;
                pool.host_broadcast(workers, &task, &mut || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        svc.serve(|call| {
                            runtime.train_step(
                                variant,
                                call.precision,
                                call.theta,
                                call.images,
                                call.labels,
                                call.lr,
                            )
                        })
                    }));
                    if let Err(p) = r {
                        serve_panic = Some(p);
                        svc.serve(|_| {
                            Err(anyhow::anyhow!("PJRT runtime panicked mid-round"))
                        });
                    }
                });
                if let Some(p) = serve_panic {
                    std::panic::resume_unwind(p);
                }
            }
        }

        for e in self.scratch.errors.iter_mut() {
            if let Some(err) = e.take() {
                return Err(err);
            }
        }
        Ok(())
    }

    /// The pipelined streaming round: super-shards of `pipeline_depth ·
    /// shard_len` slots flow through two alternating payload planes; each
    /// step is ONE pool dispatch in which task 0 superposes the PREVIOUS
    /// super-shard while tasks `1..=workers` train the CURRENT one.  The
    /// first super-shard trains without overlap and the last one drains
    /// on the coordinator thread, so the accumulator still receives every
    /// shard in ascending slot order — bit-identical to the serial loop
    /// for every `{pipeline_depth, shard_size, threads, workers}`
    /// combination (`rust/tests/shard_invariance.rs`).
    fn pipelined_shards(
        &mut self,
        kk: usize,
        shard_len: usize,
        threads: usize,
        packed_on: bool,
        stage_fq: bool,
    ) -> Result<()> {
        let step_len = shard_len
            .saturating_mul(self.cfg.pipeline_depth)
            .min(kk)
            .max(1);
        // first super-shard: nothing to overlap yet, train into plane A
        let mut prev_lo = 0usize;
        let mut prev_hi = step_len.min(kk);
        self.client_phase(prev_lo, prev_hi, threads)?;
        // `cur_in_b`: the NEXT super-shard trains into `plane2`
        let mut cur_in_b = true;
        let mut lo = prev_hi;
        while lo < kk {
            let hi = (lo + step_len).min(kk);
            self.pipeline_step(
                prev_lo, prev_hi, lo, hi, cur_in_b, threads, packed_on, stage_fq,
            )?;
            // super-shard boundary: the step's dispatch has retired, so
            // its plane/session/stats claims must all be gone
            exec::assert_quiescent();
            prev_lo = lo;
            prev_hi = hi;
            lo = hi;
            cur_in_b = !cur_in_b;
        }
        // drain: the last trained super-shard stages and superposes here,
        // after every training task has retired
        let straggler_on = self.deadline.is_some();
        {
            let RoundScratch { plane, plane2, packed, precisions, included, .. } =
                &mut self.scratch;
            let last = if cur_in_b { plane } else { plane2 };
            let prec = &precisions[prev_lo..prev_hi];
            let mask = if straggler_on {
                Some(&included[prev_lo..prev_hi])
            } else {
                None
            };
            if packed_on {
                stage_pack_shard(packed, last, prec, mask);
            } else if stage_fq {
                stage_quant_shard(last, prec, mask);
            }
        }
        let prec = &self.scratch.precisions[prev_lo..prev_hi];
        let mask = if straggler_on {
            Some(&self.scratch.included[prev_lo..prev_hi])
        } else {
            None
        };
        if packed_on {
            self.session.accumulate_packed_shard_masked(
                &self.scratch.packed,
                prev_lo,
                prec,
                mask,
            );
        } else {
            let last_plane = if cur_in_b {
                &self.scratch.plane
            } else {
                &self.scratch.plane2
            };
            self.session.accumulate_shard_masked(last_plane, prev_lo, prec, mask);
        }
        Ok(())
    }

    /// One pipelined step: a single `workers + 1`-task dispatch in which
    /// task 0 — the dispatch's sole [`sim::Session`] toucher — superposes
    /// the already-trained super-shard `[prev_lo, prev_hi)` out of one
    /// plane while tasks `1..=workers` train super-shard `[cur_lo,
    /// cur_hi)` into the other.  Nested dispatches inside the superposing
    /// task run inline, which the kernels-layer determinism contract
    /// makes bit-identical to every other thread count.
    #[allow(clippy::too_many_arguments)]
    fn pipeline_step(
        &mut self,
        prev_lo: usize,
        prev_hi: usize,
        cur_lo: usize,
        cur_hi: usize,
        cur_in_b: bool,
        threads: usize,
        packed_on: bool,
        stage_fq: bool,
    ) -> Result<()> {
        let n = self.theta.len();
        let count = cur_hi - cur_lo;
        let straggler_on = self.deadline.is_some();
        let pool = exec::pool();
        let workers = self.cfg.workers.min(count).max(1);
        let transmit_weights =
            matches!(self.cfg.transmit, crate::config::Transmit::Weights);

        let Coordinator {
            cfg,
            runtime,
            fleet,
            train_data,
            theta,
            macs_per_sample,
            layout,
            scratch,
            session,
            backend,
            train_svc,
            ..
        } = self;
        let RoundScratch {
            slab,
            plane,
            plane2,
            packed,
            precisions,
            stats,
            errors,
            included,
            ..
        } = scratch;
        let (cur_plane, prev_plane) =
            if cur_in_b { (plane2, plane) } else { (plane, plane2) };
        cur_plane.reset(count, n);

        // shard-local views for the training tasks
        let slots: &[u32] = &slab[cur_lo..cur_hi];
        let inc: &[bool] = &included[cur_lo..cur_hi];
        let stats: &mut [LocalStats] = &mut stats[cur_lo..cur_hi];
        errors.clear();
        errors.resize_with(workers, || None);
        let plane_ptr = exec::SendPtr::from_mut(cur_plane.as_mut_slice());
        let stats_ptr = exec::SendPtr::from_mut(stats);
        let errs_ptr = exec::SendPtr::from_mut(&mut errors[..]);
        let clients = exec::DisjointMut::new(fleet.values_mut());
        let env = ClientPhaseEnv {
            workers,
            kk: count,
            n,
            slots,
            data: &*train_data,
            theta: theta.as_slice(),
            lr: cfg.lr,
            local_steps: cfg.local_steps,
            macs_per_sample: *macs_per_sample,
            transmit_weights,
            layout: &*layout,
            threads,
            included: inc,
        };

        // the previous super-shard's superposition inputs — STAGED
        // (fake-quantized in place, or bit-packed into the packed buffer)
        // inside task 0, the dispatch's sole toucher of the previous
        // plane and the packed staging buffer
        let prev_plane_ptr = exec::SendMutPtr::from_mut(prev_plane);
        let packed_ptr = exec::SendMutPtr::from_mut(packed);
        let prev_prec: &[Precision] = &precisions[prev_lo..prev_hi];
        let prev_mask: Option<&[bool]> = if straggler_on {
            Some(&included[prev_lo..prev_hi])
        } else {
            None
        };
        let session_ptr = exec::SendMutPtr::from_mut(session);

        match backend {
            Some(b) => {
                // Sync backend: training tasks run on workers directly.
                let backend: &dyn exec::TrainBackend = b.as_ref();
                let task = |w: usize| {
                    if w == 0 {
                        // SAFETY: task 0 is this dispatch's only Session
                        // toucher and the only toucher of the previous
                        // (already-trained) plane and the packed staging
                        // buffer — training tasks write the OTHER plane —
                        // and every `&mut` the pointers were made from
                        // outlives the blocking dispatch.
                        let session = unsafe { session_ptr.get() };
                        // SAFETY: as above.
                        let prev = unsafe { prev_plane_ptr.get() };
                        if packed_on {
                            // SAFETY: as above — task 0 solely owns the
                            // packed staging buffer for this dispatch.
                            let packed = unsafe { packed_ptr.get() };
                            stage_pack_shard(packed, prev, prev_prec, prev_mask);
                            session.accumulate_packed_shard_masked(
                                packed, prev_lo, prev_prec, prev_mask,
                            );
                        } else {
                            if stage_fq {
                                stage_quant_shard(prev, prev_prec, prev_mask);
                            }
                            session.accumulate_shard_masked(
                                prev, prev_lo, prev_prec, prev_mask,
                            );
                        }
                    } else {
                        run_client_slots(
                            &env, &clients, plane_ptr, stats_ptr, errs_ptr,
                            w - 1, backend,
                        );
                    }
                };
                pool.broadcast(workers + 1, &task);
            }
            None => {
                // PJRT: training tasks funnel their train steps back to
                // this thread, which sits in `serve`; the superposition
                // task submits no train calls and just detaches when done.
                let svc = &*train_svc;
                svc.reset(workers + 1);
                let runtime = &*runtime;
                let variant = cfg.variant.as_str();
                let task = |w: usize| {
                    struct DetachGuard<'a>(&'a exec::TrainService);
                    impl Drop for DetachGuard<'_> {
                        fn drop(&mut self) {
                            self.0.detach();
                        }
                    }
                    let _guard = DetachGuard(svc);
                    if w == 0 {
                        // SAFETY: sole toucher of Session, previous plane
                        // and packed staging buffer, as above.
                        let session = unsafe { session_ptr.get() };
                        // SAFETY: as above.
                        let prev = unsafe { prev_plane_ptr.get() };
                        if packed_on {
                            // SAFETY: as above — task 0 solely owns the
                            // packed staging buffer for this dispatch.
                            let packed = unsafe { packed_ptr.get() };
                            stage_pack_shard(packed, prev, prev_prec, prev_mask);
                            session.accumulate_packed_shard_masked(
                                packed, prev_lo, prev_prec, prev_mask,
                            );
                        } else {
                            if stage_fq {
                                stage_quant_shard(prev, prev_prec, prev_mask);
                            }
                            session.accumulate_shard_masked(
                                prev, prev_lo, prev_prec, prev_mask,
                            );
                        }
                    } else {
                        let step = exec::GatewayStep::new(svc);
                        run_client_slots(
                            &env, &clients, plane_ptr, stats_ptr, errs_ptr,
                            w - 1, &step,
                        );
                    }
                };
                let mut serve_panic: Option<Box<dyn std::any::Any + Send>> = None;
                pool.host_broadcast(workers + 1, &task, &mut || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        svc.serve(|call| {
                            runtime.train_step(
                                variant,
                                call.precision,
                                call.theta,
                                call.images,
                                call.labels,
                                call.lr,
                            )
                        })
                    }));
                    if let Err(p) = r {
                        serve_panic = Some(p);
                        svc.serve(|_| {
                            Err(anyhow::anyhow!("PJRT runtime panicked mid-round"))
                        });
                    }
                });
                if let Some(p) = serve_panic {
                    std::panic::resume_unwind(p);
                }
            }
        }

        for e in errors.iter_mut() {
            if let Some(err) = e.take() {
                return Err(err);
            }
        }
        Ok(())
    }

    /// Evaluate a flat model on the held-out test set through the
    /// configured backend (PJRT by default).
    fn evaluate_theta(&self, theta: &[f32]) -> Result<crate::runtime::EvalResult> {
        match &self.backend {
            Some(b) => b.evaluate(theta, &self.test_data.images, &self.test_data.labels),
            None => self.runtime.evaluate(
                &self.cfg.variant,
                theta,
                &self.test_data.images,
                &self.test_data.labels,
            ),
        }
    }

    /// Execute round `t` AND append its record to the run log — the
    /// manual-stepping form of [`run`](Self::run).  Keeping the log
    /// current is what feeds `PolicyCtx::prev`, carries evaluation
    /// results across non-eval rounds, and makes the end-of-run
    /// [`report`](Self::report) correct.  (Unlike `run`, artifact warmup
    /// is lazy: the first dispatch per precision pays compile latency.)
    pub fn step(&mut self, t: usize) -> Result<RoundRecord> {
        let rec = self.round(t)?;
        self.log.push(rec.clone());
        Ok(rec)
    }

    /// Run all configured rounds and produce the final report.
    pub fn run(&mut self) -> Result<RunReport> {
        let t0 = Instant::now();
        match &self.backend {
            Some(b) => b.warmup(&self.policy.levels()).context("backend warmup")?,
            None => self
                .runtime
                .warmup(&self.cfg.variant, &self.policy.levels())
                .context("artifact warmup")?,
        }
        for t in 1..=self.cfg.rounds {
            self.step(t)?;
        }
        let report = self.report(t0.elapsed().as_secs_f64())?;
        self.session.end_run(&report);
        Ok(report)
    }

    /// Post-run report: requantized client evals + energy summary.
    pub fn report(&mut self, wall_secs: f64) -> Result<RunReport> {
        let mut requant = Vec::new();
        for p in self.policy.levels() {
            let q = self.requantize_global(p);
            let eval = self.evaluate_theta(&q)?;
            requant.push(RequantEval {
                precision: p,
                accuracy: eval.accuracy,
                loss: eval.loss,
            });
        }
        let final_eval = self.evaluate_theta(&self.theta)?;
        Ok(RunReport {
            label: self.log.label.clone(),
            final_accuracy: final_eval.accuracy,
            final_loss: final_eval.loss,
            requant,
            energy: self.energy_report(),
            rounds_to_90: self.log.rounds_to_accuracy(0.90),
            wall_secs,
            log: self.log.clone(),
        })
    }

    /// Cumulative fleet energy so far (the per-round record field) —
    /// allocation-free, unlike the full counterfactual report.  Each
    /// client accrues energy at the precision it actually ran each round,
    /// so dynamic policies are accounted correctly.
    pub fn actual_energy_joules(&self) -> f64 {
        self.fleet.actual_energy_joules()
    }

    /// Energy actuals + homogeneous counterfactuals over the same MACs.
    pub fn energy_report(&self) -> EnergyReport {
        let macs = self.fleet.macs_spent();
        EnergyReport {
            actual_joules: self.actual_energy_joules(),
            all32_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(32)),
            all16_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(16)),
            all8_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(8)),
            all4_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(4)),
        }
    }

    /// Access the accumulated run log.
    pub fn log(&self) -> &RunLog {
        &self.log
    }

    /// The data-shard indices of a materialized client (anyone selected
    /// within the last two rounds is still resident in the lazy fleet
    /// window).  Diagnostics/tests accessor — panics if the client has
    /// never been selected or has been evicted.
    pub fn client_shard(&self, id: usize) -> &[usize] {
        &self
            .fleet
            .get(id)
            .expect("client not resident in the fleet window")
            .shard
    }

    /// The server-side session (channel model, aggregator, observers).
    pub fn session(&self) -> &sim::Session {
        &self.session
    }

    /// Tear down into the recyclable scratch arena (runtime + buffers for
    /// the next run of a sweep).
    pub fn into_arena(self) -> sim::Arena {
        let (agg, channel) = self.session.into_state();
        sim::Arena { round: self.scratch, agg, channel }
    }

    /// Per-layer re-quantization of the current global model to precision
    /// `p` (Fig. 2c — the deployment view of a precision-p client).
    pub fn requantize_global(&self, p: Precision) -> Vec<f32> {
        quant::fake_quant_layout(&self.theta, &self.layout, p, quant::Rounding::Nearest)
    }

    /// Evaluate an arbitrary flat model on the held-out test set (through
    /// the injected backend when one is configured).
    pub fn evaluate_model(&self, theta: &[f32]) -> Result<crate::runtime::EvalResult> {
        self.evaluate_theta(theta)
    }
}
