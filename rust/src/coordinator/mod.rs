//! The L3 coordinator: the paper's Algorithm 1 as a round-driven state
//! machine over the substrate modules.
//!
//! Per communication round t (Alg. 1):
//!   1. the precision policy assigns each client's level, the coordinator
//!      broadcasts θ^(t-1) to the selected clients;
//!   2. each client re-quantizes to its precision q_k and trains locally
//!      (PJRT execution of the `train_q{b}` artifact — [`client`]);
//!   3. the [`crate::sim::Session`] draws the round's channel through the
//!      pluggable [`crate::sim::ChannelModel`] and aggregates the payload
//!      plane through the pluggable [`crate::sim::Aggregator`] (analog
//!      OTA, digital orthogonal, or ideal FedAvg by default);
//!   4. the server applies the aggregate and the result becomes θ^(t).
//!
//! The pluggable parts arrive via [`crate::sim::SimParts`] (usually built
//! through [`crate::sim::Experiment`]); `Coordinator::new` wires the
//! config-selected defaults, which reproduce the pre-redesign enum
//! dispatch bit-for-bit per seed (`rust/tests/sim.rs`).
//!
//! Scheduling: the round streams its K selected clients through
//! fixed-size SHARDS (`RunConfig::shard_size`; `0` = one whole-round
//! shard, the historical path): each shard fills a small reusable
//! [`PayloadPlane`] and is immediately fused-superposed into the
//! session's persistent air accumulator before the next shard reuses the
//! buffers — round memory is O(shard_size·N + K) instead of O(K·N), and
//! the trajectory is bit-identical per seed for EVERY `{shard_size,
//! threads, workers}` combination (`rust/tests/shard_invariance.rs`).
//! With `RunConfig::workers > 1` each shard's client phase (step 2 —
//! re-quantize, local SGD orchestration, payload diff into the plane
//! row) is partitioned across the persistent [`crate::exec`] pool, each
//! worker owning a contiguous slot range and its disjoint plane rows.
//! The PJRT client is `Rc`-based (not `Send`), so its dispatches funnel
//! back to the coordinator thread through [`crate::exec::TrainService`];
//! an injected `Sync` [`crate::exec::TrainBackend`] runs on the workers
//! directly.  Per-client RNG/state makes the trajectory bit-identical at
//! every worker count (`rust/tests/sim.rs`).

pub mod client;
pub mod pretrain;
pub mod report;

pub use client::ClientState;
pub use report::{EnergyReport, RequantEval, RunReport};

use std::rc::Rc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::RunConfig;
use crate::data::{equal_shards, Dataset};
use crate::energy;
use crate::exec;
use crate::fl::Selection;
use crate::kernels::{par, PayloadPlane};
use crate::metrics::{RoundRecord, RunLog};
use crate::quant::{self, Precision};
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::sim;
use crate::tensor;

use client::LocalStats;

/// Round scratch arena for the coordinator-side buffers (participant
/// list, payload plane, per-round precision assignments), allocated once
/// and reused so steady-state [`Coordinator::round`] performs no heap
/// allocation outside the PJRT training dispatch
/// (`rust/tests/alloc_counter.rs` pins this on the aggregation path).
/// The aggregation-side buffers live in the [`sim::Session`]'s
/// [`sim::AggScratch`]; both recycle across runs through [`sim::Arena`].
#[derive(Default)]
pub struct RoundScratch {
    /// Participant indices for the round.
    pub(crate) selected: Vec<usize>,
    /// shard×N decimal payload rows (the superposition input).  With
    /// `RunConfig::shard_size == 0` this is the whole round's K×N plane;
    /// otherwise it holds one shard at a time and is recycled shard to
    /// shard — the O(shard·N) round-memory contract.
    pub(crate) plane: PayloadPlane,
    /// Per-participant precision levels (aligned with ROUND slots, all K
    /// of them — shards index it at `lo..hi`).
    pub(crate) precisions: Vec<Precision>,
    /// Per-client precision assignment for the full fleet (policy output).
    pub(crate) assigned: Vec<Precision>,
    /// Per-slot client training stats (parallel workers write disjoint
    /// entries; the coordinator sums them in slot order afterwards, so
    /// the reduction is bit-identical at every worker count).
    pub(crate) stats: Vec<LocalStats>,
    /// Per-worker first-error slots for the partitioned client phase.
    pub(crate) errors: Vec<Option<anyhow::Error>>,
}

/// Read-only context shared by every client-phase pool task.
struct ClientPhaseEnv<'a> {
    workers: usize,
    kk: usize,
    n: usize,
    selected: &'a [usize],
    data: &'a Dataset,
    theta: &'a [f32],
    lr: f32,
    local_steps: usize,
    macs_per_sample: u64,
    transmit_weights: bool,
    layout: &'a crate::tensor::ParamLayout,
    threads: usize,
}

/// One worker's share of the client phase: slots
/// `[chunk_start(kk, workers, w), +chunk_len)` — contiguous, so the plane
/// rows and stats entries it writes are disjoint from every other
/// worker's; client indices come from `selected`, whose entries are
/// pairwise distinct.
fn run_client_slots<S: exec::TrainStep + ?Sized>(
    env: &ClientPhaseEnv<'_>,
    clients: &exec::DisjointMut<'_, ClientState>,
    plane: exec::SendPtr<f32>,
    stats: exec::SendPtr<LocalStats>,
    errors: exec::SendPtr<Option<anyhow::Error>>,
    w: usize,
    step: &S,
) {
    let lo = par::chunk_start(env.kk, env.workers, w);
    let hi = lo + par::chunk_len(env.kk, env.workers, w);
    for slot in lo..hi {
        let k = env.selected[slot];
        // SAFETY: `selected` indices are pairwise distinct (Selection
        // contract) and each slot belongs to exactly one worker range, so
        // no client, plane row or stats entry is aliased; the buffers
        // outlive the blocking pool dispatch.
        let c = unsafe { clients.get(k) };
        let row = unsafe { plane.slice_at(slot * env.n, env.n) };
        let res = c.local_round_into(
            step,
            env.data,
            env.theta,
            env.lr,
            env.local_steps,
            env.macs_per_sample,
            env.transmit_weights,
            env.layout,
            env.threads,
            row,
        );
        match res {
            Ok(s) => unsafe { *stats.at(slot) = s },
            Err(e) => {
                // first error wins for this worker; stop its share so a
                // broken backend fails fast instead of spinning
                unsafe { *errors.at(w) = Some(e) };
                return;
            }
        }
    }
}

/// Orchestrates one full federated run.
pub struct Coordinator {
    pub cfg: RunConfig,
    pub runtime: Rc<Runtime>,
    clients: Vec<ClientState>,
    train_data: Dataset,
    test_data: Dataset,
    /// Global model (flat decimal values).
    theta: Vec<f32>,
    selection: Selection,
    select_rng: Rng,
    log: RunLog,
    macs_per_sample: u64,
    layout: crate::tensor::ParamLayout,
    scratch: RoundScratch,
    session: sim::Session,
    policy: Box<dyn sim::PrecisionPolicy>,
    /// Injected training/eval backend; `None` = the PJRT runtime.
    backend: Option<Box<dyn exec::TrainBackend>>,
    /// PJRT request funnel for the `workers > 1` client phase.
    train_svc: exec::TrainService,
}

impl Coordinator {
    /// Build everything with the config-selected default parts: runtime,
    /// data, shards, clients, initial model, static-scheme policy, the
    /// configured channel model and aggregator.
    pub fn new(cfg: RunConfig) -> Result<Self> {
        Coordinator::from_parts(cfg, sim::SimParts::default())
    }

    /// Build with injected parts; `None` fields fall back to the
    /// config-selected defaults.  This is the [`sim::Experiment`]
    /// builder's entry point.
    pub fn from_parts(cfg: RunConfig, parts: sim::SimParts) -> Result<Self> {
        cfg.validate()?;
        let runtime = match parts.runtime {
            Some(rt) => rt,
            None => Rc::new(Runtime::load(&cfg.artifacts_dir)?),
        };
        let variant = runtime.manifest.variant(&cfg.variant)?.clone();

        let root = Rng::seed_from(cfg.seed);
        let mut data_rng = root.stream("data");
        let train_data = Dataset::generate(cfg.train_samples, &mut data_rng);
        let test_data = Dataset::generate(cfg.test_samples, &mut data_rng);

        let mut policy = parts
            .policy
            .unwrap_or_else(|| sim::policy::from_config(cfg.policy, &cfg));

        let sim::Arena { round: mut scratch, agg, channel } =
            parts.arena.unwrap_or_default();

        // round-1 assignment doubles as the construction-time precisions
        policy.assign_into(
            &sim::PolicyCtx {
                round: 1,
                clients: cfg.clients,
                snr_db: cfg.channel.snr_db,
                prev: None,
            },
            &mut scratch.assigned,
        )?;

        let mut shard_rng = root.stream("shard");
        let shards = equal_shards(train_data.n, cfg.clients, &mut shard_rng);
        let clients: Vec<ClientState> = shards
            .into_iter()
            .zip(scratch.assigned.iter())
            .map(|(s, &p)| {
                ClientState::new(s.client, p, s.indices, runtime.manifest.train_batch, &root)
            })
            .collect();

        let theta = match &cfg.init_params {
            Some(path) => {
                let p = tensor::read_f32_file(path)?;
                anyhow::ensure!(
                    p.len() == variant.param_count,
                    "init params {} != param_count {}",
                    p.len(),
                    variant.param_count
                );
                p
            }
            None => runtime.init_params(&cfg.variant)?,
        };

        // `Auto` reproduces the historical mapping (everyone at K == N,
        // else uniform Fisher-Yates); `Sampled` is the O(K) massive-fleet
        // selector (Floyd's algorithm).
        let selection =
            Selection::from_config(cfg.selection, cfg.clients, cfg.clients_per_round);

        let aggregator = parts
            .aggregator
            .unwrap_or_else(|| sim::aggregator::from_config(cfg.aggregation));
        let channel_model = parts
            .channel_model
            .unwrap_or_else(|| sim::channel_model::from_config(&cfg.channel));

        let label = format!("{}@{}", policy.label(), aggregator.name());
        let mut session = sim::Session::with_state(
            channel_model,
            aggregator,
            root.stream("channel"),
            root.stream("noise"),
            cfg.threads,
            agg,
            channel,
        );
        for obs in parts.observers {
            session.add_observer(obs);
        }

        Ok(Coordinator {
            select_rng: root.stream("select"),
            log: RunLog::new(label),
            macs_per_sample: variant.macs_per_sample,
            layout: variant.layout.clone(),
            cfg,
            runtime,
            clients,
            train_data,
            test_data,
            theta,
            selection,
            scratch,
            session,
            policy,
            backend: parts.backend,
            train_svc: exec::TrainService::new(),
        })
    }

    /// Current global model (flat).
    pub fn global_model(&self) -> &[f32] {
        &self.theta
    }

    /// Replace the global model (e.g. with pretrained weights).
    pub fn set_global_model(&mut self, theta: Vec<f32>) -> Result<()> {
        anyhow::ensure!(theta.len() == self.theta.len(), "model size mismatch");
        self.theta = theta;
        Ok(())
    }

    /// Execute one communication round; returns its record.
    ///
    /// Steady-state contract: every server-side buffer comes from the
    /// reused scratch arenas ([`RoundScratch`] here, [`sim::AggScratch`]
    /// in the session) — after the first round this method performs no
    /// heap allocation outside the PJRT training dispatch, including
    /// through the trait-object seams.  With `cfg.threads == 1` the
    /// default parts reproduce the historical sequential path
    /// bit-for-bit; any other thread count yields identical results
    /// (kernels-layer determinism contract).
    pub fn round(&mut self, t: usize) -> Result<RoundRecord> {
        let t0 = Instant::now();
        let threads = self.cfg.threads;
        self.session.begin_round(t);

        // Step 0: per-round precision assignment (static policy: the same
        // fleet assignment every round).
        self.policy.assign_into(
            &sim::PolicyCtx {
                round: t,
                clients: self.cfg.clients,
                snr_db: self.cfg.channel.snr_db,
                prev: self.log.rounds.last(),
            },
            &mut self.scratch.assigned,
        )?;
        for (c, &p) in self.clients.iter_mut().zip(self.scratch.assigned.iter()) {
            c.precision = p;
        }

        self.selection.select_into(
            self.cfg.clients,
            t,
            &mut self.select_rng,
            &mut self.scratch.selected,
        );
        let kk = self.scratch.selected.len();
        let n = self.theta.len();

        // Per-participant precisions and stats slots (aligned with the
        // round's slot order, shared by every shard of the round).
        self.scratch.precisions.clear();
        for slot in 0..kk {
            let k = self.scratch.selected[slot];
            self.scratch.precisions.push(self.clients[k].precision);
        }
        self.scratch.stats.clear();
        self.scratch.stats.resize(kk, LocalStats::default());

        // Steps 1-4, streamed in shards: each shard of selected clients
        // trains (partitioned across the exec pool when `cfg.workers >
        // 1`) into a small reusable payload plane which is immediately
        // fused-superposed into the session's persistent air accumulator
        // — round memory is O(shard_size·N + K), not O(K·N), and the
        // trajectory is bit-identical for EVERY shard size
        // (`rust/tests/shard_invariance.rs`).  `shard_size == 0` runs one
        // whole-round shard (the historical path).
        let shard_len = self.cfg.shard_len(kk);
        let stats = if self.session.supports_streaming() {
            // channel draw happens up front (same per-stream RNG
            // consumption as the post-training draw: the streams are
            // independent), so every shard superposes through its slots'
            // gains as soon as its clients finish
            self.session.begin_aggregate(t, kk, n);
            let mut lo = 0usize;
            while lo < kk {
                let hi = (lo + shard_len).min(kk);
                self.client_phase(lo, hi, threads)?;
                self.session.accumulate_shard(
                    &self.scratch.plane,
                    lo,
                    &self.scratch.precisions[lo..hi],
                );
                lo = hi;
            }
            self.session.finalize_aggregate(t, &self.scratch.precisions)
        } else {
            // custom aggregator without the streaming protocol: the
            // historical whole-plane round (and an explicit error rather
            // than a silently-ignored shard_size)
            anyhow::ensure!(
                shard_len >= kk,
                "aggregator '{}' does not support streaming; remove \
                 shard_size (currently {}) or use a streaming aggregator",
                self.session.aggregator_name(),
                self.cfg.shard_size
            );
            self.client_phase(0, kk, threads)?;
            self.session
                .aggregate(t, &self.scratch.plane, &self.scratch.precisions)
        };

        let mut train_loss = 0.0f64;
        let mut train_acc = 0.0f64;
        for s in &self.scratch.stats {
            train_loss += s.mean_loss;
            train_acc += s.mean_acc;
        }
        train_loss /= kk as f64;
        train_acc /= kk as f64;
        let participants = stats.participants;
        if participants > 0 {
            let agg = self.session.result();
            match self.cfg.transmit {
                // θ^(t) = θ^(t-1) + mean(Δ_k)   (Alg. 1 steps 10/14)
                crate::config::Transmit::Updates => {
                    tensor::axpy_par(&mut self.theta, 1.0, agg, threads)
                }
                // θ^(t) = mean(θ_k)             (Alg. 1 step 18, ablation)
                crate::config::Transmit::Weights => self.theta.copy_from_slice(agg),
            }
        } // else: round lost to deep fades; keep θ^(t-1)

        // Evaluation + energy accounting.
        let mut rec = RoundRecord {
            round: t,
            train_loss,
            train_accuracy: train_acc,
            participants,
            ota_mse: stats.mse_vs_ideal,
            energy_joules: self.actual_energy_joules(),
            wall_secs: 0.0,
            ..Default::default()
        };
        if t % self.cfg.eval_every == 0 || t == self.cfg.rounds {
            let eval = self.evaluate_theta(&self.theta)?;
            rec.server_accuracy = eval.accuracy;
            rec.server_loss = eval.loss;
            rec.evaluated = true;
        } else if let Some(prev) = self.log.rounds.last() {
            rec.server_accuracy = prev.server_accuracy;
            rec.server_loss = prev.server_loss;
        }
        rec.wall_secs = t0.elapsed().as_secs_f64();
        self.session.end_round(&rec);
        Ok(rec)
    }

    /// Alg. 1 steps 1-2 for ONE SHARD of selected clients — round slots
    /// `lo..hi`: re-quantize the broadcast model, run local SGD, write
    /// each payload into its shard-local plane row (`slot - lo`), and
    /// record per-slot [`LocalStats`] at the GLOBAL slot index.  The
    /// plane is reset to the shard's shape (capacity reused, so a
    /// steady-state round stays allocation-free at any shard size).
    ///
    /// With `cfg.workers > 1` (and an enabled exec pool) the shard's
    /// slots are partitioned into contiguous ranges across pool workers;
    /// each worker mutates only its own clients, its disjoint plane rows
    /// and its per-slot stats entries.  Per-client RNG streams and
    /// client-owned scratch make the result bit-identical to the
    /// sequential pass for every worker count AND every shard partition.
    /// The PJRT runtime is not `Send`, so its train steps funnel back to
    /// this thread through [`exec::TrainService`]; an injected `Sync`
    /// backend is called from the workers directly.
    fn client_phase(&mut self, lo: usize, hi: usize, threads: usize) -> Result<()> {
        let n = self.theta.len();
        let count = hi - lo;
        self.scratch.plane.reset(count, n);
        let transmit_weights =
            matches!(self.cfg.transmit, crate::config::Transmit::Weights);

        let pool = exec::pool();
        let workers = if pool.max_workers() == 0 || exec::must_inline() {
            1 // pool disabled (or we are already on a pool thread): serial
        } else {
            self.cfg.workers.min(count).max(1)
        };

        if workers <= 1 {
            for r in 0..count {
                let slot = lo + r;
                let k = self.scratch.selected[slot];
                let c = &mut self.clients[k];
                let stats = match &self.backend {
                    Some(b) => c.local_round_into(
                        b.as_ref(),
                        &self.train_data,
                        &self.theta,
                        self.cfg.lr,
                        self.cfg.local_steps,
                        self.macs_per_sample,
                        transmit_weights,
                        &self.layout,
                        threads,
                        self.scratch.plane.row_mut(r),
                    )?,
                    None => c.local_round_into(
                        &exec::RuntimeStep {
                            runtime: &self.runtime,
                            variant: &self.cfg.variant,
                        },
                        &self.train_data,
                        &self.theta,
                        self.cfg.lr,
                        self.cfg.local_steps,
                        self.macs_per_sample,
                        transmit_weights,
                        &self.layout,
                        threads,
                        self.scratch.plane.row_mut(r),
                    )?,
                };
                self.scratch.stats[slot] = stats;
            }
            return Ok(());
        }

        let RoundScratch { selected, plane, stats, errors, .. } = &mut self.scratch;
        // shard-local views: worker slot indices run 0..count over these
        let selected: &[usize] = &selected[lo..hi];
        let stats: &mut [LocalStats] = &mut stats[lo..hi];
        errors.clear();
        errors.resize_with(workers, || None);
        let plane_ptr = exec::SendPtr::from_mut(plane.as_mut_slice());
        let stats_ptr = exec::SendPtr::from_mut(stats);
        let errs_ptr = exec::SendPtr::from_mut(&mut errors[..]);
        let clients = exec::DisjointMut::new(&mut self.clients);
        let env = ClientPhaseEnv {
            workers,
            kk: count,
            n,
            selected,
            data: &self.train_data,
            theta: &self.theta,
            lr: self.cfg.lr,
            local_steps: self.cfg.local_steps,
            macs_per_sample: self.macs_per_sample,
            transmit_weights,
            layout: &self.layout,
            threads,
        };

        match &self.backend {
            Some(b) => {
                // Sync backend: workers train their clients directly.
                let backend: &dyn exec::TrainBackend = b.as_ref();
                let task = |w: usize| {
                    run_client_slots(
                        &env, &clients, plane_ptr, stats_ptr, errs_ptr, w, backend,
                    );
                };
                pool.broadcast(workers, &task);
            }
            None => {
                // PJRT: workers drive the round loop, every train step
                // funnels back to this thread, which sits in `serve`.
                let svc = &self.train_svc;
                svc.reset(workers);
                let runtime = &self.runtime;
                let variant = self.cfg.variant.as_str();
                let task = |w: usize| {
                    // detach on EVERY exit — a panicking task must still
                    // release the serve loop or it would wait forever
                    struct DetachGuard<'a>(&'a exec::TrainService);
                    impl Drop for DetachGuard<'_> {
                        fn drop(&mut self) {
                            self.0.detach();
                        }
                    }
                    let _guard = DetachGuard(svc);
                    let step = exec::GatewayStep::new(svc);
                    run_client_slots(
                        &env, &clients, plane_ptr, stats_ptr, errs_ptr, w, &step,
                    );
                };
                // If the runtime panics mid-serve, fail the remaining
                // requests so every worker task can finish and detach
                // (keeping the dispatch deadlock-free), then re-raise.
                let mut serve_panic: Option<Box<dyn std::any::Any + Send>> = None;
                pool.host_broadcast(workers, &task, &mut || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        svc.serve(|call| {
                            runtime.train_step(
                                variant,
                                call.precision,
                                call.theta,
                                call.images,
                                call.labels,
                                call.lr,
                            )
                        })
                    }));
                    if let Err(p) = r {
                        serve_panic = Some(p);
                        svc.serve(|_| {
                            Err(anyhow::anyhow!("PJRT runtime panicked mid-round"))
                        });
                    }
                });
                if let Some(p) = serve_panic {
                    std::panic::resume_unwind(p);
                }
            }
        }

        for e in self.scratch.errors.iter_mut() {
            if let Some(err) = e.take() {
                return Err(err);
            }
        }
        Ok(())
    }

    /// Evaluate a flat model on the held-out test set through the
    /// configured backend (PJRT by default).
    fn evaluate_theta(&self, theta: &[f32]) -> Result<crate::runtime::EvalResult> {
        match &self.backend {
            Some(b) => b.evaluate(theta, &self.test_data.images, &self.test_data.labels),
            None => self.runtime.evaluate(
                &self.cfg.variant,
                theta,
                &self.test_data.images,
                &self.test_data.labels,
            ),
        }
    }

    /// Execute round `t` AND append its record to the run log — the
    /// manual-stepping form of [`run`](Self::run).  Keeping the log
    /// current is what feeds `PolicyCtx::prev`, carries evaluation
    /// results across non-eval rounds, and makes the end-of-run
    /// [`report`](Self::report) correct.  (Unlike `run`, artifact warmup
    /// is lazy: the first dispatch per precision pays compile latency.)
    pub fn step(&mut self, t: usize) -> Result<RoundRecord> {
        let rec = self.round(t)?;
        self.log.push(rec.clone());
        Ok(rec)
    }

    /// Run all configured rounds and produce the final report.
    pub fn run(&mut self) -> Result<RunReport> {
        let t0 = Instant::now();
        match &self.backend {
            Some(b) => b.warmup(&self.policy.levels()).context("backend warmup")?,
            None => self
                .runtime
                .warmup(&self.cfg.variant, &self.policy.levels())
                .context("artifact warmup")?,
        }
        for t in 1..=self.cfg.rounds {
            self.step(t)?;
        }
        let report = self.report(t0.elapsed().as_secs_f64())?;
        self.session.end_run(&report);
        Ok(report)
    }

    /// Post-run report: requantized client evals + energy summary.
    pub fn report(&mut self, wall_secs: f64) -> Result<RunReport> {
        let mut requant = Vec::new();
        for p in self.policy.levels() {
            let q = self.requantize_global(p);
            let eval = self.evaluate_theta(&q)?;
            requant.push(RequantEval {
                precision: p,
                accuracy: eval.accuracy,
                loss: eval.loss,
            });
        }
        let final_eval = self.evaluate_theta(&self.theta)?;
        Ok(RunReport {
            label: self.log.label.clone(),
            final_accuracy: final_eval.accuracy,
            final_loss: final_eval.loss,
            requant,
            energy: self.energy_report(),
            rounds_to_90: self.log.rounds_to_accuracy(0.90),
            wall_secs,
            log: self.log.clone(),
        })
    }

    /// Cumulative fleet energy so far (the per-round record field) —
    /// allocation-free, unlike the full counterfactual report.  Each
    /// client accrues energy at the precision it actually ran each round,
    /// so dynamic policies are accounted correctly.
    pub fn actual_energy_joules(&self) -> f64 {
        self.clients.iter().map(|c| c.energy_joules).sum()
    }

    /// Energy actuals + homogeneous counterfactuals over the same MACs.
    pub fn energy_report(&self) -> EnergyReport {
        let macs: Vec<f64> = self.clients.iter().map(|c| c.macs_spent).collect();
        EnergyReport {
            actual_joules: self.actual_energy_joules(),
            all32_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(32)),
            all16_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(16)),
            all8_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(8)),
            all4_joules: energy::Meter::counterfactual_joules(&macs, Precision::of(4)),
        }
    }

    /// Access the accumulated run log.
    pub fn log(&self) -> &RunLog {
        &self.log
    }

    /// The server-side session (channel model, aggregator, observers).
    pub fn session(&self) -> &sim::Session {
        &self.session
    }

    /// Tear down into the recyclable scratch arena (runtime + buffers for
    /// the next run of a sweep).
    pub fn into_arena(self) -> sim::Arena {
        let (agg, channel) = self.session.into_state();
        sim::Arena { round: self.scratch, agg, channel }
    }

    /// Per-layer re-quantization of the current global model to precision
    /// `p` (Fig. 2c — the deployment view of a precision-p client).
    pub fn requantize_global(&self, p: Precision) -> Vec<f32> {
        quant::fake_quant_layout(&self.theta, &self.layout, p, quant::Rounding::Nearest)
    }

    /// Evaluate an arbitrary flat model on the held-out test set (through
    /// the injected backend when one is configured).
    pub fn evaluate_model(&self, theta: &[f32]) -> Result<crate::runtime::EvalResult> {
        self.evaluate_theta(theta)
    }
}
