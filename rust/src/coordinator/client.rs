//! Per-client state machine: owns a data shard, a precision level, and a
//! private RNG stream; executes the paper's Alg. 1 step 2 (quantize the
//! broadcast model, train locally) against a [`crate::exec::TrainStep`]
//! backend (the PJRT runtime directly, the cross-thread PJRT gateway, or
//! an injected pure-rust trainer).
//!
//! Every client's stochastic behaviour (batch shuffles) flows from its
//! OWN RNG stream and all cross-round state is client-owned, so the
//! round trajectory is independent of WHERE the client executes — the
//! foundation of the `workers`-bit-identity contract.

use anyhow::Result;

use crate::data::{BatchIter, Dataset, SAMPLE_LEN};
use crate::energy;
use crate::exec::TrainStep;
use crate::quant::{self, Precision};
use crate::rng::Rng;

/// Client-side metrics from one local round.
#[derive(Clone, Copy, Debug, Default)]
pub struct LocalStats {
    pub mean_loss: f64,
    pub mean_acc: f64,
    pub steps: usize,
    pub samples: u64,
}

/// One federated client.
pub struct ClientState {
    pub id: usize,
    pub precision: Precision,
    /// Indices into the global training corpus owned by this client.
    pub shard: Vec<usize>,
    batches: BatchIter,
    rng: Rng,
    /// Scratch buffers reused across rounds (no allocation in the loop).
    img_buf: Vec<f32>,
    label_buf: Vec<i32>,
    global_idx: Vec<usize>,
    /// Re-quantized broadcast model [θ^(t-1)]_{q_k} (reused across rounds).
    theta_start: Vec<f32>,
    /// Local training state θ_k (reused across rounds).
    theta: Vec<f32>,
    /// Double buffer for θ_k: `train_step_into` writes here, then the
    /// buffers swap — so backends that implement the allocation-free step
    /// keep warm rounds heap-silent.
    theta_next: Vec<f32>,
    /// Cumulative MACs this client has spent (energy accounting).
    pub macs_spent: f64,
    /// Cumulative joules, accrued at the precision each MAC actually ran
    /// at — correct even when a dynamic policy changes `precision`
    /// between rounds.  (Accruing per step instead of once over the MAC
    /// total can differ from the historical closed-form value in the last
    /// f64 ulp; the energy column is diagnostic and not covered by the
    /// bit-identity contract, which pins model/aggregation values.)
    pub energy_joules: f64,
}

impl ClientState {
    pub fn new(
        id: usize,
        precision: Precision,
        shard: Vec<usize>,
        train_batch: usize,
        root_rng: &Rng,
    ) -> Self {
        let mut rng = root_rng.stream("client").substream(id as u64);
        let batches = BatchIter::new(shard.len(), train_batch, &mut rng);
        ClientState {
            id,
            precision,
            shard,
            batches,
            rng,
            img_buf: vec![0.0f32; train_batch * SAMPLE_LEN],
            label_buf: vec![0i32; train_batch],
            global_idx: Vec::with_capacity(train_batch),
            theta_start: Vec::new(),
            theta: Vec::new(),
            theta_next: Vec::new(),
            macs_spent: 0.0,
            energy_joules: 0.0,
        }
    }

    /// Alg. 1 step 2: quantize the broadcast model to this client's
    /// precision, run `local_steps` minibatch SGD steps at that precision,
    /// and return the payload for OTA transmission plus local metrics.
    ///
    /// Payload semantics follow Alg. 1 step 10/14: the client transmits its
    /// model UPDATE `Δ[θ_k] = [θ_k]_{q_k} - [θ^(t-1)]_{q_k}` (as decimal
    /// values, ready for amplitude modulation).  Transmitting updates
    /// rather than full weights keeps the server's global model at full
    /// precision — coarse clients contribute small zero-mean-ish deltas
    /// instead of dragging the global weights onto their coarse grid (the
    /// failure mode EXPERIMENTS.md §Fig3-ablation demonstrates).
    pub fn local_round<S: TrainStep + ?Sized>(
        &mut self,
        step: &S,
        data: &Dataset,
        theta_global: &[f32],
        lr: f32,
        local_steps: usize,
        macs_per_sample: u64,
        transmit_weights: bool,
        layout: &crate::tensor::ParamLayout,
    ) -> Result<(Vec<f32>, LocalStats)> {
        let mut payload = vec![0.0f32; theta_global.len()];
        let stats = self.local_round_into(
            step,
            data,
            theta_global,
            lr,
            local_steps,
            macs_per_sample,
            transmit_weights,
            layout,
            1,
            &mut payload,
        )?;
        Ok((payload, stats))
    }

    /// Zero-alloc form of [`local_round`]: the payload is written straight
    /// into `payload_out` (the client's payload-plane row) and all model
    /// buffers are client-owned scratch reused across rounds.  SGD steps
    /// go through [`TrainStep::train_step_into`] with a swapped double
    /// buffer, so backends implementing the in-place seam run warm rounds
    /// without heap traffic; the PJRT default still allocates inside its
    /// dispatch (literals / backend output), outside the arena contract.
    /// Runs unchanged on the coordinator thread or on a pool
    /// worker — `step` decides where the SGD step actually executes.
    #[allow(clippy::too_many_arguments)]
    pub fn local_round_into<S: TrainStep + ?Sized>(
        &mut self,
        step: &S,
        data: &Dataset,
        theta_global: &[f32],
        lr: f32,
        local_steps: usize,
        macs_per_sample: u64,
        transmit_weights: bool,
        layout: &crate::tensor::ParamLayout,
        threads: usize,
        payload_out: &mut [f32],
    ) -> Result<LocalStats> {
        assert_eq!(payload_out.len(), theta_global.len());
        // Step 2a: re-quantize the broadcast model (Fig. 2c) onto the
        // client's TRAINING grid — per LAYER (paper §III-B), nearest
        // rounding (same grid the QAT graph uses; floor is reserved for
        // transmission/PTQ).  Fused quantize-into: no copy pass, no
        // allocation once the scratch is warm.
        self.theta_start.resize(theta_global.len(), 0.0);
        quant::fake_quant_layout_into(
            &mut self.theta_start,
            theta_global,
            layout,
            self.precision,
            quant::Rounding::Nearest,
            threads,
        );
        self.theta.resize(theta_global.len(), 0.0);
        self.theta.copy_from_slice(&self.theta_start);
        self.theta_next.resize(theta_global.len(), 0.0);

        let mut stats = LocalStats::default();
        let batch = self.label_buf.len();
        for _ in 0..local_steps {
            if !self.batches.has_next() {
                self.batches.reset(&mut self.rng);
            }
            let idx = self
                .batches
                .next_batch()
                .expect("shard smaller than one batch");
            // gather via the *global* corpus through this client's shard
            self.global_idx.clear();
            self.global_idx.extend(idx.iter().map(|&i| self.shard[i]));
            data.gather(&self.global_idx, &mut self.img_buf, &mut self.label_buf);
            let m = step.train_step_into(
                self.precision,
                &self.theta,
                &self.img_buf,
                &self.label_buf,
                lr,
                &mut self.theta_next,
            )?;
            std::mem::swap(&mut self.theta, &mut self.theta_next);
            stats.mean_loss += m.loss as f64;
            stats.mean_acc += m.correct as f64 / batch as f64;
            stats.steps += 1;
            stats.samples += batch as u64;
            // fwd+bwd ≈ 3x forward MACs per trained sample
            let step_macs = 3.0 * macs_per_sample as f64 * batch as f64;
            self.macs_spent += step_macs;
            self.energy_joules += energy::mean_energy_joules(self.precision, step_macs);
        }
        if stats.steps > 0 {
            stats.mean_loss /= stats.steps as f64;
            stats.mean_acc /= stats.steps as f64;
        }
        if transmit_weights {
            payload_out.copy_from_slice(&self.theta);
        } else {
            // Δ[θ_k] = [θ_k]_{q_k} - [θ^(t-1)]_{q_k}   (Alg. 1 step 10)
            crate::tensor::diff_into(payload_out, &self.theta, &self.theta_start);
        }
        Ok(stats)
    }

    /// Smallest number of local steps that constitutes one epoch over the
    /// client's shard.
    pub fn steps_per_epoch(&self) -> usize {
        self.batches.batches_per_epoch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_rng_streams_differ() {
        let root = Rng::seed_from(1);
        let a = ClientState::new(0, Precision::of(8), (0..64).collect(), 32, &root);
        let b = ClientState::new(1, Precision::of(8), (0..64).collect(), 32, &root);
        // different shuffle orders => different first batches (w.h.p.)
        let mut ai = a.batches;
        let mut bi = b.batches;
        assert_ne!(ai.next_batch().unwrap(), bi.next_batch().unwrap());
    }

    #[test]
    fn steps_per_epoch() {
        let root = Rng::seed_from(2);
        let c = ClientState::new(0, Precision::of(4), (0..100).collect(), 32, &root);
        assert_eq!(c.steps_per_epoch(), 3);
    }
}
