//! The [`RoundObserver`] event sink: per-round hooks for logging, live
//! progress, metric streaming or test instrumentation, without touching
//! the round loop.
//!
//! Observers attach to a [`crate::sim::Session`] (directly or through
//! [`crate::sim::ExperimentBuilder::observe`]); every hook has an empty
//! default body so implementations override only what they need.  Hook
//! bodies run on the coordinator thread inside the round — keep them
//! cheap, and allocation-free if the zero-alloc round contract matters to
//! your run.

use crate::channel::RoundChannel;
use crate::coordinator::RunReport;
use crate::metrics::RoundRecord;
use crate::ota::AggregateStats;

/// Per-round event hooks.
#[allow(unused_variables)]
pub trait RoundObserver {
    /// A communication round is starting.
    fn on_round_start(&mut self, round: usize) {}

    /// The round's channel realisation was drawn (only fires for
    /// aggregators that use a channel).
    fn on_channel(&mut self, round: usize, channel: &RoundChannel) {}

    /// The payload plane was aggregated.
    fn on_aggregate(&mut self, round: usize, stats: &AggregateStats) {}

    /// The round finished (record includes evaluation + energy).
    fn on_round_end(&mut self, record: &RoundRecord) {}

    /// The full run finished.
    fn on_run_end(&mut self, report: &RunReport) {}
}

/// Prints one line per round — the CLI's live progress view.
pub struct ProgressPrinter;

impl RoundObserver for ProgressPrinter {
    fn on_round_end(&mut self, r: &RoundRecord) {
        println!(
            "round {:>3}  acc {:.4}  loss {:.4}  train_loss {:.4}  part {:>2}  ota_mse {:.3e}",
            r.round,
            r.server_accuracy,
            r.server_loss,
            r.train_loss,
            r.participants,
            r.ota_mse
        );
    }
}
