//! The [`RoundObserver`] event sink: per-round hooks for logging, live
//! progress, metric streaming or test instrumentation, without touching
//! the round loop.
//!
//! Observers attach to a [`crate::sim::Session`] (directly or through
//! [`crate::sim::ExperimentBuilder::observe`]); every hook has an empty
//! default body so implementations override only what they need.  Hook
//! bodies run on the coordinator thread inside the round — keep them
//! cheap, and allocation-free if the zero-alloc round contract matters to
//! your run.

use crate::channel::RoundChannel;
use crate::coordinator::RunReport;
use crate::metrics::RoundRecord;
use crate::ota::AggregateStats;

/// Per-round event hooks.
#[allow(unused_variables)]
pub trait RoundObserver {
    /// A communication round is starting.
    fn on_round_start(&mut self, round: usize) {}

    /// The round's channel realisation was drawn (only fires for
    /// aggregators that use a channel).
    fn on_channel(&mut self, round: usize, channel: &RoundChannel) {}

    /// The payload plane was aggregated.
    fn on_aggregate(&mut self, round: usize, stats: &AggregateStats) {}

    /// The round finished (record includes evaluation + energy).
    fn on_round_end(&mut self, record: &RoundRecord) {}

    /// The full run finished.
    fn on_run_end(&mut self, report: &RunReport) {}
}

/// Prints one line per round — the CLI's live progress view.
pub struct ProgressPrinter;

impl RoundObserver for ProgressPrinter {
    fn on_round_end(&mut self, r: &RoundRecord) {
        println!(
            "round {:>3}  acc {:.4}  loss {:.4}  train_loss {:.4}  part {:>2}  ota_mse {:.3e}",
            r.round,
            r.server_accuracy,
            r.server_loss,
            r.train_loss,
            r.participants,
            r.ota_mse
        );
    }
}

/// Streams one JSON line per finished round to a file — the long-run
/// replacement for the post-hoc `RunLog` JSONL export, with an explicit
/// crash-safety contract:
///
/// * every record is written as ONE complete line and flushed to the OS
///   before [`push`](Self::push) returns, so an aborted process (panic,
///   `SIGKILL`, `mem::forget`) leaves only whole JSONL lines behind —
///   never a torn one (`rust/tests/robustness.rs`);
/// * round boundaries additionally fsync ([`sync`](Self::sync), called
///   from the `on_round_end` hook), so a machine crash loses at most the
///   round in flight.
///
/// Lines are exactly the [`RoundRecord::to_json`] shape
/// `RunLog::to_jsonl` emits, tagged with an optional label (sweeps tag
/// each cell's coordinates).
///
/// Wired as `--stream <path>` on `mpota train` and `mpota sweep`.
pub struct JsonlStreamer {
    out: std::io::BufWriter<std::fs::File>,
    label: String,
    /// Latched on the first write error so a full disk degrades to one
    /// warning instead of a panic mid-run.
    failed: bool,
}

impl JsonlStreamer {
    /// Create (truncate) `path` and stream into it.
    pub fn create(path: &std::path::Path) -> anyhow::Result<Self> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        Ok(JsonlStreamer {
            out: std::io::BufWriter::new(std::fs::File::create(path)?),
            label: String::new(),
            failed: false,
        })
    }

    /// Append to `path` (creating it if absent) — multi-cell sweeps open
    /// the shared stream this way for every cell after the first.
    pub fn append(path: &std::path::Path) -> anyhow::Result<Self> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        Ok(JsonlStreamer {
            out: std::io::BufWriter::new(
                std::fs::OpenOptions::new().create(true).append(true).open(path)?,
            ),
            label: String::new(),
            failed: false,
        })
    }

    /// Tag subsequent lines with `label` (builder-style).
    pub fn with_label(mut self, label: impl Into<String>) -> Self {
        self.label = label.into();
        self
    }

    /// Tag subsequent lines with `label` (serial sweeps retag per cell).
    pub fn set_label(&mut self, label: impl Into<String>) {
        self.label = label.into();
    }

    /// Write one record now (used directly by the channel-only sweep,
    /// which drives no full `RoundObserver` lifecycle).  The line is
    /// flushed to the OS before this returns — an abort after `push`
    /// cannot tear or lose it short of a machine crash.
    pub fn push(&mut self, r: &RoundRecord) {
        if self.failed {
            return;
        }
        use std::io::Write;
        let mut line = r.to_json(&self.label).to_string();
        line.push('\n');
        let res = self
            .out
            .write_all(line.as_bytes())
            .and_then(|()| self.out.flush());
        if let Err(e) = res {
            eprintln!("warning: round stream write failed ({e}); disabling stream");
            self.failed = true;
        }
    }

    /// Force everything written so far onto stable storage (fsync) —
    /// the round-boundary durability point.
    pub fn sync(&mut self) {
        if self.failed {
            return;
        }
        if let Err(e) = self.out.get_ref().sync_data() {
            eprintln!("warning: round stream sync failed ({e}); disabling stream");
            self.failed = true;
        }
    }
}

impl RoundObserver for JsonlStreamer {
    fn on_round_end(&mut self, r: &RoundRecord) {
        self.push(r);
        self.sync();
    }
}
