//! Composable simulation API: the trait seams the round loop is built
//! from, plus the [`Experiment`] session builder and the [`sweep`] driver.
//!
//! The pre-redesign coordinator hard-coded three aggregation enum arms,
//! one channel model and a static precision scheme.  This module breaks
//! those decisions into pluggable traits over the kernels-layer
//! plane/arena substrate:
//!
//! * [`Aggregator`] — payload plane + channel realisation → aggregated
//!   model ([`AnalogOta`], [`DigitalOrthogonal`], [`IdealFedAvg`]);
//! * [`ChannelModel`] — per-round channel draw ([`RayleighPilot`] is the
//!   paper's Rayleigh+pilot+inversion pipeline, [`Awgn`] a no-fading
//!   alternative, [`GaussMarkov`] adds AR(1) temporal correlation and
//!   [`PathLossGeometry`] persistent per-client path-loss/shadowing
//!   asymmetry);
//! * [`PrecisionPolicy`] — per-round client bit assignment
//!   ([`StaticScheme`] reproduces the paper's fixed groups,
//!   [`SnrAdaptive`] picks bits from the channel SNR, and the feedback
//!   policies [`LossPlateau`] / [`EnergyBudget`] react to the previous
//!   round's record via [`PolicyCtx::prev`]);
//! * [`RoundObserver`] — event sink for progress/logging/instrumentation.
//!
//! [`Session`] wires the server-side seams together over one reusable
//! scratch arena; [`Coordinator`](crate::coordinator::Coordinator) drives
//! it inside the full FL round, and [`Experiment`] is the public builder
//! over both.  Multi-run drivers ([`sweep`], benches) recycle one
//! [`Arena`] and one `Rc<Runtime>` across runs.
//!
//! # Streaming (sharded) rounds
//!
//! Aggregators that implement the streaming protocol
//! ([`Aggregator::supports_streaming`] — all three built-ins do) let the
//! round pipeline fold the K participants in shard-size payload planes:
//! [`Session::begin_aggregate`] → N × [`Session::accumulate_shard`] →
//! [`Session::finalize_aggregate`].  Round memory becomes
//! O(shard·N + K) instead of O(K·N) — the massive-fleet mode — and every
//! shard partition is bit-identical to the one-shot
//! [`Session::aggregate`] (the one-shot built-ins are implemented ON the
//! streaming pieces, so the paths share each instruction;
//! `rust/tests/shard_invariance.rs` pins full runs).
//!
//! # Determinism and allocation contracts
//!
//! The PR-1 contracts survive the trait seams and are re-pinned through
//! them: with the default parts, results are bit-identical per seed to the
//! pre-redesign enum paths at every thread count (`rust/tests/sim.rs`),
//! and a steady-state round performs zero heap allocation through the
//! trait objects (`rust/tests/alloc_counter.rs`) — including the sharded
//! streaming path at `shard_size < K`.

pub mod aggregator;
pub mod channel_model;
pub mod deadline;
pub mod experiment;
pub mod observer;
pub mod policy;
pub mod sweep;

pub use aggregator::{
    AggCtx, AggScratch, Aggregator, AnalogOta, DigitalOrthogonal, IdealFedAvg,
};
pub use channel_model::{
    Awgn, ChannelModel, GaussMarkov, PathLossGeometry, RayleighPilot,
};
pub use deadline::{DeadlineCtx, DeadlinePolicy, VirtualClock};
pub use experiment::{Experiment, ExperimentBuilder};
pub use observer::{JsonlStreamer, ProgressPrinter, RoundObserver};
pub use policy::{
    EnergyBudget, LossPlateau, PolicyCtx, PrecisionPolicy, ProfilingPlanner,
    RoundFeedback, SnrAdaptive, StaticScheme,
};
pub use sweep::{BackendFactory, SweepReport, SweepSpec};

use std::rc::Rc;

use crate::channel::RoundChannel;
use crate::coordinator::RoundScratch;
use crate::kernels::PayloadPlane;
use crate::metrics::RoundRecord;
use crate::ota::AggregateStats;
use crate::quant::Precision;
use crate::rng::Rng;
use crate::runtime::Runtime;

/// Recyclable server-side scratch: every buffer a run grows to capacity,
/// handed from a finished run to the next one so a sweep allocates its
/// arena once (see [`Experiment::into_arena`] and
/// [`ExperimentBuilder::arena`]).
#[derive(Default)]
pub struct Arena {
    pub(crate) round: RoundScratch,
    pub(crate) agg: AggScratch,
    pub(crate) channel: RoundChannel,
}

/// Injectable parts for a simulation run; `None`/empty fields fall back to
/// the config-selected defaults ([`crate::coordinator::Coordinator`]
/// resolves them).
#[derive(Default)]
pub struct SimParts {
    /// Shared runtime (sweeps/benches reuse one across runs).
    pub runtime: Option<Rc<Runtime>>,
    pub channel_model: Option<Box<dyn ChannelModel>>,
    pub aggregator: Option<Box<dyn Aggregator>>,
    pub policy: Option<Box<dyn PrecisionPolicy>>,
    pub observers: Vec<Box<dyn RoundObserver>>,
    /// Replacement training/eval backend (`None` = PJRT).  Must be `Sync`
    /// — with `RunConfig::workers > 1` it is called from pool workers.
    pub backend: Option<Box<dyn crate::exec::TrainBackend>>,
    /// Replacement straggler/dropout policy (`None` = config-selected:
    /// [`VirtualClock`] when enabled, nothing otherwise).
    pub deadline: Option<Box<dyn DeadlinePolicy>>,
    /// Recycled scratch arena from a previous run.
    pub arena: Option<Arena>,
}

/// The server-side round engine: one channel model + one aggregator +
/// observers over a reusable scratch arena and the channel/noise RNG
/// streams.  Everything below the training layer — so it runs (and is
/// tested) without PJRT artifacts.
pub struct Session {
    channel_model: Box<dyn ChannelModel>,
    aggregator: Box<dyn Aggregator>,
    observers: Vec<Box<dyn RoundObserver>>,
    channel_rng: Rng,
    noise_rng: Rng,
    threads: usize,
    round_channel: RoundChannel,
    scratch: AggScratch,
}

impl Session {
    /// Fresh session (buffers grow on first use).
    pub fn new(
        channel_model: Box<dyn ChannelModel>,
        aggregator: Box<dyn Aggregator>,
        channel_rng: Rng,
        noise_rng: Rng,
        threads: usize,
    ) -> Self {
        Session::with_state(
            channel_model,
            aggregator,
            channel_rng,
            noise_rng,
            threads,
            AggScratch::default(),
            RoundChannel::empty(),
        )
    }

    /// Session over recycled scratch buffers (the multi-run form).
    #[allow(clippy::too_many_arguments)]
    pub fn with_state(
        channel_model: Box<dyn ChannelModel>,
        aggregator: Box<dyn Aggregator>,
        channel_rng: Rng,
        noise_rng: Rng,
        threads: usize,
        scratch: AggScratch,
        round_channel: RoundChannel,
    ) -> Self {
        Session {
            channel_model,
            aggregator,
            observers: Vec::new(),
            channel_rng,
            noise_rng,
            threads,
            round_channel,
            scratch,
        }
    }

    pub fn add_observer(&mut self, obs: Box<dyn RoundObserver>) {
        self.observers.push(obs);
    }

    pub fn aggregator_name(&self) -> &'static str {
        self.aggregator.name()
    }

    pub fn channel_model_name(&self) -> &'static str {
        self.channel_model.name()
    }

    /// The last drawn channel realisation.
    pub fn channel(&self) -> &RoundChannel {
        &self.round_channel
    }

    /// Whether the configured aggregator consumes a channel realisation —
    /// i.e. whether [`channel`](Self::channel) holds THIS round's draw
    /// after aggregation (an ideal aggregator never draws, so the buffer
    /// may hold a stale realisation from a previous run of the arena).
    pub fn needs_channel(&self) -> bool {
        self.aggregator.needs_channel()
    }

    /// Notify observers that round `t` is starting.
    pub fn begin_round(&mut self, t: usize) {
        for obs in &mut self.observers {
            obs.on_round_start(t);
        }
    }

    /// Run the round's server side: draw the channel (when the aggregator
    /// uses one — skipping it also skips its RNG consumption, matching the
    /// pre-redesign enum dispatch draw-for-draw), aggregate the plane, and
    /// notify observers.  `scratch` access afterwards via
    /// [`result`](Self::result).
    pub fn aggregate(
        &mut self,
        t: usize,
        plane: &PayloadPlane,
        precisions: &[Precision],
    ) -> AggregateStats {
        if self.aggregator.needs_channel() {
            self.channel_model.draw_into(
                plane.k(),
                &mut self.channel_rng,
                &mut self.round_channel,
            );
            for obs in &mut self.observers {
                obs.on_channel(t, &self.round_channel);
            }
        }
        let mut ctx = AggCtx {
            channel: &self.round_channel,
            precisions,
            noise_rng: &mut self.noise_rng,
            threads: self.threads,
            included: None,
        };
        let stats = self.aggregator.aggregate_into(plane, &mut ctx, &mut self.scratch);
        for obs in &mut self.observers {
            obs.on_aggregate(t, &stats);
        }
        stats
    }

    /// Identity-aware one-shot aggregation: like
    /// [`aggregate`](Self::aggregate) but the channel is drawn FOR the
    /// round's selected client identities (`ids`, slot-ordered, aligned
    /// with the plane rows), so stateful channel models key their
    /// persistent state by client rather than by slot.  With
    /// `ids == [0, 1, .., k-1]` (full participation / round-robin) this
    /// is `aggregate`, instruction for instruction.
    pub fn aggregate_for(
        &mut self,
        t: usize,
        ids: &[usize],
        plane: &PayloadPlane,
        precisions: &[Precision],
    ) -> AggregateStats {
        debug_assert_eq!(ids.len(), plane.k());
        if self.aggregator.needs_channel() {
            self.channel_model.draw_for(
                ids,
                &mut self.channel_rng,
                &mut self.round_channel,
            );
            for obs in &mut self.observers {
                obs.on_channel(t, &self.round_channel);
            }
        }
        let mut ctx = AggCtx {
            channel: &self.round_channel,
            precisions,
            noise_rng: &mut self.noise_rng,
            threads: self.threads,
            included: None,
        };
        let stats = self.aggregator.aggregate_into(plane, &mut ctx, &mut self.scratch);
        for obs in &mut self.observers {
            obs.on_aggregate(t, &stats);
        }
        stats
    }

    /// Whether the configured aggregator implements the streaming
    /// (sharded) round protocol — see [`Aggregator::supports_streaming`].
    pub fn supports_streaming(&self) -> bool {
        self.aggregator.supports_streaming()
    }

    /// Start a STREAMING aggregation round of `total_k` participants with
    /// N-element payloads: draw the round's channel realisation for ALL
    /// `total_k` slots up front (identical RNG consumption to the
    /// one-shot [`aggregate`](Self::aggregate), and skipped — draws
    /// included — when the aggregator needs no channel) and reset the
    /// accumulator state.  Follow with [`accumulate_shard`] calls over
    /// consecutive slot ranges and one [`finalize_aggregate`].
    ///
    /// Memory contract: the session-side state is O(total_k + N) — the
    /// channel realisation plus the air accumulators — never O(K·N); the
    /// caller streams payload shards through a small reusable plane.
    ///
    /// [`accumulate_shard`]: Self::accumulate_shard
    /// [`finalize_aggregate`]: Self::finalize_aggregate
    pub fn begin_aggregate(&mut self, t: usize, total_k: usize, n: usize) {
        self.begin_aggregate_partial(t, total_k, total_k, n);
    }

    /// Partial-participation variant of
    /// [`begin_aggregate`](Self::begin_aggregate): only `active_k` of the
    /// round's `total_k` selected clients will actually transmit (the
    /// rest missed the deadline or dropped).  The channel is still drawn
    /// for ALL `total_k` slots — excluded clients own their slots, the
    /// realisation does not depend on who misses — but the aggregation
    /// divisor tracks `active_k` (see
    /// [`Aggregator::begin_partial_into`]).  With `active_k == total_k`
    /// this IS `begin_aggregate`, instruction for instruction.
    pub fn begin_aggregate_partial(
        &mut self,
        t: usize,
        total_k: usize,
        active_k: usize,
        n: usize,
    ) {
        if self.aggregator.needs_channel() {
            self.channel_model.draw_into(
                total_k,
                &mut self.channel_rng,
                &mut self.round_channel,
            );
            for obs in &mut self.observers {
                obs.on_channel(t, &self.round_channel);
            }
        }
        self.aggregator.begin_partial_into(total_k, active_k, n, &mut self.scratch);
    }

    /// Identity-aware variant of
    /// [`begin_aggregate_partial`](Self::begin_aggregate_partial): the
    /// channel is drawn FOR the round's selected client identities
    /// (`ids`, slot-ordered — one slot per selected client, excluded
    /// clients included), so stateful channel models key their persistent
    /// state by client rather than by slot.  With `ids == [0, 1, ..,
    /// k-1]` this is `begin_aggregate_partial`, instruction for
    /// instruction.
    pub fn begin_aggregate_partial_for(
        &mut self,
        t: usize,
        ids: &[usize],
        active_k: usize,
        n: usize,
    ) {
        if self.aggregator.needs_channel() {
            self.channel_model.draw_for(
                ids,
                &mut self.channel_rng,
                &mut self.round_channel,
            );
            for obs in &mut self.observers {
                obs.on_channel(t, &self.round_channel);
            }
        }
        self.aggregator.begin_partial_into(ids.len(), active_k, n, &mut self.scratch);
    }

    /// Fold one shard — rows `slot0 .. slot0 + shard.k()` of the round,
    /// with the SHARD's precisions (aligned with its rows) — into the
    /// round accumulator.
    pub fn accumulate_shard(
        &mut self,
        shard: &PayloadPlane,
        slot0: usize,
        precisions: &[Precision],
    ) {
        self.accumulate_shard_masked(shard, slot0, precisions, None);
    }

    /// Masked variant of [`accumulate_shard`](Self::accumulate_shard):
    /// rows `r` with `included[r] == false` (shard-aligned mask) are
    /// excluded clients — their plane rows are NEVER read (the reset
    /// plane holds stale data for slots the client phase skipped) and
    /// they contribute neither signal, channel uses nor bits.  `None`
    /// means everyone transmits, bit-identical to the unmasked entry.
    pub fn accumulate_shard_masked(
        &mut self,
        shard: &PayloadPlane,
        slot0: usize,
        precisions: &[Precision],
        included: Option<&[bool]>,
    ) {
        let mut ctx = AggCtx {
            channel: &self.round_channel,
            precisions,
            noise_rng: &mut self.noise_rng,
            threads: self.threads,
            included,
        };
        self.aggregator.accumulate_into(shard, slot0, &mut ctx, &mut self.scratch);
    }

    /// Whether the configured aggregator can fold bit-packed shards
    /// directly — see [`Aggregator::supports_packed`].  Callers that
    /// stage shards as [`crate::kernels::PackedPlane`] must check this
    /// first and fall back to the f32 streaming entry otherwise.
    pub fn supports_packed(&self) -> bool {
        self.aggregator.supports_packed()
    }

    /// Packed twin of
    /// [`accumulate_shard_masked`](Self::accumulate_shard_masked): folds a
    /// bit-packed shard (rows stored at their transmission precision)
    /// into the round accumulator, decoding codes inline in the fused
    /// kernels.  Bit-identical to staging each row through
    /// [`crate::quant::fake_quant_inplace`] and calling the f32 entry —
    /// `decode(pack(x)) == fake_quant(x)` bit-for-bit per element.
    pub fn accumulate_packed_shard_masked(
        &mut self,
        shard: &crate::kernels::PackedPlane,
        slot0: usize,
        precisions: &[Precision],
        included: Option<&[bool]>,
    ) {
        let mut ctx = AggCtx {
            channel: &self.round_channel,
            precisions,
            noise_rng: &mut self.noise_rng,
            threads: self.threads,
            included,
        };
        self.aggregator.accumulate_packed_into(shard, slot0, &mut ctx, &mut self.scratch);
    }

    /// Finish the streaming round (noise injection, scaling, diagnostics)
    /// and notify observers; [`result`](Self::result) holds the
    /// aggregated mean afterwards.  A single-shard stream produces
    /// bit-identical results to [`aggregate`](Self::aggregate) — the
    /// built-in aggregators implement the one-shot entry on the streaming
    /// pieces.
    pub fn finalize_aggregate(
        &mut self,
        t: usize,
        precisions: &[Precision],
    ) -> AggregateStats {
        let mut ctx = AggCtx {
            channel: &self.round_channel,
            precisions,
            noise_rng: &mut self.noise_rng,
            threads: self.threads,
            included: None,
        };
        let stats = self.aggregator.finalize_into(&mut ctx, &mut self.scratch);
        for obs in &mut self.observers {
            obs.on_aggregate(t, &stats);
        }
        stats
    }

    /// The aggregated MEAN vector from the last [`aggregate`](Self::aggregate).
    pub fn result(&self) -> &[f32] {
        self.scratch.result()
    }

    /// Notify observers that the round finished.
    pub fn end_round(&mut self, rec: &RoundRecord) {
        for obs in &mut self.observers {
            obs.on_round_end(rec);
        }
    }

    /// Notify observers that the run finished.
    pub fn end_run(&mut self, report: &crate::coordinator::RunReport) {
        for obs in &mut self.observers {
            obs.on_run_end(report);
        }
    }

    /// Tear down into the recyclable scratch parts.
    pub(crate) fn into_state(self) -> (AggScratch, RoundChannel) {
        (self.scratch, self.round_channel)
    }
}
