//! The [`Aggregator`] seam: from a K×N payload plane (plus the round's
//! channel realisation) to one aggregated model vector.
//!
//! The three built-in implementations wrap the kernels-layer entry points
//! the pre-redesign coordinator dispatched to through its `Aggregation`
//! enum — [`AnalogOta`] (`ota::analog::aggregate_plane_into`),
//! [`DigitalOrthogonal`] (`ota::digital::aggregate_plane_into`) and
//! [`IdealFedAvg`] (`fl::mean_plane_into`) — so default runs are
//! bit-identical per seed to the enum paths at every thread count, and the
//! zero-alloc steady-state contract holds through the trait object
//! (`rust/tests/alloc_counter.rs`, `rust/tests/sim.rs`).

use crate::channel::RoundChannel;
use crate::config::Aggregation;
use crate::fl;
use crate::kernels::{PackedPlane, PayloadPlane};
use crate::ota::{self, analog::OtaScratch, AggregateStats};
use crate::quant::Precision;
use crate::rng::Rng;

/// Which scratch buffer holds the round's aggregate (the old coordinator
/// `AggSlot`, now owned by the scratch itself so any aggregator can route
/// its output without copies).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
enum Slot {
    /// `AggScratch::ota.y_re` (the analog receive accumulator).
    Ota,
    /// `AggScratch::agg` (the plain output vector).
    #[default]
    Agg,
}

/// Server-side aggregation scratch arena: every buffer an aggregator may
/// need, allocated once per run and reused every round.  Borrow a buffer
/// through [`ota_mut`](Self::ota_mut) / [`agg_mut`](Self::agg_mut) — that
/// also marks it as the round's result slot for [`result`](Self::result).
#[derive(Debug, Default)]
pub struct AggScratch {
    ota: OtaScratch,
    agg: Vec<f32>,
    slot: Slot,
    /// Streaming-round bookkeeping: wire stats accumulated across shards
    /// (digital bits / channel uses), reset by `begin_into`.
    partial: AggregateStats,
    /// The streaming round's TOTAL participant count, set at `begin_into`
    /// (the `1/K` scale denominator for the mean-style aggregators).
    total_k: usize,
}

impl AggScratch {
    pub fn new() -> Self {
        AggScratch::default()
    }

    /// The analog-OTA accumulators; marks them as the result slot.
    pub fn ota_mut(&mut self) -> &mut OtaScratch {
        self.slot = Slot::Ota;
        &mut self.ota
    }

    /// The plain output vector; marks it as the result slot.  Custom
    /// aggregators resize/fill this and write their aggregate into it.
    pub fn agg_mut(&mut self) -> &mut Vec<f32> {
        self.slot = Slot::Agg;
        &mut self.agg
    }

    /// The aggregate the last `aggregate_into` produced (the MEAN vector).
    pub fn result(&self) -> &[f32] {
        match self.slot {
            Slot::Ota => &self.ota.y_re,
            Slot::Agg => &self.agg,
        }
    }
}

/// Everything an aggregator may consult beyond the payload plane itself.
pub struct AggCtx<'a> {
    /// This round's channel realisation.  Only drawn (and only meaningful)
    /// when the aggregator's [`Aggregator::needs_channel`] returns true.
    pub channel: &'a RoundChannel,
    /// Per-participant precision levels, aligned with the plane's rows.
    pub precisions: &'a [Precision],
    /// The server receiver-noise stream.
    pub noise_rng: &'a mut Rng,
    /// Chunk-parallelism width (1 = exact sequential path; any value is
    /// bit-identical per seed — kernels-layer determinism contract).
    pub threads: usize,
    /// Shard-aligned participation mask for straggler/dropout rounds:
    /// `included[r] == false` means row `r` of the shard belongs to an
    /// EXCLUDED client — its plane row must never be read (the client
    /// phase skipped it, the buffer holds stale data) and it contributes
    /// no signal, channel uses or bits.  `None` (the overwhelmingly
    /// common case) means every row transmits; aggregators must treat it
    /// exactly like an all-true mask, instruction for instruction.
    pub included: Option<&'a [bool]>,
}

/// One uplink architecture: superposes/averages the payload plane into the
/// scratch arena and reports diagnostics.
///
/// Contract: write the aggregated MEAN vector through `scratch.ota_mut()`
/// or `scratch.agg_mut()` (never both), allocate nothing once the scratch
/// is warm, and consume `ctx.noise_rng` deterministically (or not at all).
pub trait Aggregator {
    /// Aggregate the K×N plane; `scratch.result()` holds the mean vector
    /// afterwards (when `participants > 0`).
    fn aggregate_into(
        &mut self,
        plane: &PayloadPlane,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats;

    /// Whether the session should draw a channel realisation before
    /// calling [`aggregate_into`](Self::aggregate_into).  Returning false
    /// skips the draw AND its RNG consumption (the digital/ideal
    /// baselines, matching the pre-redesign round loop draw-for-draw).
    fn needs_channel(&self) -> bool {
        true
    }

    /// Whether this aggregator implements the STREAMING (sharded) round
    /// protocol: [`begin_into`](Self::begin_into) → N ×
    /// [`accumulate_into`](Self::accumulate_into) over consecutive slot
    /// ranges → [`finalize_into`](Self::finalize_into).
    ///
    /// Contract: a streamed round must produce BIT-IDENTICAL results to
    /// [`aggregate_into`](Self::aggregate_into) over the concatenated
    /// shards, for every shard partition — the round loop's
    /// shard-invariance guarantee rests on it
    /// (`rust/tests/shard_invariance.rs`).  Default `false`: the
    /// coordinator then materializes the whole K×N plane and rejects
    /// `shard_size < K`.
    fn supports_streaming(&self) -> bool {
        false
    }

    /// Start a streaming round of `total_k` payload rows of `n` elements.
    fn begin_into(&mut self, total_k: usize, n: usize, scratch: &mut AggScratch) {
        let _ = (total_k, n, scratch);
        unimplemented!("aggregator does not support streaming rounds")
    }

    /// Partial-participation variant of [`begin_into`](Self::begin_into):
    /// only `active_k ≤ total_k` clients will actually contribute rows
    /// this round (the rest are straggler/dropout exclusions, masked out
    /// of [`accumulate_into`](Self::accumulate_into) via
    /// [`AggCtx::included`]).  Mean-style aggregators must divide by
    /// `active_k` — the mean is over who TRANSMITTED, the paper's
    /// `1/K_active` semantics.  The default forwards to `begin_into`
    /// with `active_k` as the divisor-relevant count; aggregators whose
    /// divisor self-adjusts from the data (analog OTA's `active_total`)
    /// need no override.
    fn begin_partial_into(
        &mut self,
        total_k: usize,
        active_k: usize,
        n: usize,
        scratch: &mut AggScratch,
    ) {
        let _ = active_k;
        self.begin_into(total_k, n, scratch);
    }

    /// Fold one shard — rows `slot0 .. slot0 + shard.k()` of the round —
    /// into the accumulator state.  `ctx.precisions` holds the SHARD's
    /// precisions (aligned with its rows); `ctx.channel` the full round
    /// realisation (index it at `slot0 + row`).
    fn accumulate_into(
        &mut self,
        shard: &PayloadPlane,
        slot0: usize,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) {
        let _ = (shard, slot0, ctx, scratch);
        unimplemented!("aggregator does not support streaming rounds")
    }

    /// Finish the streaming round (noise/scale/diagnostics);
    /// `scratch.result()` holds the mean vector afterwards.
    fn finalize_into(
        &mut self,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        let _ = (ctx, scratch);
        unimplemented!("aggregator does not support streaming rounds")
    }

    /// Whether [`accumulate_packed_into`](Self::accumulate_packed_into)
    /// is implemented: the shard arrives BIT-PACKED at each row's
    /// assigned precision (`RunConfig.packed_planes`) and the aggregator
    /// decodes-and-accumulates without materializing f32 rows.
    ///
    /// Contract: a packed stream must be bit-identical to the f32 stream
    /// over the fake-quantized rows the packed rows decode to, for every
    /// shard partition (`rust/tests/shard_invariance.rs` pins the round
    /// loop both ways).  Default `false`: the coordinator then stages
    /// shards through the f32 plane.
    fn supports_packed(&self) -> bool {
        false
    }

    /// Packed-shard form of [`accumulate_into`](Self::accumulate_into):
    /// fold rows `slot0 .. slot0 + shard.k()`, decoding each row's codes
    /// inline.  Only called when
    /// [`supports_packed`](Self::supports_packed) returns true.
    fn accumulate_packed_into(
        &mut self,
        shard: &PackedPlane,
        slot0: usize,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) {
        let _ = (shard, slot0, ctx, scratch);
        unimplemented!("aggregator does not support packed shards")
    }

    /// Short architecture name for labels/reports ("ota", "digital", ...).
    fn name(&self) -> &'static str;
}

/// The paper's analog multi-precision OTA superposition (Alg. 1 steps
/// 3-4): decimal payloads through the channel gains, AWGN, 1/K_active.
pub struct AnalogOta;

impl Aggregator for AnalogOta {
    fn aggregate_into(
        &mut self,
        plane: &PayloadPlane,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        ota::analog::aggregate_plane_into(
            plane,
            ctx.channel,
            ctx.noise_rng,
            scratch.ota_mut(),
            ctx.threads,
        )
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn begin_into(&mut self, _total_k: usize, n: usize, scratch: &mut AggScratch) {
        ota::analog::begin_plane_into(n, scratch.ota_mut());
    }

    fn accumulate_into(
        &mut self,
        shard: &PayloadPlane,
        slot0: usize,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) {
        ota::analog::accumulate_plane_masked_into(
            shard,
            slot0,
            ctx.channel,
            ctx.included,
            scratch.ota_mut(),
            ctx.threads,
        );
    }

    fn finalize_into(
        &mut self,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        ota::analog::finalize_plane_into(
            ctx.channel,
            ctx.noise_rng,
            scratch.ota_mut(),
            ctx.threads,
        )
    }

    fn supports_packed(&self) -> bool {
        true
    }

    fn accumulate_packed_into(
        &mut self,
        shard: &PackedPlane,
        slot0: usize,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) {
        ota::analog::accumulate_packed_masked_into(
            shard,
            slot0,
            ctx.channel,
            ctx.included,
            scratch.ota_mut(),
            ctx.threads,
        );
    }

    fn name(&self) -> &'static str {
        "ota"
    }
}

/// Conventional digital orthogonal uplink: per-client encode at its
/// precision, error-free transport, server-side precision conversion,
/// average.  Needs no channel realisation.
pub struct DigitalOrthogonal;

impl Aggregator for DigitalOrthogonal {
    fn aggregate_into(
        &mut self,
        plane: &PayloadPlane,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        ota::digital::aggregate_plane_into(
            plane,
            ctx.precisions,
            scratch.agg_mut(),
            ctx.threads,
        )
    }

    fn needs_channel(&self) -> bool {
        false
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn begin_into(&mut self, total_k: usize, n: usize, scratch: &mut AggScratch) {
        scratch.total_k = total_k;
        scratch.partial = AggregateStats::default();
        let out = scratch.agg_mut();
        out.resize(n, 0.0);
        out.fill(0.0);
    }

    fn begin_partial_into(
        &mut self,
        _total_k: usize,
        active_k: usize,
        n: usize,
        scratch: &mut AggScratch,
    ) {
        // the 1/K scale (and the participants report) is over who
        // TRANSMITS — excluded clients never put bits on the uplink
        self.begin_into(active_k, n, scratch);
    }

    fn accumulate_into(
        &mut self,
        shard: &PayloadPlane,
        _slot0: usize,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) {
        scratch.slot = Slot::Agg;
        ota::digital::accumulate_plane_masked_into(
            shard,
            ctx.precisions,
            ctx.included,
            scratch.agg.as_mut_slice(),
            ctx.threads,
            &mut scratch.partial,
        );
    }

    fn supports_packed(&self) -> bool {
        true
    }

    fn accumulate_packed_into(
        &mut self,
        shard: &PackedPlane,
        _slot0: usize,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) {
        scratch.slot = Slot::Agg;
        ota::digital::accumulate_packed_masked_into(
            shard,
            ctx.precisions,
            ctx.included,
            scratch.agg.as_mut_slice(),
            ctx.threads,
            &mut scratch.partial,
        );
    }

    fn finalize_into(
        &mut self,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        scratch.slot = Slot::Agg;
        if scratch.total_k > 0 {
            crate::tensor::scale_par(
                &mut scratch.agg,
                1.0 / scratch.total_k as f32,
                ctx.threads,
            );
        }
        let mut stats = scratch.partial.clone();
        stats.participants = scratch.total_k;
        stats
    }

    fn name(&self) -> &'static str {
        "digital"
    }
}

/// Noise-free FedAvg oracle (Eq. 1) — upper bound / debugging.
pub struct IdealFedAvg;

impl Aggregator for IdealFedAvg {
    fn aggregate_into(
        &mut self,
        plane: &PayloadPlane,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        fl::mean_plane_into(plane, scratch.agg_mut(), ctx.threads);
        AggregateStats {
            participants: plane.k(),
            ..Default::default()
        }
    }

    fn needs_channel(&self) -> bool {
        false
    }

    fn supports_streaming(&self) -> bool {
        true
    }

    fn begin_into(&mut self, total_k: usize, n: usize, scratch: &mut AggScratch) {
        scratch.total_k = total_k;
        let out = scratch.agg_mut();
        out.resize(n, 0.0);
        out.fill(0.0);
    }

    fn begin_partial_into(
        &mut self,
        _total_k: usize,
        active_k: usize,
        n: usize,
        scratch: &mut AggScratch,
    ) {
        // FedAvg over who TRANSMITS: the divisor is the active count
        self.begin_into(active_k, n, scratch);
    }

    fn accumulate_into(
        &mut self,
        shard: &PayloadPlane,
        _slot0: usize,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) {
        if scratch.total_k == 0 {
            return;
        }
        // the 1/K weight is applied per contribution, exactly like the
        // one-shot `mean_plane_into` — which is what keeps any shard
        // partition bit-identical to the unsharded mean
        let f = 1.0f32 / scratch.total_k as f32;
        scratch.slot = Slot::Agg;
        fl::mean_plane_masked_accumulate(
            shard,
            f,
            ctx.included,
            scratch.agg.as_mut_slice(),
            ctx.threads,
        );
    }

    fn supports_packed(&self) -> bool {
        true
    }

    fn accumulate_packed_into(
        &mut self,
        shard: &PackedPlane,
        _slot0: usize,
        ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) {
        if scratch.total_k == 0 {
            return;
        }
        let f = 1.0f32 / scratch.total_k as f32;
        scratch.slot = Slot::Agg;
        fl::mean_packed_masked_accumulate(
            shard,
            f,
            ctx.included,
            scratch.agg.as_mut_slice(),
            ctx.threads,
        );
    }

    fn finalize_into(
        &mut self,
        _ctx: &mut AggCtx<'_>,
        scratch: &mut AggScratch,
    ) -> AggregateStats {
        scratch.slot = Slot::Agg;
        AggregateStats {
            participants: scratch.total_k,
            ..Default::default()
        }
    }

    fn name(&self) -> &'static str {
        "ideal"
    }
}

/// The built-in aggregator named by a config [`Aggregation`].
pub fn from_config(a: Aggregation) -> Box<dyn Aggregator> {
    match a {
        Aggregation::OtaAnalog => Box::new(AnalogOta),
        Aggregation::Digital => Box::new(DigitalOrthogonal),
        Aggregation::Ideal => Box::new(IdealFedAvg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_config_names_match_display() {
        for a in [Aggregation::OtaAnalog, Aggregation::Digital, Aggregation::Ideal] {
            assert_eq!(from_config(a).name(), a.to_string());
        }
    }

    #[test]
    fn scratch_slot_follows_last_borrow() {
        let mut s = AggScratch::new();
        s.agg_mut().extend_from_slice(&[1.0, 2.0]);
        assert_eq!(s.result(), &[1.0, 2.0]);
        s.ota_mut().y_re.push(9.0);
        assert_eq!(s.result(), &[9.0]);
    }
}
