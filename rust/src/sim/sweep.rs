//! The sweep driver: run a scheme × SNR × aggregator × channel-model ×
//! policy × fleet × shard-size config grid in ONE process, reusing one
//! runtime and one scratch arena across cells, and emit a consolidated
//! JSON report (`mpota sweep` on the CLI).
//!
//! Fleet scaling: channel-only cells select K = `clients_per_round`
//! participants per round from the cell's fleet (`RunConfig::selection`;
//! `sampled` = Floyd's O(K) sampler) and stream them through the
//! aggregator in `shard_size`-row shards, so a 100k- or 1M-client cell
//! runs in O(shard·payload_len + K) memory.  The `fleets` / `shard_sizes`
//! axes sweep both knobs; shard size never changes results (the
//! shard-invariance contract — `sharded_cells_match_unsharded_bit_for_bit`
//! and the CI byte-diff pin it).
//!
//! Two modes:
//!
//! * [`run_fl_sweep`] — full federated runs per cell (requires PJRT
//!   artifacts).  One `Rc<Runtime>` is shared by every cell so artifacts
//!   compile once, and the finished cell's [`Arena`] seeds the next
//!   cell's buffers.
//! * [`run_channel_sweep`] — aggregation-only cells (no training, no
//!   artifacts): synthetic payloads are fake-quantized per the cell's
//!   precision policy and pushed through the cell's channel model and
//!   aggregator, measuring aggregation MSE against the noise-free fleet
//!   mean.  Every cell re-derives the same RNG streams from the root
//!   seed, so cells see *paired* channel/payload realisations — the grid
//!   isolates the scheme/SNR/architecture effect.  This is the mode CI
//!   exercises.
//!
//! Cell isolation: every cell constructs a FRESH
//! [`crate::sim::ChannelModel`] and [`crate::sim::PrecisionPolicy`] from
//! its own config — stateful parts (AR(1)
//! fading memory, path-loss geometry, plateau counters) never leak
//! across cells, so enumerating the grid in a different order yields
//! bit-identical per-cell results (`cell_order_is_immaterial` pins
//! this).  Only inert *buffers* (the scratch arena) are recycled.
//!
//! Cell parallelism: channel-only cells are independent, so with
//! `RunConfig::workers > 1` they run concurrently on the persistent
//! [`crate::exec`] pool (bounded by `workers`, each task owning fresh
//! buffers) and fill their canonical grid slot — the consolidated report
//! is byte-identical to the serial run's regardless of completion order
//! (`parallel_sweep_matches_serial_cell_for_cell`; CI diffs the two
//! modulo per-cell wall-clock).  Full-FL cells run concurrently too when
//! a [`BackendFactory`] supplies each cell its own `TrainBackend` (every
//! pool task loads its own PJRT-free runtime and owns every mutable
//! part); without a factory they stay serial, sharing one PJRT runtime —
//! single-threaded by construction (`Rc`-based client) — with the client
//! phase still parallelized via `workers` inside each cell.
//!
//! Non-IID axes: `partitions`/`alphas` sweep the training-data partition
//! (`RunConfig::partition`/`alpha`).  They are full-FL axes — a
//! channel-only sweep trains nothing, so widening them there is a
//! config error.  When both axes sit at the base config's values the
//! grid JSON omits them, keeping channel-only reports byte-identical
//! across binary generations (the CI id-parity diff).
//!
//! Streaming: `SweepSpec::stream` (CLI `--stream`) appends every cell's
//! per-round records to one JSONL file, each line tagged with its cell's
//! coordinates.  One file means one writer, so streaming forces the
//! serial path for channel-only sweeps.

use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::channel::FadingKind;
use crate::config::{Aggregation, PartitionKind, PolicyKind, RunConfig};
use crate::fl::{self, Scheme};
use crate::json::Value;
use crate::kernels::{PackedPlane, PayloadPlane};
use crate::metrics::RoundRecord;
use crate::quant;
use crate::rng::Rng;
use crate::runtime::Runtime;
use crate::tensor;

use super::{
    aggregator, channel_model, policy, Arena, Experiment, PolicyCtx,
    RoundFeedback, Session,
};

/// One cell's grid coordinates, in canonical axis order: scheme, SNR,
/// aggregation, channel model, policy, fleet, shard size, deadline,
/// dropout probability, data partition, Dirichlet alpha.
type CellCoord<'a> = (
    &'a Scheme,
    f32,
    Aggregation,
    FadingKind,
    PolicyKind,
    usize,
    usize,
    f64,
    f64,
    PartitionKind,
    f64,
);

/// Per-cell training-backend constructor for parallel full-FL sweeps:
/// each pool task builds its OWN backend, so no `Sync` state is shared
/// across concurrently-running cells.  The factory must be deterministic
/// (same backend behaviour for every call) for the serial-vs-parallel
/// report parity to hold.
pub type BackendFactory =
    std::sync::Arc<dyn Fn() -> Box<dyn crate::exec::TrainBackend> + Send + Sync>;

/// A config grid: the base run crossed with schemes × SNRs × aggregators
/// × channel models × precision policies.
pub struct SweepSpec {
    /// Every cell starts from this config.
    pub base: RunConfig,
    /// Precision schemes to sweep (static policy per cell).
    pub schemes: Vec<Scheme>,
    /// Server receiver SNRs (dB) to sweep.
    pub snrs_db: Vec<f32>,
    /// Aggregation architectures to sweep.
    pub aggregations: Vec<Aggregation>,
    /// Channel models to sweep (each cell builds a FRESH instance from
    /// its config, so stateful models never share state across cells).
    pub channel_models: Vec<FadingKind>,
    /// Precision policies to sweep (fresh per cell, like the models).
    pub policies: Vec<PolicyKind>,
    /// Fleet sizes N to sweep (each cell sets `clients`; the base's
    /// `clients_per_round` is clamped to the cell's fleet).  Massive
    /// fleets pair naturally with `base.selection = Sampled` and a
    /// `shard_sizes` axis: per-round state stays O(K), round memory
    /// O(shard·payload_len).
    pub fleets: Vec<usize>,
    /// Streaming-shard sizes to sweep (each cell sets `shard_size`; `0` =
    /// one whole-round shard).  Results are bit-identical across this
    /// axis by the shard-invariance contract — sweeping it measures
    /// memory/wall-clock, and CI byte-diffs the reports to pin the
    /// contract end to end.
    pub shard_sizes: Vec<usize>,
    /// Round deadlines (seconds of virtual time) to sweep (each cell sets
    /// `deadline_s`; `0` = no deadline).  Non-zero values exclude
    /// straggling clients per the [`crate::sim::VirtualClock`] latency
    /// model — participation and MSE respond, the paired payload/channel
    /// realisations do not.
    pub deadlines: Vec<f64>,
    /// Per-round dropout probabilities to sweep (each cell sets
    /// `dropout_p`; `0` = nobody drops).  The drop process follows the
    /// base config's `dropout_model`/`dropout_burst`.
    pub dropouts: Vec<f64>,
    /// Training-data partitions to sweep (each cell sets `partition`).
    /// Full-FL axis: channel-only sweeps reject a widened partition grid.
    pub partitions: Vec<PartitionKind>,
    /// Dirichlet concentrations to sweep (each cell sets `alpha`; only
    /// read by dirichlet cells).  Full-FL axis, like `partitions`.
    pub alphas: Vec<f64>,
    /// Per-cell backend constructor: hands every full-FL cell its own
    /// `TrainBackend`, which unlocks concurrent fl-mode cells (bounded by
    /// `base.workers`, like the channel-only path).  `None` = the shared
    /// PJRT runtime, serial cells.
    pub backend_factory: Option<BackendFactory>,
    /// Payload length for the channel-only mode (full FL runs use the
    /// model's parameter count instead).
    pub payload_len: usize,
    /// Stream every cell's per-round records (JSONL, one shared file,
    /// lines tagged with the cell coordinates).  One file means one
    /// writer: streaming channel-only sweeps run serially.
    pub stream: Option<std::path::PathBuf>,
}

impl SweepSpec {
    /// A 1×…×1 grid over the base config; widen the axes from there.
    pub fn new(base: RunConfig) -> Self {
        SweepSpec {
            schemes: vec![base.scheme.clone()],
            snrs_db: vec![base.channel.snr_db],
            aggregations: vec![base.aggregation],
            channel_models: vec![base.channel.model],
            policies: vec![base.policy],
            fleets: vec![base.clients],
            shard_sizes: vec![base.shard_size],
            deadlines: vec![base.deadline_s],
            dropouts: vec![base.dropout_p],
            partitions: vec![base.partition],
            alphas: vec![base.alpha],
            backend_factory: None,
            payload_len: 4096,
            stream: None,
            base,
        }
    }

    /// Number of grid cells.
    pub fn grid_size(&self) -> usize {
        self.schemes.len()
            * self.snrs_db.len()
            * self.aggregations.len()
            * self.channel_models.len()
            * self.policies.len()
            * self.fleets.len()
            * self.shard_sizes.len()
            * self.deadlines.len()
            * self.dropouts.len()
            * self.partitions.len()
            * self.alphas.len()
    }

    /// True when the partition axes carry no information beyond the base
    /// config — the report's grid JSON then omits them entirely, keeping
    /// partition-free sweep reports byte-identical across binary
    /// generations (the CI id-parity diff pins this).
    fn partition_axes_trivial(&self) -> bool {
        self.partitions.as_slice() == [self.base.partition]
            && self.alphas.as_slice() == [self.base.alpha]
    }

    /// Reject grids whose axes a per-cell policy would silently ignore: a
    /// non-static precision policy never reads the cell's scheme, so a
    /// multi-scheme grid would emit identical results under different
    /// scheme labels.  Also pre-validates the channel knobs against every
    /// model on the `channel_models` axis, so a bad `--rho`/`--cell-radius`
    /// is a clean error up front instead of a panic inside a model
    /// constructor mid-sweep.
    fn validate(&self) -> Result<()> {
        if self.schemes.len() > 1 {
            if let Some(p) =
                self.policies.iter().find(|&&p| p != PolicyKind::Static)
            {
                bail!(
                    "policy '{p}' ignores the scheme; a multi-scheme sweep \
                     axis requires static-only policies"
                );
            }
        }
        for &model in &self.channel_models {
            let mut ch = self.base.channel.clone();
            ch.model = model;
            ch.validate()?;
        }
        for &fleet in &self.fleets {
            if fleet == 0 {
                bail!("fleet size must be positive");
            }
            // a static policy expands the scheme over the fleet — check
            // divisibility up front (modulo only: never materialize the
            // fleet-sized expansion here)
            if self.policies.iter().any(|&p| p == PolicyKind::Static) {
                for scheme in &self.schemes {
                    let g = scheme.groups().len();
                    if fleet % g != 0 {
                        bail!(
                            "fleet {fleet} does not divide into the {g} groups \
                             of scheme '{scheme}'"
                        );
                    }
                }
            }
        }
        for &dl in &self.deadlines {
            if !(dl >= 0.0 && dl.is_finite()) {
                bail!("deadline {dl} must be a finite non-negative number of seconds");
            }
        }
        for &dp in &self.dropouts {
            if !(0.0..1.0).contains(&dp) {
                bail!("dropout probability {dp} must be in [0, 1)");
            }
        }
        for &a in &self.alphas {
            if !(a > 0.0 && a.is_finite()) {
                bail!("alpha {a} must be positive and finite");
            }
        }
        if !self.partition_axes_trivial() {
            // Partition cells are convergence experiments: precision is
            // assigned over the K = clients_per_round SELECTED clients, so
            // a static scheme must divide K for every fleet on the grid —
            // caught here at spec-build time (the fleet % groups check
            // above covers only full-participation cells).
            if self.policies.iter().any(|&p| p == PolicyKind::Static) {
                for &fleet in &self.fleets {
                    let kk = self.base.clients_per_round.min(fleet);
                    for scheme in &self.schemes {
                        let g = scheme.groups().len();
                        if kk % g != 0 {
                            bail!(
                                "clients-per-round {kk} does not divide into \
                                 the {g} groups of scheme '{scheme}'"
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn cell_config(
        &self,
        scheme: &Scheme,
        snr_db: f32,
        agg: Aggregation,
        model: FadingKind,
        pol: PolicyKind,
        fleet: usize,
        shard: usize,
        deadline: f64,
        dropout: f64,
        partition: PartitionKind,
        alpha: f64,
    ) -> RunConfig {
        let mut cfg = self.base.clone();
        cfg.scheme = scheme.clone();
        cfg.channel.snr_db = snr_db;
        cfg.aggregation = agg;
        cfg.channel.model = model;
        cfg.policy = pol;
        cfg.clients = fleet;
        cfg.clients_per_round = self.base.clients_per_round.min(fleet);
        cfg.shard_size = shard;
        cfg.deadline_s = deadline;
        cfg.dropout_p = dropout;
        cfg.partition = partition;
        cfg.alpha = alpha;
        cfg
    }

    /// Enumerate the grid in canonical axis order (schemes outermost,
    /// Dirichlet alphas innermost — trivial partition axes therefore
    /// preserve the historical cell order exactly).
    #[allow(clippy::type_complexity)]
    fn cells_iter(&self) -> Vec<CellCoord<'_>> {
        let mut cells = Vec::with_capacity(self.grid_size());
        for scheme in &self.schemes {
            for &snr in &self.snrs_db {
                for &agg in &self.aggregations {
                    for &model in &self.channel_models {
                        for &pol in &self.policies {
                            for &fleet in &self.fleets {
                                for &shard in &self.shard_sizes {
                                    for &dl in &self.deadlines {
                                        for &dp in &self.dropouts {
                                            for &part in &self.partitions {
                                                for &al in &self.alphas {
                                                    cells.push((
                                                        scheme, snr, agg,
                                                        model, pol, fleet,
                                                        shard, dl, dp, part,
                                                        al,
                                                    ));
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        cells
    }

    fn grid_json(&self) -> Value {
        let mut g = Value::object();
        g.set(
            "schemes",
            Value::Array(
                self.schemes.iter().map(|s| Value::Str(s.to_string())).collect(),
            ),
        );
        g.set(
            "snrs_db",
            Value::Array(
                self.snrs_db.iter().map(|&s| Value::Num(s as f64)).collect(),
            ),
        );
        g.set(
            "aggregations",
            Value::Array(
                self.aggregations
                    .iter()
                    .map(|a| Value::Str(a.to_string()))
                    .collect(),
            ),
        );
        g.set(
            "channel_models",
            Value::Array(
                self.channel_models
                    .iter()
                    .map(|m| Value::Str(m.to_string()))
                    .collect(),
            ),
        );
        g.set(
            "policies",
            Value::Array(
                self.policies.iter().map(|p| Value::Str(p.to_string())).collect(),
            ),
        );
        g.set(
            "fleets",
            Value::Array(self.fleets.iter().map(|&n| Value::Num(n as f64)).collect()),
        );
        g.set(
            "shard_sizes",
            Value::Array(
                self.shard_sizes.iter().map(|&s| Value::Num(s as f64)).collect(),
            ),
        );
        g.set(
            "deadlines",
            Value::Array(self.deadlines.iter().map(|&d| Value::Num(d)).collect()),
        );
        g.set(
            "dropouts",
            Value::Array(self.dropouts.iter().map(|&d| Value::Num(d)).collect()),
        );
        // emitted ONLY when non-trivial: partition-free reports stay
        // byte-identical to earlier binary generations (CI id-parity)
        if !self.partition_axes_trivial() {
            g.set(
                "partitions",
                Value::Array(
                    self.partitions
                        .iter()
                        .map(|p| Value::Str(p.to_string()))
                        .collect(),
                ),
            );
            g.set(
                "alphas",
                Value::Array(self.alphas.iter().map(|&a| Value::Num(a)).collect()),
            );
        }
        g
    }
}

/// Consolidated sweep outcome: one JSON document with the grid axes, one
/// entry per cell, and timing.
pub struct SweepReport {
    pub json: Value,
}

impl SweepReport {
    /// Number of cell entries in the report.
    pub fn cells(&self) -> usize {
        self.json
            .get("cells")
            .and_then(|c| c.as_array().ok())
            .map(|a| a.len())
            .unwrap_or(0)
    }

    pub fn to_string_pretty(&self) -> String {
        self.json.to_string_pretty()
    }

    /// Write the report (pretty JSON) to `path`, creating parent dirs.
    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(path, self.to_string_pretty())
            .with_context(|| format!("writing {}", path.display()))
    }
}

/// Full federated sweep: one `Experiment` per cell over a shared runtime
/// and a recycled arena.  Requires PJRT artifacts.
pub fn run_fl_sweep(spec: &SweepSpec) -> Result<SweepReport> {
    let runtime = Rc::new(Runtime::load(&spec.base.artifacts_dir)?);
    run_fl_sweep_on(spec, runtime)
}

/// [`run_fl_sweep`] over an already-loaded runtime (callers that also use
/// the runtime for pretraining or warm pools pass it in here).
///
/// With a [`BackendFactory`] (`spec.backend_factory`) and `workers > 1`,
/// independent cells run CONCURRENTLY on the exec pool: each pool task
/// loads its own runtime, builds its own backend from the factory, and
/// fills its canonical grid slot — the consolidated report is identical
/// to the serial run's modulo per-cell wall-clock (pinned by
/// `parallel_fl_sweep_matches_serial` and the CI byte-diff).  Without a
/// factory the cells stay serial: they share ONE PJRT runtime, which is
/// single-threaded by construction (`Rc`-based client); `workers` still
/// parallelizes the client phase inside each cell.
pub fn run_fl_sweep_on(spec: &SweepSpec, runtime: Rc<Runtime>) -> Result<SweepReport> {
    spec.validate()?;
    let t0 = Instant::now();
    let coords = spec.cells_iter();
    let bound = spec.base.workers.min(coords.len()).max(1);
    let parallel = spec.backend_factory.is_some()
        && bound > 1
        && spec.stream.is_none()
        && crate::exec::pool().max_workers() > 0
        && !crate::exec::must_inline();

    let cells: Vec<Value> = if parallel {
        let slots: Vec<std::sync::OnceLock<Result<Value>>> =
            (0..coords.len()).map(|_| std::sync::OnceLock::new()).collect();
        let task = |i: usize| {
            let r = fl_cell(spec, coords[i], None, Arena::default(), None)
                .map(|(v, _)| v);
            let _ = slots[i].set(r);
        };
        crate::exec::pool().broadcast_limit(coords.len(), bound, &task);
        let mut out = Vec::with_capacity(slots.len());
        // canonical grid order regardless of completion order; the first
        // failing cell (in grid order) propagates, like the serial path
        for s in slots {
            out.push(s.into_inner().expect("sweep cell completed")?);
        }
        out
    } else {
        let mut arena = Arena::default();
        let mut out = Vec::with_capacity(coords.len());
        for (i, coord) in coords.into_iter().enumerate() {
            let stream = match &spec.stream {
                // one shared JSONL file: first cell truncates, the rest
                // append
                Some(path) if i == 0 => Some(crate::sim::JsonlStreamer::create(path)?),
                Some(path) => Some(crate::sim::JsonlStreamer::append(path)?),
                None => None,
            };
            // factory cells build their runtime/backend exactly like the
            // parallel path (fresh per cell — byte parity by
            // construction); default cells share the caller's runtime and
            // recycle the arena
            let shared = if spec.backend_factory.is_some() {
                None
            } else {
                Some(runtime.clone())
            };
            let (v, a) = fl_cell(spec, coord, shared, arena, stream)?;
            arena = a;
            out.push(v);
        }
        out
    };
    Ok(SweepReport { json: consolidated(spec, "fl", cells, t0.elapsed().as_secs_f64()) })
}

/// One full-FL grid cell: a fresh [`Experiment`] from the cell config.
/// `shared_runtime` is the serial path's single PJRT runtime; `None`
/// loads a fresh runtime from the cell config (cheap and PJRT-free under
/// an injected backend — the per-cell-backend path, safe on any pool
/// worker).  Returns the report entry plus the recyclable arena.
fn fl_cell(
    spec: &SweepSpec,
    coord: CellCoord<'_>,
    shared_runtime: Option<Rc<Runtime>>,
    arena: Arena,
    stream: Option<crate::sim::JsonlStreamer>,
) -> Result<(Value, Arena)> {
    let (scheme, snr, agg, model, pol, fleet, shard, dl, dp, part, al) = coord;
    let cfg = spec
        .cell_config(scheme, snr, agg, model, pol, fleet, shard, dl, dp, part, al);
    let cell_t0 = Instant::now();
    let runtime = match shared_runtime {
        Some(rt) => rt,
        None => Rc::new(Runtime::load(&cfg.artifacts_dir)?),
    };
    // the builder constructs fresh channel-model/policy instances from
    // this cell's config — no mutable state crosses cell boundaries
    let mut builder = Experiment::builder(cfg).runtime(runtime).arena(arena);
    if let Some(factory) = &spec.backend_factory {
        builder = builder.backend_boxed(factory());
    }
    if let Some(streamer) = stream {
        builder = builder.observe(streamer.with_label(cell_label(
            scheme, snr, agg, model, pol, fleet, shard, dl, dp, part, al,
        )));
    }
    let mut exp = builder.build()?;
    let report = exp.run()?;
    let arena = exp.into_arena();

    let mean_mse = mean_of(report.log.rounds.iter().map(|r| r.ota_mse));
    let mut c = Value::object();
    c.set("scheme", Value::Str(scheme.to_string()));
    c.set("snr_db", Value::Num(snr as f64));
    c.set("aggregation", Value::Str(agg.to_string()));
    c.set("channel_model", Value::Str(model.to_string()));
    c.set("policy", Value::Str(pol.to_string()));
    c.set("clients", Value::Num(fleet as f64));
    c.set("shard_size", Value::Num(shard as f64));
    c.set("deadline_s", Value::Num(dl));
    c.set("dropout_p", Value::Num(dp));
    c.set("partition", Value::Str(part.to_string()));
    c.set("alpha", Value::Num(al));
    c.set("label", Value::Str(report.label.clone()));
    c.set("final_accuracy", Value::Num(report.final_accuracy));
    c.set("final_loss", Value::Num(report.final_loss));
    c.set("best_accuracy", Value::Num(report.log.best_accuracy()));
    c.set(
        "rounds_to_90",
        match report.rounds_to_90 {
            Some(r) => Value::Num(r as f64),
            None => Value::Null,
        },
    );
    c.set("mean_ota_mse", Value::Num(mean_mse));
    c.set("energy_j", Value::Num(report.energy.actual_joules));
    c.set(
        "energy_saving_vs_32_pct",
        Value::Num(report.energy.saving_vs_32()),
    );
    c.set("wall_secs", Value::Num(cell_t0.elapsed().as_secs_f64()));
    Ok((c, arena))
}

/// Per-cell scratch for the channel-only sweep — recycled across cells in
/// the serial path, fresh per pool task in the parallel path.  Sized
/// O(shard·payload_len + K), never O(fleet): `selected`/`assigned` hold
/// the round's K participants and `plane` one shard of payloads.
struct CellBufs {
    agg: super::AggScratch,
    channel: crate::channel::RoundChannel,
    plane: PayloadPlane,
    /// Second plane for the pipelined cell (`pipeline_depth > 0`):
    /// generation of the next super-shard overlaps superposition of the
    /// previous one, mirroring the coordinator's round engine.
    plane2: PayloadPlane,
    /// Bit-packed transport twins of `plane`/`plane2`
    /// (`RunConfig::packed_planes`): each super-shard's rows packed at
    /// their assigned precision, folded by the packed fused kernels.
    packed: PackedPlane,
    packed2: PackedPlane,
    selected: Vec<usize>,
    assigned: Vec<crate::quant::Precision>,
    /// Round-slot participation mask (deadline/dropout exclusion).
    included: Vec<bool>,
    ideal: Vec<f32>,
    /// Per-participant |h| for the policy feedback (profiling planner).
    gains: Vec<f32>,
    /// All-zero f64 scratch passed as the feedback's energy AND loss
    /// slices (channel-only cells train nothing and spend nothing).
    zeros: Vec<f64>,
}

impl Default for CellBufs {
    fn default() -> Self {
        CellBufs {
            agg: super::AggScratch::default(),
            channel: crate::channel::RoundChannel::empty(),
            plane: PayloadPlane::new(),
            plane2: PayloadPlane::new(),
            packed: PackedPlane::new(),
            packed2: PackedPlane::new(),
            selected: Vec::new(),
            assigned: Vec::new(),
            included: Vec::new(),
            ideal: Vec::new(),
            gains: Vec::new(),
            zeros: Vec::new(),
        }
    }
}

/// Generate one super-shard of synthetic payloads (rows `lo..hi` of the
/// round) into `plane` and fold the included rows into the running ideal
/// mean.  Payloads are drawn for EVERY slot — excluded ones too — so the
/// payload stream stays paired across the deadline/dropout axes; the
/// exclusion shows up only through the mask.
///
/// Transport staging: with `packed = None` the rows are fake-quantized in
/// place (the f32 transport form); with `Some` the rows stay RAW and the
/// packed plane stores the transmission codes instead — which decode to
/// `fake_quant(row)` bit for bit, so both forms feed the ideal mean (and
/// the aggregator) identical per-element contributions in identical order.
#[allow(clippy::too_many_arguments)]
fn gen_super_shard(
    plane: &mut PayloadPlane,
    packed: Option<&mut PackedPlane>,
    lo: usize,
    hi: usize,
    n: usize,
    rng: &mut Rng,
    assigned: &[crate::quant::Precision],
    included: &[bool],
    mask_on: bool,
    f: f32,
    ideal: &mut [f32],
    threads: usize,
) {
    plane.reset(hi - lo, n);
    match packed {
        None => {
            for r in 0..(hi - lo) {
                let row = plane.row_mut(r);
                rng.fill_normal(row, 0.0, 1.0);
                quant::fake_quant_inplace(row, assigned[lo + r]);
            }
            fl::mean_plane_masked_accumulate(
                plane,
                f,
                if mask_on { Some(&included[lo..hi]) } else { None },
                ideal,
                threads,
            );
        }
        Some(packed) => {
            packed.reset(&assigned[lo..hi], n);
            for r in 0..(hi - lo) {
                let row = plane.row_mut(r);
                rng.fill_normal(row, 0.0, 1.0);
                packed.pack_row(r, row);
            }
            fl::mean_packed_masked_accumulate(
                packed,
                f,
                if mask_on { Some(&included[lo..hi]) } else { None },
                ideal,
                threads,
            );
        }
    }
}

/// Human-readable cell coordinates (report summaries, stream labels).
/// Includes every grid axis — cells differing only in fleet or shard
/// size must still tag their streamed JSONL rows distinguishably.  The
/// deadline/dropout suffix appears ONLY when the cell actually excludes
/// clients (non-zero knobs), and the partition suffix ONLY for non-IID
/// cells, so historical sweeps keep their label shape byte for byte.
#[allow(clippy::too_many_arguments)]
fn cell_label(
    scheme: &Scheme,
    snr: f32,
    agg: Aggregation,
    model: FadingKind,
    pol: PolicyKind,
    fleet: usize,
    shard: usize,
    deadline: f64,
    dropout: f64,
    partition: PartitionKind,
    alpha: f64,
) -> String {
    let mut label = format!("{scheme}@{snr}dB@{agg}@{model}/{pol}@n{fleet}/s{shard}");
    if deadline > 0.0 || dropout > 0.0 {
        label.push_str(&format!("@dl{deadline}@dp{dropout}"));
    }
    if partition != PartitionKind::Iid {
        label.push_str(&format!("@{partition}(a{alpha})"));
    }
    label
}

/// One channel-only grid cell: synthetic payloads through a FRESH policy,
/// channel model and aggregator built from the cell's config.  Every cell
/// re-derives the same RNG streams from the root seed (paired
/// realisations), touches nothing outside `bufs`, and is therefore safe
/// to run on any pool worker — results depend only on the cell config.
///
/// Robustness axes: a non-zero `deadline`/`dropout` builds a fresh
/// [`crate::sim::VirtualClock`] from the cell config and excludes the
/// straggling/dropped slots each round — exactly the coordinator's
/// protocol: exclusion decided up front from a dedicated `"sweep-straggler"`
/// stream (consumed only when enabled), masked accumulation, divisor over
/// the clients that transmit.  With `pipeline_depth > 0` the cell also
/// mirrors the pipelined round engine: each step is one two-task pool
/// dispatch overlapping the previous super-shard's superposition with the
/// next one's payload generation — bit-identical to the serial loop, which
/// the pipelined-vs-serial report diff pins in CI.
///
/// Massive-fleet shape: the round selects K = `clients_per_round`
/// participants from the cell's N-client fleet (`cfg.selection`; Floyd's
/// `sampled` keeps selection state O(K)) and streams them through the
/// aggregator `shard_size` at a time — per-round state is O(shard·n + K)
/// regardless of N, and results are bit-identical across shard sizes
/// (shard-invariance contract; CI byte-diffs sharded vs unsharded
/// reports).  With K == N and no shard cap this reproduces the historical
/// whole-fleet cell draw-for-draw.
#[allow(clippy::too_many_arguments)]
fn channel_cell(
    spec: &SweepSpec,
    scheme: &Scheme,
    snr: f32,
    agg: Aggregation,
    model: FadingKind,
    polkind: PolicyKind,
    fleet: usize,
    shard_size: usize,
    deadline: f64,
    dropout: f64,
    bufs: &mut CellBufs,
    mut stream: Option<&mut crate::sim::JsonlStreamer>,
) -> Result<Value> {
    let base = &spec.base;
    let n = spec.payload_len;
    let rounds = base.rounds;
    // mpota-lint: allow(R4): each sweep cell reseeds from the sweep's base seed by design
    let root = Rng::seed_from(base.seed);
    // channel-only cells never touch training data, so the partition
    // coords are pinned to the base config (trivial axes by validation)
    let cfg = spec.cell_config(
        scheme, snr, agg, model, polkind, fleet, shard_size, deadline, dropout,
        spec.base.partition, spec.base.alpha,
    );
    let clients = cfg.clients;
    let selection =
        fl::Selection::from_config(cfg.selection, clients, cfg.clients_per_round);
    let cell_t0 = Instant::now();
    // identical streams per cell => paired realisations; the channel
    // model and policy are FRESH instances (any fading memory,
    // geometry or plateau state starts clean for every cell)
    let mut payload_rng = root.stream("sweep-payload");
    let mut select_rng = root.stream("sweep-select");
    // derived unconditionally (stream derivation consumes nothing from
    // the root), consumed only when a deadline/dropout policy is active
    let mut straggler_rng = root.stream("sweep-straggler");
    let mut straggler = crate::sim::deadline::from_config(&cfg);
    let mask_on = straggler.is_some();
    let mut session = Session::with_state(
        channel_model::from_config(&cfg.channel),
        aggregator::from_config(cfg.aggregation),
        root.stream("sweep-channel"),
        root.stream("sweep-noise"),
        cfg.threads,
        std::mem::take(&mut bufs.agg),
        std::mem::take(&mut bufs.channel),
    );
    anyhow::ensure!(
        session.supports_streaming(),
        "channel-only cells require a streaming aggregator"
    );
    // packed transport: stage each super-shard as a bit-packed plane and
    // fold it through the packed fused kernels.  Bit-identical to the f32
    // staging (decode == fake_quant per element), so the report diff in
    // CI pins packed-on vs packed-off byte for byte modulo wall_secs.
    let packed_on = cfg.packed_planes && session.supports_packed();
    let mut pol = policy::from_config(cfg.policy, &cfg);
    let pool = crate::exec::pool();
    // mirror the coordinator's pipelined-engine gate (built-in
    // aggregators only here, by construction)
    let pipelined = cfg.pipeline_depth > 0
        && pool.max_workers() > 0
        && !crate::exec::must_inline();

    let mut mse_sum = 0.0f64;
    let mut part_sum = 0usize;
    let mut excluded_sum = 0usize;
    let mut channel_uses = 0u64;
    let mut bits = 0u64;
    let mut lost_rounds = 0usize;
    // feedback loop for reactive policies: carry a synthetic record of
    // the previous aggregation round (no training here, so the
    // loss/energy fields stay at their defaults — loss-plateau then
    // walks its ladder on the stalled loss, energy-budget stays put)
    let mut prev: Option<RoundRecord> = None;
    for t in 1..=rounds {
        selection.select_into(clients, t, &mut select_rng, &mut bufs.selected);
        let kk = bufs.selected.len();
        pol.assign_selected_into(
            &PolicyCtx {
                round: t,
                clients,
                snr_db: cfg.channel.snr_db,
                prev: prev.as_ref(),
            },
            &bufs.selected,
            &mut bufs.assigned,
        )?;
        // deadline/dropout exclusion: decided up front per round, then
        // inverted into the slot inclusion mask the aggregators consume
        bufs.included.clear();
        bufs.included.resize(kk, !mask_on);
        let mut active_k = kk;
        if let Some(policy) = straggler.as_mut() {
            policy.exclude_into(
                &crate::sim::DeadlineCtx {
                    round: t,
                    selected: &bufs.selected,
                    precisions: &bufs.assigned,
                },
                &mut straggler_rng,
                &mut bufs.included,
            );
            active_k = 0;
            for v in bufs.included.iter_mut() {
                *v = !*v;
                active_k += *v as usize;
            }
        }
        excluded_sum += kk - active_k;
        let shard = cfg.shard_len(kk);
        // the noise-free TRANSMITTING-participant mean, accumulated shard
        // by shard with the SAME per-contribution 1/active_k weighting as
        // the aggregator's divisor — bit-identical at every shard size
        bufs.ideal.resize(n, 0.0);
        bufs.ideal.fill(0.0);
        let f = if active_k > 0 { 1.0f32 / active_k as f32 } else { 0.0 };
        // identity-aware draw: stateful channel models (gauss-markov
        // fading memory, path-loss geometry) follow the SELECTED client
        // ids, not the round slots — same RNG consumption either way
        session.begin_aggregate_partial_for(t, &bufs.selected, active_k, n);
        if pipelined {
            // mirror the coordinator's pipelined round engine: each step
            // is ONE two-task dispatch — task 0 superposes the previous
            // super-shard (sole Session toucher), task 1 generates the
            // next one into the other plane.  Payload draws and
            // accumulation order are identical to the serial loop, so the
            // trajectories are bit-identical (pinned by tests + the CI
            // report byte-diff).
            let step = shard
                .saturating_mul(cfg.pipeline_depth)
                .min(kk)
                .max(1);
            let CellBufs {
                plane, plane2, packed, packed2, assigned, included, ideal, ..
            } = &mut *bufs;
            let threads = cfg.threads;
            // first super-shard generates alone (nothing to overlap yet)
            let mut prev_hi = step.min(kk);
            gen_super_shard(
                plane,
                if packed_on { Some(&mut *packed) } else { None },
                0, prev_hi, n, &mut payload_rng, assigned, included,
                mask_on, f, ideal, threads,
            );
            let mut prev_lo = 0usize;
            let mut cur_in_b = true; // next generation targets plane2
            while prev_hi < kk {
                let cur_lo = prev_hi;
                let cur_hi = (cur_lo + step).min(kk);
                let (cur_plane, cur_packed, prev_plane, prev_packed): (
                    &mut PayloadPlane,
                    &mut PackedPlane,
                    &PayloadPlane,
                    &PackedPlane,
                ) = if cur_in_b {
                    (&mut *plane2, &mut *packed2, &*plane, &*packed)
                } else {
                    (&mut *plane, &mut *packed, &*plane2, &*packed2)
                };
                let prev_prec = &assigned[prev_lo..prev_hi];
                let prev_mask =
                    if mask_on { Some(&included[prev_lo..prev_hi]) } else { None };
                let session_ptr = crate::exec::SendMutPtr::from_mut(&mut session);
                let plane_ptr = crate::exec::SendMutPtr::from_mut(cur_plane);
                let packed_ptr = crate::exec::SendMutPtr::from_mut(cur_packed);
                let rng_ptr = crate::exec::SendMutPtr::from_mut(&mut payload_rng);
                let ideal_ptr = crate::exec::SendMutPtr::from_mut(ideal);
                let assigned_ref: &[crate::quant::Precision] = assigned.as_slice();
                let included_ref: &[bool] = included.as_slice();
                let task = |w: usize| {
                    if w == 0 {
                        // SAFETY: sole Session toucher of this dispatch;
                        // the borrow outlives the blocking broadcast.
                        let session = unsafe { session_ptr.get() };
                        if packed_on {
                            session.accumulate_packed_shard_masked(
                                prev_packed, prev_lo, prev_prec, prev_mask,
                            );
                        } else {
                            session.accumulate_shard_masked(
                                prev_plane, prev_lo, prev_prec, prev_mask,
                            );
                        }
                    } else {
                        // SAFETY: sole toucher of the generation-side
                        // buffers (cur plane + its packed twin, payload
                        // RNG, ideal) — the superpose task reads only the
                        // OTHER plane pair.
                        let cur = unsafe { plane_ptr.get() };
                        let rng = unsafe { rng_ptr.get() };
                        let ideal = unsafe { ideal_ptr.get() };
                        let curp = if packed_on {
                            // SAFETY: same claim as above — generation
                            // side owns the current packed plane.
                            Some(unsafe { packed_ptr.get() })
                        } else {
                            None
                        };
                        gen_super_shard(
                            cur, curp, cur_lo, cur_hi, n, rng, assigned_ref,
                            included_ref, mask_on, f, ideal, threads,
                        );
                    }
                };
                pool.broadcast(2, &task);
                // super-shard boundary: the step dispatch retired, so its
                // session/plane/rng claims must be gone (debug registry;
                // trivially true when this cell runs nested in a sweep
                // worker, where claims belong to the outer dispatch)
                crate::exec::assert_quiescent();
                prev_lo = cur_lo;
                prev_hi = cur_hi;
                cur_in_b = !cur_in_b;
            }
            // drain: the last generated super-shard superposes serially
            let (last_plane, last_packed): (&PayloadPlane, &PackedPlane) =
                if cur_in_b { (&*plane, &*packed) } else { (&*plane2, &*packed2) };
            if packed_on {
                session.accumulate_packed_shard_masked(
                    last_packed,
                    prev_lo,
                    &assigned[prev_lo..prev_hi],
                    if mask_on { Some(&included[prev_lo..prev_hi]) } else { None },
                );
            } else {
                session.accumulate_shard_masked(
                    last_plane,
                    prev_lo,
                    &assigned[prev_lo..prev_hi],
                    if mask_on { Some(&included[prev_lo..prev_hi]) } else { None },
                );
            }
        } else {
            let mut lo = 0usize;
            while lo < kk {
                let hi = (lo + shard).min(kk);
                gen_super_shard(
                    &mut bufs.plane,
                    if packed_on { Some(&mut bufs.packed) } else { None },
                    lo, hi, n, &mut payload_rng,
                    &bufs.assigned, &bufs.included, mask_on, f,
                    &mut bufs.ideal, cfg.threads,
                );
                if packed_on {
                    session.accumulate_packed_shard_masked(
                        &bufs.packed,
                        lo,
                        &bufs.assigned[lo..hi],
                        if mask_on { Some(&bufs.included[lo..hi]) } else { None },
                    );
                } else {
                    session.accumulate_shard_masked(
                        &bufs.plane,
                        lo,
                        &bufs.assigned[lo..hi],
                        if mask_on { Some(&bufs.included[lo..hi]) } else { None },
                    );
                }
                lo = hi;
            }
        }
        let stats = session.finalize_aggregate(t, &bufs.assigned);
        // round boundary for the overlap registry (debug builds only)
        crate::exec::assert_quiescent();
        // per-round policy feedback, keyed by the selected identities:
        // |h| from the round's realisation when one was drawn; energy and
        // loss stay zero (channel-only cells train nothing).  The default
        // policies no-op; the profiling planner accumulates its per-id
        // channel history from exactly this stream.
        {
            let ch = session.channel();
            let have_ch = session.needs_channel() && ch.clients.len() == kk;
            bufs.gains.clear();
            for slot in 0..kk {
                bufs.gains
                    .push(if have_ch { ch.clients[slot].h.abs() } else { 1.0 });
            }
            bufs.zeros.clear();
            bufs.zeros.resize(kk, 0.0);
            pol.observe_feedback(&RoundFeedback {
                round: t,
                ids: &bufs.selected,
                gains: &bufs.gains,
                energy_j: &bufs.zeros,
                losses: &bufs.zeros,
            });
        }
        if stats.participants > 0 {
            mse_sum += tensor::mse(session.result(), &bufs.ideal);
        } else {
            // fully-silenced round: total loss, not 0-MSE —
            // excluded from the mean and counted separately
            lost_rounds += 1;
        }
        part_sum += stats.participants;
        channel_uses += stats.channel_uses;
        bits += stats.bits_transmitted;
        let rec = RoundRecord {
            round: t,
            participants: stats.participants,
            ota_mse: stats.mse_vs_ideal,
            // the synthetic loss (0.0) counts as a fresh observation
            // so loss-plateau exercises its ladder in channel-only
            // mode; energy stays 0, so energy-budget stays put
            evaluated: true,
            ..Default::default()
        };
        if let Some(s) = stream.as_mut() {
            s.push(&rec);
        }
        prev = Some(rec);
    }

    let mut c = Value::object();
    c.set("scheme", Value::Str(scheme.to_string()));
    c.set("snr_db", Value::Num(snr as f64));
    c.set("aggregation", Value::Str(agg.to_string()));
    c.set("channel_model", Value::Str(model.to_string()));
    c.set("policy", Value::Str(polkind.to_string()));
    c.set("clients", Value::Num(clients as f64));
    c.set("clients_per_round", Value::Num(cfg.clients_per_round as f64));
    c.set("shard_size", Value::Num(cfg.shard_size as f64));
    c.set("deadline_s", Value::Num(deadline));
    c.set("dropout_p", Value::Num(dropout));
    c.set("rounds", Value::Num(rounds as f64));
    let delivered = rounds - lost_rounds;
    c.set(
        "mean_mse_vs_ideal",
        if delivered > 0 {
            Value::Num(mse_sum / delivered as f64)
        } else {
            Value::Null // every round lost: no MSE to report
        },
    );
    c.set("lost_rounds", Value::Num(lost_rounds as f64));
    c.set(
        "mean_participants",
        Value::Num(part_sum as f64 / rounds as f64),
    );
    c.set(
        "mean_excluded",
        Value::Num(excluded_sum as f64 / rounds as f64),
    );
    c.set(
        "channel_uses_per_round",
        Value::Num(channel_uses as f64 / rounds as f64),
    );
    c.set("bits_per_round", Value::Num(bits as f64 / rounds as f64));
    c.set("wall_secs", Value::Num(cell_t0.elapsed().as_secs_f64()));

    let (a, ch) = session.into_state();
    bufs.agg = a;
    bufs.channel = ch;
    Ok(c)
}

/// Aggregation-only sweep: no training, no artifacts — synthetic payloads
/// through the cell's policy, channel model and aggregator.  Rows hold
/// the fake-quantized decimal payloads (what analog clients transmit);
/// the digital baseline re-encodes them for transport.
///
/// With `spec.base.workers > 1`, independent cells run CONCURRENTLY on
/// the exec pool (bounded by `workers`); each task owns fresh buffers and
/// fills its canonical grid slot, so the consolidated report is identical
/// to the serial run's (up to per-cell wall-clock).  Streaming
/// (`spec.stream`) shares one JSONL writer and therefore runs serially.
pub fn run_channel_sweep(spec: &SweepSpec) -> Result<SweepReport> {
    spec.validate()?;
    if !spec.partition_axes_trivial() {
        bail!(
            "partition/alpha axes sweep the training data, which \
             channel-only cells never touch; use an fl-mode sweep"
        );
    }
    let t0 = Instant::now();
    let coords = spec.cells_iter();
    let bound = spec.base.workers.min(coords.len()).max(1);
    let parallel = bound > 1
        && spec.stream.is_none()
        && crate::exec::pool().max_workers() > 0
        && !crate::exec::must_inline();

    let cells: Vec<Value> = if parallel {
        let slots: Vec<std::sync::OnceLock<Result<Value>>> =
            (0..coords.len()).map(|_| std::sync::OnceLock::new()).collect();
        let task = |i: usize| {
            let (scheme, snr, agg, model, pol, fleet, shard, dl, dp, _, _) =
                coords[i];
            let mut bufs = CellBufs::default();
            let r = channel_cell(
                spec, scheme, snr, agg, model, pol, fleet, shard, dl, dp,
                &mut bufs, None,
            );
            let _ = slots[i].set(r);
        };
        crate::exec::pool().broadcast_limit(coords.len(), bound, &task);
        let mut out = Vec::with_capacity(slots.len());
        // canonical grid order regardless of completion order; the first
        // failing cell (in grid order) propagates, like the serial path
        for s in slots {
            out.push(s.into_inner().expect("sweep cell completed")?);
        }
        out
    } else {
        // serial: one recycled buffer set (the sweep's arena), optional
        // shared JSONL stream retagged per cell
        let mut bufs = CellBufs::default();
        let mut stream = match &spec.stream {
            Some(p) => Some(crate::sim::JsonlStreamer::create(p)?),
            None => None,
        };
        let mut out = Vec::with_capacity(coords.len());
        for (scheme, snr, agg, model, pol, fleet, shard, dl, dp, part, al) in coords
        {
            if let Some(s) = stream.as_mut() {
                s.set_label(cell_label(
                    scheme, snr, agg, model, pol, fleet, shard, dl, dp, part, al,
                ));
            }
            out.push(channel_cell(
                spec,
                scheme,
                snr,
                agg,
                model,
                pol,
                fleet,
                shard,
                dl,
                dp,
                &mut bufs,
                stream.as_mut(),
            )?);
        }
        out
    };

    let mut json = consolidated(spec, "channel-only", cells, t0.elapsed().as_secs_f64());
    json.set("payload_len", Value::Num(spec.payload_len as f64));
    json.set("clients", Value::Num(spec.base.clients as f64));
    Ok(SweepReport { json })
}

fn consolidated(
    spec: &SweepSpec,
    mode: &str,
    cells: Vec<Value>,
    wall_secs: f64,
) -> Value {
    let mut o = Value::object();
    o.set("mode", Value::Str(mode.to_string()));
    o.set("grid", spec.grid_json());
    o.set("policy", Value::Str(spec.base.policy.to_string()));
    o.set("seed", Value::from_u64(spec.base.seed));
    o.set("rounds", Value::Num(spec.base.rounds as f64));
    o.set("cells", Value::Array(cells));
    o.set("wall_secs", Value::Num(wall_secs));
    o
}

fn mean_of(xs: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0f64, 0usize);
    for x in xs {
        sum += x;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        let mut base = RunConfig::default();
        base.rounds = 2;
        base.clients = 6;
        base.clients_per_round = 6;
        let mut spec = SweepSpec::new(base);
        spec.schemes = vec![
            Scheme::parse("16,8,4").unwrap(),
            Scheme::parse("8,8,8").unwrap(),
        ];
        spec.snrs_db = vec![5.0, 20.0];
        spec.aggregations = vec![Aggregation::OtaAnalog, Aggregation::Ideal];
        spec.payload_len = 512;
        spec
    }

    #[test]
    fn channel_sweep_covers_the_grid() {
        let spec = tiny_spec();
        assert_eq!(spec.grid_size(), 8);
        let report = run_channel_sweep(&spec).unwrap();
        assert_eq!(report.cells(), 8);
        let cells = report.json.get("cells").unwrap().as_array().unwrap();
        for c in cells {
            assert!(c.get("mean_mse_vs_ideal").unwrap().as_f64().unwrap() >= 0.0);
            assert!(c.get("mean_participants").unwrap().as_f64().unwrap() > 0.0);
        }
        // paired realisations: at fixed scheme+aggregation, MSE falls with SNR
        let mse = |scheme: &str, snr: f64, agg: &str| {
            cells
                .iter()
                .find(|c| {
                    c.get("scheme").unwrap().as_str().unwrap() == scheme
                        && c.get("snr_db").unwrap().as_f64().unwrap() == snr
                        && c.get("aggregation").unwrap().as_str().unwrap() == agg
                })
                .unwrap()
                .get("mean_mse_vs_ideal")
                .unwrap()
                .as_f64()
                .unwrap()
        };
        assert!(mse("16,8,4", 5.0, "ota") > mse("16,8,4", 20.0, "ota"));
        // the noise-free oracle is exact
        assert_eq!(mse("8,8,8", 20.0, "ideal"), 0.0);
    }

    #[test]
    fn scheme_axis_requires_static_policy() {
        let mut spec = tiny_spec();
        spec.policies = vec![PolicyKind::SnrAdaptive];
        // two schemes the policy would never read: reject loudly
        assert!(run_channel_sweep(&spec).is_err());
        // a single-scheme grid is fine (the axis carries no information)
        spec.schemes.truncate(1);
        assert_eq!(run_channel_sweep(&spec).unwrap().cells(), 4);
        // a mixed policy axis still trips on its non-static member
        let mut spec = tiny_spec();
        spec.policies = vec![PolicyKind::Static, PolicyKind::LossPlateau];
        assert!(run_channel_sweep(&spec).is_err());
    }

    #[test]
    fn channel_model_and_policy_axes_widen_the_grid() {
        let mut spec = tiny_spec();
        spec.schemes.truncate(1);
        spec.snrs_db.truncate(1);
        spec.aggregations = vec![Aggregation::OtaAnalog];
        spec.channel_models =
            vec![FadingKind::Rayleigh, FadingKind::GaussMarkov, FadingKind::PathLoss];
        spec.policies = vec![PolicyKind::Static, PolicyKind::LossPlateau];
        spec.base.channel.rho = 0.9;
        spec.base.rounds = 6;
        assert_eq!(spec.grid_size(), 6);
        let report = run_channel_sweep(&spec).unwrap();
        assert_eq!(report.cells(), 6);
        let cells = report.json.get("cells").unwrap().as_array().unwrap();
        for c in cells {
            let m = c.get("channel_model").unwrap().as_str().unwrap();
            assert!(["rayleigh", "gauss_markov", "path_loss"].contains(&m));
            let p = c.get("policy").unwrap().as_str().unwrap();
            assert!(["static", "loss-plateau"].contains(&p));
            // every cell delivered at least some rounds
            assert!(c.get("mean_mse_vs_ideal").unwrap().as_f64().unwrap() >= 0.0);
        }
        // gauss_markov at rho=0 is the rayleigh cell bit-for-bit
        let mut pin = tiny_spec();
        pin.schemes.truncate(1);
        pin.snrs_db.truncate(1);
        pin.aggregations = vec![Aggregation::OtaAnalog];
        pin.base.channel.rho = 0.0;
        pin.channel_models = vec![FadingKind::Rayleigh, FadingKind::GaussMarkov];
        let rep = run_channel_sweep(&pin).unwrap();
        let cs = rep.json.get("cells").unwrap().as_array().unwrap();
        assert_eq!(
            cs[0].get("mean_mse_vs_ideal"),
            cs[1].get("mean_mse_vs_ideal"),
            "rho=0 gauss_markov must reproduce the rayleigh cell"
        );
    }

    #[test]
    fn invalid_channel_knobs_error_instead_of_panicking() {
        // a bad --rho must be a clean error, not a panic mid-sweep
        let mut spec = tiny_spec();
        spec.channel_models = vec![FadingKind::GaussMarkov];
        spec.base.channel.rho = 1.5;
        assert!(run_channel_sweep(&spec).is_err());
        // cell_radius inside the reference distance, with path_loss on
        // the axis (the base model may be something else entirely)
        let mut spec = tiny_spec();
        spec.channel_models = vec![FadingKind::Rayleigh, FadingKind::PathLoss];
        spec.base.channel.cell_radius = 5.0;
        assert!(run_channel_sweep(&spec).is_err());
        // ...but a rayleigh-only grid never reads the radius knob
        let mut spec = tiny_spec();
        spec.base.channel.cell_radius = 5.0;
        assert_eq!(run_channel_sweep(&spec).unwrap().cells(), 8);
    }

    #[test]
    fn cell_order_is_immaterial() {
        // stateful channel models must not leak state across cells: the
        // same grid enumerated in a different axis order yields
        // bit-identical per-cell results
        let mut spec = tiny_spec();
        spec.base.channel.rho = 0.8;
        spec.channel_models = vec![
            FadingKind::GaussMarkov,
            FadingKind::Rayleigh,
            FadingKind::PathLoss,
        ];
        let a = run_channel_sweep(&spec).unwrap();

        let mut rev = tiny_spec();
        rev.base.channel.rho = 0.8;
        rev.channel_models = vec![
            FadingKind::PathLoss,
            FadingKind::Rayleigh,
            FadingKind::GaussMarkov,
        ];
        rev.schemes.reverse();
        rev.snrs_db.reverse();
        rev.aggregations.reverse();
        let b = run_channel_sweep(&rev).unwrap();

        let (ca, cb) = (
            a.json.get("cells").unwrap().as_array().unwrap(),
            b.json.get("cells").unwrap().as_array().unwrap(),
        );
        assert_eq!(ca.len(), cb.len());
        let coord_keys = ["scheme", "snr_db", "aggregation", "channel_model", "policy"];
        for x in ca {
            let y = cb
                .iter()
                .find(|y| coord_keys.iter().all(|k| x.get(k) == y.get(k)))
                .unwrap_or_else(|| panic!("no matching cell for {x:?}"));
            for key in
                ["mean_mse_vs_ideal", "lost_rounds", "mean_participants",
                 "bits_per_round", "channel_uses_per_round"]
            {
                assert_eq!(x.get(key), y.get(key), "{key} differs across orders");
            }
        }
    }

    #[test]
    fn parallel_sweep_matches_serial_cell_for_cell() {
        // workers > 1 runs cells concurrently on the exec pool; the
        // report must be identical to the serial run, cell for cell, in
        // canonical grid order (wall_secs is the only timing field)
        let mut spec = tiny_spec();
        spec.base.channel.rho = 0.7;
        spec.channel_models = vec![FadingKind::Rayleigh, FadingKind::GaussMarkov];
        let serial = run_channel_sweep(&spec).unwrap();
        spec.base.workers = 4;
        let parallel = run_channel_sweep(&spec).unwrap();
        let (ca, cb) = (
            serial.json.get("cells").unwrap().as_array().unwrap(),
            parallel.json.get("cells").unwrap().as_array().unwrap(),
        );
        assert_eq!(ca.len(), cb.len());
        assert_eq!(ca.len(), spec.grid_size());
        for (x, y) in ca.iter().zip(cb.iter()) {
            for key in [
                "scheme",
                "snr_db",
                "aggregation",
                "channel_model",
                "policy",
                "mean_mse_vs_ideal",
                "lost_rounds",
                "mean_participants",
                "bits_per_round",
                "channel_uses_per_round",
            ] {
                assert_eq!(x.get(key), y.get(key), "{key} differs serial vs parallel");
            }
        }
    }

    #[test]
    fn sharded_cells_match_unsharded_bit_for_bit() {
        // the sweep-level shard-invariance pin: the same cell swept over
        // shard_sizes {0, 1, 3} produces identical science fields —
        // wall_secs is the only field allowed to differ
        let mut spec = tiny_spec();
        spec.schemes.truncate(1);
        spec.snrs_db.truncate(1);
        spec.shard_sizes = vec![0, 1, 3];
        assert_eq!(spec.grid_size(), 6);
        let rep = run_channel_sweep(&spec).unwrap();
        let cells = rep.json.get("cells").unwrap().as_array().unwrap();
        assert_eq!(cells.len(), 6);
        for agg in ["ota", "ideal"] {
            let group: Vec<_> = cells
                .iter()
                .filter(|c| c.get("aggregation").unwrap().as_str().unwrap() == agg)
                .collect();
            assert_eq!(group.len(), 3);
            for c in &group[1..] {
                for key in [
                    "mean_mse_vs_ideal",
                    "lost_rounds",
                    "mean_participants",
                    "bits_per_round",
                    "channel_uses_per_round",
                ] {
                    assert_eq!(
                        group[0].get(key),
                        c.get(key),
                        "{agg}: {key} differs across shard sizes"
                    );
                }
            }
        }
    }

    #[test]
    fn massive_fleet_cell_selects_k_and_shards() {
        // a 100k-client fleet with K=64 sampled participants in 16-row
        // shards: the cell runs in O(shard·n + K) state and reports at
        // most K participants per round
        let mut base = RunConfig::default();
        base.rounds = 2;
        base.clients = 100_000;
        base.clients_per_round = 64;
        base.selection = crate::config::SelectionKind::Sampled;
        base.shard_size = 16;
        base.scheme = Scheme::parse("16,8").unwrap();
        let mut spec = SweepSpec::new(base);
        spec.payload_len = 512;
        spec.aggregations = vec![Aggregation::OtaAnalog];
        let rep = run_channel_sweep(&spec).unwrap();
        let cells = rep.json.get("cells").unwrap().as_array().unwrap();
        assert_eq!(cells.len(), 1);
        let c = &cells[0];
        assert_eq!(c.get("clients").unwrap().as_f64().unwrap(), 100_000.0);
        assert_eq!(c.get("clients_per_round").unwrap().as_f64().unwrap(), 64.0);
        assert_eq!(c.get("shard_size").unwrap().as_f64().unwrap(), 16.0);
        // truncation silences a minority of slots at 20 dB; never more
        // than the K selected participate
        let mp = c.get("mean_participants").unwrap().as_f64().unwrap();
        assert!(mp > 32.0 && mp <= 64.0, "mean participants {mp}");
    }

    #[test]
    fn fleet_axis_widens_the_grid_and_validates_divisibility() {
        let mut spec = tiny_spec();
        spec.schemes.truncate(1); // "16,8,4": 3 groups
        spec.snrs_db.truncate(1);
        spec.aggregations = vec![Aggregation::Ideal];
        spec.fleets = vec![6, 12];
        assert_eq!(spec.grid_size(), 2);
        let rep = run_channel_sweep(&spec).unwrap();
        let cells = rep.json.get("cells").unwrap().as_array().unwrap();
        assert_eq!(cells.len(), 2);
        assert_eq!(cells[0].get("clients").unwrap().as_f64().unwrap(), 6.0);
        assert_eq!(cells[1].get("clients").unwrap().as_f64().unwrap(), 12.0);
        // a fleet the static scheme cannot divide is a clean up-front error
        spec.fleets = vec![6, 7];
        assert!(run_channel_sweep(&spec).is_err());
    }

    #[test]
    fn channel_sweep_streams_jsonl_per_round() {
        let mut spec = tiny_spec();
        spec.schemes.truncate(1);
        spec.snrs_db.truncate(1);
        spec.aggregations = vec![Aggregation::OtaAnalog];
        let path = std::env::temp_dir().join("mpota_sweep_stream_test.jsonl");
        let _ = std::fs::remove_file(&path);
        spec.stream = Some(path.clone());
        let rep = run_channel_sweep(&spec).unwrap();
        assert_eq!(rep.cells(), 1);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), spec.base.rounds, "one JSONL line per round");
        for (i, line) in lines.iter().enumerate() {
            let v = crate::json::parse(line).unwrap();
            assert_eq!(v.get("round").unwrap().as_usize().unwrap(), i + 1);
            let label = v.get("label").unwrap().as_str().unwrap().to_string();
            assert!(label.contains("16,8,4"), "label {label}");
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn deadline_dropout_axes_widen_the_grid_and_exclude_clients() {
        let mut spec = tiny_spec();
        spec.schemes.truncate(1);
        spec.snrs_db.truncate(1);
        spec.aggregations = vec![Aggregation::Ideal];
        spec.base.rounds = 8;
        spec.dropouts = vec![0.0, 0.4];
        assert_eq!(spec.grid_size(), 2);
        let rep = run_channel_sweep(&spec).unwrap();
        let cells = rep.json.get("cells").unwrap().as_array().unwrap();
        assert_eq!(cells.len(), 2);
        let (clean, lossy) = (&cells[0], &cells[1]);
        assert_eq!(clean.get("dropout_p").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(lossy.get("dropout_p").unwrap().as_f64().unwrap(), 0.4);
        // the clean cell excludes nobody; the lossy cell excludes some
        // and reports fewer mean participants
        assert_eq!(clean.get("mean_excluded").unwrap().as_f64().unwrap(), 0.0);
        assert!(lossy.get("mean_excluded").unwrap().as_f64().unwrap() > 0.0);
        assert!(
            lossy.get("mean_participants").unwrap().as_f64().unwrap()
                < clean.get("mean_participants").unwrap().as_f64().unwrap()
        );
        // divisor exactness under partial participation: the noise-free
        // oracle still matches the ideal aggregator bit for bit
        if lossy.get("lost_rounds").unwrap().as_f64().unwrap()
            < spec.base.rounds as f64
        {
            assert_eq!(
                lossy.get("mean_mse_vs_ideal").unwrap().as_f64().unwrap(),
                0.0
            );
        }
        // deadline axis widens the grid the same way
        let mut spec = tiny_spec();
        spec.deadlines = vec![0.0, 0.06];
        assert_eq!(spec.grid_size(), 16);
    }

    #[test]
    fn excluded_cells_are_shard_invariant() {
        // the exclusion mask is decided per round, independent of the
        // shard partition — sharded and unsharded lossy cells must agree
        // on every science field
        let mut spec = tiny_spec();
        spec.schemes.truncate(1);
        spec.snrs_db.truncate(1);
        spec.base.rounds = 6;
        spec.dropouts = vec![0.3];
        spec.deadlines = vec![0.06];
        spec.shard_sizes = vec![0, 1, 3];
        let rep = run_channel_sweep(&spec).unwrap();
        let cells = rep.json.get("cells").unwrap().as_array().unwrap();
        assert_eq!(cells.len(), 6);
        for agg in ["ota", "ideal"] {
            let group: Vec<_> = cells
                .iter()
                .filter(|c| c.get("aggregation").unwrap().as_str().unwrap() == agg)
                .collect();
            assert_eq!(group.len(), 3);
            for c in &group[1..] {
                for key in [
                    "mean_mse_vs_ideal",
                    "lost_rounds",
                    "mean_participants",
                    "mean_excluded",
                    "bits_per_round",
                ] {
                    assert_eq!(
                        group[0].get(key),
                        c.get(key),
                        "{agg}: {key} differs across shard sizes under exclusion"
                    );
                }
            }
        }
    }

    #[test]
    fn pipelined_cells_match_serial_bit_for_bit() {
        // pipeline_depth only changes WHEN superposition happens relative
        // to generation, never the draws or the accumulation order — the
        // report's science fields are bit-identical, with and without
        // active exclusion
        let mut spec = tiny_spec();
        spec.base.rounds = 4;
        spec.shard_sizes = vec![2];
        spec.dropouts = vec![0.0, 0.25];
        let serial = run_channel_sweep(&spec).unwrap();
        spec.base.pipeline_depth = 2;
        let piped = run_channel_sweep(&spec).unwrap();
        let (ca, cb) = (
            serial.json.get("cells").unwrap().as_array().unwrap(),
            piped.json.get("cells").unwrap().as_array().unwrap(),
        );
        assert_eq!(ca.len(), cb.len());
        for (x, y) in ca.iter().zip(cb.iter()) {
            for key in [
                "scheme",
                "snr_db",
                "aggregation",
                "dropout_p",
                "mean_mse_vs_ideal",
                "lost_rounds",
                "mean_participants",
                "mean_excluded",
                "bits_per_round",
                "channel_uses_per_round",
            ] {
                assert_eq!(x.get(key), y.get(key), "{key} differs serial vs pipelined");
            }
        }
    }

    #[test]
    fn packed_cells_match_f32_staging_bit_for_bit() {
        // packed transport is a pure storage transformation: the same
        // grid with packed_planes on (the default) vs off must agree on
        // every science field — serial, sharded AND pipelined — because
        // decode(pack(x)) == fake_quant(x) bit for bit per element
        let mut spec = tiny_spec();
        spec.base.rounds = 4;
        spec.aggregations =
            vec![Aggregation::OtaAnalog, Aggregation::Digital, Aggregation::Ideal];
        spec.shard_sizes = vec![0, 2];
        assert!(spec.base.packed_planes, "packed transport is the default");
        let on = run_channel_sweep(&spec).unwrap();
        spec.base.packed_planes = false;
        let off = run_channel_sweep(&spec).unwrap();
        spec.base.packed_planes = true;
        spec.base.pipeline_depth = 2;
        let piped = run_channel_sweep(&spec).unwrap();
        let ca = on.json.get("cells").unwrap().as_array().unwrap();
        let cb = off.json.get("cells").unwrap().as_array().unwrap();
        let cc = piped.json.get("cells").unwrap().as_array().unwrap();
        assert_eq!(ca.len(), cb.len());
        assert_eq!(ca.len(), cc.len());
        assert_eq!(ca.len(), spec.grid_size());
        for ((x, y), z) in ca.iter().zip(cb.iter()).zip(cc.iter()) {
            for key in [
                "scheme",
                "snr_db",
                "aggregation",
                "shard_size",
                "mean_mse_vs_ideal",
                "lost_rounds",
                "mean_participants",
                "bits_per_round",
                "channel_uses_per_round",
            ] {
                assert_eq!(x.get(key), y.get(key), "{key} differs packed vs f32");
                assert_eq!(x.get(key), z.get(key), "{key} differs packed vs piped");
            }
        }
    }

    #[test]
    fn partition_axes_require_fl_mode() {
        // channel-only cells never touch training data: a widened
        // partition grid is a loud config error, not silently-identical
        // cells under different labels
        let mut spec = tiny_spec();
        spec.partitions = vec![PartitionKind::Iid, PartitionKind::Dirichlet];
        spec.alphas = vec![0.1, 1.0];
        let err = run_channel_sweep(&spec).unwrap_err().to_string();
        assert!(err.contains("fl-mode"), "unexpected error: {err}");
        // trivial axes (the base config's own values) stay accepted, and
        // the grid JSON omits the partition keys entirely (id-parity)
        let spec = tiny_spec();
        let rep = run_channel_sweep(&spec).unwrap();
        let grid = rep.json.get("grid").unwrap();
        assert!(grid.get("partitions").is_none());
        assert!(grid.get("alphas").is_none());
    }

    #[test]
    fn partition_sweep_prevalidates_clients_per_round_divisibility() {
        // precision is assigned over the K selected clients — a static
        // scheme that cannot divide K must fail at spec-build time, with
        // both values named (PR-6 error-text style)
        let mut base = RunConfig::default();
        base.clients = 12;
        base.clients_per_round = 8;
        let mut spec = SweepSpec::new(base);
        spec.schemes = vec![Scheme::parse("16,8,4").unwrap()]; // 3 groups
        spec.partitions = vec![PartitionKind::Dirichlet];
        spec.alphas = vec![0.1, 1.0];
        let err = spec.validate().unwrap_err().to_string();
        assert_eq!(
            err,
            "clients-per-round 8 does not divide into the 3 groups of \
             scheme '16,8,4'"
        );
        // K = 6 divides: the same grid validates
        spec.base.clients_per_round = 6;
        spec.validate().unwrap();
        // bad alphas are caught up front too
        spec.alphas = vec![0.0];
        assert!(spec.validate().is_err());
    }

    fn fl_mock_spec(tag: &str) -> SweepSpec {
        let dir = crate::testing::mock_artifacts_dir(tag);
        let mut base = RunConfig::default();
        base.artifacts_dir = dir.to_path_buf();
        base.variant = "mock".into();
        base.clients = 6;
        base.clients_per_round = 6;
        base.rounds = 3;
        base.train_samples = 96;
        base.test_samples = 32;
        base.scheme = Scheme::parse("16,8,4").unwrap();
        let mut spec = SweepSpec::new(base);
        spec.snrs_db = vec![5.0, 20.0];
        spec.partitions = vec![PartitionKind::Iid, PartitionKind::Dirichlet];
        spec.alphas = vec![0.5];
        spec.backend_factory = Some(std::sync::Arc::new(|| {
            Box::new(crate::testing::GradStatsBackend::for_mock())
                as Box<dyn crate::exec::TrainBackend>
        }));
        spec
    }

    #[test]
    fn parallel_fl_sweep_matches_serial() {
        // the PR-4 caveat lifted: with a per-cell backend factory,
        // fl-mode cells run concurrently on the pool and the report is
        // identical to the serial run's, cell for cell (wall_secs is the
        // only timing field)
        let mut spec = fl_mock_spec("flsweep-par");
        let serial = run_fl_sweep(&spec).unwrap();
        spec.base.workers = 4;
        let parallel = run_fl_sweep(&spec).unwrap();
        let (ca, cb) = (
            serial.json.get("cells").unwrap().as_array().unwrap(),
            parallel.json.get("cells").unwrap().as_array().unwrap(),
        );
        assert_eq!(ca.len(), cb.len());
        assert_eq!(ca.len(), spec.grid_size());
        for (x, y) in ca.iter().zip(cb.iter()) {
            for key in [
                "scheme",
                "snr_db",
                "partition",
                "alpha",
                "label",
                "final_accuracy",
                "final_loss",
                "best_accuracy",
                "mean_ota_mse",
                "energy_j",
            ] {
                assert_eq!(x.get(key), y.get(key), "{key} differs serial vs parallel");
            }
        }
        // the non-trivial partition axes surface in the grid JSON
        let grid = serial.json.get("grid").unwrap();
        assert!(grid.get("partitions").is_some());
        assert!(grid.get("alphas").is_some());
        // and the dirichlet cells carry the partition label suffix
        let dirichlet_labels = ca
            .iter()
            .filter(|c| c.get("partition").unwrap().as_str().unwrap() == "dirichlet")
            .map(|c| c.get("label").unwrap().as_str().unwrap().to_string())
            .collect::<Vec<_>>();
        assert_eq!(dirichlet_labels.len(), 2);
        for l in &dirichlet_labels {
            assert!(l.contains("dirichlet"), "label {l}");
        }
    }

    #[test]
    fn channel_sweep_is_deterministic() {
        let spec = tiny_spec();
        let a = run_channel_sweep(&spec).unwrap();
        let b = run_channel_sweep(&spec).unwrap();
        // wall_secs differ; compare the science fields cell by cell
        let (ca, cb) = (
            a.json.get("cells").unwrap().as_array().unwrap(),
            b.json.get("cells").unwrap().as_array().unwrap(),
        );
        assert_eq!(ca.len(), cb.len());
        for (x, y) in ca.iter().zip(cb.iter()) {
            for key in ["scheme", "snr_db", "aggregation", "mean_mse_vs_ideal"] {
                assert_eq!(x.get(key), y.get(key), "{key}");
            }
        }
    }
}
