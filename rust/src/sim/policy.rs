//! The [`PrecisionPolicy`] seam: which quantization level each client runs
//! at, decided per communication round.
//!
//! The paper evaluates STATIC group schemes (§IV-A2) — [`StaticScheme`]
//! reproduces exactly the assignment the pre-redesign coordinator fixed at
//! construction, so default runs are bit-identical per seed.  The trait
//! generalizes that to a per-round callback: [`SnrAdaptive`] is a built-in
//! dynamic policy (bit selection from the channel SNR, with optional
//! precision annealing over rounds), and custom policies can react to the
//! previous round's record (loss plateau, OTA MSE, energy budget, ...).

use anyhow::Result;

use crate::config::{PolicyKind, RunConfig};
use crate::fl::scheme::{Scheme, SCHEME_LEVELS};
use crate::metrics::RoundRecord;
use crate::quant::Precision;

/// Everything a policy may consult when assigning the round's precisions.
pub struct PolicyCtx<'a> {
    /// 1-based communication round about to run.
    pub round: usize,
    /// Total fleet size N (assignments cover every client, selected or
    /// not, so selection stays independent of the policy).
    pub clients: usize,
    /// Configured server receiver SNR in dB.
    pub snr_db: f32,
    /// The previous round's record (None on the first round).
    pub prev: Option<&'a RoundRecord>,
}

/// Per-round precision assignment for the whole fleet.
///
/// Contract: `assign_into` fills `out` with exactly `ctx.clients` levels
/// drawn from [`levels`](Self::levels), and allocates nothing once `out`
/// has warmed to fleet capacity (the zero-alloc round contract).
///
/// `assign_into` must be a pure function of the policy's configuration
/// and `ctx` — NOT of how many times it has been called: the coordinator
/// invokes it once at construction (with `round: 1, prev: None`, to size
/// the client fleet) and then once per round, so round 1 is assigned
/// twice.  Derive any "progress" from `ctx.round`/`ctx.prev`, never from
/// an internal call counter.
pub trait PrecisionPolicy {
    /// Fill `out` with one precision per client for this round.
    fn assign_into(&mut self, ctx: &PolicyCtx<'_>, out: &mut Vec<Precision>)
        -> Result<()>;

    /// Every level the policy may ever assign — drives artifact warmup and
    /// the end-of-run requantization report.
    fn levels(&self) -> Vec<Precision>;

    /// Report label (the scheme string for the static policy).
    fn label(&self) -> String;
}

/// The paper's static group scheme, every round (the default policy).
pub struct StaticScheme {
    scheme: Scheme,
}

impl StaticScheme {
    pub fn new(scheme: Scheme) -> Self {
        StaticScheme { scheme }
    }
}

impl PrecisionPolicy for StaticScheme {
    fn assign_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        self.scheme.client_precisions_into(ctx.clients, out)
    }

    fn levels(&self) -> Vec<Precision> {
        self.scheme.distinct_levels()
    }

    fn label(&self) -> String {
        self.scheme.to_string()
    }
}

/// SNR-adaptive bit selection: run the whole fleet at the cheapest level
/// whose quantization noise still sits at or below the channel noise
/// floor.
///
/// Rationale: b-bit quantization buys ≈6.02·b dB of SQNR, so payload
/// precision beyond `snr_db / 6.02` bits disappears under the receiver
/// AWGN — energy spent on it is wasted.  With `anneal_every = e > 0` the
/// policy additionally steps one ladder level down every `e` rounds
/// (precision annealing: late-training updates tolerate coarser grids),
/// making the assignment genuinely round-dependent.
pub struct SnrAdaptive {
    /// Candidate levels, descending bits (defaults to the scheme ladder
    /// [32, 24, 16, 12, 8, 6, 4]).
    ladder: Vec<Precision>,
    /// Step down one ladder level every this many rounds (0 = off).
    anneal_every: usize,
    /// Known run SNR, when constructed from a config: lets
    /// [`levels`](PrecisionPolicy::levels) report only *reachable* levels
    /// so warmup compiles and requant evals skip unreachable precisions.
    snr_hint_db: Option<f32>,
}

impl SnrAdaptive {
    pub fn new() -> Self {
        SnrAdaptive {
            ladder: SCHEME_LEVELS.iter().map(|&b| Precision::of(b)).collect(),
            anneal_every: 0,
            snr_hint_db: None,
        }
    }

    pub fn with_annealing(mut self, every: usize) -> Self {
        self.anneal_every = every;
        self
    }

    /// Declare the run's (fixed) channel SNR so `levels()` can prune
    /// unreachable ladder entries.
    pub fn with_snr_hint(mut self, snr_db: f32) -> Self {
        self.snr_hint_db = Some(snr_db);
        self
    }

    /// Ladder index of the cheapest level still reaching the SNR target.
    fn base_index(&self, snr_db: f32) -> usize {
        // ≈6.02 dB of SQNR per bit
        let target_bits = (snr_db / 6.02).ceil();
        let mut idx = 0usize;
        for (i, p) in self.ladder.iter().enumerate() {
            if (p.bits() as f32) >= target_bits {
                idx = i; // descending ladder: keep walking down while >= target
            } else {
                break;
            }
        }
        idx
    }
}

impl Default for SnrAdaptive {
    fn default() -> Self {
        SnrAdaptive::new()
    }
}

impl PrecisionPolicy for SnrAdaptive {
    fn assign_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        let mut idx = self.base_index(ctx.snr_db);
        if self.anneal_every > 0 {
            idx = (idx + (ctx.round.saturating_sub(1)) / self.anneal_every)
                .min(self.ladder.len() - 1);
        }
        let p = self.ladder[idx];
        out.clear();
        out.resize(ctx.clients, p);
        Ok(())
    }

    fn levels(&self) -> Vec<Precision> {
        match self.snr_hint_db {
            // the policy only ever walks DOWN from the SNR-selected base
            Some(snr) => {
                let base = self.base_index(snr);
                if self.anneal_every > 0 {
                    self.ladder[base..].to_vec()
                } else {
                    vec![self.ladder[base]]
                }
            }
            // no hint (hand-constructed): every ladder level is possible
            None => self.ladder.clone(),
        }
    }

    fn label(&self) -> String {
        if self.anneal_every > 0 {
            format!("snr-adaptive/anneal{}", self.anneal_every)
        } else {
            "snr-adaptive".to_string()
        }
    }
}

/// The built-in policy named by the config's [`PolicyKind`].
pub fn from_config(kind: PolicyKind, cfg: &RunConfig) -> Box<dyn PrecisionPolicy> {
    match kind {
        PolicyKind::Static => Box::new(StaticScheme::new(cfg.scheme.clone())),
        PolicyKind::SnrAdaptive => {
            Box::new(SnrAdaptive::new().with_snr_hint(cfg.channel.snr_db))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(round: usize, clients: usize, snr_db: f32) -> PolicyCtx<'static> {
        PolicyCtx { round, clients, snr_db, prev: None }
    }

    #[test]
    fn static_policy_matches_scheme_expansion() {
        let scheme = Scheme::parse("16,8,4").unwrap();
        let mut policy = StaticScheme::new(scheme.clone());
        let mut out = Vec::new();
        for t in 1..=3 {
            policy.assign_into(&ctx(t, 15, 20.0), &mut out).unwrap();
            assert_eq!(out, scheme.client_precisions(15).unwrap(), "round {t}");
        }
        assert_eq!(policy.levels(), scheme.distinct_levels());
        assert_eq!(policy.label(), "16,8,4");
    }

    #[test]
    fn static_policy_rejects_undivisible_fleet() {
        let mut policy = StaticScheme::new(Scheme::parse("16,8,4").unwrap());
        let mut out = Vec::new();
        assert!(policy.assign_into(&ctx(1, 14, 20.0), &mut out).is_err());
    }

    #[test]
    fn snr_adaptive_tracks_channel_quality() {
        let mut policy = SnrAdaptive::new();
        let mut out = Vec::new();
        // 20 dB: ceil(20/6.02) = 4 target bits -> cheapest level >= 4 is 4
        policy.assign_into(&ctx(1, 5, 20.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(4); 5]);
        // 45 dB: target 8 bits
        policy.assign_into(&ctx(1, 5, 45.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(8); 5]);
        // 90 dB: target 15 -> 16-bit
        policy.assign_into(&ctx(1, 5, 90.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(16); 5]);
        // absurdly clean channel: capped at the top of the ladder
        policy.assign_into(&ctx(1, 5, 500.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(32); 5]);
    }

    #[test]
    fn snr_hint_prunes_unreachable_levels() {
        // no hint: conservative full ladder
        assert_eq!(SnrAdaptive::new().levels().len(), SCHEME_LEVELS.len());
        // hint, no annealing: exactly the one reachable level
        let p = SnrAdaptive::new().with_snr_hint(20.0);
        assert_eq!(p.levels(), vec![Precision::of(4)]);
        // hint + annealing: the base level and everything below it
        let p = SnrAdaptive::new().with_snr_hint(90.0).with_annealing(3);
        assert_eq!(
            p.levels().iter().map(|p| p.bits()).collect::<Vec<_>>(),
            vec![16, 12, 8, 6, 4]
        );
        // from_config wires the hint from the run config
        let mut cfg = RunConfig::default();
        cfg.policy = PolicyKind::SnrAdaptive;
        cfg.channel.snr_db = 45.0;
        assert_eq!(
            from_config(cfg.policy, &cfg).levels(),
            vec![Precision::of(8)]
        );
    }

    #[test]
    fn snr_adaptive_annealing_descends_the_ladder() {
        let mut policy = SnrAdaptive::new().with_annealing(2);
        let mut out = Vec::new();
        let mut seen = Vec::new();
        for t in 1..=8 {
            policy.assign_into(&ctx(t, 3, 90.0), &mut out).unwrap();
            seen.push(out[0].bits());
        }
        // base 16-bit at 90 dB, stepping down every 2 rounds
        assert_eq!(seen, vec![16, 16, 12, 12, 8, 8, 6, 6]);
        // never leaves the ladder
        let mut late = Vec::new();
        policy.assign_into(&ctx(1000, 3, 90.0), &mut late).unwrap();
        assert_eq!(late[0].bits(), 4);
    }
}
