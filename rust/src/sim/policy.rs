//! The [`PrecisionPolicy`] seam: which quantization level each client runs
//! at, decided per communication round.
//!
//! The paper evaluates STATIC group schemes (§IV-A2) — [`StaticScheme`]
//! reproduces exactly the assignment the pre-redesign coordinator fixed at
//! construction, so default runs are bit-identical per seed.  The trait
//! generalizes that to a per-round callback: [`SnrAdaptive`] is a built-in
//! dynamic policy (bit selection from the channel SNR, with optional
//! precision annealing over rounds), and the FEEDBACK policies react to
//! the previous round's record through [`PolicyCtx::prev`]:
//! [`LossPlateau`] promotes the fleet when the global loss stalls,
//! [`EnergyBudget`] demotes it as cumulative fleet energy approaches a
//! cap (the per-round-precision energy accrual in
//! [`crate::coordinator::ClientState`] is what makes that cap
//! meaningful).  [`ProfilingPlanner`] goes one step further: it
//! accumulates PER-CLIENT channel/energy/loss profiles in a bounded
//! id-keyed LRU (fed by [`RoundFeedback`] after each round) and assigns
//! precision per client rather than fleet-wide.  Custom policies are
//! plain trait impls.

use anyhow::Result;

use crate::config::{PolicyKind, RunConfig};
use crate::fl::scheme::{Scheme, SCHEME_LEVELS};
use crate::fl::IdLru;
use crate::metrics::RoundRecord;
use crate::quant::Precision;

/// One finished round's per-participant measurements, fed back to the
/// policy after aggregation (see
/// [`PrecisionPolicy::observe_feedback`]).  All slices are slot-aligned
/// with `ids` (this round's selected client identities).
pub struct RoundFeedback<'a> {
    /// 1-based communication round that just finished.
    pub round: usize,
    /// Selected client identities, in slot order.
    pub ids: &'a [usize],
    /// Per-participant channel amplitude |h| observed this round (1.0
    /// when the aggregator drew no channel).
    pub gains: &'a [f32],
    /// Per-participant energy spent THIS round, in joules.
    pub energy_j: &'a [f64],
    /// Per-participant local training loss this round.
    pub losses: &'a [f64],
}

/// Everything a policy may consult when assigning the round's precisions.
pub struct PolicyCtx<'a> {
    /// 1-based communication round about to run.
    pub round: usize,
    /// Total fleet size N (assignments cover every client, selected or
    /// not, so selection stays independent of the policy).
    pub clients: usize,
    /// Configured server receiver SNR in dB.
    pub snr_db: f32,
    /// The previous round's record (None on the first round).
    pub prev: Option<&'a RoundRecord>,
}

/// Per-round precision assignment for the whole fleet.
///
/// Contract: `assign_into` fills `out` with exactly `ctx.clients` levels
/// drawn from [`levels`](Self::levels), and allocates nothing once `out`
/// has warmed to fleet capacity (the zero-alloc round contract).
///
/// Assignment must be a pure function of the policy's configuration and
/// `ctx` — NOT of how many times it has been called: the coordinator
/// invokes [`assign_selected_into`](Self::assign_selected_into) once at
/// construction (with `round: 1, prev: None` and an empty selection, to
/// validate the configuration) and then once per round, so round 1 is
/// assigned twice.  Derive any "progress" from `ctx.round`/`ctx.prev`,
/// never from an internal call counter.
pub trait PrecisionPolicy {
    /// Fill `out` with one precision per client for this round.
    fn assign_into(&mut self, ctx: &PolicyCtx<'_>, out: &mut Vec<Precision>)
        -> Result<()>;

    /// Fill `out` with one precision per SELECTED participant (aligned
    /// with `selected`) — the O(K) massive-fleet form.  The result must
    /// equal gathering the fleet-wide [`assign_into`](Self::assign_into)
    /// output at the selected indices, and any feedback-state update must
    /// happen exactly once per observed round (the round loop calls
    /// exactly one of the two assignment methods per round, with the same
    /// `ctx` rules).
    ///
    /// The default materializes the fleet assignment and gathers — O(N)
    /// and allocating, correct for any custom policy; the built-in
    /// policies override it with allocation-free O(K) implementations so
    /// a 10M-client fleet never materializes fleet-sized state.
    fn assign_selected_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        selected: &[usize],
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        let mut fleet = Vec::new();
        self.assign_into(ctx, &mut fleet)?;
        out.clear();
        for &k in selected {
            out.push(fleet[k]);
        }
        Ok(())
    }

    /// Observe one finished round's per-participant measurements.  The
    /// round loop calls this at most once per round, after aggregation;
    /// implementations must be idempotent per `fb.round` (key internal
    /// updates on it) and must not allocate once their per-client state
    /// has warmed to capacity.  The default ignores feedback — the
    /// ladder/fleet-wide policies derive everything from
    /// [`PolicyCtx::prev`].
    fn observe_feedback(&mut self, _fb: &RoundFeedback<'_>) {}

    /// Every level the policy may ever assign — drives artifact warmup and
    /// the end-of-run requantization report.
    fn levels(&self) -> Vec<Precision>;

    /// Report label (the scheme string for the static policy).
    fn label(&self) -> String;
}

/// The paper's static group scheme, every round (the default policy).
pub struct StaticScheme {
    scheme: Scheme,
}

impl StaticScheme {
    pub fn new(scheme: Scheme) -> Self {
        StaticScheme { scheme }
    }
}

impl PrecisionPolicy for StaticScheme {
    fn assign_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        self.scheme.client_precisions_into(ctx.clients, out)
    }

    fn assign_selected_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        selected: &[usize],
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        self.scheme.selected_precisions_into(ctx.clients, selected, out)
    }

    fn levels(&self) -> Vec<Precision> {
        self.scheme.distinct_levels()
    }

    fn label(&self) -> String {
        self.scheme.to_string()
    }
}

/// SNR-adaptive bit selection: run the whole fleet at the cheapest level
/// whose quantization noise still sits at or below the channel noise
/// floor.
///
/// Rationale: b-bit quantization buys ≈6.02·b dB of SQNR, so payload
/// precision beyond `snr_db / 6.02` bits disappears under the receiver
/// AWGN — energy spent on it is wasted.  With `anneal_every = e > 0` the
/// policy additionally steps one ladder level down every `e` rounds
/// (precision annealing: late-training updates tolerate coarser grids),
/// making the assignment genuinely round-dependent.
pub struct SnrAdaptive {
    /// Candidate levels, descending bits (defaults to the scheme ladder
    /// [32, 24, 16, 12, 8, 6, 4]).
    ladder: Vec<Precision>,
    /// Step down one ladder level every this many rounds (0 = off).
    anneal_every: usize,
    /// Known run SNR, when constructed from a config: lets
    /// [`levels`](PrecisionPolicy::levels) report only *reachable* levels
    /// so warmup compiles and requant evals skip unreachable precisions.
    snr_hint_db: Option<f32>,
}

impl SnrAdaptive {
    pub fn new() -> Self {
        SnrAdaptive {
            ladder: SCHEME_LEVELS.iter().map(|&b| Precision::of(b)).collect(),
            anneal_every: 0,
            snr_hint_db: None,
        }
    }

    pub fn with_annealing(mut self, every: usize) -> Self {
        self.anneal_every = every;
        self
    }

    /// Declare the run's (fixed) channel SNR so `levels()` can prune
    /// unreachable ladder entries.
    pub fn with_snr_hint(mut self, snr_db: f32) -> Self {
        self.snr_hint_db = Some(snr_db);
        self
    }

    /// Ladder index of the cheapest level still reaching the SNR target.
    fn base_index(&self, snr_db: f32) -> usize {
        // ≈6.02 dB of SQNR per bit
        let target_bits = (snr_db / 6.02).ceil();
        let mut idx = 0usize;
        for (i, p) in self.ladder.iter().enumerate() {
            if (p.bits() as f32) >= target_bits {
                idx = i; // descending ladder: keep walking down while >= target
            } else {
                break;
            }
        }
        idx
    }
}

impl Default for SnrAdaptive {
    fn default() -> Self {
        SnrAdaptive::new()
    }
}

impl SnrAdaptive {
    /// The (uniform) fleet level for this round's context.
    fn level_for(&self, ctx: &PolicyCtx<'_>) -> Precision {
        let mut idx = self.base_index(ctx.snr_db);
        if self.anneal_every > 0 {
            idx = (idx + (ctx.round.saturating_sub(1)) / self.anneal_every)
                .min(self.ladder.len() - 1);
        }
        self.ladder[idx]
    }
}

impl PrecisionPolicy for SnrAdaptive {
    fn assign_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        let p = self.level_for(ctx);
        out.clear();
        out.resize(ctx.clients, p);
        Ok(())
    }

    fn assign_selected_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        selected: &[usize],
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        let p = self.level_for(ctx);
        out.clear();
        out.resize(selected.len(), p);
        Ok(())
    }

    fn levels(&self) -> Vec<Precision> {
        match self.snr_hint_db {
            // the policy only ever walks DOWN from the SNR-selected base
            Some(snr) => {
                let base = self.base_index(snr);
                if self.anneal_every > 0 {
                    self.ladder[base..].to_vec()
                } else {
                    vec![self.ladder[base]]
                }
            }
            // no hint (hand-constructed): every ladder level is possible
            None => self.ladder.clone(),
        }
    }

    fn label(&self) -> String {
        if self.anneal_every > 0 {
            format!("snr-adaptive/anneal{}", self.anneal_every)
        } else {
            "snr-adaptive".to_string()
        }
    }
}

/// Feedback policy: start cheap, PROMOTE the whole fleet one precision
/// level whenever the global loss plateaus.
///
/// Intuition: early training tolerates coarse updates (the gradient
/// signal dwarfs the quantization noise), so the fleet starts at the
/// cheapest ladder level; once the previous rounds' server loss has not
/// improved by `min_delta` for `patience` consecutive observed rounds,
/// the remaining error floor is blamed on quantization and every client
/// is promoted one level up the ladder.
///
/// Feedback-state discipline: the policy reads [`PolicyCtx::prev`] and
/// keys every internal update on `prev.round`, so repeated calls with
/// the same context are idempotent — which is exactly what the
/// construction-time double assignment of round 1 requires (`prev` is
/// `None` there, so nothing updates at all).  Records whose loss is
/// carried forward from an earlier evaluation
/// (`RoundRecord::evaluated == false`, i.e. non-eval rounds under
/// `eval_every > 1`) are ignored entirely: `patience` counts *fresh
/// evaluations* without improvement, not wall-clock rounds.
pub struct LossPlateau {
    /// Candidate levels, descending bits (the scheme ladder).
    ladder: Vec<Precision>,
    /// Ladder index the fleet starts at (default: the cheapest level).
    start: usize,
    /// Observed fresh evaluations without improvement before a promotion.
    patience: usize,
    /// Minimum loss decrease that counts as improvement.
    min_delta: f64,
    // feedback state, keyed by the last observed round
    idx: usize,
    best_loss: f64,
    since_improve: usize,
    last_seen: usize,
}

impl LossPlateau {
    /// Plateau policy with the default ladder, starting at the cheapest
    /// level with a patience of 5 rounds.
    pub fn new() -> Self {
        let ladder: Vec<Precision> =
            SCHEME_LEVELS.iter().map(|&b| Precision::of(b)).collect();
        let start = ladder.len() - 1;
        LossPlateau {
            ladder,
            start,
            patience: 5,
            min_delta: 1e-3,
            idx: start,
            best_loss: f64::INFINITY,
            since_improve: 0,
            last_seen: 0,
        }
    }

    /// Observed rounds without improvement before promoting (must be
    /// positive).
    pub fn with_patience(mut self, patience: usize) -> Self {
        assert!(patience > 0, "patience must be positive");
        self.patience = patience;
        self
    }

    /// Minimum loss decrease that counts as improvement.
    pub fn with_min_delta(mut self, min_delta: f64) -> Self {
        self.min_delta = min_delta;
        self
    }

    /// Start the fleet at `bits` instead of the cheapest ladder level.
    /// Panics if `bits` is not a ladder level.
    pub fn with_start_bits(mut self, bits: u8) -> Self {
        let i = self
            .ladder
            .iter()
            .position(|p| p.bits() == bits)
            .expect("start bits must be a ladder level");
        self.start = i;
        self.idx = i;
        self
    }

    /// The precision currently assigned to the fleet (diagnostics).
    pub fn current_bits(&self) -> u8 {
        self.ladder[self.idx].bits()
    }
}

impl Default for LossPlateau {
    fn default() -> Self {
        LossPlateau::new()
    }
}

impl LossPlateau {
    /// Observe the previous round's record (idempotent per observed
    /// round) and return the fleet's current level — the shared state
    /// step behind both assignment forms.
    fn observe(&mut self, ctx: &PolicyCtx<'_>) -> Precision {
        if let Some(prev) = ctx.prev {
            // only FRESH evaluations carry information: with
            // `eval_every > 1` the coordinator carries the last eval's
            // loss forward on non-eval rounds (`evaluated == false`), and
            // counting those as stalls would promote on a schedule
            // instead of on the loss trend
            if prev.evaluated && prev.round > self.last_seen {
                self.last_seen = prev.round;
                if prev.server_loss < self.best_loss - self.min_delta {
                    self.best_loss = prev.server_loss;
                    self.since_improve = 0;
                } else {
                    self.since_improve += 1;
                    if self.since_improve >= self.patience && self.idx > 0 {
                        self.idx -= 1; // promote: one level UP the ladder
                        self.since_improve = 0;
                    }
                }
            }
        }
        self.ladder[self.idx]
    }
}

impl PrecisionPolicy for LossPlateau {
    fn assign_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        let p = self.observe(ctx);
        out.clear();
        out.resize(ctx.clients, p);
        Ok(())
    }

    fn assign_selected_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        selected: &[usize],
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        let p = self.observe(ctx);
        out.clear();
        out.resize(selected.len(), p);
        Ok(())
    }

    fn levels(&self) -> Vec<Precision> {
        // promotion only walks UP from the start level
        self.ladder[..=self.start].to_vec()
    }

    fn label(&self) -> String {
        format!("loss-plateau/p{}", self.patience)
    }
}

/// Feedback policy: start rich, DEMOTE the fleet down the ladder as
/// cumulative fleet energy approaches its budget.
///
/// The previous round's record carries the cumulative fleet energy
/// accrued at the precision each MAC actually ran at
/// ([`RoundRecord::energy_joules`]); with a ladder of L levels the fleet
/// is demoted one level for every `1/L` of the budget spent, so it lands
/// on the cheapest level as the budget runs out instead of overshooting
/// it.  Stateless: the assignment is a pure function of `ctx`, and since
/// cumulative energy never decreases, precision is monotone
/// non-increasing over a run.
pub struct EnergyBudget {
    /// Candidate levels, descending bits.
    ladder: Vec<Precision>,
    /// Per-client energy cap in joules; the fleet budget is
    /// `ctx.clients ×` this.
    budget_j: f64,
}

impl EnergyBudget {
    /// Budget policy over the default ladder.  Panics unless the
    /// per-client budget is positive and finite.
    pub fn new(budget_j: f64) -> Self {
        assert!(
            budget_j > 0.0 && budget_j.is_finite(),
            "energy budget must be positive and finite"
        );
        EnergyBudget {
            ladder: SCHEME_LEVELS.iter().map(|&b| Precision::of(b)).collect(),
            budget_j,
        }
    }

    /// The per-client energy cap in joules.
    pub fn budget_j(&self) -> f64 {
        self.budget_j
    }
}

impl EnergyBudget {
    /// The (uniform) fleet level for this round's context — a pure
    /// function of the previous round's cumulative energy.
    fn level_for(&self, ctx: &PolicyCtx<'_>) -> Precision {
        let spent = ctx.prev.map(|r| r.energy_joules).unwrap_or(0.0);
        let frac = spent / (self.budget_j * ctx.clients as f64);
        let idx =
            ((frac * self.ladder.len() as f64) as usize).min(self.ladder.len() - 1);
        self.ladder[idx]
    }
}

impl PrecisionPolicy for EnergyBudget {
    fn assign_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        let p = self.level_for(ctx);
        out.clear();
        out.resize(ctx.clients, p);
        Ok(())
    }

    fn assign_selected_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        selected: &[usize],
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        let p = self.level_for(ctx);
        out.clear();
        out.resize(selected.len(), p);
        Ok(())
    }

    fn levels(&self) -> Vec<Precision> {
        self.ladder.clone()
    }

    fn label(&self) -> String {
        format!("energy-budget/{}J", self.budget_j)
    }
}

/// One client's accumulated profile: channel-gain and loss EWMAs plus
/// cumulative energy, grown one round at a time from [`RoundFeedback`].
#[derive(Clone, Copy, Debug, Default)]
pub struct Profile {
    /// EWMA of the observed channel amplitude |h|.
    pub gain_ewma: f32,
    /// EWMA of the local training loss.
    pub loss_ewma: f64,
    /// Cumulative energy this client has spent, in joules.
    pub energy_j: f64,
    /// Rounds this client has been observed in.
    pub seen: u32,
}

/// PER-CLIENT profiling planner: the payoff of identity-keyed state.
///
/// Where every other built-in assigns one level to the whole fleet, this
/// policy accumulates a per-client [`Profile`] (channel-gain EWMA,
/// cumulative energy, loss EWMA) in a bounded id-keyed LRU
/// ([`crate::fl::IdLru`], memory O(K) like the channel models) and picks
/// each client's level from ITS OWN effective SNR: a client whose fade or
/// geometry persistently attenuates its uplink by 20·log10(gain) dB gets
/// correspondingly fewer bits — precision the receiver noise floor would
/// destroy anyway — while a well-placed client keeps transmitting rich
/// payloads.  A positive per-client energy cap additionally demotes
/// clients that have spent past it one ladder rung (per-client
/// [`EnergyBudget`], not fleet-averaged).
///
/// Unprofiled clients (first selection, or evicted after long absence)
/// fall back to the configured SNR — exactly [`SnrAdaptive`]'s choice —
/// so the policy degrades gracefully to the fleet-wide baseline.
///
/// Assignment is a pure read of the profiles (the idempotency contract);
/// all state evolution happens in
/// [`observe_feedback`](PrecisionPolicy::observe_feedback), keyed on the
/// feedback round.
pub struct ProfilingPlanner {
    /// Candidate levels, descending bits (the full scheme ladder).
    ladder: Vec<Precision>,
    /// Per-client-ID profiles — bounded id-keyed LRU (capacity 2·K).
    profiles: IdLru<Profile>,
    /// Per-client cumulative energy cap in joules (0 = no cap): a client
    /// past it is demoted one ladder rung.
    energy_cap_j: f64,
    /// Last feedback round folded in (idempotency guard).
    last_round: usize,
}

/// EWMA smoothing factor for the per-client gain/loss trackers.
const PROFILE_EWMA_ALPHA: f64 = 0.25;

impl ProfilingPlanner {
    /// Planner over the full scheme ladder.  `energy_cap_j <= 0` disables
    /// the per-client energy demotion.
    pub fn new(energy_cap_j: f64) -> Self {
        ProfilingPlanner {
            ladder: SCHEME_LEVELS.iter().map(|&b| Precision::of(b)).collect(),
            profiles: IdLru::new(),
            energy_cap_j,
            last_round: 0,
        }
    }

    /// The accumulated profile of client `id`, if it is resident
    /// (observed recently enough not to have been evicted).  Read-only —
    /// does not perturb recency.
    pub fn profile_for(&self, id: usize) -> Option<&Profile> {
        self.profiles.get(id)
    }

    /// Ladder index of the cheapest level still reaching the SNR target
    /// (the [`SnrAdaptive`] rule).
    fn base_index(&self, snr_db: f32) -> usize {
        let target_bits = (snr_db / 6.02).ceil();
        let mut idx = 0usize;
        for (i, p) in self.ladder.iter().enumerate() {
            if (p.bits() as f32) >= target_bits {
                idx = i;
            } else {
                break;
            }
        }
        idx
    }

    /// This round's level for client `id` — a pure function of the
    /// resident profiles and `ctx`.
    fn level_for_id(&self, id: usize, ctx: &PolicyCtx<'_>) -> Precision {
        let profile = self.profiles.get(id);
        let eff_snr_db = match profile {
            // the client's own link: configured SNR shifted by its
            // observed mean power gain, 20·log10(|h|) dB
            Some(p) if p.seen > 0 => {
                ctx.snr_db + 20.0 * p.gain_ewma.max(1e-6).log10()
            }
            _ => ctx.snr_db,
        };
        let mut idx = self.base_index(eff_snr_db);
        if let Some(p) = profile {
            if self.energy_cap_j > 0.0
                && p.energy_j > self.energy_cap_j
                && idx + 1 < self.ladder.len()
            {
                idx += 1; // over budget: one rung cheaper
            }
        }
        self.ladder[idx]
    }
}

impl PrecisionPolicy for ProfilingPlanner {
    fn assign_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        out.clear();
        for id in 0..ctx.clients {
            out.push(self.level_for_id(id, ctx));
        }
        Ok(())
    }

    fn assign_selected_into(
        &mut self,
        ctx: &PolicyCtx<'_>,
        selected: &[usize],
        out: &mut Vec<Precision>,
    ) -> Result<()> {
        out.clear();
        for &id in selected {
            out.push(self.level_for_id(id, ctx));
        }
        Ok(())
    }

    fn observe_feedback(&mut self, fb: &RoundFeedback<'_>) {
        if fb.round <= self.last_round {
            return; // already folded in (idempotency per observed round)
        }
        self.last_round = fb.round;
        self.profiles.reserve(2 * fb.ids.len());
        for (slot, &id) in fb.ids.iter().enumerate() {
            let gain = fb.gains.get(slot).copied().unwrap_or(1.0);
            let energy = fb.energy_j.get(slot).copied().unwrap_or(0.0);
            let loss = fb.losses.get(slot).copied().unwrap_or(0.0);
            let (ps, fresh, _evicted) =
                self.profiles.get_or_insert_with(id, Profile::default);
            let p = self.profiles.value_mut(ps);
            if fresh {
                // seed the trackers with the first observation
                p.gain_ewma = gain;
                p.loss_ewma = loss;
            } else {
                let a = PROFILE_EWMA_ALPHA;
                p.gain_ewma = ((1.0 - a) * p.gain_ewma as f64 + a * gain as f64) as f32;
                p.loss_ewma = (1.0 - a) * p.loss_ewma + a * loss;
            }
            p.energy_j += energy;
            p.seen += 1;
        }
    }

    fn levels(&self) -> Vec<Precision> {
        // any client may land anywhere on the ladder
        self.ladder.clone()
    }

    fn label(&self) -> String {
        "profiling".to_string()
    }
}

/// The built-in policy named by the config's [`PolicyKind`].
pub fn from_config(kind: PolicyKind, cfg: &RunConfig) -> Box<dyn PrecisionPolicy> {
    match kind {
        PolicyKind::Static => Box::new(StaticScheme::new(cfg.scheme.clone())),
        PolicyKind::SnrAdaptive => {
            Box::new(SnrAdaptive::new().with_snr_hint(cfg.channel.snr_db))
        }
        PolicyKind::LossPlateau => {
            Box::new(LossPlateau::new().with_patience(cfg.plateau_patience))
        }
        PolicyKind::EnergyBudget => Box::new(EnergyBudget::new(cfg.energy_budget_j)),
        PolicyKind::Profiling => Box::new(ProfilingPlanner::new(cfg.energy_budget_j)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(round: usize, clients: usize, snr_db: f32) -> PolicyCtx<'static> {
        PolicyCtx { round, clients, snr_db, prev: None }
    }

    #[test]
    fn static_policy_matches_scheme_expansion() {
        let scheme = Scheme::parse("16,8,4").unwrap();
        let mut policy = StaticScheme::new(scheme.clone());
        let mut out = Vec::new();
        for t in 1..=3 {
            policy.assign_into(&ctx(t, 15, 20.0), &mut out).unwrap();
            assert_eq!(out, scheme.client_precisions(15).unwrap(), "round {t}");
        }
        assert_eq!(policy.levels(), scheme.distinct_levels());
        assert_eq!(policy.label(), "16,8,4");
    }

    #[test]
    fn static_policy_rejects_undivisible_fleet() {
        let mut policy = StaticScheme::new(Scheme::parse("16,8,4").unwrap());
        let mut out = Vec::new();
        assert!(policy.assign_into(&ctx(1, 14, 20.0), &mut out).is_err());
    }

    #[test]
    fn snr_adaptive_tracks_channel_quality() {
        let mut policy = SnrAdaptive::new();
        let mut out = Vec::new();
        // 20 dB: ceil(20/6.02) = 4 target bits -> cheapest level >= 4 is 4
        policy.assign_into(&ctx(1, 5, 20.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(4); 5]);
        // 45 dB: target 8 bits
        policy.assign_into(&ctx(1, 5, 45.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(8); 5]);
        // 90 dB: target 15 -> 16-bit
        policy.assign_into(&ctx(1, 5, 90.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(16); 5]);
        // absurdly clean channel: capped at the top of the ladder
        policy.assign_into(&ctx(1, 5, 500.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(32); 5]);
    }

    #[test]
    fn snr_hint_prunes_unreachable_levels() {
        // no hint: conservative full ladder
        assert_eq!(SnrAdaptive::new().levels().len(), SCHEME_LEVELS.len());
        // hint, no annealing: exactly the one reachable level
        let p = SnrAdaptive::new().with_snr_hint(20.0);
        assert_eq!(p.levels(), vec![Precision::of(4)]);
        // hint + annealing: the base level and everything below it
        let p = SnrAdaptive::new().with_snr_hint(90.0).with_annealing(3);
        assert_eq!(
            p.levels().iter().map(|p| p.bits()).collect::<Vec<_>>(),
            vec![16, 12, 8, 6, 4]
        );
        // from_config wires the hint from the run config
        let mut cfg = RunConfig::default();
        cfg.policy = PolicyKind::SnrAdaptive;
        cfg.channel.snr_db = 45.0;
        assert_eq!(
            from_config(cfg.policy, &cfg).levels(),
            vec![Precision::of(8)]
        );
    }

    fn rec(round: usize, loss: f64, energy: f64) -> RoundRecord {
        RoundRecord {
            round,
            server_loss: loss,
            energy_joules: energy,
            evaluated: true,
            ..Default::default()
        }
    }

    fn fctx<'a>(
        round: usize,
        clients: usize,
        prev: &'a RoundRecord,
    ) -> PolicyCtx<'a> {
        PolicyCtx { round, clients, snr_db: 20.0, prev: Some(prev) }
    }

    #[test]
    fn loss_plateau_promotes_on_stall_and_is_idempotent() {
        let mut p = LossPlateau::new().with_patience(2);
        let mut out = Vec::new();
        // round 1 (twice — construction + first round): no prev, cheapest
        for _ in 0..2 {
            p.assign_into(&ctx(1, 3, 20.0), &mut out).unwrap();
            assert_eq!(out, vec![Precision::of(4); 3]);
        }
        // improving loss: stays cheap
        let r1 = rec(1, 1.0, 0.0);
        p.assign_into(&fctx(2, 3, &r1), &mut out).unwrap();
        assert_eq!(p.current_bits(), 4);
        // re-invoking with the SAME observed round must not double-count
        p.assign_into(&fctx(2, 3, &r1), &mut out).unwrap();
        assert_eq!(p.current_bits(), 4);
        // stalled loss: promote after `patience` stalled observations
        let mut bits = Vec::new();
        let recs: Vec<RoundRecord> = (2..=8).map(|t| rec(t, 1.0, 0.0)).collect();
        for (i, r) in recs.iter().enumerate() {
            p.assign_into(&fctx(i + 3, 3, r), &mut out).unwrap();
            bits.push(out[0].bits());
        }
        assert_eq!(bits, vec![4, 6, 6, 8, 8, 12, 12]);
        assert_eq!(p.levels().len(), SCHEME_LEVELS.len());
        assert_eq!(p.label(), "loss-plateau/p2");
    }

    #[test]
    fn loss_plateau_start_bits_and_improvement_reset() {
        let mut p = LossPlateau::new().with_patience(1).with_start_bits(8);
        let mut out = Vec::new();
        p.assign_into(&ctx(1, 2, 20.0), &mut out).unwrap();
        assert_eq!(p.current_bits(), 8);
        // levels(): only the start level and everything above it
        assert_eq!(
            p.levels().iter().map(|l| l.bits()).collect::<Vec<_>>(),
            vec![32, 24, 16, 12, 8]
        );
        // a genuine improvement resets the stall counter
        let improving = [rec(1, 2.0, 0.0), rec(2, 1.0, 0.0), rec(3, 0.5, 0.0)];
        for (i, r) in improving.iter().enumerate() {
            p.assign_into(&fctx(i + 2, 2, r), &mut out).unwrap();
        }
        // first observation sets the baseline; each later one improves
        assert_eq!(p.current_bits(), 8);
    }

    #[test]
    fn loss_plateau_ignores_carried_forward_losses() {
        // eval_every > 1: non-eval rounds carry the last loss forward
        // with `evaluated == false` — they must not count as stalls, or
        // the policy would promote on a schedule instead of on the trend
        let mut p = LossPlateau::new().with_patience(2);
        let mut out = Vec::new();
        for t in 2..=12 {
            let mut r = rec(t - 1, 1.0, 0.0);
            r.evaluated = (t - 1) % 5 == 0; // fresh eval every 5th round
            p.assign_into(&fctx(t, 3, &r), &mut out).unwrap();
        }
        // only rounds 5 and 10 were fresh: baseline + one stall — no
        // promotion despite 11 wall-clock rounds of flat loss
        assert_eq!(p.current_bits(), 4);
    }

    #[test]
    fn energy_budget_demotes_as_budget_depletes() {
        let mut p = EnergyBudget::new(1.0); // 1 J per client
        let mut out = Vec::new();
        // no history: full precision
        p.assign_into(&ctx(1, 4, 20.0), &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(32); 4]);
        // fleet budget = 4 J, ladder has 7 levels
        let cases = [(0.0, 32u8), (2.0, 12), (3.9, 4), (100.0, 4)];
        for (spent, bits) in cases {
            let r = rec(1, 0.0, spent);
            p.assign_into(&fctx(2, 4, &r), &mut out).unwrap();
            assert_eq!(out[0].bits(), bits, "spent {spent}");
        }
        assert_eq!(p.levels().len(), SCHEME_LEVELS.len());
        assert_eq!(p.label(), "energy-budget/1J");
    }

    #[test]
    fn feedback_policies_from_config() {
        let mut cfg = RunConfig::default();
        cfg.policy = PolicyKind::LossPlateau;
        cfg.plateau_patience = 3;
        assert_eq!(
            from_config(cfg.policy, &cfg).label(),
            "loss-plateau/p3"
        );
        cfg.policy = PolicyKind::EnergyBudget;
        cfg.energy_budget_j = 2.5;
        assert_eq!(from_config(cfg.policy, &cfg).label(), "energy-budget/2.5J");
        cfg.policy = PolicyKind::Profiling;
        assert_eq!(from_config(cfg.policy, &cfg).label(), "profiling");
    }

    #[test]
    fn assign_selected_matches_fleet_gather_for_every_builtin() {
        // the O(K) overrides must equal gathering the fleet assignment at
        // the selected indices — including feedback-state evolution
        let selected = [0usize, 2, 7, 8, 11];
        let clients = 12usize;
        let mk: Vec<Box<dyn Fn() -> Box<dyn PrecisionPolicy>>> = vec![
            Box::new(|| -> Box<dyn PrecisionPolicy> {
                Box::new(StaticScheme::new(Scheme::parse("16,8,4").unwrap()))
            }),
            Box::new(|| -> Box<dyn PrecisionPolicy> {
                Box::new(SnrAdaptive::new().with_annealing(2))
            }),
            Box::new(|| -> Box<dyn PrecisionPolicy> {
                Box::new(LossPlateau::new().with_patience(1))
            }),
            Box::new(|| -> Box<dyn PrecisionPolicy> {
                Box::new(EnergyBudget::new(0.5))
            }),
            Box::new(|| -> Box<dyn PrecisionPolicy> {
                Box::new(ProfilingPlanner::new(0.5))
            }),
        ];
        for make in &mk {
            let mut fleet_pol = make();
            let mut sel_pol = make();
            let mut fleet = Vec::new();
            let mut sel = Vec::new();
            for t in 1..=8 {
                let r = rec(t.max(2) - 1, 1.0, (t as f64 - 1.0) * 0.8);
                let prev = if t == 1 { None } else { Some(&r) };
                let ctx = PolicyCtx { round: t, clients, snr_db: 20.0, prev };
                fleet_pol.assign_into(&ctx, &mut fleet).unwrap();
                sel_pol.assign_selected_into(&ctx, &selected, &mut sel).unwrap();
                let want: Vec<Precision> =
                    selected.iter().map(|&k| fleet[k]).collect();
                assert_eq!(sel, want, "{} round {t}", fleet_pol.label());
                // identical per-round feedback to both instances, so
                // profile-driven policies stay gather-consistent too
                let gains = [2.0f32, 1.0, 0.4, 0.05, 1.5];
                let energy = [0.1f64 * t as f64; 5];
                let losses = [1.0 / t as f64; 5];
                let fb = RoundFeedback {
                    round: t,
                    ids: &selected,
                    gains: &gains,
                    energy_j: &energy,
                    losses: &losses,
                };
                fleet_pol.observe_feedback(&fb);
                sel_pol.observe_feedback(&fb);
            }
        }
    }

    #[test]
    fn profiling_planner_assigns_per_client_from_observed_gains() {
        let mut p = ProfilingPlanner::new(0.0);
        let mut out = Vec::new();
        // unprofiled: everyone at the SnrAdaptive baseline (20 dB -> 4 bit)
        p.assign_selected_into(&ctx(1, 10, 20.0), &[3, 6], &mut out).unwrap();
        assert_eq!(out, vec![Precision::of(4); 2]);
        // observe: client 3 has a strong link (|h| = 10 -> +20 dB), client
        // 6 a deeply attenuated one (|h| = 0.01 -> -40 dB)
        let fb = RoundFeedback {
            round: 1,
            ids: &[3, 6],
            gains: &[10.0, 0.01],
            energy_j: &[0.0, 0.0],
            losses: &[0.5, 0.5],
        };
        p.observe_feedback(&fb);
        // idempotent per observed round
        p.observe_feedback(&fb);
        assert_eq!(p.profile_for(3).unwrap().seen, 1);
        assert_eq!(p.profile_for(9), None);
        // 20 + 20 = 40 dB -> 8-bit; 20 - 40 dB < 0 -> cheapest; id 9 is
        // unprofiled -> baseline.  DIFFERENT levels in the same round:
        // the per-client assignment no fleet-wide policy can express.
        p.assign_selected_into(&ctx(2, 10, 20.0), &[3, 6, 9], &mut out).unwrap();
        let bits: Vec<u8> = out.iter().map(|p| p.bits()).collect();
        assert_eq!(bits, vec![8, 4, 4]);
        assert_eq!(p.label(), "profiling");
        assert_eq!(p.levels().len(), SCHEME_LEVELS.len());
    }

    #[test]
    fn profiling_planner_energy_cap_demotes_overspenders() {
        let mut p = ProfilingPlanner::new(1.0);
        let mut out = Vec::new();
        let fb = RoundFeedback {
            round: 1,
            ids: &[0, 1],
            gains: &[1.0, 1.0],
            energy_j: &[2.0, 0.1],
            losses: &[0.0, 0.0],
        };
        p.observe_feedback(&fb);
        // 45 dB baseline is 8-bit; client 0 blew its 1 J cap -> 6-bit
        p.assign_selected_into(&ctx(2, 4, 45.0), &[0, 1], &mut out).unwrap();
        let bits: Vec<u8> = out.iter().map(|p| p.bits()).collect();
        assert_eq!(bits, vec![6, 8]);
    }

    #[test]
    fn default_assign_selected_gathers_from_custom_policies() {
        // a custom policy that only implements assign_into still works
        // through the default (materialize + gather) path
        struct OddEven;
        impl PrecisionPolicy for OddEven {
            fn assign_into(
                &mut self,
                ctx: &PolicyCtx<'_>,
                out: &mut Vec<Precision>,
            ) -> Result<()> {
                out.clear();
                for k in 0..ctx.clients {
                    out.push(Precision::of(if k % 2 == 0 { 16 } else { 4 }));
                }
                Ok(())
            }
            fn levels(&self) -> Vec<Precision> {
                vec![Precision::of(16), Precision::of(4)]
            }
            fn label(&self) -> String {
                "odd-even".into()
            }
        }
        let mut p = OddEven;
        let mut out = Vec::new();
        p.assign_selected_into(&ctx(1, 10, 20.0), &[1, 2, 5, 8], &mut out)
            .unwrap();
        let bits: Vec<u8> = out.iter().map(|p| p.bits()).collect();
        assert_eq!(bits, vec![4, 16, 4, 16]);
    }

    #[test]
    fn snr_adaptive_annealing_descends_the_ladder() {
        let mut policy = SnrAdaptive::new().with_annealing(2);
        let mut out = Vec::new();
        let mut seen = Vec::new();
        for t in 1..=8 {
            policy.assign_into(&ctx(t, 3, 90.0), &mut out).unwrap();
            seen.push(out[0].bits());
        }
        // base 16-bit at 90 dB, stepping down every 2 rounds
        assert_eq!(seen, vec![16, 16, 12, 12, 8, 8, 6, 6]);
        // never leaves the ladder
        let mut late = Vec::new();
        policy.assign_into(&ctx(1000, 3, 90.0), &mut late).unwrap();
        assert_eq!(late[0].bits(), 4);
    }
}
