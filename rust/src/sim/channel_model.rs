//! The [`ChannelModel`] seam: everything between "the round has K
//! transmitters" and "the server has a [`RoundChannel`] realisation".
//!
//! The built-in [`RayleighPilot`] model reproduces the paper's §III-A
//! pipeline (Rayleigh block fading → pilot LS estimation → truncated
//! channel inversion) with RNG consumption identical to the pre-redesign
//! coordinator, so default runs stay bit-identical per seed.  The
//! channel-realism models relax the paper's i.i.d.-per-round assumption
//! along the two axes real deployments violate it:
//!
//! * **time** — [`GaussMarkov`] evolves each client's coefficient as an
//!   AR(1) process ([`crate::channel::correlated`]), so fades persist
//!   across rounds; ρ = 0 is pinned bit-identical to [`RayleighPilot`];
//! * **space** — [`PathLossGeometry`] places clients on a disc with
//!   log-distance path loss + shadowing
//!   ([`crate::channel::geometry`]), so per-client mean SNR differs
//!   persistently across the run.
//!
//! All models implement the same trait and plug into a
//! [`crate::sim::Session`] or [`crate::sim::Experiment`] without touching
//! the round loop.

use crate::channel::{
    correlated, fading, geometry, pilot, ChannelConfig, ClientChannel, FadingKind,
    Precode, RoundChannel, C32,
};
use crate::rng::Rng;

/// Draws one round's channel realisation.
///
/// Contract: `draw_into` must fully overwrite `out` (the buffer is reused
/// round to round), must consume `rng` deterministically — the same model
/// state and RNG state in always yield the same realisation out — and
/// must not allocate once `out` AND the model's own state have warmed to
/// capacity.  Models MAY carry mutable state across rounds (that is
/// the whole point of correlated fading); such state must be (re)built
/// from the draw inputs on the first call, never eagerly per round, so
/// the steady-state round loop stays allocation-free
/// (`rust/tests/alloc_counter.rs` pins this through `Box<dyn
/// ChannelModel>`).
///
/// Fleet-scaling contract: `num_clients` is the number of PARTICIPANT
/// SLOTS this round (K), not the fleet size N — stateful models key
/// their memory by slot and are therefore lazily sized O(K), never
/// O(fleet): a 1M-client run with `clients_per_round = 64` builds
/// channel state for 64 slots only
/// (`rust/tests/channel_stats.rs::million_client_fleet_round_state_is_o_shard_not_o_fleet`).
pub trait ChannelModel {
    /// Fill `out` with `num_clients` client-channel states plus the server
    /// noise level for this round.
    fn draw_into(&mut self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel);

    /// Short model name for labels/reports.
    fn name(&self) -> &'static str;
}

/// The paper's physical layer: Rayleigh block fading, pilot-based LS
/// estimation (unless `perfect_csi`), truncated channel-inversion
/// precoding.  Owns the precomputed broadcast pilot sequence, exactly as
/// the pre-redesign round scratch did.
pub struct RayleighPilot {
    cfg: ChannelConfig,
    pilot: Vec<C32>,
}

impl RayleighPilot {
    /// Model from the run's channel config.
    pub fn new(cfg: ChannelConfig) -> Self {
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        RayleighPilot { cfg, pilot }
    }

    /// The channel config this model was built from.
    pub fn config(&self) -> &ChannelConfig {
        &self.cfg
    }
}

impl ChannelModel for RayleighPilot {
    fn draw_into(&mut self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel) {
        out.draw_into(&self.cfg, num_clients, rng, &self.pilot);
    }

    fn name(&self) -> &'static str {
        "rayleigh"
    }
}

/// No fading: every client arrives perfectly aligned with unit gain and
/// only the server AWGN (at `snr_db`) degrades the superposition.
/// Consumes no RNG draws — the receiver noise is injected downstream by
/// the aggregator from its own stream.
pub struct Awgn {
    /// Server receiver SNR in dB.
    pub snr_db: f32,
}

impl ChannelModel for Awgn {
    fn draw_into(&mut self, num_clients: usize, _rng: &mut Rng, out: &mut RoundChannel) {
        out.snr_db = self.snr_db;
        out.clients.clear();
        for _ in 0..num_clients {
            out.clients.push(ClientChannel {
                h: C32::ONE,
                h_est: C32::ONE,
                precode: Precode::Transmit(C32::ONE),
                effective_gain: Some(C32::ONE),
            });
        }
    }

    fn name(&self) -> &'static str {
        "awgn"
    }
}

/// Temporally correlated block fading: each client's coefficient evolves
/// as a first-order Gauss-Markov process,
/// `h(t) = ρ·h(t-1) + sqrt(1-ρ²)·w(t)` with `w ~ CN(0,1)`
/// ([`correlated::ar1_step`]); pilot estimation and precoding are exactly
/// the [`RayleighPilot`] tail.
///
/// Round 1 draws from the stationary distribution (the plain Rayleigh
/// coefficient), and the per-round RNG consumption is identical to
/// [`RayleighPilot`] for EVERY ρ — so ρ = 0 reproduces the i.i.d. path
/// bit-for-bit per seed (`rust/tests/sim.rs` pins this), and changing ρ
/// alone never shifts any downstream RNG stream.
pub struct GaussMarkov {
    cfg: ChannelConfig,
    pilot: Vec<C32>,
    /// Per-client AR(1) coefficients; client k uses `rhos[k % len]`, so a
    /// single entry broadcasts to the whole fleet.
    rhos: Vec<f32>,
    /// h(t-1) per client, sized on the first draw and reused after.
    state: Vec<C32>,
    /// Whether `state` holds a previous round (false before round 1 and
    /// after a fleet resize).
    warm: bool,
}

impl GaussMarkov {
    /// Model from the run's channel config: every client shares
    /// [`ChannelConfig::rho`].
    pub fn new(cfg: ChannelConfig) -> Self {
        let rho = cfg.rho;
        GaussMarkov::with_rhos(cfg, vec![rho])
    }

    /// Heterogeneous-mobility form: client `k` evolves with
    /// `rhos[k % rhos.len()]` (static clients near 1, vehicular clients
    /// near 0).  Panics if any ρ is outside `[0, 1)` or the list is
    /// empty.
    pub fn with_rhos(cfg: ChannelConfig, rhos: Vec<f32>) -> Self {
        assert!(!rhos.is_empty(), "need at least one rho");
        for &r in &rhos {
            assert!((0.0..1.0).contains(&r), "rho {r} must be in [0, 1)");
        }
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        GaussMarkov { cfg, pilot, rhos, state: Vec::new(), warm: false }
    }

    /// The AR(1) coefficient client `k` evolves with.
    pub fn rho_for(&self, k: usize) -> f32 {
        self.rhos[k % self.rhos.len()]
    }
}

impl ChannelModel for GaussMarkov {
    fn draw_into(&mut self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel) {
        if self.state.len() != num_clients {
            // first round (or a fleet resize): restart from stationarity
            self.state.clear();
            self.state.resize(num_clients, C32::ZERO);
            self.warm = false;
        }
        out.snr_db = self.cfg.snr_db;
        out.clients.clear();
        for k in 0..num_clients {
            let w = fading::rayleigh_coeff(rng);
            let h = if self.warm {
                correlated::ar1_step(self.state[k], self.rho_for(k), w)
            } else {
                w // stationary init: exactly the i.i.d. draw
            };
            self.state[k] = h;
            out.push_from_h(&self.cfg, h, rng, &self.pilot);
        }
        self.warm = true;
    }

    fn name(&self) -> &'static str {
        "gauss_markov"
    }
}

/// Spatial asymmetry: clients placed on a disc with log-distance path
/// loss and log-normal shadowing ([`geometry::place_clients`]).  The
/// geometry is drawn ONCE, lazily, from the round's channel RNG stream —
/// deterministic per seed and fixed for the whole run — and every round's
/// channel is `h_k(t) = a_k · g_k(t)`: the client's fixed amplitude scale
/// times a fresh unit-power Rayleigh draw.  Far or heavily-shadowed
/// clients therefore face persistently worse SNR (and more
/// truncation-silencing) than near ones.
pub struct PathLossGeometry {
    cfg: ChannelConfig,
    pilot: Vec<C32>,
    sites: Vec<geometry::Site>,
}

impl PathLossGeometry {
    /// Model from the run's channel config
    /// ([`ChannelConfig::cell_radius`], [`ChannelConfig::path_loss_exp`],
    /// [`ChannelConfig::shadowing_db`]).
    pub fn new(cfg: ChannelConfig) -> Self {
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        PathLossGeometry { cfg, pilot, sites: Vec::new() }
    }

    /// The fixed per-client geometry (empty until the first draw).
    pub fn sites(&self) -> &[geometry::Site] {
        &self.sites
    }
}

impl ChannelModel for PathLossGeometry {
    fn draw_into(&mut self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel) {
        if self.sites.len() != num_clients {
            // one-time placement from the same stream: deterministic per
            // seed, persistent across rounds
            self.sites = geometry::place_clients(
                num_clients,
                self.cfg.cell_radius,
                self.cfg.path_loss_exp,
                self.cfg.shadowing_db,
                rng,
            );
        }
        out.snr_db = self.cfg.snr_db;
        out.clients.clear();
        for site in &self.sites {
            let h = fading::rayleigh_coeff(rng).scale(site.amp);
            out.push_from_h(&self.cfg, h, rng, &self.pilot);
        }
    }

    fn name(&self) -> &'static str {
        "path_loss"
    }
}

/// The built-in model named by a [`ChannelConfig`].
pub fn from_config(cfg: &ChannelConfig) -> Box<dyn ChannelModel> {
    match cfg.model {
        FadingKind::Rayleigh => Box::new(RayleighPilot::new(cfg.clone())),
        FadingKind::Awgn => Box::new(Awgn { snr_db: cfg.snr_db }),
        FadingKind::GaussMarkov => Box::new(GaussMarkov::new(cfg.clone())),
        FadingKind::PathLoss => Box::new(PathLossGeometry::new(cfg.clone())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rayleigh_model_matches_direct_draw() {
        let cfg = ChannelConfig::default();
        let mut model = RayleighPilot::new(cfg.clone());
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        let mut r1 = Rng::seed_from(314);
        let mut r2 = Rng::seed_from(314);
        let mut via_model = RoundChannel::empty();
        let mut direct = RoundChannel::empty();
        for _ in 0..3 {
            model.draw_into(15, &mut r1, &mut via_model);
            direct.draw_into(&cfg, 15, &mut r2, &pilot);
            assert_eq!(via_model.clients.len(), 15);
            for (a, b) in via_model.clients.iter().zip(direct.clients.iter()) {
                assert_eq!(a.h, b.h);
                assert_eq!(a.h_est, b.h_est);
                assert_eq!(a.effective_gain, b.effective_gain);
            }
        }
        // identical RNG consumption
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn awgn_model_is_unit_gain_and_rng_free() {
        let mut model = Awgn { snr_db: 10.0 };
        let mut rng = Rng::seed_from(7);
        let before = rng.clone();
        let mut rc = RoundChannel::empty();
        model.draw_into(8, &mut rng, &mut rc);
        assert_eq!(rc.clients.len(), 8);
        assert_eq!(rc.snr_db, 10.0);
        for c in &rc.clients {
            assert_eq!(c.effective_gain, Some(C32::ONE));
        }
        assert_eq!(rng.next_u64(), before.clone().next_u64());
    }

    #[test]
    fn from_config_picks_model() {
        let mut cfg = ChannelConfig::default();
        assert_eq!(from_config(&cfg).name(), "rayleigh");
        cfg.model = FadingKind::Awgn;
        assert_eq!(from_config(&cfg).name(), "awgn");
        cfg.model = FadingKind::GaussMarkov;
        assert_eq!(from_config(&cfg).name(), "gauss_markov");
        cfg.model = FadingKind::PathLoss;
        assert_eq!(from_config(&cfg).name(), "path_loss");
    }

    #[test]
    fn gauss_markov_rho_zero_equals_rayleigh_pilot() {
        let cfg = ChannelConfig::default();
        assert_eq!(cfg.rho, 0.0);
        let mut gm = GaussMarkov::new(cfg.clone());
        let mut rp = RayleighPilot::new(cfg);
        let mut r1 = Rng::seed_from(99);
        let mut r2 = Rng::seed_from(99);
        let mut a = RoundChannel::empty();
        let mut b = RoundChannel::empty();
        for t in 0..4 {
            gm.draw_into(9, &mut r1, &mut a);
            rp.draw_into(9, &mut r2, &mut b);
            for (x, y) in a.clients.iter().zip(b.clients.iter()) {
                assert_eq!(x.h, y.h, "round {t}");
                assert_eq!(x.h_est, y.h_est, "round {t}");
                assert_eq!(x.effective_gain, y.effective_gain, "round {t}");
            }
        }
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn gauss_markov_high_rho_correlates_rounds() {
        let mut cfg = ChannelConfig::default();
        cfg.rho = 0.98;
        cfg.perfect_csi = true;
        let mut model = GaussMarkov::new(cfg);
        let mut rng = Rng::seed_from(4);
        let mut rc = RoundChannel::empty();
        model.draw_into(5, &mut rng, &mut rc);
        let first: Vec<C32> = rc.clients.iter().map(|c| c.h).collect();
        model.draw_into(5, &mut rng, &mut rc);
        for (c, f) in rc.clients.iter().zip(first.iter()) {
            // at rho=0.98 consecutive rounds stay close; an i.i.d. draw
            // would move by O(1) in expectation
            assert!((c.h - *f).abs() < 0.8, "jump {:?} -> {:?}", f, c.h);
        }
    }

    #[test]
    fn gauss_markov_per_client_rhos_broadcast() {
        let model =
            GaussMarkov::with_rhos(ChannelConfig::default(), vec![0.1, 0.5, 0.9]);
        assert_eq!(model.rho_for(0), 0.1);
        assert_eq!(model.rho_for(4), 0.5);
        assert_eq!(model.rho_for(8), 0.9);
    }

    #[test]
    fn path_loss_geometry_is_persistent_and_asymmetric() {
        let mut cfg = ChannelConfig::default();
        cfg.model = FadingKind::PathLoss;
        let mut model = PathLossGeometry::new(cfg);
        assert!(model.sites().is_empty());
        let mut rng = Rng::seed_from(15);
        let mut rc = RoundChannel::empty();
        model.draw_into(12, &mut rng, &mut rc);
        let first: Vec<f32> = model.sites().iter().map(|s| s.amp).collect();
        assert_eq!(first.len(), 12);
        // asymmetry: amplitude scales genuinely differ across the fleet
        let (lo, hi) = first
            .iter()
            .fold((f32::INFINITY, 0.0f32), |(l, h), &a| (l.min(a), h.max(a)));
        assert!(hi / lo > 1.5, "gain spread {lo}..{hi} too flat");
        // persistence: the same sites back every round
        for _ in 0..3 {
            model.draw_into(12, &mut rng, &mut rc);
            let again: Vec<f32> = model.sites().iter().map(|s| s.amp).collect();
            assert_eq!(first, again);
        }
    }
}
