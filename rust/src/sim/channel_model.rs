//! The [`ChannelModel`] seam: everything between "the round has K
//! transmitters" and "the server has a [`RoundChannel`] realisation".
//!
//! The built-in [`RayleighPilot`] model reproduces the paper's §III-A
//! pipeline (Rayleigh block fading → pilot LS estimation → truncated
//! channel inversion) with RNG consumption identical to the pre-redesign
//! coordinator, so default runs stay bit-identical per seed.  The
//! channel-realism models relax the paper's i.i.d.-per-round assumption
//! along the two axes real deployments violate it:
//!
//! * **time** — [`GaussMarkov`] evolves each client's coefficient as an
//!   AR(1) process ([`crate::channel::correlated`]), so fades persist
//!   across rounds; ρ = 0 is pinned bit-identical to [`RayleighPilot`];
//! * **space** — [`PathLossGeometry`] places clients on a disc with
//!   log-distance path loss + shadowing
//!   ([`crate::channel::geometry`]), so per-client mean SNR differs
//!   persistently across the run.
//!
//! All models implement the same trait and plug into a
//! [`crate::sim::Session`] or [`crate::sim::Experiment`] without touching
//! the round loop.

use crate::channel::{
    correlated, fading, geometry, pilot, ChannelConfig, ClientChannel, FadingKind,
    Precode, RoundChannel, C32,
};
use crate::fl::IdLru;
use crate::rng::Rng;

/// Draws one round's channel realisation.
///
/// Contract: `draw_into`/`draw_for` must fully overwrite `out` (the
/// buffer is reused round to round), must consume `rng` deterministically
/// — the same model state and RNG state in always yield the same
/// realisation out — and must not allocate once `out` AND the model's own
/// state have warmed to capacity.  Models MAY carry mutable state across
/// rounds (that is the whole point of correlated fading); such state must
/// be (re)built from the draw inputs on the first call, never eagerly per
/// round, so the steady-state round loop stays allocation-free
/// (`rust/tests/alloc_counter.rs` pins this through `Box<dyn
/// ChannelModel>`).
///
/// Fleet-scaling contract: persistent per-client state is keyed by
/// CLIENT IDENTITY, never by the participant slot a client happens to
/// occupy this round, and it lives in a bounded id-keyed LRU
/// ([`crate::fl::IdLru`]) of capacity 2·K — so a far client keeps its
/// site and a slow-moving client keeps its fade across random
/// (`UniformK`/`SampledK`) selection, whichever slot it lands in, while
/// memory stays O(K), never O(fleet): a 1M-client run with
/// `clients_per_round = 64` holds channel state for at most 128 resident
/// clients
/// (`rust/tests/channel_stats.rs::million_client_fleet_round_state_is_o_shard_not_o_fleet`).
/// A client evicted after long absence re-enters from the stationary
/// distribution, exactly like a first-time participant.
pub trait ChannelModel {
    /// Fill `out` with `num_clients` client-channel states plus the server
    /// noise level for this round, treating slot `k` as client id `k`
    /// (full participation / round-robin, where slot == id).
    fn draw_into(&mut self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel);

    /// Identity-aware entry: fill `out` with one client-channel state per
    /// entry of `ids` (this round's selected client identities, pairwise
    /// distinct), in slot order.  Stateful models key their memory by
    /// these ids; the default delegates to [`ChannelModel::draw_into`],
    /// which is exact for stateless models (the realisation does not
    /// depend on who transmits).
    fn draw_for(&mut self, ids: &[usize], rng: &mut Rng, out: &mut RoundChannel) {
        self.draw_into(ids.len(), rng, out);
    }

    /// Short model name for labels/reports.
    fn name(&self) -> &'static str;
}

/// The paper's physical layer: Rayleigh block fading, pilot-based LS
/// estimation (unless `perfect_csi`), truncated channel-inversion
/// precoding.  Owns the precomputed broadcast pilot sequence, exactly as
/// the pre-redesign round scratch did.
pub struct RayleighPilot {
    cfg: ChannelConfig,
    pilot: Vec<C32>,
}

impl RayleighPilot {
    /// Model from the run's channel config.
    pub fn new(cfg: ChannelConfig) -> Self {
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        RayleighPilot { cfg, pilot }
    }

    /// The channel config this model was built from.
    pub fn config(&self) -> &ChannelConfig {
        &self.cfg
    }
}

impl ChannelModel for RayleighPilot {
    fn draw_into(&mut self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel) {
        out.draw_into(&self.cfg, num_clients, rng, &self.pilot);
    }

    fn name(&self) -> &'static str {
        "rayleigh"
    }
}

/// No fading: every client arrives perfectly aligned with unit gain and
/// only the server AWGN (at `snr_db`) degrades the superposition.
/// Consumes no RNG draws — the receiver noise is injected downstream by
/// the aggregator from its own stream.
pub struct Awgn {
    /// Server receiver SNR in dB.
    pub snr_db: f32,
}

impl ChannelModel for Awgn {
    fn draw_into(&mut self, num_clients: usize, _rng: &mut Rng, out: &mut RoundChannel) {
        out.snr_db = self.snr_db;
        out.clients.clear();
        for _ in 0..num_clients {
            out.clients.push(ClientChannel {
                h: C32::ONE,
                h_est: C32::ONE,
                precode: Precode::Transmit(C32::ONE),
                effective_gain: Some(C32::ONE),
            });
        }
    }

    fn name(&self) -> &'static str {
        "awgn"
    }
}

/// Temporally correlated block fading: each client's coefficient evolves
/// as a first-order Gauss-Markov process,
/// `h(t) = ρ·h(t-1) + sqrt(1-ρ²)·w(t)` with `w ~ CN(0,1)`
/// ([`correlated::ar1_step`]); pilot estimation and precoding are exactly
/// the [`RayleighPilot`] tail.
///
/// Round 1 draws from the stationary distribution (the plain Rayleigh
/// coefficient), and the per-round RNG consumption is identical to
/// [`RayleighPilot`] for EVERY ρ — so ρ = 0 reproduces the i.i.d. path
/// bit-for-bit per seed (`rust/tests/sim.rs` pins this), and changing ρ
/// alone never shifts any downstream RNG stream.
pub struct GaussMarkov {
    cfg: ChannelConfig,
    pilot: Vec<C32>,
    /// Per-client AR(1) coefficients; client ID `k` uses `rhos[k % len]`,
    /// so a single entry broadcasts to the whole fleet.  The coefficient
    /// attaches to the identity, not the slot: a heterogeneous-mobility
    /// fleet keeps each client's mobility profile under random selection.
    rhos: Vec<f32>,
    /// h(t-1) per client ID — bounded id-keyed LRU (capacity 2·K).  A
    /// client absent long enough to be evicted re-enters from the
    /// stationary distribution; a client that merely skips rounds (or
    /// survives a K-shrinking deadline/dropout round) keeps its fade.
    lru: IdLru<C32>,
    /// Identity list scratch for the slot==id compat path (`draw_into`).
    ids_scratch: Vec<usize>,
}

impl GaussMarkov {
    /// Model from the run's channel config: every client shares
    /// [`ChannelConfig::rho`].
    pub fn new(cfg: ChannelConfig) -> Self {
        let rho = cfg.rho;
        GaussMarkov::with_rhos(cfg, vec![rho])
    }

    /// Heterogeneous-mobility form: client ID `k` evolves with
    /// `rhos[k % rhos.len()]` (static clients near 1, vehicular clients
    /// near 0).  Panics if any ρ is outside `[0, 1)` or the list is
    /// empty.
    pub fn with_rhos(cfg: ChannelConfig, rhos: Vec<f32>) -> Self {
        assert!(!rhos.is_empty(), "need at least one rho");
        for &r in &rhos {
            assert!((0.0..1.0).contains(&r), "rho {r} must be in [0, 1)");
        }
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        GaussMarkov { cfg, pilot, rhos, lru: IdLru::new(), ids_scratch: Vec::new() }
    }

    /// The AR(1) coefficient client ID `k` evolves with.
    pub fn rho_for(&self, k: usize) -> f32 {
        self.rhos[k % self.rhos.len()]
    }

    /// The resident h(t-1) of client `id`, if it has fading memory
    /// (selected recently enough not to have been evicted).  Read-only —
    /// does not perturb recency.
    pub fn h_for(&self, id: usize) -> Option<C32> {
        self.lru.get(id).copied()
    }
}

impl ChannelModel for GaussMarkov {
    fn draw_into(&mut self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel) {
        // slot==id compat path (full participation / round-robin)
        let mut ids = std::mem::take(&mut self.ids_scratch);
        ids.clear();
        ids.extend(0..num_clients);
        self.draw_for(&ids, rng, out);
        self.ids_scratch = ids;
    }

    fn draw_for(&mut self, ids: &[usize], rng: &mut Rng, out: &mut RoundChannel) {
        // capacity 2·K: this round's participants can never evict each
        // other (see the IdLru capacity protocol)
        self.lru.reserve(2 * ids.len());
        out.snr_db = self.cfg.snr_db;
        out.clients.clear();
        for &id in ids {
            // one stationary draw per slot regardless of residency, so
            // RNG consumption is selection-independent per slot
            let w = fading::rayleigh_coeff(rng);
            let rho = self.rhos[id % self.rhos.len()];
            let (slot, fresh, _evicted) = self.lru.get_or_insert_with(id, || C32::ZERO);
            let s = self.lru.value_mut(slot);
            let h = if fresh {
                w // stationary init: exactly the i.i.d. draw
            } else {
                correlated::ar1_step(*s, rho, w)
            };
            *s = h;
            out.push_from_h(&self.cfg, h, rng, &self.pilot);
        }
    }

    fn name(&self) -> &'static str {
        "gauss_markov"
    }
}

/// Spatial asymmetry: clients placed on a disc with log-distance path
/// loss and log-normal shadowing ([`geometry::place_one_raw`]).  A
/// client's site is drawn ONCE, lazily, the first round that client is
/// selected — deterministic per seed and persistent for as long as the
/// client stays resident in the bounded id-keyed LRU — and every round's
/// channel is `h_k(t) = a_k · g_k(t)`: the client's fixed amplitude scale
/// times a fresh unit-power Rayleigh draw.  Far or heavily-shadowed
/// clients therefore face persistently worse SNR (and more
/// truncation-silencing) than near ones, whichever slot they occupy.
///
/// Normalization: the FIRST cohort is normalized to mean unit power gain
/// (exactly [`geometry::place_clients`] under full participation, so the
/// SNR knob keeps its calibrated meaning); later first-timers are
/// normalized against that same stored mean, so one client's gain never
/// depends on who else shows up.
pub struct PathLossGeometry {
    cfg: ChannelConfig,
    pilot: Vec<C32>,
    /// Per-client-ID site — bounded id-keyed LRU (capacity 2·K).  An
    /// evicted client re-enters with a freshly drawn site, like a new
    /// arrival at a new position.
    lru: IdLru<geometry::Site>,
    /// Mean raw power gain of the first cohort, the fleet normalizer for
    /// every later placement (None until the first non-empty draw).
    mean_gain: Option<f64>,
    /// Identity list scratch for the slot==id compat path (`draw_into`).
    ids_scratch: Vec<usize>,
}

impl PathLossGeometry {
    /// Model from the run's channel config
    /// ([`ChannelConfig::cell_radius`], [`ChannelConfig::path_loss_exp`],
    /// [`ChannelConfig::shadowing_db`]).
    pub fn new(cfg: ChannelConfig) -> Self {
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        PathLossGeometry {
            cfg,
            pilot,
            lru: IdLru::new(),
            mean_gain: None,
            ids_scratch: Vec::new(),
        }
    }

    /// The resident per-client geometry in placement order (empty until
    /// the first draw).  Under full participation placement order is id
    /// order, matching the pre-id-keyed slot table.
    pub fn sites(&self) -> &[geometry::Site] {
        self.lru.values()
    }

    /// The resident site of client `id`, if it has been placed (selected
    /// recently enough not to have been evicted).  Read-only — does not
    /// perturb recency.
    pub fn site_for(&self, id: usize) -> Option<&geometry::Site> {
        self.lru.get(id)
    }
}

impl ChannelModel for PathLossGeometry {
    fn draw_into(&mut self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel) {
        // slot==id compat path (full participation / round-robin)
        let mut ids = std::mem::take(&mut self.ids_scratch);
        ids.clear();
        ids.extend(0..num_clients);
        self.draw_for(&ids, rng, out);
        self.ids_scratch = ids;
    }

    fn draw_for(&mut self, ids: &[usize], rng: &mut Rng, out: &mut RoundChannel) {
        out.snr_db = self.cfg.snr_db;
        out.clients.clear();
        if ids.is_empty() {
            return; // nothing to place — keep mean_gain unset
        }
        // capacity 2·K: this round's participants can never evict each
        // other (see the IdLru capacity protocol)
        self.lru.reserve(2 * ids.len());
        let radius = self.cfg.cell_radius;
        let alpha = self.cfg.path_loss_exp;
        let shadow = self.cfg.shadowing_db;
        match self.mean_gain {
            None => {
                // first cohort: place everyone, then normalize the cohort
                // to mean unit power gain — bit-identical to
                // geometry::place_clients under full participation
                let mut mean = 0.0f64;
                for &id in ids {
                    let site = geometry::place_one_raw(radius, alpha, shadow, rng);
                    mean += site.amp as f64;
                    self.lru.get_or_insert_with(id, || site);
                }
                mean /= ids.len() as f64;
                for s in self.lru.values_mut() {
                    s.amp = ((s.amp as f64 / mean).sqrt()) as f32;
                }
                self.mean_gain = Some(mean);
            }
            Some(mean) => {
                // later rounds: place only unseen ids, normalized against
                // the stored first-cohort mean; residents just refresh
                // their recency
                for &id in ids {
                    let (slot, fresh, _evicted) = self
                        .lru
                        .get_or_insert_with(id, || {
                            geometry::place_one_raw(radius, alpha, shadow, rng)
                        });
                    if fresh {
                        let s = self.lru.value_mut(slot);
                        s.amp = ((s.amp as f64 / mean).sqrt()) as f32;
                    }
                }
            }
        }
        for &id in ids {
            let amp = self
                .lru
                .get(id)
                .expect("capacity 2K keeps the round's ids resident")
                .amp;
            let h = fading::rayleigh_coeff(rng).scale(amp);
            out.push_from_h(&self.cfg, h, rng, &self.pilot);
        }
    }

    fn name(&self) -> &'static str {
        "path_loss"
    }
}

/// The built-in model named by a [`ChannelConfig`].
pub fn from_config(cfg: &ChannelConfig) -> Box<dyn ChannelModel> {
    match cfg.model {
        FadingKind::Rayleigh => Box::new(RayleighPilot::new(cfg.clone())),
        FadingKind::Awgn => Box::new(Awgn { snr_db: cfg.snr_db }),
        FadingKind::GaussMarkov => Box::new(GaussMarkov::new(cfg.clone())),
        FadingKind::PathLoss => Box::new(PathLossGeometry::new(cfg.clone())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rayleigh_model_matches_direct_draw() {
        let cfg = ChannelConfig::default();
        let mut model = RayleighPilot::new(cfg.clone());
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        let mut r1 = Rng::seed_from(314);
        let mut r2 = Rng::seed_from(314);
        let mut via_model = RoundChannel::empty();
        let mut direct = RoundChannel::empty();
        for _ in 0..3 {
            model.draw_into(15, &mut r1, &mut via_model);
            direct.draw_into(&cfg, 15, &mut r2, &pilot);
            assert_eq!(via_model.clients.len(), 15);
            for (a, b) in via_model.clients.iter().zip(direct.clients.iter()) {
                assert_eq!(a.h, b.h);
                assert_eq!(a.h_est, b.h_est);
                assert_eq!(a.effective_gain, b.effective_gain);
            }
        }
        // identical RNG consumption
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn awgn_model_is_unit_gain_and_rng_free() {
        let mut model = Awgn { snr_db: 10.0 };
        let mut rng = Rng::seed_from(7);
        let before = rng.clone();
        let mut rc = RoundChannel::empty();
        model.draw_into(8, &mut rng, &mut rc);
        assert_eq!(rc.clients.len(), 8);
        assert_eq!(rc.snr_db, 10.0);
        for c in &rc.clients {
            assert_eq!(c.effective_gain, Some(C32::ONE));
        }
        assert_eq!(rng.next_u64(), before.clone().next_u64());
    }

    #[test]
    fn from_config_picks_model() {
        let mut cfg = ChannelConfig::default();
        assert_eq!(from_config(&cfg).name(), "rayleigh");
        cfg.model = FadingKind::Awgn;
        assert_eq!(from_config(&cfg).name(), "awgn");
        cfg.model = FadingKind::GaussMarkov;
        assert_eq!(from_config(&cfg).name(), "gauss_markov");
        cfg.model = FadingKind::PathLoss;
        assert_eq!(from_config(&cfg).name(), "path_loss");
    }

    #[test]
    fn gauss_markov_rho_zero_equals_rayleigh_pilot() {
        let cfg = ChannelConfig::default();
        assert_eq!(cfg.rho, 0.0);
        let mut gm = GaussMarkov::new(cfg.clone());
        let mut rp = RayleighPilot::new(cfg);
        let mut r1 = Rng::seed_from(99);
        let mut r2 = Rng::seed_from(99);
        let mut a = RoundChannel::empty();
        let mut b = RoundChannel::empty();
        for t in 0..4 {
            gm.draw_into(9, &mut r1, &mut a);
            rp.draw_into(9, &mut r2, &mut b);
            for (x, y) in a.clients.iter().zip(b.clients.iter()) {
                assert_eq!(x.h, y.h, "round {t}");
                assert_eq!(x.h_est, y.h_est, "round {t}");
                assert_eq!(x.effective_gain, y.effective_gain, "round {t}");
            }
        }
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn gauss_markov_high_rho_correlates_rounds() {
        let mut cfg = ChannelConfig::default();
        cfg.rho = 0.98;
        cfg.perfect_csi = true;
        let mut model = GaussMarkov::new(cfg);
        let mut rng = Rng::seed_from(4);
        let mut rc = RoundChannel::empty();
        model.draw_into(5, &mut rng, &mut rc);
        let first: Vec<C32> = rc.clients.iter().map(|c| c.h).collect();
        model.draw_into(5, &mut rng, &mut rc);
        for (c, f) in rc.clients.iter().zip(first.iter()) {
            // at rho=0.98 consecutive rounds stay close; an i.i.d. draw
            // would move by O(1) in expectation
            assert!((c.h - *f).abs() < 0.8, "jump {:?} -> {:?}", f, c.h);
        }
    }

    #[test]
    fn gauss_markov_per_client_rhos_broadcast() {
        let model =
            GaussMarkov::with_rhos(ChannelConfig::default(), vec![0.1, 0.5, 0.9]);
        assert_eq!(model.rho_for(0), 0.1);
        assert_eq!(model.rho_for(4), 0.5);
        assert_eq!(model.rho_for(8), 0.9);
    }

    #[test]
    fn gauss_markov_state_follows_the_client_id_across_slots() {
        let mut cfg = ChannelConfig::default();
        cfg.rho = 0.9;
        let mut model = GaussMarkov::new(cfg);
        let mut rng = Rng::seed_from(21);
        let mut rc = RoundChannel::empty();
        model.draw_for(&[5, 9], &mut rng, &mut rc);
        let h5 = model.h_for(5).expect("id 5 resident");
        let h9 = model.h_for(9).expect("id 9 resident");
        assert_eq!(h5, rc.clients[0].h);
        assert_eq!(h9, rc.clients[1].h);
        assert_eq!(model.h_for(0), None, "never-selected id has no state");
        // swapped slots: slot 0 must continue id 9's OWN fade, exactly
        let mut probe = rng.clone();
        model.draw_for(&[9, 5], &mut rng, &mut rc);
        let w0 = fading::rayleigh_coeff(&mut probe);
        assert_eq!(
            rc.clients[0].h,
            correlated::ar1_step(h9, 0.9, w0),
            "slot 0 must continue id 9's state, not the old slot-0 state"
        );
        // a round without id 5 leaves its memory untouched
        let h5_now = model.h_for(5).unwrap();
        model.draw_for(&[9], &mut rng, &mut rc);
        assert_eq!(model.h_for(5), Some(h5_now));
    }

    #[test]
    fn gauss_markov_heterogeneous_rho_attaches_to_the_id() {
        let cfg = ChannelConfig::default();
        let mut model = GaussMarkov::with_rhos(cfg, vec![0.1, 0.5, 0.9]);
        let mut rng = Rng::seed_from(8);
        let mut rc = RoundChannel::empty();
        model.draw_for(&[2], &mut rng, &mut rc);
        let h2 = rc.clients[0].h;
        let mut probe = rng.clone();
        model.draw_for(&[2], &mut rng, &mut rc);
        let w = fading::rayleigh_coeff(&mut probe);
        // id 2 evolves with rhos[2 % 3] = 0.9 even though it occupies
        // slot 0 — slot-keyed indexing would use rhos[0] = 0.1
        assert_eq!(rc.clients[0].h, correlated::ar1_step(h2, 0.9, w));
        assert_ne!(rc.clients[0].h, correlated::ar1_step(h2, 0.1, w));
    }

    #[test]
    fn gauss_markov_varying_k_keeps_surviving_clients_fade() {
        // deadline/dropout rounds shrink K between rounds; survivors must
        // keep their h(t-1) instead of restarting from stationarity
        let mut cfg = ChannelConfig::default();
        cfg.rho = 0.9;
        let mut model = GaussMarkov::new(cfg);
        let mut rng = Rng::seed_from(77);
        let mut rc = RoundChannel::empty();
        model.draw_for(&[0, 1, 2, 3], &mut rng, &mut rc);
        let h1 = model.h_for(1).unwrap();
        let mut probe = rng.clone();
        model.draw_for(&[1, 3], &mut rng, &mut rc); // K shrank: 4 -> 2
        let w = fading::rayleigh_coeff(&mut probe);
        assert_eq!(
            rc.clients[0].h,
            correlated::ar1_step(h1, 0.9, w),
            "survivor restarted from stationarity on a fleet resize"
        );
    }

    #[test]
    fn path_loss_sites_follow_the_client_id_across_slots() {
        let mut cfg = ChannelConfig::default();
        cfg.model = FadingKind::PathLoss;
        let mut model = PathLossGeometry::new(cfg);
        let mut rng = Rng::seed_from(12);
        let mut rc = RoundChannel::empty();
        model.draw_for(&[4, 11, 30], &mut rng, &mut rc);
        let site11 = *model.site_for(11).expect("placed on first selection");
        // first cohort is normalized to mean unit power gain
        let mean_pow: f64 = model
            .sites()
            .iter()
            .map(|s| (s.amp as f64) * (s.amp as f64))
            .sum::<f64>()
            / 3.0;
        assert!((mean_pow - 1.0).abs() < 1e-3, "mean power gain {mean_pow}");
        // reselected in a different slot: same site, bit for bit
        model.draw_for(&[11], &mut rng, &mut rc);
        let again = model.site_for(11).unwrap();
        assert_eq!(site11.amp.to_bits(), again.amp.to_bits());
        assert_eq!(site11.distance.to_bits(), again.distance.to_bits());
        // a later first-timer gets placed against the stored normalizer
        assert_eq!(model.site_for(99), None);
        model.draw_for(&[99, 11], &mut rng, &mut rc);
        assert!(model.site_for(99).unwrap().amp > 0.0);
        assert_eq!(model.sites().len(), 4, "one site per distinct id");
    }

    #[test]
    fn path_loss_empty_round_is_a_no_op() {
        let mut cfg = ChannelConfig::default();
        cfg.model = FadingKind::PathLoss;
        let mut model = PathLossGeometry::new(cfg);
        let mut rng = Rng::seed_from(5);
        let before = rng.clone();
        let mut rc = RoundChannel::empty();
        model.draw_for(&[], &mut rng, &mut rc);
        assert!(rc.clients.is_empty());
        assert!(model.sites().is_empty());
        assert_eq!(rng.next_u64(), before.clone().next_u64());
        // the normalizer is still unset: the NEXT non-empty cohort
        // calibrates it
        model.draw_for(&[3, 8], &mut rng, &mut rc);
        let mean_pow: f64 = model
            .sites()
            .iter()
            .map(|s| (s.amp as f64) * (s.amp as f64))
            .sum::<f64>()
            / 2.0;
        assert!((mean_pow - 1.0).abs() < 1e-3);
    }

    #[test]
    fn path_loss_geometry_is_persistent_and_asymmetric() {
        let mut cfg = ChannelConfig::default();
        cfg.model = FadingKind::PathLoss;
        let mut model = PathLossGeometry::new(cfg);
        assert!(model.sites().is_empty());
        let mut rng = Rng::seed_from(15);
        let mut rc = RoundChannel::empty();
        model.draw_into(12, &mut rng, &mut rc);
        let first: Vec<f32> = model.sites().iter().map(|s| s.amp).collect();
        assert_eq!(first.len(), 12);
        // asymmetry: amplitude scales genuinely differ across the fleet
        let (lo, hi) = first
            .iter()
            .fold((f32::INFINITY, 0.0f32), |(l, h), &a| (l.min(a), h.max(a)));
        assert!(hi / lo > 1.5, "gain spread {lo}..{hi} too flat");
        // persistence: the same sites back every round
        for _ in 0..3 {
            model.draw_into(12, &mut rng, &mut rc);
            let again: Vec<f32> = model.sites().iter().map(|s| s.amp).collect();
            assert_eq!(first, again);
        }
    }
}
