//! The [`ChannelModel`] seam: everything between "the round has K
//! transmitters" and "the server has a [`RoundChannel`] realisation".
//!
//! The built-in [`RayleighPilot`] model reproduces the paper's §III-A
//! pipeline (Rayleigh block fading → pilot LS estimation → truncated
//! channel inversion) with RNG consumption identical to the pre-redesign
//! coordinator, so default runs stay bit-identical per seed.  Alternate
//! fading/CSI models implement the same trait and plug into a
//! [`crate::sim::Session`] or [`crate::sim::Experiment`] without touching
//! the round loop.

use crate::channel::{
    pilot, ChannelConfig, ClientChannel, FadingKind, Precode, RoundChannel, C32,
};
use crate::rng::Rng;

/// Draws one round's channel realisation.
///
/// Contract: `draw_into` must fully overwrite `out` (the buffer is reused
/// round to round), must not allocate once `out` has warmed to fleet
/// capacity, and must consume `rng` deterministically — the same state in
/// always yields the same realisation out.
pub trait ChannelModel {
    /// Fill `out` with `num_clients` client-channel states plus the server
    /// noise level for this round.
    fn draw_into(&self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel);

    /// Short model name for labels/reports.
    fn name(&self) -> &'static str;
}

/// The paper's physical layer: Rayleigh block fading, pilot-based LS
/// estimation (unless `perfect_csi`), truncated channel-inversion
/// precoding.  Owns the precomputed broadcast pilot sequence, exactly as
/// the pre-redesign round scratch did.
pub struct RayleighPilot {
    cfg: ChannelConfig,
    pilot: Vec<C32>,
}

impl RayleighPilot {
    pub fn new(cfg: ChannelConfig) -> Self {
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        RayleighPilot { cfg, pilot }
    }

    pub fn config(&self) -> &ChannelConfig {
        &self.cfg
    }
}

impl ChannelModel for RayleighPilot {
    fn draw_into(&self, num_clients: usize, rng: &mut Rng, out: &mut RoundChannel) {
        out.draw_into(&self.cfg, num_clients, rng, &self.pilot);
    }

    fn name(&self) -> &'static str {
        "rayleigh"
    }
}

/// No fading: every client arrives perfectly aligned with unit gain and
/// only the server AWGN (at `snr_db`) degrades the superposition.
/// Consumes no RNG draws — the receiver noise is injected downstream by
/// the aggregator from its own stream.
pub struct Awgn {
    pub snr_db: f32,
}

impl ChannelModel for Awgn {
    fn draw_into(&self, num_clients: usize, _rng: &mut Rng, out: &mut RoundChannel) {
        out.snr_db = self.snr_db;
        out.clients.clear();
        for _ in 0..num_clients {
            out.clients.push(ClientChannel {
                h: C32::ONE,
                h_est: C32::ONE,
                precode: Precode::Transmit(C32::ONE),
                effective_gain: Some(C32::ONE),
            });
        }
    }

    fn name(&self) -> &'static str {
        "awgn"
    }
}

/// The built-in model named by a [`ChannelConfig`].
pub fn from_config(cfg: &ChannelConfig) -> Box<dyn ChannelModel> {
    match cfg.model {
        FadingKind::Rayleigh => Box::new(RayleighPilot::new(cfg.clone())),
        FadingKind::Awgn => Box::new(Awgn { snr_db: cfg.snr_db }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rayleigh_model_matches_direct_draw() {
        let cfg = ChannelConfig::default();
        let model = RayleighPilot::new(cfg.clone());
        let pilot = pilot::pilot_sequence(cfg.pilot_len);
        let mut r1 = Rng::seed_from(314);
        let mut r2 = Rng::seed_from(314);
        let mut via_model = RoundChannel::empty();
        let mut direct = RoundChannel::empty();
        for _ in 0..3 {
            model.draw_into(15, &mut r1, &mut via_model);
            direct.draw_into(&cfg, 15, &mut r2, &pilot);
            assert_eq!(via_model.clients.len(), 15);
            for (a, b) in via_model.clients.iter().zip(direct.clients.iter()) {
                assert_eq!(a.h, b.h);
                assert_eq!(a.h_est, b.h_est);
                assert_eq!(a.effective_gain, b.effective_gain);
            }
        }
        // identical RNG consumption
        assert_eq!(r1.next_u64(), r2.next_u64());
    }

    #[test]
    fn awgn_model_is_unit_gain_and_rng_free() {
        let model = Awgn { snr_db: 10.0 };
        let mut rng = Rng::seed_from(7);
        let before = rng.clone();
        let mut rc = RoundChannel::empty();
        model.draw_into(8, &mut rng, &mut rc);
        assert_eq!(rc.clients.len(), 8);
        assert_eq!(rc.snr_db, 10.0);
        for c in &rc.clients {
            assert_eq!(c.effective_gain, Some(C32::ONE));
        }
        assert_eq!(rng.next_u64(), before.clone().next_u64());
    }

    #[test]
    fn from_config_picks_model() {
        let mut cfg = ChannelConfig::default();
        assert_eq!(from_config(&cfg).name(), "rayleigh");
        cfg.model = FadingKind::Awgn;
        assert_eq!(from_config(&cfg).name(), "awgn");
    }
}
