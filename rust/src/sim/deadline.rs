//! Straggler and dropout modelling: the [`DeadlinePolicy`] seam and its
//! built-in [`VirtualClock`] implementation.
//!
//! The paper's OTA aggregation assumes every selected client transmits in
//! its slot; production OTA-FL faces clients that are slow, drop
//! mid-round, or miss the transmission deadline (arXiv 2307.00974 names
//! straggler/partial-participation handling as the open challenge, arXiv
//! 2205.05867 shows per-client compute-time heterogeneity is the driver).
//! This module decides, per round, WHICH selected clients are excluded;
//! the coordinator and the aggregators handle the consequences (skipped
//! training, masked superposition, adjusted divisor).
//!
//! # Determinism contract
//!
//! All randomness flows from the coordinator's dedicated `"straggler"`
//! RNG stream, consumed serially in slot order with a FIXED number of
//! draws per slot (one uniform when dropout is on, one normal when the
//! deadline is on).  The stream is derived — and therefore consumed — ONLY
//! when the model is enabled (`deadline_s > 0 || dropout_p > 0`), so a
//! disabled run is byte-identical to the deadline-free engine, and an
//! enabled run's exclusion pattern is a pure function of `(seed, round,
//! selection, precisions)` — independent of `threads`, `workers`,
//! `shard_size` and `pipeline_depth`.

use crate::config::{DropoutKind, RunConfig};
use crate::quant::Precision;
use crate::rng::Rng;

/// Per-round inputs to the exclusion decision.
pub struct DeadlineCtx<'a> {
    /// Round index (1-based, matching the coordinator).
    pub round: usize,
    /// Fleet client ids of the round's K selected participants, in slot
    /// order.
    pub selected: &'a [usize],
    /// Per-slot precision assignment (aligned with `selected`).
    pub precisions: &'a [Precision],
}

/// Decides which selected clients miss the round.
pub trait DeadlinePolicy {
    /// Whether this policy can ever exclude anyone.  When `false` the
    /// coordinator skips the exclusion pass entirely — including its RNG
    /// stream consumption.
    fn enabled(&self) -> bool;

    /// Fill `excluded[r] = true` for every slot `r` whose client misses
    /// the round.  `excluded` arrives pre-sized to `ctx.selected.len()`
    /// and all-false; implementations must be allocation-free in steady
    /// state and must consume `rng` a deterministic number of draws per
    /// slot.
    fn exclude_into(&mut self, ctx: &DeadlineCtx<'_>, rng: &mut Rng, excluded: &mut [bool]);

    fn name(&self) -> &'static str;
}

/// The built-in seeded virtual clock: per-client latency (precision-
/// dependent compute time + channel slot time, log-normal jitter) checked
/// against a transmission deadline, composed with a per-round dropout
/// process (i.i.d. Bernoulli or bursty Gilbert/Markov outages).
///
/// Latency model for a `b`-bit client:
/// `t = compute_s · (b/32) · exp(latency_jitter · z) + slot_s`,
/// `z ~ N(0,1)` — cheaper precisions finish earlier, matching the
/// adaptive-computation motivation.  The client is excluded when
/// `t > deadline_s` OR its dropout process says it is down this round.
pub struct VirtualClock {
    deadline_s: f64,
    compute_s: f64,
    latency_jitter: f64,
    slot_s: f64,
    dropout_p: f64,
    dropout_model: DropoutKind,
    /// Gilbert transition probabilities (recovery, failure) — derived so
    /// the stationary outage probability is exactly `dropout_p` with mean
    /// outage length `dropout_burst` rounds.
    p_recover: f64,
    p_fail: f64,
    /// Per-fleet-client outage state for the bursty model (all-up start).
    down: Vec<bool>,
}

impl VirtualClock {
    /// Build from the run config for a fleet of `clients`.
    pub fn new(cfg: &RunConfig) -> Self {
        let p = cfg.dropout_p;
        let burst = cfg.dropout_burst;
        // Gilbert: π_down = p_fail / (p_fail + p_recover) = dropout_p with
        // p_recover = 1/burst  ⇒  p_fail = p / (burst · (1 − p))
        let p_recover = 1.0 / burst;
        let p_fail = if p > 0.0 { p / (burst * (1.0 - p)) } else { 0.0 };
        VirtualClock {
            deadline_s: cfg.deadline_s,
            compute_s: cfg.compute_s,
            latency_jitter: cfg.latency_jitter,
            slot_s: cfg.slot_s,
            dropout_p: p,
            dropout_model: cfg.dropout_model,
            p_recover,
            p_fail: p_fail.min(1.0),
            down: vec![false; cfg.clients],
        }
    }

    /// Theoretical per-round deadline-miss probability for a `bits`-bit
    /// client under this clock (dropout excluded):
    /// `P(compute·(b/32)·exp(σz) + slot > D) = 1 − Φ(ln((D−slot)/(compute·b/32))/σ)`.
    /// Used by the statistical acceptance tests; returns 0/1 at the
    /// degenerate edges.
    pub fn miss_probability(&self, bits: u8) -> f64 {
        if self.deadline_s <= 0.0 {
            return 0.0;
        }
        let base = self.compute_s * bits as f64 / 32.0;
        let headroom = self.deadline_s - self.slot_s;
        if headroom <= 0.0 {
            return 1.0; // slot time alone blows the deadline
        }
        if self.latency_jitter == 0.0 {
            return if base > headroom { 1.0 } else { 0.0 };
        }
        let z = (headroom / base).ln() / self.latency_jitter;
        1.0 - normal_cdf(z)
    }
}

/// Standard normal CDF via the Abramowitz–Stegun 7.1.26 erf
/// approximation (|error| < 1.5e-7 — far below test tolerances).
fn normal_cdf(z: f64) -> f64 {
    let x = z / std::f64::consts::SQRT_2;
    let t = 1.0 / (1.0 + 0.3275911 * x.abs());
    let poly = t
        * (0.254829592
            + t * (-0.284496736 + t * (1.421413741 + t * (-1.453152027 + t * 1.061405429))));
    let erf_abs = 1.0 - poly * (-x * x).exp();
    let erf = if x < 0.0 { -erf_abs } else { erf_abs };
    0.5 * (1.0 + erf)
}

impl DeadlinePolicy for VirtualClock {
    fn enabled(&self) -> bool {
        self.deadline_s > 0.0 || self.dropout_p > 0.0
    }

    fn exclude_into(&mut self, ctx: &DeadlineCtx<'_>, rng: &mut Rng, excluded: &mut [bool]) {
        debug_assert_eq!(excluded.len(), ctx.selected.len());
        for (r, (&client, p)) in
            ctx.selected.iter().zip(ctx.precisions.iter()).enumerate()
        {
            // dropout first (one uniform per slot, drawn regardless of
            // state so the draw count per slot is fixed)
            let mut dropped = false;
            if self.dropout_p > 0.0 {
                let u = rng.uniform();
                dropped = match self.dropout_model {
                    DropoutKind::Iid => u < self.dropout_p,
                    DropoutKind::Bursty => {
                        let state = &mut self.down[client];
                        *state = if *state {
                            u >= self.p_recover // stay down unless recovered
                        } else {
                            u < self.p_fail
                        };
                        *state
                    }
                };
            }
            // deadline next (one normal per slot when armed)
            let mut missed = false;
            if self.deadline_s > 0.0 {
                let z = rng.normal();
                let latency = self.compute_s * (p.bits() as f64 / 32.0)
                    * (self.latency_jitter * z).exp()
                    + self.slot_s;
                missed = latency > self.deadline_s;
            }
            excluded[r] = dropped || missed;
        }
    }

    fn name(&self) -> &'static str {
        "virtual-clock"
    }
}

/// Config-selected default policy: `None` when the straggler model is
/// fully disabled (the coordinator then never derives the `"straggler"`
/// stream).
pub fn from_config(cfg: &RunConfig) -> Option<Box<dyn DeadlinePolicy>> {
    if cfg.straggler_enabled() {
        Some(Box::new(VirtualClock::new(cfg)))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx_inputs(k: usize, bits: u8) -> (Vec<usize>, Vec<Precision>) {
        ((0..k).collect(), vec![Precision::new(bits).unwrap(); k])
    }

    #[test]
    fn disabled_config_yields_no_policy() {
        assert!(from_config(&RunConfig::default()).is_none());
        let mut cfg = RunConfig::default();
        cfg.deadline_s = 0.3;
        assert!(from_config(&cfg).is_some());
        let mut cfg = RunConfig::default();
        cfg.dropout_p = 0.1;
        assert!(from_config(&cfg).is_some());
    }

    #[test]
    fn exclusion_is_deterministic_per_stream() {
        let mut cfg = RunConfig::default();
        cfg.deadline_s = 0.06;
        cfg.dropout_p = 0.2;
        let (selected, precisions) = ctx_inputs(12, 8);
        let run = |cfg: &RunConfig| {
            let mut clock = VirtualClock::new(cfg);
            let mut rng = Rng::seed_from(7).stream("straggler");
            let mut out = Vec::new();
            for round in 1..=5 {
                let mut ex = vec![false; 12];
                let ctx = DeadlineCtx {
                    round,
                    selected: &selected,
                    precisions: &precisions,
                };
                clock.exclude_into(&ctx, &mut rng, &mut ex);
                out.push(ex);
            }
            out
        };
        assert_eq!(run(&cfg), run(&cfg));
    }

    #[test]
    fn iid_dropout_rate_matches_p() {
        let mut cfg = RunConfig::default();
        cfg.dropout_p = 0.3;
        let mut clock = VirtualClock::new(&cfg);
        let mut rng = Rng::seed_from(11).stream("straggler");
        let (selected, precisions) = ctx_inputs(15, 8);
        let mut ex = vec![false; 15];
        let (mut total, mut dropped) = (0usize, 0usize);
        for round in 1..=2000 {
            let ctx = DeadlineCtx {
                round,
                selected: &selected,
                precisions: &precisions,
            };
            clock.exclude_into(&ctx, &mut rng, &mut ex);
            total += ex.len();
            dropped += ex.iter().filter(|&&e| e).count();
        }
        let rate = dropped as f64 / total as f64;
        assert!((rate - 0.3).abs() < 0.01, "iid rate {rate}");
    }

    #[test]
    fn bursty_dropout_is_stationary_at_p_with_longer_bursts() {
        let mut cfg = RunConfig::default();
        cfg.dropout_p = 0.2;
        cfg.dropout_model = DropoutKind::Bursty;
        cfg.dropout_burst = 4.0;
        let mut clock = VirtualClock::new(&cfg);
        let mut rng = Rng::seed_from(13).stream("straggler");
        let (selected, precisions) = ctx_inputs(15, 8);
        let mut ex = vec![false; 15];
        let (mut total, mut down) = (0usize, 0usize);
        // per-client consecutive-down run lengths
        let mut run_len = vec![0usize; 15];
        let mut runs = Vec::new();
        for round in 1..=4000 {
            let ctx = DeadlineCtx {
                round,
                selected: &selected,
                precisions: &precisions,
            };
            clock.exclude_into(&ctx, &mut rng, &mut ex);
            total += ex.len();
            for (i, &e) in ex.iter().enumerate() {
                if e {
                    down += 1;
                    run_len[i] += 1;
                } else if run_len[i] > 0 {
                    runs.push(run_len[i]);
                    run_len[i] = 0;
                }
            }
        }
        let rate = down as f64 / total as f64;
        assert!((rate - 0.2).abs() < 0.02, "bursty stationary rate {rate}");
        let mean_burst = runs.iter().sum::<usize>() as f64 / runs.len() as f64;
        assert!(
            (mean_burst - 4.0).abs() < 0.4,
            "mean outage length {mean_burst} (want ≈ 4)"
        );
    }

    #[test]
    fn deadline_misses_match_the_lognormal_tail_per_precision() {
        let mut cfg = RunConfig::default();
        cfg.deadline_s = 0.055;
        cfg.compute_s = 0.05;
        cfg.latency_jitter = 0.25;
        cfg.slot_s = 0.005;
        let mut clock = VirtualClock::new(&cfg);
        let mut rng = Rng::seed_from(17).stream("straggler");
        for bits in [16u8, 8, 4] {
            let (selected, precisions) = ctx_inputs(20, bits);
            let mut ex = vec![false; 20];
            let (mut total, mut missed) = (0usize, 0usize);
            for round in 1..=3000 {
                let ctx = DeadlineCtx {
                    round,
                    selected: &selected,
                    precisions: &precisions,
                };
                clock.exclude_into(&ctx, &mut rng, &mut ex);
                total += ex.len();
                missed += ex.iter().filter(|&&e| e).count();
            }
            let rate = missed as f64 / total as f64;
            let want = clock.miss_probability(bits);
            assert!(
                (rate - want).abs() < 0.01,
                "bits={bits}: empirical {rate} vs theory {want}"
            );
        }
        // cheaper precisions miss less: the ladder must be monotone
        assert!(clock.miss_probability(4) < clock.miss_probability(8));
        assert!(clock.miss_probability(8) < clock.miss_probability(16));
    }

    #[test]
    fn miss_probability_edges() {
        let mut cfg = RunConfig::default();
        cfg.deadline_s = 0.0;
        assert_eq!(VirtualClock::new(&cfg).miss_probability(16), 0.0);
        let mut cfg = RunConfig::default();
        cfg.deadline_s = 0.004;
        cfg.slot_s = 0.005; // slot alone exceeds the deadline
        assert_eq!(VirtualClock::new(&cfg).miss_probability(4), 1.0);
        let mut cfg = RunConfig::default();
        cfg.deadline_s = 10.0;
        cfg.latency_jitter = 0.0; // deterministic clock, huge headroom
        assert_eq!(VirtualClock::new(&cfg).miss_probability(32), 0.0);
        // sanity: normal_cdf is a CDF
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!(normal_cdf(-6.0) < 1e-8 && normal_cdf(6.0) > 1.0 - 1e-8);
    }
}
