//! [`Experiment`]: the public session builder over the coordinator and the
//! trait seams — configure a run, swap any part, attach observers, run.
//!
//! ```no_run
//! use mpota::config::RunConfig;
//! use mpota::sim::{Experiment, ProgressPrinter};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut cfg = RunConfig::default();
//! cfg.rounds = 5;
//! let mut exp = Experiment::builder(cfg).observe(ProgressPrinter).build()?;
//! let report = exp.run()?;
//! println!("final accuracy {:.4}", report.final_accuracy);
//! # Ok(())
//! # }
//! ```
//!
//! Channel realism and feedback policies plug in through the same seams —
//! no new plumbing:
//!
//! ```no_run
//! use mpota::config::RunConfig;
//! use mpota::sim::{Experiment, GaussMarkov, LossPlateau};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut cfg = RunConfig::default();
//! cfg.channel.rho = 0.9; // fades persist across rounds
//! let mut exp = Experiment::builder(cfg.clone())
//!     .channel_model(GaussMarkov::new(cfg.channel.clone()))
//!     .policy(LossPlateau::new().with_patience(3))
//!     .build()?;
//! let report = exp.run()?;
//! # let _ = report;
//! # Ok(())
//! # }
//! ```
//!
//! (Setting `cfg.channel.model`/`cfg.policy` instead selects the same
//! parts from the config without touching the builder.)
//!
//! Multi-run drivers share one runtime and recycle the scratch arena:
//!
//! ```no_run
//! # use std::rc::Rc;
//! # use mpota::config::RunConfig;
//! # use mpota::runtime::Runtime;
//! # use mpota::sim::{Arena, Experiment};
//! # fn main() -> anyhow::Result<()> {
//! let base = RunConfig::default();
//! let runtime = Rc::new(Runtime::load(&base.artifacts_dir)?);
//! let mut arena = Arena::default();
//! for snr in [5.0f32, 20.0] {
//!     let mut cfg = base.clone();
//!     cfg.channel.snr_db = snr;
//!     let mut exp = Experiment::builder(cfg)
//!         .runtime(runtime.clone())
//!         .arena(arena)
//!         .build()?;
//!     let report = exp.run()?;
//!     println!("{snr} dB -> {:.4}", report.final_accuracy);
//!     arena = exp.into_arena();
//! }
//! # Ok(())
//! # }
//! ```

use std::rc::Rc;

use anyhow::Result;

use crate::config::RunConfig;
use crate::coordinator::{Coordinator, RunReport};
use crate::metrics::{RoundRecord, RunLog};
use crate::quant::Precision;
use crate::runtime::{EvalResult, Runtime};

use super::{
    Aggregator, Arena, ChannelModel, PrecisionPolicy, RoundObserver, SimParts,
};

/// A configured federated experiment, ready to run.
pub struct Experiment {
    coord: Coordinator,
}

impl Experiment {
    /// Start building an experiment from a run configuration.
    pub fn builder(cfg: RunConfig) -> ExperimentBuilder {
        ExperimentBuilder { cfg, parts: SimParts::default() }
    }

    /// Run all configured rounds and produce the final report.
    pub fn run(&mut self) -> Result<RunReport> {
        self.coord.run()
    }

    /// Execute a single communication round (manual stepping).  The
    /// record is also appended to the run log, so feedback policies
    /// (`PolicyCtx::prev`), carried-forward eval results and the final
    /// report all behave exactly as under [`run`](Self::run).
    pub fn round(&mut self, t: usize) -> Result<RoundRecord> {
        self.coord.step(t)
    }

    /// The effective run configuration.
    pub fn cfg(&self) -> &RunConfig {
        &self.coord.cfg
    }

    /// The shared runtime handle (pass to further builders).
    pub fn runtime(&self) -> Rc<Runtime> {
        self.coord.runtime.clone()
    }

    /// The accumulated run log.
    pub fn log(&self) -> &RunLog {
        self.coord.log()
    }

    /// Current global model (flat decimal values).
    pub fn global_model(&self) -> &[f32] {
        self.coord.global_model()
    }

    /// Per-layer re-quantization of the global model (deployment view of a
    /// precision-p client).
    pub fn requantize_global(&self, p: Precision) -> Vec<f32> {
        self.coord.requantize_global(p)
    }

    /// Evaluate an arbitrary flat model on the run's held-out test set.
    pub fn evaluate_model(&self, theta: &[f32]) -> Result<EvalResult> {
        self.coord.evaluate_model(theta)
    }

    /// Escape hatch to the full coordinator API.
    pub fn coordinator(&self) -> &Coordinator {
        &self.coord
    }

    /// Escape hatch to the full coordinator API (mutable).
    pub fn coordinator_mut(&mut self) -> &mut Coordinator {
        &mut self.coord
    }

    /// Tear down into the recyclable scratch arena for the next run.
    pub fn into_arena(self) -> Arena {
        self.coord.into_arena()
    }
}

/// Builder for [`Experiment`]: every part is optional and falls back to
/// the config-selected default (static-scheme policy, Rayleigh channel,
/// the configured aggregation path) — which reproduces the pre-redesign
/// coordinator bit-for-bit per seed.
pub struct ExperimentBuilder {
    cfg: RunConfig,
    parts: SimParts,
}

impl ExperimentBuilder {
    /// Share a loaded runtime instead of loading one per run.
    pub fn runtime(mut self, rt: Rc<Runtime>) -> Self {
        self.parts.runtime = Some(rt);
        self
    }

    /// Plug in a custom channel model.
    pub fn channel_model(mut self, m: impl ChannelModel + 'static) -> Self {
        self.parts.channel_model = Some(Box::new(m));
        self
    }

    /// Plug in a custom aggregator.
    pub fn aggregator(mut self, a: impl Aggregator + 'static) -> Self {
        self.parts.aggregator = Some(Box::new(a));
        self
    }

    /// Plug in a custom precision policy.
    pub fn policy(mut self, p: impl PrecisionPolicy + 'static) -> Self {
        self.parts.policy = Some(Box::new(p));
        self
    }

    /// Plug in a custom straggler/dropout policy (overrides the
    /// `deadline_s`/`dropout_p` config-derived [`super::VirtualClock`]).
    /// A policy whose `enabled()` is false disables straggler handling
    /// entirely, whatever the config says.
    pub fn deadline(mut self, d: impl super::DeadlinePolicy + 'static) -> Self {
        self.parts.deadline = Some(Box::new(d));
        self
    }

    /// Replace the PJRT training/eval backend with a pure-rust one
    /// (deterministic test trainers, alternative execution engines).  The
    /// backend is `Sync`, so `RunConfig::workers > 1` trains clients on
    /// pool workers calling it directly.
    pub fn backend(mut self, b: impl crate::exec::TrainBackend + 'static) -> Self {
        self.parts.backend = Some(Box::new(b));
        self
    }

    /// [`backend`](Self::backend) for an already-boxed trait object —
    /// what a per-cell [`crate::sim::sweep::BackendFactory`] produces.
    pub fn backend_boxed(mut self, b: Box<dyn crate::exec::TrainBackend>) -> Self {
        self.parts.backend = Some(b);
        self
    }

    /// Attach a round observer (repeatable).
    pub fn observe(mut self, o: impl RoundObserver + 'static) -> Self {
        self.parts.observers.push(Box::new(o));
        self
    }

    /// Recycle a previous run's scratch arena.
    pub fn arena(mut self, a: Arena) -> Self {
        self.parts.arena = Some(a);
        self
    }

    /// Validate the config and wire everything up.
    pub fn build(self) -> Result<Experiment> {
        Ok(Experiment { coord: Coordinator::from_parts(self.cfg, self.parts)? })
    }
}
