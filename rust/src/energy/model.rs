//! Eq. 9 energy estimator with the per-precision DSP MAC-packing model.
//!
//! ```text
//! E_ML = D_ML / (F_DSP · N_DSP · N_MAC) · E_Package              (Eq. 9)
//! ```
//!
//! D_ML is the task's MAC count, the denominator is the platform's MAC/s
//! throughput at the given precision, and E_Package is the package power —
//! i.e. energy = time-to-compute × power.
//!
//! N_MAC — MACs per DSP slice per cycle — is where approximate computing
//! pays off, and its shape (not a smooth curve!) is what produces the
//! paper's Table-II observations:
//!
//! * fp32 needs multiple DSP48E2 slices + fabric per MAC  → N_MAC < 1;
//! * 16-bit and 12-bit both fit the 27×18 multiplier once → the slice is
//!   UNDER-UTILISED at 12-bit, so both get N_MAC = 1 ("quantizing to
//!   16-bit and 12-bit share very similar degree energy saving");
//! * 8-bit and 6-bit use the INT8 SIMD double-pump plus LUT-assisted MACs
//!   → both land near the same plateau ("the same applies to 8-bit and
//!   6-bit");
//! * 4-bit goes LUT-dominated and packs aggressively, but the *relative*
//!   gain over 8-bit shrinks ("diminishing energy saving gain when further
//!   quantizing from low precision like 8-bit to ultra low ones like
//!   4-bit" — 94% → 98% saved).

use super::platform::Platform;
use crate::quant::Precision;

/// MACs per DSP slice per cycle at each precision level (see module doc).
pub fn macs_per_dsp(p: Precision) -> f32 {
    match p.bits() {
        32 => 0.45, // 2 DSP + fabric per fp32 MAC
        24 => 0.60, // trimmed float, still multi-slice
        16 => 1.0,  // one 27x18 multiply per slice per cycle
        12 => 1.05, // same slice, slightly cheaper routing
        8 => 7.7,   // INT8 SIMD + LUT-assisted parallel MACs
        6 => 8.1,   // 6-bit packs marginally better than 8
        4 => 30.0,  // LUT-dominated ultra-low-precision fabric
        3 => 40.0,  // Table-I probing levels (not used by schemes)
        2 => 64.0,
        _ => unreachable!("validated precision"),
    }
}

/// Joules for `macs` multiply-accumulates at precision `p` on `plat` (Eq. 9).
pub fn energy_joules(plat: &Platform, p: Precision, macs: f64) -> f64 {
    let throughput = plat.dsp_mhz as f64 * 1e6
        * plat.dsp_slices as f64
        * macs_per_dsp(p) as f64
        * plat.utilization as f64; // sustained MAC/s
    macs / throughput * plat.package_w as f64
}

/// Average over the 9 platforms — the quantity Table II reports.
pub fn mean_energy_joules(p: Precision, macs: f64) -> f64 {
    let ps = &super::platform::PLATFORMS;
    ps.iter().map(|plat| energy_joules(plat, p, macs)).sum::<f64>() / ps.len() as f64
}

/// Relative saving (%) vs the 32-bit baseline on the same workload.
pub fn saving_vs_f32(p: Precision, macs: f64) -> f64 {
    let base = mean_energy_joules(Precision::of(32), macs);
    let e = mean_energy_joules(p, macs);
    (1.0 - e / base) * 100.0
}

/// ResNet-50 forward-pass MACs per 224×224 sample — the workload the
/// paper's Table II is computed on (≈4.09 GMAC).
pub const RESNET50_MACS_PER_SAMPLE: f64 = 4.09e9;

/// MACs for one local training step: fwd + bwd ≈ 3× the forward cost
/// (standard rule of thumb: backward does 2× forward work).
pub fn training_macs(fwd_macs_per_sample: f64, samples: u64) -> f64 {
    3.0 * fwd_macs_per_sample * samples as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::platform::PLATFORMS;

    #[test]
    fn savings_monotone_nonincreasing_energy() {
        // lower precision never costs more energy
        let levels = [32u8, 24, 16, 12, 8, 6, 4, 3, 2];
        let energies: Vec<f64> = levels
            .iter()
            .map(|&b| mean_energy_joules(Precision::of(b), 1e9))
            .collect();
        for w in energies.windows(2) {
            assert!(w[1] <= w[0] * 1.0001, "{energies:?}");
        }
    }

    #[test]
    fn table2_shape_plateaus() {
        // 16 vs 12: within 10% of each other; 8 vs 6 likewise
        let e16 = mean_energy_joules(Precision::of(16), 1e9);
        let e12 = mean_energy_joules(Precision::of(12), 1e9);
        assert!((e16 - e12).abs() / e16 < 0.10, "{e16} vs {e12}");
        let e8 = mean_energy_joules(Precision::of(8), 1e9);
        let e6 = mean_energy_joules(Precision::of(6), 1e9);
        assert!((e8 - e6).abs() / e8 < 0.10, "{e8} vs {e6}");
    }

    #[test]
    fn table2_shape_savings_bands() {
        // paper Table II: 16-bit ≈ 52.6%, 8-bit ≈ 93.9%, 4-bit ≈ 98.5%
        let macs = RESNET50_MACS_PER_SAMPLE;
        let s16 = saving_vs_f32(Precision::of(16), macs);
        let s8 = saving_vs_f32(Precision::of(8), macs);
        let s4 = saving_vs_f32(Precision::of(4), macs);
        assert!((45.0..65.0).contains(&s16), "16-bit saving {s16}");
        assert!((90.0..96.0).contains(&s8), "8-bit saving {s8}");
        assert!((97.0..99.5).contains(&s4), "4-bit saving {s4}");
        // diminishing returns: 8->4 gains far less than 16->8
        assert!((s8 - s16) > 3.0 * (s4 - s8), "s16={s16} s8={s8} s4={s4}");
    }

    #[test]
    fn eq9_scales_linearly_in_macs_and_power() {
        let plat = &PLATFORMS[0];
        let p = Precision::of(16);
        let e1 = energy_joules(plat, p, 1e9);
        let e2 = energy_joules(plat, p, 2e9);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn bigger_part_is_faster_not_necessarily_cheaper() {
        // vu13p has 34x the DSPs of zu3eg but also 9x the power; energy
        // per MAC differs far less than throughput.
        let small = super::super::platform::by_name("zu3eg").unwrap();
        let big = super::super::platform::by_name("vu13p").unwrap();
        let p = Precision::of(8);
        let es = energy_joules(small, p, 1e9);
        let eb = energy_joules(big, p, 1e9);
        assert!(es / eb < 10.0 && eb / es < 10.0, "es={es} eb={eb}");
    }

    #[test]
    fn training_macs_is_three_forward() {
        assert_eq!(training_macs(1e6, 10), 3.0e7);
    }

    #[test]
    fn absolute_magnitude_is_plausible() {
        // paper Table II 32-bit: 0.36 J/sample (avg over platforms);
        // our datasheet table should land within the same decade.
        let e = mean_energy_joules(Precision::of(32), RESNET50_MACS_PER_SAMPLE);
        assert!((0.03..3.0).contains(&e), "32-bit J/sample = {e}");
    }
}
