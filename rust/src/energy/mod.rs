//! Client energy accounting (paper §III-C, Table II, Fig. 4's x-axis).
//!
//! [`platform`] carries the 9-platform datasheet table; [`model`] is the
//! Eq. 9 estimator; [`Meter`] accumulates per-client energy over a run so
//! the coordinator can report per-scheme totals and savings vs homogeneous
//! baselines.

pub mod model;
pub mod platform;

pub use model::{
    energy_joules, macs_per_dsp, mean_energy_joules, saving_vs_f32, training_macs,
    RESNET50_MACS_PER_SAMPLE,
};
pub use platform::{by_name, Platform, PLATFORMS};

use crate::quant::Precision;

/// Accumulates energy spent by every client across a run.
#[derive(Clone, Debug, Default)]
pub struct Meter {
    /// (client, precision, joules) — joules accumulated per client.
    per_client: Vec<(usize, Precision, f64)>,
}

impl Meter {
    pub fn new(precisions: &[Precision]) -> Self {
        Meter {
            per_client: precisions
                .iter()
                .enumerate()
                .map(|(i, &p)| (i, p, 0.0))
                .collect(),
        }
    }

    /// Charge client `k` for `macs` MACs at its precision (platform-mean).
    pub fn charge(&mut self, k: usize, macs: f64) {
        let (_, p, ref mut j) = self.per_client[k];
        *j += mean_energy_joules(p, macs);
    }

    /// Total joules across all clients.
    pub fn total_joules(&self) -> f64 {
        self.per_client.iter().map(|(_, _, j)| j).sum()
    }

    /// Joules for client `k`.
    pub fn client_joules(&self, k: usize) -> f64 {
        self.per_client[k].2
    }

    /// What the same per-client MAC workload would have cost had every
    /// client run at `p` — for "savings vs homogeneous 32/16-bit" claims.
    /// Requires the per-client MAC trace, so the coordinator keeps one.
    pub fn counterfactual_joules(macs_per_client: &[f64], p: Precision) -> f64 {
        macs_per_client
            .iter()
            .map(|&m| mean_energy_joules(p, m))
            .sum()
    }

    /// Saving (%) of `actual` relative to `baseline` joules.
    pub fn saving_pct(actual: f64, baseline: f64) -> f64 {
        (1.0 - actual / baseline) * 100.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates_per_precision() {
        let ps = vec![Precision::of(32), Precision::of(4)];
        let mut m = Meter::new(&ps);
        m.charge(0, 1e9);
        m.charge(1, 1e9);
        // 4-bit client must spend far less than the 32-bit one
        assert!(m.client_joules(1) < m.client_joules(0) * 0.05);
        assert!((m.total_joules()
            - (m.client_joules(0) + m.client_joules(1)))
        .abs()
            < 1e-12);
    }

    #[test]
    fn counterfactual_and_saving() {
        let macs = vec![1e9, 1e9, 1e9];
        let all32 = Meter::counterfactual_joules(&macs, Precision::of(32));
        let all4 = Meter::counterfactual_joules(&macs, Precision::of(4));
        let s = Meter::saving_pct(all4, all32);
        assert!(s > 90.0, "saving {s}");
        assert_eq!(Meter::saving_pct(1.0, 1.0), 0.0);
    }

    #[test]
    fn mixed_scheme_sits_between_homogeneous_extremes() {
        // [16,8,4] total must be between all-4 and all-16 for equal work
        let macs = 1e9;
        let mixed: f64 = [16u8, 8, 4]
            .iter()
            .map(|&b| mean_energy_joules(Precision::of(b), macs))
            .sum();
        let all16 = 3.0 * mean_energy_joules(Precision::of(16), macs);
        let all4 = 3.0 * mean_energy_joules(Precision::of(4), macs);
        assert!(mixed < all16 && mixed > all4);
    }
}
