//! FPGA edge-platform datasheet table (paper §III-C).
//!
//! The paper estimates client energy from "official data sheets of typical
//! FPGA edge platforms" across "9 Xilinx FPGA platforms of varying
//! specifications".  This table carries the same datasheet quantities
//! Eq. 9 needs — DSP slice count, DSP fmax, and typical package power —
//! for nine UltraScale+-class parts spanning the embedded (Zynq), mid
//! (Kintex) and datacenter (Virtex) tiers.  Values are rounded datasheet
//! figures (DS923 and friends); the *relative* spread across platforms is
//! what the averaged Table-II numbers inherit.

/// One FPGA platform's Eq.-9 inputs.
#[derive(Clone, Copy, Debug)]
pub struct Platform {
    pub name: &'static str,
    /// Number of DSP slices on the part (N_DSP).
    pub dsp_slices: u32,
    /// DSP fmax in MHz (F_DSP).
    pub dsp_mhz: u32,
    /// Typical package power draw in watts (E_Package's rate).
    pub package_w: f32,
    /// Achievable sustained DSP utilisation for a dense CNN dataflow —
    /// accelerators never keep every slice busy every cycle (memory
    /// stalls, control, partial tiles).
    pub utilization: f32,
}

/// The nine evaluated platforms.
pub const PLATFORMS: [Platform; 9] = [
    Platform { name: "zu3eg", dsp_slices: 360, dsp_mhz: 650, package_w: 10.0, utilization: 0.30 },
    Platform { name: "zu7ev", dsp_slices: 1_728, dsp_mhz: 650, package_w: 20.0, utilization: 0.28 },
    Platform { name: "zu9eg", dsp_slices: 2_520, dsp_mhz: 650, package_w: 25.0, utilization: 0.26 },
    Platform { name: "ku5p", dsp_slices: 1_824, dsp_mhz: 775, package_w: 16.0, utilization: 0.28 },
    Platform { name: "ku15p", dsp_slices: 1_968, dsp_mhz: 775, package_w: 25.0, utilization: 0.26 },
    Platform { name: "vu3p", dsp_slices: 2_280, dsp_mhz: 891, package_w: 25.0, utilization: 0.25 },
    Platform { name: "vu9p", dsp_slices: 6_840, dsp_mhz: 891, package_w: 60.0, utilization: 0.22 },
    Platform { name: "vu13p", dsp_slices: 12_288, dsp_mhz: 891, package_w: 90.0, utilization: 0.20 },
    Platform { name: "vu35p", dsp_slices: 5_952, dsp_mhz: 891, package_w: 75.0, utilization: 0.22 },
];

pub fn by_name(name: &str) -> Option<&'static Platform> {
    PLATFORMS.iter().find(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_platforms() {
        assert_eq!(PLATFORMS.len(), 9);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = PLATFORMS.iter().map(|p| p.name).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 9);
    }

    #[test]
    fn lookup() {
        assert_eq!(by_name("vu9p").unwrap().dsp_slices, 6_840);
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn sane_datasheet_ranges() {
        for p in &PLATFORMS {
            assert!(p.dsp_slices >= 100 && p.dsp_slices <= 20_000, "{}", p.name);
            assert!(p.dsp_mhz >= 400 && p.dsp_mhz <= 1_000, "{}", p.name);
            assert!(p.package_w > 1.0 && p.package_w < 200.0, "{}", p.name);
            assert!(p.utilization > 0.0 && p.utilization <= 1.0, "{}", p.name);
        }
    }
}
