//! # mpota — Mixed-Precision Over-The-Air Federated Learning
//!
//! Rust coordinator (L3) of the three-layer reproduction of
//! *"Mixed-Precision Federated Learning via Multi-Precision Over-the-Air
//! Aggregation"* (Yuan, Wei, Guo — IEEE WCNC 2025).
//!
//! The layers (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — federated-learning orchestration: round
//!   scheduling, client precision schemes, the wireless physical layer
//!   (Rayleigh fading, pilot-based channel estimation, channel-inversion
//!   precoding, analog amplitude-modulated superposition + AWGN), energy
//!   accounting, metrics, CLI.  Python never runs here.
//! * **L2** — jax model graphs (`python/compile/model.py`), AOT-lowered to
//!   HLO text once by `make artifacts`.
//! * **L1** — Pallas kernels (`python/compile/kernels/`) for Algorithm-2
//!   quantization, tiled quantized matmul and the K-client OTA
//!   superposition; lowered into the same artifacts.
//!
//! The crate is organised as many small substrate modules; `coordinator`
//! wires them into the paper's Algorithm 1 through the trait seams of
//! [`sim`] — the composable simulation API.
//!
//! ## The simulation API (§Scenarios)
//!
//! [`sim`] decomposes the round loop into pluggable traits over the
//! kernels substrate: [`sim::Aggregator`] (analog OTA / digital / ideal /
//! custom), [`sim::ChannelModel`] (Rayleigh+pilot / AWGN / AR(1)
//! Gauss-Markov correlated fading / path-loss geometry / custom),
//! [`sim::PrecisionPolicy`] (static scheme / SNR-adaptive / loss-plateau
//! and energy-budget feedback / custom) and [`sim::RoundObserver`] event
//! sinks.  [`sim::Experiment`] is the builder-style entry point;
//! [`sim::sweep`] runs config grids in one process over a shared runtime
//! and scratch arena (`mpota sweep`).
//!
//! ## The kernels layer (§Perf)
//!
//! The round hot path — quantize + modulate K payloads, superpose, inject
//! AWGN, average — runs on [`kernels`]: a contiguous K×N
//! [`kernels::PayloadPlane`] instead of `&[Vec<f32>]`, fused single-pass
//! kernels ([`kernels::fused`]), and chunk-parallelism ([`kernels::par`])
//! gated by the `RunConfig::threads` knob.  The layer honours a strict
//! determinism contract: for a fixed seed, results are bit-identical to
//! the sequential scalar path at every thread count (see the module docs
//! and `rust/tests/kernels.rs`).  The coordinator reuses a round scratch
//! arena so steady-state rounds perform no heap allocation outside PJRT
//! dispatch (`rust/tests/alloc_counter.rs`).
//!
//! ## The execution runtime (§Scale)
//!
//! All parallelism dispatches onto ONE persistent, parked worker pool
//! ([`exec::ExecPool`]): intra-kernel chunks (`RunConfig::threads`),
//! inter-client local training and inter-cell sweep parallelism (both
//! `RunConfig::workers`).  PJRT stays on its owning thread behind the
//! [`exec::TrainService`] funnel; nested dispatches run inline, and the
//! bit-identity contract holds for every `{threads, workers}` combination
//! (`rust/tests/sim.rs`).
//!
//! Massive fleets stream: the round pipeline processes its K selected
//! clients in `RunConfig::shard_size`-row payload shards folded into a
//! persistent air accumulator (round memory O(shard·N + K), not O(K·N)),
//! selection is O(K) for any fleet (sparse Fisher-Yates or Floyd's
//! sampling via `RunConfig::selection`), and trajectories are
//! bit-identical per seed at every shard size
//! (`rust/tests/shard_invariance.rs`; README §"Fleet scaling").

pub mod channel;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod exec;
pub mod fl;
pub mod json;
pub mod kernels;
pub mod metrics;
pub mod ota;
pub mod quant;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod testing;

/// Crate-wide result alias (anyhow is the only error dependency).
pub type Result<T> = anyhow::Result<T>;
