//! Run configuration: every knob of an experiment in one validated struct,
//! loadable from a JSON file and overridable from the CLI.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::channel::ChannelConfig;
use crate::fl::scheme::Scheme;
use crate::json::{self, Value};

/// How client precisions are chosen each round (the config-file name for
/// the built-in [`crate::sim::PrecisionPolicy`] implementations).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    /// The static group scheme of `RunConfig::scheme` every round
    /// (paper §IV-A2 — the default).
    Static,
    /// SNR-adaptive bit selection: the fleet runs at the cheapest level
    /// whose quantization noise still sits at/below the channel noise
    /// floor (≈6 dB per bit); see `sim::SnrAdaptive`.
    SnrAdaptive,
    /// Feedback policy: start cheap and promote the fleet one precision
    /// level whenever the global loss has stalled for
    /// `RunConfig::plateau_patience` rounds (reads the previous round's
    /// record through `sim::PolicyCtx::prev`); see `sim::LossPlateau`.
    LossPlateau,
    /// Feedback policy: start rich and demote the fleet down the
    /// precision ladder as cumulative fleet energy approaches
    /// `clients × RunConfig::energy_budget_j`; see `sim::EnergyBudget`.
    EnergyBudget,
    /// Per-client profiling planner: accumulates each client's
    /// channel/energy/loss history in a bounded id-keyed LRU and assigns
    /// precision per client from its own effective SNR, demoting clients
    /// past `RunConfig::energy_budget_j` (0 = no cap); see
    /// `sim::ProfilingPlanner`.
    Profiling,
}

impl std::str::FromStr for PolicyKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "static" | "scheme" => Ok(PolicyKind::Static),
            "snr-adaptive" | "snr_adaptive" | "snr" => Ok(PolicyKind::SnrAdaptive),
            "loss-plateau" | "loss_plateau" | "plateau" => Ok(PolicyKind::LossPlateau),
            "energy-budget" | "energy_budget" | "energy" => Ok(PolicyKind::EnergyBudget),
            "profiling" | "profile" => Ok(PolicyKind::Profiling),
            other => bail!(
                "unknown precision policy '{other}' \
                 (static|snr-adaptive|loss-plateau|energy-budget|profiling)"
            ),
        }
    }
}

impl std::fmt::Display for PolicyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}",
            match self {
                PolicyKind::Static => "static",
                PolicyKind::SnrAdaptive => "snr-adaptive",
                PolicyKind::LossPlateau => "loss-plateau",
                PolicyKind::EnergyBudget => "energy-budget",
                PolicyKind::Profiling => "profiling",
            }
        )
    }
}

/// How the K participants are drawn from the N-client fleet each round
/// (the config-file name for the [`crate::fl::Selection`] variants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectionKind {
    /// The historical default: everyone when `clients_per_round ==
    /// clients`, else uniform-without-replacement (partial Fisher-Yates).
    Auto,
    /// Uniform without replacement via partial Fisher-Yates (the
    /// historical RNG draw order; O(K) scratch since the sparse rewrite).
    Uniform,
    /// Uniform without replacement via Floyd's sampling — O(K) draws and
    /// O(K) state, the massive-fleet selector.
    Sampled,
    /// Deterministic rotation through client blocks.
    RoundRobin,
}

impl std::str::FromStr for SelectionKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(SelectionKind::Auto),
            "uniform" | "fisher-yates" | "fisher_yates" => Ok(SelectionKind::Uniform),
            "sampled" | "floyd" => Ok(SelectionKind::Sampled),
            "round-robin" | "round_robin" | "rotate" => Ok(SelectionKind::RoundRobin),
            other => bail!(
                "unknown selection '{other}' (auto|uniform|sampled|round-robin)"
            ),
        }
    }
}

impl std::fmt::Display for SelectionKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}",
            match self {
                SelectionKind::Auto => "auto",
                SelectionKind::Uniform => "uniform",
                SelectionKind::Sampled => "sampled",
                SelectionKind::RoundRobin => "round-robin",
            }
        )
    }
}

/// Per-round dropout process for the straggler/robustness model (the
/// config-file name for the [`crate::sim::VirtualClock`] dropout draw).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropoutKind {
    /// Independent Bernoulli(`dropout_p`) per selected client per round.
    Iid,
    /// Bursty Gilbert (two-state Markov) outages: a client that drops
    /// stays down for `dropout_burst` rounds in expectation, with the
    /// stationary dropout probability still `dropout_p`.
    Bursty,
}

impl std::str::FromStr for DropoutKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "iid" | "bernoulli" => Ok(DropoutKind::Iid),
            "bursty" | "markov" | "gilbert" => Ok(DropoutKind::Bursty),
            other => bail!("unknown dropout model '{other}' (iid|bursty)"),
        }
    }
}

impl std::fmt::Display for DropoutKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}",
            match self {
                DropoutKind::Iid => "iid",
                DropoutKind::Bursty => "bursty",
            }
        )
    }
}

/// How the training corpus is split across clients (the config-file name
/// for the [`crate::data`] partitioners).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PartitionKind {
    /// Equal-size random shards from one global shuffle — every client's
    /// label marginal matches the corpus (the historical default).
    Iid,
    /// Dirichlet(α) label skew (Hsu et al.-style per-class proportion
    /// draws): small `RunConfig::alpha` concentrates each class on few
    /// clients, large α approaches IID.  Optional power-law sample-count
    /// skew via `RunConfig::skew_zipf`.
    Dirichlet,
}

impl std::str::FromStr for PartitionKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "iid" | "uniform" => Ok(PartitionKind::Iid),
            "dirichlet" | "dir" | "non-iid" | "noniid" => Ok(PartitionKind::Dirichlet),
            other => bail!("unknown partition '{other}' (iid|dirichlet)"),
        }
    }
}

impl std::fmt::Display for PartitionKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}",
            match self {
                PartitionKind::Iid => "iid",
                PartitionKind::Dirichlet => "dirichlet",
            }
        )
    }
}

/// What clients put on the air each round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Transmit {
    /// Model updates Δ[θ_k] (Alg. 1 step 10/14 — the default; keeps the
    /// server's global model at full precision).
    Updates,
    /// Full local weights [θ_k] (Alg. 1 step 18's literal reading) —
    /// ablation mode showing why update-transmission matters for
    /// mixed-precision fleets.
    Weights,
}

impl std::str::FromStr for Transmit {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "updates" | "delta" => Ok(Transmit::Updates),
            "weights" | "model" => Ok(Transmit::Weights),
            other => bail!("unknown transmit mode '{other}' (updates|weights)"),
        }
    }
}

impl std::fmt::Display for Transmit {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}",
            match self {
                Transmit::Updates => "updates",
                Transmit::Weights => "weights",
            }
        )
    }
}

/// How client updates reach the server.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Aggregation {
    /// The paper's analog multi-precision OTA superposition.
    OtaAnalog,
    /// Conventional digital orthogonal uplink (baseline).
    Digital,
    /// Noise-free FedAvg oracle (Eq. 1) — upper bound / debugging.
    Ideal,
}

impl std::str::FromStr for Aggregation {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "ota" | "analog" | "ota-analog" => Ok(Aggregation::OtaAnalog),
            "digital" | "orthogonal" => Ok(Aggregation::Digital),
            "ideal" | "fedavg" => Ok(Aggregation::Ideal),
            other => bail!("unknown aggregation '{other}' (ota|digital|ideal)"),
        }
    }
}

impl std::fmt::Display for Aggregation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Aggregation::OtaAnalog => "ota",
            Aggregation::Digital => "digital",
            Aggregation::Ideal => "ideal",
        };
        write!(f, "{s}")
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    /// Directory holding `manifest.json` + HLO artifacts.
    pub artifacts_dir: PathBuf,
    /// Model variant (must exist in the manifest).
    pub variant: String,
    /// Total clients N (paper: 15).
    pub clients: usize,
    /// Clients selected per round K (paper: all 15).
    pub clients_per_round: usize,
    /// How the K participants are drawn from the fleet (`Auto` reproduces
    /// the historical behavior; `Sampled` is the O(K) massive-fleet
    /// selector).
    pub selection: SelectionKind,
    /// Streaming-shard size for the round pipeline: the round's K
    /// selected clients are processed `shard_size` at a time through a
    /// small reusable payload plane that is fused-superposed into a
    /// persistent air accumulator, making round memory O(shard_size·N +
    /// K) instead of O(K·N).  `0` (the default) means one shard — the
    /// historical whole-round plane.  Trajectories are bit-identical per
    /// seed for EVERY shard size (`rust/tests/shard_invariance.rs`).
    pub shard_size: usize,
    /// Pipelined round engine: overlap the client phase of super-shard
    /// t+1 with the superposition of super-shard t on the exec pool,
    /// through double-buffered shard planes.  `0` (the default) is the
    /// serial PR-5 path; `d ≥ 1` widens each pipeline step to `d ×
    /// shard_len` rows.  A pure scheduling transformation: trajectories
    /// are bit-identical per seed at every depth
    /// (`rust/tests/shard_invariance.rs`).
    pub pipeline_depth: usize,
    /// Bit-packed transport planes: stage each streaming shard as a
    /// [`crate::kernels::PackedPlane`] — every row stored at its assigned
    /// precision (4-bit rows cost 4 bits/value) — and fold it through the
    /// packed fused kernels, which decode codes inline during
    /// superposition.  On by default; results are bit-identical to the
    /// f32 staging path (`decode(pack(x)) == fake_quant(x)` exactly), so
    /// this is purely a memory-traffic/bandwidth optimization.  `false`
    /// restores the f32 transport plane.
    pub packed_planes: bool,
    /// Per-round transmission deadline in virtual seconds; a selected
    /// client whose simulated latency (precision-dependent compute time +
    /// channel slot time) exceeds it is excluded from the superposition
    /// and the aggregation divisor.  `0` (the default) disables the
    /// straggler model entirely — the round path is then byte-identical
    /// to the deadline-free engine (no straggler RNG draws).
    pub deadline_s: f64,
    /// Virtual compute seconds for one full-precision (32-bit) local
    /// round; a b-bit client takes `compute_s · b/32` before jitter.
    pub compute_s: f64,
    /// Log-normal jitter sigma on the per-client compute time
    /// (`exp(latency_jitter · z)`, z ~ N(0,1)); 0 = deterministic clock.
    pub latency_jitter: f64,
    /// Virtual seconds of channel slot time added to every client's
    /// latency (synchronization + pilot overhead).
    pub slot_s: f64,
    /// Per-round dropout probability per selected client (stationary rate
    /// for both dropout models).  `0` (the default) disables dropout.
    pub dropout_p: f64,
    /// Dropout process shape: i.i.d. Bernoulli or bursty Gilbert/Markov.
    pub dropout_model: DropoutKind,
    /// Mean outage length in rounds for the `bursty` dropout model
    /// (ignored by `iid`; must be ≥ 1).
    pub dropout_burst: f64,
    /// Communication rounds T (paper: 100).
    pub rounds: usize,
    /// Precision scheme (paper §IV-A2) — the static assignment used by
    /// the default precision policy, and the label baseline.
    pub scheme: Scheme,
    /// Per-round precision policy (static scheme by default).
    pub policy: PolicyKind,
    /// Rounds without global-loss improvement before the `loss-plateau`
    /// policy promotes the fleet one precision level.
    pub plateau_patience: usize,
    /// Per-client energy cap (J) steering the `energy-budget` policy
    /// (the fleet budget is `clients ×` this).
    pub energy_budget_j: f64,
    /// Local SGD steps per client per round.
    pub local_steps: usize,
    /// Client learning rate.
    pub lr: f32,
    /// Training samples in the synthetic corpus.
    pub train_samples: usize,
    /// Held-out test samples.
    pub test_samples: usize,
    /// How the training corpus is split across the fleet (IID equal
    /// shards by default; `dirichlet` enables label-skewed shards).
    pub partition: PartitionKind,
    /// Dirichlet concentration α for the `dirichlet` partition: per-class
    /// client proportions are drawn from Dirichlet(α), so α → 0
    /// concentrates each class on few clients and α → ∞ approaches IID.
    /// Ignored by the `iid` partition.
    pub alpha: f64,
    /// Power-law sample-count skew for the `dirichlet` partition: client
    /// i's expected shard size is proportional to `(i+1)^-skew_zipf`
    /// (0 = equal expected sizes).  Every client keeps at least one train
    /// batch of samples.  Ignored by the `iid` partition.
    pub skew_zipf: f64,
    /// Aggregation path.
    pub aggregation: Aggregation,
    /// Payload semantics (updates vs full weights).
    pub transmit: Transmit,
    /// Wireless channel knobs.
    pub channel: ChannelConfig,
    /// Root seed for everything.
    pub seed: u64,
    /// Optional pretrained-params blob (flat f32) to start from; None uses
    /// the He init shipped with the artifacts.
    pub init_params: Option<PathBuf>,
    /// Worker threads for client-parallel local training (1 = sequential).
    pub workers: usize,
    /// Chunk-parallelism for the server-side kernels (superposition,
    /// noise, quantization, vector ops).  `1` runs the exact sequential
    /// path; any value produces bit-identical results for a fixed seed
    /// (see the `kernels` module determinism contract).
    pub threads: usize,
    /// Where run logs go.
    pub out_dir: PathBuf,
    /// Evaluate the server model every `eval_every` rounds.
    pub eval_every: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            variant: "base".to_string(),
            clients: 15,
            clients_per_round: 15,
            selection: SelectionKind::Auto,
            shard_size: 0,
            pipeline_depth: 0,
            packed_planes: true,
            deadline_s: 0.0,
            compute_s: 0.05,
            latency_jitter: 0.25,
            slot_s: 0.005,
            dropout_p: 0.0,
            dropout_model: DropoutKind::Iid,
            dropout_burst: 3.0,
            rounds: 100,
            scheme: Scheme::parse("16,8,4").expect("static scheme"),
            policy: PolicyKind::Static,
            plateau_patience: 5,
            energy_budget_j: 5.0,
            local_steps: 4,
            lr: 0.05,
            train_samples: 3840,
            test_samples: 960,
            partition: PartitionKind::Iid,
            alpha: 0.5,
            skew_zipf: 0.0,
            aggregation: Aggregation::OtaAnalog,
            transmit: Transmit::Updates,
            channel: ChannelConfig::default(),
            seed: 42,
            init_params: None,
            workers: 1,
            threads: 1,
            out_dir: PathBuf::from("runs"),
            eval_every: 1,
        }
    }
}

impl RunConfig {
    /// Effective streaming-shard length for a round of `kk` participants:
    /// `shard_size == 0` means one whole-round shard, anything else is
    /// clamped to `[1, kk]`.  The single source of truth for the clamp —
    /// the coordinator round loop and the channel-only sweep cells both
    /// use it, so the shard-invariance contract cannot drift between
    /// them.
    pub fn shard_len(&self, kk: usize) -> usize {
        if self.shard_size == 0 {
            kk
        } else {
            self.shard_size.min(kk).max(1)
        }
    }

    /// Whether the straggler/dropout model draws anything this run: a
    /// positive deadline or a positive dropout rate.  When this is false
    /// the round engine consumes ZERO straggler RNG draws and the
    /// trajectory is byte-identical to the deadline-free engine.
    pub fn straggler_enabled(&self) -> bool {
        self.deadline_s > 0.0 || self.dropout_p > 0.0
    }

    /// Validate cross-field invariants.
    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 || self.rounds == 0 {
            bail!("clients and rounds must be positive");
        }
        if self.clients_per_round == 0 || self.clients_per_round > self.clients {
            bail!(
                "clients_per_round {} must be in 1..={}",
                self.clients_per_round,
                self.clients
            );
        }
        // the scheme must expand over the SELECTED set each round — O(1)
        // divisibility check, no fleet-sized materialization
        self.scheme.check_divides(self.clients)?;
        if self.local_steps == 0 {
            bail!("local_steps must be positive");
        }
        if !(self.lr > 0.0 && self.lr.is_finite()) {
            bail!("lr must be positive and finite");
        }
        if self.train_samples < self.clients {
            bail!("need at least one training sample per client");
        }
        if !(self.alpha > 0.0 && self.alpha.is_finite()) {
            bail!("alpha {} must be positive and finite", self.alpha);
        }
        if !(self.skew_zipf >= 0.0 && self.skew_zipf.is_finite()) {
            bail!("skew_zipf {} must be >= 0 and finite", self.skew_zipf);
        }
        if self.eval_every == 0 {
            bail!("eval_every must be positive");
        }
        if self.workers == 0 {
            bail!("workers must be positive");
        }
        if self.threads == 0 {
            bail!("threads must be positive (1 = sequential)");
        }
        self.channel.validate()?;
        if self.plateau_patience == 0 {
            bail!("plateau_patience must be positive");
        }
        if !(self.energy_budget_j > 0.0 && self.energy_budget_j.is_finite()) {
            bail!("energy_budget_j must be positive and finite");
        }
        if !(self.deadline_s >= 0.0 && self.deadline_s.is_finite()) {
            bail!("deadline_s must be >= 0 and finite (0 disables the deadline)");
        }
        if !(self.compute_s > 0.0 && self.compute_s.is_finite()) {
            bail!("compute_s must be positive and finite");
        }
        if !(self.latency_jitter >= 0.0 && self.latency_jitter.is_finite()) {
            bail!("latency_jitter must be >= 0 and finite");
        }
        if !(self.slot_s >= 0.0 && self.slot_s.is_finite()) {
            bail!("slot_s must be >= 0 and finite");
        }
        if !(self.dropout_p >= 0.0 && self.dropout_p < 1.0) {
            bail!(
                "dropout_p {} must be in [0, 1) (1 would exclude every round)",
                self.dropout_p
            );
        }
        if !(self.dropout_burst >= 1.0 && self.dropout_burst.is_finite()) {
            bail!("dropout_burst must be >= 1 round");
        }
        Ok(())
    }

    /// Load overrides from a JSON config file (any subset of keys).
    pub fn load_overrides(&mut self, path: &Path) -> Result<()> {
        let v = json::parse_file(path)?;
        self.apply_json(&v)
            .with_context(|| format!("applying {}", path.display()))
    }

    /// Apply a JSON object of overrides.
    pub fn apply_json(&mut self, v: &Value) -> Result<()> {
        for (k, val) in v.as_object()? {
            match k.as_str() {
                "artifacts_dir" => self.artifacts_dir = PathBuf::from(val.as_str()?),
                "variant" => self.variant = val.as_str()?.to_string(),
                "clients" => self.clients = val.as_usize()?,
                "clients_per_round" => self.clients_per_round = val.as_usize()?,
                "selection" => self.selection = val.as_str()?.parse()?,
                "shard_size" => self.shard_size = val.as_usize()?,
                "pipeline_depth" => self.pipeline_depth = val.as_usize()?,
                "packed_planes" => self.packed_planes = val.as_bool()?,
                "deadline_s" => self.deadline_s = val.as_f64()?,
                "compute_s" => self.compute_s = val.as_f64()?,
                "latency_jitter" => self.latency_jitter = val.as_f64()?,
                "slot_s" => self.slot_s = val.as_f64()?,
                "dropout_p" => self.dropout_p = val.as_f64()?,
                "dropout_model" => self.dropout_model = val.as_str()?.parse()?,
                "dropout_burst" => self.dropout_burst = val.as_f64()?,
                "rounds" => self.rounds = val.as_usize()?,
                "scheme" => self.scheme = Scheme::parse(val.as_str()?)?,
                "policy" => self.policy = val.as_str()?.parse()?,
                "local_steps" => self.local_steps = val.as_usize()?,
                "lr" => self.lr = val.as_f64()? as f32,
                "train_samples" => self.train_samples = val.as_usize()?,
                "test_samples" => self.test_samples = val.as_usize()?,
                "partition" => self.partition = val.as_str()?.parse()?,
                "alpha" => self.alpha = val.as_f64()?,
                "skew_zipf" => self.skew_zipf = val.as_f64()?,
                "aggregation" => self.aggregation = val.as_str()?.parse()?,
                "transmit" => self.transmit = val.as_str()?.parse()?,
                "snr_db" => self.channel.snr_db = val.as_f64()? as f32,
                "pilot_len" => self.channel.pilot_len = val.as_usize()?,
                "pilot_noise_var" => {
                    self.channel.pilot_noise_var = val.as_f64()? as f32
                }
                "truncation" => self.channel.truncation = val.as_f64()? as f32,
                "perfect_csi" => self.channel.perfect_csi = val.as_bool()?,
                "channel_model" => self.channel.model = val.as_str()?.parse()?,
                "rho" => self.channel.rho = val.as_f64()? as f32,
                "path_loss_exp" => self.channel.path_loss_exp = val.as_f64()? as f32,
                "shadowing_db" => self.channel.shadowing_db = val.as_f64()? as f32,
                "cell_radius" => self.channel.cell_radius = val.as_f64()? as f32,
                "plateau_patience" => self.plateau_patience = val.as_usize()?,
                "energy_budget_j" => self.energy_budget_j = val.as_f64()?,
                // exact integer parse: f64 would silently corrupt seeds
                // above 2^53
                "seed" => self.seed = val.as_u64()?,
                "init_params" => {
                    self.init_params = match val {
                        Value::Null => None,
                        v => Some(PathBuf::from(v.as_str()?)),
                    }
                }
                "workers" => self.workers = val.as_usize()?,
                "threads" => self.threads = val.as_usize()?,
                "out_dir" => self.out_dir = PathBuf::from(val.as_str()?),
                "eval_every" => self.eval_every = val.as_usize()?,
                other => bail!("unknown config key '{other}'"),
            }
        }
        Ok(())
    }

    /// Serialize the effective config for run provenance logs.
    ///
    /// Full fidelity: every key `apply_json` understands is emitted, so
    /// applying the output to a default config reproduces this config
    /// exactly (`provenance_roundtrip` pins this).
    pub fn to_json(&self) -> Value {
        let mut o = Value::object();
        o.set(
            "artifacts_dir",
            Value::Str(self.artifacts_dir.display().to_string()),
        );
        o.set("variant", Value::Str(self.variant.clone()));
        o.set("clients", Value::Num(self.clients as f64));
        o.set("clients_per_round", Value::Num(self.clients_per_round as f64));
        o.set("selection", Value::Str(self.selection.to_string()));
        o.set("shard_size", Value::Num(self.shard_size as f64));
        o.set("pipeline_depth", Value::Num(self.pipeline_depth as f64));
        o.set("packed_planes", Value::Bool(self.packed_planes));
        o.set("deadline_s", Value::Num(self.deadline_s));
        o.set("compute_s", Value::Num(self.compute_s));
        o.set("latency_jitter", Value::Num(self.latency_jitter));
        o.set("slot_s", Value::Num(self.slot_s));
        o.set("dropout_p", Value::Num(self.dropout_p));
        o.set("dropout_model", Value::Str(self.dropout_model.to_string()));
        o.set("dropout_burst", Value::Num(self.dropout_burst));
        o.set("rounds", Value::Num(self.rounds as f64));
        o.set("scheme", Value::Str(self.scheme.to_string()));
        o.set("policy", Value::Str(self.policy.to_string()));
        o.set("local_steps", Value::Num(self.local_steps as f64));
        o.set("lr", Value::Num(self.lr as f64));
        o.set("train_samples", Value::Num(self.train_samples as f64));
        o.set("test_samples", Value::Num(self.test_samples as f64));
        o.set("partition", Value::Str(self.partition.to_string()));
        o.set("alpha", Value::Num(self.alpha));
        o.set("skew_zipf", Value::Num(self.skew_zipf));
        o.set("aggregation", Value::Str(self.aggregation.to_string()));
        o.set("transmit", Value::Str(self.transmit.to_string()));
        o.set("snr_db", Value::Num(self.channel.snr_db as f64));
        o.set("pilot_len", Value::Num(self.channel.pilot_len as f64));
        o.set("pilot_noise_var", Value::Num(self.channel.pilot_noise_var as f64));
        o.set("truncation", Value::Num(self.channel.truncation as f64));
        o.set("perfect_csi", Value::Bool(self.channel.perfect_csi));
        o.set("channel_model", Value::Str(self.channel.model.to_string()));
        o.set("rho", Value::Num(self.channel.rho as f64));
        o.set("path_loss_exp", Value::Num(self.channel.path_loss_exp as f64));
        o.set("shadowing_db", Value::Num(self.channel.shadowing_db as f64));
        o.set("cell_radius", Value::Num(self.channel.cell_radius as f64));
        o.set("plateau_patience", Value::Num(self.plateau_patience as f64));
        o.set("energy_budget_j", Value::Num(self.energy_budget_j));
        o.set("seed", Value::from_u64(self.seed));
        o.set(
            "init_params",
            match &self.init_params {
                Some(p) => Value::Str(p.display().to_string()),
                None => Value::Null,
            },
        );
        o.set("workers", Value::Num(self.workers as f64));
        o.set("threads", Value::Num(self.threads as f64));
        o.set("out_dir", Value::Str(self.out_dir.display().to_string()));
        o.set("eval_every", Value::Num(self.eval_every as f64));
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::FadingKind;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_fields() {
        let mut c = RunConfig::default();
        c.clients_per_round = 20;
        assert!(c.validate().is_err());

        let mut c = RunConfig::default();
        c.clients = 16; // 16 % 3 groups != 0
        c.clients_per_round = 16;
        assert!(c.validate().is_err());

        let mut c = RunConfig::default();
        c.lr = -1.0;
        assert!(c.validate().is_err());

        let mut c = RunConfig::default();
        c.rounds = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_overrides_apply() {
        let mut c = RunConfig::default();
        let v = json::parse(
            r#"{"rounds": 7, "scheme": "8,8,8", "snr_db": 12.5,
                "aggregation": "digital", "perfect_csi": true,
                "packed_planes": false}"#,
        )
        .unwrap();
        c.apply_json(&v).unwrap();
        assert_eq!(c.rounds, 7);
        assert_eq!(c.scheme.to_string(), "8,8,8");
        assert_eq!(c.channel.snr_db, 12.5);
        assert_eq!(c.aggregation, Aggregation::Digital);
        assert!(c.channel.perfect_csi);
        assert!(!c.packed_planes, "packed_planes default is on; override off");
    }

    #[test]
    fn threads_knob_validates_and_overrides() {
        let mut c = RunConfig::default();
        c.threads = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.apply_json(&json::parse(r#"{"threads": 4}"#).unwrap()).unwrap();
        assert_eq!(c.threads, 4);
        c.validate().unwrap();
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = RunConfig::default();
        let v = json::parse(r#"{"roundz": 7}"#).unwrap();
        assert!(c.apply_json(&v).is_err());
    }

    #[test]
    fn provenance_roundtrip() {
        // every field off its default, including the ones a logged config
        // historically lost (truncation, pilot_noise_var, out_dir,
        // init_params) and a seed beyond f64's exact integer range
        let mut c = RunConfig::default();
        c.artifacts_dir = PathBuf::from("elsewhere/artifacts");
        c.variant = "wide".into();
        c.clients = 30;
        c.clients_per_round = 10;
        c.selection = SelectionKind::Sampled;
        c.shard_size = 4;
        c.pipeline_depth = 2;
        c.packed_planes = false; // off its default (true)
        c.deadline_s = 0.5;
        c.compute_s = 0.1;
        c.latency_jitter = 0.5;
        c.slot_s = 0.01;
        c.dropout_p = 0.15;
        c.dropout_model = DropoutKind::Bursty;
        c.dropout_burst = 5.0;
        c.rounds = 7;
        c.scheme = Scheme::parse("24,12,6").unwrap();
        c.policy = PolicyKind::SnrAdaptive;
        c.local_steps = 3;
        c.lr = 0.125;
        c.train_samples = 600;
        c.test_samples = 120;
        c.partition = PartitionKind::Dirichlet;
        c.alpha = 0.1;
        c.skew_zipf = 1.5;
        c.aggregation = Aggregation::Digital;
        c.transmit = Transmit::Weights;
        c.channel.snr_db = 7.5;
        c.channel.pilot_len = 8;
        c.channel.pilot_noise_var = 0.125;
        c.channel.truncation = 0.25;
        c.channel.perfect_csi = true;
        c.channel.model = FadingKind::GaussMarkov;
        c.channel.rho = 0.875;
        c.channel.path_loss_exp = 2.5;
        c.channel.shadowing_db = 4.0;
        c.channel.cell_radius = 250.0;
        c.plateau_patience = 3;
        c.energy_budget_j = 0.75;
        c.seed = (1u64 << 53) + 12345;
        c.init_params = Some(PathBuf::from("runs/warm.f32.bin"));
        c.workers = 2;
        c.threads = 4;
        c.out_dir = PathBuf::from("runs/prov");
        c.eval_every = 2;

        // serialize -> text -> parse -> apply onto a default config
        let text = c.to_json().to_string();
        let mut c2 = RunConfig::default();
        c2.apply_json(&json::parse(&text).unwrap()).unwrap();
        assert_eq!(c2, c, "logged config must reproduce the run exactly");

        // None init_params roundtrips too (emitted as null)
        let d = RunConfig::default();
        let mut d2 = RunConfig::default();
        d2.init_params = Some(PathBuf::from("stale"));
        d2.apply_json(&d.to_json()).unwrap();
        assert_eq!(d2, d);
    }

    #[test]
    fn seed_parsing_is_exact_and_strict() {
        let mut c = RunConfig::default();
        let big = u64::MAX - 7;
        c.apply_json(&json::parse(&format!("{{\"seed\": {big}}}")).unwrap())
            .unwrap();
        assert_eq!(c.seed, big, "seeds above 2^53 must not be corrupted");
        assert!(c.apply_json(&json::parse(r#"{"seed": 1.5}"#).unwrap()).is_err());
        assert!(c.apply_json(&json::parse(r#"{"seed": -4}"#).unwrap()).is_err());
    }

    #[test]
    fn policy_and_channel_model_parse() {
        assert_eq!("static".parse::<PolicyKind>().unwrap(), PolicyKind::Static);
        assert_eq!(
            "snr-adaptive".parse::<PolicyKind>().unwrap(),
            PolicyKind::SnrAdaptive
        );
        assert_eq!(
            "loss-plateau".parse::<PolicyKind>().unwrap(),
            PolicyKind::LossPlateau
        );
        assert_eq!(
            "energy_budget".parse::<PolicyKind>().unwrap(),
            PolicyKind::EnergyBudget
        );
        assert!("smoke".parse::<PolicyKind>().is_err());
        assert_eq!(
            "gauss_markov".parse::<FadingKind>().unwrap(),
            FadingKind::GaussMarkov
        );
        assert_eq!(
            "path-loss".parse::<FadingKind>().unwrap(),
            FadingKind::PathLoss
        );
        let mut c = RunConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"policy": "snr-adaptive", "channel_model": "awgn"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.policy, PolicyKind::SnrAdaptive);
        assert_eq!(c.channel.model, FadingKind::Awgn);
    }

    #[test]
    fn channel_realism_knobs_validate() {
        let mut c = RunConfig::default();
        c.channel.rho = 1.0; // AR(1) requires rho < 1
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.channel.rho = -0.1;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.channel.rho = 0.95;
        c.validate().unwrap();

        let mut c = RunConfig::default();
        c.channel.model = FadingKind::PathLoss;
        c.validate().unwrap();
        c.channel.cell_radius = 5.0; // inside the reference distance
        assert!(c.validate().is_err());
        // the radius knob is only checked for the model that reads it
        c.channel.model = FadingKind::Rayleigh;
        c.validate().unwrap();

        let mut c = RunConfig::default();
        c.plateau_patience = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.energy_budget_j = 0.0;
        assert!(c.validate().is_err());

        // JSON overrides reach the new knobs
        let mut c = RunConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"channel_model": "gauss_markov", "rho": 0.9,
                    "path_loss_exp": 2.2, "shadowing_db": 8.0,
                    "cell_radius": 400.0, "plateau_patience": 2,
                    "energy_budget_j": 1.25}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.channel.model, FadingKind::GaussMarkov);
        assert_eq!(c.channel.rho, 0.9);
        assert_eq!(c.channel.cell_radius, 400.0);
        assert_eq!(c.plateau_patience, 2);
        assert_eq!(c.energy_budget_j, 1.25);
        c.validate().unwrap();
    }

    #[test]
    fn selection_and_shard_size_parse_and_roundtrip() {
        assert_eq!("auto".parse::<SelectionKind>().unwrap(), SelectionKind::Auto);
        assert_eq!(
            "uniform".parse::<SelectionKind>().unwrap(),
            SelectionKind::Uniform
        );
        assert_eq!(
            "sampled".parse::<SelectionKind>().unwrap(),
            SelectionKind::Sampled
        );
        assert_eq!("floyd".parse::<SelectionKind>().unwrap(), SelectionKind::Sampled);
        assert_eq!(
            "round-robin".parse::<SelectionKind>().unwrap(),
            SelectionKind::RoundRobin
        );
        assert!("lottery".parse::<SelectionKind>().is_err());

        let mut c = RunConfig::default();
        c.apply_json(
            &json::parse(r#"{"selection": "sampled", "shard_size": 16}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.selection, SelectionKind::Sampled);
        assert_eq!(c.shard_size, 16);
        c.validate().unwrap();
        // shard_size 0 (one shard) and any positive value are both valid
        c.shard_size = 0;
        c.validate().unwrap();
        c.shard_size = 10_000;
        c.validate().unwrap();
    }

    #[test]
    fn shard_len_clamps_to_the_round() {
        let mut c = RunConfig::default();
        c.shard_size = 0; // one whole-round shard
        assert_eq!(c.shard_len(15), 15);
        c.shard_size = 4;
        assert_eq!(c.shard_len(15), 4);
        c.shard_size = 99; // larger than the round: clamp to K
        assert_eq!(c.shard_len(15), 15);
        c.shard_size = 4; // smaller round than the shard
        assert_eq!(c.shard_len(3), 3);
    }

    #[test]
    fn robustness_knobs_parse_validate_and_roundtrip() {
        assert_eq!("iid".parse::<DropoutKind>().unwrap(), DropoutKind::Iid);
        assert_eq!("bernoulli".parse::<DropoutKind>().unwrap(), DropoutKind::Iid);
        assert_eq!("bursty".parse::<DropoutKind>().unwrap(), DropoutKind::Bursty);
        assert_eq!("markov".parse::<DropoutKind>().unwrap(), DropoutKind::Bursty);
        assert_eq!("gilbert".parse::<DropoutKind>().unwrap(), DropoutKind::Bursty);
        assert!("flaky".parse::<DropoutKind>().is_err());

        // defaults: straggler model fully off
        let c = RunConfig::default();
        assert!(!c.straggler_enabled());
        c.validate().unwrap();

        // JSON overrides reach every robustness knob
        let mut c = RunConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"pipeline_depth": 2, "deadline_s": 0.4, "compute_s": 0.08,
                    "latency_jitter": 0.3, "slot_s": 0.002, "dropout_p": 0.1,
                    "dropout_model": "bursty", "dropout_burst": 4.0}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.pipeline_depth, 2);
        assert_eq!(c.deadline_s, 0.4);
        assert_eq!(c.compute_s, 0.08);
        assert_eq!(c.latency_jitter, 0.3);
        assert_eq!(c.slot_s, 0.002);
        assert_eq!(c.dropout_p, 0.1);
        assert_eq!(c.dropout_model, DropoutKind::Bursty);
        assert_eq!(c.dropout_burst, 4.0);
        assert!(c.straggler_enabled());
        c.validate().unwrap();

        // range checks
        let mut c = RunConfig::default();
        c.dropout_p = 1.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.dropout_p = -0.1;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.dropout_burst = 0.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.deadline_s = -1.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.compute_s = 0.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.latency_jitter = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.slot_s = f64::INFINITY;
        assert!(c.validate().is_err());
    }

    #[test]
    fn partition_knobs_parse_validate_and_roundtrip() {
        assert_eq!("iid".parse::<PartitionKind>().unwrap(), PartitionKind::Iid);
        assert_eq!(
            "dirichlet".parse::<PartitionKind>().unwrap(),
            PartitionKind::Dirichlet
        );
        assert_eq!("dir".parse::<PartitionKind>().unwrap(), PartitionKind::Dirichlet);
        assert_eq!(
            "non-iid".parse::<PartitionKind>().unwrap(),
            PartitionKind::Dirichlet
        );
        assert!("sorted".parse::<PartitionKind>().is_err());

        // JSON overrides reach the partition knobs
        let mut c = RunConfig::default();
        c.apply_json(
            &json::parse(
                r#"{"partition": "dirichlet", "alpha": 0.1, "skew_zipf": 1.2}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.partition, PartitionKind::Dirichlet);
        assert_eq!(c.alpha, 0.1);
        assert_eq!(c.skew_zipf, 1.2);
        c.validate().unwrap();

        // range checks: alpha must be positive, skew_zipf non-negative
        let mut c = RunConfig::default();
        c.alpha = 0.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.alpha = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.skew_zipf = -0.5;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.skew_zipf = f64::INFINITY;
        assert!(c.validate().is_err());
    }

    #[test]
    fn aggregation_parse() {
        assert_eq!("ota".parse::<Aggregation>().unwrap(), Aggregation::OtaAnalog);
        assert_eq!(
            "FEDAVG".parse::<Aggregation>().unwrap(),
            Aggregation::Ideal
        );
        assert!("smoke".parse::<Aggregation>().is_err());
    }
}
